"""Batched serving example: continuous batching over a reduced model.

    PYTHONPATH=src python examples/serve_batch.py
"""
import time

import jax
import numpy as np

from repro.configs.registry import get_config
from repro.models import api
from repro.serve.engine import Request, ServingEngine


def main():
    cfg = get_config("gemma2-2b", reduced=True)
    params = api.init_params(jax.random.PRNGKey(1), cfg)
    eng = ServingEngine(cfg, params, n_slots=4, max_seq=96)

    rng = np.random.default_rng(3)
    reqs = [Request(i, rng.integers(1, cfg.vocab, size=6).astype(np.int32),
                    max_new_tokens=12) for i in range(10)]
    t0 = time.perf_counter()
    for r in reqs:
        eng.submit(r)
    eng.run(max_ticks=5_000)
    dt = time.perf_counter() - t0
    toks = sum(len(r.generated) for r in reqs)
    print(f"{sum(r.done for r in reqs)}/{len(reqs)} done, "
          f"{toks} tokens in {dt:.2f}s ({toks/dt:.1f} tok/s)")
    for r in reqs[:4]:
        print(f"  req{r.request_id}: prompt={list(r.prompt)} "
              f"-> {r.generated}")


if __name__ == "__main__":
    main()
