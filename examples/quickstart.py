"""Quickstart: characterise a power sensor black-box, then measure a
workload's energy the naive way and the paper's good-practice way.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (CalibrationStore, GoodPracticeConfig,
                        GroundTruthMeter, OnboardSensor, Workload,
                        measure_good_practice, measure_naive)
from repro.core import load as loads
from repro.core import profiles


def main():
    # 1. An A100-class sensor: 100 ms update period, but only a 25 ms
    #    averaging window — 75 % of the runtime is never observed.
    profile = profiles.get("a100")
    sensor = OnboardSensor(profile, seed=42)
    pmd = GroundTruthMeter(seed=7)          # external power meter

    # 2. Characterise it black-box (the paper's micro-benchmarks).
    store = CalibrationStore("/tmp/repro_calib")
    calib = store.get_or_characterise("gpu0", sensor, pmd)
    print(f"update period : {calib.update_period_s*1e3:6.1f} ms")
    print(f"boxcar window : {calib.window_s*1e3:6.1f} ms")
    print(f"sampled frac  : {calib.sampled_fraction:6.2f}")
    print(f"gain / offset : {calib.gain:.4f} / {calib.offset_w:+.2f} W")

    # 3. A bursty workload: 60 ms hot phase + 40 ms cool phase.
    wl = Workload("bursty", loads.multi_phase_workload(
        [(0.060, 230.0), (0.040, 140.0)]))
    truth = wl.true_energy_j

    # 4. Naive single-shot vs good practice.
    sensor2 = OnboardSensor(profile, seed=43)
    naive = measure_naive(sensor2, wl)
    est = measure_good_practice(sensor2, wl, calib,
                                GoodPracticeConfig(apply_calibration=True))
    print(f"\ntruth          : {truth:8.2f} J/rep")
    print(f"naive          : {naive:8.2f} J/rep ({(naive-truth)/truth:+.1%})")
    print(f"good practice  : {est.joules_per_rep:8.2f} J/rep "
          f"({est.error_vs(truth):+.1%})  ± {est.std_j:.2f} J")


if __name__ == "__main__":
    main()
