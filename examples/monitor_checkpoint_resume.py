"""Kill a live fleet monitor mid-stream, restore it, and keep serving.

A 2k-device mixed-scenario fleet streams poll slabs into a
``MonitorService`` while a ``MonitorQueryService`` answers batched
dashboard queries against its immutable snapshots.  Halfway through,
the monitor is checkpointed (``save_monitor`` — one step per ingest
epoch, atomic-rename manifest layout) and thrown away; a *restored*
monitor ingests the remaining slabs and the demo verifies that every
query answer is bitwise identical to an uninterrupted run.

Run:  PYTHONPATH=src python examples/monitor_checkpoint_resume.py [n_devices]
"""
import sys
import tempfile
import time

import numpy as np

from repro.core import load as loads
from repro.core.fleet_engine import SensorBank
from repro.core.stream import (MonitorService, restore_monitor,
                               save_monitor)
from repro.serve.monitor_service import MonitorQuery, MonitorQueryService

N = int(sys.argv[1]) if len(sys.argv) > 1 else 2_000


def poll_slabs(n):
    names = (["a100"] * (n // 2) + ["h100_instant"] * (n // 4)
             + ["v100"] * (n - n // 2 - n // 4))
    ws = loads.mixed_fleet_workloads(n, seed=7, as_bank=True)
    bank = SensorBank.from_catalog(names, seeds=np.arange(n))
    tlb = ws.timeline_bank
    tlb = tlb.shift(0.3 - tlb.t_start)
    bank.attach(tlb, t_end=tlb.t_end + 1.0)
    t1 = float(np.max(tlb.t_end) + 0.5)
    return list(bank.iter_poll_slabs(0.0, t1, period_s=0.005, tick_s=0.5,
                                     grid=True))


def serve_some(svc, t_hi):
    qs = [MonitorQuery.fleet_energy(t) for t in
          np.linspace(0.1, max(t_hi - 0.1, 0.1), 16)]
    qs += [MonitorQuery.fleet_energy(), MonitorQuery.by_label(),
           MonitorQuery.energy_between(0.2, max(t_hi - 0.2, 0.2))]
    tickets = [svc.submit(q) for q in qs]
    res = svc.flush()
    return res[tickets[-3]]          # the since-start FleetEnergy


def main() -> None:
    slabs = poll_slabs(N)
    half = len(slabs) // 2
    print(f"{N} devices, {len(slabs)} poll slabs "
          f"({sum(v.size for _, _, v in slabs)} samples)")

    # --- uninterrupted reference run -----------------------------------
    ref = MonitorService(N, ring_slots=8)
    for dev, ts, vals in slabs:
        ref.ingest_grid(dev, ts, vals)

    # --- live run: ingest + serve, checkpoint at a slab boundary -------
    live = MonitorService(N, ring_slots=8)
    svc = MonitorQueryService(live)
    t_hi = 0.0
    for dev, ts, vals in slabs[:half]:
        live.ingest_grid(dev, ts, vals)
        t_hi = max(t_hi, float(ts[-1]))
        fe = serve_some(svc, t_hi)
    print(f"served while ingesting: {svc.stats()['n_answered']} queries, "
          f"cache hit rate {svc.stats()['cache_hit_rate']:.2f}, "
          f"fleet so far {fe.total_j / 1e3:.1f} kJ")

    ckpt = tempfile.mkdtemp(prefix="monitor_ckpt_")
    t0 = time.perf_counter()
    save_monitor(live, ckpt)
    print(f"checkpointed epoch {live.epoch} -> {ckpt} "
          f"({(time.perf_counter() - t0) * 1e3:.0f} ms)")
    del live, svc                    # "the process died here"

    # --- restore and finish the stream ---------------------------------
    resumed = restore_monitor(ckpt)
    svc = MonitorQueryService(resumed)
    print(f"restored at epoch {resumed.epoch}; resuming stream")
    for dev, ts, vals in slabs[half:]:
        resumed.ingest_grid(dev, ts, vals)
        t_hi = max(t_hi, float(ts[-1]))
        serve_some(svc, t_hi)

    # --- bitwise parity with the uninterrupted run ---------------------
    checks = {
        "fleet_energy": (ref.fleet_energy().per_device_j,
                         resumed.fleet_energy().per_device_j),
        "energy_between": (ref.energy_between(0.5, t_hi - 0.5)[0],
                           resumed.energy_between(0.5, t_hi - 0.5)[0]),
        "window_energy": (ref.window_energy(t=t_hi - 0.3),
                          resumed.window_energy(t=t_hi - 0.3)),
        "update_period_s": (ref.update_period_s(),
                            resumed.update_period_s()),
    }
    for name, (a, b) in checks.items():
        same = (np.array_equal(a, b, equal_nan=True))
        print(f"  {name:16s} bitwise equal: {same}")
        assert same, name
    assert ref.counters == resumed.counters
    print("resume is bitwise-exact; final fleet "
          f"{resumed.fleet_energy().total_j / 1e3:.1f} kJ over "
          f"{resumed.counters['accepted']} samples")


if __name__ == "__main__":
    main()
