"""Monitor a 10k-device mixed-scenario fleet *live*.

Replays a heterogeneous fleet — training pods, Poisson inference
serving, idle/maintenance, diurnal cycles — through the streaming
monitor tick by tick, printing the running naive vs §5-corrected fleet
energy and the convergence of the online update-period estimates, then
cross-checks the final window energies against the offline
``integrate_polled`` ground truth on the same reading schedules.

Run:  PYTHONPATH=src python examples/live_fleet_monitor.py [n_devices]
"""
import sys
import time

import numpy as np

from repro.core import load as loads
from repro.core.stream import stream_fleet
from repro.core.telemetry import FleetLedger

N = int(sys.argv[1]) if len(sys.argv) > 1 else 10_000


def main() -> None:
    names = (["a100"] * (N // 2) + ["h100_instant"] * (N // 4)
             + ["v100"] * (N - N // 2 - N // 4))
    ws = loads.mixed_fleet_workloads(N, seed=7, as_bank=True)

    print(f"streaming {N} devices (mixed scenarios) ...")
    last = {"t": 0.0}

    def progress(mon, t):
        if t - last["t"] < 0.25:
            return
        last["t"] = t
        naive_w = float(np.sum(mon.window_energy(t=t, corrected=False)))
        corr_w = float(np.sum(mon.window_energy(t=t, corrected=True)))
        sigma = mon.fleet_energy(corrected=True).sigma_worstcase_j
        that = mon.update_period_s()
        conv = int(np.sum(np.isfinite(that)))
        print(f"  t={t:5.2f}s  window naive={naive_w/1e3:8.1f} kJ  "
              f"corrected={corr_w/1e3:8.1f} kJ (±{sigma/1e3:.1f})  "
              f"period-est converged: {conv}/{N}")

    t0 = time.perf_counter()
    res = stream_fleet(N, profile=names, workload=ws, seed=7,
                       compare=True, progress=progress)
    wall = time.perf_counter() - t0
    mon = res.monitor

    print(f"\nstream done: {res.n_samples} samples in {wall:.1f} s "
          f"({res.n_samples / wall / 1e6:.2f} M samples/s), "
          f"monitor state {mon.nbytes() / 1e6:.0f} MB")

    dn = np.max(np.abs(res.naive_stream_j - res.naive_offline_j)
                / np.abs(res.naive_offline_j))
    dc = np.max(np.abs(res.corrected_stream_j - res.corrected_offline_j)
                / np.abs(res.corrected_offline_j))
    print(f"parity vs offline integrate_polled: naive {dn:.2e}, "
          f"corrected {dc:.2e} (max rel dev)")

    truth = ws.true_energies_j
    ne = np.mean(np.abs(res.naive_stream_j - truth) / truth)
    ce = np.mean(np.abs(res.corrected_stream_j - truth) / truth)
    print(f"mean abs error vs analytic truth: naive {ne * 100:.2f} %  ->  "
          f"corrected {ce * 100:.2f} %")

    that = mon.update_period_s()
    print("\nonline update-period estimates (converged devices):")
    for name in sorted(set(names)):
        sel = np.isfinite(that) & (np.asarray(names) == name)
        if np.any(sel):
            print(f"  {name:14s} median {np.median(that[sel]) * 1e3:6.1f} ms"
                  f"  over {int(sel.sum())} devices")

    print("\nper-scenario energy (since stream start, incl. idle tails):")
    for label, row in mon.by_label().items():
        print(f"  {label:10s} n={row['n_devices']:6d}  "
              f"total={row['total_j'] / 1e3:8.1f} kJ  "
              f"mean={row['mean_j']:7.1f} J")

    flags = mon.flags()
    print(f"\nhealth: {int(flags['silent'].sum())} silent, "
          f"{int(flags['anomalous'].sum())} anomalous, "
          f"{int(flags['drifting'].sum())} drifting")

    ledger = FleetLedger()
    ledger.register_monitor(mon)
    s = ledger.summary()
    print(f"ledger fold: {s.kwh:.2f} kWh ± {s.sigma_worstcase_j / 3.6e6:.2f} "
          f"(worst-case), ${s.cost_usd:.2f}")


if __name__ == "__main__":
    main()
