"""End-to-end driver: train a reduced LM for a few hundred steps with
fault-tolerant checkpointing and first-class energy accounting.

    PYTHONPATH=src python examples/train_mini_lm.py [--steps 200]

Kill it mid-run and re-run: it resumes exactly (optimizer, data stream and
the energy ledger all survive the restart).
"""
import argparse

from repro.configs.base import ShapeCell
from repro.configs.registry import get_config
from repro.optim.adamw import AdamWConfig
from repro.train.loop import LoopConfig, run_training
from repro.train.step import TrainConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_mini_lm")
    args = ap.parse_args()

    cfg = get_config("olmo-1b", reduced=True).replace(
        n_layers=4, d_model=128, d_ff=512)          # ~100M-class reduced
    shape = ShapeCell("mini", seq_len=128, global_batch=16, mode="train")
    tcfg = TrainConfig(
        microbatches=2,
        optim=AdamWConfig(lr_peak=3e-3, warmup_steps=20,
                          total_steps=args.steps))
    lcfg = LoopConfig(total_steps=args.steps, ckpt_every=50, log_every=20)
    out = run_training(cfg, shape, tcfg, lcfg, ckpt_dir=args.ckpt_dir)
    print(f"\nloss {out['losses'][0]:.3f} -> {out['final_loss']:.3f} "
          f"over {len(out['losses'])} steps")
    print("energy summary:", out["energy"])


if __name__ == "__main__":
    main()
