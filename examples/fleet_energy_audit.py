"""Fleet energy audit at datacentre scale: simulate a pod where every
chip has a part-time sensor with its own hidden gain/offset/phase error;
compare the naive fleet energy bill against the §5 good-practice one.

The audit runs through the batched engine (`repro.core.fleet_engine`):
one `SensorBank` holds all 4,096 chips and every trial dispatches the
whole fleet's reading matrix at once, so this demo takes ~1 s where the
per-device loop took minutes (and scales to 10k+; see benchmarks/fleet.py).

    PYTHONPATH=src python examples/fleet_energy_audit.py
"""
import time

import numpy as np

from repro.core import (CalibrationRecord, FleetLedger, SensorBank,
                        datacenter_projection)
from repro.core import load as loads
from repro.core import profiles
from repro.core.meter import (GoodPracticeConfig, Workload,
                              measure_good_practice_batch,
                              measure_naive_batch)


def main():
    profile = profiles.get("tpu_v5e_chip")   # 25/100 part-time class
    step = Workload("train_step", loads.multi_phase_workload(
        [(0.130, 215.0), (0.070, 165.0)]))   # compute + collective phases
    n_chips = 4096

    t0 = time.perf_counter()
    bank = SensorBank.from_catalog(profile.name, n=n_chips, base_seed=1000)
    calib = CalibrationRecord(
        "pod", profile.name, profile.update_period_s, profile.window_s,
        "instant", 0.25, sampled_fraction=profile.sampled_fraction)

    naive = measure_naive_batch(bank, step)
    est = measure_good_practice_batch(bank, step, calib,
                                      GoodPracticeConfig(n_trials=2))
    wall = time.perf_counter() - t0

    fleet = FleetLedger(price_usd_per_kwh=0.35)
    fleet.register_batch(est.joules_per_rep, duration_s=step.duration_s)
    s = fleet.summary()

    truth = step.true_energy_j * n_chips
    naive_total = float(np.sum(naive))
    err = est.error_vs(step.true_energy_j)
    print(f"chips audited        : {s.n_devices}  ({wall:.2f}s batched)")
    print(f"true energy          : {truth:9.1f} J/step")
    print(f"naive fleet reading  : {naive_total:9.1f} J/step "
          f"({(naive_total-truth)/truth:+.1%})")
    print(f"good-practice total  : {s.total_j:9.1f} J/step "
          f"({(s.total_j-truth)/truth:+.1%})")
    print(f"per-chip |err| p50/p99: {np.percentile(np.abs(err), 50):.2%} / "
          f"{np.percentile(np.abs(err), 99):.2%}")
    print(f"uncertainty (indep)  : {s.sigma_independent_j:7.1f} J  (1/√N)")
    print(f"uncertainty (worst)  : {s.sigma_worstcase_j:7.1f} J  "
          "(correlated resistor lot)")
    proj = datacenter_projection()
    print(f"\n10k-GPU projection of NVIDIA's spec gap: "
          f"${proj['annual_err_usd']:,.0f}/yr unaccounted")


if __name__ == "__main__":
    main()
