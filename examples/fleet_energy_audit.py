"""Fleet energy audit at datacentre scale — now with a *heterogeneous*
fleet: every chip runs its own job (training pods, bursty Poisson-arrival
inference serving, idle/maintenance windows, diurnal cycles), each with a
part-time sensor carrying its own hidden gain/offset/phase error.  The
naive fleet energy bill is compared against the §5 good-practice one, with
the error broken down per workload scenario — the paper's Fig. 18 spread,
emergent from workload mix rather than seed noise.

The audit runs through the batched engine (`repro.core.fleet_engine`):
per-device timelines are stacked into one `TimelineBank`, one `SensorBank`
holds all 4,096 chips, and every trial dispatches the whole fleet's
reading matrix at once — ~1 s where the per-device loop took minutes (and
scales to 10k+; see benchmarks/fleet.py).

    PYTHONPATH=src python examples/fleet_energy_audit.py
"""
import time

import numpy as np

from repro.core import FleetLedger, datacenter_projection
from repro.core import load as loads
from repro.core import profiles
from repro.core.fleet_engine import fleet_audit


def main():
    profile = profiles.get("tpu_v5e_chip")   # 25/100 part-time class
    n_chips = 4096

    # every chip its own timeline, drawn from the default scenario mix
    workloads = loads.mixed_fleet_workloads(n_chips, seed=1000)

    t0 = time.perf_counter()
    res = fleet_audit(n_chips, profile=profile.name, workload=workloads,
                      seed=1000, good_practice=True, n_trials=2)
    wall = time.perf_counter() - t0

    fleet = FleetLedger(price_usd_per_kwh=0.35)
    fleet.register_batch(res.gp_j, duration_s=float(np.mean(
        [w.duration_s for w in workloads])),
        labels=np.array(res.scenarios, dtype=object))
    s = fleet.summary()

    truth = float(np.sum(res.true_j))
    naive_total = float(np.sum(res.naive_j))
    print(f"chips audited        : {s.n_devices}  ({wall:.2f}s batched, "
          "every chip its own timeline)")
    print(f"true energy          : {truth:9.1f} J/rep")
    print(f"naive fleet reading  : {naive_total:9.1f} J/rep "
          f"({(naive_total-truth)/truth:+.1%})")
    print(f"good-practice total  : {s.total_j:9.1f} J/rep "
          f"({(s.total_j-truth)/truth:+.1%})")
    print(f"uncertainty (indep)  : {s.sigma_independent_j:7.1f} J  (1/√N)")
    print(f"uncertainty (worst)  : {s.sigma_worstcase_j:7.1f} J  "
          "(correlated resistor lot)")

    print("\nper-scenario breakdown (naive → good practice, mean |err|):")
    by_naive = res.by_scenario()
    by_gp = res.by_scenario(res.gp_err)
    by_energy = fleet.by_label()
    for label in sorted(by_naive):
        n = by_naive[label]["n_devices"]
        print(f"  {label:10s} n={n:5d}  "
              f"{by_naive[label]['mean_abs_err']:6.2%} → "
              f"{by_gp[label]['mean_abs_err']:6.2%}   "
              f"({by_energy[label].total_j:8.1f} J)")

    proj = datacenter_projection()
    print(f"\n10k-GPU projection of NVIDIA's spec gap: "
          f"${proj['annual_err_usd']:,.0f}/yr unaccounted")


if __name__ == "__main__":
    main()
