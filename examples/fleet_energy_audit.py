"""Fleet energy audit: simulate a 256-chip pod training run where every
chip has a part-time sensor with its own hidden gain error; compare the
naive fleet energy bill against the calibrated good-practice one.

    PYTHONPATH=src python examples/fleet_energy_audit.py
"""
import numpy as np

from repro.core import (CalibrationRecord, EnergyLedger, FleetLedger,
                        OnboardSensor, datacenter_projection)
from repro.core import load as loads
from repro.core import profiles
from repro.core.meter import GoodPracticeConfig, Workload, \
    measure_good_practice, measure_naive


def main():
    profile = profiles.get("tpu_v5e_chip")   # 25/100 part-time class
    step = Workload("train_step", loads.multi_phase_workload(
        [(0.130, 215.0), (0.070, 165.0)]))   # compute + collective phases
    fleet = FleetLedger(price_usd_per_kwh=0.35)

    naive_total = 0.0
    n_chips = 32                             # sample of the pod (fast demo)
    for chip in range(n_chips):
        sensor = OnboardSensor(profile, seed=1000 + chip)
        calib = CalibrationRecord(
            f"chip{chip}", profile.name, profile.update_period_s,
            profile.window_s, "instant", 0.25,
            sampled_fraction=profile.sampled_fraction)
        naive = measure_naive(OnboardSensor(profile, seed=1000 + chip), step)
        est = measure_good_practice(sensor, step, calib,
                                    GoodPracticeConfig(n_trials=2),
                                    seed=chip)
        led = EnergyLedger(device_id=f"chip{chip}")
        led.append(0, 0.0, step.duration_s, naive, est.joules_per_rep,
                   0.05 * est.joules_per_rep)
        fleet.register(led, calib)
        naive_total += naive

    s = fleet.summary()
    truth = step.true_energy_j * n_chips
    print(f"chips sampled        : {s.n_devices}")
    print(f"true energy          : {truth:9.1f} J/step")
    print(f"naive fleet reading  : {naive_total:9.1f} J/step "
          f"({(naive_total-truth)/truth:+.1%})")
    print(f"good-practice total  : {s.total_j:9.1f} J/step "
          f"({(s.total_j-truth)/truth:+.1%})")
    print(f"uncertainty (indep)  : {s.sigma_independent_j:7.1f} J")
    print(f"uncertainty (worst)  : {s.sigma_worstcase_j:7.1f} J")
    proj = datacenter_projection()
    print(f"\n10k-GPU projection of NVIDIA's spec gap: "
          f"${proj['annual_err_usd']:,.0f}/yr unaccounted")


if __name__ == "__main__":
    main()
