"""Regenerate ``repro.core.engine_backend._ziggurat`` from the local numpy.

The vectorized per-seed RNG (:mod:`repro.core.engine_backend.vecrng`)
replays ``np.random.Generator``'s ziggurat samplers bitwise, which needs
the exact 256-entry acceptance tables compiled into numpy
(``numpy/random/src/distributions/ziggurat_constants.h``).  Those tables
are not exposed at the Python level and recomputing them from the
Marsaglia–Tsang recurrence lands tens of ulps away (numpy's header was
generated at a different precision), so this script *extracts* them
empirically instead:

* ``wi``/``we`` (the strip widths) are pinned exactly: every accepted
  first draw of a fresh ``default_rng(seed)`` satisfies
  ``value == fl(rabs * wi[idx])`` for the known raw 64-bit word, and a
  few hundred such exact-product constraints per strip leave exactly one
  float64 candidate;
* ``ki``/``ke`` (the acceptance thresholds) and ``fi``/``fe`` (the pdf
  ordinates) are derived from the extracted widths with the published
  generation formulas — a potential off-by-one-ulp there only matters
  when a draw lands exactly on the threshold ulp (~2⁻⁵² per draw), and
  the deep-parity test sweep (`tests/test_vecrng.py`) guards the result.

Run from the repo root (writes the module in place)::

    PYTHONPATH=src python tools/gen_vecrng_tables.py

The output module is committed; re-running is only needed if numpy ever
changes its ziggurat constants (it has not since the Generator API
landed in 1.17).
"""
from __future__ import annotations

import sys

import numpy as np

from repro.core.engine_backend.vecrng import (NOR_R, EXP_R, VecStreams,
                                              _U64 as U64)

OUT = "src/repro/core/engine_backend/_ziggurat.py"
K = 400_000


def _refine(ra: np.ndarray, ar: np.ndarray) -> float:
    """The unique float64 ``w`` with ``fl(ra*w) == ar`` for all pairs."""
    w0 = float(np.median(ar / ra))
    cands = [w0]
    up = down = w0
    for _ in range(10):
        up = np.nextafter(up, np.inf)
        down = np.nextafter(down, -np.inf)
        cands += [up, down]
    ok = [c for c in cands if np.all(ra * c == ar)]
    if len(ok) != 1:
        raise RuntimeError(f"width not pinned uniquely ({len(ok)} candidates)")
    return ok[0]


def _extract_widths(first_value, idx, mant) -> np.ndarray:
    out = np.zeros(256)
    for b in range(256):
        m = (idx == b) & (mant > 0)
        ra = mant[m].astype(np.float64)
        ar = first_value[m]
        ratio = ar / ra
        med = np.median(ratio)
        inl = np.abs(ratio / med - 1.0) < 1e-9   # drop rejected-then-redrawn
        out[b] = _refine(ra[inl], ar[inl])
    return out


def main() -> None:
    seeds = np.arange(K, dtype=np.uint64)
    streams = VecStreams(seeds)
    raw0 = streams._next_raw()

    # normal layout: [0:8) idx, [8] sign, [9:61) mantissa
    idx = (raw0 & U64(0xff)).astype(np.int64)
    mant = (raw0 >> U64(9)) & U64(0x000fffffffffffff)
    refs = np.empty(K)
    for s in range(K):
        refs[s] = np.random.default_rng(s).standard_normal()
    wi = _extract_widths(np.abs(refs), idx, mant)

    # exponential layout: drop 3, [0:8) idx, rest mantissa
    ri = raw0 >> U64(3)
    eidx = (ri & U64(0xff)).astype(np.int64)
    emant = ri >> U64(8)
    erefs = np.empty(K)
    for s in range(K):
        erefs[s] = np.random.default_rng(s).standard_exponential()
    we = _extract_widths(erefs, eidx, emant)

    m1, m2 = 2.0 ** 52, 2.0 ** 53
    x = wi * m1
    ki = np.zeros(256, dtype=np.uint64)
    ki[0] = np.uint64(NOR_R / wi[0])
    for i in range(1, 255):
        ki[i + 1] = np.uint64((x[i] / x[i + 1]) * m1)
    fi = np.exp(-0.5 * x * x)
    fi[0] = 1.0

    xe = we * m2
    ke = np.zeros(256, dtype=np.uint64)
    ke[0] = np.uint64(EXP_R / we[0])
    for i in range(1, 255):
        ke[i + 1] = np.uint64((xe[i] / xe[i + 1]) * m2)
    fe = np.exp(-xe)
    fe[0] = 1.0

    def fmt_u64(arr):
        words = [f"0x{int(v):016x}" for v in arr]
        lines = []
        for i in range(0, 256, 4):
            lines.append("    " + ", ".join(words[i:i + 4]) + ",")
        return "\n".join(lines)

    def fmt_f64(arr):
        return fmt_u64(arr.view(np.uint64))

    with open(OUT, "w") as fh:
        fh.write('"""Ziggurat acceptance tables '
                 '(generated — do not edit by hand).\n\n'
                 "Bit-exact copies of numpy's compiled "
                 "``ziggurat_constants.h`` tables, extracted\n"
                 "empirically by ``tools/gen_vecrng_tables.py`` "
                 "(see there for provenance).\n"
                 "Float tables are stored as uint64 bit patterns so no "
                 "decimal round-trip can\nperturb them.\n"
                 '"""\n'
                 "import numpy as np\n\n")
        for name, arr, kind in (("NORMAL_KI", ki, "u"),
                                ("NORMAL_WI", wi, "f"),
                                ("NORMAL_FI", fi, "f"),
                                ("EXP_KE", ke, "u"),
                                ("EXP_WE", we, "f"),
                                ("EXP_FE", fe, "f")):
            body = fmt_u64(arr) if kind == "u" else fmt_f64(arr)
            fh.write(f"_{name}_BITS = np.array([\n{body}\n"
                     "], dtype=np.uint64)\n")
            if kind == "u":
                fh.write(f"{name} = _{name}_BITS\n\n")
            else:
                fh.write(f"{name} = _{name}_BITS.view(np.float64)\n\n")
    print(f"wrote {OUT}")


if __name__ == "__main__":
    sys.exit(main())
