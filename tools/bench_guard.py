"""Guard fleet-benchmark throughput against a committed baseline.

``benchmarks/fleet.py`` writes ``BENCH_fleet.json``; this tool compares
the smoke-size throughput numbers (``devices_per_sec``) and the
workload-generation wall time (``wall_s_workload_gen``) against
``benchmarks/baselines/fleet_smoke.json`` with a generous multiplicative
tolerance, so a CI run fails only on order-of-magnitude regressions
(shared runners are far too noisy for tight thresholds).

Baseline format::

    {
      "tolerance_factor": 4.0,
      "floors":   {"heterogeneous.devices_per_sec": 1500.0, ...},
      "ceilings": {"heterogeneous.wall_s_workload_gen": 0.12, ...},
      "dominance": [
        {"left": "streaming.jax.samples_per_sec",
         "right": "streaming.numpy.samples_per_sec",
         "margin": 1.0}
      ],
      "scaling": [
        {"block": "sharded", "at": 4, "ref": 1,
         "min_efficiency": 0.7, "min_host_cores": 4}
      ]
    }

``floors`` fail when ``measured < baseline / factor`` (throughput
collapsed); ``ceilings`` fail when ``measured > baseline * factor``
(latency exploded); ``dominance`` entries compare two *measured*
metrics against each other — failing when ``left < right * margin`` —
which pins an ordering (e.g. the accelerated ingest tiers must never
fall behind the numpy reference) independent of the machine's absolute
speed, so it needs no tolerance factor.  ``scaling`` rules guard the
sharded-audit parallel efficiency: over a bench block shaped like
``{"host_cpu_count": C, "scaling": {"1": {"devices_per_sec": ...},
"4": {...}}}`` they fail when ``dps[at] / ((at/ref) * dps[ref]) <
min_efficiency``.  Forced host devices only express real parallelism
when backed by real cores, so the efficiency gate applies only where
``host_cpu_count >= min_host_cores`` (the recorded shard metrics must
exist everywhere — the block silently disappearing still fails).  Keys
are dotted paths into the bench JSON; a key missing from the bench file
fails the guard (the metric silently disappearing is itself a
regression).

Usage::

    python tools/bench_guard.py [--bench BENCH_fleet.json] \
        [--baseline benchmarks/baselines/fleet_smoke.json]
"""
from __future__ import annotations

import argparse
import json
import sys


def _lookup(tree: dict, dotted: str):
    node = tree
    for part in dotted.split("."):
        if not isinstance(node, dict) or part not in node:
            return None
        node = node[part]
    return node


def check(bench: dict, baseline: dict) -> list:
    factor = float(baseline.get("tolerance_factor", 4.0))
    failures = []
    for key, floor in baseline.get("floors", {}).items():
        got = _lookup(bench, key)
        if got is None:
            failures.append(f"{key}: missing from bench output")
        elif float(got) < float(floor) / factor:
            failures.append(f"{key}: {got:.1f} < floor {floor:.1f} "
                            f"/ {factor:g} (throughput regression)")
    for key, ceiling in baseline.get("ceilings", {}).items():
        got = _lookup(bench, key)
        if got is None:
            failures.append(f"{key}: missing from bench output")
        elif float(got) > float(ceiling) * factor:
            failures.append(f"{key}: {got:.3f}s > ceiling {ceiling:.3f}s "
                            f"× {factor:g} (latency regression)")
    for rule in baseline.get("dominance", []):
        lk, rk = rule["left"], rule["right"]
        margin = float(rule.get("margin", 1.0))
        left, right = _lookup(bench, lk), _lookup(bench, rk)
        if left is None:
            failures.append(f"{lk}: missing from bench output")
        elif right is None:
            failures.append(f"{rk}: missing from bench output")
        elif float(left) < float(right) * margin:
            failures.append(
                f"{lk}: {float(left):.1f} < {rk} ({float(right):.1f}) "
                f"× {margin:g} (ordering regression)")
    for rule in baseline.get("scaling", []):
        name = rule["block"]
        block = _lookup(bench, name)
        if not isinstance(block, dict):
            failures.append(f"{name}: missing from bench output")
            continue
        at, ref = str(rule.get("at", 4)), str(rule.get("ref", 1))
        d_at = _lookup(block, f"scaling.{at}.devices_per_sec")
        d_ref = _lookup(block, f"scaling.{ref}.devices_per_sec")
        if d_at is None or d_ref is None:
            failures.append(f"{name}.scaling: missing devices_per_sec "
                            f"at {ref} and/or {at} shards")
            continue
        min_cores = int(rule.get("min_host_cores", int(at)))
        cores = block.get("host_cpu_count")
        if cores is not None and int(cores) < min_cores:
            # forced host devices time-slice the same cores on this
            # machine — efficiency is unmeasurable, only shape is guarded
            continue
        speedup = int(at) / int(ref)
        eff = float(d_at) / (float(d_ref) * speedup)
        min_eff = float(rule.get("min_efficiency", 0.7))
        if eff < min_eff:
            failures.append(
                f"{name}.scaling: {eff:.2f} parallel efficiency at "
                f"{at} shards (vs {ref}) < {min_eff:g} "
                f"(scaling regression)")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--bench", default="BENCH_fleet.json")
    ap.add_argument("--baseline",
                    default="benchmarks/baselines/fleet_smoke.json")
    args = ap.parse_args(argv)
    with open(args.bench) as fh:
        bench = json.load(fh)
    with open(args.baseline) as fh:
        baseline = json.load(fh)
    failures = check(bench, baseline)
    if failures:
        print("bench_guard: FAIL")
        for f in failures:
            print(f"  - {f}")
        return 1
    checked = (len(baseline.get("floors", {}))
               + len(baseline.get("ceilings", {}))
               + len(baseline.get("dominance", []))
               + len(baseline.get("scaling", [])))
    print(f"bench_guard: OK ({checked} metrics within "
          f"{baseline.get('tolerance_factor', 4.0):g}x of baseline)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
