"""Regenerate the committed collector fixtures in ``tests/data/``.

Two recorded sample logs, deterministic (seeded, fixed epoch base — no
wall clock anywhere), exercising every parser path the collect tests
pin:

* ``daemon_sample.csv`` — daemon-style per-row CSV
  (``gpu_uuid,timestamp,power.draw,utilization``): 4 devices at 100 ms
  with a 5th joining two thirds in (the hot-add case), duplicate rows,
  out-of-order timestamps, malformed lines, blank lines, a repeated
  header from a "restarted" collector.
* ``smi_sample.csv`` — ``nvidia-smi --query-gpu`` CSV: bracketed-unit
  header, date timestamps, ``[N/A]`` / ``[Unknown Error]`` / ``ERR!``
  cells, a mid-stream ``mW`` unit variant, a repeated ``nounits``
  header section.

The expected parse accounting for both files is pinned in
``tests/test_collect.py`` (``FIXTURE_EXPECT``); regenerate with::

    PYTHONPATH=src python tools/gen_collect_fixture.py

and update those pins if you change anything here.
"""
from __future__ import annotations

import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

DATA = os.path.join(os.path.dirname(__file__), "..", "tests", "data")

EPOCH0 = 1700000000.0          # fixed base instant (no wall clock)
PERIOD = 0.1
UUIDS = [f"GPU-f1xt-{i:04d}" for i in range(5)]   # [4] joins late


def _power(rng: np.random.Generator, i: int, k: int) -> float:
    # a two-level square wave + noise: busy 280 W / idle 90 W phases
    busy = (k // 40 + i) % 2 == 0
    base = 280.0 if busy else 90.0
    return round(base + rng.normal(0.0, 2.0), 3)


def gen_daemon(path: str) -> None:
    rng = np.random.default_rng(1234)
    lines = ["gpu_uuid,timestamp,power.draw,utilization"]
    n_polls = 300
    for k in range(n_polls):
        t = EPOCH0 + PERIOD * k
        fleet = UUIDS[:4] if k < 200 else UUIDS          # hot-add at k=200
        for i, u in enumerate(fleet):
            lines.append(f"{u},{t!r},{_power(rng, i, k)},"
                         f"{int(rng.integers(0, 101))}")
        if k == 97:              # duplicate row (exact repeat)
            lines.append(lines[-1])
        if k == 120:             # out-of-order: re-send an old poll
            told = EPOCH0 + PERIOD * 60
            lines.append(f"{UUIDS[0]},{told!r},{_power(rng, 0, 60)},50")
        if k == 150:             # collector restart: header repeats
            lines.append("")
            lines.append("gpu_uuid,timestamp,power.draw,utilization")
        if k == 180:             # malformed rows
            lines.append(f"{UUIDS[1]},not-a-time,123.0,50")
            lines.append(f"{UUIDS[2]},{EPOCH0 + PERIOD * k!r}")
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")


def gen_smi(path: str) -> None:
    rng = np.random.default_rng(5678)
    hdr = "uuid, timestamp, power.draw [W], utilization.gpu [%]"
    lines = [hdr]
    n_polls = 240
    for k in range(n_polls):
        t = EPOCH0 + PERIOD * k
        from datetime import datetime, timezone
        dt = datetime.fromtimestamp(t, tz=timezone.utc)
        stamp = dt.strftime("%Y/%m/%d %H:%M:%S") + \
            f".{dt.microsecond // 1000:03d}"
        for i, u in enumerate(UUIDS[:4]):
            p = _power(rng, i, k)
            if k == 50 and i == 2:
                cell = "[N/A]"                       # driver hiccup
            elif k == 51 and i == 2:
                cell = "[Unknown Error]"
            elif k == 52 and i == 2:
                cell = "ERR!"
            elif k == 90 and i == 1:
                cell = f"{p * 1000:.0f} mW"          # unit variant
            else:
                cell = f"{p:.2f} W"
            u_cell = "[N/A]" if (k == 60 and i == 0) \
                else f"{int(rng.integers(0, 101))} %"
            lines.append(f"{u}, {stamp}, {cell}, {u_cell}")
        if k == 160:             # restarted capture under csv,nounits
            lines.append("uuid, timestamp, power.draw, utilization.gpu")
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")


def main() -> None:
    os.makedirs(DATA, exist_ok=True)
    gen_daemon(os.path.join(DATA, "daemon_sample.csv"))
    gen_smi(os.path.join(DATA, "smi_sample.csv"))
    from repro.collect import wire
    for name in ("daemon_sample.csv", "smi_sample.csv"):
        path = os.path.join(DATA, name)
        batch, c = wire.parse_log(path)
        print(f"{name}: {os.path.getsize(path)} bytes, "
              f"{len(batch)} samples, {c.as_dict()}")


if __name__ == "__main__":
    main()
