"""List top dot instructions by flops (with trip multipliers)."""
import os, re, sys, collections
os.environ["XLA_FLAGS"]="--xla_force_host_platform_device_count=" + os.environ.get("REPRO_DRYRUN_DEVICES","256")
sys.path.insert(0, "src")
import numpy as np
from repro.configs.registry import get_config
from repro.configs.base import get_shape
from repro.launch.dryrun import lower_cell
from repro.launch.mesh import make_production_mesh
from repro.launch import hlo as H

arch, shape = sys.argv[1], sys.argv[2]
cfg = get_config(arch)
mesh = make_production_mesh()
compiled, txt, _, _ = lower_cell(cfg, get_shape(shape), mesh)
comps = H._split_computations(txt)
mult = {n: 1.0 for n in comps}
for name, lines in comps.items():
    for line in lines:
        m = H._WHILE_RE.search(line)
        if m:
            trips = H._trip_count(comps.get(m.group(1), []))
            for t in (m.group(2), m.group(1)):
                if t in mult:
                    mult[t] = max(mult[t], trips * mult[name])
agg = collections.Counter(); cnt = collections.Counter()
for name, lines in comps.items():
    types = {}
    for line in lines:
        m = H._INSTR_RE.match(line.strip())
        if m: types[m.group(1)] = m.group(2)
    for line in lines:
        m = H._INSTR_RE.match(line.strip())
        if not m: continue
        dm = H._DOT_RE.match(m.group(2))
        if not dm: continue
        out_t, operands, lhs_cd = dm.group(1), dm.group(2), dm.group(3)
        _, out_shape = H._shape_of(out_t)
        lhs = operands.split(",")[0].strip().lstrip("%")
        _, lhs_shape = H._shape_of(types.get(lhs, ""))
        kk = 1
        for d in lhs_cd.split(","):
            if d and lhs_shape:
                i = int(d)
                if i < len(lhs_shape): kk *= lhs_shape[i]
        fl = 2.0*float(np.prod(out_shape))*kk if out_shape else 0.0
        op = re.search(r'op_name="([^"]*)"', line)
        opn = (op.group(1)[-90:] if op else "?")
        key = f"{out_t.split('{')[0].strip()} K={kk} | {opn}"
        agg[key] += fl * mult.get(name, 1.0); cnt[key] += int(mult.get(name,1.0))
total = sum(agg.values())
print(f"TOTAL {total:.3e} dot flops/device")
for k, fl in agg.most_common(18):
    print(f"{fl:11.3e} ({fl/total*100:5.1f}%) x{cnt[k]:4d} {k}")
