"""Generate the EXPERIMENTS.md roofline markdown table from artifacts."""
import glob, json, sys

rows = []
for fn in sorted(glob.glob("artifacts/dryrun/*.json")):
    art = json.load(open(fn))
    if art["status"] != "ok":
        continue
    r = art["roofline"]
    rows.append(r)

def fmt(r):
    return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['compute_s']*1e3:.1f} | {r['memory_s']*1e3:.1f} | "
            f"{r['collective_s']*1e3:.1f} | {r['bottleneck']} | "
            f"{r['useful_ratio']:.2f} | {r['roofline_fraction']:.3f} | "
            f"{r['peak_memory_per_device']/1e9:.1f} | "
            f"{'Y' if r['fits_hbm'] else 'N'} |")

print("| arch | shape | mesh | comp ms | mem ms | coll ms | bottleneck | useful | roofline frac | peak GB/dev | fits |")
print("|---|---|---|---|---|---|---|---|---|---|---|")
for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
    print(fmt(r))
