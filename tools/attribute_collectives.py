"""Attribute collective bytes to source jax ops via HLO metadata op_name."""
import os, re, sys, collections
os.environ["XLA_FLAGS"]="--xla_force_host_platform_device_count=" + os.environ.get("REPRO_DRYRUN_DEVICES","256")
sys.path.insert(0, "src")
import jax
from repro.configs.registry import get_config
from repro.configs.base import get_shape
from repro.launch.dryrun import lower_cell
from repro.launch.mesh import make_production_mesh
from repro.launch import hlo as H

arch, shape = sys.argv[1], sys.argv[2]
cfg = get_config(arch)
mesh = make_production_mesh()
compiled, txt, _, _ = lower_cell(cfg, get_shape(shape), mesh)
print("peak mem check done")
comps = H._split_computations(txt)
mult = {n: 1.0 for n in comps}
for name, lines in comps.items():
    for line in lines:
        m = H._WHILE_RE.search(line)
        if m:
            trips = H._trip_count(comps.get(m.group(1), []))
            for t in (m.group(2), m.group(1)):
                if t in mult:
                    mult[t] = max(mult[t], trips * mult[name])
agg = collections.Counter()
cnt = collections.Counter()
for name, lines in comps.items():
    for line in lines:
        m = H._INSTR_RE.match(line.strip())
        if not m: continue
        for kind in H._COLLECTIVES:
            km = re.match(rf"(.+?)\s{re.escape(kind)}(-start)?\(", m.group(2))
            if km:
                b = H._type_bytes(km.group(1)) * mult.get(name,1.0)
                op = re.search(r'op_name="([^"]*)"', line)
                opn = op.group(1)[:110] if op else "?"
                opn = km.group(1).split("{")[0].strip()[-22:] + " | " + opn
                agg[(kind, opn)] += b
                cnt[(kind, opn)] += 1
                break
total = sum(agg.values())
print(f"TOTAL {total/1e9:.2f} GB/device")
for (kind, opn), b in agg.most_common(25):
    print(f"{b/1e9:9.3f} GB  x{cnt[(kind,opn)]:3d} {kind:18s} {opn}")
