"""§6 (GH200): the `instant` option reads the whole module — CPU activity
bleeds into "GPU" power; the framework's scope guard + baseline
subtraction recovers chip-level energy."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core import load as loads
from repro.core import profiles
from repro.core.meter import ModuleScopeError, Workload, measure_naive
from repro.core.sensor import OnboardSensor


def run() -> None:
    gpu_wl = Workload("gpu_burst", loads.workload_burst(0.500, 210.0))
    cpu_tl = loads.workload_burst(0.500, 120.0, idle_w=80.0)

    # chip-scope sensor: unaffected by host activity
    s_chip = OnboardSensor(profiles.get("gh200_gpu"), seed=1)
    e_chip = measure_naive(s_chip, gpu_wl)

    # module-scope sensor with concurrent CPU load
    s_mod = OnboardSensor(profiles.get("gh200_module_instant"), seed=1,
                          host_timeline=cpu_tl.shift(0.3))
    guarded = False
    try:
        measure_naive(s_mod, gpu_wl)
    except ModuleScopeError:
        guarded = True
    e_mod = measure_naive(
        OnboardSensor(profiles.get("gh200_module_instant"), seed=1,
                      host_timeline=cpu_tl.shift(0.3)),
        gpu_wl, host_baseline_w=0.0)
    truth = gpu_wl.true_energy_j
    emit("sec6_gh200/module_bleed", 0.0,
         f"guard_raises={int(guarded)};chip_err_pct="
         f"{(e_chip-truth)/truth*100:.1f};module_err_pct="
         f"{(e_mod-truth)/truth*100:.1f}")
    emit("sec6_gh200/sampled_fraction", 0.0,
         f"gpu={profiles.get('gh200_gpu').sampled_fraction:.2f};"
         f"cpu={profiles.get('gh200_cpu').sampled_fraction:.2f}")


if __name__ == "__main__":
    run()
