"""Fig. 8/9: steady-state gain/offset across devices — error is
proportional (±5 %), not NVIDIA's flat ±5 W."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit
from repro.core import microbench, profiles
from repro.core.ground_truth import GroundTruthMeter
from repro.core.sensor import OnboardSensor


def run() -> None:
    gains, offsets = [], []
    prof = profiles.get("rtx3090_instant")
    for card in range(5):       # the paper's 5× RTX 3090 population
        s = OnboardSensor(prof, seed=100 + card)
        meter = GroundTruthMeter(seed=card)
        ss = microbench.estimate_steady_state(s, meter)
        gains.append(ss.gain)
        offsets.append(ss.offset_w)
        emit(f"fig9_steady_state/rtx3090_{card}", 0.0,
             f"gain={ss.gain:.4f};offset_w={ss.offset_w:.2f};r2={ss.r2:.5f};"
             f"true_gain={s.true_gain:.4f}")
    emit("fig9_steady_state/population", 0.0,
         f"gain_spread={max(gains)-min(gains):.4f};"
         f"within_5pct={int(all(abs(g-1)<0.05 for g in gains))}")
    us = timeit(lambda: microbench.estimate_steady_state(
        OnboardSensor(prof, seed=1), GroundTruthMeter(seed=1)), n=1)
    emit("fig8_steady_state/runtime", us, "per_characterisation")


if __name__ == "__main__":
    run()
