"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads artifacts/dryrun/*.json (produced by `python -m repro.launch.dryrun`)
and emits one CSV row per (arch × shape × mesh) cell with the three terms,
the bottleneck and the MODEL_FLOPS/HLO_FLOPs ratio.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit

ARTIFACTS = os.environ.get("REPRO_DRYRUN_ARTIFACTS", "artifacts/dryrun")


def run() -> None:
    files = sorted(glob.glob(os.path.join(ARTIFACTS, "*.json")))
    if not files:
        emit("roofline/NO_ARTIFACTS", 0.0,
             "run `python -m repro.launch.dryrun --mesh both` first")
        return
    for fn in files:
        with open(fn) as f:
            art = json.load(f)
        if art.get("status") != "ok":
            continue
        r = art["roofline"]
        step_ms = max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e3
        emit(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
             step_ms * 1e3,
             f"comp_ms={r['compute_s']*1e3:.2f};mem_ms={r['memory_s']*1e3:.2f};"
             f"coll_ms={r['collective_s']*1e3:.2f};bn={r['bottleneck']};"
             f"useful={r['useful_ratio']:.3f};"
             f"frac={r['roofline_fraction']:.4f};"
             f"peak_gb={r['peak_memory_per_device']/1e9:.1f};"
             f"fits={int(r['fits_hbm'])}")


if __name__ == "__main__":
    run()
