"""Figs. 10–13: boxcar-window estimation (aliasing + Nelder–Mead fit).

Reproduces the paper's three representative devices: GTX1080Ti-class
(10/20), A100 (25/100) and RTX 3090 (100/100), reporting the estimate
distribution like Fig. 13's violins.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit
from repro.core import microbench, profiles
from repro.core.sensor import OnboardSensor


def run() -> None:
    for name, truth_ms in (("v100", 10.0), ("a100", 25.0),
                           ("rtx3090_instant", 100.0)):
        prof = profiles.get(name)
        s = OnboardSensor(prof, seed=21)
        est, samples = microbench.estimate_boxcar_window(
            s, prof.update_period_s, repetitions=12, seed=4)
        emit(f"fig13_boxcar/{name}", 0.0,
             f"est_ms={est*1e3:.1f};truth_ms={truth_ms};"
             f"std_ms={float(np.std(samples))*1e3:.2f};n={len(samples)}")
    us = timeit(lambda: microbench.estimate_boxcar_window(
        OnboardSensor(profiles.get("a100"), seed=2), 0.1,
        repetitions=2, seed=2), n=1)
    emit("fig11_boxcar/runtime_2reps", us, "")
    # headline: sampled fraction per device class (Fig. 14 summary)
    for name in ("a100", "h100_instant", "v100", "rtx3090_instant",
                 "gh200_gpu", "gh200_cpu"):
        p = profiles.get(name)
        emit(f"fig14_sampled_fraction/{name}", 0.0,
             f"fraction={p.sampled_fraction:.2f}")


if __name__ == "__main__":
    run()
