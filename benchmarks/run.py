"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (harness contract).

Modules:
  fig5   load_linearity   — FMA-chain duration linearity (benchmark load)
  fig6   update_period    — power-update-period recovery
  fig7   transient        — four transient-response classes
  fig8/9 steady_state     — proportional gain error, per-card population
  fig10-14 boxcar         — averaging-window fits + sampled fractions
  fig15-17 energy_cases   — reps vs error for W==T / W>T / W<T
  fig18  workloads        — nine workloads, naive vs good practice
  §6     module_scope     — GH200 whole-module `instant` reading
  $1M    fleet            — data-centre projection + fleet telemetry
  §Roofline roofline_report — per-cell terms from dry-run artifacts
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (boxcar, energy_cases, fleet, load_linearity,
                            module_scope, profile_sweep, roofline_report,
                            steady_state, transient, update_period,
                            workloads)
    modules = [
        ("load_linearity", load_linearity),
        ("update_period", update_period),
        ("transient", transient),
        ("steady_state", steady_state),
        ("boxcar", boxcar),
        ("profile_sweep", profile_sweep),
        ("energy_cases", energy_cases),
        ("workloads", workloads),
        ("module_scope", module_scope),
        ("fleet", fleet),
        ("roofline_report", roofline_report),
    ]
    failed = []
    for name, mod in modules:
        try:
            mod.run()
        except Exception:      # noqa: BLE001 — keep the sweep going
            traceback.print_exc()
            failed.append(name)
    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
