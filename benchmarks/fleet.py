"""Data-centre projection + fleet telemetry (the paper's $1M/yr headline
and the 1/√N vs worst-case uncertainty scaling)."""
from __future__ import annotations

from benchmarks.common import emit
from repro.core.ledger import EnergyLedger
from repro.core.telemetry import FleetLedger, datacenter_projection


def run() -> None:
    proj = datacenter_projection(n_gpus=10_000, tdp_w=700.0, gain_tol=0.05)
    emit("headline_datacenter/10k_h100", 0.0,
         f"per_gpu_err_w={proj['per_gpu_err_w']:.0f};"
         f"annual_err_usd={proj['annual_err_usd']:.0f}")

    fleet = FleetLedger()
    for i in range(256):
        led = EnergyLedger(device_id=f"chip{i}")
        for s in range(20):
            led.append(s, s * 1.0, s + 1.0, 205.0, 200.0, 10.0)
        fleet.register(led)
    s = fleet.summary()
    emit("fleet_telemetry/pod256", 0.0,
         f"total_kwh={s.kwh:.4f};sigma_ind_pct="
         f"{s.sigma_independent_j/s.total_j*100:.2f};sigma_wc_pct="
         f"{s.sigma_worstcase_j/s.total_j*100:.2f};"
         f"mean_power_w={s.mean_power_w:.0f}")


if __name__ == "__main__":
    run()
