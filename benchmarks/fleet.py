"""Data-centre projection + fleet telemetry (the paper's $1M/yr headline
and the 1/√N vs worst-case uncertainty scaling), driven through the
batched engine two ways: the shared-timeline audit (one workload × 10k
seeds) and the heterogeneous mixed-scenario audit (every device its own
timeline via the `TimelineBank` substrate), with per-scenario error
breakdowns and a machine-readable ``BENCH_fleet.json`` so the perf
trajectory has data points.

Backend comparison (ISSUE 3): the same heterogeneous naive audit is
timed under every selected execution backend
(:mod:`repro.core.engine_backend`), then the jax backend runs a
fleet-scale audit (100k devices by default).  CLI::

    python benchmarks/fleet.py --backend both --n-devices 10000 \
        --scale-devices 100000
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from benchmarks.common import emit
from repro.core import load as loads
from repro.core.engine_backend import available_backends
from repro.core.fleet_engine import fleet_audit
from repro.core.ledger import EnergyLedger
from repro.core.meter import WorkloadSet
from repro.core.telemetry import FleetLedger, datacenter_projection

N_DEVICES = 10_000
SCALE_DEVICES = 100_000
JSON_PATH = os.environ.get("BENCH_FLEET_JSON", "BENCH_fleet.json")


def _emit_err(name: str, us_per_dev: float, st: dict) -> None:
    emit(name, us_per_dev,
         f"mean_abs={st['mean_abs_err']:.4f};std={st['std_err']:.4f};"
         f"p50={st['p50_abs']:.4f};p90={st['p90_abs']:.4f};"
         f"p99={st['p99_abs']:.4f};worst={st['worst_abs']:.4f}")


def _profile_names(n: int) -> list:
    return (["a100"] * (n // 2) + ["h100_instant"] * (n // 4)
            + ["v100"] * (n - n // 2 - n // 4))


def _parse_args(argv=None) -> argparse.Namespace:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--backend", choices=("numpy", "jax", "both", "auto"),
                    default="both",
                    help="execution backend(s) to benchmark; 'both'/'auto' "
                         "degrade to numpy-only when jax is missing")
    ap.add_argument("--n-devices", type=int, default=N_DEVICES,
                    help="fleet size for the main audits "
                         f"(default {N_DEVICES})")
    ap.add_argument("--scale-devices", type=int, default=SCALE_DEVICES,
                    help="fleet size for the jax-backend scale audit "
                         f"(default {SCALE_DEVICES}; 0 disables)")
    return ap.parse_args(argv)


def _selected_backends(choice: str) -> list:
    avail = available_backends()
    if choice in ("both", "auto"):
        return list(avail)
    if choice == "jax" and "jax" not in avail:
        raise SystemExit("--backend jax requested but jax is not installed")
    return [choice]


def _audit_stats(n, names, ws, backend):
    """One timed heterogeneous naive audit; returns (wall_s, result)."""
    t0 = time.perf_counter()
    res = fleet_audit(n, profile=names, workload=ws, good_practice=False,
                      backend=backend)
    return time.perf_counter() - t0, res


def run(argv=None) -> None:
    # programmatic callers (benchmarks/run.py) get the defaults; the CLI
    # passes sys.argv[1:] explicitly
    args = _parse_args(argv if argv is not None else [])
    n = args.n_devices
    backends = _selected_backends(args.backend)

    proj = datacenter_projection(n_gpus=10_000, tdp_w=700.0, gain_tol=0.05)
    emit("headline_datacenter/10k_h100", 0.0,
         f"per_gpu_err_w={proj['per_gpu_err_w']:.0f};"
         f"annual_err_usd={proj['annual_err_usd']:.0f}")

    # object path (reference): a small pod of per-device ledgers
    fleet = FleetLedger()
    for i in range(256):
        led = EnergyLedger(device_id=f"chip{i}")
        for s in range(20):
            led.append(s, s * 1.0, s + 1.0, 205.0, 200.0, 10.0)
        fleet.register(led)
    s = fleet.summary()
    emit("fleet_telemetry/pod256", 0.0,
         f"total_kwh={s.kwh:.4f};sigma_ind_pct="
         f"{s.sigma_independent_j/s.total_j*100:.2f};sigma_wc_pct="
         f"{s.sigma_worstcase_j/s.total_j*100:.2f};"
         f"mean_power_w={s.mean_power_w:.0f}")

    # shared-timeline path: n heterogeneous devices, one workload,
    # naive + good practice (the paper's Fig. 18 at fleet scale)
    names = _profile_names(n)
    # time the two protocols separately: the naive-only pass first, then
    # the full audit (same seeds → identical naive results), so each
    # metric's us-per-device reflects only its own protocol's cost
    t0 = time.perf_counter()
    fleet_audit(n, profile=names, good_practice=False)
    wall_naive = time.perf_counter() - t0
    t0 = time.perf_counter()
    res = fleet_audit(n, profile=names, good_practice=True, n_trials=2)
    wall_shared = time.perf_counter() - t0
    wall_gp = max(wall_shared - wall_naive, 0.0)
    st = res.stats()
    gp = res.stats(res.gp_err)
    _emit_err(f"fleet_audit/naive_err_{n}", wall_naive * 1e6 / n, st)
    _emit_err(f"fleet_audit/goodpractice_err_{n}", wall_gp * 1e6 / n, gp)

    unc = res.uncertainty()
    big = FleetLedger()
    big.register_batch(res.gp_j, duration_s=0.2)
    bs = big.summary()
    emit(f"fleet_audit/uncertainty_{n}", wall_shared * 1e6 / n,
         f"n={bs.n_devices};sigma_ind_pct="
         f"{unc['sigma_independent_rel']*100:.3f};"
         f"sigma_wc_pct={unc['sigma_worstcase_rel']*100:.3f};"
         f"wall_s={wall_shared:.2f}")

    # heterogeneous path: every device its own timeline (mixed scenarios:
    # training pods, Poisson inference serving, idle/maintenance, diurnal)
    t0 = time.perf_counter()
    ws = WorkloadSet(loads.mixed_fleet_workloads(n, seed=7))
    ws.timeline_bank      # stack the [N, S] substrate outside the audits
    wall_gen = time.perf_counter() - t0
    # naive-only pass first (same seeds → identical naive results), so
    # each metric's us-per-device reflects only its own protocol's cost
    t0 = time.perf_counter()
    fleet_audit(n, profile=names, workload=ws, good_practice=False)
    wall_naive_h = time.perf_counter() - t0
    t0 = time.perf_counter()
    res_h = fleet_audit(n, profile=names, workload=ws,
                        good_practice=True, n_trials=2)
    wall_hetero = time.perf_counter() - t0
    wall_gp_h = max(wall_hetero - wall_naive_h, 0.0)
    sth = res_h.stats()
    gph = res_h.stats(res_h.gp_err)
    _emit_err(f"fleet_audit/hetero_naive_err_{n}", wall_naive_h * 1e6 / n,
              sth)
    _emit_err(f"fleet_audit/hetero_goodpractice_err_{n}",
              wall_gp_h * 1e6 / n, gph)
    by_naive = res_h.by_scenario()
    by_gp = res_h.by_scenario(res_h.gp_err)
    for label in sorted(by_naive):
        emit(f"fleet_audit/scenario_{label}", 0.0,
             f"n={by_naive[label]['n_devices']};"
             f"naive_mean_abs={by_naive[label]['mean_abs_err']:.4f};"
             f"gp_mean_abs={by_gp[label]['mean_abs_err']:.4f}")
    ratio = wall_hetero / max(wall_shared, 1e-9)
    emit("fleet_audit/hetero_over_shared", 0.0,
         f"wall_shared_s={wall_shared:.2f};wall_hetero_s={wall_hetero:.2f};"
         f"ratio={ratio:.2f}")

    # -- backend comparison (ISSUE 3): the same heterogeneous naive audit
    # timed per backend, cold (first call pays jax compilation) and warm
    backend_stats = {}
    ref_naive = None
    for be in backends:
        wall_cold, res_be = _audit_stats(n, names, ws, be)
        wall_warm, res_be = _audit_stats(n, names, ws, be)
        entry = {
            "n_devices": n,
            "wall_s_cold": round(wall_cold, 4),
            "wall_s": round(wall_warm, 4),
            "devices_per_sec": round(n / wall_warm, 1),
        }
        if ref_naive is None:
            ref_naive = res_be.naive_j
        else:
            entry["max_abs_dev_j_vs_numpy"] = float(
                np.max(np.abs(res_be.naive_j - ref_naive)))
        backend_stats[be] = entry
        emit(f"fleet_audit/backend_{be}_{n}", wall_warm * 1e6 / n,
             f"devices_per_sec={entry['devices_per_sec']};"
             f"wall_s_cold={wall_cold:.2f}")

    # -- jax at fleet scale: the ROADMAP's 100k-device heterogeneous audit
    if "jax" in backends and args.scale_devices > 0:
        ns = args.scale_devices
        t0 = time.perf_counter()
        ws_scale = WorkloadSet(loads.mixed_fleet_workloads(ns, seed=7))
        ws_scale.timeline_bank
        wall_gen_s = time.perf_counter() - t0
        wall_scale, res_scale = _audit_stats(
            ns, _profile_names(ns), ws_scale, "jax")
        backend_stats["jax"]["scale"] = {
            "n_devices": ns,
            "wall_s_workload_gen": round(wall_gen_s, 4),
            "wall_s": round(wall_scale, 4),
            "devices_per_sec": round(ns / wall_scale, 1),
            "naive_mean_abs_err": res_scale.stats()["mean_abs_err"],
        }
        emit(f"fleet_audit/backend_jax_scale_{ns}", wall_scale * 1e6 / ns,
             f"devices_per_sec={round(ns / wall_scale, 1)};"
             f"wall_s={wall_scale:.2f}")

    payload = {
        "n_devices": n,
        "profiles": {"a100": n // 2, "h100_instant": n // 4,
                     "v100": n - n // 2 - n // 4},
        "backends": backend_stats,
        "shared": {
            "wall_s_naive": round(wall_naive, 4),
            "wall_s_total": round(wall_shared, 4),
            "devices_per_sec": round(n / wall_shared, 1),
            "naive": st,
            "good_practice": gp,
        },
        "heterogeneous": {
            "wall_s_workload_gen": round(wall_gen, 4),
            "wall_s_naive": round(wall_naive_h, 4),
            "wall_s_total": round(wall_hetero, 4),
            "devices_per_sec": round(n / wall_hetero, 1),
            "naive": sth,
            "good_practice": gph,
            "by_scenario": {k: {"n_devices": by_naive[k]["n_devices"],
                                "naive_mean_abs":
                                    by_naive[k]["mean_abs_err"],
                                "gp_mean_abs": by_gp[k]["mean_abs_err"]}
                            for k in sorted(by_naive)},
        },
        "hetero_over_shared_wall": round(ratio, 3),
    }
    with open(JSON_PATH, "w") as fh:
        json.dump(payload, fh, indent=2, sort_keys=True)
        fh.write("\n")
    emit("fleet_audit/bench_json", 0.0, f"path={JSON_PATH}")


if __name__ == "__main__":
    import sys
    run(sys.argv[1:])
