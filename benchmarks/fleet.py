"""Data-centre projection + fleet telemetry (the paper's $1M/yr headline
and the 1/√N vs worst-case uncertainty scaling), driven through the
batched engine two ways: the shared-timeline audit (one workload × 10k
seeds) and the heterogeneous mixed-scenario audit (every device its own
timeline via the `TimelineBank` substrate), with per-scenario error
breakdowns and a machine-readable ``BENCH_fleet.json`` so the perf
trajectory has data points.
"""
from __future__ import annotations

import json
import os
import time

from benchmarks.common import emit
from repro.core import load as loads
from repro.core.fleet_engine import fleet_audit
from repro.core.ledger import EnergyLedger
from repro.core.meter import WorkloadSet
from repro.core.telemetry import FleetLedger, datacenter_projection

N_DEVICES = 10_000
JSON_PATH = os.environ.get("BENCH_FLEET_JSON", "BENCH_fleet.json")


def _emit_err(name: str, us_per_dev: float, st: dict) -> None:
    emit(name, us_per_dev,
         f"mean_abs={st['mean_abs_err']:.4f};std={st['std_err']:.4f};"
         f"p50={st['p50_abs']:.4f};p90={st['p90_abs']:.4f};"
         f"p99={st['p99_abs']:.4f};worst={st['worst_abs']:.4f}")


def run() -> None:
    proj = datacenter_projection(n_gpus=10_000, tdp_w=700.0, gain_tol=0.05)
    emit("headline_datacenter/10k_h100", 0.0,
         f"per_gpu_err_w={proj['per_gpu_err_w']:.0f};"
         f"annual_err_usd={proj['annual_err_usd']:.0f}")

    # object path (reference): a small pod of per-device ledgers
    fleet = FleetLedger()
    for i in range(256):
        led = EnergyLedger(device_id=f"chip{i}")
        for s in range(20):
            led.append(s, s * 1.0, s + 1.0, 205.0, 200.0, 10.0)
        fleet.register(led)
    s = fleet.summary()
    emit("fleet_telemetry/pod256", 0.0,
         f"total_kwh={s.kwh:.4f};sigma_ind_pct="
         f"{s.sigma_independent_j/s.total_j*100:.2f};sigma_wc_pct="
         f"{s.sigma_worstcase_j/s.total_j*100:.2f};"
         f"mean_power_w={s.mean_power_w:.0f}")

    # shared-timeline path: 10k heterogeneous devices, one workload,
    # naive + good practice (the paper's Fig. 18 at fleet scale)
    n = N_DEVICES
    names = (["a100"] * (n // 2) + ["h100_instant"] * (n // 4)
             + ["v100"] * (n // 4))
    # time the two protocols separately: the naive-only pass first, then
    # the full audit (same seeds → identical naive results), so each
    # metric's us-per-device reflects only its own protocol's cost
    t0 = time.perf_counter()
    fleet_audit(n, profile=names, good_practice=False)
    wall_naive = time.perf_counter() - t0
    t0 = time.perf_counter()
    res = fleet_audit(n, profile=names, good_practice=True, n_trials=2)
    wall_shared = time.perf_counter() - t0
    wall_gp = max(wall_shared - wall_naive, 0.0)
    st = res.stats()
    gp = res.stats(res.gp_err)
    _emit_err("fleet_audit/naive_err_10k", wall_naive * 1e6 / n, st)
    _emit_err("fleet_audit/goodpractice_err_10k", wall_gp * 1e6 / n, gp)

    unc = res.uncertainty()
    big = FleetLedger()
    big.register_batch(res.gp_j, duration_s=0.2)
    bs = big.summary()
    emit("fleet_audit/uncertainty_10k", wall_shared * 1e6 / n,
         f"n={bs.n_devices};sigma_ind_pct="
         f"{unc['sigma_independent_rel']*100:.3f};"
         f"sigma_wc_pct={unc['sigma_worstcase_rel']*100:.3f};"
         f"wall_s={wall_shared:.2f}")

    # heterogeneous path: every device its own timeline (mixed scenarios:
    # training pods, Poisson inference serving, idle/maintenance, diurnal)
    t0 = time.perf_counter()
    ws = WorkloadSet(loads.mixed_fleet_workloads(n, seed=7))
    ws.timeline_bank      # stack the [N, S] substrate outside the audits
    wall_gen = time.perf_counter() - t0
    # naive-only pass first (same seeds → identical naive results), so
    # each metric's us-per-device reflects only its own protocol's cost
    t0 = time.perf_counter()
    fleet_audit(n, profile=names, workload=ws, good_practice=False)
    wall_naive_h = time.perf_counter() - t0
    t0 = time.perf_counter()
    res_h = fleet_audit(n, profile=names, workload=ws,
                        good_practice=True, n_trials=2)
    wall_hetero = time.perf_counter() - t0
    wall_gp_h = max(wall_hetero - wall_naive_h, 0.0)
    sth = res_h.stats()
    gph = res_h.stats(res_h.gp_err)
    _emit_err("fleet_audit/hetero_naive_err_10k", wall_naive_h * 1e6 / n, sth)
    _emit_err("fleet_audit/hetero_goodpractice_err_10k",
              wall_gp_h * 1e6 / n, gph)
    by_naive = res_h.by_scenario()
    by_gp = res_h.by_scenario(res_h.gp_err)
    for label in sorted(by_naive):
        emit(f"fleet_audit/scenario_{label}", 0.0,
             f"n={by_naive[label]['n_devices']};"
             f"naive_mean_abs={by_naive[label]['mean_abs_err']:.4f};"
             f"gp_mean_abs={by_gp[label]['mean_abs_err']:.4f}")
    ratio = wall_hetero / max(wall_shared, 1e-9)
    emit("fleet_audit/hetero_over_shared", 0.0,
         f"wall_shared_s={wall_shared:.2f};wall_hetero_s={wall_hetero:.2f};"
         f"ratio={ratio:.2f}")

    payload = {
        "n_devices": n,
        "profiles": {"a100": n // 2, "h100_instant": n // 4,
                     "v100": n // 4},
        "shared": {
            "wall_s_naive": round(wall_naive, 4),
            "wall_s_total": round(wall_shared, 4),
            "devices_per_sec": round(n / wall_shared, 1),
            "naive": st,
            "good_practice": gp,
        },
        "heterogeneous": {
            "wall_s_workload_gen": round(wall_gen, 4),
            "wall_s_naive": round(wall_naive_h, 4),
            "wall_s_total": round(wall_hetero, 4),
            "devices_per_sec": round(n / wall_hetero, 1),
            "naive": sth,
            "good_practice": gph,
            "by_scenario": {k: {"n_devices": by_naive[k]["n_devices"],
                                "naive_mean_abs":
                                    by_naive[k]["mean_abs_err"],
                                "gp_mean_abs": by_gp[k]["mean_abs_err"]}
                            for k in sorted(by_naive)},
        },
        "hetero_over_shared_wall": round(ratio, 3),
    }
    with open(JSON_PATH, "w") as fh:
        json.dump(payload, fh, indent=2, sort_keys=True)
        fh.write("\n")
    emit("fleet_audit/bench_json", 0.0, f"path={JSON_PATH}")


if __name__ == "__main__":
    run()
