"""Data-centre projection + fleet telemetry (the paper's $1M/yr headline
and the 1/√N vs worst-case uncertainty scaling), now driven through the
batched engine: a 10,000-device Monte-Carlo audit — every device with its
own hidden gain/offset/phase — in one vectorized pass."""
from __future__ import annotations

import time

from benchmarks.common import emit
from repro.core.fleet_engine import fleet_audit
from repro.core.ledger import EnergyLedger
from repro.core.telemetry import FleetLedger, datacenter_projection


def run() -> None:
    proj = datacenter_projection(n_gpus=10_000, tdp_w=700.0, gain_tol=0.05)
    emit("headline_datacenter/10k_h100", 0.0,
         f"per_gpu_err_w={proj['per_gpu_err_w']:.0f};"
         f"annual_err_usd={proj['annual_err_usd']:.0f}")

    # object path (reference): a small pod of per-device ledgers
    fleet = FleetLedger()
    for i in range(256):
        led = EnergyLedger(device_id=f"chip{i}")
        for s in range(20):
            led.append(s, s * 1.0, s + 1.0, 205.0, 200.0, 10.0)
        fleet.register(led)
    s = fleet.summary()
    emit("fleet_telemetry/pod256", 0.0,
         f"total_kwh={s.kwh:.4f};sigma_ind_pct="
         f"{s.sigma_independent_j/s.total_j*100:.2f};sigma_wc_pct="
         f"{s.sigma_worstcase_j/s.total_j*100:.2f};"
         f"mean_power_w={s.mean_power_w:.0f}")

    # batched path: 10k heterogeneous devices, naive + good practice,
    # per-device error distribution (the paper's Fig. 18 at fleet scale)
    n = 10_000
    names = (["a100"] * (n // 2) + ["h100_instant"] * (n // 4)
             + ["v100"] * (n // 4))
    # time the two protocols separately: the naive-only pass first, then
    # the full audit (same seeds → identical naive results), so each
    # metric's us-per-device reflects only its own protocol's cost
    t0 = time.perf_counter()
    fleet_audit(n, profile=names, good_practice=False)
    wall_naive = time.perf_counter() - t0
    t0 = time.perf_counter()
    res = fleet_audit(n, profile=names, good_practice=True, n_trials=2)
    wall = time.perf_counter() - t0
    wall_gp = max(wall - wall_naive, 0.0)
    st = res.stats()
    gp = res.stats(res.gp_err)
    emit("fleet_audit/naive_err_10k", wall_naive * 1e6 / n,
         f"mean_abs={st['mean_abs_err']:.4f};std={st['std_err']:.4f};"
         f"p50={st['p50_abs']:.4f};p90={st['p90_abs']:.4f};"
         f"p99={st['p99_abs']:.4f};worst={st['worst_abs']:.4f}")
    emit("fleet_audit/goodpractice_err_10k", wall_gp * 1e6 / n,
         f"mean_abs={gp['mean_abs_err']:.4f};std={gp['std_err']:.4f};"
         f"p50={gp['p50_abs']:.4f};p90={gp['p90_abs']:.4f};"
         f"p99={gp['p99_abs']:.4f};worst={gp['worst_abs']:.4f}")

    unc = res.uncertainty()
    big = FleetLedger()
    big.register_batch(res.gp_j, duration_s=0.2)
    bs = big.summary()
    emit("fleet_audit/uncertainty_10k", wall * 1e6 / n,
         f"n={bs.n_devices};sigma_ind_pct="
         f"{unc['sigma_independent_rel']*100:.3f};"
         f"sigma_wc_pct={unc['sigma_worstcase_rel']*100:.3f};"
         f"wall_s={wall:.2f}")


if __name__ == "__main__":
    run()
