"""Data-centre projection + fleet telemetry (the paper's $1M/yr headline
and the 1/√N vs worst-case uncertainty scaling), driven through the
batched engine two ways: the shared-timeline audit (one workload × 10k
seeds) and the heterogeneous mixed-scenario audit (every device its own
timeline via the `TimelineBank` substrate), with per-scenario error
breakdowns and a machine-readable ``BENCH_fleet.json`` so the perf
trajectory has data points.

Backend comparison (ISSUE 3): the same heterogeneous naive audit is
timed under every selected execution backend
(:mod:`repro.core.engine_backend`), then the jax backend runs a
fleet-scale audit (100k devices by default).

Array-native synthesis + streaming audits (ISSUE 4): workload
generation uses the bank-native samplers (`mixed_fleet_workloads(...,
as_bank=True)`), timed against the per-device object path; the
``--mega-devices`` run audits a million-device heterogeneous fleet in
bounded-memory slabs (`fleet_audit(workload=FleetScenarioSpec(...),
chunk_devices=...)`).  CLI::

Streaming monitor (ISSUE 5): the heterogeneous fleet is also replayed
as a *live* poll-sample stream through
:class:`repro.core.stream.MonitorService` (per backend, pinned against
the offline audit), and ``--stream-devices`` runs a scale replay with
spec-synthesised device slabs at bounded memory.

Pallas kernel tier (ISSUE 6): ``--backend both`` now also times the
fused-kernel ``pallas`` tier; ``tools/bench_guard.py`` dominance rules
pin the accelerated tiers' streaming ingest above the numpy reference
at both the main and ``--stream-devices`` scales.  CLI::

    python benchmarks/fleet.py --backend both --n-devices 10000 \
        --scale-devices 100000 --mega-devices 1000000 \
        --stream-devices 100000

Mesh-sharded audits (ISSUE 7): ``--shard-devices`` sweeps the
``shard_map``-sharded audit over forced host-device counts
(``--shard-counts``), each in a subprocess (the XLA flag must precede
the first jax import), and ``--shard-mega-devices`` records the
ten-million-device bounded-memory run::

    python benchmarks/fleet.py --n-devices 2000 --shard-devices 200000 \
        --shard-counts 1,2,4 --shard-mega-devices 10000000

Snapshot serving (ISSUE 8): the ``serving`` block interleaves slab
ingestion with batched query flushes through
:class:`repro.serve.monitor_service.MonitorQueryService` — sustained
queries/sec while ingesting, per-flush p50/p99 latency, cache hit rate.
``--serving-devices`` adds the 100k-device scale run;
``--serving-only`` reruns just this block (merging into an existing
``BENCH_fleet.json``)::

    python benchmarks/fleet.py --serving-only --serving-devices 100000

Fault-domain resilience (ISSUE 9): the ``chaos`` block streams the same
fleet through the full transport-fault taxonomy
(:class:`repro.core.stream.FaultSpec` — clock drift/skew, collector
blackouts, corrupt slabs, permanent dropouts) into a hardened
health-tracked monitor, recording degraded-mode ingest throughput
against the clean strict path, then kills the run mid-stream and times
the supervisor's restore-then-resume cycle (checking the recovered
monitor is *bitwise* the uninterrupted one).  ``--chaos-only`` reruns
just this block (merging into an existing ``BENCH_fleet.json``)::

    python benchmarks/fleet.py --chaos-only --backend numpy

Live collector (ISSUE 10): the ``collect`` block times the wire-format
parsers (nvidia-smi csv + daemon per-row csv, rows/sec) on a synthetic
capture and the full file→monitor replay path
(:class:`repro.collect.CollectorPipeline`), and records the committed
fixtures' parse accounting.  ``--collect-only`` reruns just this block
(merging into an existing ``BENCH_fleet.json``)::

    python benchmarks/fleet.py --collect-only
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from benchmarks.common import emit
from repro.core import load as loads
from repro.core.engine_backend import available_backends
from repro.core.fleet_engine import SensorBank, fleet_audit
from repro.core.ledger import EnergyLedger
from repro.core.meter import WorkloadSet
from repro.core.telemetry import FleetLedger, datacenter_projection

N_DEVICES = 10_000
SCALE_DEVICES = 100_000
MEGA_CHUNK = 100_000
JSON_PATH = os.environ.get("BENCH_FLEET_JSON", "BENCH_fleet.json")


def _emit_err(name: str, us_per_dev: float, st: dict) -> None:
    emit(name, us_per_dev,
         f"mean_abs={st['mean_abs_err']:.4f};std={st['std_err']:.4f};"
         f"p50={st['p50_abs']:.4f};p90={st['p90_abs']:.4f};"
         f"p99={st['p99_abs']:.4f};worst={st['worst_abs']:.4f}")


def _profile_names(n: int) -> list:
    return (["a100"] * (n // 2) + ["h100_instant"] * (n // 4)
            + ["v100"] * (n - n // 2 - n // 4))


def _parse_args(argv=None) -> argparse.Namespace:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--backend",
                    choices=("numpy", "jax", "pallas", "both", "auto"),
                    default="both",
                    help="execution backend(s) to benchmark; 'both'/'auto' "
                         "run every available tier (numpy + jax + pallas) "
                         "and degrade to numpy-only when jax is missing")
    ap.add_argument("--n-devices", type=int, default=N_DEVICES,
                    help="fleet size for the main audits "
                         f"(default {N_DEVICES})")
    ap.add_argument("--scale-devices", type=int, default=SCALE_DEVICES,
                    help="fleet size for the jax-backend scale audit "
                         f"(default {SCALE_DEVICES}; 0 disables)")
    ap.add_argument("--mega-devices", type=int, default=0,
                    help="fleet size for the chunked streaming audit "
                         "(default 0 = disabled; the committed "
                         "BENCH_fleet.json uses 1000000)")
    ap.add_argument("--mega-chunk", type=int, default=MEGA_CHUNK,
                    help=f"device slab size for --mega-devices "
                         f"(default {MEGA_CHUNK})")
    ap.add_argument("--stream-devices", type=int, default=0,
                    help="fleet size for the scale streaming-monitor "
                         "replay (default 0 = disabled; the committed "
                         "BENCH_fleet.json uses 100000)")
    ap.add_argument("--stream-chunk", type=int, default=20_000,
                    help="device slab size for --stream-devices "
                         "(default 20000)")
    ap.add_argument("--shard-devices", type=int, default=0,
                    help="fleet size for the mesh-sharded scaling sweep "
                         "(default 0 = disabled); each shard count runs "
                         "in a subprocess with "
                         "XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=<k> (ISSUE 7)")
    ap.add_argument("--shard-counts", default="1,2,4,8",
                    help="comma-separated forced-host shard counts for "
                         "the scaling sweep (default 1,2,4,8)")
    ap.add_argument("--shard-chunk", type=int, default=25_000,
                    help="device rows per shard per super-slab in the "
                         "sharded runs (default 25000)")
    ap.add_argument("--shard-mega-devices", type=int, default=0,
                    help="fleet size for the sharded mega audit "
                         "(default 0 = disabled; the committed "
                         "BENCH_fleet.json uses 10000000)")
    ap.add_argument("--shard-mega-shards", type=int, default=4,
                    help="forced-host shard count for the sharded mega "
                         "audit (default 4)")
    ap.add_argument("--serving-devices", type=int, default=0,
                    help="fleet size for the scale serving bench "
                         "(default 0 = disabled; the committed "
                         "BENCH_fleet.json uses 100000)")
    ap.add_argument("--serving-only", action="store_true",
                    help="run only the snapshot-serving bench and merge "
                         "its block into an existing BENCH_fleet.json")
    ap.add_argument("--chaos-devices", type=int, default=2000,
                    help="fleet size for the fault-injection/recovery "
                         "bench (default 2000; 0 disables the block)")
    ap.add_argument("--chaos-only", action="store_true",
                    help="run only the chaos (fault-injection + "
                         "kill/recover) bench and merge its block into "
                         "an existing BENCH_fleet.json")
    ap.add_argument("--collect-rows", type=int, default=120_000,
                    help="synthetic capture size (rows) for the "
                         "collector parse/replay bench (default 120000; "
                         "0 disables the block)")
    ap.add_argument("--collect-only", action="store_true",
                    help="run only the collector (wire parse + replay) "
                         "bench and merge its block into an existing "
                         "BENCH_fleet.json")
    return ap.parse_args(argv)


def _run_shard_worker(n_devices, n_shards, shard_chunk, repeat=1,
                      parity_devices=0):
    """One shard-count measurement in a fresh interpreter: the forced
    host-device flag only takes effect before jax first imports, which
    in this process happened long ago."""
    import subprocess
    import sys as _sys

    here = os.path.dirname(os.path.abspath(__file__))
    env = dict(os.environ)
    flags = [f for f in env.get("XLA_FLAGS", "").split()
             if "--xla_force_host_platform_device_count" not in f]
    flags.append(f"--xla_force_host_platform_device_count={n_shards}")
    env["XLA_FLAGS"] = " ".join(flags)
    env.setdefault("JAX_PLATFORMS", "cpu")   # forced host devices are CPU
    src = os.path.join(os.path.dirname(here), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    cmd = [_sys.executable, os.path.join(here, "shard_worker.py"),
           "--n-devices", str(n_devices), "--n-shards", str(n_shards),
           "--shard-chunk", str(shard_chunk), "--repeat", str(repeat)]
    if parity_devices:
        cmd += ["--parity-devices", str(parity_devices)]
    proc = subprocess.run(cmd, capture_output=True, text=True, env=env)
    if proc.returncode != 0:
        raise RuntimeError(
            f"shard_worker failed (k={n_shards}): {proc.stderr.strip()}")
    return json.loads(proc.stdout)


def _shard_blocks(args) -> tuple:
    """The ``sharded`` BENCH block: devices/sec per forced-host shard
    count (+ parallel efficiency at 4 shards when measured), and the
    sharded mega audit.  ``host_cpu_count`` is recorded so the
    bench_guard scaling rule can tell real parallelism from time-sliced
    forced devices on small machines."""
    counts = sorted({int(c) for c in args.shard_counts.split(",") if c})
    block = {
        "n_devices": args.shard_devices,
        "shard_chunk": args.shard_chunk,
        "host_cpu_count": os.cpu_count(),
        "scaling": {},
    }
    for k in counts:
        r = _run_shard_worker(args.shard_devices, k, args.shard_chunk,
                              repeat=2,
                              parity_devices=min(args.shard_devices,
                                                 10_000) if k == counts[-1]
                              else 0)
        block["scaling"][str(k)] = r
        emit(f"fleet_audit/sharded_{args.shard_devices}_k{k}",
             r["wall_s"] * 1e6 / args.shard_devices,
             f"devices_per_sec={r['devices_per_sec']};"
             f"wall_s={r['wall_s']};peak_rss_mb={r['peak_rss_mb']}")
    if "1" in block["scaling"] and "4" in block["scaling"]:
        d1 = block["scaling"]["1"]["devices_per_sec"]
        d4 = block["scaling"]["4"]["devices_per_sec"]
        block["efficiency_4"] = round(d4 / (4.0 * d1), 3)
        emit("fleet_audit/sharded_efficiency_4", 0.0,
             f"efficiency={block['efficiency_4']};"
             f"host_cpu_count={block['host_cpu_count']}")

    mega = None
    if args.shard_mega_devices > 0:
        r = _run_shard_worker(args.shard_mega_devices,
                              args.shard_mega_shards, args.shard_chunk)
        mega = r
        emit(f"fleet_audit/sharded_mega_{args.shard_mega_devices}",
             r["wall_s"] * 1e6 / args.shard_mega_devices,
             f"devices_per_sec={r['devices_per_sec']};"
             f"wall_s={r['wall_s']};chunks={r['n_chunks']};"
             f"peak_rss_mb={r['peak_rss_mb']}")
    return block, mega


def _selected_backends(choice: str) -> list:
    avail = available_backends()
    if choice in ("both", "auto"):
        return list(avail)
    if choice in ("jax", "pallas") and choice not in avail:
        raise SystemExit(f"--backend {choice} requested but jax is not "
                         f"installed")
    return [choice]


def _materialize_grid_slabs(n, names, ws, seed, period_s=0.001,
                            tick_s=0.5, chunk_devices=None,
                            start_offset_s=0.3):
    """Pre-generate the clean rectangular poll slabs ``stream_fleet``
    would emit (same banks, seeds and attach geometry), so the monitor's
    ingest hot loop can be timed with no sensor simulation inside the
    timed region."""
    spec = ws if isinstance(ws, loads.FleetScenarioSpec) else None
    if chunk_devices is None:
        chunks = [(0, n)]
    else:
        chunks = [(lo, min(lo + chunk_devices, n))
                  for lo in range(0, n, chunk_devices)]
    slabs = []
    for lo, hi in chunks:
        wsc = (spec.workload_set(lo, hi) if spec is not None
               else (ws if len(chunks) == 1 else ws.rows(lo, hi)))
        bank = SensorBank.from_catalog(names[lo:hi],
                                       seeds=np.arange(lo, hi) + seed)
        tlb = wsc.timeline_bank
        tlb = tlb.shift(start_offset_s - tlb.t_start)
        bank.attach(tlb, t_end=tlb.t_end + 1.0)
        t1 = float(np.max(tlb.t_end) + 0.5)
        for dev, ts, vals in bank.iter_poll_slabs(
                0.0, t1, period_s=period_s, tick_s=tick_s,
                device_base=lo, grid=True):
            if len(ts):
                slabs.append((dev, ts, vals))
    return slabs


def _ingest_throughput(slabs, n, backend):
    """Time a pure ingest pass over pre-materialised slabs (one untimed
    warm-up pass first, so jit compilation is not billed to the tier)."""
    from repro.core.stream import MonitorService

    def one_pass():
        mon = MonitorService(n, backend=backend)
        mon.set_windows(np.full(n, 0.3), np.full(n, 1.0))
        for dev, ts, vals in slabs:
            mon.ingest_grid(dev, ts, vals)

    one_pass()
    t0 = time.perf_counter()
    one_pass()
    wall = time.perf_counter() - t0
    return sum(v.size for _, _, v in slabs), wall


def _serving_throughput(slabs, n, backend, *, queries_per_flush=512,
                        flushes_per_slab=4, hot_instants=24,
                        cache_size=512, seed=0):
    """Interleave slab ingestion with batched query flushes: after each
    slab lands, ``flushes_per_slab`` request batches hit the monitor's
    fresh snapshot — each batch many concurrent clients asking a small
    pool of hot dashboard instants (dedup folds repeats inside a flush,
    the ``(query, epoch)`` cache serves later flushes at the same
    epoch), plus the since-start/window/between/by-label staples.

    Returns the bench entry: sustained queries/sec and concurrent
    ingest samples/sec over the same wall clock, per-flush latency
    percentiles, cache hit rate.  One untimed warm-up pass first, so
    jit compilation is not billed to the tier.
    """
    from repro.core.stream import MonitorService
    from repro.serve.monitor_service import (MonitorQuery,
                                             MonitorQueryService)

    def one_pass():
        mon = MonitorService(n, backend=backend)
        mon.set_windows(np.full(n, 0.3), np.full(n, 1.0))
        svc = MonitorQueryService(mon, cache_size=cache_size)
        rng = np.random.default_rng(seed)
        lat, n_q, n_samp, t_hi = [], 0, 0, 0.0
        t_all = time.perf_counter()
        for dev, ts, vals in slabs:
            mon.ingest_grid(dev, ts, vals)
            n_samp += vals.size
            t_hi = max(t_hi, float(np.max(ts)))
            pool = np.round(rng.uniform(0.0, t_hi, hot_instants), 2)
            for _ in range(flushes_per_slab):
                picks = rng.choice(pool, queries_per_flush - 4)
                t0 = time.perf_counter()
                for t in picks:
                    svc.submit(MonitorQuery.fleet_energy(float(t)))
                svc.submit(MonitorQuery.fleet_energy())
                svc.submit(MonitorQuery.window_energy())
                svc.submit(MonitorQuery.energy_between(
                    float(pool.min()), float(pool.max())))
                svc.submit(MonitorQuery.by_label())
                svc.flush()
                lat.append(time.perf_counter() - t0)
                n_q += queries_per_flush
        wall = time.perf_counter() - t_all
        return mon, svc, wall, lat, n_q, n_samp

    one_pass()
    mon, svc, wall, lat, n_q, n_samp = one_pass()
    lat_ms = 1e3 * np.asarray(lat)
    st = svc.stats()
    return {
        "queries_per_flush": queries_per_flush,
        "flushes_per_slab": flushes_per_slab,
        "n_queries": n_q,
        "n_samples": int(n_samp),
        "wall_s": round(wall, 4),
        "queries_per_sec": round(n_q / wall, 1),
        "ingest_samples_per_sec_concurrent": round(n_samp / wall, 1),
        "p50_ms": round(float(np.percentile(lat_ms, 50)), 3),
        "p99_ms": round(float(np.percentile(lat_ms, 99)), 3),
        "cache_hit_rate": round(st["cache_hit_rate"], 4),
        "epochs": int(mon.epoch),
    }


def _serving_blocks(args, backends, slabs, n):
    """The ``serving`` BENCH block: per backend at the main size (on the
    already-materialised slabs), plus the ``--serving-devices`` scale
    run on spec-synthesised slabs."""
    block = {"n_devices": n}
    for be in backends:
        entry = _serving_throughput(slabs, n, be)
        block[be] = entry
        emit(f"serving/backend_{be}_{n}", 0.0,
             f"queries_per_sec={entry['queries_per_sec']};"
             f"ingest_samples_per_sec_concurrent="
             f"{entry['ingest_samples_per_sec_concurrent']};"
             f"p50_ms={entry['p50_ms']};p99_ms={entry['p99_ms']};"
             f"cache_hit_rate={entry['cache_hit_rate']}")
    if args.serving_devices > 0:
        ns = args.serving_devices
        spec = loads.FleetScenarioSpec(n=ns, seed=7)
        slabs_sv = _materialize_grid_slabs(
            ns, _profile_names(ns), spec, seed=7, period_s=0.01,
            chunk_devices=min(args.stream_chunk, ns))
        scale = {"n_devices": ns, "period_s": 0.01}
        for be in backends:
            # at fleet scale a flush's kernel cost is amortised over a
            # deeper request queue (more concurrent clients, same small
            # pool of hot dashboard instants)
            entry = _serving_throughput(slabs_sv, ns, be,
                                        queries_per_flush=4096)
            scale[be] = entry
            emit(f"serving/scale_{be}_{ns}", 0.0,
                 f"queries_per_sec={entry['queries_per_sec']};"
                 f"ingest_samples_per_sec_concurrent="
                 f"{entry['ingest_samples_per_sec_concurrent']};"
                 f"p50_ms={entry['p50_ms']};p99_ms={entry['p99_ms']};"
                 f"cache_hit_rate={entry['cache_hit_rate']}")
        del slabs_sv
        block["scale"] = scale
    return block


def _chaos_slabs(n, n_slabs=16, seed=3):
    """Deterministic messy poll slabs (0.5 s of stream each) — the raw
    pre-fault stream the chaos bench injects into."""
    rng = np.random.default_rng(seed)
    out, t0 = [], 0.0
    for _ in range(n_slabs):
        k = int(rng.integers(8 * n, 12 * n))
        dev = rng.integers(0, n, k).astype(np.int64)
        t = t0 + np.sort(rng.uniform(0.0, 0.5, k))
        v = 80.0 + 40.0 * rng.random(k)
        out.append((dev, t, v))
        t0 += 0.5
    return out


def _chaos_block(args, backends):
    """The ``chaos`` BENCH block: degraded-mode ingest throughput under
    the full fault taxonomy vs the clean strict path, plus a
    kill-mid-stream → restore → resume cycle timed end to end (and
    checked bitwise against the uninterrupted faulty run)."""
    import dataclasses
    import tempfile

    from repro.core.stream import (FaultInjector, FaultSpec, HealthPolicy,
                                   MonitorService, MonitorSupervisor,
                                   restore_monitor)

    n = args.chaos_devices
    raw = _chaos_slabs(n)
    t1 = 0.5 * len(raw)
    n_samples = sum(v.size for _, _, v in raw)
    spec = FaultSpec(shuffle=True, dup_fraction=0.05, drop_fraction=0.05,
                     delay_fraction=0.05, clock_drift=0.005,
                     clock_skew_s=0.01, restart_every_s=2.0,
                     corrupt_fraction=0.02, dropout_fraction=0.10,
                     seed=11)

    def faulted():
        inj = FaultInjector(spec, n, 0.0, t1)
        for seq, (dev, ts, vs) in enumerate(raw):
            dev, ts, vs = inj.apply(seq, dev, ts, vs)
            if dev.size:
                yield seq, dev, ts, vs

    def hardened(be):
        return MonitorService(n, strict_ids=False, health=HealthPolicy(),
                              health_every_s=0.5, silent_after_s=1.0,
                              backend=be)

    block = {"n_devices": n, "n_samples": int(n_samples),
             "fault_spec": dataclasses.asdict(spec)}
    for be in backends:
        def clean_pass():
            mon = MonitorService(n, backend=be)
            for dev, ts, vs in raw:
                mon.ingest(dev, ts, vs)
            return mon

        def degraded_pass():
            mon = hardened(be)
            for _, dev, ts, vs in faulted():
                mon.ingest(dev, ts, vs)
            return mon

        clean_pass()                       # untimed warm-up (jit etc.)
        t0 = time.perf_counter()
        clean_pass()
        wall_clean = time.perf_counter() - t0
        degraded_pass()
        t0 = time.perf_counter()
        ref = degraded_pass()
        wall_deg = time.perf_counter() - t0

        crash = {"armed": True}

        def crashing():
            for i, slab in enumerate(faulted()):
                if crash["armed"] and i == len(raw) // 2:
                    crash["armed"] = False
                    raise RuntimeError("chaos kill")
                yield slab

        with tempfile.TemporaryDirectory() as root:
            sup = MonitorSupervisor(lambda: hardened(be), root,
                                    checkpoint_every=4)
            t0 = time.perf_counter()
            rep = sup.run(crashing)
            wall_rec = time.perf_counter() - t0
            # the restore step alone (what a restarted collector pays
            # before its first ingest)
            t0 = time.perf_counter()
            restore_monitor(root, fallback=True)
            restore_s = time.perf_counter() - t0
        bitwise = bool(
            np.array_equal(sup.monitor.state.energy_corr_j,
                           ref.state.energy_corr_j)
            and np.array_equal(sup.monitor.health.code, ref.health.code))
        entry = {
            "ingest_samples_per_sec_clean": round(n_samples / wall_clean, 1),
            "ingest_samples_per_sec_degraded": round(n_samples / wall_deg, 1),
            "degraded_over_clean_wall": round(wall_deg / wall_clean, 3),
            "n_rejected": int(ref.counters["rejected"]),
            "n_quarantined": int(ref.counters["n_quarantined"]),
            "recovery_run_wall_s": round(wall_rec, 4),
            "restore_s": round(restore_s, 4),
            "n_restores": int(rep.n_restores),
            "n_checkpoints": int(rep.n_checkpoints),
            "recovered_bitwise": bitwise,
        }
        block[be] = entry
        emit(f"chaos/backend_{be}_{n}", 0.0,
             f"ingest_samples_per_sec_degraded="
             f"{entry['ingest_samples_per_sec_degraded']};"
             f"degraded_over_clean={entry['degraded_over_clean_wall']};"
             f"restore_s={entry['restore_s']};"
             f"recovered_bitwise={entry['recovered_bitwise']}")
        if not bitwise:
            raise SystemExit("chaos bench: recovered monitor diverged "
                             "from the uninterrupted run")
    return block


def _collect_block(args) -> dict:
    """The ``collect`` BENCH block: wire-parse throughput per format
    (rows/sec) on a synthetic capture, the full file→monitor replay
    path through :class:`repro.collect.CollectorPipeline` (numpy
    backend — the parse side is pure python, the same on every tier),
    and the committed fixtures' parse accounting so the bench JSON
    records what CI smoke-replays."""
    import tempfile

    from repro.collect import CollectorPipeline
    from repro.collect import wire as cwire

    n_dev = 16
    polls = max(args.collect_rows // n_dev, 1)
    rng = np.random.default_rng(9)
    uuids = np.asarray([f"GPU-bench-{i:04d}" for i in range(n_dev)],
                       dtype=object)
    batch = cwire.SampleBatch(
        uuid=np.tile(uuids, polls),
        t=1.7e9 + np.repeat(np.arange(polls) * 0.1, n_dev),
        power_w=80.0 + 40.0 * rng.random(polls * n_dev),
        util=rng.uniform(0.0, 100.0, polls * n_dev))
    block = {"n_rows": len(batch), "n_devices": n_dev}
    with tempfile.TemporaryDirectory() as d:
        writers = (("daemon", lambda b: cwire.format_daemon(b, precision=3)),
                   ("smi", cwire.format_query_gpu))
        for fmt, writer in writers:
            path = os.path.join(d, f"log_{fmt}.csv")
            with open(path, "w") as fh:
                fh.write(writer(batch))
            t0 = time.perf_counter()
            _, c = cwire.parse_log(path, fmt=fmt)
            wall = time.perf_counter() - t0
            assert c.samples == len(batch)
            block[f"{fmt}_parse_rows_per_sec"] = round(c.rows / wall, 1)
            block[f"{fmt}_wall_s"] = round(wall, 4)

        path = os.path.join(d, "log_daemon.csv")
        t0 = time.perf_counter()
        pipe = CollectorPipeline(backend="numpy", now=0.0)
        counters = cwire.WireCounters()
        for b in cwire.iter_batches(path, fmt="daemon", counters=counters):
            pipe.feed(b)
        mon = pipe.finish()
        wall = time.perf_counter() - t0
        block["replay_rows_per_sec"] = round(counters.rows / wall, 1)
        block["replay_wall_s"] = round(wall, 4)
        block["replay_accepted"] = int(mon.counters["accepted"])

    data = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "..", "tests", "data")
    fixtures = {}
    for name in ("daemon_sample.csv", "smi_sample.csv"):
        p = os.path.join(data, name)
        if os.path.exists(p):
            _, c = cwire.parse_log(p)
            fixtures[name] = c.as_dict()
    block["fixtures"] = fixtures

    emit(f"collect/parse_{block['n_rows']}", 0.0,
         f"daemon_rows_per_sec={block['daemon_parse_rows_per_sec']};"
         f"smi_rows_per_sec={block['smi_parse_rows_per_sec']}")
    emit(f"collect/replay_{block['n_rows']}", 0.0,
         f"replay_rows_per_sec={block['replay_rows_per_sec']};"
         f"accepted={block['replay_accepted']}")
    return block


def _audit_stats(n, names, ws, backend):
    """One timed heterogeneous naive audit; returns (wall_s, result)."""
    t0 = time.perf_counter()
    res = fleet_audit(n, profile=names, workload=ws, good_practice=False,
                      backend=backend)
    return time.perf_counter() - t0, res


def run(argv=None) -> None:
    # programmatic callers (benchmarks/run.py) get the defaults; the CLI
    # passes sys.argv[1:] explicitly
    args = _parse_args(argv if argv is not None else [])
    n = args.n_devices
    backends = _selected_backends(args.backend)

    if args.serving_only:
        names = _profile_names(n)
        ws = loads.mixed_fleet_workloads(n, seed=7, as_bank=True)
        slabs = _materialize_grid_slabs(n, names, ws, seed=7)
        serving = _serving_blocks(args, backends, slabs, n)
        payload = {}
        if os.path.exists(JSON_PATH):
            with open(JSON_PATH) as fh:
                payload = json.load(fh)
        payload["serving"] = serving
        with open(JSON_PATH, "w") as fh:
            json.dump(payload, fh, indent=2, sort_keys=True)
            fh.write("\n")
        emit("fleet_audit/bench_json", 0.0, f"path={JSON_PATH}")
        return

    if args.collect_only:
        collect = _collect_block(args)
        payload = {}
        if os.path.exists(JSON_PATH):
            with open(JSON_PATH) as fh:
                payload = json.load(fh)
        payload["collect"] = collect
        with open(JSON_PATH, "w") as fh:
            json.dump(payload, fh, indent=2, sort_keys=True)
            fh.write("\n")
        emit("fleet_audit/bench_json", 0.0, f"path={JSON_PATH}")
        return

    if args.chaos_only:
        chaos = _chaos_block(args, backends)
        payload = {}
        if os.path.exists(JSON_PATH):
            with open(JSON_PATH) as fh:
                payload = json.load(fh)
        payload["chaos"] = chaos
        with open(JSON_PATH, "w") as fh:
            json.dump(payload, fh, indent=2, sort_keys=True)
            fh.write("\n")
        emit("fleet_audit/bench_json", 0.0, f"path={JSON_PATH}")
        return

    proj = datacenter_projection(n_gpus=10_000, tdp_w=700.0, gain_tol=0.05)
    emit("headline_datacenter/10k_h100", 0.0,
         f"per_gpu_err_w={proj['per_gpu_err_w']:.0f};"
         f"annual_err_usd={proj['annual_err_usd']:.0f}")

    # object path (reference): a small pod of per-device ledgers
    fleet = FleetLedger()
    for i in range(256):
        led = EnergyLedger(device_id=f"chip{i}")
        for s in range(20):
            led.append(s, s * 1.0, s + 1.0, 205.0, 200.0, 10.0)
        fleet.register(led)
    s = fleet.summary()
    emit("fleet_telemetry/pod256", 0.0,
         f"total_kwh={s.kwh:.4f};sigma_ind_pct="
         f"{s.sigma_independent_j/s.total_j*100:.2f};sigma_wc_pct="
         f"{s.sigma_worstcase_j/s.total_j*100:.2f};"
         f"mean_power_w={s.mean_power_w:.0f}")

    # shared-timeline path: n heterogeneous devices, one workload,
    # naive + good practice (the paper's Fig. 18 at fleet scale)
    names = _profile_names(n)
    # time the two protocols separately: the naive-only pass first, then
    # the full audit (same seeds → identical naive results), so each
    # metric's us-per-device reflects only its own protocol's cost
    t0 = time.perf_counter()
    fleet_audit(n, profile=names, good_practice=False)
    wall_naive = time.perf_counter() - t0
    t0 = time.perf_counter()
    res = fleet_audit(n, profile=names, good_practice=True, n_trials=2)
    wall_shared = time.perf_counter() - t0
    wall_gp = max(wall_shared - wall_naive, 0.0)
    st = res.stats()
    gp = res.stats(res.gp_err)
    _emit_err(f"fleet_audit/naive_err_{n}", wall_naive * 1e6 / n, st)
    _emit_err(f"fleet_audit/goodpractice_err_{n}", wall_gp * 1e6 / n, gp)

    unc = res.uncertainty()
    big = FleetLedger()
    big.register_batch(res.gp_j, duration_s=0.2)
    bs = big.summary()
    emit(f"fleet_audit/uncertainty_{n}", wall_shared * 1e6 / n,
         f"n={bs.n_devices};sigma_ind_pct="
         f"{unc['sigma_independent_rel']*100:.3f};"
         f"sigma_wc_pct={unc['sigma_worstcase_rel']*100:.3f};"
         f"wall_s={wall_shared:.2f}")

    # heterogeneous path: every device its own timeline (mixed scenarios:
    # training pods, Poisson inference serving, idle/maintenance, diurnal)
    # — synthesised array-natively (ISSUE 4), timed against the
    # per-device-object path it replaced (same timelines bitwise)
    t0 = time.perf_counter()
    ws_obj = WorkloadSet(loads.mixed_fleet_workloads(n, seed=7))
    ws_obj.timeline_bank  # stack the [N, S] substrate outside the audits
    wall_gen_obj = time.perf_counter() - t0
    t0 = time.perf_counter()
    ws = loads.mixed_fleet_workloads(n, seed=7, as_bank=True)
    wall_gen = time.perf_counter() - t0
    emit(f"fleet_audit/workload_gen_{n}", wall_gen * 1e6 / n,
         f"bank_s={wall_gen:.3f};objects_s={wall_gen_obj:.3f};"
         f"speedup={wall_gen_obj / max(wall_gen, 1e-9):.1f}x")
    # naive-only pass first (same seeds → identical naive results), so
    # each metric's us-per-device reflects only its own protocol's cost
    t0 = time.perf_counter()
    fleet_audit(n, profile=names, workload=ws, good_practice=False)
    wall_naive_h = time.perf_counter() - t0
    t0 = time.perf_counter()
    res_h = fleet_audit(n, profile=names, workload=ws,
                        good_practice=True, n_trials=2)
    wall_hetero = time.perf_counter() - t0
    wall_gp_h = max(wall_hetero - wall_naive_h, 0.0)
    sth = res_h.stats()
    gph = res_h.stats(res_h.gp_err)
    _emit_err(f"fleet_audit/hetero_naive_err_{n}", wall_naive_h * 1e6 / n,
              sth)
    _emit_err(f"fleet_audit/hetero_goodpractice_err_{n}",
              wall_gp_h * 1e6 / n, gph)
    by_naive = res_h.by_scenario()
    by_gp = res_h.by_scenario(res_h.gp_err)
    for label in sorted(by_naive):
        emit(f"fleet_audit/scenario_{label}", 0.0,
             f"n={by_naive[label]['n_devices']};"
             f"naive_mean_abs={by_naive[label]['mean_abs_err']:.4f};"
             f"gp_mean_abs={by_gp[label]['mean_abs_err']:.4f}")
    ratio = wall_hetero / max(wall_shared, 1e-9)
    emit("fleet_audit/hetero_over_shared", 0.0,
         f"wall_shared_s={wall_shared:.2f};wall_hetero_s={wall_hetero:.2f};"
         f"ratio={ratio:.2f}")

    # -- backend comparison (ISSUE 3): the same heterogeneous naive audit
    # timed per backend, cold (first call pays jax compilation) and warm
    backend_stats = {}
    ref_naive = None
    for be in backends:
        wall_cold, res_be = _audit_stats(n, names, ws, be)
        wall_warm, res_be = _audit_stats(n, names, ws, be)
        entry = {
            "n_devices": n,
            "wall_s_cold": round(wall_cold, 4),
            "wall_s": round(wall_warm, 4),
            "devices_per_sec": round(n / wall_warm, 1),
        }
        if ref_naive is None:
            ref_naive = res_be.naive_j
        else:
            entry["max_abs_dev_j_vs_numpy"] = float(
                np.max(np.abs(res_be.naive_j - ref_naive)))
        backend_stats[be] = entry
        emit(f"fleet_audit/backend_{be}_{n}", wall_warm * 1e6 / n,
             f"devices_per_sec={entry['devices_per_sec']};"
             f"wall_s_cold={wall_cold:.2f}")

    # -- jax at fleet scale: the ROADMAP's 100k-device heterogeneous audit
    scale_stats = None
    if "jax" in backends and args.scale_devices > 0:
        ns = args.scale_devices
        t0 = time.perf_counter()
        ws_scale = loads.mixed_fleet_workloads(ns, seed=7, as_bank=True)
        wall_gen_s = time.perf_counter() - t0
        # the object path this replaced, for the ISSUE 4 ≥10× criterion
        t0 = time.perf_counter()
        WorkloadSet(loads.mixed_fleet_workloads(ns, seed=7)).timeline_bank
        wall_gen_obj_s = time.perf_counter() - t0
        wall_scale, res_scale = _audit_stats(
            ns, _profile_names(ns), ws_scale, "jax")
        scale_stats = {
            "n_devices": ns,
            "wall_s_workload_gen": round(wall_gen_s, 4),
            "wall_s_workload_gen_objects": round(wall_gen_obj_s, 4),
            "workload_gen_speedup": round(
                wall_gen_obj_s / max(wall_gen_s, 1e-9), 1),
            "wall_s": round(wall_scale, 4),
            "devices_per_sec": round(ns / wall_scale, 1),
            "naive_mean_abs_err": res_scale.stats()["mean_abs_err"],
        }
        backend_stats["jax"]["scale"] = scale_stats
        emit(f"fleet_audit/backend_jax_scale_{ns}", wall_scale * 1e6 / ns,
             f"devices_per_sec={round(ns / wall_scale, 1)};"
             f"wall_s={wall_scale:.2f};"
             f"gen_speedup={scale_stats['workload_gen_speedup']}x")

        # chunked-vs-unchunked consistency at a reduced size (streaming
        # moments merge across ragged slabs; per-device within float
        # accumulation of the padded grids)
        nc = min(ns, 10_000)
        spec_c = loads.FleetScenarioSpec(n=nc, seed=7)
        ref_c = fleet_audit(nc, profile=_profile_names(nc), workload=spec_c)
        t0 = time.perf_counter()
        got_c = fleet_audit(nc, profile=_profile_names(nc), workload=spec_c,
                            chunk_devices=max(nc // 8, 1))
        wall_chunked = time.perf_counter() - t0
        dev = float(np.max(np.abs(got_c.naive_j - ref_c.naive_j)
                           / np.abs(ref_c.naive_j)))
        sm_delta = abs(got_c.streamed["naive"]["overall"]["mean_abs_err"]
                       - got_c.stats()["mean_abs_err"])
        emit(f"fleet_audit/chunked_consistency_{nc}",
             wall_chunked * 1e6 / nc,
             f"max_rel_dev_vs_unchunked={dev:.3e};"
             f"streamed_vs_exact_mean_abs={sm_delta:.3e}")
        chunk_block = {
            "n_devices": nc,
            "chunk_devices": max(nc // 8, 1),
            "wall_s": round(wall_chunked, 4),
            "max_rel_dev_vs_unchunked": dev,
            "streamed_vs_exact_mean_abs": sm_delta,
        }
    else:
        chunk_block = None

    # -- streaming monitor (ISSUE 5): replay the heterogeneous fleet as a
    # live poll stream through MonitorService, per backend, and pin the
    # stream-ingested window energies against the offline audit
    from repro.core.stream import stream_fleet
    stream_block = {"n_devices": n, "period_s": 0.001}
    slabs = _materialize_grid_slabs(n, names, ws, seed=7)
    for be in backends:
        # replay_samples_per_sec times the whole live pipeline (sensor
        # simulation + ingest); ingest_samples_per_sec isolates the
        # monitor's ingest hot loop on pre-materialised slabs — the
        # ISSUE 6 metric the accelerated tiers must dominate
        t0 = time.perf_counter()
        res_s = stream_fleet(n, profile=names, workload=ws, seed=7,
                             backend=be)
        wall_s = time.perf_counter() - t0
        n_ing, wall_ing = _ingest_throughput(slabs, n, be)
        entry = {
            "n_samples": int(res_s.n_samples),
            "wall_s": round(wall_s, 4),
            "samples_per_sec": round(res_s.n_samples / wall_s, 1),
            "wall_s_ingest": round(wall_ing, 4),
            "ingest_samples_per_sec": round(n_ing / wall_ing, 1),
            "monitor_state_mb": round(res_s.monitor.nbytes() / 1e6, 2),
        }
        stream_block[be] = entry
        emit(f"stream_monitor/backend_{be}_{n}", wall_s * 1e6 / n,
             f"samples_per_sec={entry['samples_per_sec']};"
             f"ingest_samples_per_sec={entry['ingest_samples_per_sec']};"
             f"n_samples={entry['n_samples']};"
             f"state_mb={entry['monitor_state_mb']}")

    # -- snapshot serving (ISSUE 8): batched query executor under
    # concurrent ingest, reusing the materialised slabs
    serving_block = _serving_blocks(args, backends, slabs, n)
    del slabs
    # untimed stream↔offline parity pin at a reduced size
    nc = min(n, 2000)
    res_p = stream_fleet(nc, profile=_profile_names(nc),
                         workload=loads.mixed_fleet_workloads(
                             nc, seed=7, as_bank=True),
                         seed=7, compare=True)
    stream_block["parity_n_devices"] = nc
    stream_block["parity_max_rel_dev"] = float(np.max(
        np.abs(res_p.naive_stream_j - res_p.naive_offline_j)
        / np.abs(res_p.naive_offline_j)))
    emit(f"stream_monitor/parity_{nc}", 0.0,
         f"max_rel_dev={stream_block['parity_max_rel_dev']:.3e}")

    # scale streaming replay: spec-synthesised slabs, bounded memory —
    # per backend, so the ISSUE 6 ordering (accelerated tiers dominate
    # numpy on ingest) is recorded at scale too
    if args.stream_devices > 0:
        import resource
        ns = args.stream_devices
        spec = loads.FleetScenarioSpec(n=ns, seed=7)
        scale_stream = {
            "n_devices": ns,
            "chunk_devices": min(args.stream_chunk, ns),
            "period_s": 0.01,
        }
        slabs_sc = _materialize_grid_slabs(
            ns, _profile_names(ns), spec, seed=7, period_s=0.01,
            chunk_devices=min(args.stream_chunk, ns))
        for be in backends:
            rss0 = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
            t0 = time.perf_counter()
            res_sc = stream_fleet(
                ns, profile=_profile_names(ns), workload=spec, seed=7,
                chunk_devices=min(args.stream_chunk, ns), period_s=0.01,
                backend=be, monitor_kwargs=dict(ring_slots=4))
            wall_sc = time.perf_counter() - t0
            rss1 = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
            n_ing, wall_ing = _ingest_throughput(slabs_sc, ns, be)
            scale_stream[be] = {
                "n_samples": int(res_sc.n_samples),
                "wall_s": round(wall_sc, 2),
                "samples_per_sec": round(res_sc.n_samples / wall_sc, 1),
                "wall_s_ingest": round(wall_ing, 4),
                "ingest_samples_per_sec": round(n_ing / wall_ing, 1),
                "devices_per_sec": round(ns / wall_sc, 1),
                "monitor_state_mb": round(res_sc.monitor.nbytes() / 1e6,
                                          1),
                "peak_rss_mb": round(rss1 / 1024.0, 1),
                "peak_rss_before_mb": round(rss0 / 1024.0, 1),
            }
            emit(f"stream_monitor/scale_{be}_{ns}", wall_sc * 1e6 / ns,
                 f"samples_per_sec={scale_stream[be]['samples_per_sec']};"
                 f"ingest_samples_per_sec="
                 f"{scale_stream[be]['ingest_samples_per_sec']};"
                 f"wall_s={wall_sc:.1f};"
                 f"state_mb={scale_stream[be]['monitor_state_mb']};"
                 f"peak_rss_mb={scale_stream[be]['peak_rss_mb']}")
        del slabs_sc
        stream_block["scale"] = scale_stream

    # -- streaming million-device audit: FleetScenarioSpec slabs keep
    # peak memory bounded regardless of fleet size (ISSUE 4)
    mega_block = None
    if args.mega_devices > 0:
        import resource      # Unix-only; needed for this block alone
        nm = args.mega_devices
        chunk = min(args.mega_chunk, nm)
        # cyclic profile mix keeps every slab heterogeneous
        pattern = ["a100", "a100", "h100_instant", "v100"]
        names_m = [pattern[i % 4] for i in range(nm)]
        spec = loads.FleetScenarioSpec(n=nm, seed=7)
        rss0 = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
        t0 = time.perf_counter()
        res_m = fleet_audit(nm, profile=names_m, workload=spec,
                            chunk_devices=chunk)
        wall_m = time.perf_counter() - t0
        rss1 = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
        st_m = res_m.stats()
        mega_block = {
            "n_devices": nm,
            "chunk_devices": chunk,
            "n_chunks": (nm + chunk - 1) // chunk,
            "wall_s": round(wall_m, 2),
            "devices_per_sec": round(nm / wall_m, 1),
            "peak_rss_mb": round(rss1 / 1024.0, 1),
            "peak_rss_before_mb": round(rss0 / 1024.0, 1),
            "naive": st_m,
            "by_scenario_streamed":
                res_m.streamed["naive"]["by_scenario"],
        }
        emit(f"fleet_audit/mega_{nm}", wall_m * 1e6 / nm,
             f"devices_per_sec={round(nm / wall_m, 1)};"
             f"wall_s={wall_m:.1f};chunks={mega_block['n_chunks']};"
             f"peak_rss_mb={mega_block['peak_rss_mb']}")

    payload = {
        "n_devices": n,
        "profiles": {"a100": n // 2, "h100_instant": n // 4,
                     "v100": n - n // 2 - n // 4},
        "backends": backend_stats,
        "shared": {
            "wall_s_naive": round(wall_naive, 4),
            "wall_s_total": round(wall_shared, 4),
            "devices_per_sec": round(n / wall_shared, 1),
            "naive": st,
            "good_practice": gp,
        },
        "heterogeneous": {
            "wall_s_workload_gen": round(wall_gen, 4),
            "wall_s_workload_gen_objects": round(wall_gen_obj, 4),
            "workload_gen_speedup": round(
                wall_gen_obj / max(wall_gen, 1e-9), 1),
            "wall_s_naive": round(wall_naive_h, 4),
            "wall_s_total": round(wall_hetero, 4),
            "devices_per_sec": round(n / wall_hetero, 1),
            "naive": sth,
            "good_practice": gph,
            "by_scenario": {k: {"n_devices": by_naive[k]["n_devices"],
                                "naive_mean_abs":
                                    by_naive[k]["mean_abs_err"],
                                "gp_mean_abs": by_gp[k]["mean_abs_err"]}
                            for k in sorted(by_naive)},
        },
        "hetero_over_shared_wall": round(ratio, 3),
        "streaming": stream_block,
        "serving": serving_block,
    }
    if chunk_block is not None:
        payload["chunked"] = chunk_block
    if mega_block is not None:
        payload["mega"] = mega_block
    if args.shard_devices > 0:
        shard_block, shard_mega = _shard_blocks(args)
        if shard_mega is not None:
            shard_block["mega"] = shard_mega
        payload["sharded"] = shard_block
    if args.chaos_devices > 0:
        payload["chaos"] = _chaos_block(args, backends)
    if args.collect_rows > 0:
        payload["collect"] = _collect_block(args)
    with open(JSON_PATH, "w") as fh:
        json.dump(payload, fh, indent=2, sort_keys=True)
        fh.write("\n")
    emit("fleet_audit/bench_json", 0.0, f"path={JSON_PATH}")


if __name__ == "__main__":
    import sys
    run(sys.argv[1:])
