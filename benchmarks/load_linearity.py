"""Fig. 5: benchmark-load duration is linear in chain length (R² ≈ 1.000).

Runs the actual Pallas fma_chain kernel (XLA path on CPU; interpret-mode
correctness is covered in tests) and fits duration vs iterations.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit


def run() -> None:
    x = jax.random.normal(jax.random.PRNGKey(0), (4096, 128), jnp.float32)

    @jax.jit
    def chain(x, n):
        def body(_, v):
            v = v * 2.0 + 2.0
            return v * 0.5 - 1.0
        return jax.lax.fori_loop(0, n, body, x)

    ns = [256, 512, 1024, 2048, 4096]
    times = []
    for n in ns:
        chain(x, n).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(3):
            chain(x, n).block_until_ready()
        times.append((time.perf_counter() - t0) / 3)
    coef = np.polyfit(ns, times, 1)
    pred = np.polyval(coef, ns)
    ss_res = float(np.sum((np.asarray(times) - pred) ** 2))
    ss_tot = float(np.sum((np.asarray(times) - np.mean(times)) ** 2))
    r2 = 1 - ss_res / ss_tot
    emit("fig5_load_linearity/fit", times[-1] * 1e6,
         f"r2={r2:.4f};slope_us_per_iter={coef[0]*1e6:.4f};"
         f"iters={'/'.join(map(str, ns))}")

    # amplitude control: fraction of active grid slots (paper: SM fraction)
    from repro.core.load import amplitude_for_fraction
    for frac in (0.0, 0.2, 0.4, 0.6, 0.8, 1.0):
        emit(f"fig8_amplitude/frac_{int(frac*100)}", 0.0,
             f"watts={amplitude_for_fraction(frac):.1f}")


if __name__ == "__main__":
    run()
