"""Fig. 18 / Table 2: nine real workloads, naive vs good practice vs truth.

The paper's nine benchmarks (CUBLAS, CUFFT, nvJPEG, StereoDisparity,
Black-Scholes, Quasi-random, ResNet-50, RetinaNet, BERT) are represented
by nine workload power profiles with distinct duration/phase structure,
generated from actual (reduced-config) framework steps where available:
matmul-heavy train steps, attention-heavy prefill, MoE dispatch, decode
streams, plus kernel microloads — each mapped to an activity timeline
through the roofline activity model, mirroring DESIGN.md §2.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core import load as loads
from repro.core import profiles
from repro.core.activity import ChipPowerModel, StepActivity, steps_timeline
from repro.core.calibrate import CalibrationRecord
from repro.core.meter import (GoodPracticeConfig, Workload,
                              compare_protocols)
from repro.core.sensor import OnboardSensor


def _nine_workloads() -> list:
    pm = ChipPowerModel()
    mk = lambda name, tl: Workload(name, tl)
    wl = []
    # library-kernel style loads (CUBLAS / CUFFT / nvJPEG analogues)
    wl.append(mk("matmul", steps_timeline(
        StepActivity(0.080, 0.030, 0.004), 2, pm)))
    wl.append(mk("fft", steps_timeline(
        StepActivity(0.020, 0.035, 0.002), 4, pm)))
    wl.append(mk("image_codec", steps_timeline(
        StepActivity(0.008, 0.018, 0.001), 8, pm)))
    # domain-specific (stereo / black-scholes / quasirandom analogues)
    wl.append(mk("stereo", loads.multi_phase_workload(
        [(0.040, 205.0), (0.025, 140.0), (0.040, 215.0)])))
    wl.append(mk("blackscholes", loads.workload_burst(0.060, 238.0)))
    wl.append(mk("quasirandom", loads.workload_burst(0.012, 190.0)))
    # ML steps (ResNet / RetinaNet / BERT analogues from framework shapes)
    wl.append(mk("cnn_train", steps_timeline(
        StepActivity(0.120, 0.070, 0.030), 3, pm)))
    wl.append(mk("detector_infer", steps_timeline(
        StepActivity(0.045, 0.050, 0.008), 5, pm)))
    wl.append(mk("lm_train_step", steps_timeline(
        StepActivity(0.210, 0.120, 0.090), 2, pm)))
    return wl


def run() -> None:
    for case, prof_name, W, rise in [
            ("case1_100_100", "rtx3090_instant", 0.100, 0.25),
            ("case2_1000_100", "rtx3090_average", 1.000, 1.25),
            ("case3_25_100", "a100", 0.025, 0.25)]:
        prof = profiles.get(prof_name)
        calib = CalibrationRecord(
            "bench", prof_name, prof.update_period_s, W,
            "instant" if W <= prof.update_period_s else "linear", rise,
            sampled_fraction=min(1.0, W / prof.update_period_s))
        naive_all, gp_all = [], []
        for i, wl in enumerate(_nine_workloads()):
            s = OnboardSensor(prof, seed=50 + i)
            r = compare_protocols(s, wl, calib,
                                  GoodPracticeConfig(n_trials=2), seed=i)
            naive_all.append(abs(r["naive_err"]))
            gp_all.append(abs(r["gp_err"]))
            emit(f"fig18_workloads/{case}/{wl.name}", 0.0,
                 f"naive_pct={r['naive_err']*100:.1f};"
                 f"gp_pct={r['gp_err']*100:.1f}")
        emit(f"fig18_workloads/{case}/MEAN", 0.0,
             f"naive_pct={np.mean(naive_all)*100:.2f};"
             f"gp_pct={np.mean(gp_all)*100:.2f};"
             f"reduction_pct={(np.mean(naive_all)-np.mean(gp_all))*100:.2f};"
             f"gp_std_pct={np.std(gp_all)*100:.2f}")


if __name__ == "__main__":
    run()
