"""Fig. 6: power-update-period histogram across the sensor catalog."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit
from repro.core import microbench, profiles
from repro.core.sensor import OnboardSensor


def run() -> None:
    for name in ("v100", "a100", "h100_instant", "turing",
                 "rtx3090_instant", "kepler", "tpu_v5e_chip"):
        prof = profiles.get(name)
        ests = []
        for seed in range(5):
            s = OnboardSensor(prof, seed=seed)
            ests.append(microbench.estimate_update_period(s))
        med = float(np.median(ests))
        us = timeit(lambda: microbench.estimate_update_period(
            OnboardSensor(prof, seed=0)), n=1)
        emit(f"fig6_update_period/{name}", us,
             f"est_ms={med*1e3:.1f};truth_ms={prof.update_period_s*1e3:.1f};"
             f"spread_ms={float(np.std(ests))*1e3:.2f}")


if __name__ == "__main__":
    run()
