"""Figs. 15–17: repetition count vs energy-measurement error for the three
window/period classes (W==T, W>T, W<T), naive vs corrected."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core import load as loads
from repro.core import profiles
from repro.core.calibrate import CalibrationRecord
from repro.core.meter import (GoodPracticeConfig, Workload,
                              measure_good_practice, measure_naive)
from repro.core.sensor import OnboardSensor

CASES = [
    ("case1_100_100", "rtx3090_instant", 0.100, 0.25),
    ("case2_1000_100", "rtx3090_average", 1.000, 1.25),
    ("case3_25_100", "a100", 0.025, 0.25),
]
# short / medium / long loads: 25 %, 100 %, 800 % of the update period
LOADS = [("short", 0.025), ("medium", 0.100), ("long", 0.800)]


def run() -> None:
    for case, prof_name, W, rise in CASES:
        prof = profiles.get(prof_name)
        calib = CalibrationRecord(
            "bench", prof_name, prof.update_period_s, W,
            "instant" if W <= prof.update_period_s else "linear", rise,
            sampled_fraction=min(1.0, W / prof.update_period_s))
        for load_name, dur in LOADS:
            wl = Workload(load_name, loads.multi_phase_workload(
                [(dur * 0.5, 235.0), (dur * 0.5, 150.0)]))
            truth = wl.true_energy_j
            naive_errs, gp_errs = [], []
            for seed in range(4):
                s = OnboardSensor(prof, seed=900 + seed)
                naive_errs.append(
                    (measure_naive(s, wl,
                                   start_offset_s=0.3 + seed * 0.041)
                     - truth) / truth)
                s2 = OnboardSensor(prof, seed=900 + seed)
                est = measure_good_practice(s2, wl, calib,
                                            GoodPracticeConfig(n_trials=2),
                                            seed=seed)
                gp_errs.append(est.error_vs(truth))
            emit(f"fig15to17_energy/{case}/{load_name}", 0.0,
                 f"naive_err_pct={np.mean(np.abs(naive_errs))*100:.1f};"
                 f"gp_err_pct={np.mean(np.abs(gp_errs))*100:.1f};"
                 f"gp_std_pct={np.std(gp_errs)*100:.2f}")


if __name__ == "__main__":
    run()
