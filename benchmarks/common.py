"""Shared benchmark utilities: CSV emission per the harness contract."""
from __future__ import annotations

import sys
import time
from typing import Callable, Iterable


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.3f},{derived}")


def timeit(fn: Callable, n: int = 3) -> float:
    fn()   # warmup
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6
