"""Subprocess worker for the shard-scaling bench (ISSUE 7).

``XLA_FLAGS=--xla_force_host_platform_device_count=<k>`` must be set
*before the first jax import*, so each shard count of the scaling sweep
runs in its own interpreter: ``benchmarks/fleet.py --shard-devices``
spawns this script once per count with the flag injected into the
environment, and reads one JSON object from stdout (all human noise
goes to stderr).

Standalone use (mirrors what the parent does)::

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    PYTHONPATH=src python benchmarks/shard_worker.py \
        --n-devices 200000 --n-shards 4 --shard-chunk 25000 --repeat 2
"""
from __future__ import annotations

import argparse
import json
import resource
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--n-devices", type=int, required=True)
    ap.add_argument("--n-shards", type=int, required=True)
    ap.add_argument("--shard-chunk", type=int, default=25_000,
                    help="device rows per shard per super-slab")
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--repeat", type=int, default=1,
                    help="audit passes; the reported wall is the last "
                         "pass (>=2 excludes jit compilation)")
    ap.add_argument("--parity-devices", type=int, default=0,
                    help="also compare a reduced sharded audit against "
                         "the single-process jax path (0 = skip)")
    args = ap.parse_args(argv)

    import numpy as np
    import jax
    if jax.device_count() < args.n_shards:
        print(f"shard_worker: jax exposes {jax.device_count()} devices, "
              f"need {args.n_shards} (XLA_FLAGS not set before import?)",
              file=sys.stderr)
        return 2

    from repro.core import load as loads
    from repro.core.fleet_engine import fleet_audit
    from repro.core.fleet_engine_shard import fleet_audit_sharded

    def names(n):
        pattern = ["a100", "a100", "h100_instant", "v100"]
        return [pattern[i % 4] for i in range(n)]

    n, k = args.n_devices, args.n_shards
    spec = loads.FleetScenarioSpec(n=n, seed=args.seed)
    wall = None
    for _ in range(max(args.repeat, 1)):
        t0 = time.perf_counter()
        res = fleet_audit_sharded(n, profile=names(n), workload=spec,
                                  n_shards=k, shard_chunk=args.shard_chunk)
        wall = time.perf_counter() - t0
    peak_rss = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss

    out = {
        "n_devices": n,
        "n_shards": k,
        "shard_chunk": args.shard_chunk,
        "n_chunks": -(-n // (args.shard_chunk * k)),
        "wall_s": round(wall, 2),
        "devices_per_sec": round(n / wall, 1),
        "peak_rss_mb": round(peak_rss / 1024.0, 1),
        "naive_mean_abs_err": res.streamed["naive"]["overall"][
            "mean_abs_err"],
        "streamed_vs_exact_mean_abs": abs(
            res.streamed["naive"]["overall"]["mean_abs_err"]
            - res.stats()["mean_abs_err"]),
    }

    if args.parity_devices > 0:
        np_ = args.parity_devices
        spec_p = loads.FleetScenarioSpec(n=np_, seed=args.seed)
        chunk = min(args.shard_chunk * k, np_)
        ref = fleet_audit(np_, profile=names(np_), workload=spec_p,
                          backend="jax", chunk_devices=chunk)
        sh = fleet_audit_sharded(np_, profile=names(np_), workload=spec_p,
                                 n_shards=k,
                                 shard_chunk=args.shard_chunk)
        out["parity_n_devices"] = np_
        out["parity_max_rel_dev"] = float(np.max(
            np.abs(sh.naive_j - ref.naive_j) / np.abs(ref.naive_j)))

    json.dump(out, sys.stdout)
    sys.stdout.write("\n")
    return 0


if __name__ == "__main__":
    sys.exit(main())
