"""Fig. 14: full-catalog characterisation sweep — every sensor class from
Fermi to GH200 (plus TPU-fleet classes) run through the complete
micro-benchmark suite, reproducing the paper's summary table."""
from __future__ import annotations

from benchmarks.common import emit
from repro.core import microbench, profiles
from repro.core.ground_truth import GroundTruthMeter
from repro.core.sensor import OnboardSensor, SensorUnsupported


def run() -> None:
    for name in sorted(profiles.CATALOG):
        prof = profiles.get(name)
        s = OnboardSensor(prof, seed=17,
                          host_timeline=None)
        try:
            res = microbench.characterise(s, GroundTruthMeter(seed=3),
                                          boxcar_reps=4)
        except SensorUnsupported:
            emit(f"fig14_catalog/{name}", 0.0, "supported=0")
            continue
        win = f"{res.window_s*1e3:.0f}" if res.window_s else "NA"
        emit(f"fig14_catalog/{name}", 0.0,
             f"period_ms={res.update_period_s*1e3:.0f};window_ms={win};"
             f"transient={res.transient.kind};"
             f"sampled={res.sampled_fraction:.2f};"
             f"gain={res.gain:.3f};scope={prof.scope}")


if __name__ == "__main__":
    run()
