"""Fig. 7: the four transient-response classes."""
from __future__ import annotations

from benchmarks.common import emit, timeit
from repro.core import microbench, profiles
from repro.core.sensor import OnboardSensor


CASES = [
    ("case1_instant_fastrise", "a100"),
    ("case2_instant_slowload", "turing"),
    ("case3_linear_1s", "rtx3090_average"),
    ("case4_logarithmic", "kepler"),
]


def run() -> None:
    for label, prof_name in CASES:
        prof = profiles.get(prof_name)
        s = OnboardSensor(prof, seed=3)
        T = microbench.estimate_update_period(s)
        tr = microbench.measure_transient(s, T)
        us = timeit(lambda: microbench.measure_transient(
            OnboardSensor(prof, seed=3), T), n=1)
        emit(f"fig7_transient/{label}", us,
             f"kind={tr.kind};rise_ms={tr.rise_time_s*1e3:.0f};"
             f"delay_ms={tr.delay_s*1e3:.0f}")


if __name__ == "__main__":
    run()
