"""Fleet telemetry, ledger persistence, data-centre projection."""
import json

import numpy as np
import pytest

from repro.core.calibrate import CalibrationRecord, CalibrationStore
from repro.core.ledger import EnergyLedger
from repro.core.telemetry import FleetLedger, datacenter_projection
from repro.core import profiles
from repro.core.ground_truth import GroundTruthMeter
from repro.core.sensor import OnboardSensor


def _ledger(dev: str, steps: int = 10, j: float = 50.0) -> EnergyLedger:
    led = EnergyLedger(device_id=dev)
    for i in range(steps):
        led.append(i, i * 1.0, (i + 1) * 1.0, j * 1.1, j, 0.05 * j)
    return led


def test_ledger_roundtrip():
    led = _ledger("d0")
    led2 = EnergyLedger.from_json(led.to_json())
    assert led2.total_corrected_j == pytest.approx(led.total_corrected_j)
    assert led2.device_id == "d0"
    assert len(led2.entries) == len(led.entries)


def test_ledger_summary():
    led = _ledger("d0", steps=10, j=50.0)
    s = led.summary()
    assert s["total_corrected_j"] == pytest.approx(500.0)
    assert s["mean_power_w"] == pytest.approx(50.0)
    assert s["naive_vs_corrected"] == pytest.approx(0.1)


def test_fleet_uncertainty_scaling():
    """Independent ±5 % gain errors shrink relatively as 1/sqrt(N); the
    worst-case (correlated lot) bound does not — the paper's caveat."""
    fleet = FleetLedger()
    N = 64
    for i in range(N):
        fleet.register(_ledger(f"d{i}"))
    s = fleet.summary()
    per_dev_sigma = 0.05 * 500.0
    assert s.sigma_independent_j == pytest.approx(
        per_dev_sigma * np.sqrt(N), rel=1e-6)
    assert s.sigma_worstcase_j == pytest.approx(per_dev_sigma * N, rel=1e-6)
    assert s.sigma_worstcase_j / s.total_j == pytest.approx(0.05)


def test_calibrated_devices_tighten_fleet_sigma():
    fleet = FleetLedger()
    calib = CalibrationRecord("d0", "a100", 0.1, 0.025, "instant", 0.25,
                              gain=0.97, offset_w=1.0, sampled_fraction=0.25)
    fleet.register(_ledger("d0"), calib)
    fleet.register(_ledger("d1"))          # uncalibrated
    s = fleet.summary()
    # calibrated: 1 %, uncalibrated: 5 %
    assert s.sigma_worstcase_j == pytest.approx(
        0.01 * 500.0 + 0.05 * 500.0, rel=1e-6)


def test_mean_power_weights_per_group_durations():
    """Regression: merged fleets that ran for different durations must
    convert energy → power per group.  One batch of 100 J over 10 s
    (10 W) plus one of 100 J over 100 s (1 W) is an 11 W fleet; the old
    ``max``-duration fold reported 200 J / 100 s = 2 W."""
    fleet = FleetLedger()
    fleet.register_batch(np.array([100.0]), duration_s=10.0)
    fleet.register_batch(np.array([100.0]), duration_s=100.0)
    s = fleet.summary()
    assert s.mean_power_w == pytest.approx(11.0)
    assert s.total_j == pytest.approx(200.0)


def test_mean_power_mixes_object_and_batch_durations():
    fleet = FleetLedger()
    led = EnergyLedger(device_id="d0")
    led.append(0, 0.0, 5.0, 110.0, 100.0, 5.0)      # 100 J over 5 s = 20 W
    fleet.register(led)
    fleet.register_batch(np.array([50.0, 50.0]), duration_s=10.0)  # 10 W
    s = fleet.summary()
    assert s.mean_power_w == pytest.approx(30.0)


def test_annualised_uncertainty_tracks_weighted_power():
    """The $/yr figure derives from mean power; it must follow the
    duration-weighted value."""
    fleet = FleetLedger(price_usd_per_kwh=1.0)
    fleet.register_batch(np.array([100.0]), duration_s=10.0)
    fleet.register_batch(np.array([100.0]), duration_s=100.0)
    s = fleet.summary()
    expected_kwh = (s.sigma_worstcase_j / s.total_j) * 11.0 * 8760.0 / 1000.0
    assert s.annual_cost_uncertainty_usd == pytest.approx(expected_kwh)


def test_empty_ledger_summary_is_all_zero():
    s = FleetLedger().summary()
    assert s.n_devices == 0
    assert s.total_j == 0.0
    assert s.mean_power_w == 0.0
    assert s.kwh == 0.0
    assert s.cost_usd == 0.0
    assert s.sigma_independent_j == 0.0
    assert s.sigma_worstcase_j == 0.0
    assert s.annual_cost_uncertainty_usd == 0.0


def test_zero_duration_batches_contribute_no_power():
    """duration_s=0 (unknown runtime) registers energy but no power."""
    fleet = FleetLedger()
    fleet.register_batch(np.array([100.0]))
    s = fleet.summary()
    assert s.total_j == pytest.approx(100.0)
    assert s.mean_power_w == 0.0


def test_datacenter_projection_order_of_magnitude():
    """The paper's headline: 10k GPUs × ±5 % of 700 W ≈ $1M/yr."""
    proj = datacenter_projection(n_gpus=10_000, tdp_w=700.0, gain_tol=0.05,
                                 duty=0.8, price_usd_per_kwh=0.35)
    assert proj["per_gpu_err_w"] == pytest.approx(35.0)
    assert 5e5 < proj["annual_err_usd"] < 2e6


def test_calibration_store_roundtrip(tmp_path):
    store = CalibrationStore(str(tmp_path))
    rec = CalibrationRecord("dev7", "a100", 0.1, 0.025, "instant", 0.25,
                            gain=0.96, offset_w=-1.2, r2=0.9999,
                            sampled_fraction=0.25)
    store.put(rec)
    store2 = CalibrationStore(str(tmp_path))
    got = store2.get("dev7")
    assert got is not None
    assert got.gain == pytest.approx(0.96)
    assert got.sampled_fraction == pytest.approx(0.25)


def test_from_json_tolerates_schema_drift():
    """Regression: persisted stores outlive the code that wrote them.
    A record with a removed (unknown) field, or written before a field
    with a default existed, must still load."""
    rec = CalibrationRecord("dev1", "a100", 0.1, 0.025, "instant", 0.25,
                            gain=0.97, sampled_fraction=0.25)
    d = json.loads(rec.to_json())
    d["retired_field"] = 123            # forward-compat: field was removed
    del d["sampled_fraction"]           # backward-compat: field was added
    del d["created_at"]
    got = CalibrationRecord.from_json(json.dumps(d))
    assert got.device_id == "dev1"
    assert got.gain == pytest.approx(0.97)
    assert got.sampled_fraction == 1.0  # dataclass default
    assert got.created_at == 0.0
    assert not hasattr(got, "retired_field")


def test_from_json_missing_required_field_raises():
    rec = CalibrationRecord("dev1", "a100", 0.1, 0.025, "instant", 0.25)
    d = json.loads(rec.to_json())
    del d["update_period_s"]            # required: no dataclass default
    with pytest.raises(ValueError, match="update_period_s"):
        CalibrationRecord.from_json(json.dumps(d))


def test_from_json_rejects_non_object():
    with pytest.raises(ValueError, match="JSON object"):
        CalibrationRecord.from_json("[1, 2, 3]")


def test_store_characterises_once(tmp_path):
    store = CalibrationStore(str(tmp_path))
    s = OnboardSensor(profiles.get("v100"), seed=4)
    meter = GroundTruthMeter(seed=5)
    rec1 = store.get_or_characterise("devX", s, meter)
    assert rec1.update_period_s == pytest.approx(0.020, rel=0.2)
    # second call hits the cache (no sensor needed)
    rec2 = store.get_or_characterise("devX", None)
    assert rec2.created_at == rec1.created_at
