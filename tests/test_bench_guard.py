"""Unit tests for ``tools/bench_guard.py`` (floors, ceilings, and the
ISSUE 6 cross-metric dominance rules)."""
import importlib.util
import os

_GUARD = os.path.join(os.path.dirname(__file__), "..", "tools",
                      "bench_guard.py")
_spec = importlib.util.spec_from_file_location("bench_guard", _GUARD)
bench_guard = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(bench_guard)
check = bench_guard.check


BENCH = {
    "streaming": {
        "numpy": {"samples_per_sec": 1.0e6},
        "jax": {"samples_per_sec": 5.0e6},
        "pallas": {"samples_per_sec": 4.0e6},
    },
    "heterogeneous": {"devices_per_sec": 400.0,
                      "wall_s_workload_gen": 0.04},
}


def test_floors_and_ceilings_pass_within_tolerance():
    baseline = {"tolerance_factor": 4.0,
                "floors": {"heterogeneous.devices_per_sec": 1000.0},
                "ceilings": {"heterogeneous.wall_s_workload_gen": 0.05}}
    assert check(BENCH, baseline) == []


def test_floor_fails_on_collapse():
    baseline = {"tolerance_factor": 2.0,
                "floors": {"heterogeneous.devices_per_sec": 1000.0}}
    fails = check(BENCH, baseline)
    assert len(fails) == 1 and "throughput regression" in fails[0]


def test_ceiling_fails_on_explosion():
    baseline = {"tolerance_factor": 2.0,
                "ceilings": {"heterogeneous.wall_s_workload_gen": 0.01}}
    fails = check(BENCH, baseline)
    assert len(fails) == 1 and "latency regression" in fails[0]


def test_missing_metric_fails():
    baseline = {"floors": {"streaming.cuda.samples_per_sec": 1.0}}
    fails = check(BENCH, baseline)
    assert fails == ["streaming.cuda.samples_per_sec: "
                     "missing from bench output"]


def test_dominance_passes_when_left_leads():
    baseline = {"dominance": [
        {"left": "streaming.jax.samples_per_sec",
         "right": "streaming.numpy.samples_per_sec", "margin": 1.0},
        {"left": "streaming.pallas.samples_per_sec",
         "right": "streaming.numpy.samples_per_sec", "margin": 1.0},
    ]}
    assert check(BENCH, baseline) == []


def test_dominance_fails_when_ordering_inverts():
    baseline = {"dominance": [
        {"left": "streaming.numpy.samples_per_sec",
         "right": "streaming.jax.samples_per_sec", "margin": 1.0}]}
    fails = check(BENCH, baseline)
    assert len(fails) == 1 and "ordering regression" in fails[0]


def test_dominance_margin_scales_the_bar():
    # pallas at 4x numpy clears margin 3 but not margin 5
    ok = {"dominance": [{"left": "streaming.pallas.samples_per_sec",
                         "right": "streaming.numpy.samples_per_sec",
                         "margin": 3.0}]}
    bad = {"dominance": [{"left": "streaming.pallas.samples_per_sec",
                          "right": "streaming.numpy.samples_per_sec",
                          "margin": 5.0}]}
    assert check(BENCH, ok) == []
    assert len(check(BENCH, bad)) == 1


def test_dominance_ignores_tolerance_factor():
    # the ordering rule is machine-independent: a huge tolerance_factor
    # must not excuse an inverted ordering
    baseline = {"tolerance_factor": 100.0,
                "dominance": [
                    {"left": "streaming.numpy.samples_per_sec",
                     "right": "streaming.jax.samples_per_sec",
                     "margin": 1.0}]}
    assert len(check(BENCH, baseline)) == 1


def test_dominance_missing_side_fails():
    baseline = {"dominance": [
        {"left": "streaming.cuda.samples_per_sec",
         "right": "streaming.numpy.samples_per_sec"},
        {"left": "streaming.jax.samples_per_sec",
         "right": "streaming.tpu.samples_per_sec"},
    ]}
    fails = check(BENCH, baseline)
    assert len(fails) == 2
    assert all("missing from bench output" in f for f in fails)


def test_dominance_default_margin_is_one():
    baseline = {"dominance": [
        {"left": "streaming.jax.samples_per_sec",
         "right": "streaming.pallas.samples_per_sec"}]}
    assert check(BENCH, baseline) == []


# -- scaling rules (ISSUE 7: sharded-audit parallel efficiency) -------------

def _sharded_block(dps1, dps4, cores):
    return {"sharded": {
        "host_cpu_count": cores,
        "scaling": {"1": {"devices_per_sec": dps1},
                    "4": {"devices_per_sec": dps4}}}}


_SCALING_BASE = {"scaling": [{"block": "sharded", "at": 4, "ref": 1,
                              "min_efficiency": 0.7,
                              "min_host_cores": 4}]}


def test_scaling_passes_at_good_efficiency():
    bench = _sharded_block(1000.0, 3200.0, 8)     # 0.8 efficiency
    assert check(bench, _SCALING_BASE) == []


def test_scaling_fails_below_min_efficiency():
    bench = _sharded_block(1000.0, 2000.0, 8)     # 0.5 efficiency
    fails = check(bench, _SCALING_BASE)
    assert len(fails) == 1 and "scaling regression" in fails[0]


def test_scaling_gated_on_host_cores():
    """Forced host devices time-slice the same cores on a small machine:
    the efficiency gate must not fire there, but the metrics must still
    exist."""
    bench = _sharded_block(1000.0, 1050.0, 1)     # 1-core box: ~no speedup
    assert check(bench, _SCALING_BASE) == []
    missing = {"sharded": {"host_cpu_count": 1, "scaling": {}}}
    fails = check(missing, _SCALING_BASE)
    assert len(fails) == 1 and "missing" in fails[0]


def test_scaling_missing_block_fails():
    fails = check({}, _SCALING_BASE)
    assert len(fails) == 1 and "missing" in fails[0]
