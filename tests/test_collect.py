"""Live collector subsystem (ISSUE 10).

Five groups:

* wire parsing — cell parsers (units, failure cells, timestamp
  formats), writer↔parser round-trips (daemon lossless, smi within its
  quantisation), and exact parse-accounting pins on the committed
  fixtures in ``tests/data/``;
* device registry — first-seen-order ids, hot-add stamping, frozen
  (reject-and-count) and strict (raise) policies;
* monitor growth — ``MonitorService.grow`` pinned *bitwise* against
  building the full width up front, through checkpoints, and growing
  under the collector pipeline;
* calibration artifacts — the versioned :class:`ArtifactStore`
  lifecycle (save/activate/rollback/deactivate/age-out/gc), schema
  drift in both directions, and the ``resolve_corrections`` fallback
  ladder;
* end to end — the ``python -m repro.collect replay`` path over the
  committed fixture with an activated store record applied, pinned
  bitwise (numpy backend) against the equivalent direct construction,
  and the CLI as a subprocess.
"""
import dataclasses
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.collect import (CollectorPipeline, DeviceRegistry, SampleBatch,
                           SimulatedSampler, SlabAssembler,
                           UnknownDeviceError, wire)
from repro.collect.cli import main as cli_main
from repro.core import load as loads
from repro.core import profiles
from repro.core.calibrate import CalibrationRecord, nominal_record
from repro.core.calibrate_store import (ArtifactStore, StoreError,
                                        record_stamp, resolve_corrections)
from repro.core.fleet_engine import SensorBank
from repro.core.stream import MonitorService, StreamCorrections, replay
from repro.core.stream.checkpoint import restore_monitor, save_monitor

DATA = os.path.join(os.path.dirname(__file__), "data")
DAEMON_FIXTURE = os.path.join(DATA, "daemon_sample.csv")
SMI_FIXTURE = os.path.join(DATA, "smi_sample.csv")

# exact parse accounting of the committed fixtures — regenerate with
# tools/gen_collect_fixture.py and update here if the fixtures change
FIXTURE_EXPECT = {
    "daemon_sample.csv": {"rows": 1306, "samples": 1302, "headers": 2,
                          "blank": 1, "malformed": 2, "not_available": 0,
                          "error_cells": 0},
    "smi_sample.csv": {"rows": 962, "samples": 957, "headers": 2,
                       "blank": 0, "malformed": 0, "not_available": 1,
                       "error_cells": 2},
}


# ---------------------------------------------------------------------------
# wire: cell parsers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cell,watts,status", [
    ("68.84 W", 68.84, "ok"),
    ("68840 mW", 68.84, "ok"),
    ("0.25 kW", 250.0, "ok"),
    ("132.5", 132.5, "ok"),               # csv,nounits
    ("  99.0 w ", 99.0, "ok"),
    ("[N/A]", None, "na"),
    ("N/A", None, "na"),
    ("[Unknown Error]", None, "error"),
    ("ERR!", None, "error"),
    ("[Unsupported]", None, "error"),
    ("12 parsecs", None, "malformed"),
    ("watts 12", None, "malformed"),
    ("", None, "malformed"),
])
def test_power_cell(cell, watts, status):
    w, s = wire.parse_power_cell(cell)
    assert s == status
    if watts is None:
        assert np.isnan(w)
    else:
        assert w == pytest.approx(watts, rel=1e-12)


def test_timestamp_cell_formats():
    assert wire.parse_timestamp_cell("1700000000.25") == 1700000000.25
    # nvidia-smi's format, with and without milliseconds — taken as UTC
    t = wire.parse_timestamp_cell("2023/11/14 22:13:20.500")
    assert t == 1700000000.5
    assert wire.parse_timestamp_cell("2023/11/14 22:13:20") == 1700000000.0
    assert wire.parse_timestamp_cell("2023-11-14T22:13:20") == 1700000000.0
    assert wire.parse_timestamp_cell("2023-11-14 22:13:20.250") \
        == 1700000000.25
    assert np.isnan(wire.parse_timestamp_cell("yesterday"))


def test_util_cell():
    assert wire.parse_util_cell(" 85 % ") == 85.0
    assert wire.parse_util_cell("85") == 85.0
    assert np.isnan(wire.parse_util_cell("[N/A]"))
    assert np.isnan(wire.parse_util_cell(""))


# ---------------------------------------------------------------------------
# wire: round-trips and fixture pins
# ---------------------------------------------------------------------------

def _random_batch(n=257, seed=0):
    rng = np.random.default_rng(seed)
    uuids = np.asarray([f"GPU-{rng.integers(0, 8):x}" for _ in range(n)],
                       dtype=object)
    t = 1.7e9 + np.sort(rng.uniform(0.0, 60.0, n))
    p = rng.uniform(30.0, 700.0, n)
    u = rng.uniform(0.0, 100.0, n)
    u[rng.random(n) < 0.1] = np.nan       # wire had no utilisation
    return SampleBatch(uuid=uuids, t=t, power_w=p, util=u)


def test_daemon_round_trip_is_lossless():
    """repr-precision daemon CSV → parser → the same batch, bitwise."""
    batch = _random_batch()
    text = wire.format_daemon(batch, precision=None)
    back, c = wire.parse_daemon(text)
    assert c.samples == len(batch) and c.malformed == 0
    np.testing.assert_array_equal(back.uuid, batch.uuid)
    np.testing.assert_array_equal(back.t, batch.t)
    np.testing.assert_array_equal(back.power_w, batch.power_w)
    np.testing.assert_array_equal(back.util, batch.util)


@pytest.mark.parametrize("nounits", [False, True])
def test_smi_round_trip_within_quantisation(nounits):
    """The smi writer is lossy by design (ms timestamps, 2-decimal
    watts); the parser recovers it to exactly that quantisation."""
    batch = _random_batch(seed=3)
    text = wire.format_query_gpu(batch, nounits=nounits)
    back, c = wire.parse_query_gpu(text)
    assert c.samples == len(batch) and c.headers == 1
    np.testing.assert_array_equal(back.uuid, batch.uuid)
    np.testing.assert_allclose(back.t, batch.t, atol=1.0e-3)
    np.testing.assert_allclose(back.power_w, batch.power_w, atol=0.005)


@pytest.mark.parametrize("name,path", [
    ("daemon_sample.csv", DAEMON_FIXTURE),
    ("smi_sample.csv", SMI_FIXTURE),
])
def test_fixture_parse_accounting_pinned(name, path):
    batch, c = wire.parse_log(path)
    assert c.as_dict() == FIXTURE_EXPECT[name]
    assert len(batch) == FIXTURE_EXPECT[name]["samples"]
    # every row lands in exactly one bucket
    assert c.rows == (c.samples + c.headers + c.malformed
                      + c.not_available + c.error_cells)


def test_fixture_sniffing():
    with open(DAEMON_FIXTURE) as f:
        assert wire.sniff_format([next(f) for _ in range(3)]) == "daemon"
    with open(SMI_FIXTURE) as f:
        assert wire.sniff_format([next(f) for _ in range(3)]) == "smi"


@pytest.mark.parametrize("batch_rows", [7, 100, 10_000])
def test_iter_batches_chunking_invariant(batch_rows):
    """Streaming a fixture in any chunk size reproduces the one-shot
    parse bitwise — headers carried across chunk boundaries included."""
    whole, cw = wire.parse_log(DAEMON_FIXTURE)
    c = wire.WireCounters()
    parts = list(wire.iter_batches(DAEMON_FIXTURE, batch_rows=batch_rows,
                                   counters=c))
    got = parts[0]
    for b in parts[1:]:
        got = got.concat(b)
    np.testing.assert_array_equal(got.uuid, whole.uuid)
    np.testing.assert_array_equal(got.t, whole.t)
    np.testing.assert_array_equal(got.power_w, whole.power_w)
    assert c.as_dict() == cw.as_dict()


def test_smi_fixture_chunking_carries_headers():
    whole, cw = wire.parse_log(SMI_FIXTURE)
    c = wire.WireCounters()
    parts = list(wire.iter_batches(SMI_FIXTURE, batch_rows=13, counters=c))
    got = parts[0]
    for b in parts[1:]:
        got = got.concat(b)
    np.testing.assert_array_equal(got.power_w, whole.power_w)
    assert c.as_dict() == cw.as_dict()


# ---------------------------------------------------------------------------
# device registry
# ---------------------------------------------------------------------------

def test_registry_first_seen_order_and_stamping():
    reg = DeviceRegistry()
    ids = reg.resolve(np.asarray(["b", "a", "b", "c"], dtype=object),
                      t=np.asarray([5.0, 6.0, 7.0, 8.0]))
    np.testing.assert_array_equal(ids, [0, 1, 0, 2])
    assert reg.uuids == ["b", "a", "c"]
    assert reg.first_seen_t == [5.0, 6.0, 8.0]
    # idempotent adds keep ids stable
    assert reg.add("a") == 1 and reg.n_devices == 3


def test_registry_reject_policy_counts():
    reg = DeviceRegistry(["a", "b"], on_unknown="reject")
    ids = reg.resolve(np.asarray(["a", "x", "b", "y"], dtype=object))
    np.testing.assert_array_equal(ids, [0, -1, 1, -1])
    assert reg.n_rejected == 2 and reg.n_devices == 2


def test_registry_raise_policy():
    reg = DeviceRegistry(["a"], on_unknown="raise")
    with pytest.raises(UnknownDeviceError):
        reg.resolve(np.asarray(["a", "nope"], dtype=object))
    with pytest.raises(ValueError):
        DeviceRegistry(on_unknown="explode")


# ---------------------------------------------------------------------------
# monitor growth
# ---------------------------------------------------------------------------

def _stream_rows(n_all=4, late_at=100, polls=300, seed=2):
    """A synthetic sample stream where devices n_all-2.. join late."""
    rng = np.random.default_rng(seed)
    uuids = [f"GPU-{i}" for i in range(n_all)]
    rows = []
    for k in range(polls):
        fleet = uuids[:2] if k < late_at else uuids
        for u in fleet:
            rows.append((u, 0.01 * k, 50.0 + rng.standard_normal()))
    return uuids, SampleBatch.from_rows([r[0] for r in rows],
                                        [r[1] for r in rows],
                                        [r[2] for r in rows])


def _chunks(batch, size):
    for i in range(0, len(batch), size):
        yield SampleBatch(uuid=batch.uuid[i:i + size],
                          t=batch.t[i:i + size],
                          power_w=batch.power_w[i:i + size],
                          util=batch.util[i:i + size])


def _assert_monitor_equal(a, b):
    np.testing.assert_array_equal(a.state.energy_j, b.state.energy_j)
    np.testing.assert_array_equal(a.state.win_corr_j, b.state.win_corr_j)
    np.testing.assert_array_equal(a.ring.t, b.ring.t)
    np.testing.assert_array_equal(a.ring.e_corr, b.ring.e_corr)
    fa, fb = a.fleet_energy(), b.fleet_energy()
    np.testing.assert_array_equal(fa.per_device_j, fb.per_device_j)
    assert fa.total_j == fb.total_j


def test_grow_bitwise_equals_upfront_construction():
    """Hot-adding devices mid-stream (lenient registry + grow) yields
    the *same bits* as knowing the full fleet from the start."""
    uuids, batch = _stream_rows()
    pipe = CollectorPipeline(slab_samples=128, now=0.0)
    for chunk in _chunks(batch, 37):
        pipe.feed(chunk)
    grown = pipe.finish()
    assert grown.n_devices == 4

    reg = DeviceRegistry(uuids)
    asm = SlabAssembler(reg, slab_samples=128)
    upfront = MonitorService(4, strict_ids=False, backend="numpy")
    for chunk in _chunks(batch, 37):
        for dev, t, v in asm.push(chunk):
            upfront.ingest(dev, t, v)
    for dev, t, v in asm.flush():
        upfront.ingest(dev, t, v)
    _assert_monitor_equal(grown, upfront)


def test_slab_boundaries_independent_of_feed_chunking():
    """Pipeline state depends on (stream, slab_samples) only — not on
    how the file reader chunked its batches."""
    _, batch = _stream_rows(late_at=10_000)   # no hot-add: pure assembly
    monitors = []
    for feed in (11, 97, 1200):
        pipe = CollectorPipeline(slab_samples=256, now=0.0)
        for chunk in _chunks(batch, feed):
            pipe.feed(chunk)
        monitors.append(pipe.finish())
        assert pipe.assembler.n_slabs == len(batch) // 256 + \
            (1 if len(batch) % 256 else 0)
    _assert_monitor_equal(monitors[0], monitors[1])
    _assert_monitor_equal(monitors[0], monitors[2])


def test_grow_validation():
    mon = MonitorService(4, backend="numpy")
    with pytest.raises(ValueError):
        mon.grow(2)                       # shrink is not a thing
    corr = StreamCorrections.identity(3)  # wrong tail width
    with pytest.raises(ValueError):
        mon.grow(6, corrections=corr)


def test_grow_checkpoint_round_trip(tmp_path):
    """A grown monitor checkpoints and restores bitwise — growth leaves
    no state the schema registries don't know about."""
    uuids, batch = _stream_rows()
    pipe = CollectorPipeline(slab_samples=128, now=0.0)
    for chunk in _chunks(batch, 50):
        pipe.feed(chunk)
    mon = pipe.finish()
    save_monitor(mon, str(tmp_path), step=1)
    back = restore_monitor(str(tmp_path))
    _assert_monitor_equal(mon, back)
    assert back.n_devices == 4


def test_grow_epoch_bumps_and_serves_fresh():
    """Growth invalidates serving caches via the epoch tag: a cached
    pre-growth answer is never replayed at the new width."""
    from repro.serve.monitor_service import MonitorQuery, MonitorQueryService
    mon = MonitorService(2, backend="numpy")
    mon.ingest(np.array([0, 1]), np.array([0.0, 0.0]),
               np.array([100.0, 100.0]))
    mon.ingest(np.array([0, 1]), np.array([1.0, 1.0]),
               np.array([100.0, 100.0]))
    svc = MonitorQueryService(mon)
    q = MonitorQuery.fleet_energy(t=1.0)
    before = svc.query(q)
    assert before.per_device_j.shape == (2,)
    epoch0 = mon.epoch
    mon.grow(3)
    assert mon.epoch == epoch0 + 1
    mon.ingest(np.array([2, 2]), np.array([0.0, 1.0]),
               np.array([50.0, 50.0]))
    after = svc.query(q)
    assert after.per_device_j.shape == (3,)
    assert after.total_j == pytest.approx(before.total_j + 50.0)


def test_sampler_pipeline_matches_replay_bitwise():
    """The full collector path (SimulatedSampler → registry → assembler
    → monitor) reproduces the simulation-fed ``replay`` driver bitwise
    when slab boundaries align (one slab per replay tick)."""
    n = 6
    bank = SensorBank.from_catalog(["a100"] * n, seeds=np.arange(n) + 3)
    tl = loads.multi_phase_workload([(0.130, 215.0), (0.070, 165.0)])
    bank.attach(tl, t_end=2.0)

    ref = MonitorService(n, backend="numpy")
    replay(bank, ref, 0.0, 1.0, period_s=0.001, grid=False)

    sampler = SimulatedSampler(bank, t0=0.0, period_s=0.001)
    # replay's tick_s=0.5 at 1 ms → 500 polls × n devices per slab
    pipe = CollectorPipeline(slab_samples=500 * n, now=0.0,
                             monitor_kwargs={"backend": "numpy"})
    for batch in sampler.run(1000):
        pipe.feed(batch)
    mon = pipe.finish()
    assert mon.n_devices == n
    np.testing.assert_array_equal(mon.state.energy_j, ref.state.energy_j)
    np.testing.assert_array_equal(mon.state.win_corr_j,
                                  ref.state.win_corr_j)


def test_sampler_uuid_stability():
    bank = SensorBank.from_catalog(["a100"] * 3, seeds=[11, 12, 13])
    a = SimulatedSampler(bank)
    b = SimulatedSampler(bank)
    np.testing.assert_array_equal(a.uuids, b.uuids)
    assert len(set(a.uuids)) == 3
    with pytest.raises(ValueError):
        SimulatedSampler(bank, uuids=["x", "x", "y"])


# ---------------------------------------------------------------------------
# calibration artifacts
# ---------------------------------------------------------------------------

def _rec(device_id="GPU-a", gain=1.05, fitted_at=None, **kw):
    base = nominal_record(device_id, profiles.get("a100"))
    return dataclasses.replace(base, gain=gain, offset_w=-2.0,
                               fitted_at=fitted_at, **kw)


def test_store_versions_are_append_only(tmp_path):
    store = ArtifactStore(str(tmp_path))
    assert store.save(_rec(gain=1.01)) == 1
    assert store.save(_rec(gain=1.02), activate=True) == 2
    assert store.save(_rec(gain=1.03)) == 3
    assert store.active_version("GPU-a") == 2
    assert store.active("GPU-a").gain == 1.02
    infos = store.versions("GPU-a")
    assert [i.version for i in infos] == [1, 2, 3]
    assert [i.active for i in infos] == [False, True, False]
    # rollback is just activation of an older version
    store.activate("GPU-a", 1)
    assert store.active("GPU-a").gain == 1.01


def test_store_activate_phantom_raises(tmp_path):
    store = ArtifactStore(str(tmp_path))
    store.save(_rec())
    with pytest.raises(StoreError):
        store.activate("GPU-a", 99)
    with pytest.raises(StoreError):
        store.load("GPU-a", 99)


def test_store_deactivate(tmp_path):
    store = ArtifactStore(str(tmp_path))
    store.save(_rec(), activate=True)
    assert store.deactivate("GPU-a") is True
    assert store.active("GPU-a") is None
    assert store.deactivate("GPU-a") is False


def test_store_age_out(tmp_path):
    store = ArtifactStore(str(tmp_path))
    store.save(_rec(fitted_at=1000.0), activate=True)
    assert store.active("GPU-a", max_age_s=500.0, now=1400.0) is not None
    assert store.active("GPU-a", max_age_s=500.0, now=1600.0) is None
    # records with no provenance stamp never age out
    store.save(_rec(device_id="GPU-b", fitted_at=None), activate=True)
    assert record_stamp(store.active("GPU-b")) == 0.0
    assert store.active("GPU-b", max_age_s=1.0, now=1e12) is not None


def test_store_gc(tmp_path):
    store = ArtifactStore(str(tmp_path))
    store.save(_rec(fitted_at=100.0))                  # v1 stale
    store.save(_rec(fitted_at=200.0), activate=True)   # v2 stale but active
    store.save(_rec(fitted_at=900.0))                  # v3 fresh
    dry = store.gc(max_age_s=300.0, now=1000.0, dry_run=True)
    assert len(dry) == 1 and "v0001" in dry[0]
    assert len(store.versions("GPU-a")) == 3           # dry run removed nothing
    removed = store.gc(max_age_s=300.0, now=1000.0)
    assert [os.path.basename(p) for p in removed] == ["v0001.json"]
    assert [i.version for i in store.versions("GPU-a")] == [2, 3]
    # keep_active=False collects the stale active artifact too
    removed = store.gc(max_age_s=300.0, now=1000.0, keep_active=False)
    assert [os.path.basename(p) for p in removed] == ["v0002.json"]


def test_store_schema_drift_both_directions(tmp_path):
    """Artifacts written by older code (missing the provenance fields)
    and newer code (unknown extra fields) both still load."""
    store = ArtifactStore(str(tmp_path))
    store.save(_rec(), activate=True)
    path = store.versions("GPU-a")[0].path
    data = json.loads(open(path).read())
    for f in ("fitted_at", "source", "note"):
        data.pop(f)                       # "older writer" artifact
    data["flux_capacitance"] = 1.21       # "newer writer" field
    with open(path, "w") as f:
        json.dump(data, f)
    rec = store.active("GPU-a")
    assert rec.fitted_at is None and rec.source == "" and rec.note == ""
    assert rec.gain == 1.05
    with pytest.raises(ValueError):
        CalibrationRecord.from_json(json.dumps({"device_id": "x"}))
    with pytest.raises(ValueError):
        CalibrationRecord.from_json("[1, 2]")


def test_calibration_record_metadata_round_trip():
    rec = _rec(fitted_at=123.0, source="bench", note="rack 7")
    back = CalibrationRecord.from_json(rec.to_json())
    assert back == rec
    assert record_stamp(back) == 123.0
    # fitted_at takes precedence over created_at for ageing
    assert record_stamp(dataclasses.replace(rec, fitted_at=None,
                                            created_at=77.0)) == 77.0


def test_resolve_corrections_fallback_ladder(tmp_path):
    store = ArtifactStore(str(tmp_path))
    store.save(_rec(device_id="GPU-0", gain=1.10), activate=True)
    default = _rec(device_id="*", gain=1.25)
    corr, labels, n_active = resolve_corrections(
        ["GPU-0", "GPU-1"], store=store, default=default)
    assert n_active == 1
    np.testing.assert_allclose(corr.gain, [1.10, 1.25])
    assert list(labels) == ["a100", "a100"]
    # no default → identity, honestly labelled
    corr, labels, n_active = resolve_corrections(["GPU-0", "GPU-1"],
                                                 store=store)
    assert n_active == 1
    np.testing.assert_allclose(corr.gain, [1.10, 1.0])
    np.testing.assert_array_equal(corr.calibrated, [True, False])
    assert list(labels) == ["a100", "uncalibrated"]


# ---------------------------------------------------------------------------
# end to end: the committed fixture through the CLI path
# ---------------------------------------------------------------------------

FIXTURE_UUIDS = [f"GPU-f1xt-{i:04d}" for i in range(5)]


def _fixture_store(root):
    store = ArtifactStore(root)
    store.save(_rec(device_id=FIXTURE_UUIDS[0], gain=1.08,
                    fitted_at=1.7e9), activate=True)
    return store


def test_fixture_replay_matches_direct_construction(tmp_path):
    """The acceptance pin: the committed daemon log replayed through the
    CLI entry point (hot-add growth, store-resolved corrections) equals
    the equivalent direct full-width construction bitwise on numpy."""
    _fixture_store(str(tmp_path / "store"))
    out_json = str(tmp_path / "out.json")
    rc = cli_main(["replay", DAEMON_FIXTURE,
                   "--store", str(tmp_path / "store"),
                   "--default-profile", "a100",
                   "--backend", "numpy", "--slab-samples", "512",
                   "--now", "1.7e9", "--json", out_json])
    assert rc == 0
    got = json.loads(open(out_json).read())
    assert got["wire"] == FIXTURE_EXPECT["daemon_sample.csv"]
    assert got["registry"]["uuids"] == FIXTURE_UUIDS
    assert got["pipeline"]["n_active_records"] == 1

    # direct: full width up front, same store resolution, same slabs
    store = ArtifactStore(str(tmp_path / "store"))
    default = nominal_record("*", profiles.get("a100"))
    corr, labels, _ = resolve_corrections(FIXTURE_UUIDS, store=store,
                                          default=default, now=1.7e9)
    mon = MonitorService(5, corrections=corr, labels=labels,
                         strict_ids=False, backend="numpy")
    reg = DeviceRegistry(FIXTURE_UUIDS)
    asm = SlabAssembler(reg, slab_samples=512)
    counters = wire.WireCounters()
    for batch in wire.iter_batches(DAEMON_FIXTURE, counters=counters):
        for dev, t, v in asm.push(batch):
            mon.ingest(dev, t, v)
    for dev, t, v in asm.flush():
        mon.ingest(dev, t, v)

    fleet = mon.fleet_energy()
    assert got["fleet_energy"]["corrected_j"] == fleet.total_j
    assert got["fleet_energy"]["raw_j"] == mon.fleet_energy(
        corrected=False).total_j
    assert got["fleet_energy"]["n_reporting"] == fleet.n_reporting
    # the applied record actually moved the answer
    assert got["fleet_energy"]["corrected_j"] != \
        got["fleet_energy"]["raw_j"]
    # ingest accounting survived the trip too (duplicate + stale rows
    # in the fixture are dropped-and-counted identically)
    assert got["pipeline"]["ingest"] == dict(mon.counters)


def test_fixture_replay_frozen_fleet_rejects(tmp_path):
    """--frozen pins the fleet: the late joiner's samples are counted,
    not absorbed."""
    out_json = str(tmp_path / "out.json")
    rc = cli_main(["replay", DAEMON_FIXTURE,
                   "--frozen", *FIXTURE_UUIDS[:4],
                   "--backend", "numpy", "--json", out_json])
    assert rc == 0
    got = json.loads(open(out_json).read())
    assert got["registry"]["n_devices"] == 4
    assert got["registry"]["n_rejected"] == 100      # 100 late-joiner rows
    assert got["pipeline"]["ingest"]["rejected"] == 100


def test_smi_fixture_replays_end_to_end(tmp_path):
    out_json = str(tmp_path / "out.json")
    rc = cli_main(["replay", SMI_FIXTURE, "--backend", "numpy",
                   "--rebase", "--json", out_json])
    assert rc == 0
    got = json.loads(open(out_json).read())
    assert got["wire"] == FIXTURE_EXPECT["smi_sample.csv"]
    assert got["registry"]["n_devices"] == 4
    assert got["fleet_energy"]["n_reporting"] == 4
    assert got["fleet_energy"]["raw_j"] > 0


def test_cli_calibrate_lifecycle(tmp_path, capsys):
    store_dir = str(tmp_path / "store")
    assert cli_main(["calibrate", "save", "--store", store_dir,
                     "--device", "GPU-a", "--profile", "a100",
                     "--gain", "1.1", "--activate"]) == 0
    assert cli_main(["calibrate", "save", "--store", store_dir,
                     "--device", "GPU-a", "--profile", "a100",
                     "--gain", "1.2"]) == 0
    capsys.readouterr()
    assert cli_main(["calibrate", "list", "--store", store_dir]) == 0
    listed = json.loads(capsys.readouterr().out)
    assert [a["version"] for a in listed["artifacts"]] == [1, 2]
    assert [a["active"] for a in listed["artifacts"]] == [True, False]
    assert cli_main(["calibrate", "activate", "--store", store_dir,
                     "--device", "GPU-a", "--version", "2"]) == 0
    assert ArtifactStore(store_dir).active("GPU-a").gain == 1.2
    # activating a phantom version fails loudly but cleanly
    assert cli_main(["calibrate", "activate", "--store", store_dir,
                     "--device", "GPU-a", "--version", "9"]) == 2
    assert cli_main(["calibrate", "deactivate", "--store", store_dir,
                     "--device", "GPU-a"]) == 0
    assert ArtifactStore(store_dir).active("GPU-a") is None


def test_cli_smoke_subprocess():
    """``python -m repro.collect`` works as an actual subprocess (the CI
    smoke invocation) and prints machine-readable JSON."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.collect", "replay", DAEMON_FIXTURE,
         "--backend", "numpy"],
        capture_output=True, text=True, env=env, timeout=300)
    assert proc.returncode == 0, proc.stderr
    got = json.loads(proc.stdout)
    assert got["wire"]["samples"] == \
        FIXTURE_EXPECT["daemon_sample.csv"]["samples"]
    assert got["fleet_energy"]["n_reporting"] == 5
