"""Integration: training loop (loss goes down), fault-tolerant restart,
micro-batching equivalence, straggler detection, serving engine."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeCell
from repro.configs.registry import get_config
from repro.models import api
from repro.optim import adamw
from repro.serve.engine import Request, ServingEngine
from repro.train.loop import LoopConfig, StragglerStats, run_training
from repro.train.step import TrainConfig, make_train_step

SHAPE = ShapeCell("tiny", 32, 4, "train")


def _tcfg(**kw):
    base = dict(optim=adamw.AdamWConfig(lr_peak=3e-3, warmup_steps=5,
                                        total_steps=60))
    base.update(kw)
    return TrainConfig(**base)


def test_loss_decreases():
    cfg = get_config("olmo-1b", reduced=True)
    out = run_training(cfg, SHAPE, _tcfg(),
                       LoopConfig(total_steps=30, log_every=100))
    first = np.mean(out["losses"][:5])
    last = np.mean(out["losses"][-5:])
    assert last < first - 0.1, (first, last)


def test_checkpoint_restart_is_exact(tmp_path):
    """Train 20 straight vs 10 + restart + 10: identical final loss (data
    iterator and optimizer state survive the restart)."""
    cfg = get_config("olmo-1b", reduced=True).replace(param_dtype="float32")
    tcfg = _tcfg()
    lc = LoopConfig(total_steps=20, ckpt_every=10, log_every=100)
    straight = run_training(cfg, SHAPE, tcfg, lc, ckpt_dir=None, seed=5)

    d = str(tmp_path / "ck")
    run_training(cfg, SHAPE, tcfg,
                 dataclasses.replace(lc, total_steps=10), ckpt_dir=d, seed=5)
    resumed = run_training(cfg, SHAPE, tcfg, lc, ckpt_dir=d, seed=5)
    assert resumed["final_loss"] == pytest.approx(straight["final_loss"],
                                                  rel=1e-4)


def test_energy_ledger_populated_and_persisted(tmp_path):
    cfg = get_config("olmo-1b", reduced=True)
    out = run_training(cfg, SHAPE, _tcfg(),
                       LoopConfig(total_steps=8, ckpt_every=4,
                                  log_every=100),
                       ckpt_dir=str(tmp_path / "ck"))
    e = out["energy"]
    assert e["steps"] == 8
    assert e["total_corrected_j"] > 0


def test_microbatch_accumulation_matches_full_batch():
    cfg = get_config("olmo-1b", reduced=True).replace(param_dtype="float32")
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw.init(params)
    batch = api.concrete_inputs(jax.random.PRNGKey(1), cfg, SHAPE)
    s1 = make_train_step(cfg, _tcfg(microbatches=1, remat=False))
    s4 = make_train_step(cfg, _tcfg(microbatches=4, remat=False))
    p1, _, m1 = s1(params, opt, batch)
    p4, _, m4 = s4(params, opt, batch)
    # losses are means over different partitions — close but not identical;
    # parameters after one step should agree tightly
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p4)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-2, atol=2e-4)


def test_compressed_microbatch_grads_close():
    cfg = get_config("olmo-1b", reduced=True).replace(param_dtype="float32")
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw.init(params)
    batch = api.concrete_inputs(jax.random.PRNGKey(1), cfg, SHAPE)
    plain = make_train_step(cfg, _tcfg(microbatches=4, remat=False))
    comp = make_train_step(cfg, _tcfg(microbatches=4, remat=False,
                                      compress_grads=True))
    p1, _, m1 = plain(params, opt, batch)
    p2, _, m2 = comp(params, opt, batch)
    assert float(m2["loss"]) == pytest.approx(float(m1["loss"]), rel=1e-4)
    # int8 compression perturbs the update only slightly
    num = sum(float(jnp.sum((a - b) ** 2)) for a, b in
              zip(jax.tree_util.tree_leaves(p1),
                  jax.tree_util.tree_leaves(p2)))
    den = sum(float(jnp.sum(a ** 2))
              for a in jax.tree_util.tree_leaves(p1))
    assert num / den < 1e-4


def test_straggler_detection():
    st = StragglerStats()
    for _ in range(10):
        assert not st.record(0.1, factor=2.0)
    assert st.record(0.5, factor=2.0)
    assert st.n_stragglers == 1


def test_serving_engine_generates():
    cfg = get_config("olmo-1b", reduced=True)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, n_slots=2, max_seq=64)
    reqs = [Request(i, np.arange(3) + 1 + i, max_new_tokens=5)
            for i in range(3)]
    for r in reqs:
        eng.submit(r)
    eng.run(max_ticks=200)
    for r in reqs:
        assert r.done
        assert len(r.generated) == 5
        assert all(0 <= t < cfg.vocab for t in r.generated)


def test_serving_greedy_matches_forward_argmax():
    """First generated token == argmax of the forward pass at the prompt
    end (greedy decoding consistency through the cache path)."""
    cfg = get_config("olmo-1b", reduced=True).replace(param_dtype="float32")
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    prompt = np.asarray([5, 9, 2, 7], np.int32)
    logits, _ = api.forward(params, cfg,
                            {"tokens": jnp.asarray(prompt)[None]},
                            remat=False)
    want = int(jnp.argmax(logits[0, -1]))
    eng = ServingEngine(cfg, params, n_slots=1, max_seq=32)
    r = Request(0, prompt, max_new_tokens=1)
    eng.submit(r)
    eng.run(max_ticks=50)
    assert r.generated[0] == want
