"""Streaming fleet monitor (ISSUE 5).

Four groups:

* the shared step-integration kernel — pinned against the historical
  scalar ``_integrate_readings`` formula (single source of truth);
* stream↔offline parity — replaying a fleet's poll series through
  ``MonitorService`` reproduces ``integrate_polled`` / ``fleet_audit``
  on the same reading schedules within float accumulation order;
* stream edge cases — out-of-order, duplicate, delayed and dropped
  samples, silent devices, empty windows, single-sample devices — all
  degrade gracefully instead of raising;
* online estimators and queries — update-period convergence to the
  offline §4.1 estimator, windowed/by-label queries, health flags,
  telemetry integration.
"""
import numpy as np
import pytest

from repro.core import load as loads
from repro.core import microbench
from repro.core.engine_backend.numpy_backend import step_integrate
from repro.core.fleet_engine import SensorBank, fleet_audit
from repro.core.meter import Workload, _integrate_readings
from repro.core.sensor import OnboardSensor
from repro.core import profiles
from repro.core.stream import (IngestBuffer, MonitorService,
                               OnlinePeriodEstimator, StreamCorrections,
                               replay, stream_fleet)
from repro.core.telemetry import (CALIBRATED_TOLERANCE, SHUNT_TOLERANCE,
                                  FleetLedger)

MIXED_NAMES = ["a100"] * 10 + ["v100"] * 5 + ["h100_instant"] * 5
BURST = Workload("burst", loads.multi_phase_workload(
    [(0.130, 215.0), (0.070, 165.0)]))


def _legacy_integrate(ts, vals, t0, t1):
    """The pre-refactor scalar rectangle rule (the pinned reference)."""
    sel = (ts >= t0) & (ts <= t1)
    if not np.any(sel):
        return 0.0
    t = ts[sel]
    v = vals[sel]
    dt = np.diff(np.concatenate([t, [t1]]))
    return float(np.sum(v * dt))


# ---------------------------------------------------------------------------
# shared step-integration kernel
# ---------------------------------------------------------------------------

def test_step_integrate_matches_legacy_scalar():
    rng = np.random.default_rng(0)
    for _ in range(20):
        m = int(rng.integers(1, 60))
        ts = np.sort(rng.uniform(0.0, 10.0, m))
        vals = rng.uniform(50.0, 250.0, m)
        t0 = float(rng.uniform(-1.0, 9.0))
        t1 = t0 + float(rng.uniform(0.0, 6.0))
        got = step_integrate(ts[None, :], vals[None, :],
                             np.array([t0]), np.array([t1]))[0]
        assert got == pytest.approx(_legacy_integrate(ts, vals, t0, t1),
                                    rel=1e-12, abs=1e-9)


def test_integrate_readings_delegates_to_kernel():
    ts = np.arange(100) * 0.01
    vals = 100.0 + 10.0 * np.sin(ts)
    for (a, b) in [(0.05, 0.73), (0.0, 0.99), (0.5, 0.5), (0.9, 0.2),
                   (2.0, 3.0), (-1.0, 0.31)]:
        assert _integrate_readings(ts, vals, a, b) == pytest.approx(
            _legacy_integrate(ts, vals, a, b), rel=1e-12, abs=1e-12)


def test_step_integrate_padded_rows_and_empty_windows():
    ts = np.array([[0.1, 0.2, 0.3, np.inf, np.inf],
                   [0.5, np.inf, np.inf, np.inf, np.inf]])
    vals = np.array([[10.0, 20.0, 30.0, 7.0, 7.0],
                     [100.0, 3.0, 3.0, 3.0, 3.0]])
    # row 0 full window; row 1 single sample held to t1
    out = step_integrate(ts, vals, np.array([0.0, 0.0]),
                         np.array([0.4, 1.0]))
    assert out[0] == pytest.approx(10 * 0.1 + 20 * 0.1 + 30 * 0.1)
    assert out[1] == pytest.approx(100.0 * 0.5)
    # empty / inverted windows integrate to exactly 0
    out = step_integrate(ts, vals, np.array([0.31, 2.0]),
                         np.array([0.4, 1.0]))
    assert out[0] == 0.0  # no sample inside [0.31, 0.4]... (0.3 < 0.31)
    out = step_integrate(ts, vals, np.array([0.4, 0.9]),
                         np.array([0.0, 0.1]))
    assert np.all(out == 0.0)


def test_step_integrate_empty_series_is_zero():
    """A zero-sample series integrates to 0, like the pre-refactor
    scalar path."""
    out = step_integrate(np.empty((2, 0)), np.empty((2, 0)),
                         np.array([0.0, 1.0]), np.array([1.0, 2.0]))
    np.testing.assert_array_equal(out, [0.0, 0.0])
    assert _integrate_readings(np.empty(0), np.empty(0), 0.0, 1.0) == 0.0


def test_step_integrate_trapezoid():
    ts = np.array([[0.0, 1.0, 2.0]])
    vals = np.array([[0.0, 100.0, 50.0]])
    out = step_integrate(ts, vals, np.array([0.0]), np.array([2.0]),
                         trapezoid=True)
    assert out[0] == pytest.approx(0.5 * (0 + 100) + 0.5 * (100 + 50))


# ---------------------------------------------------------------------------
# stream ↔ offline parity
# ---------------------------------------------------------------------------

def test_stream_matches_offline_integrate_polled_mixed_fleet():
    n = len(MIXED_NAMES)
    ws = loads.mixed_fleet_workloads(n, seed=7, as_bank=True)
    res = stream_fleet(n, profile=MIXED_NAMES, workload=ws, seed=0,
                       compare=True)
    np.testing.assert_allclose(res.naive_stream_j, res.naive_offline_j,
                               rtol=1e-11)
    np.testing.assert_allclose(res.corrected_stream_j,
                               res.corrected_offline_j, rtol=1e-11)
    # the §5 corrections actually move the estimate (they are not a no-op)
    assert np.max(np.abs(res.corrected_stream_j
                         - res.naive_stream_j)) > 1e-3


def test_stream_matches_fleet_audit_naive():
    n = len(MIXED_NAMES)
    ws = loads.mixed_fleet_workloads(n, seed=7, as_bank=True)
    audit = fleet_audit(n, profile=MIXED_NAMES, workload=ws, seed=0)
    res = stream_fleet(n, profile=MIXED_NAMES, workload=ws, seed=0)
    np.testing.assert_allclose(res.naive_stream_j, audit.naive_j,
                               rtol=1e-11)


def test_stream_shared_workload_parity():
    res = stream_fleet(8, profile="a100", workload=BURST, seed=3,
                       compare=True)
    np.testing.assert_allclose(res.naive_stream_j, res.naive_offline_j,
                               rtol=1e-11)
    audit = fleet_audit(8, profile="a100", workload=BURST, seed=3)
    np.testing.assert_allclose(res.naive_stream_j, audit.naive_j,
                               rtol=1e-11)


def test_stream_chunked_equals_unchunked():
    n = len(MIXED_NAMES)
    ws = loads.mixed_fleet_workloads(n, seed=11, as_bank=True)
    whole = stream_fleet(n, profile=MIXED_NAMES, workload=ws, seed=0)
    chunked = stream_fleet(n, profile=MIXED_NAMES, workload=ws, seed=0,
                           chunk_devices=7)
    np.testing.assert_array_equal(chunked.naive_stream_j,
                                  whole.naive_stream_j)
    np.testing.assert_array_equal(chunked.corrected_stream_j,
                                  whole.corrected_stream_j)


def test_stream_scenario_spec_slab_generation():
    spec = loads.FleetScenarioSpec(n=12, seed=5)
    ws = spec.workload_set()
    ref = stream_fleet(12, profile="a100", workload=ws, seed=1)
    got = stream_fleet(12, profile="a100", workload=spec, seed=1,
                       chunk_devices=5)
    np.testing.assert_array_equal(got.naive_stream_j, ref.naive_stream_j)


# ---------------------------------------------------------------------------
# edge cases: disorder, duplication, loss, silence
# ---------------------------------------------------------------------------

def _attached_bank(n=6, seed=0):
    bank = SensorBank.from_catalog(["a100"] * n, seeds=np.arange(n) + seed)
    tl = BURST.timeline.shift(0.3)
    bank.attach(tl, t_end=tl.t_end + 1.0)
    return bank


def test_shuffled_and_duplicated_slabs_are_exact():
    """Within-slab disorder is sorted, duplicates dropped: the result is
    *bitwise* the clean replay.  The clean reference forces the
    flattened ingest path — the messy stream necessarily flows through
    it, and this pin is about the resort/dedup being exact (the grid
    fast path matches it within float accumulation order, pinned in
    test_stream_backend.py)."""
    bank = _attached_bank()
    clean = MonitorService(6)
    replay(bank, clean, 0.0, 1.0, grid=False)
    messy = MonitorService(6)
    rep = replay(bank, messy, 0.0, 1.0, shuffle=True, dup_fraction=0.3,
                 seed=4)
    assert rep["duplicates"] > 0
    np.testing.assert_array_equal(messy.state.energy_j,
                                  clean.state.energy_j)
    np.testing.assert_array_equal(messy.state.win_corr_j,
                                  clean.state.win_corr_j)


def test_delayed_samples_count_late_and_do_not_raise():
    bank = _attached_bank()
    mon = MonitorService(6)
    rep = replay(bank, mon, 0.0, 1.0, delay_fraction=0.2, seed=2)
    assert rep["late"] > 0
    clean = MonitorService(6)
    replay(bank, clean, 0.0, 1.0)
    # late samples are dropped; rectangle integration fills the gaps, so
    # totals stay close to the clean replay
    np.testing.assert_allclose(mon.state.energy_j, clean.state.energy_j,
                               rtol=0.05)


def test_dropped_samples_keep_totals_close():
    bank = _attached_bank()
    mon = MonitorService(6)
    replay(bank, mon, 0.0, 1.0, drop_fraction=0.1, seed=9)
    clean = MonitorService(6)
    replay(bank, clean, 0.0, 1.0)
    np.testing.assert_allclose(mon.state.energy_j, clean.state.energy_j,
                               rtol=0.05)


def test_silent_device_flags_and_max_hold_cap():
    mon = MonitorService(2, max_hold_s=0.5, ring_slots=4)
    # device 0 polls steadily to t=1.0 then goes silent; device 1 sends a
    # single sample and goes silent immediately
    ts0 = 0.1 * np.arange(11)
    mon.ingest(np.zeros(11, np.int64), ts0, np.full(11, 100.0))
    mon.ingest([1], [0.0], [80.0])
    flags = mon.flags(t=5.0)
    assert bool(flags["silent"][0]) and bool(flags["silent"][1])
    fe = mon.fleet_energy(t=5.0)
    # gap-aware rectangle: any sampling gap longer than max_hold_s stops
    # extrapolating after max_hold_s (steady 0.1 s polls are unaffected)
    assert fe.per_device_j[0] == pytest.approx(100.0 * 1.0 + 100.0 * 0.5)
    assert fe.per_device_j[1] == pytest.approx(80.0 * 0.5)


def test_single_sample_and_never_reporting_devices():
    mon = MonitorService(3, ring_slots=4)
    mon.ingest([0], [0.5], [120.0])
    fe = mon.fleet_energy(t=2.0)
    assert fe.per_device_j[0] == pytest.approx(120.0 * 1.5)
    assert fe.per_device_j[1] == 0.0 and fe.per_device_j[2] == 0.0
    assert fe.n_reporting == 1
    assert np.isnan(mon.update_period_s()).all()
    e, cov = mon.energy_between(0.6, 0.7)
    assert cov[0] and e[0] == pytest.approx(120.0 * 0.1)


def test_empty_and_precoverage_windows_degrade_gracefully():
    mon = MonitorService(1, ring_slots=4)
    ts = 0.1 * np.arange(1, 30)          # 2.9 s of samples, ring keeps 4
    mon.ingest(np.zeros(len(ts), np.int64), ts, np.full(len(ts), 50.0))
    # window entirely before the first sample: zero, covered
    e, cov = mon.energy_between(0.0, 0.05)
    assert cov[0] and e[0] == 0.0
    # window older than ring coverage: nan + not covered, no raise
    e, cov = mon.energy_between(0.5, 0.6)
    assert not cov[0] and np.isnan(e[0])
    # recent window inside ring coverage: exact
    e, cov = mon.energy_between(2.65, 2.85)
    assert cov[0] and e[0] == pytest.approx(50.0 * 0.2)


def test_invalid_samples_and_bad_inputs():
    mon = MonitorService(2)
    rep = mon.ingest([0, 1], [np.nan, 1.0], [100.0, np.inf])
    assert rep.invalid == 2 and rep.accepted == 0
    with pytest.raises(ValueError):
        mon.ingest([0, 2], [0.0, 0.0], [1.0, 1.0])    # id out of range
    with pytest.raises(ValueError):
        mon.ingest([0], [0.0, 1.0], [1.0])            # shape mismatch
    with pytest.raises(ValueError):
        MonitorService(2, integration="simpson")
    with pytest.raises(ValueError):
        MonitorService(0)
    mon2 = MonitorService(2)
    mon2.ingest([0], [0.0], [1.0])
    with pytest.raises(RuntimeError):
        mon2.set_windows(0.0, 1.0)       # windows after first ingest


def test_energy_between_rejects_inverted_and_nan_windows():
    """Edge contract (docs/streaming.md): malformed windows raise at the
    API boundary instead of returning silently-wrong zeros."""
    mon = MonitorService(2)
    mon.ingest([0, 1], [0.0, 0.0], [100.0, 100.0])
    with pytest.raises(ValueError):
        mon.energy_between(1.0, 0.5)
    with pytest.raises(ValueError):
        mon.energy_between(np.nan, 1.0)
    with pytest.raises(ValueError):
        mon.energy_between(0.0, np.nan)
    # degenerate t0 == t1: exactly zero wherever covered
    e, cov = mon.energy_between(0.0, 0.0)
    assert np.all(e[cov] == 0.0)


def test_by_label_empty_groups_report_nan_means():
    """Groups with no covered device answer total_j = 0 but nan
    mean/std — 'no data' must not masquerade as 'measured zero'."""
    mon = MonitorService(2, labels=np.array(["a", "b"], dtype=object))
    for d in mon.by_label().values():
        assert d["n_covered"] == 0 and d["total_j"] == 0.0
        assert np.isnan(d["mean_j"]) and np.isnan(d["std_j"])


def test_window_energy_past_query_reports_nan_not_overstatement():
    """A still-open window that already streamed past the query instant
    cannot be rewound: the device reports nan instead of the inflated
    through-newest-sample value; closed windows stay exact."""
    mon = MonitorService(1)
    mon.set_windows(0.0, 20.0)
    ts = 0.5 * np.arange(20)                 # samples to t = 9.5
    mon.ingest(np.zeros(20, np.int64), ts, np.full(20, 100.0))
    assert np.isnan(mon.window_energy(t=5.0, corrected=False)[0])
    # live/future instants still serve the rectangle tail
    assert mon.window_energy(t=10.0, corrected=False)[0] == \
        pytest.approx(100.0 * 10.0)
    # instants before the window opens are exactly 0
    assert mon.window_energy(t=0.0, corrected=False)[0] == 0.0
    # a *closed* window is exact for any later query instant
    mon2 = MonitorService(1)
    mon2.set_windows(0.0, 2.0)
    mon2.ingest(np.zeros(20, np.int64), ts, np.full(20, 100.0))
    assert mon2.window_energy(t=5.0, corrected=False)[0] == \
        pytest.approx(100.0 * 2.0)


def test_integrate_polled_vector_grid_offset():
    """Per-device grid_offset equals the per-group scalar calls (fleets
    mixing averaging windows re-synchronise in one pass)."""
    bank = _attached_bank(n=6)
    a = np.full(6, 0.3)
    b = np.full(6, 0.5)
    offs = np.array([0.0, -0.025, -0.1, 0.0, -0.025, -0.1])
    got = bank.integrate_polled(0.0, 1.0, 0.001, a, b, grid_offset=offs)
    for w in np.unique(offs):
        rows = offs == w
        ref = bank.integrate_polled(0.0, 1.0, 0.001, a, b,
                                    grid_offset=float(w))
        np.testing.assert_allclose(got[rows], ref[rows], rtol=1e-12)


def test_trapezoid_integration_mode():
    mon = MonitorService(1, integration="trapezoid")
    mon.ingest([0, 0, 0], [0.0, 1.0, 2.0], [0.0, 100.0, 50.0])
    assert mon.state.energy_j[0] == pytest.approx(
        0.5 * (0 + 100) + 0.5 * (100 + 50))


# ---------------------------------------------------------------------------
# online estimators, queries, flags, telemetry
# ---------------------------------------------------------------------------

def test_online_period_estimator_unit():
    est = OnlinePeriodEstimator(2, min_runs=3)
    est.record(np.zeros(8, np.int64), np.full(8, 0.1))
    est.record(np.array([0]), np.array([0.2]))       # one outlier run
    out = est.estimates()
    assert out[0] == pytest.approx(0.1, rel=1e-9)    # median bin mean
    assert np.isnan(out[1])
    assert est.n_runs[0] == 9


def test_online_period_matches_offline_estimator():
    """Streaming the §4.1 square-wave capture through the monitor lands
    on the same update period as the offline median-of-complete-runs."""
    prof = profiles.get("a100")
    sensor = OnboardSensor(prof, seed=7)
    offline = microbench.estimate_update_period(sensor, duration_s=4.0)

    bank = SensorBank.from_catalog(["a100"], seeds=[7])
    wave = loads.square_wave(period_s=0.020, n_cycles=int(4.0 / 0.020),
                             p_high=220.0, p_low=70.0, seed=11)
    bank.attach(wave, t_end=4.0)
    mon = MonitorService(1)
    replay(bank, mon, 0.0, 4.0, period_s=0.001, tick_s=0.25)
    online = float(mon.update_period_s()[0])
    assert online == pytest.approx(0.100, rel=0.05)
    assert online == pytest.approx(offline, rel=0.05)


def test_complete_run_durations_shared_rule():
    ts = 0.001 * np.arange(600)
    vals = np.searchsorted([0.03, 0.13, 0.33, 0.53], ts, side="right")
    runs = microbench.complete_run_durations(ts, vals)
    assert len(runs) == 3
    assert np.median(runs) == pytest.approx(0.2, abs=1e-9)
    # fewer than two changes -> no complete run
    assert len(microbench.complete_run_durations(ts, np.zeros(600))) == 0


def test_by_label_and_reading_stats():
    n = 8
    labels = np.array(["train"] * 4 + ["serve"] * 4, dtype=object)
    mon = MonitorService(n, labels=labels, ring_slots=8)
    ts = np.tile(0.1 * np.arange(1, 11), n)
    dev = np.repeat(np.arange(n), 10)
    v = np.where(dev < 4, 200.0, 100.0)
    mon.ingest(dev, ts, v)
    by = mon.by_label()
    assert set(by) == {"train", "serve"}
    assert by["train"]["total_j"] == pytest.approx(4 * 200.0 * 0.9)
    assert by["serve"]["total_j"] == pytest.approx(4 * 100.0 * 0.9)
    # windowed breakdown over ring coverage
    by_w = mon.by_label(t0=0.55, t1=0.95)
    assert by_w["train"]["total_j"] == pytest.approx(4 * 200.0 * 0.4)
    stats = mon.reading_stats()
    assert stats["train"]["mean_err"] == pytest.approx(200.0)
    assert stats["serve"]["worst_abs"] == pytest.approx(100.0)


def test_anomaly_envelope_and_drift_flags():
    mon = MonitorService(2, envelope_w=(0.0, 150.0), drift_tau_s=0.1,
                         drift_rel=0.05, drift_abs_w=1.0)
    ts = 0.01 * np.arange(1, 101)
    # stream tick by tick (the EWMA tracks recency across slabs):
    # device 0 holds steady, device 1 ramps up and leaves the envelope
    for lo in range(0, 100, 10):
        sl = ts[lo:lo + 10]
        mon.ingest(np.zeros(10, np.int64), sl, np.full(10, 100.0))
        mon.ingest(np.ones(10, np.int64), sl, 100.0 + sl * 100.0)
    flags = mon.flags()
    assert not flags["anomalous"][0]
    assert bool(flags["anomalous"][1])      # peaked at 200 W > 150 W
    assert not flags["drifting"][0]
    assert bool(flags["drifting"][1])


def test_fleet_energy_uncertainty_tolerances():
    corr = StreamCorrections.identity(2)
    corr.calibrated[0] = True
    mon = MonitorService(2, corrections=corr)
    mon.ingest([0, 0, 1, 1], [0.0, 1.0, 0.0, 1.0],
               [100.0, 100.0, 100.0, 100.0])
    fe = mon.fleet_energy()
    assert fe.sigma_worstcase_j == pytest.approx(
        100.0 * CALIBRATED_TOLERANCE + 100.0 * SHUNT_TOLERANCE)
    assert fe.sigma_independent_j <= fe.sigma_worstcase_j


def test_register_monitor_in_fleet_ledger():
    labels = np.array(["a", "b"], dtype=object)
    mon = MonitorService(2, labels=labels)
    mon.ingest([0, 0, 1, 1], [0.0, 2.0, 0.0, 2.0],
               [100.0, 100.0, 50.0, 50.0])
    led = FleetLedger()
    led.register_monitor(mon)
    s = led.summary()
    assert s.n_devices == 2
    assert s.total_j == pytest.approx(300.0)
    by = led.by_label()
    assert by["a"].total_j == pytest.approx(200.0)
    assert by["b"].total_j == pytest.approx(100.0)


def test_ingest_buffer_ring_ordering():
    buf = IngestBuffer(1, 4)
    dev = np.zeros(6, np.int64)
    ordi = np.arange(6)
    cnt = np.full(6, 6)
    t = np.arange(6.0)
    e = np.cumsum(t)
    buf.write(dev, ordi, cnt, t, t * 10, e, e, np.array([0]),
              np.array([6]))
    ts, vs, er, ec = buf.sorted_view()
    np.testing.assert_array_equal(ts[0], [2.0, 3.0, 4.0, 5.0])
    assert int(buf.n_written[0]) == 6
    with pytest.raises(ValueError):
        IngestBuffer(1, -1)
    none = IngestBuffer(1, 0)
    with pytest.raises(RuntimeError):
        none.sorted_view()


def test_monitor_bounded_state_reporting():
    mon = MonitorService(1000, ring_slots=4)
    per_device = mon.nbytes() / 1000
    assert per_device < 1000     # a few hundred bytes per device
