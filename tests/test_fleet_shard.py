"""Mesh-sharded audit parity (ISSUE 7).

``ShardedBackend`` must be a drop-in for the jax backend module: every
kernel call over a ``("data",)`` mesh matches the single-process jax
result (row-independent math — bitwise up to shard padding), the
on-device Chan tree reduction matches the host-side sequential
``StreamingMoments`` folding, and ``fleet_audit_sharded`` reproduces
``fleet_audit`` (energies, ``_err_stats``, ``by_scenario`` moments)
within the chunked-audit tolerance.

The module runs on however many devices the host exposes — a degenerate
1-device mesh in a plain run; CI's shard-mesh job (and the recipe in
``docs/scaling.md``) sets ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
before the first jax import so the same assertions exercise a real
multi-shard mesh with padding seams.
"""
import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core import load as loads
from repro.core.engine_backend import get_backend, resolve_backend
from repro.core.engine_backend import jax_backend, numpy_backend
from repro.core.fleet_engine import SensorBank, StreamingMoments, fleet_audit
from repro.core.fleet_engine_shard import (ShardedBackend,
                                           fleet_audit_sharded,
                                           tree_merge_moments)
from repro.launch.mesh import data_mesh

N_DEV = jax.device_count()
PROFILES = ["a100", "h100_instant", "v100", "rtx3090_530"]


def _names(n):
    return [PROFILES[i % len(PROFILES)] for i in range(n)]


@pytest.fixture(scope="module")
def sharded_be():
    return ShardedBackend(data_mesh(N_DEV))


def test_resolve_backend_passes_objects_through(sharded_be):
    assert resolve_backend(sharded_be) is sharded_be
    assert get_backend(sharded_be) is sharded_be
    with pytest.raises(ValueError, match="lacks kernel"):
        resolve_backend(object())


def test_sharded_backend_requires_data_axis():
    from repro.launch.mesh import make_mesh
    with pytest.raises(ValueError, match="data"):
        ShardedBackend(make_mesh((1,), ("model",)))


def test_sharded_kernels_match_jax_bank(sharded_be):
    """Every transient kind + query path through a sharded bank equals
    the plain jax bank — row counts chosen to force padding on any
    shard count up to 8."""
    n = 4 * N_DEV + 3 if N_DEV > 1 else 11
    names = _names(n)
    bank_j = SensorBank.from_catalog(names, base_seed=5, backend="jax")
    bank_s = SensorBank.from_catalog(names, base_seed=5,
                                     backend=sharded_be)
    tl = loads.square_wave(0.230, 16, 220.0, 90.0)
    bank_j.attach(tl, t_start=0.0)
    bank_s.attach(tl, t_start=0.0)
    np.testing.assert_allclose(bank_s._values, bank_j._values,
                               rtol=1e-12, atol=1e-12)
    tq = np.linspace(0.0, 3.5, 7)
    np.testing.assert_allclose(bank_s.query(tq), bank_j.query(tq),
                               rtol=1e-12, atol=1e-12)


def test_sharded_integrate_polled_matches_jax(sharded_be):
    n = 4 * N_DEV + 1 if N_DEV > 1 else 9
    names = _names(n)
    tl = loads.square_wave(0.200, 12, 230.0, 80.0)
    banks = {}
    for key, be in (("jax", "jax"), ("shard", sharded_be)):
        bank = SensorBank.from_catalog(names, base_seed=2, backend=be)
        bank.attach(tl, t_start=0.0)
        banks[key] = bank.integrate_polled(0.0, 2.4, 0.001, 0.1, 2.3)
    np.testing.assert_allclose(banks["shard"], banks["jax"],
                               rtol=1e-12, atol=1e-12)


def test_on_device_moments_match_numpy(sharded_be):
    rng = np.random.default_rng(0)
    for size in (1, 2, N_DEV, 5 * N_DEV + 3, 1000):
        e = rng.normal(scale=0.2, size=size)
        ns, ms, m2s, mas, xs = sharded_be.err_moments(e)
        nn, mn, m2n, man, xn = numpy_backend.err_moments(e)
        assert ns == nn
        np.testing.assert_allclose([ms, m2s, mas, xs],
                                   [mn, m2n, man, xn],
                                   rtol=1e-12, atol=1e-15)
    assert sharded_be.err_moments(np.array([])) == (0, 0.0, 0.0, 0.0, 0.0)


def test_tree_merge_matches_sequential_fold():
    """The on-device binary tree over per-partition moment blocks agrees
    with the host-side sequential Chan folding, for awkward block counts
    (non-powers of two, empty blocks interleaved)."""
    rng = np.random.default_rng(7)
    e = rng.normal(size=257)
    for cuts in ([0, 257], [0, 1, 257], [0, 40, 40, 100, 256, 257],
                 [0, 17, 45, 45, 45, 120, 200, 250, 257]):
        blocks = []
        seq = StreamingMoments()
        for lo, hi in zip(cuts[:-1], cuts[1:]):
            m = numpy_backend.err_moments(e[lo:hi])
            blocks.append([float(m[0]), m[1], m[2], m[3], m[4]])
            seq.merge(*m)
        merged = np.asarray(tree_merge_moments(np.asarray(blocks)))
        assert int(merged[0]) == seq.n
        np.testing.assert_allclose(
            merged[1:], [seq.mean, seq.m2, seq.mean_abs, seq.max_abs],
            rtol=1e-12, atol=1e-15)


def test_streaming_moments_update_routes_through_sharded_backend(sharded_be):
    e = np.random.default_rng(3).normal(size=101)
    sm = StreamingMoments().update(e, sharded_be)
    ref = StreamingMoments().update(e)
    assert sm.n == ref.n
    np.testing.assert_allclose(
        [sm.mean, sm.m2, sm.mean_abs, sm.max_abs],
        [ref.mean, ref.m2, ref.mean_abs, ref.max_abs], rtol=1e-12)


def test_fleet_audit_sharded_matches_single_shard():
    """ISSUE 7 acceptance: sharded audit == single-process audit at the
    same super-slab chunking — energies bitwise-tight, streamed moment
    stats within float tolerance, by_scenario intact."""
    n = 25 * max(N_DEV, 4) + 2            # never a multiple of the mesh
    names = _names(n)
    spec = loads.FleetScenarioSpec(n=n, seed=7)
    chunk = 50 * max(N_DEV, 4)
    ref = fleet_audit(n, profile=names, workload=spec, backend="jax",
                      chunk_devices=chunk, good_practice=True)
    sh = fleet_audit_sharded(n, profile=names, workload=spec,
                             n_shards=N_DEV,
                             shard_chunk=-(-chunk // N_DEV),
                             good_practice=True)
    np.testing.assert_allclose(sh.naive_j, ref.naive_j, rtol=1e-9)
    np.testing.assert_allclose(sh.naive_err, ref.naive_err,
                               rtol=1e-9, atol=1e-12)
    np.testing.assert_allclose(sh.gp_j, ref.gp_j, rtol=1e-9)
    for key in ("mean_err", "mean_abs_err", "std_err", "worst_abs"):
        assert sh.stats()[key] == pytest.approx(ref.stats()[key],
                                                rel=1e-9, abs=1e-12)
    assert sh.streamed["naive"]["overall"]["n_devices"] == n
    ref_by = ref.by_scenario()
    sh_by = sh.by_scenario()
    assert sorted(sh_by) == sorted(ref_by)
    for label, st in ref_by.items():
        assert sh_by[label]["n_devices"] == st["n_devices"]
        assert sh_by[label]["mean_abs_err"] == pytest.approx(
            st["mean_abs_err"], rel=1e-9, abs=1e-12)
    for label, st in ref.streamed["naive"]["by_scenario"].items():
        got = sh.streamed["naive"]["by_scenario"][label]
        assert got["n_devices"] == st["n_devices"]
        assert got["mean_abs_err"] == pytest.approx(st["mean_abs_err"],
                                                    rel=1e-9, abs=1e-12)


def test_fleet_audit_mesh_kwarg_equivalent_to_entry_point():
    n = 8 * max(N_DEV, 1)
    names = _names(n)
    mesh = data_mesh(N_DEV)
    via_kwarg = fleet_audit(n, profile=names, mesh=mesh,
                            chunk_devices=n)
    via_entry = fleet_audit_sharded(n, profile=names, n_shards=N_DEV,
                                    shard_chunk=-(-n // N_DEV))
    np.testing.assert_array_equal(via_kwarg.naive_j, via_entry.naive_j)
    with pytest.raises(ValueError, match="not both"):
        fleet_audit(4, mesh=mesh, backend=ShardedBackend(mesh))


def test_sharded_prefetch_identical_to_sequential():
    n = 12 * max(N_DEV, 1)
    spec = loads.FleetScenarioSpec(n=n, seed=11)
    mesh = data_mesh(N_DEV)
    a = fleet_audit(n, profile=_names(n), workload=spec, mesh=mesh,
                    chunk_devices=4 * N_DEV, prefetch_workloads=True)
    b = fleet_audit(n, profile=_names(n), workload=spec, mesh=mesh,
                    chunk_devices=4 * N_DEV, prefetch_workloads=False)
    np.testing.assert_array_equal(a.naive_j, b.naive_j)
    np.testing.assert_array_equal(a.naive_err, b.naive_err)
