"""ActivityTimeline / GroundTruthMeter invariants (unit + property)."""
import numpy as np
import pytest
from _hyp import given, settings, st  # degrades to per-test skips without hypothesis

from repro.core.ground_truth import (ActivityTimeline, GroundTruthMeter,
                                     from_segments)
from repro.core import load as loads


def test_power_at_basic():
    tl = from_segments([(1.0, 100.0), (0.5, 50.0)], idle_w=60.0)
    assert tl.power_at(np.array([0.5]))[0] == 100.0
    assert tl.power_at(np.array([1.2]))[0] == 50.0
    assert tl.power_at(np.array([2.0]))[0] == 60.0     # past end: idle
    assert tl.power_at(np.array([-1.0]))[0] == 60.0    # before start: idle


def test_energy_analytic():
    tl = from_segments([(1.0, 100.0), (0.5, 50.0)])
    assert tl.energy() == pytest.approx(125.0)
    assert tl.integral(np.array(0.5), np.array(1.25)) == pytest.approx(
        0.5 * 100 + 0.25 * 50)


def test_mean_power():
    tl = from_segments([(1.0, 100.0), (1.0, 50.0)])
    assert tl.mean_power(np.array(0.0), np.array(2.0)) == pytest.approx(75.0)


def test_concat_and_repeat_preserve_energy():
    frag = from_segments([(0.1, 200.0)], idle_w=60.0)
    train = frag.repeat(10)
    assert train.energy() == pytest.approx(10 * frag.energy())
    with_gaps = ActivityTimeline.concat([frag] * 10, gap_s=0.05)
    assert with_gaps.energy() == pytest.approx(
        10 * frag.energy() + 9 * 0.05 * 60.0)
    assert with_gaps.t_end == pytest.approx(10 * 0.1 + 9 * 0.05)


def test_concat_is_contiguous():
    frag = from_segments([(0.1, 200.0), (0.05, 80.0)])
    train = frag.repeat(4)
    # power at the very start of each repetition is the high state
    for i in range(4):
        t = i * 0.15 + 1e-6
        assert train.power_at(np.array([t]))[0] == 200.0
        assert train.power_at(np.array([t + 0.1]))[0] == 80.0


@settings(max_examples=30, deadline=None)
@given(
    segs=st.lists(
        st.tuples(st.floats(0.01, 1.0), st.floats(0.0, 500.0)),
        min_size=1, max_size=10),
    idle=st.floats(1.0, 100.0),
)
def test_integral_matches_riemann(segs, idle):
    tl = from_segments(segs, idle_w=idle)
    t0, t1 = -0.5, tl.t_end + 0.5
    ts = np.linspace(t0, t1, 20001)
    dt = ts[1] - ts[0]
    riemann = float(np.sum(tl.power_at(ts[:-1])) * dt)
    exact = float(tl.integral(np.array(t0), np.array(t1)))
    # left-Riemann discretisation error: one grid cell of the largest
    # power jump per segment edge
    p_max = max(float(np.max(tl.powers)), idle)
    tol = dt * p_max * (len(tl.powers) + 2)
    assert exact == pytest.approx(riemann, rel=2e-3, abs=tol)


@settings(max_examples=20, deadline=None)
@given(period=st.floats(0.02, 0.3), n=st.integers(2, 20),
       hi=st.floats(100, 400), lo=st.floats(10, 90))
def test_square_wave_energy(period, n, hi, lo):
    tl = loads.square_wave(period, n, hi, lo, duty=0.5)
    expect = n * period * 0.5 * (hi + lo)
    assert tl.energy() == pytest.approx(expect, rel=1e-9)


def test_concat_gap_idle_energy_accounting():
    """Gap energy uses the *override* idle level when one is supplied,
    regardless of the fragments' own idle_w."""
    frag = from_segments([(0.1, 200.0)], idle_w=60.0)
    over = ActivityTimeline.concat([frag] * 4, gap_s=0.2, idle_w=10.0)
    assert over.energy() == pytest.approx(4 * 20.0 + 3 * 0.2 * 10.0)
    # default: idle of the first part
    default = ActivityTimeline.concat([frag] * 4, gap_s=0.2)
    assert default.energy() == pytest.approx(4 * 20.0 + 3 * 0.2 * 60.0)


def test_concat_mismatched_idle_w_uses_first_part():
    a = from_segments([(0.1, 200.0)], idle_w=60.0)
    b = from_segments([(0.1, 100.0)], idle_w=30.0)
    tl = ActivityTimeline.concat([a, b], gap_s=0.5)
    assert tl.idle_w == 60.0
    # the gap segment carries the first part's idle level
    assert tl.power_at(np.array([0.3]))[0] == 60.0
    assert tl.energy() == pytest.approx(20.0 + 10.0 + 0.5 * 60.0)


def test_concat_empty_parts_raises():
    with pytest.raises(ValueError, match="no parts"):
        ActivityTimeline.concat([])


def test_zero_width_segments_contribute_nothing():
    tl = from_segments([(0.5, 100.0), (0.0, 900.0), (0.5, 50.0)])
    assert tl.energy() == pytest.approx(75.0)
    # a zero-width segment never owns any instant
    assert tl.power_at(np.array([0.5]))[0] == 50.0
    train = tl.repeat(3)
    assert train.energy() == pytest.approx(3 * 75.0)
    assert train.t_end == pytest.approx(3.0)


def test_repeat_with_gap_matches_concat():
    frag = from_segments([(0.1, 200.0), (0.05, 80.0)], idle_w=40.0)
    np.testing.assert_array_equal(
        frag.repeat(5, gap_s=0.02).edges,
        ActivityTimeline.concat([frag] * 5, gap_s=0.02).edges)


def test_sum_timelines_pointwise_and_idle():
    from repro.core.sensor import _sum_timelines

    a = from_segments([(1.0, 100.0), (1.0, 50.0)], idle_w=60.0)
    b = from_segments([(0.5, 10.0), (2.0, 20.0)], t0=0.75, idle_w=40.0)
    s = _sum_timelines(a, b)
    # idle levels add (module = chip + host when both are idle)
    assert s.idle_w == 100.0
    ts = np.array([0.1, 0.8, 1.5, 2.2, 3.5])
    np.testing.assert_allclose(s.power_at(ts),
                               a.power_at(ts) + b.power_at(ts))
    # edges are the union: piecewise-constant everywhere in between
    fine = np.linspace(-0.5, 3.5, 4001)
    np.testing.assert_allclose(s.power_at(fine),
                               a.power_at(fine) + b.power_at(fine))


def test_sum_timelines_disjoint_support_gap_is_sum_of_idles():
    """Between a's end and b's start neither covers t: the summed timeline
    reports a.idle + b.idle there — the module draws both idle floors."""
    from repro.core.sensor import _sum_timelines

    a = from_segments([(1.0, 100.0)], idle_w=60.0)
    b = from_segments([(1.0, 30.0)], t0=2.0, idle_w=40.0)
    s = _sum_timelines(a, b)
    assert s.power_at(np.array([1.5]))[0] == pytest.approx(100.0)
    assert s.energy() == pytest.approx(
        1.0 * (100.0 + 40.0) + 1.0 * (60.0 + 40.0) + 1.0 * (60.0 + 30.0))


def test_sum_timelines_with_zero_width_segments():
    from repro.core.sensor import _sum_timelines

    a = from_segments([(0.5, 100.0), (0.0, 999.0), (0.5, 50.0)], idle_w=60.0)
    b = from_segments([(1.0, 10.0)], idle_w=5.0)
    s = _sum_timelines(a, b)
    assert s.power_at(np.array([0.25]))[0] == pytest.approx(110.0)
    assert s.power_at(np.array([0.75]))[0] == pytest.approx(60.0)
    assert s.energy() == pytest.approx(0.5 * 110.0 + 0.5 * 60.0)


def test_pmd_trace_close_to_truth():
    tl = loads.square_wave(0.1, 20, 220.0, 70.0)
    meter = GroundTruthMeter(seed=1)
    e = meter.energy(tl)
    assert e == pytest.approx(tl.energy(), rel=0.02)


def test_meter_quantisation_error_is_bounded():
    tl = from_segments([(2.0, 123.456)])
    meter = GroundTruthMeter(noise_w=0.0, seed=0)
    ts, w = meter.trace(tl, 0.0, 2.0)
    # ADC quantum: 0.0488 A * 12 V ≈ 0.586 W
    assert np.all(np.abs(w - 123.456) < 0.6)
