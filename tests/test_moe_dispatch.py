"""MoE grouped inverse-map dispatch correctness (§Perf iterations M1–M4).

The dispatch rewrite is the framework's hottest perf fix — these tests pin
its semantics: group-local dispatch ≡ ungrouped when capacity is ample,
dropped tokens never clobber live slots, padded experts receive nothing.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # degrades to per-test skips without hypothesis

from repro.distributed import act_shard
from repro.models.moe import moe_ffn


def _params(rng, D, F, E_pad, shared=False):
    ks = jax.random.split(rng, 7)
    p = {
        "router": jax.random.normal(ks[0], (D, 8), jnp.float32) * 0.3,
        "w_gate": jax.random.normal(ks[1], (E_pad, D, F), jnp.float32) * 0.1,
        "w_up": jax.random.normal(ks[2], (E_pad, D, F), jnp.float32) * 0.1,
        "w_down": jax.random.normal(ks[3], (E_pad, F, D), jnp.float32) * 0.1,
    }
    if shared:
        p["shared_gate"] = jax.random.normal(ks[4], (D, F), jnp.float32) * 0.1
        p["shared_up"] = jax.random.normal(ks[5], (D, F), jnp.float32) * 0.1
        p["shared_down"] = jax.random.normal(ks[6], (F, D), jnp.float32) * 0.1
    return p


def _ref_moe(x, p, E, k):
    """Dense oracle: every token through its top-k experts, no capacity."""
    B, S, D = x.shape
    T = B * S
    xt = x.reshape(T, D).astype(jnp.float32)
    probs = jax.nn.softmax(xt @ p["router"], axis=-1)
    topw, topi = jax.lax.top_k(probs, k)
    topw = topw / topw.sum(-1, keepdims=True)
    y = jnp.zeros((T, D), jnp.float32)
    for slot in range(k):
        e = topi[:, slot]
        wg = p["w_gate"][e]      # [T,D,F]
        wu = p["w_up"][e]
        wd = p["w_down"][e]
        g = jax.nn.silu(jnp.einsum("td,tdf->tf", xt, wg))
        u = jnp.einsum("td,tdf->tf", xt, wu)
        y = y + topw[:, slot:slot + 1] * jnp.einsum("tf,tfd->td", g * u, wd)
    return y.reshape(B, S, D)


def test_moe_matches_dense_oracle_when_capacity_ample():
    rng = jax.random.PRNGKey(0)
    B, S, D, F = 2, 16, 8, 16
    p = _params(rng, D, F, E_pad=8)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, D), jnp.float32)
    out = moe_ffn(x, p, n_experts=8, top_k=2, capacity_factor=8.0)
    want = _ref_moe(x, p, 8, 2)
    np.testing.assert_allclose(np.asarray(out.y), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=6, deadline=None)
@given(G=st.sampled_from([1, 2, 4]), seed=st.integers(0, 50))
def test_grouped_dispatch_independent_of_group_count(G, seed):
    """With ample capacity the result must not depend on G (groups only
    change WHERE slots live, not which tokens compute)."""
    rng = jax.random.PRNGKey(seed)
    B, S, D, F = 4, 8, 8, 16
    p = _params(rng, D, F, E_pad=8)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (B, S, D),
                          jnp.float32)
    act_shard.set_context((), "", 1, batch_size=G)
    try:
        out_g = moe_ffn(x, p, n_experts=8, top_k=2, capacity_factor=8.0)
    finally:
        act_shard.clear_context()
    out_1 = moe_ffn(x, p, n_experts=8, top_k=2, capacity_factor=8.0)
    np.testing.assert_allclose(np.asarray(out_g.y), np.asarray(out_1.y),
                               rtol=2e-4, atol=2e-4)


def test_padded_experts_receive_no_tokens():
    """Router has 8 logits but weights are padded to 16: output must be
    identical to the unpadded weights (dummy rows untouched)."""
    rng = jax.random.PRNGKey(2)
    B, S, D, F = 2, 8, 8, 16
    p8 = _params(rng, D, F, E_pad=8)
    p16 = dict(p8)
    for k in ("w_gate", "w_up", "w_down"):
        pad_shape = (8,) + p8[k].shape[1:]
        # poison the padded rows: if any token touched them, outputs differ
        p16[k] = jnp.concatenate(
            [p8[k], jnp.full(pad_shape, 1e3, jnp.float32)], axis=0)
    x = jax.random.normal(jax.random.PRNGKey(3), (B, S, D), jnp.float32)
    o8 = moe_ffn(x, p8, n_experts=8, top_k=2, capacity_factor=8.0)
    o16 = moe_ffn(x, p16, n_experts=8, top_k=2, capacity_factor=8.0)
    np.testing.assert_allclose(np.asarray(o8.y), np.asarray(o16.y),
                               rtol=1e-5, atol=1e-5)


def test_dropped_tokens_zero_not_clobber():
    """Tiny capacity: over-capacity tokens contribute zero and never
    overwrite live slots (§Perf M4 latent-bug regression test)."""
    rng = jax.random.PRNGKey(4)
    # capacity rounds up to 128 slots, so force > 128 tokens into one
    # expert to actually exercise drops
    B, S, D, F = 2, 512, 8, 16
    p = _params(rng, D, F, E_pad=8)
    # route everything to expert 0 by biasing the router
    p = dict(p, router=jnp.zeros((D, 8)).at[:, 0].set(5.0))
    x = jax.random.normal(jax.random.PRNGKey(5), (B, S, D), jnp.float32)
    out = moe_ffn(x, p, n_experts=8, top_k=1, capacity_factor=0.05)
    assert np.isfinite(np.asarray(out.y)).all()
    # most tokens dropped: output rows mostly exactly zero
    zero_rows = np.mean(np.all(np.asarray(out.y) == 0.0, axis=-1))
    assert zero_rows > 0.5


def test_moe_grads_finite_under_drops():
    rng = jax.random.PRNGKey(6)
    p = _params(rng, 8, 16, E_pad=8, shared=True)
    x = jax.random.normal(jax.random.PRNGKey(7), (2, 32, 8), jnp.float32)

    def loss(p):
        out = moe_ffn(x, p, n_experts=8, top_k=2, capacity_factor=0.5)
        return jnp.sum(out.y ** 2) + out.aux_loss

    grads = jax.grad(loss)(p)
    for g in jax.tree_util.tree_leaves(grads):
        assert np.isfinite(np.asarray(g)).all()
