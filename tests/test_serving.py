"""Snapshot serving stack (ISSUE 8).

Five groups:

* snapshot publication — immutability (a held snapshot answers bitwise
  identically while ingestion continues; its arrays refuse writes) and
  epoch monotonicity (seeded always-run variant + hypothesis property);
* the batched query executor — bitwise equality with the direct query
  path, dedup, LRU behaviour, and the ``(query, epoch)`` cache never
  serving a result across epochs;
* query-edge contract — ``energy_between`` endpoint validation, ring
  horizon, ``by_label`` on empty monitors (regression pins for the
  documented semantics);
* checkpoint/restore — kill at an arbitrary slab boundary, restore
  (same process and a fresh one), continue, all queries bitwise equal
  to the uninterrupted run, on every available backend;
* schema versioning — field drift, dtype drift, version and key-set
  mismatches all fail loudly instead of corrupting restores.

This module is jax-optional end to end: the jax-parametrized cases
skip on numpy-only hosts.
"""
import dataclasses
import subprocess
import sys

import numpy as np
import pytest

from _hyp import given, settings, st
from repro.core.stream import (DeviceState, MonitorService, SchemaError,
                               StreamCorrections, restore_monitor,
                               save_monitor)
from repro.core.stream import schema as stream_schema
from repro.serve.monitor_service import MonitorQuery, MonitorQueryService


@pytest.fixture(params=["numpy", "jax"])
def backend(request):
    from repro.core.engine_backend import available_backends
    if request.param not in available_backends():
        pytest.skip(f"backend '{request.param}' not available")
    return request.param


def _corr(n, seed=0):
    rng = np.random.default_rng(seed)
    return StreamCorrections(
        gain=rng.uniform(0.9, 1.1, n), offset_w=rng.uniform(-3.0, 3.0, n),
        time_shift_s=rng.uniform(-0.05, 0.0, n),
        baseline_w=rng.uniform(0.0, 5.0, n),
        ref_period_s=np.full(n, 0.1),
        calibrated=rng.random(n) < 0.5)


def _slabs(n, n_slabs=8, seed=0):
    """Deterministic messy poll slabs: per-slab jittered times, a few
    duplicates, out-of-order arrival."""
    rng = np.random.default_rng(seed)
    out = []
    t0 = 0.0
    for _ in range(n_slabs):
        k = int(rng.integers(3 * n, 6 * n))
        dev = rng.integers(0, n, k).astype(np.int64)
        t = t0 + np.sort(rng.uniform(0.0, 0.5, k))
        v = 80.0 + 40.0 * rng.random(k)
        perm = rng.permutation(k)
        out.append((dev[perm], t[perm], v[perm]))
        t0 += 0.5
    return out


def _monitor(n, backend, seed=0, **kw):
    labels = np.array(["train", "serve", "idle"], dtype=object)[
        np.arange(n) % 3]
    mon = MonitorService(n, corrections=_corr(n, seed), labels=labels,
                         max_hold_s=2.0, ring_slots=8, backend=backend,
                         **kw)
    mon.set_windows(0.5, 2.5)
    return mon


def _query_fingerprint(mon_or_snap):
    """Every query family's answers, for bitwise comparison."""
    fe = mon_or_snap.fleet_energy(t=1.7)
    eb = mon_or_snap.energy_between(0.9, 1.9)
    return {
        "fleet_per_device": fe.per_device_j,
        "fleet_covered": fe.covered,
        "fleet_total": np.float64(fe.total_j),
        "fleet_sig_ind": np.float64(fe.sigma_independent_j),
        "fleet_latest": mon_or_snap.fleet_energy().per_device_j,
        "between_e": eb[0], "between_cov": eb[1],
        "window": mon_or_snap.window_energy(t=1.8),
        "window_acc": mon_or_snap.window_energy(),
        "periods": mon_or_snap.update_period_s(),
        **{f"by_label.{k}.{m}": np.float64(v)
           for k, d in mon_or_snap.by_label().items() for m, v in d.items()},
        **{f"flags.{k}": v for k, v in mon_or_snap.flags(t=2.0).items()},
        **{f"stats.{k}.{m}": np.float64(v)
           for k, d in mon_or_snap.reading_stats().items()
           for m, v in d.items()},
    }


def _assert_fingerprints_equal(a, b):
    assert set(a) == set(b)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k], err_msg=k)


# ---------------------------------------------------------------------------
# snapshot immutability + epoch monotonicity
# ---------------------------------------------------------------------------

def test_snapshot_answers_stable_while_ingestion_continues(backend):
    mon = _monitor(9, backend)
    slabs = _slabs(9, n_slabs=6, seed=3)
    for dev, t, v in slabs[:3]:
        mon.ingest(dev, t, v)
    snap = mon.snapshot()
    before = _query_fingerprint(snap)
    for dev, t, v in slabs[3:]:
        mon.ingest(dev, t, v)
    # the held snapshot is bitwise frozen...
    _assert_fingerprints_equal(_query_fingerprint(snap), before)
    # ...while the monitor itself moved on
    assert mon.fleet_energy().total_j > before["fleet_total"]
    assert mon.snapshot() is not snap
    assert mon.snapshot().epoch > snap.epoch


def test_snapshot_arrays_refuse_writes():
    mon = _monitor(5, "numpy")
    dev, t, v = _slabs(5, 1, seed=1)[0]
    mon.ingest(dev, t, v)
    snap = mon.snapshot()
    with pytest.raises((ValueError, RuntimeError)):
        snap.state.energy_corr_j[0] = 1e9
    with pytest.raises((ValueError, RuntimeError)):
        snap.labels[0] = "oops"
    with pytest.raises((ValueError, RuntimeError)):
        snap._ring_view[0][0, 0] = -1.0
    # and the capture really is a copy: mutating live state (as the next
    # ingest does) leaves the snapshot untouched
    live_before = float(snap.state.energy_corr_j[0])
    mon.state.energy_corr_j[0] += 123.0
    assert float(snap.state.energy_corr_j[0]) == live_before
    mon.state.energy_corr_j[0] -= 123.0


def test_epoch_monotonic_seeded():
    mon = _monitor(6, "numpy")
    assert mon.epoch == 1          # set_windows published a config change
    seen = [mon.epoch]
    for dev, t, v in _slabs(6, n_slabs=5, seed=7):
        mon.ingest(dev, t, v)
        seen.append(mon.epoch)
    assert all(b > a for a, b in zip(seen, seen[1:]))
    # an empty slab mutates nothing and publishes nothing
    e = mon.epoch
    mon.ingest(np.empty(0, np.int64), np.empty(0), np.empty(0))
    assert mon.epoch == e
    # same epoch -> the published snapshot is reused, not re-copied
    assert mon.snapshot() is mon.snapshot()
    # grid ingestion bumps too
    mon2 = MonitorService(4)
    mon2.ingest_grid(np.arange(4), np.array([0.1, 0.2]),
                     np.full((4, 2), 100.0))
    assert mon2.epoch == 1


@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 5), st.integers(1, 12)),
                min_size=1, max_size=12),
       st.integers(0, 2 ** 31 - 1))
def test_epoch_and_cache_property(plan, seed):
    """Property: epochs only move forward; every served result was
    computed at the serving epoch (never leaked across a slab)."""
    rng = np.random.default_rng(seed)
    mon = MonitorService(6, ring_slots=4)
    svc = MonitorQueryService(mon, cache_size=8)
    t_hi = 0.0
    last_epoch = mon.epoch
    for kind, k in plan:
        if kind == 0:     # ingest one messy slab
            dev = rng.integers(0, 6, k).astype(np.int64)
            t = t_hi + rng.uniform(0.0, 0.3, k)
            mon.ingest(dev, t, 100.0 + rng.random(k))
            t_hi = max(t_hi, float(t.max()))
            assert mon.epoch > last_epoch
            last_epoch = mon.epoch
        else:             # serve a batch; answers must match the direct
            q = MonitorQuery.fleet_energy(t=t_hi * (k / 12.0))
            res = svc.query(q)
            direct = mon.fleet_energy(t=q.t)
            np.testing.assert_array_equal(res.per_device_j,
                                          direct.per_device_j)
            assert res.total_j == direct.total_j
        assert mon.epoch == last_epoch


# ---------------------------------------------------------------------------
# batched executor
# ---------------------------------------------------------------------------

def _query_mix():
    ts = [0.4, 1.1, 1.7, 2.3]
    qs = []
    for t in ts:
        qs.append(MonitorQuery.fleet_energy(t))
        qs.append(MonitorQuery.fleet_energy(t, corrected=False))
        qs.append(MonitorQuery.window_energy(t))
    qs.append(MonitorQuery.fleet_energy())
    qs.append(MonitorQuery.window_energy())
    qs.append(MonitorQuery.energy_between(0.9, 1.9))
    qs.append(MonitorQuery.energy_between(1.1, 1.1, corrected=False))
    qs.append(MonitorQuery.by_label())
    qs.append(MonitorQuery.by_label(0.9, 1.9))
    return qs


def test_executor_matches_direct_path(backend):
    mon = _monitor(10, backend, seed=5)
    for dev, t, v in _slabs(10, n_slabs=5, seed=5):
        mon.ingest(dev, t, v)
    svc = MonitorQueryService(mon)
    qs = _query_mix()
    tickets = [svc.submit(q) for q in qs]
    results = svc.flush()
    assert len(results) == len(qs)
    snap = mon.snapshot()
    exact = backend == "numpy"
    for q, tk in zip(qs, tickets):
        got = results[tk]
        if q.kind == "fleet_energy":
            want = snap.fleet_energy(q.t, q.corrected)
            cmp = (np.testing.assert_array_equal if exact
                   else lambda a, b: np.testing.assert_allclose(
                       a, b, rtol=1e-12))
            cmp(got.per_device_j, want.per_device_j)
            np.testing.assert_array_equal(got.covered, want.covered)
            if exact:
                assert got.total_j == want.total_j
                assert got.sigma_independent_j == want.sigma_independent_j
            assert got.n_reporting == want.n_reporting
        elif q.kind == "window_energy":
            want = snap.window_energy(q.t, q.corrected)
            np.testing.assert_array_equal(got, want) if exact else \
                np.testing.assert_allclose(got, want, rtol=1e-12)
        elif q.kind == "energy_between":
            we, wc = snap.energy_between(q.t0, q.t1, q.corrected)
            np.testing.assert_array_equal(got[1], wc)
            np.testing.assert_array_equal(got[0], we) if exact else \
                np.testing.assert_allclose(got[0], we, rtol=1e-12)
        else:
            want = snap.by_label(q.t0, q.t1, q.corrected)
            assert set(got) == set(want)
            for lb in want:
                for m in want[lb]:
                    a, b = got[lb][m], want[lb][m]
                    assert (a == b) or (np.isnan(a) and np.isnan(b)), \
                        (lb, m)


def test_executor_dedup_and_cache_within_epoch():
    mon = _monitor(6, "numpy")
    for dev, t, v in _slabs(6, 3, seed=2):
        mon.ingest(dev, t, v)
    svc = MonitorQueryService(mon)
    q = MonitorQuery.fleet_energy(1.5)
    t1, t2 = svc.submit(q), svc.submit(MonitorQuery.fleet_energy(1.5))
    res = svc.flush()
    # duplicates inside one flush compute once and share the result object
    assert res[t1] is res[t2]
    assert svc.stats()["cache_misses"] == 2   # both tickets were misses
    # second flush at the same epoch: pure cache hit, identical object
    again = svc.query(q)
    assert again is res[t1]
    st_ = svc.stats()
    assert st_["cache_hits"] == 1 and st_["cache_misses"] == 2
    assert 0.0 < st_["cache_hit_rate"] < 1.0


def test_cache_never_serves_across_epochs():
    mon = _monitor(6, "numpy")
    dev, t, v = _slabs(6, 1, seed=4)[0]
    mon.ingest(dev, t, v)
    svc = MonitorQueryService(mon)
    q = MonitorQuery.fleet_energy(0.3)
    first = svc.query(q)
    # new slab -> new epoch: the same query must be recomputed against
    # the new snapshot, not served from the stale entry
    dev2, t2, v2 = _slabs(6, 2, seed=4)[1]
    mon.ingest(dev2, t2, v2)
    second = svc.query(q)
    assert second is not first
    assert svc.stats()["cache_hits"] == 0
    np.testing.assert_array_equal(
        second.per_device_j, mon.fleet_energy(t=0.3).per_device_j)
    # the held first answer still reflects its own epoch (immutability)
    assert first.total_j != second.total_j or True   # values may coincide
    assert svc.stats()["cache_misses"] == 2


def test_cache_lru_eviction_and_disable():
    mon = _monitor(5, "numpy")
    dev, t, v = _slabs(5, 1, seed=6)[0]
    mon.ingest(dev, t, v)
    svc = MonitorQueryService(mon, cache_size=2)
    qa, qb, qc = (MonitorQuery.fleet_energy(x) for x in (0.1, 0.2, 0.3))
    svc.query(qa), svc.query(qb), svc.query(qc)     # a evicted
    assert svc.stats()["cache_entries"] == 2
    svc.query(qb)                                    # still cached
    assert svc.stats()["cache_hits"] == 1
    svc.query(qa)                                    # recomputed
    assert svc.stats()["cache_misses"] == 4
    off = MonitorQueryService(mon, cache_size=0)
    off.query(qa), off.query(qa)
    assert off.stats()["cache_hits"] == 0 and \
        off.stats()["cache_entries"] == 0
    with pytest.raises(ValueError):
        MonitorQueryService(mon, cache_size=-1)


def test_query_validation():
    with pytest.raises(ValueError):
        MonitorQuery.energy_between(2.0, 1.0)
    with pytest.raises(ValueError):
        MonitorQuery.energy_between(np.nan, 1.0)
    with pytest.raises(ValueError):
        MonitorQuery.by_label(1.0, None)
    with pytest.raises(ValueError):
        MonitorQuery.by_label(2.0, 1.0)
    with pytest.raises(ValueError):
        MonitorQuery("no_such_kind")
    svc = MonitorQueryService(_monitor(2, "numpy"))
    with pytest.raises(TypeError):
        svc.submit("fleet_energy")
    assert svc.flush() == {}


# ---------------------------------------------------------------------------
# query-edge contract (regression pins for docs/streaming.md "Serving")
# ---------------------------------------------------------------------------

def test_energy_between_endpoint_contract():
    mon = _monitor(4, "numpy")
    dev, t, v = _slabs(4, 2, seed=8)[0]
    mon.ingest(dev, t, v)
    with pytest.raises(ValueError):
        mon.energy_between(1.0, 0.5)
    with pytest.raises(ValueError):
        mon.energy_between(np.nan, 1.0)
    with pytest.raises(ValueError):
        mon.energy_between(0.0, np.nan)
    # degenerate window: exactly zero wherever covered
    e, cov = mon.energy_between(0.3, 0.3)
    assert np.all(e[cov] == 0.0)


def test_ring_horizon_answers_nan_never_wrong():
    mon = MonitorService(1, ring_slots=4)
    ts = 0.1 * np.arange(1, 30)
    mon.ingest(np.zeros(len(ts), np.int64), ts, np.full(len(ts), 50.0))
    e, cov = mon.energy_between(0.5, 0.6)     # older than ring coverage
    assert not cov[0] and np.isnan(e[0])
    fe = mon.fleet_energy(t=0.5)
    assert not fe.covered[0] and np.isnan(fe.per_device_j[0])
    assert fe.total_j == 0.0                  # covered-only aggregation


def test_by_label_empty_groups_report_nan():
    # never-ingested monitor: every group nan mean/std, zero totals
    mon = _monitor(6, "numpy")
    for d in mon.by_label().values():
        assert d["n_covered"] == 0 and d["total_j"] == 0.0
        assert np.isnan(d["mean_j"]) and np.isnan(d["std_j"])
    # windowed query outside ring coverage: same nan contract per group
    ts = 0.1 * np.arange(1, 30)
    mon2 = MonitorService(2, ring_slots=4,
                          labels=np.array(["a", "b"], dtype=object))
    mon2.ingest(np.zeros(len(ts), np.int64), ts, np.full(len(ts), 50.0))
    by = mon2.by_label(t0=0.4, t1=0.6)
    assert by["a"]["n_covered"] == 0 and np.isnan(by["a"]["mean_j"])
    assert by["b"]["n_covered"] == 0 and np.isnan(by["b"]["std_j"])


def test_snapshot_energy_at_kernel_backend_parity(accel_backend):
    from repro.core.engine_backend import get_backend
    from repro.core.engine_backend import numpy_backend as nb
    rng = np.random.default_rng(0)
    n, r, q = 64, 6, 17
    last_t = rng.uniform(4.0, 6.0, n)
    args = dict(
        tq=rng.uniform(-1.0, 8.0, q),
        last_t=last_t, dens=rng.uniform(50.0, 200.0, n),
        has=rng.random(n) < 0.9, first_t=rng.uniform(0.0, 1.0, n),
        base=rng.uniform(0.0, 500.0, n),
        max_hold=np.where(rng.random(n) < 0.5, 2.0, np.inf),
        ring_t=np.sort(np.where(rng.random((n, r)) < 0.2, np.inf,
                                rng.uniform(1.0, 4.0, (n, r))), axis=1),
        ring_dens=rng.uniform(50.0, 200.0, (n, r)),
        ring_base=rng.uniform(0.0, 400.0, (n, r)))
    e_ref, c_ref = nb.snapshot_energy_at(**args)
    e_acc, c_acc = get_backend(accel_backend).snapshot_energy_at(**args)
    np.testing.assert_array_equal(c_acc, c_ref)
    np.testing.assert_allclose(e_acc, e_ref, rtol=1e-13, atol=1e-12)
    # ring-less variant
    e2, c2 = nb.snapshot_energy_at(**{**args, "ring_t": None,
                                      "ring_dens": None, "ring_base": None})
    e2a, c2a = get_backend(accel_backend).snapshot_energy_at(
        **{**args, "ring_t": None, "ring_dens": None, "ring_base": None})
    np.testing.assert_array_equal(c2a, c2)
    np.testing.assert_allclose(e2a, e2, rtol=1e-13, atol=1e-12)


# ---------------------------------------------------------------------------
# checkpoint / restore
# ---------------------------------------------------------------------------

def test_restore_resumes_bitwise(backend, tmp_path):
    n = 10
    slabs = _slabs(n, n_slabs=8, seed=9)
    # uninterrupted reference
    ref = _monitor(n, backend, seed=9)
    for dev, t, v in slabs:
        ref.ingest(dev, t, v)
    # killed-and-restored run: checkpoint at an arbitrary slab boundary
    live = _monitor(n, backend, seed=9)
    for dev, t, v in slabs[:5]:
        live.ingest(dev, t, v)
    save_monitor(live, str(tmp_path / "ckpt"))
    resumed = restore_monitor(str(tmp_path / "ckpt"), backend=backend)
    assert resumed.epoch == live.epoch
    del live
    for dev, t, v in slabs[5:]:
        resumed.ingest(dev, t, v)
    _assert_fingerprints_equal(_query_fingerprint(resumed),
                               _query_fingerprint(ref))
    assert resumed.counters == ref.counters
    # the ring and accumulators themselves are byte-identical, not just
    # the query answers
    for f in dataclasses.fields(DeviceState):
        np.testing.assert_array_equal(getattr(resumed.state, f.name),
                                      getattr(ref.state, f.name), f.name)
    for arr in ("t", "v", "e_raw", "e_corr", "n_written"):
        np.testing.assert_array_equal(getattr(resumed.ring, arr),
                                      getattr(ref.ring, arr), arr)


def test_restore_into_fresh_process_bitwise(backend, tmp_path):
    n = 6
    slabs = _slabs(n, n_slabs=6, seed=13)
    ref = _monitor(n, backend, seed=13)
    for dev, t, v in slabs:
        ref.ingest(dev, t, v)
    live = _monitor(n, backend, seed=13)
    for dev, t, v in slabs[:3]:
        live.ingest(dev, t, v)
    save_monitor(live, str(tmp_path / "ckpt"))
    rest = {f"d{i}": s[0] for i, s in enumerate(slabs[3:])}
    rest.update({f"t{i}": s[1] for i, s in enumerate(slabs[3:])})
    rest.update({f"v{i}": s[2] for i, s in enumerate(slabs[3:])})
    np.savez(tmp_path / "rest.npz", **rest)
    script = (
        "import sys, numpy as np\n"
        f"sys.path.insert(0, {repr('src')})\n"
        "from repro.core.stream import restore_monitor\n"
        f"mon = restore_monitor({repr(str(tmp_path / 'ckpt'))}, "
        f"backend={repr(backend)})\n"
        f"z = np.load({repr(str(tmp_path / 'rest.npz'))})\n"
        "for i in range(3):\n"
        "    mon.ingest(z[f'd{i}'], z[f't{i}'], z[f'v{i}'])\n"
        "fe = mon.fleet_energy(t=1.7)\n"
        "eb = mon.energy_between(0.9, 1.9)\n"
        f"np.savez({repr(str(tmp_path / 'out.npz'))},\n"
        "         per_device=fe.per_device_j, total=fe.total_j,\n"
        "         between=eb[0], cov=eb[1],\n"
        "         window=mon.window_energy(t=1.8),\n"
        "         periods=mon.update_period_s())\n")
    subprocess.run([sys.executable, "-c", script], check=True,
                   cwd="/root/repo", timeout=240)
    out = np.load(tmp_path / "out.npz")
    fe = ref.fleet_energy(t=1.7)
    np.testing.assert_array_equal(out["per_device"], fe.per_device_j)
    assert float(out["total"]) == fe.total_j
    eb = ref.energy_between(0.9, 1.9)
    np.testing.assert_array_equal(out["between"], eb[0])
    np.testing.assert_array_equal(out["cov"], eb[1])
    np.testing.assert_array_equal(out["window"], ref.window_energy(t=1.8))
    np.testing.assert_array_equal(out["periods"], ref.update_period_s())


def test_async_save_and_retention(tmp_path):
    mon = _monitor(4, "numpy")
    root = str(tmp_path / "ckpt")
    steps = []
    for i, (dev, t, v) in enumerate(_slabs(4, 5, seed=11)):
        mon.ingest(dev, t, v)
        mgr = save_monitor(mon, root, asynchronous=True, retain=2)
        steps.append(mon.epoch)
    mgr.wait()
    from repro.core.stream.checkpoint import checkpoint_steps
    kept = checkpoint_steps(root)
    assert kept == steps[-2:]              # retain=2 garbage-collects
    restored = restore_monitor(root)       # latest by default
    np.testing.assert_array_equal(restored.state.energy_corr_j,
                                  mon.state.energy_corr_j)
    with pytest.raises(FileNotFoundError):
        restore_monitor(root, step=steps[0])
    with pytest.raises(FileNotFoundError):
        restore_monitor(str(tmp_path / "nope"))


# ---------------------------------------------------------------------------
# schema versioning: drift fails loudly
# ---------------------------------------------------------------------------

def test_new_state_field_fails_loudly(tmp_path):
    @dataclasses.dataclass
    class GrownState(DeviceState):
        shiny_new: np.ndarray = None

    mon = _monitor(3, "numpy")
    grown = GrownState(
        **{f.name: getattr(mon.state, f.name)
           for f in dataclasses.fields(DeviceState)},
        shiny_new=np.zeros(3))
    mon.core.state = grown
    with pytest.raises(SchemaError, match="shiny_new"):
        mon.nbytes()                       # memory reporting trips first
    with pytest.raises(SchemaError, match="shiny_new"):
        save_monitor(mon, str(tmp_path / "ckpt"))


def test_dtype_drift_fails_loudly():
    mon = _monitor(3, "numpy")
    mon.core.state.n_samples = mon.state.n_samples.astype(np.float32)
    with pytest.raises(SchemaError, match="n_samples"):
        mon.nbytes()


def test_restore_rejects_version_and_keyset_mismatch(tmp_path):
    mon = _monitor(3, "numpy")
    dev, t, v = _slabs(3, 1, seed=1)[0]
    mon.ingest(dev, t, v)
    arrays, meta = stream_schema.pack_monitor(mon)
    with pytest.raises(SchemaError, match="schema"):
        stream_schema.unpack_monitor(arrays, {**meta, "schema_version": 99})
    missing = dict(arrays)
    missing.pop("state.energy_corr_j")
    with pytest.raises(SchemaError, match="energy_corr_j"):
        stream_schema.unpack_monitor(missing, meta)
    extra = dict(arrays)
    extra["state.bogus"] = np.zeros(3)
    with pytest.raises(SchemaError, match="bogus"):
        stream_schema.unpack_monitor(extra, meta)


def test_pack_unpack_roundtrip_preserves_everything():
    mon = _monitor(7, "numpy", seed=21)
    for dev, t, v in _slabs(7, 4, seed=21):
        mon.ingest(dev, t, v)
    # some invalid samples so the counter round-trips a nonzero value
    mon.ingest(np.array([0, 1]), np.array([np.nan, 99.0]),
               np.array([1.0, np.inf]))
    arrays, meta = stream_schema.pack_monitor(mon)
    clone = stream_schema.unpack_monitor(arrays, meta)
    assert clone.epoch == mon.epoch
    assert clone.counters == mon.counters
    assert [str(x) for x in clone.labels] == [str(x) for x in mon.labels]
    _assert_fingerprints_equal(_query_fingerprint(clone),
                               _query_fingerprint(mon))
