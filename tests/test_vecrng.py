"""Bitwise parity of the vectorized per-seed RNG vs `np.random`.

`engine_backend.vecrng.VecStreams` lane ``i`` must replay
``np.random.default_rng(seeds[i])`` draw-for-draw, bitwise, for every
draw kind the fleet engine and scenario samplers use — uniforms,
ziggurat normals/exponentials (including wedge and tail paths), poisson
in both the product and PTRS regimes, and the block forms with per-lane
counts.  These pins are what let the array-native synthesis layer claim
"row i is bitwise the scalar generator" without per-device Generators.
"""
import numpy as np
import pytest

from repro.core.engine_backend.vecrng import VecStreams, seedseq_state

SEEDS = np.array([0, 1, 2, 3, 42, 12345, 987654321, 2**33 + 7,
                  2**63 - 11, 7919 * 7919], dtype=np.uint64)


def _rngs(seeds):
    return [np.random.default_rng(int(s)) for s in seeds]


def test_seedseq_state_bitwise():
    got = seedseq_state(SEEDS, 4)
    for j, s in enumerate(SEEDS):
        ref = np.random.SeedSequence(int(s)).generate_state(4, np.uint64)
        np.testing.assert_array_equal(got[j], ref, err_msg=f"seed {s}")


def test_raw_stream_bitwise():
    v = VecStreams(SEEDS)
    got = np.stack([v._next_raw() for _ in range(64)], axis=1)
    for j, s in enumerate(SEEDS):
        ref = np.random.PCG64(int(s)).random_raw(64)
        np.testing.assert_array_equal(got[j], ref, err_msg=f"seed {s}")


def test_uniform_bitwise_scalar_and_per_lane_bounds():
    v = VecStreams(SEEDS)
    got_a = np.stack([v.uniform(0.1, 0.35) for _ in range(16)], axis=1)
    lows = np.linspace(-2.0, 1.0, len(SEEDS))
    highs = lows + np.linspace(0.5, 3.0, len(SEEDS))
    got_b = v.uniform(lows, highs)
    for j, r in enumerate(_rngs(SEEDS)):
        np.testing.assert_array_equal(got_a[j], r.uniform(0.1, 0.35, 16))
        assert got_b[j] == r.uniform(lows[j], highs[j])


@pytest.mark.parametrize("m", [300])
def test_standard_normal_bitwise(m):
    v = VecStreams(SEEDS)
    got = np.stack([v.standard_normal() for _ in range(m)], axis=1)
    for j, r in enumerate(_rngs(SEEDS)):
        np.testing.assert_array_equal(got[j], r.standard_normal(m),
                                      err_msg=f"seed {SEEDS[j]}")


@pytest.mark.parametrize("m", [300])
def test_standard_exponential_bitwise(m):
    v = VecStreams(SEEDS)
    got = np.stack([v.standard_exponential() for _ in range(m)], axis=1)
    for j, r in enumerate(_rngs(SEEDS)):
        np.testing.assert_array_equal(got[j], r.standard_exponential(m),
                                      err_msg=f"seed {SEEDS[j]}")


def test_ziggurat_tail_paths_hit_and_match():
    """Wide lane sweep specifically deep enough to exercise the rare
    |z| > 3.65 normal tail and x > 7.70 exponential tail bitwise."""
    seeds = np.arange(1500, dtype=np.uint64) * 7919 + 13
    m = 220
    v = VecStreams(seeds)
    got = np.stack([v.standard_normal() for _ in range(m)], axis=1)
    saw_tail = False
    for j, s in enumerate(seeds):
        ref = np.random.default_rng(int(s)).standard_normal(m)
        saw_tail |= bool(np.any(np.abs(ref) > 3.6541528853610088))
        np.testing.assert_array_equal(got[j], ref, err_msg=f"seed {s}")
    assert saw_tail, "sweep never reached the ziggurat tail — widen it"


@pytest.mark.parametrize("lam", [0.0, 0.3, 4.9, 9.99, 10.0, 42.0, 133.7])
def test_poisson_bitwise_both_regimes(lam):
    v = VecStreams(SEEDS)
    got = np.stack([v.poisson(lam) for _ in range(24)], axis=1)
    for j, r in enumerate(_rngs(SEEDS)):
        np.testing.assert_array_equal(got[j], r.poisson(lam, 24),
                                      err_msg=f"seed {SEEDS[j]} lam {lam}")


def test_interleaved_draw_kinds_stay_in_sync():
    """Mixing draw kinds must keep every lane on its scalar trajectory
    (the consumption contract: each kind eats the same words)."""
    v = VecStreams(SEEDS)
    got = []
    for _ in range(20):
        got += [v.standard_normal(), v.poisson(4.9).astype(float),
                v.uniform(0.2, 0.8), v.standard_exponential()]
    got = np.stack(got, axis=1)
    for j, r in enumerate(_rngs(SEEDS)):
        ref = []
        for _ in range(20):
            ref += [r.standard_normal(), float(r.poisson(4.9)),
                    r.uniform(0.2, 0.8), r.standard_exponential()]
        np.testing.assert_array_equal(got[j], np.array(ref),
                                      err_msg=f"seed {SEEDS[j]}")


def test_uniform_block_per_lane_counts_and_state_commit():
    counts = np.arange(len(SEEDS), dtype=np.int64) * 3  # includes 0
    v = VecStreams(SEEDS)
    blk = v.uniform_block(0.25, 1.75, counts)
    after = v.uniform(0.0, 1.0)       # proves states advanced exactly
    for j, r in enumerate(_rngs(SEEDS)):
        k = int(counts[j])
        np.testing.assert_array_equal(blk[j, :k], r.uniform(0.25, 1.75, k))
        assert np.all(blk[j, k:] == 0.0)
        assert after[j] == r.uniform(0.0, 1.0)


def test_uniform_block_long_jump_path():
    """Columns beyond one jump stride exercise the boundary-state path."""
    counts = np.full(len(SEEDS), 700)
    v = VecStreams(SEEDS)
    blk = v.uniform_block(0.0, 1.0, counts)
    for j, r in enumerate(_rngs(SEEDS)):
        np.testing.assert_array_equal(blk[j], r.uniform(0.0, 1.0, 700))


def test_normal_and_exponential_blocks_with_per_lane_scale():
    counts = (np.arange(len(SEEDS)) % 5) * 2 + 1
    scales = 0.05 + (np.arange(len(SEEDS)) % 3) * 0.2
    v = VecStreams(SEEDS)
    nb = v.normal_block(scales, counts)
    eb = v.exponential_block(scales, counts)
    for j, r in enumerate(_rngs(SEEDS)):
        k = int(counts[j])
        np.testing.assert_array_equal(
            nb[j, :k], r.normal(0.0, scales[j], k), err_msg=f"seed {SEEDS[j]}")
        np.testing.assert_array_equal(
            eb[j, :k], r.exponential(scales[j], k), err_msg=f"seed {SEEDS[j]}")


def test_masked_draws_do_not_consume():
    mask = np.zeros(len(SEEDS), dtype=bool)
    mask[::2] = True
    v = VecStreams(SEEDS)
    first = v.standard_normal(mask)
    second = v.standard_normal()
    for j, r in enumerate(_rngs(SEEDS)):
        if mask[j]:
            assert first[j] == r.standard_normal()
        else:
            assert first[j] == 0.0
        assert second[j] == r.standard_normal()


def test_advance_matches_masked_stepping():
    v1 = VecStreams(SEEDS)
    v2 = VecStreams(SEEDS)
    adv = (np.arange(len(SEEDS)) * 37) % 450
    v1._advance(adv)
    for j in range(int(adv.max())):
        v2._next_double(adv > j)
    np.testing.assert_array_equal(v1._hi, v2._hi)
    np.testing.assert_array_equal(v1._lo, v2._lo)


@pytest.mark.slow
def test_deep_parity_sweep():
    """10⁶-draw sweep across lanes — the guard for the derived-threshold
    ulp caveat documented in the module docstring."""
    seeds = np.arange(2000, dtype=np.uint64) * 104729 + 7
    m = 500
    v = VecStreams(seeds)
    got = np.stack([v.standard_normal() for _ in range(m)], axis=1)
    for j, s in enumerate(seeds):
        np.testing.assert_array_equal(
            got[j], np.random.default_rng(int(s)).standard_normal(m),
            err_msg=f"seed {s}")


# -- deterministic shard substreams (ISSUE 7) -------------------------------

def test_split_concat_bitwise_across_draw_kinds():
    """Shard outputs concatenated in shard order must be bitwise what the
    undivided bank draws — per lane the stream is independent, so the
    partition cannot matter, for any draw kind or shard count."""
    for n_shards in (1, 2, 3, len(SEEDS)):
        full = VecStreams(SEEDS)
        parts = VecStreams(SEEDS).split(n_shards)
        assert sum(p.n_lanes for p in parts) == len(SEEDS)
        ref_u = full.uniform_block(0.0, 1.0, np.full(len(SEEDS), 7))
        got_u = np.concatenate(
            [p.uniform_block(0.0, 1.0, np.full(p.n_lanes, 7))
             for p in parts])
        np.testing.assert_array_equal(got_u, ref_u)
        ref_n = full.normal_block(1.5, np.full(len(SEEDS), 4))
        got_n = np.concatenate(
            [p.normal_block(1.5, np.full(p.n_lanes, 4)) for p in parts])
        np.testing.assert_array_equal(got_n, ref_n)
        ref_p = full.poisson(8.5)
        got_p = np.concatenate([p.poisson(8.5) for p in parts])
        np.testing.assert_array_equal(got_p, ref_p)


def test_split_leaves_parent_untouched_and_validates():
    v = VecStreams(SEEDS)
    before = (v._hi.copy(), v._lo.copy())
    parts = v.split(3)
    parts[0].random()
    np.testing.assert_array_equal(v._hi, before[0])
    np.testing.assert_array_equal(v._lo, before[1])
    with pytest.raises(ValueError):
        v.split(0)
    with pytest.raises(ValueError):
        v.split(len(SEEDS) + 1)


def test_split_scenario_bank_concat_bitwise():
    """ISSUE 7 pin: sharded scenario generation concatenated in shard
    order is bitwise the single-stream ``scenario_bank`` output — for
    every scenario kind, including the variable-consumption Poisson
    inference sampler."""
    from repro.core.load import SCENARIO_BANKS, scenario_bank

    seeds = np.arange(40, dtype=np.uint64) + 3
    cuts = [0, 13, 26, 40]                 # ragged 3-way shard split
    for kind in sorted(SCENARIO_BANKS):
        full = scenario_bank(kind, seeds)
        fa = full.arrays
        row = 0
        for lo, hi in zip(cuts[:-1], cuts[1:]):
            part = scenario_bank(kind, seeds[lo:hi]).arrays
            for i in range(hi - lo):
                ns = int(part.n_segs[i])
                assert ns == int(fa.n_segs[row])
                np.testing.assert_array_equal(part.edges[i, :ns + 1],
                                              fa.edges[row, :ns + 1],
                                              err_msg=f"{kind} row {row}")
                np.testing.assert_array_equal(part.powers[i, :ns],
                                              fa.powers[row, :ns])
                assert part.idle_w[i] == fa.idle_w[row]
                row += 1


def test_jumped_exact_draw_offsets():
    """``jumped(k)`` lands exactly k raw words downstream — scalar and
    per-lane counts — without touching the source."""
    v = VecStreams(SEEDS)
    j3 = v.jumped(3)
    ref = VecStreams(SEEDS)
    for _ in range(3):
        ref.random()
    np.testing.assert_array_equal(j3.random(), ref.random())
    np.testing.assert_array_equal(v._hi, VecStreams(SEEDS)._hi)

    counts = (np.arange(len(SEEDS)) * 11) % 97
    jv = VecStreams(SEEDS).jumped(counts)
    got = jv.random()
    for j, s in enumerate(SEEDS):
        r = np.random.default_rng(int(s))
        for _ in range(int(counts[j])):
            r.random()
        assert got[j] == r.random(), f"seed {s}"
    with pytest.raises(ValueError):
        VecStreams(SEEDS).jumped(-1)
