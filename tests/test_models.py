"""Per-arch smoke tests (reduced configs) + layer/numerics units.

Every assigned architecture: instantiate REDUCED config, one forward +
train-grad step on CPU, assert output shapes and no NaNs (per brief §f),
plus prefill/decode consistency for the decoder-only families.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeCell
from repro.configs.registry import ARCH_IDS, get_config
from repro.models import api, layers
from repro.models import recurrent as rec
from repro.models import transformer as tf

SMOKE = ShapeCell("smoke", 32, 2, "train")
RNG = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_arch_smoke_train_step(arch_id):
    cfg = get_config(arch_id, reduced=True)
    params = api.init_params(RNG, cfg)
    batch = api.concrete_inputs(RNG, cfg, SMOKE)
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: api.loss_fn(p, cfg, batch), has_aux=True)(params)
    assert np.isfinite(float(loss)), arch_id
    gn = sum(float(jnp.sum(g.astype(jnp.float32) ** 2))
             for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gn) and gn > 0, arch_id
    logits, aux = api.forward(params, cfg, batch, remat=False) \
        if not cfg.encdec else api.forward(params, cfg, batch)
    S = SMOKE.seq_len
    assert logits.shape == (SMOKE.global_batch, S, cfg.vocab), arch_id
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch_id


@pytest.mark.parametrize("arch_id", [a for a in ARCH_IDS
                                     if not get_config(a, True).encdec])
def test_arch_decode_consistency(arch_id):
    """prefill(S-1) + decode_step(token S-1) ≡ forward at position S-1."""
    cfg = get_config(arch_id, reduced=True).replace(param_dtype="float32")
    params = api.init_params(RNG, cfg)
    S, B = 24, 2
    toks = jax.random.randint(RNG, (B, S), 0, cfg.vocab, jnp.int32)
    if cfg.input_mode == "embeds":
        emb = jax.random.normal(RNG, (B, S, cfg.d_model), jnp.float32)
        full = {"embeds": emb}
        pre = {"embeds": emb[:, :S - 1]}
        dec = {"embeds": emb[:, S - 1:S], "pos": jnp.asarray([S - 1])}
        if cfg.mrope:
            p3 = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, None],
                                  (3, B, S))
            full["positions3"] = p3
            pre["positions3"] = p3[:, :, :S - 1]
            dec["positions3"] = p3[:, :, S - 1:S]
    else:
        full = {"tokens": toks}
        pre = {"tokens": toks[:, :S - 1]}
        dec = {"tokens": toks[:, S - 1:S], "pos": jnp.asarray([S - 1])}
    logits_full, _ = tf.forward(params, cfg, full, remat=False)
    _, cache = tf.prefill(params, cfg, pre, max_seq=S)
    logits_dec, _ = tf.decode_step(params, cfg, cache, dec)
    err = float(jnp.max(jnp.abs(logits_dec[:, 0] - logits_full[:, S - 1])))
    tol = 5e-2 if cfg.family == "moe" else 5e-5   # MoE: capacity-drop noise
    assert err < tol, (arch_id, err)


def test_ring_buffer_local_attention_cache():
    """Local-attention caches are window-sized: recurrentgemma's 500k
    decode state is O(window), not O(seq)."""
    cfg = get_config("recurrentgemma-9b", reduced=True)
    cache = api.cache_specs(cfg, batch=1, max_seq=5000)
    k_shapes = [s.shape for path, s in
                jax.tree_util.tree_flatten_with_path(cache)[0]
                if "k" == str(path[-1].key)]
    for shp in k_shapes:
        assert shp[-3] == cfg.sliding_window  # ring buffer, not 5000


def test_gemma2_softcap_applied():
    cfg = get_config("gemma2-2b", reduced=True)
    params = api.init_params(RNG, cfg)
    batch = api.concrete_inputs(RNG, cfg, SMOKE)
    logits, _ = api.forward(params, cfg, batch, remat=False)
    assert float(jnp.max(jnp.abs(logits))) <= cfg.final_softcap + 1e-3


def test_olmo_norm_has_no_params():
    cfg = get_config("olmo-1b", reduced=True)
    specs = api.param_specs(cfg)
    names = [str(p[-1].key) for p, _ in
             jax.tree_util.tree_flatten_with_path(specs)[0]]
    assert not any("ln" in n or "final_norm" in n for n in names)


def test_moe_aux_loss_nonzero():
    cfg = get_config("qwen2-moe-a2.7b", reduced=True)
    params = api.init_params(RNG, cfg)
    batch = api.concrete_inputs(RNG, cfg, SMOKE)
    _, metrics = api.loss_fn(params, cfg, batch)
    assert float(metrics["aux"]) > 0.0


def test_mrope_band_split():
    x = jax.random.normal(RNG, (2, 8, 4, 16), jnp.float32)
    pos3 = jnp.stack([jnp.arange(8)[None].repeat(2, 0)] * 3).astype(jnp.int32)
    # equal positions on all 3 axes == standard rope
    a = layers.apply_mrope(x, pos3)
    b = layers.apply_rope(x, jnp.arange(8, dtype=jnp.int32)[None])
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_blocked_attention_matches_direct_long():
    from repro.kernels.ref import attention_direct_ref
    q = jax.random.normal(RNG, (1, 200, 4, 16), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(5), (1, 200, 2, 16), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(6), (1, 200, 2, 16), jnp.float32)
    out = layers.blocked_attention(q, k, v, block_q=64, block_k=32)
    want = attention_direct_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


def test_mlstm_parallel_matches_step():
    """Chunked GLA form ≡ sequential mlstm_step recurrence."""
    B, S, H, D = 2, 37, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, D), jnp.float32)
    log_f = jax.nn.log_sigmoid(jax.random.normal(ks[3], (B, S, H)))
    log_i = jax.random.normal(ks[4], (B, S, H))
    ypar = rec.mlstm_parallel(q, k, v, log_f, log_i, chunk=8)
    st = rec.MLSTMState(jnp.zeros((B, H, D, D)), jnp.zeros((B, H, D)),
                        jnp.zeros((B, H)))
    outs = []
    for t in range(S):
        y, st = rec.mlstm_step(q[:, t], k[:, t], v[:, t],
                               log_f[:, t], log_i[:, t], st)
        outs.append(y)
    yseq = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(ypar), np.asarray(yseq),
                               rtol=2e-4, atol=2e-4)


def test_causal_conv_step_matches_full():
    B, S, D, K = 2, 12, 8, 4
    x = jax.random.normal(RNG, (B, S, D), jnp.float32)
    kern = jax.random.normal(jax.random.PRNGKey(9), (K, D), jnp.float32)
    full = rec.causal_conv1d(x, kern)
    buf = jnp.zeros((B, K - 1, D))
    outs = []
    for t in range(S):
        y, buf = rec.causal_conv1d_step(x[:, t], buf, kern)
        outs.append(y)
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(full), rtol=1e-5, atol=1e-5)


def test_active_param_count_moe_less_than_total():
    cfg = get_config("granite-moe-3b-a800m", reduced=False)
    total = tf.param_count(cfg)
    active = tf.active_param_count(cfg)
    assert active < total * 0.6


def test_long_500k_applicability():
    from repro.configs.base import cell_applicable, get_shape
    long = get_shape("long_500k")
    runs = {a: cell_applicable(get_config(a), long)[0] for a in ARCH_IDS}
    assert runs["xlstm-125m"] and runs["recurrentgemma-9b"]
    assert not runs["llama3-405b"] and not runs["gemma2-2b"]
    assert sum(runs.values()) == 2
