"""Scenario-generator layer + heterogeneous fleet audits.

Mixed fleets (training pods, Poisson inference serving, idle/maintenance,
diurnal cycles) feed per-device timelines end-to-end: workload set →
TimelineBank → SensorBank → batched protocols → per-scenario error
breakdowns in the audit result and the fleet ledger.
"""
import numpy as np
import pytest

from repro.core import load as loads
from repro.core.fleet_engine import fleet_audit
from repro.core.meter import Workload, WorkloadSet
from repro.core.telemetry import FleetLedger


@pytest.mark.parametrize("kind", sorted(loads.SCENARIOS))
def test_scenario_timelines_well_formed(kind):
    for seed in range(5):
        tl = loads.scenario_timeline(kind, seed=seed)
        dur = tl.t_end - tl.t_start
        assert dur > 0.0
        assert tl.energy() > 0.0
        assert np.all(tl.powers >= 0.0)
        assert np.all(tl.powers <= 300.0)
        # deterministic per seed
        tl2 = loads.scenario_timeline(kind, seed=seed)
        np.testing.assert_array_equal(tl.edges, tl2.edges)
        np.testing.assert_array_equal(tl.powers, tl2.powers)


def test_scenario_unknown_kind_raises():
    with pytest.raises(KeyError, match="unknown scenario"):
        loads.scenario_timeline("mining")
    with pytest.raises(KeyError, match="unknown scenario"):
        loads.mixed_fleet_workloads(4, mix={"mining": 1.0})


def test_mixed_fleet_counts_and_labels():
    wls = loads.mixed_fleet_workloads(100, seed=0)
    assert len(wls) == 100
    counts = {}
    for w in wls:
        counts[w.scenario] = counts.get(w.scenario, 0) + 1
    # default mix: 40/30/15/15
    assert counts == {"training": 40, "inference": 30,
                      "idle": 15, "diurnal": 15}


def test_mixed_fleet_every_device_its_own_timeline():
    wls = loads.mixed_fleet_workloads(40, seed=1)
    sigs = {(w.timeline.edges.tobytes(), w.timeline.powers.tobytes())
            for w in wls}
    assert len(sigs) == len(wls)          # no two devices share a trace
    # deterministic rebuild
    wls2 = loads.mixed_fleet_workloads(40, seed=1)
    for a, b in zip(wls, wls2):
        assert a.scenario == b.scenario
        np.testing.assert_array_equal(a.timeline.edges, b.timeline.edges)


def test_mixed_fleet_degenerate_inputs():
    with pytest.raises(ValueError):
        loads.mixed_fleet_workloads(0)
    with pytest.raises(ValueError):
        loads.mixed_fleet_workloads(4, mix={"training": 0.0})


def test_fleet_audit_mixed_scenarios_end_to_end():
    n = 80
    wls = loads.mixed_fleet_workloads(n, seed=3)
    res = fleet_audit(n, profile=["a100"] * (n // 2) + ["v100"] * (n // 2),
                      workload=wls, good_practice=True, n_trials=2)
    assert res.naive_j.shape == (n,)
    assert np.all(np.isfinite(res.naive_err))
    assert isinstance(res.true_j, np.ndarray) and res.true_j.shape == (n,)
    by = res.by_scenario()
    assert set(by) == set(loads.DEFAULT_MIX)
    assert sum(v["n_devices"] for v in by.values()) == n
    # the protocol collapses the error for every scenario class
    by_gp = res.by_scenario(res.gp_err)
    for label in by:
        assert by_gp[label]["mean_abs_err"] < 0.10


def test_fleet_audit_workload_count_mismatch():
    wls = loads.mixed_fleet_workloads(5, seed=0)
    with pytest.raises(ValueError, match="5 workloads for 6 devices"):
        fleet_audit(6, profile="a100", workload=wls)


def test_fleet_seed_mode_rejects_per_device_timelines():
    wls = loads.mixed_fleet_workloads(4, seed=0)
    with pytest.raises(ValueError, match="seed_mode='fleet'"):
        fleet_audit(4, profile="a100", workload=wls, seed_mode="fleet")


def test_ledger_label_breakdown_sums_to_total():
    n = 60
    wls = loads.mixed_fleet_workloads(n, seed=5)
    res = fleet_audit(n, profile="a100", workload=wls)
    led = FleetLedger()
    led.register_batch(res.naive_j, duration_s=0.4,
                       labels=np.array(res.scenarios, dtype=object))
    led.register_batch(np.array([100.0, 200.0]), duration_s=0.4)
    by = led.by_label()
    assert "(unlabelled)" in by
    assert by["(unlabelled)"].total_j == pytest.approx(300.0)
    total = sum(s.total_j for s in by.values())
    assert total == pytest.approx(led.summary().total_j)
    assert sum(s.n_devices for s in by.values()) == n + 2


def test_workload_set_validation():
    from repro.core.ground_truth import from_segments

    with pytest.raises(ValueError, match="empty WorkloadSet"):
        WorkloadSet([])
    with pytest.raises(ValueError, match="zero/negative duration"):
        Workload("null", from_segments([], t0=1.0))
    with pytest.raises(ValueError, match="zero/negative duration"):
        Workload("flat", from_segments([(0.0, 200.0)]))
