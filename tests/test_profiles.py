"""Profile catalog invariants: the characterised Fig. 14 values."""
import os

import pytest

from repro.core import profiles
from repro.core.sensor import SensorProfile

# the paper's characterised sampled fractions (window / update period)
CHARACTERISED = {
    "a100": 0.25,                 # 25 ms / 100 ms — the headline number
    "h100_instant": 0.25,
    "h100_average": 1.0,          # 1 s running average covers everything
    "gh200_gpu": 0.20,
    "gh200_cpu": 0.10,
    "gh200_module_instant": 0.20,
    "rtx3090_pre530": 1.0,
    "rtx3090_530": 1.0,
    "rtx3090_instant": 1.0,
    "rtx3090_average": 1.0,
    "rtx4090_instant": 1.0,
    "turing": 1.0,
    "v100": 0.50,
    "p100": 0.50,
    "kepler": 1.0,                # logarithmic filter sees everything
    "maxwell": 1.0,
    "fermi2": 1.0,
    "tpu_v5e_chip": 0.25,
    "tpu_v5e_host": 1.0,
    "tpu_v5e_dash": 1.0,
}


def test_every_catalog_entry_is_characterised():
    assert set(profiles.CATALOG) == set(CHARACTERISED) | {"fermi1"}


@pytest.mark.parametrize("name,frac", sorted(CHARACTERISED.items()))
def test_sampled_fraction_matches_paper(name, frac):
    assert profiles.get(name).sampled_fraction == pytest.approx(frac)


def test_get_raises_on_unknown_name():
    with pytest.raises(KeyError, match="unknown sensor profile"):
        profiles.get("b200")


def test_get_returns_catalog_object():
    assert profiles.get("a100") is profiles.CATALOG["a100"]
    assert isinstance(profiles.get("a100"), SensorProfile)


def test_catalog_names_are_keys():
    for name, prof in profiles.CATALOG.items():
        assert prof.name == name


def test_fermi1_unsupported():
    assert not profiles.get("fermi1").supported


def test_evaluation_cases_of_section5():
    # case 1: W == T, case 2: W > T, case 3: W < T (part-time)
    assert profiles.CASE1.sampled_fraction == pytest.approx(1.0)
    assert profiles.CASE2.window_s > profiles.CASE2.update_period_s
    assert profiles.CASE3.sampled_fraction == pytest.approx(0.25)


def test_docs_profile_table_matches_catalog():
    """docs/sensor-model.md's Fig. 14 table is generated from CATALOG;
    fail if someone edits the catalog without regenerating the docs."""
    import importlib.util
    root = os.path.join(os.path.dirname(__file__), "..")
    spec = importlib.util.spec_from_file_location(
        "make_profile_table",
        os.path.join(root, "tools", "make_profile_table.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    with open(os.path.join(root, "docs", "sensor-model.md")) as f:
        text = f.read()
    assert mod.render_block() in text, (
        "profile table stale; run: PYTHONPATH=src python "
        "tools/make_profile_table.py")


def test_part_time_parts_are_flagged():
    """Every part-time (W < T) part misses activity; the A100/H100 story."""
    for name in ("a100", "h100_instant", "gh200_gpu", "v100"):
        p = profiles.get(name)
        assert p.window_s < p.update_period_s
        assert p.sampled_fraction < 1.0
