"""Scalar ↔ batched engine equivalence and fleet-audit behaviour.

The contract under test (ISSUE 1 acceptance): `SensorBank` reproduces the
scalar `OnboardSensor` readings per-device — same profile + seed — within
one reporting quantum, across every transient kind in the catalog, and the
batched measurement protocols match their scalar counterparts.
"""
import numpy as np
import pytest

from repro.core import load as loads
from repro.core import profiles
from repro.core.calibrate import CalibrationRecord
from repro.core.fleet_engine import SensorBank, fleet_audit
from repro.core.ground_truth import TimelineBank
from repro.core.meter import (GoodPracticeConfig, ModuleScopeError, Workload,
                              WorkloadSet, measure_good_practice,
                              measure_good_practice_batch, measure_naive,
                              measure_naive_batch)
from repro.core.sensor import OnboardSensor, SensorUnsupported
from repro.core.telemetry import FleetLedger

# one of each behavioural class: part-time boxcar, long-window boxcar,
# fast Volta grid, logarithmic transients, estimation-based Fermi
MIXED = ["a100", "h100_average", "v100", "rtx3090_530", "kepler",
         "maxwell", "fermi2", "gh200_gpu", "tpu_v5e_dash"]

TL = loads.square_wave(0.230, 16, 220.0, 90.0)


def _calib(name: str) -> CalibrationRecord:
    p = profiles.get(name)
    return CalibrationRecord("d", name, p.update_period_s, p.window_s,
                             "instant", 2.5 * p.update_period_s,
                             sampled_fraction=p.sampled_fraction)


def test_bank_hidden_params_match_scalar():
    bank = SensorBank.from_catalog(MIXED, base_seed=42)
    for i, name in enumerate(MIXED):
        s = OnboardSensor(profiles.get(name), seed=42 + i)
        assert bank.true_gain[i] == s.true_gain
        assert bank.true_offset[i] == s.true_offset
        assert bank.true_phase[i] == s.true_phase


@pytest.mark.parametrize("rep", range(2))
def test_bank_readings_match_scalar_within_quantum(rep):
    """Same seeds → same readings, across every transient kind."""
    base = 42 + 100 * rep
    names = MIXED * 2
    bank = SensorBank.from_catalog(names, base_seed=base)
    bank.attach(TL, t_end=6.0)
    qs = np.linspace(0.0, 6.0, 500)
    got = bank.query(qs)
    for i, name in enumerate(names):
        s = OnboardSensor(profiles.get(name), seed=base + i)
        s.attach(TL, t_end=6.0)
        quantum = profiles.get(name).quantum_w
        np.testing.assert_allclose(got[i], s.query(qs), atol=quantum + 1e-12,
                                   err_msg=f"device {i} ({name})")


def test_bank_poll_matches_scalar_poll():
    bank = SensorBank.from_catalog(["a100", "v100"], base_seed=3)
    bank.attach(TL, t_end=4.0)
    ts, mat = bank.poll(0.0, 4.0, period_s=0.002)
    for i, name in enumerate(["a100", "v100"]):
        s = OnboardSensor(profiles.get(name), seed=3 + i)
        s.attach(TL, t_end=4.0)
        ts_ref, vals_ref = s.poll(0.0, 4.0, period_s=0.002)
        np.testing.assert_array_equal(ts, ts_ref)
        np.testing.assert_allclose(mat[i], vals_ref, atol=1e-12)


def test_unsupported_profile_raises_on_attach():
    bank = SensorBank.from_catalog(["a100", "fermi1"], base_seed=0)
    with pytest.raises(SensorUnsupported):
        bank.attach(TL)


def test_module_scope_host_timeline_matches_scalar():
    host = loads.workload_burst(2.0, 55.0, idle_w=40.0)
    bank = SensorBank.from_catalog(["gh200_module_instant"], base_seed=9,
                                   host_timeline=host)
    bank.attach(TL, t_end=4.0)
    s = bank.scalar_reference(0)
    s.attach(TL, t_end=4.0)
    qs = np.linspace(0.0, 4.0, 200)
    np.testing.assert_allclose(bank.query(qs)[0], s.query(qs), atol=1e-12)


def test_measure_naive_batch_matches_scalar():
    wl = Workload("w", loads.multi_phase_workload([(0.130, 215.0),
                                                   (0.070, 165.0)]))
    names = ["a100", "a100", "rtx3090_average", "v100", "kepler"]
    bank = SensorBank.from_catalog(names, base_seed=7)
    batch = measure_naive_batch(bank, wl)
    for i, name in enumerate(names):
        ref = measure_naive(OnboardSensor(profiles.get(name), seed=7 + i), wl)
        assert batch[i] == pytest.approx(ref, abs=1e-9)


def test_measure_good_practice_batch_matches_scalar():
    wl = Workload("w", loads.multi_phase_workload([(0.130, 215.0),
                                                   (0.070, 165.0)]))
    names = ["a100", "a100", "rtx3090_average", "v100"]
    bank = SensorBank.from_catalog(names, base_seed=7)
    cfg = GoodPracticeConfig(n_trials=2)
    calibs = {n: _calib(n) for n in set(names)}
    batch = measure_good_practice_batch(bank, wl, calibs, cfg)
    for i, name in enumerate(names):
        s = OnboardSensor(profiles.get(name), seed=7 + i)
        ref = measure_good_practice(s, wl, calibs[name], cfg, seed=i)
        assert batch.joules_per_rep[i] == pytest.approx(
            ref.joules_per_rep, abs=1e-3)
        np.testing.assert_allclose(batch.trial_values[i], ref.trial_values,
                                   atol=1e-3)


def test_measure_batch_module_scope_guard():
    wl = Workload("w", loads.workload_burst(0.1, 210.0))
    bank = SensorBank.from_catalog(["a100", "gh200_module_instant"],
                                   base_seed=0)
    with pytest.raises(ModuleScopeError):
        measure_naive_batch(bank, wl)
    e = measure_naive_batch(bank, wl, host_baseline_w=0.0)
    assert np.all(np.isfinite(e))


def test_mixed_scope_baseline_only_hits_module_rows():
    """The host baseline is debited from module-scope devices only: in a
    mixed fleet a chip-scope sensor never sees host power, so its reading
    must match a no-baseline run of the same device."""
    wl = Workload("w", loads.workload_burst(0.2, 210.0))
    mixed = SensorBank.from_catalog(["a100", "gh200_module_instant"],
                                    base_seed=0)
    e = measure_naive_batch(mixed, wl, host_baseline_w=50.0)
    chip_only = SensorBank.from_catalog(["a100"], base_seed=0)
    ref = measure_naive_batch(chip_only, wl)
    assert e[0] == pytest.approx(ref[0], abs=1e-9)
    # ... while the module row *is* debited
    e0 = measure_naive_batch(
        SensorBank.from_catalog(["a100", "gh200_module_instant"],
                                base_seed=0), wl, host_baseline_w=0.0)
    assert e[1] < e0[1]


def test_gp_batch_with_chip_only_host_timeline():
    """A host timeline on an all-chip-scope bank is inert — the batched
    §5 protocol (which uses per-device shifts) must still run."""
    host = loads.workload_burst(2.0, 55.0, idle_w=40.0)
    bank = SensorBank.from_catalog(["a100"] * 3, base_seed=1,
                                   host_timeline=host)
    wl = Workload("w", loads.workload_burst(0.130, 215.0))
    est = measure_good_practice_batch(bank, wl, _calib("a100"),
                                      GoodPracticeConfig(n_trials=2))
    assert np.all(np.isfinite(est.joules_per_rep))


def test_subset_shares_hidden_params():
    bank = SensorBank.from_catalog(MIXED, base_seed=11)
    sub = bank.subset(np.array([2, 5]))
    assert sub.n_devices == 2
    assert sub.true_gain[0] == bank.true_gain[2]
    assert sub.profiles[1].name == MIXED[5]


# -- per-device timelines (the heterogeneous-fleet substrate) ---------------

def _per_device_timelines(n, seed=0):
    rng = np.random.default_rng(seed)
    return [loads.square_wave(float(rng.uniform(0.1, 0.4)),
                              int(rng.integers(4, 12)),
                              float(rng.uniform(150, 250)),
                              float(rng.uniform(60, 120)), seed=seed + i)
            for i in range(n)]


def test_bank_per_device_timelines_match_scalar():
    """The ISSUE 2 equivalence pin: a TimelineBank-backed bank row
    reproduces OnboardSensor on the same per-device timeline, across every
    transient kind."""
    names = MIXED
    tls = _per_device_timelines(len(names), seed=5)
    bank = SensorBank.from_catalog(names, base_seed=42)
    bank.attach(TimelineBank.from_timelines(tls), t_end=6.0)
    qs = np.linspace(0.0, 6.0, 300)
    got = bank.query(qs)
    for i, name in enumerate(names):
        s = OnboardSensor(profiles.get(name), seed=42 + i)
        s.attach(tls[i], t_end=6.0)
        quantum = profiles.get(name).quantum_w
        np.testing.assert_allclose(got[i], s.query(qs), atol=quantum + 1e-12,
                                   err_msg=f"device {i} ({name})")


def test_bank_per_device_module_scope_matches_scalar():
    host = loads.workload_burst(2.0, 55.0, idle_w=40.0)
    names = ["gh200_module_instant", "a100"]
    tls = _per_device_timelines(2, seed=9)
    bank = SensorBank.from_catalog(names, base_seed=9, host_timeline=host)
    bank.attach(TimelineBank.from_timelines(tls), t_end=4.0)
    qs = np.linspace(0.0, 4.0, 200)
    got = bank.query(qs)
    for i in range(2):
        s = bank.scalar_reference(i)
        s.attach(tls[i], t_end=4.0)
        np.testing.assert_allclose(got[i], s.query(qs), atol=1e-12)


def test_bank_attach_per_device_validation():
    bank = SensorBank.from_catalog(["a100"] * 3, base_seed=0)
    tb = TimelineBank.from_timelines(_per_device_timelines(2, seed=1))
    with pytest.raises(ValueError, match="2 rows for 3 devices"):
        bank.attach(tb)
    tb3 = TimelineBank.from_timelines(_per_device_timelines(3, seed=1))
    with pytest.raises(ValueError, match="redundant with a TimelineBank"):
        bank.attach(tb3, shifts=np.zeros(3))
    fleet_bank = SensorBank.from_catalog(["a100"] * 3, base_seed=0,
                                         seed_mode="fleet")
    with pytest.raises(ValueError, match="seed_mode='fleet'"):
        fleet_bank.attach(tb3)


def test_measure_naive_batch_per_device_workloads():
    names = ["a100", "v100", "kepler", "rtx3090_average"]
    rng = np.random.default_rng(2)
    wls = [Workload(f"w{i}", loads.multi_phase_workload(
        [(float(rng.uniform(0.05, 0.2)), float(rng.uniform(180, 240))),
         (float(rng.uniform(0.03, 0.1)), float(rng.uniform(120, 180)))]))
        for i in range(len(names))]
    bank = SensorBank.from_catalog(names, base_seed=7)
    batch = measure_naive_batch(bank, WorkloadSet(wls))
    for i, name in enumerate(names):
        ref = measure_naive(OnboardSensor(profiles.get(name), seed=7 + i),
                            wls[i])
        assert batch[i] == pytest.approx(ref, abs=1e-9)


def test_measure_good_practice_batch_per_device_workloads():
    names = ["a100", "a100", "rtx3090_average", "v100"]
    rng = np.random.default_rng(3)
    wls = [Workload(f"w{i}", loads.multi_phase_workload(
        [(float(rng.uniform(0.08, 0.2)), float(rng.uniform(180, 240))),
         (float(rng.uniform(0.04, 0.1)), float(rng.uniform(120, 180)))]))
        for i in range(len(names))]
    bank = SensorBank.from_catalog(names, base_seed=7)
    cfg = GoodPracticeConfig(n_trials=2)
    calibs = {n: _calib(n) for n in set(names)}
    batch = measure_good_practice_batch(bank, WorkloadSet(wls), calibs, cfg)
    for i, name in enumerate(names):
        s = OnboardSensor(profiles.get(name), seed=7 + i)
        ref = measure_good_practice(s, wls[i], calibs[name], cfg, seed=i)
        assert batch.joules_per_rep[i] == pytest.approx(
            ref.joules_per_rep, abs=1e-3)
        np.testing.assert_allclose(batch.trial_values[i], ref.trial_values,
                                   atol=1e-3)
        assert batch.n_reps[i] == ref.n_reps


def test_fleet_audit_shape_and_gp_beats_naive():
    res = fleet_audit(300, profile="a100", seed=5, good_practice=True,
                      n_trials=2)
    assert res.naive_j.shape == (300,)
    assert res.gp_j.shape == (300,)
    st, gp = res.stats(), res.stats(res.gp_err)
    # the paper's Fig. 18 at fleet scale: protocol collapses the error
    assert gp["mean_abs_err"] < st["mean_abs_err"]
    assert gp["mean_abs_err"] < 0.10
    unc = res.uncertainty()
    # 1/sqrt(N) scaling: independent bound ~ worst-case / sqrt(300)
    assert unc["sigma_independent_j"] == pytest.approx(
        unc["sigma_worstcase_j"] / np.sqrt(300), rel=0.15)


def test_fleet_audit_heterogeneous_profiles():
    names = ["a100"] * 50 + ["v100"] * 50
    res = fleet_audit(100, profile=names, seed=2)
    assert res.naive_j.shape == (100,)
    assert np.all(np.isfinite(res.naive_err))


def test_register_batch_summary_matches_object_path():
    e = np.full(64, 500.0)
    obj = FleetLedger()
    from repro.core.ledger import EnergyLedger
    for i in range(64):
        led = EnergyLedger(device_id=f"d{i}")
        led.append(0, 0.0, 10.0, 550.0, 500.0, 25.0)
        obj.register(led)
    arr = FleetLedger()
    arr.register_batch(e, duration_s=10.0)
    so, sa = obj.summary(), arr.summary()
    assert sa.n_devices == so.n_devices
    assert sa.total_j == pytest.approx(so.total_j)
    assert sa.sigma_independent_j == pytest.approx(so.sigma_independent_j)
    assert sa.sigma_worstcase_j == pytest.approx(so.sigma_worstcase_j)
    assert sa.mean_power_w == pytest.approx(so.mean_power_w)


def test_register_batch_mixes_with_object_path():
    fleet = FleetLedger()
    from repro.core.ledger import EnergyLedger
    led = EnergyLedger(device_id="d0")
    led.append(0, 0.0, 1.0, 110.0, 100.0, 5.0)
    fleet.register(led)
    fleet.register_batch(np.array([100.0, 100.0]), duration_s=1.0)
    s = fleet.summary()
    assert s.n_devices == 3
    assert s.total_j == pytest.approx(300.0)
    assert s.sigma_worstcase_j == pytest.approx(15.0)


# -- auto_chunk_devices (ISSUE 7: the one hoisted sizing rule) --------------

def test_auto_chunk_devices_reproduces_historical_heuristics():
    from repro.core.fleet_engine import auto_chunk_devices

    # poll: 16M-element budget over n_polls-wide rows
    for n_polls in (1, 100, 16_000_000, 64_000_000):
        assert auto_chunk_devices(10**9, n_polls) == \
            max(1, 16_000_000 // max(n_polls, 1))
    # iter_poll_slabs: 4M budget over per-tick columns
    assert auto_chunk_devices(10**9, 500, budget_elems=4_000_000) == 8000


def test_auto_chunk_devices_edge_cases():
    from repro.core.fleet_engine import auto_chunk_devices

    assert auto_chunk_devices(0, 100) >= 1          # empty fleet: range ok
    assert auto_chunk_devices(0, 0) >= 1
    assert auto_chunk_devices(5, 10**9) == 1        # huge rows: row-by-row
    assert auto_chunk_devices(3, 100) == 3          # tiny fleet: one slab
    assert auto_chunk_devices(7, 0) == 7            # zero-width rows
    chunk = auto_chunk_devices(10**7, 1600)
    assert 1 <= chunk <= 10**7 and chunk == 10_000


def test_query_auto_chunking_identical():
    bank = SensorBank.from_catalog(MIXED, base_seed=4)
    bank.attach(TL, t_start=0.0)
    tq = np.linspace(0.1, 3.3, 11)
    np.testing.assert_array_equal(bank.query(tq, chunk_devices="auto"),
                                  bank.query(tq))


def test_fleet_audit_prefetch_workloads_identical():
    """Double-buffered slab synthesis must not change a bit (slabs are
    exact row-ranges; the thread only changes *when* they are built)."""
    spec = loads.FleetScenarioSpec(n=120, seed=5)
    names = (MIXED * 14)[:120]
    a = fleet_audit(120, profile=names, workload=spec, chunk_devices=33,
                    prefetch_workloads=True)
    b = fleet_audit(120, profile=names, workload=spec, chunk_devices=33)
    np.testing.assert_array_equal(a.naive_j, b.naive_j)
    np.testing.assert_array_equal(a.naive_err, b.naive_err)
    assert a.streamed == b.streamed


# -- StreamingMoments tree-order invariance (ISSUE 7 precondition) ----------

from _hyp import HAVE_HYPOTHESIS, given, settings, st  # noqa: E402

if HAVE_HYPOTHESIS:
    _moment_cases = given(
        data=st.data(),
        n=st.integers(min_value=1, max_value=300),
        scale=st.sampled_from([1e-6, 1.0, 1e6]))
else:                                    # pragma: no cover
    _moment_cases = given()


def _fold_tree(blocks, order_rng):
    """Merge moment blocks pairwise in a random tree shape."""
    from repro.core.fleet_engine import StreamingMoments

    nodes = []
    for b in blocks:
        sm = StreamingMoments()
        sm.merge(*b)
        nodes.append(sm)
    while len(nodes) > 1:
        i = int(order_rng.integers(len(nodes) - 1))
        right = nodes.pop(i + 1)
        nodes[i].merge(right.n, right.mean, right.m2,
                       right.mean_abs, right.max_abs)
    return nodes[0]


@_moment_cases
@settings(max_examples=60, deadline=None)
def test_streaming_moments_tree_order_invariant(data, n, scale):
    """Any fold tree over random partitions agrees with the sequential
    merge: counts bitwise, moments within float tolerance — the
    correctness precondition for the on-device tree reduction."""
    from repro.core.engine_backend import numpy_backend
    from repro.core.fleet_engine import StreamingMoments

    seed = data.draw(st.integers(min_value=0, max_value=2**31))
    rng = np.random.default_rng(seed)
    e = rng.normal(scale=scale, size=n)
    n_cuts = data.draw(st.integers(min_value=0, max_value=min(n, 8)))
    cuts = sorted(data.draw(
        st.lists(st.integers(min_value=0, max_value=n),
                 min_size=n_cuts, max_size=n_cuts)))
    bounds = [0] + cuts + [n]
    blocks = [numpy_backend.err_moments(e[lo:hi])
              for lo, hi in zip(bounds[:-1], bounds[1:])]

    seq = StreamingMoments()
    for b in blocks:
        seq.merge(*b)
    tree = _fold_tree(blocks, rng)

    assert tree.n == seq.n == n                 # counts exact, always
    assert tree.max_abs == seq.max_abs          # max is order-free
    for got, ref in ((tree.mean, seq.mean), (tree.mean_abs, seq.mean_abs)):
        assert got == pytest.approx(ref, rel=1e-9, abs=1e-12 * scale)
    assert tree.m2 == pytest.approx(seq.m2, rel=1e-6,
                                    abs=1e-9 * scale * scale)


def test_streaming_moments_tree_order_invariant_seeded():
    """Deterministic counterpart of the hypothesis property (runs even
    where hypothesis is absent): 40 random partitions × random fold
    trees vs the sequential merge."""
    from repro.core.engine_backend import numpy_backend
    from repro.core.fleet_engine import StreamingMoments

    rng = np.random.default_rng(2024)
    for _ in range(40):
        n = int(rng.integers(1, 400))
        e = rng.normal(scale=float(rng.choice([1e-6, 1.0, 1e6])), size=n)
        bounds = np.unique(np.concatenate(
            [[0, n], rng.integers(0, n + 1, size=rng.integers(0, 9))]))
        blocks = [numpy_backend.err_moments(e[lo:hi])
                  for lo, hi in zip(bounds[:-1], bounds[1:])]
        seq = StreamingMoments()
        for b in blocks:
            seq.merge(*b)
        tree = _fold_tree(blocks, rng)
        assert tree.n == seq.n == n
        assert tree.max_abs == seq.max_abs
        assert tree.mean == pytest.approx(seq.mean, rel=1e-9,
                                          abs=1e-9 * seq.mean_abs)
        assert tree.mean_abs == pytest.approx(seq.mean_abs, rel=1e-9)
        assert tree.m2 == pytest.approx(seq.m2, rel=1e-6,
                                        abs=1e-12 * seq.m2 + 1e-30)
