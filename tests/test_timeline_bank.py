"""TimelineBank ↔ ActivityTimeline equivalence (unit + property).

The substrate contract (ISSUE 2): row ``i`` of a bank is *bitwise*
equivalent to the scalar timeline it was built from — same ``power_at`` /
``integral`` / ``mean_power`` outputs, not merely close — and the
round-trip through ``from_timelines`` / ``row`` is exact.
"""
import numpy as np
import pytest
from _hyp import given, settings, st  # degrades to per-test skips without hypothesis

from repro.core import load as loads
from repro.core.ground_truth import (ActivityTimeline, GroundTruthMeter,
                                     TimelineBank, batch_searchsorted,
                                     from_segments)


def _random_timelines(seed, n=6):
    rng = np.random.default_rng(seed)
    tls = []
    for _ in range(n):
        k = int(rng.integers(1, 9))
        segs = [(float(rng.uniform(0.01, 1.0)), float(rng.uniform(0, 400)))
                for _ in range(k)]
        tls.append(from_segments(segs, t0=float(rng.uniform(-1, 1)),
                                 idle_w=float(rng.uniform(1, 100))))
    return tls


def test_round_trip_exact():
    tls = _random_timelines(0)
    bank = TimelineBank.from_timelines(tls)
    assert bank.n_rows == len(tls)
    for i, t in enumerate(tls):
        r = bank.row(i)
        np.testing.assert_array_equal(r.edges, t.edges)
        np.testing.assert_array_equal(r.powers, t.powers)
        assert r.idle_w == t.idle_w


def test_batch_searchsorted_matches_numpy():
    rng = np.random.default_rng(1)
    for side in ("left", "right"):
        a = np.sort(rng.integers(0, 10, size=(5, 12)).astype(float), axis=1)
        v = rng.integers(-1, 11, size=(5, 20)).astype(float)
        got = batch_searchsorted(a, v, side)
        ref = np.stack([np.searchsorted(a[i], v[i], side) for i in range(5)])
        np.testing.assert_array_equal(got, ref)


def test_analytics_bitwise_vs_scalar_rows():
    tls = _random_timelines(2)
    bank = TimelineBank.from_timelines(tls)
    rng = np.random.default_rng(3)
    ts = rng.uniform(-2, 5, size=(len(tls), 41))
    t0 = rng.uniform(-2, 5, size=(len(tls), 41))
    t1 = t0 + rng.uniform(0, 3, size=t0.shape)
    pa, I = bank.power_at(ts), bank.integral(t0, t1)
    mp, en = bank.mean_power(t0, t1), bank.energy()
    for i, t in enumerate(tls):
        np.testing.assert_array_equal(pa[i], t.power_at(ts[i]))
        np.testing.assert_array_equal(I[i], t.integral(t0[i], t1[i]))
        np.testing.assert_array_equal(mp[i], t.mean_power(t0[i], t1[i]))
        assert en[i] == t.energy()


def test_single_row_broadcasts_over_query_rows():
    tl = loads.square_wave(0.2, 6, 220.0, 80.0)
    bank = TimelineBank.from_timelines([tl])
    ts = np.random.default_rng(4).uniform(-1, 3, size=(7, 19))
    got = bank.mean_power(ts - 0.05, ts)
    ref = tl.mean_power(ts - 0.05, ts)      # scalar path is 2-D capable
    np.testing.assert_array_equal(got, ref)


def test_shift_scalar_and_vector():
    tls = _random_timelines(5, n=4)
    bank = TimelineBank.from_timelines(tls)
    dt = np.arange(4.0)
    shifted = bank.shift(dt)
    for i, t in enumerate(tls):
        ref = t.shift(float(dt[i]))
        np.testing.assert_array_equal(shifted.row(i).edges, ref.edges)
    both = bank.shift(0.5)
    np.testing.assert_array_equal(both.t_start, bank.t_start + 0.5)


def test_query_shapes():
    bank = TimelineBank.from_timelines(_random_timelines(6, n=3))
    assert bank.power_at(0.5).shape == (3,)
    np.testing.assert_array_equal(bank.power_at(np.full(3, 0.5)),
                                  bank.power_at(0.5))
    assert bank.power_at(np.zeros((3, 9))).shape == (3, 9)
    # shared [1, M] grid broadcasts to every row
    grid = np.linspace(0.0, 1.0, 9)[None, :]
    np.testing.assert_array_equal(bank.power_at(grid),
                                  bank.power_at(np.broadcast_to(grid, (3, 9))))
    with pytest.raises(ValueError):
        bank.power_at(np.zeros(5))           # neither [N] nor single-row
    with pytest.raises(ValueError):
        bank.power_at(np.zeros((4, 9)))      # wrong row count


def test_degenerate_inputs_raise():
    with pytest.raises(ValueError, match="empty TimelineBank"):
        TimelineBank.from_timelines([])
    with pytest.raises(ValueError, match="empty TimelineBank"):
        TimelineBank.from_timeline(loads.workload_burst(0.1, 200.0), 0)
    with pytest.raises(ValueError, match="at least one segment"):
        TimelineBank(np.zeros((1, 2)), np.zeros((1, 1)), np.zeros(1),
                     np.zeros(1, dtype=np.int64))
    with pytest.raises(ValueError, match="non-decreasing"):
        TimelineBank(np.array([[0.0, 2.0, 1.0]]), np.ones((1, 2)),
                     np.ones(1), np.full(1, 2, dtype=np.int64))


def test_from_timeline_broadcast_with_shifts():
    tl = loads.workload_burst(0.3, 210.0)
    shifts = np.array([0.0, 0.5, 1.25])
    bank = TimelineBank.from_timeline(tl, 3, shifts=shifts)
    for i, s in enumerate(shifts):
        np.testing.assert_array_equal(bank.row(i).edges, tl.shift(s).edges)


def test_padding_rows_of_unequal_length():
    """A 1-segment row stacked with an 8-segment row: padding must not
    leak into either row's analytics."""
    short = from_segments([(0.5, 100.0)], idle_w=10.0)
    long = loads.square_wave(0.25, 4, 300.0, 50.0, idle_w=20.0)
    bank = TimelineBank.from_timelines([short, long])
    ts = np.linspace(-0.5, 3.0, 101)
    qs = np.broadcast_to(ts, (2, 101))
    got = bank.power_at(qs)
    np.testing.assert_array_equal(got[0], short.power_at(ts))
    np.testing.assert_array_equal(got[1], long.power_at(ts))
    np.testing.assert_array_equal(
        bank.energy(), [short.energy(), long.energy()])


def test_energy_batch_matches_per_device_meters():
    """Row i of energy_batch is the scalar meter seeded seed+i, bitwise."""
    tls = _random_timelines(7, n=5)
    bank = TimelineBank.from_timelines(tls)
    meter = GroundTruthMeter(seed=11)
    got = meter.energy_batch(bank)
    for i, t in enumerate(tls):
        assert got[i] == GroundTruthMeter(seed=11 + i).energy(t)


@settings(max_examples=30, deadline=None)
@given(
    rows=st.lists(
        st.tuples(
            st.lists(st.tuples(st.floats(0.005, 0.8), st.floats(0.0, 500.0)),
                     min_size=1, max_size=9),
            st.floats(-1.0, 1.0),        # t0
            st.floats(1.0, 100.0)),      # idle_w
        min_size=1, max_size=6),
    qseed=st.integers(0, 2**31 - 1),
)
def test_property_rows_bitwise_match_scalar(rows, qseed):
    """Hypothesis: for random per-row segment lists, every TimelineBank
    analytic matches the scalar ActivityTimeline bitwise."""
    tls = [from_segments(segs, t0=t0, idle_w=idle)
           for segs, t0, idle in rows]
    bank = TimelineBank.from_timelines(tls)
    rng = np.random.default_rng(qseed)
    ts = rng.uniform(-2.0, 8.0, size=(len(tls), 17))
    t0q = rng.uniform(-2.0, 8.0, size=ts.shape)
    t1q = t0q + rng.uniform(0.0, 4.0, size=ts.shape)
    pa = bank.power_at(ts)
    I = bank.integral(t0q, t1q)
    mp = bank.mean_power(t0q, t1q)
    en = bank.energy()
    for i, t in enumerate(tls):
        np.testing.assert_array_equal(pa[i], t.power_at(ts[i]))
        np.testing.assert_array_equal(I[i], t.integral(t0q[i], t1q[i]))
        np.testing.assert_array_equal(mp[i], t.mean_power(t0q[i], t1q[i]))
        assert en[i] == t.energy()
