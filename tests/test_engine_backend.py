"""Execution-backend contracts (ISSUE 3, pallas tier in ISSUE 6).

Three groups:

* registry semantics (resolve/auto-detect/unknown names);
* numpy↔accelerated kernel and end-to-end parity — every accelerated
  backend (jax, pallas via the shared ``accel_backend`` fixture) must
  reproduce the numpy backend within one reporting quantum on every
  transient kind in the catalog, for shared and per-device timelines,
  through both measurement protocols (skipped when jax is missing, e.g.
  in the numpy-only core CI job);
* ``integrate_polled`` degenerate windows (``a == b``, ``b < a``, window
  entirely off the poll grid), pinned against the scalar
  ``meter._integrate_readings`` reference on both backends.
"""
import numpy as np
import pytest

from repro.core import load as loads
from repro.core import profiles
from repro.core.engine_backend import (available_backends, get_backend,
                                       has_jax, resolve_backend)
from repro.core.engine_backend.pytrees import TimelineArrays
from repro.core.fleet_engine import SensorBank, fleet_audit
from repro.core.ground_truth import TimelineBank
from repro.core.meter import (GoodPracticeConfig, Workload, WorkloadSet,
                              _integrate_readings,
                              measure_good_practice_batch,
                              measure_naive_batch)

# one of each behavioural class: part-time boxcar, long-window boxcar,
# fast Volta grid, logarithmic transients, estimation-based Fermi
MIXED = ["a100", "h100_average", "v100", "rtx3090_530", "kepler",
         "maxwell", "fermi2", "gh200_gpu", "tpu_v5e_dash"]

TL = loads.square_wave(0.230, 16, 220.0, 90.0)

needs_jax = pytest.mark.skipif(not has_jax(), reason="jax not installed")


def _per_device_timelines(n, seed=0):
    rng = np.random.default_rng(seed)
    return [loads.square_wave(float(rng.uniform(0.1, 0.4)),
                              int(rng.integers(4, 12)),
                              float(rng.uniform(150, 250)),
                              float(rng.uniform(60, 120)), seed=seed + i)
            for i in range(n)]


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_numpy_backend_always_available():
    assert "numpy" in available_backends()
    assert resolve_backend(None) == "numpy"
    assert resolve_backend("numpy") == "numpy"
    be = get_backend("numpy")
    assert be.name == "numpy"


def test_unknown_backend_rejected():
    with pytest.raises(ValueError, match="unknown backend"):
        resolve_backend("cuda")
    with pytest.raises(ValueError, match="unknown backend"):
        SensorBank.from_catalog(["a100"], backend="cuda")


def test_auto_resolves_to_an_available_backend():
    assert resolve_backend("auto") in available_backends()


@needs_jax
def test_jax_backend_listed_and_loadable():
    assert available_backends() == ("numpy", "jax", "pallas")
    assert resolve_backend("auto") == "jax"
    assert get_backend("jax").name == "jax"


@needs_jax
def test_pallas_backend_listed_and_loadable():
    assert "pallas" in available_backends()
    assert resolve_backend("pallas") == "pallas"
    assert get_backend("pallas").name == "pallas"


def test_bank_records_backend_and_propagates_to_views():
    bank = SensorBank.from_catalog(["a100", "v100"], base_seed=0)
    assert bank.backend == "numpy"
    assert bank.subset(np.array([1])).backend == "numpy"
    other = bank.with_backend("numpy")
    assert other.true_gain[0] == bank.true_gain[0]   # rows shared, not redrawn


# ---------------------------------------------------------------------------
# kernel-level parity
# ---------------------------------------------------------------------------

def test_kernel_parity_boxcar_and_integral(accel_backend):
    npb, jxb = get_backend("numpy"), get_backend(accel_backend)
    tls = TimelineBank.from_timelines(_per_device_timelines(6, seed=3))
    rng = np.random.default_rng(0)
    t1 = rng.uniform(-0.5, 3.0, size=(6, 40))
    t0 = t1 - rng.uniform(0.0, 0.3, size=(6, 40))
    arr = tls.arrays
    np.testing.assert_allclose(jxb.timeline_integral(arr, t0, t1),
                               npb.timeline_integral(arr, t0, t1),
                               rtol=1e-12, atol=1e-9)
    np.testing.assert_allclose(jxb.boxcar_means(arr, t0, t1),
                               npb.boxcar_means(arr, t0, t1),
                               rtol=1e-12, atol=1e-9)


def test_kernel_parity_boxcar_single_row_broadcast(accel_backend):
    npb, jxb = get_backend("numpy"), get_backend(accel_backend)
    bank = TimelineBank.from_timelines([TL])
    rng = np.random.default_rng(1)
    t1 = rng.uniform(0.0, 4.0, size=(5, 30))
    t0 = t1 - 0.025
    np.testing.assert_allclose(jxb.boxcar_means(bank.arrays, t0, t1),
                               npb.boxcar_means(bank.arrays, t0, t1),
                               rtol=1e-12, atol=1e-9)


def test_kernel_parity_log_filter(accel_backend):
    npb, jxb = get_backend("numpy"), get_backend(accel_backend)
    tls = TimelineBank.from_timelines(_per_device_timelines(4, seed=9))
    rng = np.random.default_rng(2)
    ticks = np.sort(rng.uniform(0.0, 3.0, size=(4, 25)), axis=1)
    tau = rng.uniform(0.2, 1.0, size=4)
    got = jxb.log_filter(tls.arrays, ticks, tau)
    ref = npb.log_filter(tls.arrays, ticks, tau)
    # the associative scan reorders the recurrence's float ops, so allow
    # tiny drift — far below one reporting quantum (0.01 W)
    np.testing.assert_allclose(got, ref, rtol=1e-9, atol=1e-9)


def test_kernel_parity_poll_counts_and_query_slots(accel_backend):
    npb, jxb = get_backend("numpy"), get_backend(accel_backend)
    bank = SensorBank.from_catalog(MIXED, base_seed=17)
    bank.attach(TL, t_end=5.0)
    sched = bank._schedule
    from repro.core.engine_backend.pytrees import PollGrid
    n = bank.n_devices
    grid = PollGrid(0.0, np.full(n, 4.0), 0.001, -0.025)
    rng = np.random.default_rng(3)
    a = rng.uniform(0.0, 2.0, size=n)
    b = a + rng.uniform(0.0, 2.0, size=n)
    ref = npb.poll_counts(sched, grid, a, b)
    got = jxb.poll_counts(sched, grid, a, b)
    for r, g in zip(ref, got):
        np.testing.assert_array_equal(np.asarray(r), np.asarray(g))
    tq = rng.uniform(0.0, 5.0, size=(n, 16))
    np.testing.assert_array_equal(npb.query_slots(sched, tq),
                                  jxb.query_slots(sched, tq))


# ---------------------------------------------------------------------------
# end-to-end parity: every transient kind, both timeline shapes
# ---------------------------------------------------------------------------

def test_backend_parity_shared_timeline_all_kinds(accel_backend):
    """Accelerated readings match numpy within one reporting quantum, per
    device, across every transient kind in the catalog (the acceptance
    pin)."""
    b_np = SensorBank.from_catalog(MIXED, base_seed=42)
    b_jx = SensorBank.from_catalog(MIXED, base_seed=42,
                                   backend=accel_backend)
    b_np.attach(TL, t_end=6.0)
    b_jx.attach(TL, t_end=6.0)
    qs = np.linspace(0.0, 6.0, 400)
    v_np, v_jx = b_np.query(qs), b_jx.query(qs)
    for i, name in enumerate(MIXED):
        quantum = profiles.get(name).quantum_w
        np.testing.assert_allclose(v_jx[i], v_np[i], atol=quantum + 1e-12,
                                   err_msg=f"device {i} ({name})")


def test_backend_parity_per_device_timelines_all_kinds(accel_backend):
    tb = TimelineBank.from_timelines(_per_device_timelines(len(MIXED),
                                                           seed=5))
    b_np = SensorBank.from_catalog(MIXED, base_seed=11)
    b_jx = SensorBank.from_catalog(MIXED, base_seed=11,
                                   backend=accel_backend)
    b_np.attach(tb, t_end=6.0)
    b_jx.attach(tb, t_end=6.0)
    qs = np.linspace(0.0, 6.0, 400)
    v_np, v_jx = b_np.query(qs), b_jx.query(qs)
    for i, name in enumerate(MIXED):
        quantum = profiles.get(name).quantum_w
        np.testing.assert_allclose(v_jx[i], v_np[i], atol=quantum + 1e-12,
                                   err_msg=f"device {i} ({name})")


def test_backend_parity_catalog_profiles_scalar_contract(accel_backend):
    """Every catalog profile that publishes readings also honours the
    scalar-equivalence contract under the accelerated backends."""
    names = [n for n, p in profiles.CATALOG.items() if p.supported]
    bank = SensorBank.from_catalog(names, base_seed=3,
                                   backend=accel_backend)
    bank.attach(TL, t_end=4.0)
    qs = np.linspace(0.0, 4.0, 200)
    got = bank.query(qs)
    for i, name in enumerate(names):
        s = bank.scalar_reference(i)
        s.attach(TL, t_end=4.0)
        quantum = profiles.get(name).quantum_w
        np.testing.assert_allclose(got[i], s.query(qs),
                                   atol=quantum + 1e-12,
                                   err_msg=f"device {i} ({name})")


def test_backend_parity_naive_batch(accel_backend):
    wls = WorkloadSet([Workload(f"w{i}", tl) for i, tl in
                       enumerate(_per_device_timelines(len(MIXED), seed=2))])
    b_np = SensorBank.from_catalog(MIXED, base_seed=7)
    b_jx = SensorBank.from_catalog(MIXED, base_seed=7,
                                   backend=accel_backend)
    e_np = measure_naive_batch(b_np, wls)
    e_jx = measure_naive_batch(b_jx, wls)
    np.testing.assert_allclose(e_jx, e_np, rtol=1e-9, atol=1e-6)


def test_backend_parity_good_practice_batch(accel_backend):
    from repro.core.calibrate import CalibrationRecord
    names = ["a100", "v100", "kepler", "fermi2"]
    wl = Workload("w", loads.multi_phase_workload([(0.130, 215.0),
                                                   (0.070, 165.0)]))
    calibs = {}
    for n in set(names):
        p = profiles.get(n)
        calibs[n] = CalibrationRecord(
            "d", n, p.update_period_s, p.window_s, "instant",
            2.5 * p.update_period_s, sampled_fraction=p.sampled_fraction)
    cfg = GoodPracticeConfig(n_trials=2)
    b_np = SensorBank.from_catalog(names, base_seed=5)
    est_np = measure_good_practice_batch(b_np, wl, calibs, cfg)
    est_jx = measure_good_practice_batch(b_np, wl, calibs, cfg,
                                         backend=accel_backend)
    np.testing.assert_allclose(est_jx.joules_per_rep, est_np.joules_per_rep,
                               rtol=1e-9, atol=1e-6)
    np.testing.assert_allclose(est_jx.trial_values, est_np.trial_values,
                               rtol=1e-9, atol=1e-6)


def test_backend_parity_fleet_audit_stats(accel_backend):
    names = ["a100"] * 30 + ["v100"] * 20 + ["maxwell"] * 10
    r_np = fleet_audit(60, profile=names, seed=4)
    r_jx = fleet_audit(60, profile=names, seed=4, backend=accel_backend)
    np.testing.assert_allclose(r_jx.naive_j, r_np.naive_j,
                               rtol=1e-9, atol=1e-6)


# ---------------------------------------------------------------------------
# integrate_polled degenerate windows (both backends, scalar-pinned)
# ---------------------------------------------------------------------------

DEGENERATE = [
    ("a_eq_b_on_grid", 1.0, 1.0),
    ("a_eq_b_off_grid", 1.0005, 1.0005),
    ("b_lt_a", 2.0, 1.0),
    ("before_grid", -3.0, -1.0),
    ("after_grid", 9.0, 11.0),
    ("inside_one_step", 1.0002, 1.0008),   # no poll instant falls inside
]


def _degenerate_backends():
    return [None] + (["jax", "pallas"] if has_jax() else [])


@pytest.mark.parametrize("name,a,b", DEGENERATE)
def test_integrate_polled_degenerate_windows(name, a, b):
    """Empty/degenerate windows integrate to exactly 0.0 on every device,
    matching the scalar reference (`j1 = min(j1, m_i - 1)` must not leave
    a phantom step when the selected range is empty)."""
    names = ["a100", "v100", "kepler"]
    for backend in _degenerate_backends():
        bank = SensorBank.from_catalog(names, base_seed=5, backend=backend)
        bank.attach(TL, t_end=5.0)
        got = bank.integrate_polled(0.0, 4.0, 0.001, a, b)
        for i in range(len(names)):
            s = bank.scalar_reference(i)
            s.attach(TL, t_end=5.0)
            ts, vals = s.poll(0.0, 4.0, period_s=0.001)
            ref = _integrate_readings(ts, vals, a, b)
            assert got[i] == pytest.approx(ref, abs=1e-12), \
                f"{name} device {i} backend={backend or 'numpy'}"
            assert got[i] == 0.0


def test_integrate_polled_window_past_grid_end_matches_scalar():
    """b beyond the last poll instant: the final reading extends to b,
    exactly as `_integrate_readings` does on the scalar series."""
    names = ["a100", "v100"]
    for backend in _degenerate_backends():
        bank = SensorBank.from_catalog(names, base_seed=3, backend=backend)
        bank.attach(TL, t_end=6.0)
        got = bank.integrate_polled(0.0, 4.0, 0.001, 3.9, 4.5)
        for i in range(len(names)):
            s = bank.scalar_reference(i)
            s.attach(TL, t_end=6.0)
            ts, vals = s.poll(0.0, 4.0, period_s=0.001)
            ref = _integrate_readings(ts, vals, 3.9, 4.5)
            assert got[i] == pytest.approx(ref, abs=1e-9)
            assert got[i] > 0.0


def test_integrate_polled_single_poll_instant():
    """A window containing exactly one poll instant: only the partial
    step from that instant to b contributes."""
    bank = SensorBank.from_catalog(["a100"], base_seed=1)
    bank.attach(TL, t_end=5.0)
    got = bank.integrate_polled(0.0, 4.0, 0.001, 0.9995, 1.0009)
    s = bank.scalar_reference(0)
    s.attach(TL, t_end=5.0)
    ts, vals = s.poll(0.0, 4.0, period_s=0.001)
    ref = _integrate_readings(ts, vals, 0.9995, 1.0009)
    assert got[0] == pytest.approx(ref, abs=1e-12)
    assert got[0] > 0.0


# ---------------------------------------------------------------------------
# pytree containers
# ---------------------------------------------------------------------------

def test_timeline_arrays_roundtrip_view():
    tb = TimelineBank.from_timelines(_per_device_timelines(3, seed=8))
    arr = tb.arrays
    assert isinstance(arr, TimelineArrays)
    assert arr.n_rows == 3
    assert arr.edges is tb.edges          # zero-copy view
    np.testing.assert_array_equal(arr.t_start, tb.t_start)
    np.testing.assert_array_equal(arr.t_end, tb.t_end)


@needs_jax
def test_timeline_arrays_is_jax_pytree():
    import jax
    tb = TimelineBank.from_timelines([TL])
    leaves = jax.tree_util.tree_leaves(tb.arrays)
    assert len(leaves) == 4
