"""Energy-measurement protocol tests: naive vs good practice (paper §5).

The quantitative claims validated here:
  * naive single-shot error on part-time sensors is large and erratic
    (paper: up to ~70 %, avg 39 %);
  * the good-practice protocol brings it to ~5 % (gain-error floor) with
    small spread (paper: 4.89 % avg, std ≈ 0.25 %);
  * calibration (gain/offset inversion) removes the remaining bias down to
    the time-domain floor;
  * module-scope sensors (GH200 `instant`) are refused without a host
    baseline (paper §6).
"""
import numpy as np
import pytest
from _hyp import given, settings, st  # degrades to per-test skips without hypothesis

from repro.core import load as loads
from repro.core import profiles
from repro.core.calibrate import CalibrationRecord
from repro.core.ground_truth import GroundTruthMeter
from repro.core.meter import (GoodPracticeConfig, ModuleScopeError, Workload,
                              compare_protocols, measure_good_practice,
                              measure_naive)
from repro.core.microbench import estimate_steady_state
from repro.core.sensor import OnboardSensor


def _calib(profile_name: str, gain=None, offset=None) -> CalibrationRecord:
    p = profiles.get(profile_name)
    W = p.window_s
    return CalibrationRecord(
        device_id="d0", profile_name=profile_name,
        update_period_s=p.update_period_s, window_s=W,
        transient_kind="instant" if (W or 0) <= p.update_period_s else "linear",
        rise_time_s=0.25 if (W or 0) <= 0.1 else 1.25,
        gain=gain, offset_w=offset,
        sampled_fraction=p.sampled_fraction)


BURST = Workload("burst100ms", loads.workload_burst(0.100, 210.0))


@pytest.mark.parametrize("profile", ["a100", "rtx3090_instant",
                                     "rtx3090_average"])
def test_good_practice_beats_naive(profile):
    calib = _calib(profile)
    naive_errs, gp_errs = [], []
    for seed in range(5):
        s = OnboardSensor(profiles.get(profile), seed=300 + seed)
        r = compare_protocols(s, BURST, calib, GoodPracticeConfig(),
                              seed=seed)
        naive_errs.append(abs(r["naive_err"]))
        gp_errs.append(abs(r["gp_err"]))
    assert np.mean(gp_errs) < np.mean(naive_errs)
    assert np.mean(gp_errs) < 0.12       # ~gain floor + protocol residue
    # mirrors Fig. 18: naive errors are large on these stress loads
    assert np.mean(naive_errs) > 0.15


def test_error_reduction_magnitude_case3():
    """A100 (25/100): the paper reduces error by ~35 points on average."""
    calib = _calib("a100")
    reductions = []
    for seed in range(6):
        s = OnboardSensor(profiles.get("a100"), seed=400 + seed)
        r = compare_protocols(s, BURST, calib, GoodPracticeConfig(),
                              seed=seed)
        reductions.append(abs(r["naive_err"]) - abs(r["gp_err"]))
    assert np.mean(reductions) > 0.10


def test_phase_shift_delays_reduce_error():
    """Case 3's fix: a 100 ms-period workload with internal structure
    aligned to the 100 ms update period exposes only one fixed 25 ms slice
    to the A100's window — without phase shifts the estimate depends on
    which slice (paper: std up to 30 %); 8 controlled delays of W expose
    every slice and collapse the error."""
    calib = _calib("a100")
    # one repetition = 50 ms hot (240 W) + 50 ms cool (120 W)
    wl = Workload("structured100ms", loads.multi_phase_workload(
        [(0.050, 240.0), (0.050, 120.0)]))

    def errors(n_shifts):
        errs = []
        for seed in range(8):
            s = OnboardSensor(profiles.get("a100"), seed=500 + seed)
            est = measure_good_practice(
                s, wl, calib,
                GoodPracticeConfig(n_phase_shifts=n_shifts, n_trials=2),
                seed=seed)
            errs.append(est.error_vs(wl.true_energy_j))
        return np.asarray(errs)

    e0, e8 = errors(0), errors(8)
    # without shifts the window samples a fixed slice → biased & spread out
    assert np.abs(e8).mean() < np.abs(e0).mean()
    assert np.abs(e8).mean() < 0.10


def test_calibration_removes_gain_bias():
    prof = profiles.get("rtx3090_instant")
    s = OnboardSensor(prof, seed=77)
    meter = GroundTruthMeter(seed=8)
    ss = estimate_steady_state(s, meter)
    calib_plain = _calib("rtx3090_instant")
    calib_gain = _calib("rtx3090_instant", gain=ss.gain, offset=ss.offset_w)
    wl = Workload("burst", loads.workload_burst(0.200, 230.0))
    est_plain = measure_good_practice(s, wl, calib_plain,
                                      GoodPracticeConfig(), seed=3)
    est_cal = measure_good_practice(
        s, wl, calib_gain, GoodPracticeConfig(apply_calibration=True), seed=3)
    truth = wl.true_energy_j
    assert abs(est_cal.error_vs(truth)) <= abs(est_plain.error_vs(truth)) + 0.01


def test_module_scope_guard():
    """GH200 `instant` measures GPU+CPU+DRAM (paper §6): refuse to
    attribute it to chip energy without a host baseline."""
    s = OnboardSensor(profiles.get("gh200_module_instant"), seed=1)
    with pytest.raises(ModuleScopeError):
        measure_naive(s, BURST)
    # with a baseline it runs
    s2 = OnboardSensor(profiles.get("gh200_module_instant"), seed=1)
    e = measure_naive(s2, BURST, host_baseline_w=0.0)
    assert np.isfinite(e)


@settings(max_examples=6, deadline=None)
@given(dur=st.sampled_from([0.025, 0.1, 0.8]), seed=st.integers(0, 50))
def test_good_practice_error_bounded_across_durations(dur, seed):
    """Paper §5.1 tests short/medium/long loads (25 %, 100 %, 800 % of the
    update period); the protocol holds across all of them."""
    calib = _calib("a100")
    wl = Workload("wl", loads.workload_burst(dur, 200.0))
    s = OnboardSensor(profiles.get("a100"), seed=seed)
    est = measure_good_practice(s, wl, calib, GoodPracticeConfig(),
                                seed=seed)
    assert abs(est.error_vs(wl.true_energy_j)) < 0.15


def test_estimate_has_uncertainty_and_trials():
    calib = _calib("a100")
    s = OnboardSensor(profiles.get("a100"), seed=2)
    est = measure_good_practice(s, BURST, calib, GoodPracticeConfig(),
                                seed=0)
    assert est.n_trials == 4
    assert len(est.trial_values) == 4
    assert est.std_j >= 0.0
