"""Optional-`hypothesis` shim for the property-based tests.

The tier-1 environment may lack `hypothesis` (it is pinned in
``requirements.txt`` but not baked into every image).  Importing this
module instead of `hypothesis` directly lets the suite *degrade* —
property tests are individually skipped — rather than erroring six test
modules at collection time.

Usage (in a test module)::

    from _hyp import HAVE_HYPOTHESIS, given, settings, st
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised when hypothesis absent
    HAVE_HYPOTHESIS = False

    _SKIP = pytest.mark.skip(reason="hypothesis not installed "
                                    "(see requirements.txt)")

    class _Strategy:
        """Inert placeholder accepted anywhere a strategy is expected."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    class _Strategies:
        def __getattr__(self, name):
            return _Strategy()

    st = _Strategies()

    def given(*args, **kwargs):
        def deco(fn):
            return _SKIP(fn)
        return deco

    def settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco
