"""Dry-run path exercised in-process on a tiny forced-device mesh via a
subprocess (XLA device count must be set before jax import, so the test
spawns `python -m repro.launch.dryrun --mesh tiny --reduced`)."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")
SRC = os.path.join(ROOT, "src")


def _run(args, devices="4"):
    env = dict(os.environ,
               PYTHONPATH=SRC,
               REPRO_DRYRUN_DEVICES=devices)
    env.pop("JAX_PLATFORMS", None)
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", *args],
        capture_output=True, text=True, env=env, cwd=ROOT, timeout=500)


@pytest.mark.slow
def test_dryrun_tiny_mesh_reduced(tmp_path):
    out = str(tmp_path / "art")
    r = _run(["--mesh", "tiny", "--reduced", "--arch", "gemma2-2b",
              "--shape", "train_4k", "--out", out])
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout
    files = os.listdir(out)
    assert len(files) == 1
    art = json.load(open(os.path.join(out, files[0])))
    assert art["status"] == "ok"
    rl = art["roofline"]
    assert rl["dot_flops_per_device"] > 0
    assert rl["bottleneck"] in ("compute", "memory", "collective")
    assert "temp_size_in_bytes" in art["memory_analysis"]


@pytest.mark.slow
def test_dryrun_decode_and_skip(tmp_path):
    out = str(tmp_path / "art")
    r = _run(["--mesh", "tiny", "--reduced", "--arch", "recurrentgemma-9b",
              "--shape", "long_500k", "--out", out])
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout
    r2 = _run(["--mesh", "tiny", "--reduced", "--arch", "llama3-405b",
               "--shape", "long_500k", "--out", out])
    assert r2.returncode == 0
    assert "SKIP" in r2.stdout


@pytest.mark.slow
def test_dryrun_multipod_tiny(tmp_path):
    """The pod axis shards: a (2,2,2) pod×data×model mesh compiles."""
    env = dict(os.environ, PYTHONPATH=SRC, REPRO_DRYRUN_DEVICES="8")
    env.pop("JAX_PLATFORMS", None)
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
from repro.configs.registry import get_config
from repro.configs.base import get_shape
from repro.launch.dryrun import lower_cell
from repro.launch.mesh import make_mesh
cfg = get_config("olmo-1b", reduced=True)
mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
compiled, txt, _, _ = lower_cell(cfg, get_shape("train_4k"), mesh)
print("MULTIPOD_OK", compiled.cost_analysis() is not None)
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, cwd=ROOT, timeout=500)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "MULTIPOD_OK" in r.stdout


def test_sharding_rules_divisibility():
    """Rules never shard a non-divisible dim (recurrentgemma kv=1 must not
    be padded 16×)."""
    import jax
    from jax.sharding import Mesh
    import numpy as np
    from repro.distributed.sharding import ShardingRules
    devs = np.asarray(jax.devices()[:1]).reshape(1, 1)
    mesh = Mesh(devs, ("data", "model"))
    rules = ShardingRules(mesh)
    rules.axis_sizes = {"data": 16, "model": 16}   # pretend production
    # kv heads = 1: wk must not use the model axis on the head dim
    spec = rules.param_pspec("blocks.p2_attn.wk", (38, 4096, 1, 256))
    assert spec[2] is None
    # divisible head dim: wq uses it
    spec2 = rules.param_pspec("blocks.p0_attn.wq", (36, 4096, 32, 128))
    assert spec2[2] == "model"
    # embeddings: vocab over model only when divisible
    assert rules.param_pspec("embed", (49155, 1536))[0] is None
    assert rules.param_pspec("embed", (256000, 2304))[0] == "model"
