import os
import sys

# tests must see the single real CPU device (the dry-run subprocess sets its
# own XLA_FLAGS); keep jax quiet and deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# make the `_hyp` optional-hypothesis shim importable from every test module
sys.path.insert(0, os.path.dirname(__file__))
