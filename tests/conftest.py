import os
import sys

import pytest

# tests must see the single real CPU device (the dry-run subprocess sets its
# own XLA_FLAGS); keep jax quiet and deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# make the `_hyp` optional-hypothesis shim importable from every test module
sys.path.insert(0, os.path.dirname(__file__))


@pytest.fixture(params=["jax", "pallas"])
def accel_backend(request):
    """Every accelerated backend tier, for parametrizing parity tests.

    Skips when the tier cannot load (numpy-only CI); the pallas tier
    auto-selects ``interpret=True`` on CPU-only hosts, so no accelerator
    is required to exercise it.
    """
    from repro.core.engine_backend import available_backends
    name = request.param
    if name not in available_backends():
        pytest.skip(f"backend '{name}' not available")
    return name
