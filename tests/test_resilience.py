"""Fault-domain resilience (ISSUE 9).

Six groups:

* fault injection — ``FaultSpec`` validation, per-slab determinism of
  the injector (slab ``seq`` faults identically regardless of replay
  history), injection counts surfaced by ``replay``, and the legacy
  knob / spec equivalence;
* hardened ingest — out-of-range ids (strict raise vs reject-and-count),
  non-finite timestamps, all-rejected slabs bumping the epoch exactly
  once, duplicates straddling a checkpoint boundary;
* the health machine — healthy → stale → quarantined transitions on the
  flag criteria, clean-streak recovery with dwell, and the opt-in
  default changing nothing;
* degraded-mode queries — quarantined devices excluded from fleet and
  by-label aggregates, sigma widening, honest coverage, inf bounds when
  nothing trustworthy remains;
* checkpoint hardening — truncated ``.npy``, garbled/missing manifests
  and partial writes raise typed ``CheckpointError``; ``fallback=True``
  restores the newest complete generation;
* the crash-recovery supervisor — a run killed at arbitrary slab
  boundaries under every fault knob at once restores, resumes, and
  answers every query *bitwise* identically to an uninterrupted run, on
  every available backend.
"""
import json
import os

import numpy as np
import pytest

from repro.core import load as loads
from repro.core.fleet_engine import SensorBank
from repro.core.stream import (QUARANTINED, STALE, CheckpointError,
                               FaultInjector, FaultSpec, HealthPolicy,
                               MissingCheckpointError, MonitorService,
                               MonitorSupervisor, StreamCorrections,
                               replay, restore_monitor, save_monitor)


@pytest.fixture(params=["numpy", "jax"])
def backend(request):
    from repro.core.engine_backend import available_backends
    if request.param not in available_backends():
        pytest.skip(f"backend '{request.param}' not available")
    return request.param


def _corr(n, seed=0):
    rng = np.random.default_rng(seed)
    return StreamCorrections(
        gain=rng.uniform(0.9, 1.1, n), offset_w=rng.uniform(-3.0, 3.0, n),
        time_shift_s=rng.uniform(-0.05, 0.0, n),
        baseline_w=rng.uniform(0.0, 5.0, n),
        ref_period_s=np.full(n, 0.1),
        calibrated=rng.random(n) < 0.5)


def _monitor(n, backend="numpy", seed=0, **kw):
    labels = np.array(["train", "serve", "idle"], dtype=object)[
        np.arange(n) % 3]
    mon = MonitorService(n, corrections=_corr(n, seed), labels=labels,
                         max_hold_s=2.0, ring_slots=8, backend=backend,
                         **kw)
    mon.set_windows(0.5, 2.5)
    return mon


def _slabs(n, n_slabs=8, seed=0):
    """Deterministic messy poll slabs (0.5 s of stream each)."""
    rng = np.random.default_rng(seed)
    out = []
    t0 = 0.0
    for _ in range(n_slabs):
        k = int(rng.integers(3 * n, 6 * n))
        dev = rng.integers(0, n, k).astype(np.int64)
        t = t0 + np.sort(rng.uniform(0.0, 0.5, k))
        v = 80.0 + 40.0 * rng.random(k)
        perm = rng.permutation(k)
        out.append((dev[perm], t[perm], v[perm]))
        t0 += 0.5
    return out


def _fingerprint(mon):
    """Every query family + the ingest counters, for bitwise comparison."""
    fe = mon.fleet_energy(t=1.7)
    eb = mon.energy_between(0.9, 1.9)
    return {
        "fleet_per_device": fe.per_device_j,
        "fleet_covered": fe.covered,
        "fleet_total": np.float64(fe.total_j),
        "fleet_coverage": np.float64(fe.coverage),
        "fleet_n_q": np.int64(fe.n_quarantined),
        "fleet_latest": mon.fleet_energy().per_device_j,
        "between_e": eb[0], "between_cov": eb[1],
        "window": mon.window_energy(t=1.8),
        "periods": mon.update_period_s(),
        **{f"by_label.{k}.{m}": np.float64(v)
           for k, d in mon.by_label().items() for m, v in d.items()},
        **{f"flags.{k}": v for k, v in mon.flags(t=2.0).items()},
        **{f"counters.{k}": np.int64(v) for k, v in mon.counters.items()},
        **{f"health.{k}": np.float64(v)
           for k, v in mon.health_summary().items()},
    }


def _assert_fingerprints_equal(a, b):
    assert set(a) == set(b)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k], err_msg=k)


# ---------------------------------------------------------------------------
# fault injection
# ---------------------------------------------------------------------------

def test_fault_spec_validation():
    with pytest.raises(ValueError, match="dup_fraction"):
        FaultSpec(dup_fraction=1.5)
    with pytest.raises(ValueError, match="clock_drift"):
        FaultSpec(clock_drift=1.0)
    with pytest.raises(ValueError, match="clock_skew_s"):
        FaultSpec(clock_skew_s=-0.1)
    with pytest.raises(ValueError, match="restart"):
        FaultSpec(restart_every_s=-1.0)
    assert not FaultSpec().any
    assert FaultSpec(corrupt_fraction=0.1).any


ALL_FAULTS = FaultSpec(shuffle=True, dup_fraction=0.10, drop_fraction=0.05,
                       delay_fraction=0.10, clock_drift=0.01,
                       clock_skew_s=0.02, restart_every_s=0.8,
                       restart_blackout_s=0.05, corrupt_fraction=0.05,
                       dropout_fraction=0.25, dropout_after=0.4, seed=7)


def test_fault_injector_slab_decisions_are_seq_keyed():
    """Slab ``seq`` injects identical faults no matter what came before
    — the property crash-recovery replays rely on."""
    spec = FaultSpec(drop_fraction=0.2, corrupt_fraction=0.2,
                     dup_fraction=0.2, shuffle=True, seed=3)
    slabs = _slabs(6, n_slabs=6, seed=1)
    a = FaultInjector(spec, 6, 0.0, 3.0)
    full = [a.apply(i, *s) for i, s in enumerate(slabs)]
    b = FaultInjector(spec, 6, 0.0, 3.0)
    only3 = b.apply(3, *slabs[3])
    for got, want in zip(only3, full[3]):
        np.testing.assert_array_equal(got, want)


def test_fault_injector_plan_is_deterministic_and_logged():
    a = FaultInjector(ALL_FAULTS, 8, 0.0, 4.0)
    b = FaultInjector(ALL_FAULTS, 8, 0.0, 4.0)
    np.testing.assert_array_equal(a.log.drift_rate, b.log.drift_rate)
    np.testing.assert_array_equal(a.log.skew_s, b.log.skew_s)
    np.testing.assert_array_equal(a.log.dropout_t, b.log.dropout_t)
    np.testing.assert_array_equal(a.log.restarts, b.log.restarts)
    assert np.isfinite(a.log.dropout_t).any()      # someone died
    dead = a.log.dropout_t[np.isfinite(a.log.dropout_t)]
    assert np.all(dead >= 0.0 + 0.4 * 4.0)         # after dropout_after
    s = a.log.summary()
    json.dumps(s)                                  # machine-readable
    assert s["n_devices"] == 8 and s["seed"] == 7


def test_replay_reports_injection_counts():
    bank = _bank(6)
    mon = MonitorService(6, strict_ids=False)
    rep = replay(bank, mon, 0.0, 1.0, faults=ALL_FAULTS)
    inj = rep["injected"]
    assert inj["dropped"] > 0 and inj["duplicated"] > 0
    assert inj["corrupt_value"] + inj["corrupt_id"] + inj["corrupt_time"] > 0
    # corrupted ids reach the monitor and are rejected-and-counted there
    # (duplication can re-emit a corrupted sample, hence >=)
    assert rep["rejected"] >= inj["corrupt_id"] > 0
    clean = MonitorService(6)
    rep2 = replay(bank, clean, 0.0, 1.0)
    assert all(v == 0 for v in rep2["injected"].values())


def test_replay_faults_and_legacy_knobs_conflict():
    bank = _bank(4)
    with pytest.raises(ValueError, match="not both"):
        replay(bank, MonitorService(4), 0.0, 1.0, shuffle=True,
               faults=FaultSpec(shuffle=True))
    with pytest.raises(ValueError, match="grid"):
        replay(bank, MonitorService(4), 0.0, 1.0,
               faults=FaultSpec(drop_fraction=0.1), grid=True)


def test_legacy_knobs_equal_explicit_spec():
    bank = _bank(5)
    a = MonitorService(5)
    replay(bank, a, 0.0, 1.0, shuffle=True, dup_fraction=0.2,
           drop_fraction=0.1, seed=11)
    b = MonitorService(5)
    replay(bank, b, 0.0, 1.0,
           faults=FaultSpec(shuffle=True, dup_fraction=0.2,
                            drop_fraction=0.1, seed=11))
    np.testing.assert_array_equal(a.state.energy_j, b.state.energy_j)
    np.testing.assert_array_equal(a.state.win_corr_j, b.state.win_corr_j)


def _bank(n, seed=0):
    bank = SensorBank.from_catalog(["a100"] * n, seeds=np.arange(n) + seed)
    tl = loads.step(0.1, 0.7, 210.0, idle_w=60.0)
    bank.attach(tl, t_end=tl.t_end + 1.0)
    return bank


def test_adversarial_mix_labels_and_banks():
    assert set(loads.ADVERSARIAL_MIX) <= set(loads.SCENARIOS)
    assert set(loads.ADVERSARIAL_MIX) <= set(loads.SCENARIO_BANKS)
    ws = loads.mixed_fleet_workloads(40, loads.ADVERSARIAL_MIX, seed=5,
                                     as_bank=True)
    assert set(ws.scenarios) <= set(loads.ADVERSARIAL_MIX)


# ---------------------------------------------------------------------------
# hardened ingest
# ---------------------------------------------------------------------------

def test_out_of_range_ids_strict_default_raises():
    mon = MonitorService(3)
    with pytest.raises(ValueError, match="out of range"):
        mon.ingest(np.array([0, 7]), np.array([0.1, 0.2]),
                   np.array([100.0, 100.0]))


def test_out_of_range_ids_rejected_and_counted():
    mon = MonitorService(3, strict_ids=False)
    rep = mon.ingest(np.array([0, 7, 1, -1]),
                     np.array([0.1, 0.2, 0.3, 0.4]),
                     np.array([100.0, 100.0, 90.0, 80.0]))
    assert rep.rejected == 2
    assert rep.accepted == 2
    assert mon.counters["rejected"] == 2
    assert mon.state.has[0] and mon.state.has[1] and not mon.state.has[2]


def test_all_rejected_slab_bumps_epoch_exactly_once():
    mon = MonitorService(3, strict_ids=False)
    e0 = mon.epoch
    rep = mon.ingest(np.array([5, 9]), np.array([0.1, 0.2]),
                     np.array([100.0, 100.0]))
    assert rep.accepted == 0 and rep.rejected == 2
    assert mon.epoch == e0 + 1
    assert not mon.state.has.any()


def test_nonfinite_timestamps_and_values_dropped():
    mon = MonitorService(2)
    rep = mon.ingest(np.array([0, 0, 1, 1]),
                     np.array([0.1, np.nan, 0.1, np.inf]),
                     np.array([100.0, 100.0, np.nan, 90.0]))
    assert rep.accepted == 1                      # only (0, 0.1, 100)
    assert rep.invalid == 3
    assert np.isfinite(mon.state.energy_j).all()
    fp = mon.fleet_energy()
    assert np.isfinite(fp.total_j)


def test_grid_ingest_rejects_bad_device_rows():
    mon = MonitorService(3, strict_ids=False)
    ts = 0.1 + 0.1 * np.arange(4)
    vals = np.full((2, 4), 100.0)
    rep = mon.ingest_grid(np.array([0, 9]), ts, vals)
    assert rep.rejected == 4                      # one bad row × 4 ticks
    assert mon.state.has[0] and not mon.state.has[1:].any()
    mon2 = MonitorService(3)
    with pytest.raises(ValueError, match="out of range"):
        mon2.ingest_grid(np.array([0, 9]), ts, vals)


def test_duplicates_straddling_checkpoint_boundary(tmp_path):
    """Samples re-sent after a restore (the at-least-once overlap a
    resumed collector produces) are deduplicated, not double-counted."""
    a_dev = np.repeat(np.arange(3), 10).astype(np.int64)
    a_ts = np.tile(0.1 * np.arange(10), 3)
    a_vs = np.full(30, 120.0)
    b_dev = np.repeat(np.arange(3), 10).astype(np.int64)
    b_ts = np.tile(1.0 + 0.1 * np.arange(10), 3)
    b_vs = np.full(30, 95.0)

    ref = MonitorService(3)
    ref.ingest(a_dev, a_ts, a_vs)
    ref.ingest(b_dev, b_ts, b_vs)

    mon = MonitorService(3)
    mon.ingest(a_dev, a_ts, a_vs)
    save_monitor(mon, str(tmp_path / "ck"))
    clone = restore_monitor(str(tmp_path / "ck"))
    # the resumed stream replays the tail of slab A before slab B
    clone.ingest(np.concatenate([a_dev[-9:], b_dev]),
                 np.concatenate([a_ts[-9:], b_ts]),
                 np.concatenate([a_vs[-9:], b_vs]))
    np.testing.assert_array_equal(clone.state.energy_j, ref.state.energy_j)
    np.testing.assert_array_equal(clone.state.win_corr_j,
                                  ref.state.win_corr_j)
    # the replayed tail: 1 exact duplicate of the newest sample + 8
    # older-than-newest stragglers, all counted instead of re-folded
    extra = (clone.counters["duplicates"] + clone.counters["late"]
             - ref.counters["duplicates"] - ref.counters["late"])
    assert extra == 9


# ---------------------------------------------------------------------------
# the health machine
# ---------------------------------------------------------------------------

def _health_mon(n=3, **pol):
    return MonitorService(n, silent_after_s=0.5,
                          health=HealthPolicy(**pol))


def _steady(mon, devs, t0, t1, p=100.0, dt=0.1):
    ts = np.arange(t0, t1, dt)
    devs = np.asarray(list(devs), np.int64)
    dev = np.repeat(devs, ts.size)
    mon.ingest(dev, np.tile(ts, devs.size), np.full(dev.size, p))


def test_health_demotion_chain_silent_to_quarantined():
    mon = _health_mon()
    _steady(mon, [0, 1, 2], 0.0, 1.0)
    assert mon.health_summary()["n_quarantined"] == 0
    _steady(mon, [0], 1.0, 1.3)
    # device 1, 2 silent since 0.9; thresholds: stale > 0.5, dead > 1.5
    assert mon.update_health(1.6)
    code = mon.health.code
    assert code[0] == 0 and code[1] == STALE and code[2] == STALE
    assert mon.update_health(2.6)
    assert (mon.health.code[1:] == QUARANTINED).all()
    assert mon.counters["n_quarantined"] == 2
    s = mon.health_summary()
    assert s["tracked"] and s["n_quarantined"] == 2
    assert s["coverage"] == pytest.approx(1.0 / 3.0)


def test_health_recovery_needs_clean_dwell():
    mon = _health_mon(2, recover_after_s=1.0)
    _steady(mon, [0, 1], 0.0, 1.0)
    mon.update_health(3.0)
    assert (mon.health.code == QUARANTINED).all()
    _steady(mon, [0, 1], 3.0, 3.3)
    mon.update_health(3.4)                        # clean streak starts
    assert (mon.health.code == QUARANTINED).all()
    _steady(mon, [0, 1], 3.3, 4.6)
    mon.update_health(4.6)                        # dwell >= 1.0 s clean
    assert (mon.health.code == 0).all()
    assert mon.counters["n_quarantined"] == 0
    assert (mon.health.n_quarantines == 1).all()  # lifetime count sticks


def test_health_instant_recovery_without_dwell():
    mon = _health_mon()
    _steady(mon, [0, 1, 2], 0.0, 1.0)
    mon.update_health(3.0)
    _steady(mon, [0, 1, 2], 3.0, 3.5)
    mon.update_health(3.5)
    assert (mon.health.code == 0).all()


def test_health_update_bumps_epoch_only_on_change():
    mon = _health_mon()
    _steady(mon, [0, 1, 2], 0.0, 1.0)
    e = mon.epoch
    assert not mon.update_health(1.05)            # nothing changed
    assert mon.epoch == e
    assert mon.update_health(3.0)
    assert mon.epoch == e + 1


def test_health_opt_in_default_changes_nothing():
    mon = MonitorService(3)
    _steady(mon, [0], 0.0, 1.0)
    assert mon.health is None and mon.health_policy is None
    assert "n_quarantined" not in mon.counters
    s = mon.health_summary()
    assert not s["tracked"] and s["coverage"] == 1.0
    fl = mon.flags(t=5.0)
    assert not fl["stale"].any() and not fl["quarantined"].any()
    fe = mon.fleet_energy()
    assert fe.coverage == 1.0 and fe.n_quarantined == 0


def test_health_policy_validation_and_meta_roundtrip():
    with pytest.raises(ValueError):
        HealthPolicy(stale_factor=0.0)
    with pytest.raises(ValueError):
        HealthPolicy(stale_factor=4.0, quarantine_factor=2.0)
    with pytest.raises(ValueError):
        HealthPolicy(recover_after_s=-1.0)
    pol = HealthPolicy(stale_factor=1.5, recover_after_s=2.0)
    assert HealthPolicy.from_meta(pol.to_meta()) == pol


# ---------------------------------------------------------------------------
# degraded-mode queries
# ---------------------------------------------------------------------------

def test_quarantined_devices_excluded_with_widened_bounds():
    from repro.core.telemetry import CALIBRATED_TOLERANCE, SHUNT_TOLERANCE
    n = 4
    mon = MonitorService(n, silent_after_s=0.5, health=HealthPolicy())
    _steady(mon, range(n), 0.0, 2.01, p=100.0, dt=0.1)
    base = mon.fleet_energy()
    assert base.coverage == 1.0 and base.n_quarantined == 0
    _steady(mon, [0, 1, 2], 2.0, 4.0, p=100.0, dt=0.1)
    mon.update_health(4.0)       # device 3 silent 2.0 s > 3 × 0.5 s
    fe = mon.fleet_energy()
    assert fe.n_quarantined == 1
    assert fe.coverage == pytest.approx(3 / 4)
    # the excluded device's energy is out of the total but its row stays
    assert fe.total_j == pytest.approx(float(
        np.sum(fe.per_device_j[:3])))
    assert fe.per_device_j[3] > 0.0
    # bounds widen by (n_included + n_quarantined) / n_included
    tol = np.where(mon.corrections.calibrated,
                   CALIBRATED_TOLERANCE, SHUNT_TOLERANCE)
    sig = tol[:3] * np.abs(fe.per_device_j[:3])
    widen = 4.0 / 3.0
    assert fe.sigma_independent_j == pytest.approx(
        widen * float(np.sqrt(np.sum(sig ** 2))))
    assert fe.sigma_worstcase_j == pytest.approx(
        widen * float(np.sum(sig)))


def test_all_quarantined_reports_inf_bounds():
    mon = MonitorService(2, silent_after_s=0.2, health=HealthPolicy())
    _steady(mon, [0, 1], 0.0, 0.5)
    mon.update_health(10.0)
    fe = mon.fleet_energy()
    assert fe.coverage == 0.0 and fe.n_quarantined == 2
    assert fe.total_j == 0.0
    assert np.isinf(fe.sigma_independent_j)
    assert np.isinf(fe.sigma_worstcase_j)


def test_by_label_reports_per_label_quarantine():
    mon = _monitor(6, silent_after_s=0.5, health=HealthPolicy())
    _steady(mon, range(6), 0.0, 1.01)
    _steady(mon, [0, 1, 2], 1.0, 3.0)             # labels t/s/i stay alive
    mon.update_health(3.0)
    bl = mon.by_label()
    assert sum(d["n_quarantined"] for d in bl.values()) == 3
    for d in bl.values():
        assert d["n_covered"] + d["n_quarantined"] <= d["n_devices"]
    plain = _monitor(6)
    _steady(plain, range(6), 0.0, 1.01)
    assert all(d["n_quarantined"] == 0 for d in plain.by_label().values())


def test_flags_surface_health_states():
    mon = _health_mon()
    _steady(mon, [0, 1, 2], 0.0, 1.0)
    _steady(mon, [0], 1.0, 1.3)
    mon.update_health(1.6)
    fl = mon.flags(t=1.6)
    np.testing.assert_array_equal(fl["stale"], mon.health.code == STALE)
    np.testing.assert_array_equal(fl["quarantined"],
                                  mon.health.code == QUARANTINED)


def test_node_failure_fleet_bounded_error_and_honest_coverage():
    """The acceptance scenario: half the fleet drops out permanently
    mid-stream; quarantine keeps the fleet total an honest aggregate of
    the surviving devices, with coverage reported."""
    n = 8
    spec = FaultSpec(dropout_fraction=0.5, dropout_after=0.4, seed=3)
    # the injector plan spans [0, 3] so every death lands well before
    # the stream ends at 4.0 — survivors are provably fresh at eval time
    inj = FaultInjector(spec, n, 0.0, 3.0)
    dead = np.isfinite(inj.log.dropout_t)
    assert 0 < dead.sum() < n
    mon = MonitorService(n, silent_after_s=0.2,
                         health=HealthPolicy(), health_every_s=0.1)
    powers = 100.0 + 10.0 * np.arange(n)
    ts_all = 0.05 * np.arange(81)                 # [0, 4] at 50 ms
    for seq in range(8):
        sl = ts_all[(ts_all >= seq * 0.5) & (ts_all < (seq + 1) * 0.5)]
        dev = np.repeat(np.arange(n), sl.size).astype(np.int64)
        ts = np.tile(sl, n)
        vs = powers[dev]
        mon.ingest(*inj.apply(seq, dev, ts, vs))
    mon.update_health(4.1)
    code = mon.health.code
    assert (code[dead] == QUARANTINED).all()
    assert (code[~dead] == 0).all()
    fe = mon.fleet_energy()
    n_dead = int(dead.sum())
    assert fe.n_quarantined == n_dead
    assert fe.coverage == pytest.approx((n - n_dead) / n)
    true_alive = float(np.sum(powers[~dead]) * 3.95)
    assert fe.total_j == pytest.approx(true_alive, rel=0.05)


# ---------------------------------------------------------------------------
# checkpoint hardening
# ---------------------------------------------------------------------------

def _saved(tmp_path, step=None, n=4):
    mon = _monitor(n)
    dev, ts, vs = _slabs(n, n_slabs=4, seed=2)[0]
    mon.ingest(dev, ts, vs)
    root = str(tmp_path / "ck")
    save_monitor(mon, root, step=step)
    return mon, root


def test_missing_root_and_step_raise_missing_error(tmp_path):
    with pytest.raises(FileNotFoundError):
        restore_monitor(str(tmp_path / "nope"))
    with pytest.raises(MissingCheckpointError):
        restore_monitor(str(tmp_path / "nope"))
    _, root = _saved(tmp_path, step=3)
    with pytest.raises(MissingCheckpointError, match="step_9"):
        restore_monitor(root, step=9)


def test_truncated_array_raises_checkpoint_error(tmp_path):
    _, root = _saved(tmp_path, step=1)
    d = os.path.join(root, "step_1")
    npys = sorted(f for f in os.listdir(d) if f.endswith(".npy"))
    victim = os.path.join(d, npys[0])
    with open(victim, "rb") as f:
        head = f.read(16)
    with open(victim, "wb") as f:
        f.write(head)                             # truncate mid-header
    with pytest.raises(CheckpointError, match="truncated or corrupt"):
        restore_monitor(root)


def test_missing_array_and_manifest_raise_checkpoint_error(tmp_path):
    _, root = _saved(tmp_path, step=1)
    d = os.path.join(root, "step_1")
    npys = sorted(f for f in os.listdir(d) if f.endswith(".npy"))
    os.remove(os.path.join(d, npys[0]))
    with pytest.raises(CheckpointError, match="missing"):
        restore_monitor(root)
    os.remove(os.path.join(d, "manifest.json"))
    with pytest.raises(CheckpointError, match="manifest.json missing"):
        restore_monitor(root)


def test_garbled_manifest_raises_checkpoint_error(tmp_path):
    _, root = _saved(tmp_path, step=1)
    with open(os.path.join(root, "step_1", "manifest.json"), "w") as f:
        f.write("{not json")
    with pytest.raises(CheckpointError, match="unreadable manifest"):
        restore_monitor(root)


def test_fallback_restores_newest_complete_generation(tmp_path):
    mon = _monitor(4)
    slabs = _slabs(4, n_slabs=3, seed=2)
    root = str(tmp_path / "ck")
    mon.ingest(*slabs[0])
    save_monitor(mon, root, step=1)
    want = _fingerprint(mon)
    mon.ingest(*slabs[1])
    save_monitor(mon, root, step=2)
    d = os.path.join(root, "step_2")
    npys = sorted(f for f in os.listdir(d) if f.endswith(".npy"))
    os.remove(os.path.join(d, npys[0]))           # newest gen is broken
    with pytest.raises(CheckpointError):
        restore_monitor(root)                     # strict: surfaces it
    clone = restore_monitor(root, fallback=True)  # falls back to step 1
    _assert_fingerprints_equal(_fingerprint(clone), want)
    os.remove(os.path.join(root, "step_1", "manifest.json"))
    with pytest.raises(CheckpointError, match="no readable checkpoint"):
        restore_monitor(root, fallback=True)


def test_save_extras_roundtrip_and_collision(tmp_path):
    mon = _monitor(3)
    mon.ingest(*_slabs(3, n_slabs=1, seed=0)[0])
    root = str(tmp_path / "ck")
    save_monitor(mon, root, step=5, extras={"slab_seq": 41})
    clone, meta = restore_monitor(root, with_meta=True)
    assert meta["slab_seq"] == 41
    assert clone.epoch == mon.epoch
    with pytest.raises(ValueError, match="collide"):
        save_monitor(mon, root, step=6, extras={"epoch": 0})


def test_health_monitor_checkpoint_roundtrip(tmp_path):
    mon = _health_mon()
    _steady(mon, [0, 1, 2], 0.0, 1.0)
    _steady(mon, [0], 1.0, 1.3)
    mon.update_health(1.6)
    root = str(tmp_path / "ck")
    save_monitor(mon, root)
    clone = restore_monitor(root)
    assert clone.health_policy == mon.health_policy
    np.testing.assert_array_equal(clone.health.code, mon.health.code)
    _assert_fingerprints_equal(_fingerprint(clone), _fingerprint(mon))
    # the restored machine keeps evolving identically
    clone.update_health(2.6)
    mon.update_health(2.6)
    np.testing.assert_array_equal(clone.health.code, mon.health.code)


# ---------------------------------------------------------------------------
# the crash-recovery supervisor
# ---------------------------------------------------------------------------

def _faulty_source(spec, slabs, n, t0, t1):
    """A deterministic slab source: rebuilds the injector each call, so
    every (re)play emits the identical faulted stream."""
    def source():
        inj = FaultInjector(spec, n, t0, t1)
        for seq, (dev, ts, vs) in enumerate(slabs):
            dev, ts, vs = inj.apply(seq, dev, ts, vs)
            if dev.size:
                yield seq, dev, ts, vs
    return source


def _crashing(source, fail_at, n_fails=1):
    state = {"left": n_fails}
    def src():
        for i, slab in enumerate(source()):
            if state["left"] > 0 and i == fail_at:
                state["left"] -= 1
                raise RuntimeError("collector died")
            yield slab
    return src


def _sup_factory(n, backend):
    def factory():
        return _monitor(n, backend, strict_ids=False,
                        health=HealthPolicy(), health_every_s=0.25,
                        silent_after_s=1.0)
    return factory


@pytest.mark.parametrize("fail_at", [1, 4, 9])
def test_supervisor_recovery_is_bitwise(tmp_path, backend, fail_at):
    """The acceptance pin: kill the run at an arbitrary slab under every
    fault knob at once; the supervisor restores the newest complete
    checkpoint, resumes at the slab boundary, and the final monitor
    answers every query bitwise identically to a never-killed run."""
    n, n_slabs = 6, 12
    slabs = _slabs(n, n_slabs=n_slabs, seed=3)
    source = _faulty_source(ALL_FAULTS, slabs, n, 0.0, 0.5 * n_slabs)
    ref = _sup_factory(n, backend)()
    for _, dev, ts, vs in source():
        ref.ingest(dev, ts, vs)
    want = _fingerprint(ref)

    sup = MonitorSupervisor(_sup_factory(n, backend),
                            str(tmp_path / "ck"), checkpoint_every=3)
    report = sup.run(_crashing(source, fail_at))
    assert report.n_crashes == 1 and report.n_restores == 1
    assert report.n_slabs + report.n_skipped >= n_slabs
    _assert_fingerprints_equal(_fingerprint(sup.monitor), want)


def test_supervisor_survives_repeated_crashes(tmp_path):
    n, n_slabs = 5, 10
    slabs = _slabs(n, n_slabs=n_slabs, seed=6)
    source = _faulty_source(ALL_FAULTS, slabs, n, 0.0, 5.0)
    ref = _sup_factory(n, "numpy")()
    for _, dev, ts, vs in source():
        ref.ingest(dev, ts, vs)
    sup = MonitorSupervisor(_sup_factory(n, "numpy"),
                            str(tmp_path / "ck"), checkpoint_every=2)
    report = sup.run(_crashing(source, 6, n_fails=3))
    assert report.n_crashes == 3 and report.n_restores == 3
    _assert_fingerprints_equal(_fingerprint(sup.monitor),
                               _fingerprint(ref))


def test_supervisor_resumes_across_instances(tmp_path):
    """Hard-kill semantics: a brand-new supervisor (fresh process in
    spirit) picks up the slab cursor from the checkpoint meta and skips
    everything already folded."""
    n, n_slabs = 5, 10
    slabs = _slabs(n, n_slabs=n_slabs, seed=4)
    source = _faulty_source(ALL_FAULTS, slabs, n, 0.0, 5.0)
    ref = _sup_factory(n, "numpy")()
    for _, dev, ts, vs in source():
        ref.ingest(dev, ts, vs)

    def truncated():
        for i, slab in enumerate(source()):
            if i >= 6:
                return
            yield slab

    root = str(tmp_path / "ck")
    first = MonitorSupervisor(_sup_factory(n, "numpy"), root,
                              checkpoint_every=4)
    rep1 = first.run(truncated)
    assert rep1.n_slabs == 6 and rep1.resumed_from is None
    second = MonitorSupervisor(_sup_factory(n, "numpy"), root,
                               checkpoint_every=4)
    rep2 = second.run(source)
    assert rep2.resumed_from == rep1.last_seq
    assert rep2.n_skipped == 6
    _assert_fingerprints_equal(_fingerprint(second.monitor),
                               _fingerprint(ref))


def test_supervisor_exhausts_restores_and_reraises(tmp_path):
    def always_crash():
        raise RuntimeError("hopeless")
        yield  # pragma: no cover

    sup = MonitorSupervisor(lambda: MonitorService(2),
                            str(tmp_path / "ck"), max_restores=2)
    with pytest.raises(RuntimeError, match="hopeless"):
        sup.run(always_crash)


def test_supervisor_validation():
    with pytest.raises(ValueError):
        MonitorSupervisor(lambda: None, "x", checkpoint_every=0)
    with pytest.raises(ValueError):
        MonitorSupervisor(lambda: None, "x", max_restores=-1)
