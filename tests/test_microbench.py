"""Closed-loop validation: black-box estimators recover hidden sensor
parameters (the paper's §4 experiments as property tests)."""
import numpy as np
import pytest
from _hyp import given, settings, st  # degrades to per-test skips without hypothesis

from repro.core import microbench, profiles
from repro.core.ground_truth import GroundTruthMeter
from repro.core.sensor import OnboardSensor, SensorProfile, SensorUnsupported


# ---------------------------------------------------------------------------
# 4.1 update period
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("profile,expect", [
    ("a100", 0.100), ("v100", 0.020), ("turing", 0.100),
    ("rtx3090_instant", 0.100),
])
def test_update_period_catalog(profile, expect):
    s = OnboardSensor(profiles.get(profile), seed=7)
    T = microbench.estimate_update_period(s)
    assert T == pytest.approx(expect, rel=0.15)


@settings(max_examples=10, deadline=None)
@given(T=st.sampled_from([0.015, 0.02, 0.05, 0.1, 0.2]),
       seed=st.integers(0, 1000))
def test_update_period_property(T, seed):
    prof = SensorProfile("x", update_period_s=T, window_s=T / 4)
    s = OnboardSensor(prof, seed=seed)
    est = microbench.estimate_update_period(s)
    assert est == pytest.approx(T, rel=0.2)


class _StubSensor:
    """Duck-typed sensor with a hand-built reading series: readings
    change at given times, so the estimator's run-length policy can be
    pinned without seeding luck."""

    def __init__(self, change_times, duration_s):
        self.change_times = np.asarray(change_times)
        self.duration_s = duration_s

    def attach(self, timeline, t_end=None):
        pass

    def poll(self, t0, t1, period_s=0.001):
        n = int(np.floor((t1 - t0) / period_s))
        ts = t0 + period_s * np.arange(n)
        # reading value = number of change times passed (all distinct)
        vals = np.searchsorted(self.change_times, ts, side="right").astype(
            np.float64)
        return ts, vals


def test_update_period_uses_complete_runs_only():
    """Regression: the phase-truncated first run (poll start → first
    change) and the capture-truncated last run must not enter the median.
    Complete runs here are [0.1, 0.2, 0.2] s → median 0.2; counting the
    0.03 s truncated first run used to drag it to 0.15."""
    s = _StubSensor([0.03, 0.13, 0.33, 0.53], duration_s=0.60)
    est = microbench.estimate_update_period(s, duration_s=0.60)
    assert est == pytest.approx(0.2, abs=1e-9)


def test_update_period_short_capture_returns_nan():
    """Fewer than three complete runs cannot support a median: captures
    whose only extra information is a partial run report nan instead of
    a phase-biased estimate."""
    s = _StubSensor([0.03, 0.13, 0.23], duration_s=0.30)
    assert np.isnan(microbench.estimate_update_period(s, duration_s=0.30))


def test_update_period_accurate_on_short_capture():
    """With the partial runs dropped, even a ~0.75 s capture of a 100 ms
    sensor lands on T regardless of the hidden phase."""
    for seed in range(6):
        s = OnboardSensor(profiles.get("a100"), seed=seed)
        est = microbench.estimate_update_period(s, duration_s=0.75)
        assert est == pytest.approx(0.100, rel=0.05)


# ---------------------------------------------------------------------------
# 4.2 transient response
# ---------------------------------------------------------------------------

def test_transient_instant():
    s = OnboardSensor(profiles.get("a100"), seed=3)
    tr = microbench.measure_transient(s, 0.100)
    assert tr.kind == "instant"
    assert tr.delay_s < 0.25


def test_transient_linear_1s():
    s = OnboardSensor(profiles.get("rtx3090_average"), seed=3)
    tr = microbench.measure_transient(s, 0.100)
    assert tr.kind == "linear"
    assert 0.6 < tr.rise_time_s < 1.2


def test_transient_logarithmic():
    s = OnboardSensor(profiles.get("kepler"), seed=3)
    tr = microbench.measure_transient(s, 0.015)
    assert tr.kind == "logarithmic"


def test_fermi_unsupported():
    s = OnboardSensor(profiles.get("fermi1"), seed=0)
    with pytest.raises(SensorUnsupported):
        microbench.estimate_update_period(s)


# ---------------------------------------------------------------------------
# 4.2 steady-state gain/offset
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_steady_state_recovers_gain_offset(seed):
    prof = profiles.get("rtx3090_instant")
    s = OnboardSensor(prof, seed=seed)
    meter = GroundTruthMeter(seed=seed + 1)
    ss = microbench.estimate_steady_state(s, meter)
    assert ss.gain == pytest.approx(s.true_gain, abs=0.01)
    assert ss.offset_w == pytest.approx(s.true_offset, abs=2.5)
    assert ss.r2 > 0.999     # the paper's "near perfect linear" (Fig. 8)


def test_gain_error_is_proportional_not_flat():
    """The paper's key correction of NVIDIA's spec: error grows with power
    (±5 %), it is not a flat ±5 W."""
    prof = SensorProfile("g", 0.1, 0.1, gain_tol=0.05, offset_tol_w=0.5,
                         noise_w=0.0)
    s = OnboardSensor(prof, seed=12)
    meter = GroundTruthMeter(seed=3, noise_w=0.0)
    ss = microbench.estimate_steady_state(s, meter)
    lo, hi = 100.0, 400.0
    err_lo = (ss.gain - 1) * lo + ss.offset_w
    err_hi = (ss.gain - 1) * hi + ss.offset_w
    # proportional: hi-power error ≈ 4× lo-power error (same sign)
    assert abs(err_hi) > 2.0 * abs(err_lo)


# ---------------------------------------------------------------------------
# 4.3 boxcar window
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("profile,W", [
    ("a100", 0.025),            # 25/100: the part-time headline case
    ("rtx3090_instant", 0.100),  # 100/100
    ("v100", 0.010),            # 10/20
])
def test_boxcar_window_catalog(profile, W):
    prof = profiles.get(profile)
    s = OnboardSensor(prof, seed=5)
    est, samples = microbench.estimate_boxcar_window(
        s, prof.update_period_s, repetitions=8, seed=11)
    assert est == pytest.approx(W, rel=0.3)


@settings(max_examples=6, deadline=None)
@given(frac=st.sampled_from([0.25, 0.5, 1.0]), seed=st.integers(0, 100))
def test_boxcar_window_property(frac, seed):
    T = 0.1
    prof = SensorProfile("x", T, T * frac)
    s = OnboardSensor(prof, seed=seed)
    est, _ = microbench.estimate_boxcar_window(s, T, repetitions=6,
                                               seed=seed)
    assert est == pytest.approx(T * frac, rel=0.35)


# ---------------------------------------------------------------------------
# full characterisation
# ---------------------------------------------------------------------------

def test_characterise_a100_sampled_fraction():
    """The headline finding: A100/H100 sample only 25 % of runtime."""
    s = OnboardSensor(profiles.get("a100"), seed=9)
    meter = GroundTruthMeter(seed=2)
    res = microbench.characterise(s, meter, boxcar_reps=6)
    assert res.update_period_s == pytest.approx(0.100, rel=0.1)
    assert res.sampled_fraction == pytest.approx(0.25, rel=0.35)
    assert res.gain == pytest.approx(s.true_gain, abs=0.015)


def test_characterise_volta_half_time():
    s = OnboardSensor(profiles.get("v100"), seed=9)
    res = microbench.characterise(s, boxcar_reps=6)
    assert res.sampled_fraction == pytest.approx(0.5, rel=0.35)
