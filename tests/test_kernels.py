"""Per-kernel interpret-mode validation vs pure-jnp oracles, with
shape/dtype sweeps (per brief)."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # degrades to per-test skips without hypothesis

from repro.kernels import ops, ref
from repro.kernels.fma_chain import fma_chain
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rglru_scan import rglru_scan


# ---------------------------------------------------------------------------
# fma_chain — the paper's benchmark load (Listing 1 / Fig. 5)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rows,niter,frac", [
    (256, 3, 1.0), (512, 10, 0.5), (1024, 1, 0.25), (256, 0, 1.0),
])
def test_fma_chain_identity(rows, niter, frac):
    x = jax.random.normal(jax.random.PRNGKey(0), (rows, 128), jnp.float32)
    y = fma_chain(x, niter, frac, block_rows=256, interpret=True)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(ref.fma_chain_ref(x, niter)),
                               atol=1e-6)


def test_fma_chain_wall_time_linear():
    """Fig. 5: duration is linear in chain length (R² ≈ 1). On CPU the
    interpret-mode overhead dominates at small n, so we check the jit'd
    XLA path monotonically and fit R² over larger iteration counts."""
    x = jax.random.normal(jax.random.PRNGKey(0), (2048, 128), jnp.float32)

    @jax.jit
    def run(x, n):
        def body(_, v):
            v = v * 2.0 + 2.0
            return v * 0.5 - 1.0
        return jax.lax.fori_loop(0, n, body, x)

    ns = [200, 400, 800, 1600]
    times = []
    for n in ns:
        run(x, n).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(3):
            run(x, n).block_until_ready()
        times.append((time.perf_counter() - t0) / 3)
    a = np.polyfit(ns, times, 1)
    pred = np.polyval(a, ns)
    ss_res = np.sum((np.asarray(times) - pred) ** 2)
    ss_tot = np.sum((np.asarray(times) - np.mean(times)) ** 2)
    r2 = 1 - ss_res / ss_tot
    assert r2 > 0.97
    assert a[0] > 0


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S,T,Hq,Hkv,D,kw", [
    (64, 64, 4, 4, 32, dict(causal=True)),
    (100, 100, 4, 2, 32, dict(causal=True)),          # GQA + ragged
    (64, 64, 8, 1, 16, dict(causal=True)),            # MQA
    (64, 64, 4, 2, 32, dict(causal=False)),
    (96, 96, 2, 2, 32, dict(causal=True, window=17)),
    (64, 64, 2, 2, 32, dict(causal=True, softcap=20.0)),
    (32, 128, 2, 2, 32, dict(causal=False)),          # cross-attn shape
])
def test_flash_attention_vs_direct(S, T, Hq, Hkv, D, kw):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(k1, (2, S, Hq, D), jnp.float32)
    k = jax.random.normal(k2, (2, T, Hkv, D), jnp.float32)
    v = jax.random.normal(k3, (2, T, Hkv, D), jnp.float32)
    out = flash_attention(q, k, v, block_q=32, block_k=32, interpret=True,
                          **kw)
    want = ref.attention_direct_ref(q, k, v, **kw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                       (jnp.bfloat16, 2e-2)])
def test_flash_attention_dtypes(dtype, tol):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(k1, (1, 64, 4, 32), jnp.float32).astype(dtype)
    k = jax.random.normal(k2, (1, 64, 2, 32), jnp.float32).astype(dtype)
    v = jax.random.normal(k3, (1, 64, 2, 32), jnp.float32).astype(dtype)
    out = flash_attention(q, k, v, block_q=32, block_k=32, interpret=True)
    want = ref.attention_direct_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@settings(max_examples=8, deadline=None)
@given(S=st.integers(8, 70), Hkv=st.sampled_from([1, 2]),
       G=st.sampled_from([1, 2, 4]), blk=st.sampled_from([16, 32]))
def test_flash_attention_property(S, Hkv, G, blk):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(S), 3)
    q = jax.random.normal(k1, (1, S, Hkv * G, 16), jnp.float32)
    k = jax.random.normal(k2, (1, S, Hkv, 16), jnp.float32)
    v = jax.random.normal(k3, (1, S, Hkv, 16), jnp.float32)
    out = flash_attention(q, k, v, block_q=blk, block_k=blk, interpret=True)
    want = ref.attention_direct_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# rglru_scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,D,bd,ck", [
    (1, 64, 256, 128, 16), (2, 100, 512, 256, 32), (3, 17, 128, 128, 8),
])
def test_rglru_scan_vs_ref(B, S, D, bd, ck):
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    a = jax.nn.sigmoid(jax.random.normal(k1, (B, S, D), jnp.float32))
    u = jax.random.normal(k2, (B, S, D), jnp.float32)
    h = rglru_scan(a, u, block_d=bd, chunk=ck, interpret=True)
    want = ref.rglru_scan_ref(a, u)
    np.testing.assert_allclose(np.asarray(h), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=8, deadline=None)
@given(S=st.integers(2, 50), seed=st.integers(0, 99))
def test_rglru_scan_property(S, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    a = jax.nn.sigmoid(jax.random.normal(k1, (2, S, 128), jnp.float32))
    u = jax.random.normal(k2, (2, S, 128), jnp.float32)
    h = rglru_scan(a, u, block_d=128, chunk=16, interpret=True)
    # sequential truth
    hs = []
    hh = np.zeros((2, 128), np.float32)
    an, un = np.asarray(a), np.asarray(u)
    for t in range(S):
        hh = an[:, t] * hh + un[:, t]
        hs.append(hh.copy())
    want = np.stack(hs, axis=1)
    np.testing.assert_allclose(np.asarray(h), want, rtol=3e-5, atol=3e-5)


def test_ops_wrappers_jit():
    """ops.py wrappers are jit-compiled and pick interpret mode on CPU."""
    x = jax.random.normal(jax.random.PRNGKey(0), (256, 128), jnp.float32)
    y = ops.fma_chain(x, niter=2)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=1e-6)
