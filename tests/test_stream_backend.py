"""numpy↔jax parity for the streaming kernels (ISSUE 5).

The streaming monitor's hot path — ``step_integrate`` and
``stream_ingest`` — has one implementation per execution backend.  The
jax kernels must reproduce the numpy reference on random slabs (raw
kernel outputs) and end-to-end through ``MonitorService`` /
``stream_fleet`` (the offline-parity pin must hold on both backends).
Skipped without jax (e.g. the numpy-only core CI job); the CI jax
matrix job runs this module explicitly.
"""
import numpy as np
import pytest

from repro.core import load as loads
from repro.core.engine_backend import get_backend, has_jax
from repro.core.engine_backend import numpy_backend as nb
from repro.core.stream import MonitorService, replay, stream_fleet
from repro.core.fleet_engine import SensorBank
from repro.core.meter import Workload

needs_jax = pytest.mark.skipif(not has_jax(), reason="jax not installed")

MIXED_NAMES = ["a100"] * 8 + ["v100"] * 4 + ["h100_instant"] * 4


def _random_slab(rng, k=300, u=11):
    dev = np.sort(rng.integers(0, u, k))
    # make groups contiguous ids 0..u'-1
    uniq, seg = np.unique(dev, return_inverse=True)
    uu = len(uniq)
    t = np.empty(k)
    for g in range(uu):
        m = seg == g
        t[m] = np.sort(rng.uniform(0.0, 5.0, m.sum()))
    v = rng.uniform(60.0, 250.0, k)
    # force some exact value repeats so run tracking sees real runs
    rep = rng.random(k) < 0.3
    v[rep] = np.round(v[rep] / 25.0) * 25.0
    first = np.r_[True, seg[1:] != seg[:-1]]
    start_idx = np.flatnonzero(first)
    end_idx = np.r_[start_idx[1:] - 1, k - 1]
    state = dict(
        prev_t=rng.uniform(-1.0, 0.0, uu),
        prev_v=rng.uniform(60.0, 250.0, uu),
        has_prev=rng.random(uu) > 0.3,
        n_changes=rng.integers(0, 4, uu),
        gain=rng.uniform(0.95, 1.05, uu),
        offset=rng.uniform(-3.0, 3.0, uu),
        tshift=np.full(uu, 0.025),
        win_a=np.full(uu, 1.0),
        win_b=np.full(uu, 4.0),
        max_hold=np.where(rng.random(uu) < 0.5, np.inf, 0.5),
        env_lo=np.full(uu, 0.0),
        env_hi=np.full(uu, 240.0),
    )
    state["run_t"] = np.where(state["has_prev"], state["prev_t"],
                              t[start_idx])
    return (t, v, seg, first, start_idx, end_idx, state)


@needs_jax
@pytest.mark.parametrize("trapezoid", [False, True])
def test_stream_ingest_kernel_parity(trapezoid):
    jb = get_backend("jax")
    rng = np.random.default_rng(42)
    for trial in range(3):
        t, v, seg, first, start_idx, end_idx, st = _random_slab(rng)
        args = (t, v, seg, first, start_idx, end_idx,
                st["prev_t"], st["prev_v"], st["has_prev"], st["run_t"],
                st["n_changes"], st["gain"], st["offset"], st["tshift"],
                st["win_a"], st["win_b"], st["max_hold"], st["env_lo"],
                st["env_hi"], trapezoid)
        outn = nb.stream_ingest(*args)
        outj = jb.stream_ingest(*args)
        assert len(outn) == len(outj)
        for i, (a, b) in enumerate(zip(outn, outj)):
            np.testing.assert_allclose(
                np.asarray(a, dtype=np.float64),
                np.asarray(b, dtype=np.float64),
                rtol=1e-12, atol=1e-12,
                err_msg=f"output {i} (trial {trial})")


@needs_jax
@pytest.mark.parametrize("trapezoid", [False, True])
def test_step_integrate_kernel_parity(trapezoid):
    jb = get_backend("jax")
    rng = np.random.default_rng(7)
    n, m = 13, 50
    ts = np.sort(rng.uniform(0.0, 10.0, (n, m)), axis=1)
    nv = rng.integers(1, m, n)
    for i in range(n):
        ts[i, nv[i]:] = np.inf
    vals = rng.uniform(50.0, 250.0, (n, m))
    t0 = rng.uniform(-1.0, 5.0, n)
    t1 = t0 + rng.uniform(0.0, 8.0, n)
    outn = nb.step_integrate(ts, vals, t0, t1, trapezoid=trapezoid)
    outj = jb.step_integrate(ts, vals, t0, t1, trapezoid=trapezoid)
    np.testing.assert_allclose(outj, outn, rtol=1e-12, atol=1e-12)


@needs_jax
def test_monitor_end_to_end_backend_parity():
    """Same fleet replayed through a numpy-kernel and a jax-kernel
    monitor: identical ingestion decisions, energies within float
    accumulation order, and the offline parity pin holds on jax."""
    n = len(MIXED_NAMES)
    ws = loads.mixed_fleet_workloads(n, seed=7, as_bank=True)
    rn = stream_fleet(n, profile=MIXED_NAMES, workload=ws, seed=0,
                      backend="numpy", compare=True)
    rj = stream_fleet(n, profile=MIXED_NAMES, workload=ws, seed=0,
                      backend="jax", compare=True)
    np.testing.assert_allclose(rj.naive_stream_j, rn.naive_stream_j,
                               rtol=1e-11)
    np.testing.assert_allclose(rj.corrected_stream_j,
                               rn.corrected_stream_j, rtol=1e-11)
    np.testing.assert_allclose(rj.naive_stream_j, rj.naive_offline_j,
                               rtol=1e-11)
    np.testing.assert_allclose(rj.corrected_stream_j,
                               rj.corrected_offline_j, rtol=1e-11)
    assert rn.monitor.counters == rj.monitor.counters


@needs_jax
def test_monitor_jax_messy_stream_matches_numpy():
    bank = SensorBank.from_catalog(["a100"] * 5, seeds=np.arange(5))
    wl = Workload("w", loads.multi_phase_workload([(0.13, 215.0),
                                                   (0.07, 165.0)]))
    tl = wl.timeline.shift(0.3)
    bank.attach(tl, t_end=tl.t_end + 1.0)
    mons = {}
    for be in ("numpy", "jax"):
        mon = MonitorService(5, backend=be)
        replay(bank, mon, 0.0, 1.0, shuffle=True, dup_fraction=0.2,
               delay_fraction=0.1, seed=5)
        mons[be] = mon
    assert mons["numpy"].counters == mons["jax"].counters
    np.testing.assert_allclose(mons["jax"].state.energy_j,
                               mons["numpy"].state.energy_j, rtol=1e-12)
    np.testing.assert_allclose(mons["jax"].update_period_s(),
                               mons["numpy"].update_period_s(),
                               rtol=1e-9, equal_nan=True)
