"""numpy↔accelerated parity for the streaming kernels (ISSUE 5/6).

The streaming monitor's hot path — ``step_integrate``,
``stream_ingest`` and the rectangular ``stream_ingest_grid`` — has one
implementation per execution backend.  Every accelerated tier (jax and
pallas, via the shared ``accel_backend`` fixture) must reproduce the
numpy reference on random slabs (raw kernel outputs) and end-to-end
through ``MonitorService`` / ``stream_fleet`` (the offline-parity pin
must hold on every backend).  Skipped without jax (e.g. the numpy-only
core CI job); the CI accelerated jobs run this module explicitly.
"""
import numpy as np
import pytest

from repro.core import load as loads
from repro.core.engine_backend import get_backend, has_jax
from repro.core.engine_backend import numpy_backend as nb
from repro.core.stream import MonitorService, replay, stream_fleet
from repro.core.fleet_engine import SensorBank
from repro.core.meter import Workload

needs_jax = pytest.mark.skipif(not has_jax(), reason="jax not installed")

MIXED_NAMES = ["a100"] * 8 + ["v100"] * 4 + ["h100_instant"] * 4


def _random_slab(rng, k=300, u=11):
    dev = np.sort(rng.integers(0, u, k))
    # make groups contiguous ids 0..u'-1
    uniq, seg = np.unique(dev, return_inverse=True)
    uu = len(uniq)
    t = np.empty(k)
    for g in range(uu):
        m = seg == g
        t[m] = np.sort(rng.uniform(0.0, 5.0, m.sum()))
    v = rng.uniform(60.0, 250.0, k)
    # force some exact value repeats so run tracking sees real runs
    rep = rng.random(k) < 0.3
    v[rep] = np.round(v[rep] / 25.0) * 25.0
    first = np.r_[True, seg[1:] != seg[:-1]]
    start_idx = np.flatnonzero(first)
    end_idx = np.r_[start_idx[1:] - 1, k - 1]
    state = dict(
        prev_t=rng.uniform(-1.0, 0.0, uu),
        prev_v=rng.uniform(60.0, 250.0, uu),
        has_prev=rng.random(uu) > 0.3,
        n_changes=rng.integers(0, 4, uu),
        gain=rng.uniform(0.95, 1.05, uu),
        offset=rng.uniform(-3.0, 3.0, uu),
        tshift=np.full(uu, 0.025),
        win_a=np.full(uu, 1.0),
        win_b=np.full(uu, 4.0),
        max_hold=np.where(rng.random(uu) < 0.5, np.inf, 0.5),
        env_lo=np.full(uu, 0.0),
        env_hi=np.full(uu, 240.0),
    )
    state["run_t"] = np.where(state["has_prev"], state["prev_t"],
                              t[start_idx])
    return (t, v, seg, first, start_idx, end_idx, state)


@pytest.mark.parametrize("trapezoid", [False, True])
def test_stream_ingest_kernel_parity(accel_backend, trapezoid):
    jb = get_backend(accel_backend)
    rng = np.random.default_rng(42)
    for trial in range(3):
        t, v, seg, first, start_idx, end_idx, st = _random_slab(rng)
        args = (t, v, seg, first, start_idx, end_idx,
                st["prev_t"], st["prev_v"], st["has_prev"], st["run_t"],
                st["n_changes"], st["gain"], st["offset"], st["tshift"],
                st["win_a"], st["win_b"], st["max_hold"], st["env_lo"],
                st["env_hi"], trapezoid)
        outn = nb.stream_ingest(*args)
        outj = jb.stream_ingest(*args)
        assert len(outn) == len(outj)
        for i, (a, b) in enumerate(zip(outn, outj)):
            np.testing.assert_allclose(
                np.asarray(a, dtype=np.float64),
                np.asarray(b, dtype=np.float64),
                rtol=1e-12, atol=1e-12,
                err_msg=f"output {i} (trial {trial})")


@pytest.mark.parametrize("trapezoid", [False, True])
def test_step_integrate_kernel_parity(accel_backend, trapezoid):
    jb = get_backend(accel_backend)
    rng = np.random.default_rng(7)
    n, m = 13, 50
    ts = np.sort(rng.uniform(0.0, 10.0, (n, m)), axis=1)
    nv = rng.integers(1, m, n)
    for i in range(n):
        ts[i, nv[i]:] = np.inf
    vals = rng.uniform(50.0, 250.0, (n, m))
    t0 = rng.uniform(-1.0, 5.0, n)
    t1 = t0 + rng.uniform(0.0, 8.0, n)
    outn = nb.step_integrate(ts, vals, t0, t1, trapezoid=trapezoid)
    outj = jb.step_integrate(ts, vals, t0, t1, trapezoid=trapezoid)
    np.testing.assert_allclose(outj, outn, rtol=1e-12, atol=1e-12)


def test_monitor_end_to_end_backend_parity(accel_backend):
    """Same fleet replayed through a numpy-kernel and an accelerated
    monitor: identical ingestion decisions, energies within float
    accumulation order, and the offline parity pin holds on the
    accelerated tier."""
    n = len(MIXED_NAMES)
    ws = loads.mixed_fleet_workloads(n, seed=7, as_bank=True)
    rn = stream_fleet(n, profile=MIXED_NAMES, workload=ws, seed=0,
                      backend="numpy", compare=True)
    rj = stream_fleet(n, profile=MIXED_NAMES, workload=ws, seed=0,
                      backend=accel_backend, compare=True)
    np.testing.assert_allclose(rj.naive_stream_j, rn.naive_stream_j,
                               rtol=1e-11)
    np.testing.assert_allclose(rj.corrected_stream_j,
                               rn.corrected_stream_j, rtol=1e-11)
    np.testing.assert_allclose(rj.naive_stream_j, rj.naive_offline_j,
                               rtol=1e-11)
    np.testing.assert_allclose(rj.corrected_stream_j,
                               rj.corrected_offline_j, rtol=1e-11)
    assert rn.monitor.counters == rj.monitor.counters


def test_monitor_messy_stream_matches_numpy(accel_backend):
    bank = SensorBank.from_catalog(["a100"] * 5, seeds=np.arange(5))
    wl = Workload("w", loads.multi_phase_workload([(0.13, 215.0),
                                                   (0.07, 165.0)]))
    tl = wl.timeline.shift(0.3)
    bank.attach(tl, t_end=tl.t_end + 1.0)
    mons = {}
    for be in ("numpy", accel_backend):
        mon = MonitorService(5, backend=be)
        replay(bank, mon, 0.0, 1.0, shuffle=True, dup_fraction=0.2,
               delay_fraction=0.1, seed=5)
        mons[be] = mon
    acc = mons[accel_backend]
    assert mons["numpy"].counters == acc.counters
    np.testing.assert_allclose(acc.state.energy_j,
                               mons["numpy"].state.energy_j, rtol=1e-12)
    np.testing.assert_allclose(acc.update_period_s(),
                               mons["numpy"].update_period_s(),
                               rtol=1e-9, equal_nan=True)


@pytest.mark.parametrize("trapezoid", [False, True])
def test_stream_ingest_grid_kernel_parity(accel_backend, trapezoid):
    """The rectangular fast-path kernel matches numpy on random [D, M]
    slabs, including the empty-slab passthrough."""
    jb = get_backend(accel_backend)
    rng = np.random.default_rng(11)
    for trial in range(3):
        d = int(rng.integers(1, 30))
        m = int(rng.integers(1, 40))
        ts = np.cumsum(rng.uniform(0.001, 0.1, m)) + 2.0
        v = rng.uniform(60.0, 250.0, (d, m))
        rep = rng.random((d, m)) < 0.4
        v[rep] = np.round(v[rep] / 25.0) * 25.0
        has_prev = rng.random(d) > 0.3
        prev_t = rng.uniform(0.0, 2.0, d)
        args = (ts, v, prev_t, rng.uniform(60.0, 250.0, d), has_prev,
                np.where(has_prev, prev_t, ts[0]),
                rng.integers(0, 4, d), rng.uniform(0.95, 1.05, d),
                rng.uniform(-3.0, 3.0, d), np.full(d, 0.025),
                np.full(d, 2.2), np.full(d, 3.4),
                np.where(rng.random(d) < 0.5, np.inf, 0.05),
                np.full(d, 0.0), np.full(d, 240.0), trapezoid)
        outn = nb.stream_ingest_grid(*args)
        outj = jb.stream_ingest_grid(*args)
        assert len(outn) == len(outj) == 16
        for i, (a, b) in enumerate(zip(outn, outj)):
            np.testing.assert_allclose(
                np.asarray(a, dtype=np.float64),
                np.asarray(b, dtype=np.float64),
                rtol=1e-12, atol=1e-12,
                err_msg=f"output {i} (trial {trial})")
    empty = (np.zeros(0), np.zeros((3, 0)), np.zeros(3), np.ones(3),
             np.ones(3, dtype=bool), np.zeros(3),
             np.zeros(3, dtype=np.int64), np.ones(3), np.zeros(3),
             np.zeros(3), np.zeros(3), np.ones(3), np.full(3, np.inf),
             np.zeros(3), np.full(3, 240.0), trapezoid)
    for a, b in zip(nb.stream_ingest_grid(*empty),
                    jb.stream_ingest_grid(*empty)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_monitor_grid_path_matches_flat_path(accel_backend):
    """A clean replay through ``ingest_grid`` reproduces the flattened
    ``ingest`` path: identical counters, ring contents, run tracking
    and per-label moments (the fast path changes the route, never the
    answer)."""
    bank = SensorBank.from_catalog(["a100"] * 4 + ["v100"] * 3,
                                   seeds=np.arange(7))
    wl = Workload("w", loads.multi_phase_workload([(0.13, 215.0),
                                                   (0.07, 165.0)]))
    tl = wl.timeline.shift(0.3)
    bank.attach(tl, t_end=tl.t_end + 1.0)
    mons = {}
    for grid in (False, True):
        mon = MonitorService(7, backend=accel_backend)
        replay(bank, mon, 0.0, 1.0, grid=grid)
        mons[grid] = mon
    assert mons[True].counters == mons[False].counters
    np.testing.assert_allclose(mons[True].state.energy_j,
                               mons[False].state.energy_j, rtol=1e-11)
    np.testing.assert_allclose(mons[True].state.energy_corr_j,
                               mons[False].state.energy_corr_j,
                               rtol=1e-11)
    np.testing.assert_array_equal(mons[True].state.n_changes,
                                  mons[False].state.n_changes)
    np.testing.assert_array_equal(mons[True].state.run_t,
                                  mons[False].state.run_t)
    for arr in ("t", "v", "e_raw", "e_corr"):
        np.testing.assert_allclose(getattr(mons[True].ring, arr),
                                   getattr(mons[False].ring, arr),
                                   rtol=1e-11, err_msg=f"ring.{arr}")
    np.testing.assert_allclose(mons[True].update_period_s(),
                               mons[False].update_period_s(),
                               rtol=1e-12, equal_nan=True)
    for lbl, sf in mons[False].reading_stats().items():
        sg = mons[True].reading_stats()[lbl]
        for key, val in sf.items():
            np.testing.assert_allclose(sg[key], val, rtol=1e-9,
                                       err_msg=f"{lbl}.{key}")


def test_monitor_grid_path_falls_back_on_dirty_slabs(accel_backend):
    """Slabs violating the rectangular contract (non-finite readings,
    stale times) reroute through the general ingest path with its drop
    accounting intact."""
    mon = MonitorService(3, backend=accel_backend)
    ts = np.array([0.1, 0.2, 0.3])
    vals = np.full((3, 3), 100.0)
    vals[1, 1] = np.nan
    rep = mon.ingest_grid(np.arange(3), ts, vals)
    assert rep.invalid == 1 and rep.accepted == 8
    # a repeat of the same slab is all duplicates/late via the fallback
    # (the nan hole at t=0.2 is now behind its device's newest sample)
    rep2 = mon.ingest_grid(np.arange(3), ts, np.full((3, 3), 100.0))
    assert rep2.accepted == 0
    assert rep2.duplicates == 3 and rep2.late == 6
    assert mon.counters["accepted"] == 8


def test_jax_ingest_run_tracking_carries_state_across_slabs():
    """The O(slab) run tracking (carried ``run_t`` + in-slab ordinal
    arithmetic, replacing the full-ring cummax) is equivalent to the
    numpy reference across slab boundaries: runs spanning two slabs
    still record their full duration."""
    if not has_jax():
        pytest.skip("jax not installed")
    rng = np.random.default_rng(3)
    mons = {be: MonitorService(4, backend=be, ring_slots=4)
            for be in ("numpy", "jax")}
    t_base = 0.0
    for _ in range(6):      # several slabs; runs span the boundaries
        k = int(rng.integers(3, 9))
        dev = np.repeat(np.arange(4), k)
        t = np.tile(t_base + np.cumsum(rng.uniform(0.01, 0.1, k)), 4)
        v = np.round(rng.uniform(60.0, 250.0, 4 * k) / 50.0) * 50.0
        for mon in mons.values():
            mon.ingest(dev, t, v)
        t_base = float(t.max())
    np.testing.assert_array_equal(mons["jax"].state.run_t,
                                  mons["numpy"].state.run_t)
    np.testing.assert_array_equal(mons["jax"].state.n_changes,
                                  mons["numpy"].state.n_changes)
    np.testing.assert_allclose(mons["jax"].update_period_s(),
                               mons["numpy"].update_period_s(),
                               rtol=1e-12, equal_nan=True)
