"""Encoder-decoder (seamless) specific tests: decode-vs-forward
consistency through the cross-attention cache."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.models import api, encdec


def test_encdec_decode_matches_forward():
    cfg = get_config("seamless-m4t-medium", reduced=True).replace(
        param_dtype="float32")
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    B, Ss, St = 2, 12, 10
    src = jax.random.normal(jax.random.PRNGKey(1), (B, Ss, cfg.d_model),
                            jnp.float32)
    tgt = jax.random.randint(jax.random.PRNGKey(2), (B, St), 0, cfg.vocab)
    logits_full, _ = encdec.forward(params, cfg,
                                    {"src_embeds": src, "tokens": tgt})
    cache = encdec.init_cache_from_encoder(params, cfg, src, max_tgt=St)
    outs = []
    for t in range(St):
        lg, cache = encdec.decode_step(
            params, cfg, cache,
            {"tokens": tgt[:, t:t + 1], "pos": jnp.asarray([t], jnp.int32)})
        outs.append(lg[:, 0])
    logits_dec = jnp.stack(outs, axis=1)
    err = float(jnp.max(jnp.abs(logits_dec - logits_full)))
    assert err < 1e-3, err


def test_encdec_encoder_is_bidirectional():
    """Flipping a late source frame changes logits at EARLY target
    positions (cross-attention sees the whole encoded source)."""
    cfg = get_config("seamless-m4t-medium", reduced=True).replace(
        param_dtype="float32")
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    B, Ss, St = 1, 8, 4
    src = jax.random.normal(jax.random.PRNGKey(1), (B, Ss, cfg.d_model))
    tgt = jax.random.randint(jax.random.PRNGKey(2), (B, St), 0, cfg.vocab)
    lg1, _ = encdec.forward(params, cfg, {"src_embeds": src, "tokens": tgt})
    src2 = src.at[:, -1].set(-src[:, -1])
    lg2, _ = encdec.forward(params, cfg, {"src_embeds": src2, "tokens": tgt})
    assert float(jnp.max(jnp.abs(lg1[:, 0] - lg2[:, 0]))) > 1e-6


def test_encdec_causal_decoder():
    """Changing a LATER target token must not affect earlier logits."""
    cfg = get_config("seamless-m4t-medium", reduced=True).replace(
        param_dtype="float32")
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    B, Ss, St = 1, 8, 6
    src = jax.random.normal(jax.random.PRNGKey(1), (B, Ss, cfg.d_model))
    tgt = jax.random.randint(jax.random.PRNGKey(2), (B, St), 0, cfg.vocab)
    lg1, _ = encdec.forward(params, cfg, {"src_embeds": src, "tokens": tgt})
    tgt2 = tgt.at[:, -1].set((tgt[:, -1] + 1) % cfg.vocab)
    lg2, _ = encdec.forward(params, cfg, {"src_embeds": src, "tokens": tgt2})
    np.testing.assert_allclose(np.asarray(lg1[:, :-1]),
                               np.asarray(lg2[:, :-1]), atol=1e-5)
