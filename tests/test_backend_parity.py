"""Cross-backend property harness for the streaming hot loops (ISSUE 6).

Every accelerated kernel tier (jax and pallas) must agree with the
numpy reference not just on well-behaved slabs but on the adversarial
inputs a real collection pipeline produces: out-of-order arrival,
duplicated samples, sampling gaps, devices that never report,
non-finite readings, single-sample series and zero-length query
windows.  Two layers of coverage:

* **Deterministic adversarial streams** — hand-built worst-case slab
  sequences pushed through :class:`MonitorService` on every backend
  (always run; this is the tier-1 floor).
* **Property tests** — `hypothesis`-driven random slab/window/timeline
  generation over the raw kernels ``stream_ingest``,
  ``stream_ingest_grid``, ``step_integrate`` and ``log_filter``.
  Imported through the ``_hyp`` shim so environments without
  `hypothesis` skip these instead of failing collection.

Backends are looped *inside* the property tests (a function-scoped
fixture cannot feed ``@given``); the deterministic tests use the shared
``accel_backend`` fixture for per-tier reporting.
"""
import numpy as np
import pytest

from _hyp import HAVE_HYPOTHESIS, given, settings, st
from repro.core import load as loads
from repro.core.engine_backend import available_backends, get_backend
from repro.core.engine_backend import numpy_backend as nb
from repro.core.ground_truth import TimelineBank
from repro.core.stream import MonitorService


def _accel_backends():
    return [b for b in available_backends() if b != "numpy"]


needs_accel = pytest.mark.skipif(
    not _accel_backends(),
    reason="no accelerated backend available (jax not installed)")

# run-tracking / counter outputs must be bitwise identical; cumulative
# float outputs only up to accumulation order
KERNEL_RTOL = 1e-12
KERNEL_ATOL = 1e-12


# ---------------------------------------------------------------------------
# slab generators
# ---------------------------------------------------------------------------
def _valid_ingest_slab(rng, k, u, *, single_sample=False):
    """A contract-respecting ``stream_ingest`` slab: grouped samples,
    strictly increasing times per group, finite readings."""
    if single_sample:
        k = u
        seg = np.arange(u)
    else:
        dev = np.sort(rng.integers(0, u, k))
        _, seg = np.unique(dev, return_inverse=True)
    uu = int(seg.max()) + 1
    t = np.empty(k)
    for g in range(uu):
        m = seg == g
        t[m] = np.cumsum(rng.uniform(1e-4, 0.2, m.sum()))
    v = rng.uniform(60.0, 250.0, k)
    rep = rng.random(k) < 0.35            # exact repeats → real runs
    v[rep] = np.round(v[rep] / 25.0) * 25.0
    first = np.r_[True, seg[1:] != seg[:-1]]
    start_idx = np.flatnonzero(first)
    end_idx = np.r_[start_idx[1:] - 1, k - 1]
    has_prev = rng.random(uu) > 0.3
    prev_t = rng.uniform(-1.0, 0.0, uu)
    state = dict(
        prev_t=prev_t,
        prev_v=np.where(rng.random(uu) < 0.3,
                        np.round(rng.uniform(60.0, 250.0, uu) / 25.0) * 25.0,
                        rng.uniform(60.0, 250.0, uu)),
        has_prev=has_prev,
        run_t=np.where(has_prev, prev_t, t[start_idx]),
        n_changes=rng.integers(0, 4, uu),
        gain=rng.uniform(0.95, 1.05, uu),
        offset=rng.uniform(-3.0, 3.0, uu),
        tshift=rng.uniform(0.0, 0.05, uu),
        win_a=rng.uniform(0.0, 2.0, uu),
        win_b=rng.uniform(2.0, 5.0, uu),
        max_hold=np.where(rng.random(uu) < 0.5, np.inf, 0.5),
        env_lo=np.where(rng.random(uu) < 0.5, -np.inf, 70.0),
        env_hi=np.where(rng.random(uu) < 0.5, np.inf, 240.0),
    )
    # exercise zero-length and inverted windows too
    degen = rng.random(uu) < 0.2
    state["win_b"] = np.where(degen, state["win_a"], state["win_b"])
    return t, v, seg, first, start_idx, end_idx, state


def _ingest_args(slab, trapezoid):
    t, v, seg, first, start_idx, end_idx, s = slab
    return (t, v, seg, first, start_idx, end_idx,
            s["prev_t"], s["prev_v"], s["has_prev"], s["run_t"],
            s["n_changes"], s["gain"], s["offset"], s["tshift"],
            s["win_a"], s["win_b"], s["max_hold"], s["env_lo"],
            s["env_hi"], trapezoid)


def _assert_tuples_close(outn, outj, label):
    assert len(outn) == len(outj)
    for i, (a, b) in enumerate(zip(outn, outj)):
        np.testing.assert_allclose(
            np.asarray(a, dtype=np.float64),
            np.asarray(b, dtype=np.float64),
            rtol=KERNEL_RTOL, atol=KERNEL_ATOL,
            err_msg=f"{label}: output {i}")


# ---------------------------------------------------------------------------
# deterministic adversarial streams through MonitorService
# ---------------------------------------------------------------------------
def _adversarial_stream(case, rng):
    """Build a worst-case slab sequence for a 6-device monitor.

    Returns a list of ``(dev, t, v)`` triples fed to ``ingest`` in
    order.  The monitor must make identical accept/duplicate/late/
    invalid decisions on every backend.
    """
    n = 6
    base_t = np.arange(1, 9) * 0.1

    def slab(devs, ts, vs):
        return (np.asarray(devs, dtype=np.int64),
                np.asarray(ts, dtype=np.float64),
                np.asarray(vs, dtype=np.float64))

    if case == "out_of_order":
        # shuffled within a slab: monitor re-sorts, nothing dropped
        dev = np.repeat(np.arange(4), len(base_t))
        t = np.tile(base_t, 4)
        v = 100.0 + 10.0 * dev + np.round(t * 10)
        perm = rng.permutation(len(dev))
        return [slab(dev[perm], t[perm], v[perm])]
    if case == "duplicates":
        # exact (dev, t) re-sends inside a slab and across slabs
        s1 = slab([0, 0, 0, 1, 1], [0.1, 0.2, 0.2, 0.1, 0.3],
                  [100.0, 110.0, 110.0, 90.0, 95.0])
        s2 = slab([0, 1, 1], [0.2, 0.3, 0.4], [110.0, 95.0, 97.0])
        return [s1, s2]
    if case == "late_cross_slab":
        # timestamps that regress across slab boundaries arrive late
        s1 = slab([0, 0, 1], [0.5, 0.6, 0.5], [100.0, 101.0, 90.0])
        s2 = slab([0, 0, 1], [0.3, 0.7, 0.2], [99.0, 102.0, 80.0])
        return [s1, s2]
    if case == "gaps_and_empty_devices":
        # devices 4 and 5 never report; device 2 has a long silent gap
        s1 = slab([0, 1, 2], [0.1, 0.1, 0.1], [100.0, 110.0, 120.0])
        s2 = slab([0, 1], [0.2, 0.2], [100.0, 111.0])
        s3 = slab([0, 1, 2], [0.3, 0.3, 5.0], [101.0, 111.0, 125.0])
        return [s1, s2, s3]
    if case == "non_finite":
        # nan/inf readings and timestamps must be rejected identically
        s1 = slab([0, 1, 2, 3], [0.1, 0.1, 0.1, 0.1],
                  [100.0, np.nan, np.inf, -np.inf])
        s2 = slab([0, 1, 2], [np.nan, 0.2, np.inf], [101.0, 110.0, 120.0])
        s3 = slab([0, 1], [0.3, 0.3], [102.0, 111.0])
        return [s1, s2, s3]
    if case == "single_sample_series":
        # one isolated sample per device — no deltas anywhere
        return [slab([d], [0.1 + 0.01 * d], [100.0 + d]) for d in range(n)]
    if case == "chaos":
        # everything at once, three slabs of it
        out = []
        for _ in range(3):
            k = 40
            dev = rng.integers(0, n, k)
            t = rng.uniform(0.0, 2.0, k)
            v = rng.uniform(60.0, 250.0, k)
            v[rng.random(k) < 0.1] = np.nan
            t[rng.random(k) < 0.05] = np.inf
            dup = rng.random(k) < 0.2
            out.append(slab(np.r_[dev, dev[dup]], np.r_[t, t[dup]],
                            np.r_[v, v[dup]]))
        return out
    raise AssertionError(case)


ADVERSARIAL_CASES = ["out_of_order", "duplicates", "late_cross_slab",
                     "gaps_and_empty_devices", "non_finite",
                     "single_sample_series", "chaos"]


def _monitor(backend):
    return MonitorService(6, backend=backend, max_hold_s=0.5,
                          envelope_w=(0.0, 300.0), ring_slots=4)


def _assert_monitors_match(mn, mj, label):
    assert mn.counters == mj.counters, label
    sn, sj = mn.state, mj.state
    np.testing.assert_array_equal(sj.has, sn.has, err_msg=label)
    np.testing.assert_array_equal(sj.n_samples, sn.n_samples,
                                  err_msg=label)
    np.testing.assert_array_equal(sj.n_changes, sn.n_changes,
                                  err_msg=label)
    np.testing.assert_array_equal(sj.n_out, sn.n_out, err_msg=label)
    for fld in ("last_t", "last_v", "first_t", "run_t"):
        np.testing.assert_allclose(getattr(sj, fld), getattr(sn, fld),
                                   rtol=0, atol=0, err_msg=label)
    for fld in ("energy_j", "energy_corr_j", "win_j", "win_corr_j"):
        np.testing.assert_allclose(getattr(sj, fld), getattr(sn, fld),
                                   rtol=1e-12, atol=1e-12, err_msg=label)
    np.testing.assert_allclose(mj.update_period_s(), mn.update_period_s(),
                               rtol=1e-9, equal_nan=True, err_msg=label)


@pytest.mark.parametrize("case", ADVERSARIAL_CASES)
def test_monitor_adversarial_stream_parity(accel_backend, case):
    rng_n = np.random.default_rng(123)
    rng_j = np.random.default_rng(123)
    mn, mj = _monitor("numpy"), _monitor(accel_backend)
    mn.set_windows(np.full(6, 0.15), np.full(6, 0.45))
    mj.set_windows(np.full(6, 0.15), np.full(6, 0.45))
    for (dn, tn, vn), (dj, tj, vj) in zip(_adversarial_stream(case, rng_n),
                                          _adversarial_stream(case, rng_j)):
        rn = mn.ingest(dn, tn, vn)
        rj = mj.ingest(dj, tj, vj)
        assert rn == rj, f"{case}: ingest reports differ"
    _assert_monitors_match(mn, mj, case)


def test_step_integrate_zero_length_and_empty_rows(accel_backend):
    """Zero-length windows, inverted windows, windows fully outside
    coverage, and rows with zero valid samples all integrate to 0 —
    identically on every backend."""
    jb = get_backend(accel_backend)
    ts = np.array([[0.1, 0.2, 0.3, np.inf],
                   [np.inf, np.inf, np.inf, np.inf],   # empty row
                   [1.0, np.inf, np.inf, np.inf],      # single sample
                   [0.1, 0.2, 0.3, 0.4]])
    vals = np.array([[100.0, 110.0, 120.0, 0.0],
                     [0.0, 0.0, 0.0, 0.0],
                     [50.0, 0.0, 0.0, 0.0],
                     [100.0, 100.0, 100.0, 100.0]])
    t0 = np.array([0.2, 0.1, 1.0, 9.0])   # zero-length / empty / point /
    t1 = np.array([0.2, 0.1, 1.0, 9.5])   # outside coverage
    for trapezoid in (False, True):
        outn = nb.step_integrate(ts, vals, t0, t1, trapezoid=trapezoid)
        outj = jb.step_integrate(ts, vals, t0, t1, trapezoid=trapezoid)
        np.testing.assert_allclose(np.asarray(outj), outn,
                                   rtol=KERNEL_RTOL, atol=KERNEL_ATOL)
        np.testing.assert_allclose(outn, 0.0, atol=1e-15)


def test_stream_ingest_single_sample_series(accel_backend):
    """Every segment holds exactly one sample (the degenerate slab the
    blocked kernels must not mis-seam)."""
    jb = get_backend(accel_backend)
    rng = np.random.default_rng(3)
    for trapezoid in (False, True):
        slab = _valid_ingest_slab(rng, 8, 8, single_sample=True)
        args = _ingest_args(slab, trapezoid)
        _assert_tuples_close(nb.stream_ingest(*args),
                             jb.stream_ingest(*args),
                             f"single-sample trapezoid={trapezoid}")


# ---------------------------------------------------------------------------
# hypothesis property tests (skipped when hypothesis is absent)
# ---------------------------------------------------------------------------
@needs_accel
@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**32 - 1), k=st.integers(1, 160),
       u=st.integers(1, 10), trapezoid=st.booleans())
def test_property_stream_ingest_parity(seed, k, u, trapezoid):
    rng = np.random.default_rng(seed)
    slab = _valid_ingest_slab(rng, k, u)
    args = _ingest_args(slab, trapezoid)
    outn = nb.stream_ingest(*args)
    for be in _accel_backends():
        _assert_tuples_close(outn, get_backend(be).stream_ingest(*args),
                             f"{be} seed={seed}")


@needs_accel
@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**32 - 1), d=st.integers(1, 24),
       m=st.integers(1, 32), trapezoid=st.booleans())
def test_property_stream_ingest_grid_parity(seed, d, m, trapezoid):
    rng = np.random.default_rng(seed)
    ts = np.cumsum(rng.uniform(1e-4, 0.1, m)) + 2.0
    v = rng.uniform(60.0, 250.0, (d, m))
    rep = rng.random((d, m)) < 0.4
    v[rep] = np.round(v[rep] / 25.0) * 25.0
    has_prev = rng.random(d) > 0.3
    prev_t = rng.uniform(0.0, 2.0, d)
    win_a = rng.uniform(1.5, 3.0, d)
    win_b = np.where(rng.random(d) < 0.2, win_a,      # zero-length windows
                     win_a + rng.uniform(0.0, 2.0, d))
    args = (ts, v, prev_t, rng.uniform(60.0, 250.0, d), has_prev,
            np.where(has_prev, prev_t, ts[0]), rng.integers(0, 4, d),
            rng.uniform(0.95, 1.05, d), rng.uniform(-3.0, 3.0, d),
            rng.uniform(0.0, 0.05, d), win_a, win_b,
            np.where(rng.random(d) < 0.5, np.inf, 0.05),
            np.full(d, 0.0), np.full(d, 240.0), trapezoid)
    outn = nb.stream_ingest_grid(*args)
    for be in _accel_backends():
        outj = get_backend(be).stream_ingest_grid(*args)
        _assert_tuples_close(outn, outj, f"{be} seed={seed}")


@needs_accel
@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**32 - 1), n=st.integers(1, 12),
       m=st.integers(1, 24), trapezoid=st.booleans())
def test_property_step_integrate_parity(seed, n, m, trapezoid):
    rng = np.random.default_rng(seed)
    ts = np.sort(rng.uniform(0.0, 10.0, (n, m)), axis=1)
    nv = rng.integers(0, m + 1, n)        # rows may be fully empty
    for i in range(n):
        ts[i, nv[i]:] = np.inf
    vals = rng.uniform(50.0, 250.0, (n, m))
    t0 = rng.uniform(-1.0, 5.0, n)
    span = rng.uniform(0.0, 8.0, n)
    span[rng.random(n) < 0.25] = 0.0      # zero-length windows
    t1 = t0 + span
    outn = nb.step_integrate(ts, vals, t0, t1, trapezoid=trapezoid)
    for be in _accel_backends():
        outj = get_backend(be).step_integrate(ts, vals, t0, t1,
                                              trapezoid=trapezoid)
        np.testing.assert_allclose(np.asarray(outj), outn,
                                   rtol=KERNEL_RTOL, atol=KERNEL_ATOL,
                                   err_msg=f"{be} seed={seed}")


@needs_accel
@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**32 - 1), g=st.integers(1, 8),
       q=st.integers(1, 20))
def test_property_log_filter_parity(seed, g, q):
    rng = np.random.default_rng(seed)
    tls = [loads.square_wave(float(rng.uniform(0.05, 0.4)),
                             int(rng.integers(1, 10)),
                             float(rng.uniform(150, 250)),
                             float(rng.uniform(60, 120)),
                             seed=int(rng.integers(0, 1000)))
           for _ in range(g)]
    tl = TimelineBank.from_timelines(tls).arrays
    ticks = np.sort(rng.uniform(-0.5, 4.0, (g, q)), axis=1)
    tau = rng.uniform(0.05, 1.0, g)
    ref = nb.log_filter(tl, ticks, tau)
    for be in _accel_backends():
        got = get_backend(be).log_filter(tl, ticks, tau)
        # associative scans reorder the recurrence's float ops
        np.testing.assert_allclose(np.asarray(got), ref,
                                   rtol=1e-9, atol=1e-9,
                                   err_msg=f"{be} seed={seed}")


@needs_accel
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**32 - 1))
def test_property_monitor_chaotic_stream_parity(seed):
    """Random lossy streams — shuffles, duplicates, regressions,
    non-finite readings — yield identical monitor state everywhere."""
    rng = np.random.default_rng(seed)
    slabs = []
    for _ in range(3):
        k = int(rng.integers(1, 60))
        dev = rng.integers(0, 6, k)
        t = rng.uniform(0.0, 2.0, k)
        v = rng.uniform(40.0, 320.0, k)
        v[rng.random(k) < 0.08] = np.nan
        t[rng.random(k) < 0.04] = np.inf
        slabs.append((dev, t, v))
    mons = []
    for be in ["numpy"] + _accel_backends():
        mon = _monitor(be)
        mon.set_windows(np.full(6, 0.2), np.full(6, 1.4))
        for dev, t, v in slabs:
            mon.ingest(dev.copy(), t.copy(), v.copy())
        mons.append((be, mon))
    ref = mons[0][1]
    for be, mon in mons[1:]:
        _assert_monitors_match(ref, mon, f"{be} seed={seed}")


def test_hypothesis_shim_status():
    """Record (not assert) shim mode so CI logs show which layer ran."""
    assert HAVE_HYPOTHESIS in (True, False)
