"""Array-native scenario synthesis: the ISSUE 4 tentpole pins.

Every vectorized scenario sampler must be *bitwise* row-for-row
equivalent to the scalar ``scenario_timeline(seed=...)`` reference —
edges, powers and idle floor — across seeds, and the bank-native mixed
fleet must reproduce the object path label-for-label and row-for-row.
Chunked (streaming) fleet audits must match unchunked per-device and in
every error statistic, including the per-scenario breakdown and
empty/ragged chunk edges.
"""
import numpy as np
import pytest

from _hyp import given, settings, st
from repro.core import load as loads
from repro.core.fleet_engine import StreamingMoments, fleet_audit
from repro.core.meter import WorkloadSet

PROFILES_40 = ["a100"] * 20 + ["v100"] * 10 + ["h100_instant"] * 10


def _assert_row_equals_scalar(bank, i, tl):
    row = bank.row(i)
    np.testing.assert_array_equal(row.edges, tl.edges)
    np.testing.assert_array_equal(row.powers, tl.powers)
    assert row.idle_w == tl.idle_w


@pytest.mark.parametrize("kind", sorted(loads.SCENARIOS))
def test_scenario_bank_rows_bitwise_match_scalar(kind):
    seeds = np.arange(160) * 911 + 5
    bank = loads.scenario_bank(kind, seeds)
    assert bank.n_rows == len(seeds)
    for j, s in enumerate(seeds):
        _assert_row_equals_scalar(
            bank, j, loads.scenario_timeline(kind, seed=int(s)))


@pytest.mark.parametrize("kind", sorted(loads.SCENARIOS))
@given(seed=st.integers(min_value=0, max_value=2**32), idle=st.floats(40.0, 80.0),
       peak=st.floats(200.0, 400.0))
@settings(max_examples=25, deadline=None)
def test_scenario_bank_property_any_seed_and_params(kind, seed, idle, peak):
    bank = loads.SCENARIO_BANKS[kind](np.array([seed]), idle_w=idle,
                                      peak_w=peak)
    tl = loads.SCENARIOS[kind](seed=seed, idle_w=idle, peak_w=peak)
    _assert_row_equals_scalar(bank, 0, tl)


def test_inference_bank_heavy_rate_and_zero_burst_rows():
    """Force both the k = 0 idle-window path and the max_bursts clip."""
    seeds = np.arange(300)
    lo = loads.inference_serving_bank(seeds, rate_hz=0.5)   # many k == 0
    hi = loads.inference_serving_bank(seeds, rate_hz=200.0)  # clipped
    saw_zero = False
    for j, s in enumerate(seeds):
        tl_lo = loads.inference_serving_timeline(seed=int(s), rate_hz=0.5)
        tl_hi = loads.inference_serving_timeline(seed=int(s), rate_hz=200.0)
        saw_zero |= len(tl_lo.powers) == 1
        _assert_row_equals_scalar(lo, j, tl_lo)
        _assert_row_equals_scalar(hi, j, tl_hi)
    assert saw_zero


def test_inference_max_bursts_is_explicit_and_documented_clip():
    """ISSUE 4 satellite: the silent min(poisson, 12) became an explicit
    parameter — heavy-rate sweeps can raise it, and raising it changes
    the realised burst count where the old cap was binding."""
    lam_heavy = 200.0 * 0.350      # >> 12: the default cap always binds
    capped = loads.inference_serving_timeline(seed=3, rate_hz=200.0)
    raised = loads.inference_serving_timeline(seed=3, rate_hz=200.0,
                                              max_bursts=64)
    k_raw = int(np.random.default_rng(3).poisson(lam_heavy))
    assert k_raw > 12
    # the capped timeline merged at most 12 bursts; the raised cap admits
    # more segments (bursts may merge, so compare energy-bearing content)
    assert raised.energy() != capped.energy()
    with pytest.raises(ValueError, match="max_bursts"):
        loads.inference_serving_timeline(seed=0, max_bursts=0)
    with pytest.raises(ValueError, match="max_bursts"):
        loads.inference_serving_bank(np.arange(3), max_bursts=0)
    # vectorized counterpart honours the same parameter bitwise
    bank = loads.inference_serving_bank(np.array([3]), rate_hz=200.0,
                                        max_bursts=64)
    _assert_row_equals_scalar(bank, 0, raised)


def test_mixed_fleet_bank_matches_object_path():
    n = 120
    wls = loads.mixed_fleet_workloads(n, seed=7)
    bank, labels = loads.mixed_fleet_bank(n, seed=7)
    assert bank.n_rows == n
    for i, w in enumerate(wls):
        assert w.scenario == str(labels[i])
        _assert_row_equals_scalar(bank, i, w.timeline)


def test_mixed_fleet_bank_slab_equals_full_rows():
    n = 200
    full, labels = loads.mixed_fleet_bank(n, seed=3)
    slab, sl = loads.mixed_fleet_bank(n, seed=3, lo=60, hi=140)
    np.testing.assert_array_equal(sl, labels[60:140])
    for g, i in enumerate(range(60, 140)):
        a, b = slab.row(g), full.row(i)
        np.testing.assert_array_equal(a.edges, b.edges)
        np.testing.assert_array_equal(a.powers, b.powers)
    with pytest.raises(ValueError, match="bad slab"):
        loads.mixed_fleet_bank(10, lo=5, hi=3)


def test_as_bank_workload_set_equivalent_to_object_set():
    n = 80
    ws_obj = WorkloadSet(loads.mixed_fleet_workloads(n, seed=11))
    ws_bank = loads.mixed_fleet_workloads(n, seed=11, as_bank=True)
    assert isinstance(ws_bank, WorkloadSet)
    assert len(ws_bank) == n
    np.testing.assert_array_equal(ws_bank.durations_s, ws_obj.durations_s)
    np.testing.assert_array_equal(ws_bank.true_energies_j,
                                  ws_obj.true_energies_j)
    assert list(ws_bank.scenarios) == list(ws_obj.scenarios)
    # lazy per-device views round-trip
    w = ws_bank[5]
    np.testing.assert_array_equal(w.timeline.edges, ws_obj[5].timeline.edges)
    assert w.scenario == ws_obj[5].scenario
    # audits agree bitwise
    r_obj = fleet_audit(n, profile="a100", workload=ws_obj)
    r_bank = fleet_audit(n, profile="a100", workload=ws_bank)
    np.testing.assert_array_equal(r_obj.naive_j, r_bank.naive_j)


def test_workload_set_ctor_validation():
    with pytest.raises(ValueError, match="exactly one"):
        WorkloadSet()
    bank, labels = loads.mixed_fleet_bank(4, seed=0)
    with pytest.raises(ValueError, match="exactly one"):
        WorkloadSet([], bank=bank)
    with pytest.raises(ValueError, match="scenario labels"):
        WorkloadSet(bank=bank, scenarios=["a", "b"])


def test_fleet_scenario_spec_validation_and_slabs():
    with pytest.raises(ValueError, match="at least one device"):
        loads.FleetScenarioSpec(n=0)
    with pytest.raises(KeyError, match="unknown scenario"):
        loads.FleetScenarioSpec(n=4, mix={"mining": 1.0})
    spec = loads.FleetScenarioSpec(n=50, seed=2)
    full_ws = spec.workload_set()
    part = spec.workload_set(10, 30)
    np.testing.assert_array_equal(part.true_energies_j,
                                  full_ws.true_energies_j[10:30])


@pytest.mark.parametrize("chunk", [17, 50, 64, 1000])
def test_chunked_fleet_audit_identical_to_unchunked(chunk):
    """ISSUE 4 acceptance: chunked audit per-device results identical
    within float accumulation (each slab's reading grid pads to the slab
    max, which permutes the padded-width summation tree — ≲1e-12
    relative), stats likewise, for ragged tails (17), exact divisors
    (50), and single-slab oversize chunks (1000)."""
    n = 100
    ws = loads.mixed_fleet_workloads(n, seed=5, as_bank=True)
    ref = fleet_audit(n, profile=PROFILES_40[:25] * 4, workload=ws,
                      good_practice=True, n_trials=2)
    got = fleet_audit(n, profile=PROFILES_40[:25] * 4, workload=ws,
                      good_practice=True, n_trials=2, chunk_devices=chunk)
    np.testing.assert_allclose(ref.naive_j, got.naive_j, rtol=1e-12, atol=0)
    np.testing.assert_allclose(ref.gp_j, got.gp_j, rtol=1e-12, atol=0)
    np.testing.assert_array_equal(np.asarray(ref.true_j),
                                  np.asarray(got.true_j))
    for a, b in ((ref.stats(), got.stats()),
                 (ref.stats(ref.gp_err), got.stats(got.gp_err))):
        assert set(a) == set(b)
        for key in a:
            assert a[key] == pytest.approx(b[key], rel=1e-9, abs=1e-15), key
    by_a, by_b = ref.by_scenario(), got.by_scenario()
    assert set(by_a) == set(by_b)
    for label in by_a:
        for key in by_a[label]:
            assert by_a[label][key] == pytest.approx(
                by_b[label][key], rel=1e-9, abs=1e-15), (label, key)
    assert got.chunk_devices == chunk


def test_chunked_audit_streamed_moments_match_exact_stats():
    n = 90
    spec = loads.FleetScenarioSpec(n=n, seed=9)
    res = fleet_audit(n, profile="a100", workload=spec, chunk_devices=13)
    exact = res.stats()
    stream = res.streamed["naive"]["overall"]
    for key in ("mean_err", "mean_abs_err", "std_err", "worst_abs"):
        assert stream[key] == pytest.approx(exact[key], rel=1e-12, abs=1e-15)
    assert stream["n_devices"] == n
    by_exact = res.by_scenario()
    by_stream = res.streamed["naive"]["by_scenario"]
    assert set(by_stream) == set(by_exact)
    for label, st_ in by_stream.items():
        assert st_["mean_abs_err"] == pytest.approx(
            by_exact[label]["mean_abs_err"], rel=1e-12, abs=1e-15)
        assert st_["n_devices"] == by_exact[label]["n_devices"]


def test_chunked_audit_spec_streams_slabs_lazily():
    """Spec-driven chunking synthesises each slab on demand and still
    matches a fully materialised audit bitwise."""
    n = 75
    spec = loads.FleetScenarioSpec(n=n, seed=4)
    ws = loads.mixed_fleet_workloads(n, seed=4, as_bank=True)
    a = fleet_audit(n, profile="v100", workload=spec, chunk_devices=20)
    b = fleet_audit(n, profile="v100", workload=ws)
    np.testing.assert_array_equal(a.naive_j, b.naive_j)
    np.testing.assert_array_equal(a.scenarios, np.asarray(ws.scenarios))


def test_streaming_moments_empty_and_single_updates():
    sm = StreamingMoments()
    assert sm.stats()["n_devices"] == 0
    sm.update(np.array([]))                     # empty chunk: no-op
    assert sm.n == 0
    e = np.array([0.5, -0.25, 0.125])
    sm.update(e[:1]).update(e[1:]).update(np.array([]))
    assert sm.stats()["mean_err"] == pytest.approx(np.mean(e))
    assert sm.stats()["std_err"] == pytest.approx(np.std(e))
    assert sm.stats()["worst_abs"] == pytest.approx(0.5)


def test_fleet_audit_chunk_validation():
    with pytest.raises(ValueError, match="chunk_devices"):
        fleet_audit(10, profile="a100", chunk_devices=0)
    spec = loads.FleetScenarioSpec(n=5)
    with pytest.raises(ValueError, match="covers 5 devices"):
        fleet_audit(6, profile="a100", workload=spec)
    # the shared-stream seed mode cannot honour slab-invariance: a
    # per-slab bank would restart the fleet RNG (each slab re-drawing
    # slab-0's hidden truths) — refuse rather than silently diverge
    with pytest.raises(ValueError, match="seed_mode='per_device'"):
        fleet_audit(10, profile="a100", seed_mode="fleet", chunk_devices=4)
    # an oversize chunk is one slab == unchunked, so fleet mode is fine
    a = fleet_audit(10, profile="a100", seed_mode="fleet", chunk_devices=10)
    b = fleet_audit(10, profile="a100", seed_mode="fleet")
    np.testing.assert_array_equal(a.naive_j, b.naive_j)


def test_sensor_bank_distinct_profiles_sharing_a_name():
    """Field stacking groups by profile *identity*: two distinct profile
    objects that happen to share a name must keep their own physics."""
    from repro.core.fleet_engine import SensorBank
    from repro.core.sensor import SensorProfile

    a = SensorProfile("x", noise_w=0.1)
    b = SensorProfile("x", noise_w=5.0)
    bank = SensorBank([a, b])
    np.testing.assert_array_equal(bank.noise_w, [0.1, 5.0])


def test_workload_gen_vectorized_speedup_smoke():
    """The tentpole's reason to exist: bank-native synthesis must be
    much faster than the object path (ISSUE 4 targets ≥10× at 100k; at
    smoke size we require a conservative ≥3× to stay CI-stable)."""
    import time
    n = 3000
    t0 = time.perf_counter()
    loads.mixed_fleet_workloads(n, seed=1)
    t_obj = time.perf_counter() - t0
    t0 = time.perf_counter()
    loads.mixed_fleet_workloads(n, seed=1, as_bank=True)
    t_bank = time.perf_counter() - t0
    assert t_bank < t_obj / 3.0, (t_obj, t_bank)
