"""Substrate tests: data pipeline, optimizer, compression, checkpointing,
sharding rules, HLO parsers."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # degrades to per-test skips without hypothesis

from repro.configs.base import ShapeCell
from repro.configs.registry import get_config
from repro.data.pipeline import LoaderState, PrefetchLoader, SyntheticTokens
from repro.models import api
from repro.optim import adamw, compress


SMOKE = ShapeCell("smoke", 16, 4, "train")


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_loader_deterministic_and_resumable():
    cfg = get_config("olmo-1b", reduced=True)
    l1 = SyntheticTokens(cfg, SMOKE, seed=3)
    batches = [next(iter_) for iter_ in [iter(l1)] for _ in range(5)]
    # resume from step 3
    l2 = SyntheticTokens(cfg, SMOKE, seed=3)
    l2.state = LoaderState(step=3)
    b3 = next(iter(l2))
    np.testing.assert_array_equal(b3["tokens"], batches[3]["tokens"])


def test_loader_host_sharding_partitions_batch():
    cfg = get_config("olmo-1b", reduced=True)
    full = SyntheticTokens(cfg, SMOKE, seed=1, host_id=0, n_hosts=1)
    h0 = SyntheticTokens(cfg, SMOKE, seed=1, host_id=0, n_hosts=2)
    h1 = SyntheticTokens(cfg, SMOKE, seed=1, host_id=1, n_hosts=2)
    assert h0.local_batch == full.local_batch // 2
    b0, b1 = h0.batch_at(0), h1.batch_at(0)
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_prefetch_loader():
    cfg = get_config("olmo-1b", reduced=True)
    src = SyntheticTokens(cfg, SMOKE, seed=2)
    pf = PrefetchLoader(src, depth=2)
    pf.start()
    b = pf.next()
    assert b["tokens"].shape == (SMOKE.global_batch, SMOKE.seq_len)
    pf.stop()


def test_loader_tokens_in_vocab():
    cfg = get_config("olmo-1b", reduced=True)
    b = SyntheticTokens(cfg, SMOKE, seed=0).batch_at(0)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < cfg.vocab


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_descends_quadratic():
    cfg = adamw.AdamWConfig(lr_peak=0.1, warmup_steps=1, total_steps=200,
                            weight_decay=0.0, clip_norm=1e9)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = adamw.init(params)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, m = adamw.update(cfg, grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.2


def test_adamw_clips_global_norm():
    cfg = adamw.AdamWConfig(clip_norm=1.0)
    params = {"w": jnp.ones((4,))}
    state = adamw.init(params)
    _, _, m = adamw.update(cfg, {"w": jnp.full((4,), 100.0)}, state, params)
    assert float(m["grad_norm"]) == pytest.approx(200.0)


def test_cosine_schedule_shape():
    cfg = adamw.AdamWConfig(lr_peak=1.0, warmup_steps=10, total_steps=100,
                            lr_min_ratio=0.1)
    lrs = [float(adamw.cosine_lr(cfg, jnp.asarray(s))) for s in range(101)]
    assert lrs[0] == pytest.approx(0.0)
    assert max(lrs) == pytest.approx(1.0, rel=1e-3)
    assert lrs[-1] == pytest.approx(0.1, rel=1e-2)


# ---------------------------------------------------------------------------
# int8 error-feedback compression
# ---------------------------------------------------------------------------

def test_quantize_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 10
    qz = compress.quantize(x)
    err = np.abs(np.asarray(compress.dequantize(qz) - x))
    assert err.max() <= float(qz.scale) * 0.5 + 1e-6


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100), steps=st.integers(2, 12))
def test_error_feedback_unbiased_over_window(seed, steps):
    """Σ dequantised ≈ Σ true gradients: the residual never exceeds one
    quantisation step, so accumulated bias does not grow with steps."""
    rng = np.random.default_rng(seed)
    gs = [jnp.asarray(rng.normal(size=(64,)), jnp.float32)
          for _ in range(steps)]
    err = jnp.zeros((64,))
    total_deq = jnp.zeros((64,))
    for g in gs:
        qz, err = compress.quantize_with_feedback(g, err)
        total_deq = total_deq + compress.dequantize(qz)
    total_true = sum(gs)
    resid = np.abs(np.asarray(total_deq + err - total_true))
    assert resid.max() < 1e-4
    # carried error bounded by one quantum
    last_scale = float(compress.quantize(gs[-1] + 0).scale)
    assert np.abs(np.asarray(err)).max() <= 2.0


def test_compressed_psum_matches_plain():
    try:
        from jax import shard_map
    except ImportError:  # jax<0.5 keeps it under experimental
        from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P
    devs = np.asarray(jax.devices()[:1])
    mesh = Mesh(devs.reshape(1), ("x",))
    x = jnp.linspace(-1, 1, 128)
    f = shard_map(
        lambda v: compress.compressed_psum(v, "x"), mesh=mesh,
        in_specs=P(), out_specs=P())
    out = f(x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x), atol=0.02)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    from repro.ckpt.checkpoint import CheckpointManager
    cfg = get_config("olmo-1b", reduced=True)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw.init(params)
    mgr = CheckpointManager(str(tmp_path), retain=2)
    mgr.save(10, {"params": params, "opt": opt},
             extras={"loader": {"step": 10}})
    specs = {
        "params": jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params),
        "opt": jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), opt),
    }
    restored, extras = mgr.restore(10, specs)
    assert extras["loader"]["step"] == 10
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(restored["params"])):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_retention_and_latest(tmp_path):
    from repro.ckpt.checkpoint import CheckpointManager
    mgr = CheckpointManager(str(tmp_path), retain=2)
    tree = {"x": jnp.zeros((4,))}
    for s in (1, 2, 3, 4):
        mgr.save(s, {"t": tree})
    assert mgr.steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_checkpoint_async_and_atomic(tmp_path):
    from repro.ckpt.checkpoint import CheckpointManager
    mgr = CheckpointManager(str(tmp_path))
    tree = {"x": jnp.arange(8, dtype=jnp.float32)}
    mgr.save_async(5, {"t": tree})
    mgr.wait()
    assert mgr.latest_step() == 5
    # no tmp dirs left behind
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]


def test_checkpoint_shape_mismatch_raises(tmp_path):
    from repro.ckpt.checkpoint import CheckpointManager
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"t": {"x": jnp.zeros((4,))}})
    bad = {"t": {"x": jax.ShapeDtypeStruct((5,), jnp.float32)}}
    with pytest.raises(ValueError):
        mgr.restore(1, bad)


# ---------------------------------------------------------------------------
# HLO parsers
# ---------------------------------------------------------------------------

def test_hlo_type_bytes():
    from repro.launch.hlo import _type_bytes
    assert _type_bytes("bf16[128,256]{1,0}") == 128 * 256 * 2
    assert _type_bytes("f32[8]{0}") == 32
    assert _type_bytes("(bf16[2,2]{1,0}, f32[4]{0})") == 8 + 16


def test_hlo_trip_count_and_collectives():
    from repro.launch.hlo import collective_bytes
    hlo = """
HloModule test

%body (p: (s32[], f32[64,64])) -> (s32[], f32[64,64]) {
  %ar = f32[64,64]{1,0} all-reduce(%x), replica_groups={}
  ROOT %t = (s32[], f32[64,64]) tuple(%i, %ar)
}

%cond (p: (s32[], f32[64,64])) -> pred[] {
  %bound = s32[] constant(12)
  ROOT %cmp = pred[] compare(%i, %bound), direction=LT
}

ENTRY %main (a: f32[64,64]) -> f32[64,64] {
  %ag = f32[64,64]{1,0} all-gather(%a), dimensions={0}
  %w = (s32[], f32[64,64]) while(%init), condition=%cond, body=%body
  ROOT %r = f32[64,64]{1,0} get-tuple-element(%w), index=1
}
"""
    st = collective_bytes(hlo)
    per = 64 * 64 * 4
    assert st.bytes_by_kind["all-gather"] == per
    assert st.bytes_by_kind["all-reduce"] == per * 12


def test_hlo_dot_flops_with_loop():
    from repro.launch.hlo import hlo_dot_flops
    hlo = """
HloModule test

%body (p: (s32[], f32[32,16])) -> (s32[], f32[32,16]) {
  %w = f32[16,16]{1,0} parameter(1)
  %x = f32[32,16]{1,0} get-tuple-element(%p), index=1
  %d = f32[32,16]{1,0} dot(%x, %w), lhs_batch_dims={}, lhs_contracting_dims={1}, rhs_batch_dims={}, rhs_contracting_dims={0}
  ROOT %t = (s32[], f32[32,16]) tuple(%i, %d)
}

%cond (p: (s32[], f32[32,16])) -> pred[] {
  %bound = s32[] constant(4)
  ROOT %cmp = pred[] compare(%i, %bound), direction=LT
}

ENTRY %main (a: f32[32,16]) -> f32[32,16] {
  %w = (s32[], f32[32,16]) while(%init), condition=%cond, body=%body
  ROOT %r = f32[32,16]{1,0} get-tuple-element(%w), index=1
}
"""
    # 2*32*16*16 per iter × 4 iters
    assert hlo_dot_flops(hlo) == 2 * 32 * 16 * 16 * 4
