"""Minimal structured logger used across the framework.

Avoids the stdlib logging global-state pitfalls in multi-host launches:
each component gets a named logger that prefixes host/pod identity when
running distributed.
"""
from __future__ import annotations

import os
import sys
import time
from typing import Any


_LEVELS = {"debug": 10, "info": 20, "warn": 30, "error": 40}
_LEVEL = _LEVELS.get(os.environ.get("REPRO_LOG_LEVEL", "info"), 20)


class Logger:
    def __init__(self, name: str):
        self.name = name

    def _emit(self, level: str, msg: str, **kw: Any) -> None:
        if _LEVELS[level] < _LEVEL:
            return
        extra = " ".join(f"{k}={v}" for k, v in kw.items())
        ts = time.strftime("%H:%M:%S")
        print(f"[{ts}] {level.upper():5s} {self.name}: {msg} {extra}".rstrip(),
              file=sys.stderr)

    def debug(self, msg: str, **kw: Any) -> None:
        self._emit("debug", msg, **kw)

    def info(self, msg: str, **kw: Any) -> None:
        self._emit("info", msg, **kw)

    def warn(self, msg: str, **kw: Any) -> None:
        self._emit("warn", msg, **kw)

    def error(self, msg: str, **kw: Any) -> None:
        self._emit("error", msg, **kw)


def get_logger(name: str) -> Logger:
    return Logger(name)
