"""Lightweight frozen-dataclass config base with dict/JSON round-trip.

Every subsystem config in the framework derives from :class:`Config`.
Configs are immutable; ``replace`` returns an updated copy. This is the
single config system used by model configs, sensor profiles, shard rules,
training hyperparameters and the launcher.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Type, TypeVar

T = TypeVar("T", bound="Config")


@dataclasses.dataclass(frozen=True)
class Config:
    """Base class for all framework configs."""

    def replace(self: T, **kw: Any) -> T:
        return dataclasses.replace(self, **kw)

    def to_dict(self) -> Dict[str, Any]:
        out = {}
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if isinstance(v, Config):
                v = v.to_dict()
            elif isinstance(v, tuple):
                v = [x.to_dict() if isinstance(x, Config) else x for x in v]
            out[f.name] = v
        return out

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2, sort_keys=True)

    @classmethod
    def from_dict(cls: Type[T], d: Dict[str, Any]) -> T:
        kw = {}
        for f in dataclasses.fields(cls):
            if f.name not in d:
                continue
            v = d[f.name]
            ft = f.type
            if isinstance(ft, str):
                ft = None  # forward-ref; trust the raw value
            if ft is not None and isinstance(ft, type) and issubclass(ft, Config) and isinstance(v, dict):
                v = ft.from_dict(v)
            elif isinstance(v, list):
                v = tuple(v)
            kw[f.name] = v
        return cls(**kw)

    @classmethod
    def from_json(cls: Type[T], s: str) -> T:
        return cls.from_dict(json.loads(s))


def validate_positive(name: str, value: float) -> None:
    if value <= 0:
        raise ValueError(f"{name} must be positive, got {value}")
