"""Pytree helpers shared by checkpointing, sharding and optimizers."""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Tuple

import jax
import numpy as np


def flatten_with_paths(tree: Any) -> List[Tuple[str, Any]]:
    """Flatten a pytree into (dot.path, leaf) pairs with stable ordering."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        out.append((path_str(path), leaf))
    return out


def path_str(path: Tuple[Any, ...]) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return ".".join(parts)


def tree_bytes(tree: Any) -> int:
    """Total bytes across all array leaves."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        if hasattr(leaf, "nbytes"):
            total += int(leaf.nbytes)
        elif hasattr(leaf, "size") and hasattr(leaf, "dtype"):
            total += int(leaf.size) * np.dtype(leaf.dtype).itemsize
    return total


def tree_param_count(tree: Any) -> int:
    return sum(int(np.prod(leaf.shape)) for leaf in jax.tree_util.tree_leaves(tree)
               if hasattr(leaf, "shape"))


def map_with_paths(fn: Callable[[str, Any], Any], tree: Any) -> Any:
    """tree_map where fn also receives the dot.path of each leaf."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: fn(path_str(path), leaf), tree)


def assert_trees_all_close(a: Any, b: Any, rtol: float = 1e-5,
                           atol: float = 1e-5) -> None:
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb), f"leaf count {len(la)} != {len(lb)}"
    for x, y in zip(la, lb):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=rtol, atol=atol)


def tree_as_dict(tree: Any) -> Dict[str, np.ndarray]:
    return {k: np.asarray(v) for k, v in flatten_with_paths(tree)}
