"""Pytree helpers shared by checkpointing, sharding and optimizers.

jax is optional here: the streaming-monitor checkpoint path runs on
numpy-only hosts, so :func:`flatten_with_paths` falls back to a plain
recursive flattener over dicts/lists/tuples (same sorted-key ordering
jax uses) when jax is absent.  Helpers that genuinely need pytree
registry support still require jax and say so.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Tuple

try:
    import jax
except ImportError:                                   # numpy-only host
    jax = None
import numpy as np


def _require_jax(what: str):
    if jax is None:
        raise RuntimeError(f"{what} requires jax, which is not installed")
    return jax


def _flatten_plain(tree: Any, prefix: Tuple[str, ...],
                   out: List[Tuple[str, Any]]) -> None:
    # mirrors jax's container ordering: dict keys sorted, sequences by index
    if isinstance(tree, dict):
        for k in sorted(tree):
            _flatten_plain(tree[k], prefix + (str(k),), out)
    elif isinstance(tree, (list, tuple)):
        for i, leaf in enumerate(tree):
            _flatten_plain(leaf, prefix + (str(i),), out)
    elif tree is None:
        pass
    else:
        out.append((".".join(prefix), tree))


def flatten_with_paths(tree: Any) -> List[Tuple[str, Any]]:
    """Flatten a pytree into (dot.path, leaf) pairs with stable ordering."""
    if jax is None:
        out: List[Tuple[str, Any]] = []
        _flatten_plain(tree, (), out)
        return out
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        out.append((path_str(path), leaf))
    return out


def path_str(path: Tuple[Any, ...]) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return ".".join(parts)


def _tree_leaves(tree: Any) -> List[Any]:
    if jax is None:
        return [leaf for _, leaf in flatten_with_paths(tree)]
    return jax.tree_util.tree_leaves(tree)


def tree_bytes(tree: Any) -> int:
    """Total bytes across all array leaves."""
    total = 0
    for leaf in _tree_leaves(tree):
        if hasattr(leaf, "nbytes"):
            total += int(leaf.nbytes)
        elif hasattr(leaf, "size") and hasattr(leaf, "dtype"):
            total += int(leaf.size) * np.dtype(leaf.dtype).itemsize
    return total


def tree_param_count(tree: Any) -> int:
    return sum(int(np.prod(leaf.shape)) for leaf in _tree_leaves(tree)
               if hasattr(leaf, "shape"))


def map_with_paths(fn: Callable[[str, Any], Any], tree: Any) -> Any:
    """tree_map where fn also receives the dot.path of each leaf."""
    return _require_jax("map_with_paths").tree_util.tree_map_with_path(
        lambda path, leaf: fn(path_str(path), leaf), tree)


def assert_trees_all_close(a: Any, b: Any, rtol: float = 1e-5,
                           atol: float = 1e-5) -> None:
    la = _tree_leaves(a)
    lb = _tree_leaves(b)
    assert len(la) == len(lb), f"leaf count {len(la)} != {len(lb)}"
    for x, y in zip(la, lb):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=rtol, atol=atol)


def tree_as_dict(tree: Any) -> Dict[str, np.ndarray]:
    return {k: np.asarray(v) for k, v in flatten_with_paths(tree)}
