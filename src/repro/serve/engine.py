"""Batched serving engine: slot-based continuous batching + energy ledger.

A fixed pool of ``n_slots`` sequences decodes in lockstep (one jit'd
decode_step per tick for the whole batch); finished slots are refilled
from the request queue without interrupting the others (their cache rows
are re-prefilled).  Per-request latency/energy is accounted through the
same ledger machinery as training.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.logging import get_logger
from repro.configs.base import ArchConfig
from repro.models import api

log = get_logger("serve")


@dataclasses.dataclass
class Request:
    request_id: int
    prompt: np.ndarray            # [S] int32
    max_new_tokens: int = 16
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    """Decoder-only serving (enc-dec uses its own prefill path)."""

    def __init__(self, cfg: ArchConfig, params: Any, n_slots: int = 4,
                 max_seq: int = 256, greedy: bool = True):
        assert not cfg.encdec, "use EncDecEngine for enc-dec models"
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.greedy = greedy
        self.cache = api.init_cache(cfg, n_slots, max_seq)
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self.slot_pos = np.zeros(n_slots, np.int32)
        self.queue: List[Request] = []
        self._decode = jax.jit(
            lambda p, c, b: api.decode_step(p, self.cfg, c, b))
        self.ticks = 0

    # -- request management ------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _fill_slots(self) -> None:
        for s in range(self.n_slots):
            if self.slot_req[s] is None and self.queue:
                req = self.queue.pop(0)
                self._prefill_slot(s, req)

    def _prefill_slot(self, slot: int, req: Request) -> None:
        """Feed the prompt token-by-token through decode_step for this
        slot (slot-isolated prefill keeps one compiled program; a batched
        prefill fast-path exists in launch/serve.py for cold starts)."""
        self.slot_req[slot] = req
        self.slot_pos[slot] = 0
        for t, tok in enumerate(req.prompt):
            batch = self._batch_for(step_tokens=self._tokens_with(slot, tok),
                                    pos=t)
            logits, cache = self._decode(self.params, self.cache, batch)
            # only this slot's cache rows matter; other slots re-write the
            # same contents they already hold (pos is shared — see note)
            self.cache = cache
            self.slot_pos[slot] = t + 1

    def _tokens_with(self, slot: int, tok: int) -> np.ndarray:
        toks = np.zeros((self.n_slots, 1), np.int32)
        toks[slot, 0] = int(tok)
        return toks

    def _batch_for(self, step_tokens: np.ndarray, pos: int) -> Dict[str, Any]:
        batch: Dict[str, Any] = {
            "tokens": jnp.asarray(step_tokens),
            "pos": jnp.asarray([pos], jnp.int32),
        }
        if self.cfg.input_mode == "embeds":
            emb = jnp.take(self.params["embed"], batch["tokens"], axis=0)
            batch = {"embeds": emb, "pos": batch["pos"]}
        return batch

    # -- decoding ------------------------------------------------------------
    def step(self) -> int:
        """One decode tick for all active slots; returns #active."""
        self._fill_slots()
        active = [s for s in range(self.n_slots)
                  if self.slot_req[s] is not None]
        if not active:
            return 0
        toks = np.zeros((self.n_slots, 1), np.int32)
        for s in active:
            req = self.slot_req[s]
            last = req.generated[-1] if req.generated else int(req.prompt[-1])
            toks[s, 0] = last
        pos = int(max(self.slot_pos[s] for s in active))
        logits, self.cache = self._decode(
            self.params, self.cache, self._batch_for(toks, pos))
        lg = np.asarray(logits[:, 0])
        for s in active:
            req = self.slot_req[s]
            nxt = int(np.argmax(lg[s]))
            req.generated.append(nxt)
            self.slot_pos[s] += 1
            if (len(req.generated) >= req.max_new_tokens
                    or self.slot_pos[s] >= self.max_seq - 1):
                req.done = True
                self.slot_req[s] = None
        self.ticks += 1
        return len(active)

    def run(self, max_ticks: int = 1000) -> List[Request]:
        done: List[Request] = []
        t0 = time.perf_counter()
        while (self.queue or any(r is not None for r in self.slot_req)):
            if self.ticks >= max_ticks:
                break
            self.step()
        dt = time.perf_counter() - t0
        log.info("serving drained", ticks=self.ticks,
                 wall=f"{dt:.2f}s")
        return done
