"""Batched query serving over the streaming fleet monitor.

The serving counterpart of :mod:`repro.serve.engine`'s slot loop, for
monitor queries instead of token decoding: callers ``submit`` any mix
of ``fleet_energy`` / ``window_energy`` / ``energy_between`` /
``by_label`` requests, and ``flush`` executes the whole batch against
**one** immutable :class:`~repro.core.stream.snapshot.MonitorSnapshot`:

* all distinct query instants of a flavour collapse into a single
  ``snapshot_energy_at`` kernel call ([Q, N] — one vectorized array op
  however many thousand requests are queued);
* results are memoised in an LRU cache keyed ``(query, epoch)`` —
  an epoch tag in every key means a result can never be served against
  a different snapshot than the one that computed it;
* duplicate queries inside one batch are computed once and fanned out.

Results are the same objects the direct ``MonitorService`` query
methods return, produced through the same snapshot reduction helpers —
on the numpy backend the executor's answers are *bitwise* equal to the
direct path (pinned in ``tests/test_serving.py``).

Usage::

    svc = MonitorQueryService(mon)
    tickets = [svc.submit(MonitorQuery.fleet_energy(t)) for t in instants]
    results = svc.flush()               # {ticket: FleetEnergy}
    one = svc.query(MonitorQuery.energy_between(2.0, 4.0))
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.stream.monitor import MonitorService
from repro.core.stream.snapshot import MonitorSnapshot

_KINDS = ("fleet_energy", "window_energy", "energy_between", "by_label")


@dataclasses.dataclass(frozen=True)
class MonitorQuery:
    """One hashable monitor query (build via the factory classmethods —
    they validate the edge contract at construction, so a malformed
    query fails at submit time, not deep inside a batch)."""

    kind: str
    t: Optional[float] = None
    t0: Optional[float] = None
    t1: Optional[float] = None
    corrected: bool = True

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"unknown query kind '{self.kind}'; "
                             f"known: {', '.join(_KINDS)}")

    @classmethod
    def fleet_energy(cls, t: Optional[float] = None,
                     corrected: bool = True) -> "MonitorQuery":
        return cls("fleet_energy", t=None if t is None else float(t),
                   corrected=corrected)

    @classmethod
    def window_energy(cls, t: Optional[float] = None,
                      corrected: bool = True) -> "MonitorQuery":
        return cls("window_energy", t=None if t is None else float(t),
                   corrected=corrected)

    @classmethod
    def energy_between(cls, t0: float, t1: float,
                       corrected: bool = True) -> "MonitorQuery":
        t0, t1 = float(t0), float(t1)
        if not (t1 >= t0):        # also rejects NaN endpoints
            raise ValueError(f"bad window [{t0}, {t1}]")
        return cls("energy_between", t0=t0, t1=t1, corrected=corrected)

    @classmethod
    def by_label(cls, t0: Optional[float] = None,
                 t1: Optional[float] = None,
                 corrected: bool = True) -> "MonitorQuery":
        if (t0 is None) != (t1 is None):
            raise ValueError("pass both t0 and t1, or neither")
        if t0 is not None:
            t0, t1 = float(t0), float(t1)
            if not (t1 >= t0):
                raise ValueError(f"bad window [{t0}, {t1}]")
        return cls("by_label", t0=t0, t1=t1, corrected=corrected)


class MonitorQueryService:
    """Queue + batch executor + ``(query, epoch)`` LRU over one monitor.

    ``cache_size`` bounds the number of memoised results (fleet-energy
    answers carry [N] per-device arrays, so size the cache against
    ``n_devices`` — the default keeps a 100k-device monitor under
    ~250 MB worst-case).
    """

    def __init__(self, monitor: MonitorService, cache_size: int = 256):
        if cache_size < 0:
            raise ValueError("cache_size must be >= 0")
        self.monitor = monitor
        self.cache_size = int(cache_size)
        self._cache: "OrderedDict[Tuple[MonitorQuery, int], Any]" = \
            OrderedDict()
        self._pending: List[Tuple[int, MonitorQuery]] = []
        self._next_ticket = 0
        self.n_submitted = 0
        self.n_hits = 0
        self.n_misses = 0
        self.n_flushes = 0

    # -- request management ------------------------------------------------
    def submit(self, query: MonitorQuery) -> int:
        """Queue one query; returns the ticket that keys its result in
        the next :meth:`flush`."""
        if not isinstance(query, MonitorQuery):
            raise TypeError(f"submit takes a MonitorQuery, "
                            f"got {type(query).__name__}")
        ticket = self._next_ticket
        self._next_ticket += 1
        self.n_submitted += 1
        self._pending.append((ticket, query))
        return ticket

    def query(self, query: MonitorQuery):
        """Submit + flush a single query (convenience; batching still
        applies to whatever else is already queued)."""
        ticket = self.submit(query)
        return self.flush()[ticket]

    def query_many(self, queries: List[MonitorQuery]) -> List[Any]:
        """Submit a batch and flush once; results in input order.  The
        one-call shape the collector CLI uses for its replay summary —
        every distinct instant still collapses into one kernel call."""
        tickets = [self.submit(q) for q in queries]
        results = self.flush()
        return [results[t] for t in tickets]

    # -- execution ---------------------------------------------------------
    def flush(self) -> Dict[int, Any]:
        """Execute every pending query against the monitor's *current*
        snapshot and return ``{ticket: result}``.

        Cache hits are served without touching the snapshot arrays;
        misses are deduplicated, grouped by kind, and executed as one
        vectorized op per (kind, corrected) group.
        """
        if not self._pending:
            return {}
        snap = self.monitor.snapshot()
        epoch = snap.epoch
        self.n_flushes += 1
        pending, self._pending = self._pending, []

        # dedup: every distinct query computes once per flush
        tickets_for: "OrderedDict[MonitorQuery, List[int]]" = OrderedDict()
        for ticket, q in pending:
            tickets_for.setdefault(q, []).append(ticket)

        results: Dict[MonitorQuery, Any] = {}
        misses: List[MonitorQuery] = []
        for q in tickets_for:
            key = (q, epoch)
            if key in self._cache:
                self._cache.move_to_end(key)
                results[q] = self._cache[key]
                self.n_hits += len(tickets_for[q])
            else:
                misses.append(q)
                self.n_misses += len(tickets_for[q])

        for q, res in self._execute(snap, misses).items():
            results[q] = res
            if self.cache_size:
                self._cache[(q, epoch)] = res
        while len(self._cache) > self.cache_size:
            self._cache.popitem(last=False)

        return {ticket: results[q]
                for q, ts in tickets_for.items() for ticket in ts}

    def _execute(self, snap: MonitorSnapshot,
                 misses: List[MonitorQuery]) -> Dict[MonitorQuery, Any]:
        """Run the deduplicated cache misses against one snapshot."""
        out: Dict[MonitorQuery, Any] = {}
        # collect every energy-at instant per corrected flavour:
        # fleet_energy(t) needs one row, energy_between(t0, t1) two
        for corrected in (True, False):
            instants: List[float] = []
            seen: Dict[float, int] = {}

            def row_of(t: float) -> int:
                if t not in seen:
                    seen[t] = len(instants)
                    instants.append(t)
                return seen[t]

            plan: List[Tuple[MonitorQuery, Tuple[int, ...]]] = []
            for q in misses:
                if q.corrected != corrected:
                    continue
                if q.kind == "fleet_energy" and q.t is not None:
                    plan.append((q, (row_of(q.t),)))
                elif q.kind in ("energy_between", "by_label") \
                        and q.t0 is not None:
                    plan.append((q, (row_of(q.t0), row_of(q.t1))))
            if plan:
                e, cov = snap.energy_at_batch(np.array(instants), corrected)
                for q, rows in plan:
                    if q.kind == "fleet_energy":
                        (r,) = rows
                        out[q] = snap.fleet_from_rows(
                            q.t, corrected, e[r].copy(), cov[r].copy())
                    else:
                        r0, r1 = rows
                        de, dc = snap.between_from_rows(
                            e[r0], cov[r0], e[r1], cov[r1])
                        if q.kind == "energy_between":
                            out[q] = (de, dc)
                        else:
                            out[q] = self._by_label_from_rows(
                                snap, de, dc & snap.state.has)

            # window_energy: all instants of a flavour in one broadcast
            wq = [q for q in misses
                  if q.kind == "window_energy" and q.corrected == corrected
                  and q.t is not None]
            if wq:
                wt = []
                wseen: Dict[float, int] = {}
                for q in wq:
                    if q.t not in wseen:
                        wseen[q.t] = len(wt)
                        wt.append(q.t)
                we = snap.window_energy_batch(np.array(wt), corrected)
                for q in wq:
                    out[q] = we[wseen[q.t]].copy()

        # the t=None / since-start variants read snapshot arrays directly
        for q in misses:
            if q in out:
                continue
            if q.kind == "fleet_energy":
                out[q] = snap.fleet_energy(None, q.corrected)
            elif q.kind == "window_energy":
                out[q] = snap.window_energy(None, q.corrected)
            elif q.kind == "by_label":
                out[q] = snap.by_label(None, None, q.corrected)
            else:                                    # pragma: no cover
                raise AssertionError(f"unplanned query {q}")
        return out

    @staticmethod
    def _by_label_from_rows(snap: MonitorSnapshot, e: np.ndarray,
                            covered: np.ndarray) -> Dict[str, Dict[str, float]]:
        """The by-label grouping over a precomputed energy row (same
        reductions — including the degraded-mode quarantine exclusion —
        as ``MonitorSnapshot.by_label``)."""
        from repro.core.fleet_engine import StreamingMoments
        active = snap.active_mask
        out: Dict[str, Dict[str, float]] = {}
        for label in np.unique(snap.labels):
            sel = (snap.labels == label) & covered
            n_q = 0
            if active is not None:
                n_q = int(np.sum(sel & ~active))
                sel = sel & active
            vals = e[sel]
            sm = StreamingMoments().update(vals, snap._be)
            stats = sm.stats()
            n_cov = int(np.sum(sel))
            out[str(label)] = {
                "n_devices": int(np.sum(snap.labels == label)),
                "n_covered": n_cov,
                "n_quarantined": n_q,
                "total_j": float(np.sum(vals)) if vals.size else 0.0,
                "mean_j": stats["mean_err"] if n_cov else float("nan"),
                "std_j": stats["std_err"] if n_cov else float("nan"),
            }
        return out

    # -- accounting --------------------------------------------------------
    def stats(self) -> Dict[str, float]:
        """Executor counters: submissions, cache hit rate, flushes."""
        answered = self.n_hits + self.n_misses
        return {
            "n_submitted": self.n_submitted,
            "n_answered": answered,
            "n_pending": len(self._pending),
            "cache_hits": self.n_hits,
            "cache_misses": self.n_misses,
            "cache_hit_rate": (self.n_hits / answered) if answered else 0.0,
            "cache_entries": len(self._cache),
            "n_flushes": self.n_flushes,
        }
