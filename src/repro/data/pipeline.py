"""Deterministic synthetic data pipeline — sharded, prefetched, resumable.

No external datasets ship on the image, so the pipeline synthesises a
reproducible token stream: batch ``i`` is a pure function of (seed, step),
which makes checkpoint/restart exact (the loader state is just the step
counter) and makes multi-host sharding trivial (each host slices its rows
of the global batch).  The same interface is what a real corpus-backed
loader would implement; see DESIGN.md §3.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ArchConfig, ShapeCell


@dataclasses.dataclass
class LoaderState:
    step: int = 0

    def to_dict(self) -> dict:
        return {"step": self.step}

    @classmethod
    def from_dict(cls, d: dict) -> "LoaderState":
        return cls(step=int(d["step"]))


class SyntheticTokens:
    """Markov-ish synthetic LM stream: deterministic per (seed, step)."""

    def __init__(self, cfg: ArchConfig, shape: ShapeCell, seed: int = 0,
                 host_id: int = 0, n_hosts: int = 1):
        assert shape.global_batch % n_hosts == 0, (shape.global_batch, n_hosts)
        self.cfg = cfg
        self.shape = shape
        self.seed = seed
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.local_batch = shape.global_batch // n_hosts
        self.state = LoaderState()

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        B, S = self.local_batch, self.shape.seq_len
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + self.host_id)
        # zipfian-ish marginals so the loss signal is learnable
        z = rng.zipf(1.5, size=(B, S)).astype(np.int64)
        toks = (z % (self.cfg.vocab - 2)) + 1
        out: Dict[str, np.ndarray] = {}
        if self.cfg.encdec:
            emb = rng.standard_normal((B, S, self.cfg.d_model)).astype(np.float32)
            out["src_embeds"] = emb
            out["tokens"] = toks.astype(np.int32)
        elif self.cfg.input_mode == "embeds":
            out["embeds"] = rng.standard_normal(
                (B, S, self.cfg.d_model)).astype(np.float32)
            out["labels"] = toks.astype(np.int32)
            if self.cfg.mrope:
                pos = np.broadcast_to(np.arange(S, dtype=np.int32),
                                      (3, B, S)).copy()
                out["positions3"] = pos
        else:
            out["tokens"] = toks.astype(np.int32)
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            b = self.batch_at(self.state.step)
            self.state.step += 1
            yield b


class PrefetchLoader:
    """Background-thread prefetch (depth-N queue) over any batch source."""

    def __init__(self, source: SyntheticTokens, depth: int = 2):
        self.source = source
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def start(self) -> None:
        def run():
            it = iter(self.source)
            while not self._stop.is_set():
                try:
                    self.q.put(next(it), timeout=0.2)
                except queue.Full:
                    continue
        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def next(self, timeout: float = 30.0) -> Dict[str, np.ndarray]:
        return self.q.get(timeout=timeout)

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)

    @property
    def state(self) -> LoaderState:
        # NOTE: prefetched-but-unconsumed batches are regenerated on resume —
        # exactness comes from batch_at() being a pure function of step.
        return self.source.state
