"""Fault-tolerant training loop with first-class energy accounting.

Production behaviours implemented (and exercised by tests):
  * checkpoint/restart — async sharded checkpoints every N steps; on
    (re)start the loop resumes from the latest complete checkpoint with
    exact data-iterator and energy-ledger state;
  * straggler mitigation — per-step wall time is tracked against a rolling
    median; steps slower than ``straggler_factor``× median increment a
    counter and emit advisories (on a real fleet this feeds the hot-spare
    swap; here the hook is the part that matters);
  * energy telemetry (the paper's contribution) — each step's activity
    extends a simulated ground-truth power timeline; an OnboardSensor
    samples it part-time, and the ledger records BOTH the naive sensor
    integral and the good-practice-corrected energy with uncertainty, so
    runs report calibrated J/step (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.common.config import Config
from repro.common.logging import get_logger
from repro.configs.base import ArchConfig, ShapeCell
from repro.core import profiles
from repro.core.activity import ChipPowerModel, StepActivity, steps_timeline
from repro.core.calibrate import CalibrationRecord
from repro.core.ledger import EnergyLedger
from repro.core.sensor import OnboardSensor
from repro.data.pipeline import LoaderState, SyntheticTokens
from repro.models import api
from repro.optim import adamw
from repro.train.step import TrainConfig, make_train_step

log = get_logger("train")


@dataclasses.dataclass(frozen=True)
class LoopConfig(Config):
    total_steps: int = 50
    ckpt_every: int = 20
    log_every: int = 10
    straggler_factor: float = 2.0
    sensor_profile: str = "tpu_v5e_chip"
    sensor_seed: int = 0
    power_idle_w: float = 65.0
    power_peak_w: float = 250.0


@dataclasses.dataclass
class StragglerStats:
    times: list = dataclasses.field(default_factory=list)
    n_stragglers: int = 0

    def record(self, dt: float, factor: float) -> bool:
        med = float(np.median(self.times)) if self.times else dt
        self.times.append(dt)
        if len(self.times) > 200:
            self.times.pop(0)
        is_straggler = len(self.times) > 5 and dt > factor * med
        if is_straggler:
            self.n_stragglers += 1
        return is_straggler


class EnergyMonitor:
    """Per-run sensor simulation + naive/corrected ledger entries."""

    def __init__(self, lcfg: LoopConfig, device_id: str = "dev0"):
        self.profile = profiles.get(lcfg.sensor_profile)
        self.sensor = OnboardSensor(self.profile, seed=lcfg.sensor_seed)
        self.model = ChipPowerModel(idle_w=lcfg.power_idle_w,
                                    peak_w=lcfg.power_peak_w)
        self.ledger = EnergyLedger(device_id=device_id)
        self.calib = CalibrationRecord(
            device_id=device_id, profile_name=self.profile.name,
            update_period_s=self.profile.update_period_s,
            window_s=self.profile.window_s,
            transient_kind="instant",
            rise_time_s=2.5 * self.profile.update_period_s,
            sampled_fraction=self.profile.sampled_fraction)
        self.t = 0.0

    def record_step(self, step: int, wall_s: float, util: float) -> None:
        act = StepActivity(compute_s=wall_s * util, memory_s=wall_s * 0.6,
                           collective_s=wall_s * 0.3)
        # one-step timeline at current simulated clock
        tl = steps_timeline(
            dataclasses.replace(act, compute_s=wall_s * util,
                                memory_s=min(wall_s, act.memory_s),
                                collective_s=min(wall_s, act.collective_s)),
            1, self.model, t0=self.t)
        self.sensor.attach(tl, t_end=self.t + wall_s + 1.0,
                           t_start=self.t)
        ts, vals = self.sensor.poll(self.t, self.t + wall_s, period_s=0.005)
        naive = float(np.sum(vals) * 0.005)
        truth = tl.energy(self.t, self.t + wall_s)
        # corrected estimate: time-shift + window-coverage correction
        W = self.profile.window_s or self.profile.update_period_s
        ts2, vals2 = self.sensor.poll(self.t, self.t + wall_s + W, 0.005)
        corrected = float(np.sum(vals2[ts2 - W >= self.t]) * 0.005)
        sigma = 0.05 * corrected
        self.ledger.append(step, self.t, self.t + wall_s, naive,
                           corrected, sigma)
        self.t += wall_s
        del truth

    def state(self) -> str:
        return self.ledger.to_json()

    def load_state(self, s: str) -> None:
        self.ledger = EnergyLedger.from_json(s)
        if self.ledger.entries:
            self.t = self.ledger.entries[-1].t1


def run_training(cfg: ArchConfig, shape: ShapeCell, tcfg: TrainConfig,
                 lcfg: LoopConfig, ckpt_dir: Optional[str] = None,
                 seed: int = 0) -> Dict[str, Any]:
    """Single-host training driver (examples + integration tests).

    The distributed launcher (launch/train.py) wraps this with mesh
    creation and sharding constraints; on one CPU device it runs as-is.
    """
    from repro.ckpt.checkpoint import CheckpointManager

    rng = jax.random.PRNGKey(seed)
    params = api.init_params(rng, cfg)
    opt_state = adamw.init(params)
    loader = SyntheticTokens(cfg, shape, seed=seed)
    monitor = EnergyMonitor(lcfg)
    stats = StragglerStats()
    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None

    start_step = 0
    if mgr is not None and mgr.latest_step() is not None:
        s = mgr.latest_step()
        specs = {"params": jax.tree_util.tree_map(
                     lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params),
                 "opt": jax.tree_util.tree_map(
                     lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                     opt_state)}
        restored, extras = mgr.restore(s, specs)
        params = restored["params"]
        opt_state = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(opt_state),
            jax.tree_util.tree_leaves(restored["opt"]))
        loader.state = LoaderState.from_dict(extras["loader"])
        monitor.load_state(extras["ledger"])
        start_step = s
        log.info("resumed", step=s)

    step_fn = jax.jit(make_train_step(cfg, tcfg))
    history = []
    it = iter(loader)
    # skip batches consumed before resume is unnecessary: loader.state.step
    # already points at the next batch (pure function of step).
    for step in range(start_step, lcfg.total_steps):
        batch_np = next(it)
        batch = {k: jax.numpy.asarray(v) for k, v in batch_np.items()}
        t0 = time.perf_counter()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        straggler = stats.record(dt, lcfg.straggler_factor)
        monitor.record_step(step, dt, util=0.5)
        if straggler:
            log.warn("straggler step", step=step, dt=f"{dt:.3f}s")
        if step % lcfg.log_every == 0:
            log.info("step", step=step, loss=f"{float(metrics['loss']):.4f}",
                     dt=f"{dt*1e3:.1f}ms")
        history.append(float(metrics["loss"]))
        if mgr is not None and (step + 1) % lcfg.ckpt_every == 0:
            mgr.save_async(step + 1, {"params": params, "opt": opt_state},
                           extras={"loader": loader.state.to_dict(),
                                   "ledger": monitor.state()})
    if mgr is not None:
        mgr.wait()
    return {
        "losses": history,
        "final_loss": history[-1] if history else float("nan"),
        "stragglers": stats.n_stragglers,
        "energy": monitor.ledger.summary(),
        "params": params,
    }
