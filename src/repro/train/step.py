"""train_step / serve_step factories.

``make_train_step`` builds the jit-able full step: loss → grad →
(optional micro-batch accumulation with int8 error-feedback compression)
→ AdamW update.  The same factory serves both the real training loop and
the dry-run lowering (the returned function is pure and shape-polymorphic
over the batch).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import Config
from repro.configs.base import ArchConfig
from repro.models import api
from repro.optim import adamw, compress


@dataclasses.dataclass(frozen=True)
class TrainConfig(Config):
    microbatches: int = 1
    remat: bool = True
    # "full": save nothing inside a layer (min memory);
    # "dots": save matmul outputs (skips recompute of every einsum in the
    # backward pass — lifts useful_ratio toward 1 when HBM affords it)
    remat_policy: str = "full"
    use_pallas: bool = False
    compress_grads: bool = False
    aux_weight: float = 0.01
    optim: adamw.AdamWConfig = adamw.AdamWConfig()


def _split_microbatch(batch: Dict[str, jax.Array], n: int, i: jax.Array
                      ) -> Dict[str, jax.Array]:
    out = {}
    for k, v in batch.items():
        if k in ("pos",):
            out[k] = v
            continue
        axis = 1 if k == "positions3" else 0
        size = v.shape[axis] // n
        out[k] = jax.lax.dynamic_slice_in_dim(v, i * size, size, axis)
    return out


def make_train_step(cfg: ArchConfig, tcfg: TrainConfig) -> Callable:
    """Returns train_step(params, opt_state, batch) ->
    (params, opt_state, metrics)."""

    def loss_for(params, batch):
        total, metrics = api.loss_fn(params, cfg, batch,
                                     use_pallas=tcfg.use_pallas,
                                     remat=tcfg.remat,
                                     remat_policy=tcfg.remat_policy)
        return total, metrics

    grad_fn = jax.value_and_grad(loss_for, has_aux=True)

    def train_step(params, opt_state: adamw.AdamWState,
                   batch: Dict[str, jax.Array]):
        n = tcfg.microbatches
        if n <= 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def micro(carry, i):
                acc, err = carry
                mb = _split_microbatch(batch, n, i)
                (loss_i, m_i), g_i = grad_fn(params, mb)
                if tcfg.compress_grads:
                    g_i, err = compress.tree_quantize_with_feedback(g_i, err)
                acc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32) / n, acc, g_i)
                return (acc, err), (loss_i, m_i["loss"], m_i["aux"])

            acc0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            err0 = compress.init_error_tree(params) if tcfg.compress_grads \
                else acc0
            (grads, _), (losses, plain, auxes) = jax.lax.scan(
                micro, (acc0, err0), jnp.arange(n))
            loss = losses.mean()
            metrics = {"loss": plain.mean(), "aux": auxes.mean()}

        params, opt_state, opt_metrics = adamw.update(
            tcfg.optim, grads, opt_state, params)
        metrics = dict(metrics, **opt_metrics, total=loss)
        return params, opt_state, metrics

    return train_step


def make_eval_step(cfg: ArchConfig, tcfg: TrainConfig) -> Callable:
    def eval_step(params, batch):
        total, metrics = api.loss_fn(params, cfg, batch,
                                     use_pallas=tcfg.use_pallas, remat=False)
        return metrics
    return eval_step


def make_prefill_step(cfg: ArchConfig, max_seq: int,
                      use_pallas: bool = False) -> Callable:
    from repro.models import encdec, transformer

    def prefill_step(params, batch):
        if cfg.encdec:
            cache = encdec.init_cache_from_encoder(
                params, cfg, batch["src_embeds"], max_tgt=max_seq)
            return cache
        logits, cache = transformer.prefill(params, cfg, batch,
                                            max_seq=max_seq,
                                            use_pallas=use_pallas)
        return logits, cache
    return prefill_step


def make_decode_step(cfg: ArchConfig) -> Callable:
    def serve_step(params, cache, batch):
        logits, cache = api.decode_step(params, cfg, cache, batch)
        return logits, cache
    return serve_step
