"""olmo-1b [arXiv:2402.00838]: non-parametric LayerNorm."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="olmo-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=8192, vocab=50_304,
    norm_kind="nonparam_ln", tie_embeddings=True,
)

REDUCED = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab=256)
