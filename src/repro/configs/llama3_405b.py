"""llama3-405b [arXiv:2407.21783]: GQA, 128k vocab — the largest cell."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama3-405b", family="dense",
    n_layers=126, d_model=16_384, n_heads=128, n_kv_heads=8, head_dim=128,
    d_ff=53_248, vocab=128_256,
    rope_theta=500_000.0, tie_embeddings=False,
)

REDUCED = CONFIG.replace(
    n_layers=2, d_model=128, n_heads=8, n_kv_heads=2, head_dim=16,
    d_ff=256, vocab=512)
