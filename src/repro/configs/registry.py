"""Architecture registry: --arch <id> resolution for every launcher."""
from __future__ import annotations

import importlib
from typing import Dict, Tuple

from repro.configs.base import ArchConfig

_MODULES: Dict[str, str] = {
    "granite-moe-3b-a800m": "repro.configs.granite_moe_3b_a800m",
    "qwen2-moe-a2.7b": "repro.configs.qwen2_moe_a2_7b",
    "llama3-405b": "repro.configs.llama3_405b",
    "olmo-1b": "repro.configs.olmo_1b",
    "granite-8b": "repro.configs.granite_8b",
    "gemma2-2b": "repro.configs.gemma2_2b",
    "xlstm-125m": "repro.configs.xlstm_125m",
    "qwen2-vl-7b": "repro.configs.qwen2_vl_7b",
    "seamless-m4t-medium": "repro.configs.seamless_m4t_medium",
    "recurrentgemma-9b": "repro.configs.recurrentgemma_9b",
}

ARCH_IDS: Tuple[str, ...] = tuple(_MODULES)


def get_config(arch_id: str, reduced: bool = False) -> ArchConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch '{arch_id}'; available: {ARCH_IDS}")
    mod = importlib.import_module(_MODULES[arch_id])
    cfg = mod.REDUCED if reduced else mod.CONFIG
    cfg.validate()
    return cfg
