"""granite-moe-3b-a800m [hf:ibm-granite/granite-3.0-1b-a400m-base]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, head_dim=64,
    d_ff=512, moe_d_ff=512, vocab=49155,
    n_experts=40, top_k=8,
    block_pattern=("attn",), tie_embeddings=True,
)

REDUCED = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=96, moe_d_ff=96, vocab=256, n_experts=8, top_k=2)
