"""gemma2-2b [arXiv:2408.00118]: local/global alternating attention +
logit soft-capping."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-2b", family="dense",
    n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4, head_dim=256,
    d_ff=9216, vocab=256_000,
    block_pattern=("attn_local", "attn_global"),
    alt_local_global=True, sliding_window=4096,
    attn_softcap=50.0, final_softcap=30.0,
    act="gelu", tie_embeddings=True,
)

REDUCED = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=256, sliding_window=16)
