"""qwen2-vl-7b [arXiv:2409.12191]: M-RoPE; vision frontend is a STUB —
input_specs() supplies precomputed patch embeddings (see brief)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-7b", family="vlm",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, head_dim=128,
    d_ff=18_944, vocab=152_064,
    mrope=True, input_mode="embeds", tie_embeddings=False,
)

REDUCED = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=256)
