"""granite-8b [arXiv:2405.04324]: llama-arch code model."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-8b", family="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14_336, vocab=49_152,
    tie_embeddings=False,
)

REDUCED = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=256)
