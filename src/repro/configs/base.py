"""ArchConfig — the single model-config schema for all 10 assigned
architectures (plus reduced smoke variants)."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.common.config import Config


@dataclasses.dataclass(frozen=True)
class ArchConfig(Config):
    name: str = ""
    family: str = "dense"        # dense | moe | ssm | vlm | audio | hybrid

    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 2
    n_kv_heads: int = 2
    head_dim: int = 64
    d_ff: int = 256
    vocab: int = 1000

    # block structure: a repeating pattern of block kinds; "attn" blocks
    # include the MLP/MoE; recurrent kinds are self-contained.
    block_pattern: Tuple[str, ...] = ("attn",)

    # attention details
    sliding_window: int = 0          # 0 = full attention
    alt_local_global: bool = False   # gemma2: even layers local, odd global
    attn_softcap: float = 0.0
    final_softcap: float = 0.0
    rope_theta: float = 10_000.0
    mrope: bool = False              # qwen2-vl 3-axis M-RoPE

    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    # experts are padded to a multiple of this so the expert dim shards
    # cleanly over the 16-way model axis (dummy experts get no tokens)
    expert_pad_to: int = 16

    # norms / embeddings
    norm_kind: str = "rmsnorm"       # rmsnorm | layernorm | nonparam_ln
    tie_embeddings: bool = True
    act: str = "silu"

    # encoder-decoder (seamless)
    encdec: bool = False
    n_enc_layers: int = 0
    n_dec_layers: int = 0

    # modality frontend: "tokens" (LM) or "embeds" (VLM/audio stubs)
    input_mode: str = "tokens"

    # recurrent dims
    d_rec: int = 0                   # RG-LRU width (0 => d_model)
    conv_width: int = 4
    mlstm_chunk: int = 128

    # numerics
    param_dtype: str = "bfloat16"

    @property
    def d_rec_actual(self) -> int:
        return self.d_rec or self.d_model

    @property
    def n_experts_padded(self) -> int:
        if self.n_experts == 0:
            return 0
        p = self.expert_pad_to
        return ((self.n_experts + p - 1) // p) * p

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run the 500k-token decode cell? True when no block
        requires unbounded full attention (see DESIGN.md §Arch-applicability)."""
        kinds = set(self.block_pattern)
        if "attn" in kinds and self.sliding_window == 0:
            return False
        if "attn_global" in kinds:   # gemma2 global layers: full attention
            return False
        if self.encdec:              # full cross/self attention
            return False
        return True

    def layer_kinds(self) -> Tuple[str, ...]:
        """Expanded per-layer block kinds, length n_layers."""
        out = []
        i = 0
        while len(out) < self.n_layers:
            out.append(self.block_pattern[i % len(self.block_pattern)])
            i += 1
        return tuple(out)

    def validate(self) -> None:
        assert self.n_heads % self.n_kv_heads == 0, (self.n_heads,
                                                     self.n_kv_heads)
        if self.family == "moe":
            assert self.n_experts > 0 and self.top_k > 0
        if self.encdec:
            assert self.n_enc_layers > 0 and self.n_dec_layers > 0


@dataclasses.dataclass(frozen=True)
class ShapeCell(Config):
    """One assigned input-shape cell."""
    name: str = ""
    seq_len: int = 0
    global_batch: int = 0
    mode: str = "train"      # train | prefill | decode


SHAPES: Tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", 4_096, 256, "train"),
    ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    ShapeCell("decode_32k", 32_768, 128, "decode"),
    ShapeCell("long_500k", 524_288, 1, "decode"),
)


def get_shape(name: str) -> ShapeCell:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


def cell_applicable(cfg: ArchConfig, shape: ShapeCell) -> Tuple[bool, str]:
    """Whether an (arch × shape) cell runs; reason string when skipped."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch: 500k decode is quadratic (skip per brief)"
    return True, ""
