"""recurrentgemma-9b [arXiv:2402.19427]: Griffin — RG-LRU + local
attention, 2 recurrent : 1 local-attn pattern; MQA (kv=1)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, head_dim=256,
    d_ff=12_288, vocab=256_000,
    block_pattern=("rglru", "rglru", "attn"), sliding_window=2048,
    d_rec=4096, act="gelu", tie_embeddings=True,
)

REDUCED = CONFIG.replace(
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
    d_ff=128, vocab=256, sliding_window=16, d_rec=64)
