"""xlstm-125m [arXiv:2405.04517]: alternating mLSTM / sLSTM blocks,
no separate FFN (d_ff=0)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4, head_dim=192,
    d_ff=0, vocab=50_304,
    block_pattern=("mlstm", "slstm"), tie_embeddings=True,
)

REDUCED = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
    vocab=256, mlstm_chunk=16)
