"""seamless-m4t-medium [arXiv:2308.11596]: enc-dec transformer backbone;
the audio frontend is a STUB — input_specs() supplies precomputed frame
embeddings (see brief). 12 encoder + 12 decoder layers."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium", family="audio",
    n_layers=12, d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=4096, vocab=256_206,
    encdec=True, n_enc_layers=12, n_dec_layers=12,
    input_mode="embeds", norm_kind="layernorm", act="gelu",
    tie_embeddings=True,
)

REDUCED = CONFIG.replace(
    n_layers=2, n_enc_layers=2, n_dec_layers=2,
    d_model=64, n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, vocab=256)
