"""qwen2-moe-a2.7b [hf:Qwen/Qwen1.5-MoE-A2.7B]: 4 shared + 60 routed top-4."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1408, moe_d_ff=1408, vocab=151_936,
    n_experts=60, top_k=4, n_shared_experts=4,
    block_pattern=("attn",), tie_embeddings=True,
)

REDUCED = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=96, moe_d_ff=96, vocab=256, n_experts=8, top_k=2,
    n_shared_experts=1)
