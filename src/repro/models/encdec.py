"""Encoder-decoder transformer (seamless-m4t backbone).

The audio frontend is a stub per the brief: ``src_embeds`` are precomputed
frame embeddings.  Encoder = bidirectional self-attention stack; decoder =
causal self-attention + cross-attention.  Both stacks scan over layers.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import (apply_norm, apply_rope, blocked_attention,
                                 decode_attention, gated_mlp)

Params = Dict[str, Any]


def _dt(cfg):
    return jnp.dtype(cfg.param_dtype)


def _attn_proj_specs(cfg: ArchConfig, prefix: str) -> Dict[str, Any]:
    D, Hq, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = _dt(cfg)
    return {
        f"{prefix}wq": jax.ShapeDtypeStruct((D, Hq, hd), dt),
        f"{prefix}wk": jax.ShapeDtypeStruct((D, Hkv, hd), dt),
        f"{prefix}wv": jax.ShapeDtypeStruct((D, Hkv, hd), dt),
        f"{prefix}wo": jax.ShapeDtypeStruct((Hq, hd, D), dt),
    }


def _mlp_specs(cfg: ArchConfig) -> Dict[str, Any]:
    D, F = cfg.d_model, cfg.d_ff
    dt = _dt(cfg)
    return {"w_gate": jax.ShapeDtypeStruct((D, F), dt),
            "w_up": jax.ShapeDtypeStruct((D, F), dt),
            "w_down": jax.ShapeDtypeStruct((F, D), dt)}


def _enc_layer_specs(cfg: ArchConfig) -> Dict[str, Any]:
    dt = _dt(cfg)
    s = _attn_proj_specs(cfg, "")
    s["mlp"] = _mlp_specs(cfg)
    s["ln1"] = jax.ShapeDtypeStruct((cfg.d_model,), dt)
    s["ln2"] = jax.ShapeDtypeStruct((cfg.d_model,), dt)
    return s


def _dec_layer_specs(cfg: ArchConfig) -> Dict[str, Any]:
    dt = _dt(cfg)
    s = _attn_proj_specs(cfg, "")
    s.update(_attn_proj_specs(cfg, "x_"))
    s["mlp"] = _mlp_specs(cfg)
    for k in ("ln1", "ln_x", "ln2"):
        s[k] = jax.ShapeDtypeStruct((cfg.d_model,), dt)
    return s


def _stack(tree, n):
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), tree)


def param_specs(cfg: ArchConfig) -> Params:
    dt = _dt(cfg)
    return {
        "embed": jax.ShapeDtypeStruct((cfg.vocab, cfg.d_model), dt),
        "enc": _stack(_enc_layer_specs(cfg), cfg.n_enc_layers),
        "dec": _stack(_dec_layer_specs(cfg), cfg.n_dec_layers),
        "enc_norm": jax.ShapeDtypeStruct((cfg.d_model,), dt),
        "final_norm": jax.ShapeDtypeStruct((cfg.d_model,), dt),
    }


def _proj(p, prefix, h):
    q = jnp.einsum("bsd,dhe->bshe", h, p[f"{prefix}wq"])
    k = jnp.einsum("bsd,dhe->bshe", h, p[f"{prefix}wk"])
    v = jnp.einsum("bsd,dhe->bshe", h, p[f"{prefix}wv"])
    return q, k, v


def encode(params: Params, cfg: ArchConfig, src_embeds: jax.Array) -> jax.Array:
    x = src_embeds.astype(_dt(cfg))
    pos = jnp.arange(x.shape[1], dtype=jnp.int32)[None]

    def body(x, p):
        h = apply_norm(cfg.norm_kind, x, p["ln1"])
        q, k, v = _proj(p, "", h)
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
        att = blocked_attention(q, k, v, causal=False)
        x = x + jnp.einsum("bshe,hed->bsd", att, p["wo"])
        h2 = apply_norm(cfg.norm_kind, x, p["ln2"])
        x = x + gated_mlp(h2, p["mlp"]["w_gate"], p["mlp"]["w_up"],
                          p["mlp"]["w_down"], act=cfg.act)
        return x, None

    body_ck = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body_ck, x, params["enc"])
    return apply_norm(cfg.norm_kind, x, params["enc_norm"])


def _dec_layer(cfg: ArchConfig, p: Params, x: jax.Array, enc_out: jax.Array,
               pos: jax.Array) -> jax.Array:
    h = apply_norm(cfg.norm_kind, x, p["ln1"])
    q, k, v = _proj(p, "", h)
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    att = blocked_attention(q, k, v, causal=True)
    x = x + jnp.einsum("bshe,hed->bsd", att, p["wo"])

    hx = apply_norm(cfg.norm_kind, x, p["ln_x"])
    qx = jnp.einsum("bsd,dhe->bshe", hx, p["x_wq"])
    kx = jnp.einsum("bsd,dhe->bshe", enc_out, p["x_wk"])
    vx = jnp.einsum("bsd,dhe->bshe", enc_out, p["x_wv"])
    attx = blocked_attention(qx, kx, vx, causal=False)
    x = x + jnp.einsum("bshe,hed->bsd", attx, p["x_wo"])

    h2 = apply_norm(cfg.norm_kind, x, p["ln2"])
    return x + gated_mlp(h2, p["mlp"]["w_gate"], p["mlp"]["w_up"],
                         p["mlp"]["w_down"], act=cfg.act)


def forward(params: Params, cfg: ArchConfig, batch: Dict[str, jax.Array]
            ) -> Tuple[jax.Array, jax.Array]:
    """batch: src_embeds [B,Ss,D], tokens [B,St] → logits [B,St,V]."""
    enc_out = encode(params, cfg, batch["src_embeds"])
    x = jnp.take(params["embed"], batch["tokens"], axis=0)
    x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    pos = jnp.arange(x.shape[1], dtype=jnp.int32)[None]

    def body(x, p):
        return _dec_layer(cfg, p, x, enc_out, pos), None

    body_ck = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body_ck, x, params["dec"])
    x = apply_norm(cfg.norm_kind, x, params["final_norm"])
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"],
                        preferred_element_type=jnp.float32)
    return logits, jnp.zeros((), jnp.float32)


def lm_loss(params: Params, cfg: ArchConfig, batch: Dict[str, jax.Array]
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    logits, aux = forward(params, cfg, batch)
    lb = batch["tokens"][:, 1:]
    lg = logits[:, :-1]
    logz = jax.nn.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, lb[..., None], axis=-1)[..., 0]
    loss = jnp.mean(logz - gold)
    return loss, {"loss": loss, "aux": aux}


# -- decoding ----------------------------------------------------------------

def cache_specs(cfg: ArchConfig, batch: int, src_len: int,
                max_tgt: int) -> Params:
    dt = _dt(cfg)
    Hkv, hd = cfg.n_kv_heads, cfg.head_dim
    L = cfg.n_dec_layers
    return {
        "enc_out": jax.ShapeDtypeStruct((batch, src_len, cfg.d_model), dt),
        "self_k": jax.ShapeDtypeStruct((L, batch, max_tgt, Hkv, hd), dt),
        "self_v": jax.ShapeDtypeStruct((L, batch, max_tgt, Hkv, hd), dt),
        "cross_k": jax.ShapeDtypeStruct((L, batch, src_len, Hkv, hd), dt),
        "cross_v": jax.ShapeDtypeStruct((L, batch, src_len, Hkv, hd), dt),
    }


def init_cache_from_encoder(params: Params, cfg: ArchConfig,
                            src_embeds: jax.Array, max_tgt: int) -> Params:
    enc_out = encode(params, cfg, src_embeds)
    B, Ss = enc_out.shape[0], enc_out.shape[1]
    kx = jnp.einsum("bsd,ldhe->lbshe", enc_out,
                    params["dec"]["x_wk"])
    vx = jnp.einsum("bsd,ldhe->lbshe", enc_out,
                    params["dec"]["x_wv"])
    dt = _dt(cfg)
    L = cfg.n_dec_layers
    z = jnp.zeros((L, B, max_tgt, cfg.n_kv_heads, cfg.head_dim), dt)
    return {"enc_out": enc_out, "self_k": z, "self_v": z,
            "cross_k": kx.astype(dt), "cross_v": vx.astype(dt)}


def decode_step(params: Params, cfg: ArchConfig, cache: Params,
                batch: Dict[str, jax.Array]) -> Tuple[jax.Array, Params]:
    """tokens [B,1], pos [1] → (logits [B,1,V], cache)."""
    x = jnp.take(params["embed"], batch["tokens"], axis=0)
    x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    pos = batch["pos"].astype(jnp.int32)
    B = x.shape[0]
    Tmax = cache["self_k"].shape[2]
    cache_len = jnp.minimum(pos[0] + 1, Tmax) * jnp.ones((B,), jnp.int32)
    src_len = cache["cross_k"].shape[2] * jnp.ones((B,), jnp.int32)

    def body(x, xs):
        p, sk, sv, ck, cv = xs
        h = apply_norm(cfg.norm_kind, x, p["ln1"])
        q, k, v = _proj(p, "", h)
        q = apply_rope(q, pos[None], cfg.rope_theta)
        k = apply_rope(k, pos[None], cfg.rope_theta)
        slot = pos[0] % Tmax
        sk = jax.lax.dynamic_update_slice(sk, k, (0, slot, 0, 0))
        sv = jax.lax.dynamic_update_slice(sv, v, (0, slot, 0, 0))
        att = decode_attention(q, sk, sv, cache_len)
        x = x + jnp.einsum("bshe,hed->bsd", att, p["wo"])
        hx = apply_norm(cfg.norm_kind, x, p["ln_x"])
        qx = jnp.einsum("bsd,dhe->bshe", hx, p["x_wq"])
        attx = decode_attention(qx, ck, cv, src_len)
        x = x + jnp.einsum("bshe,hed->bsd", attx, p["x_wo"])
        h2 = apply_norm(cfg.norm_kind, x, p["ln2"])
        x = x + gated_mlp(h2, p["mlp"]["w_gate"], p["mlp"]["w_up"],
                          p["mlp"]["w_down"], act=cfg.act)
        return x, (sk, sv)

    x, (new_sk, new_sv) = jax.lax.scan(
        body, x, (params["dec"], cache["self_k"], cache["self_v"],
                  cache["cross_k"], cache["cross_v"]))
    x = apply_norm(cfg.norm_kind, x, params["final_norm"])
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"],
                        preferred_element_type=jnp.float32)
    cache = dict(cache, self_k=new_sk, self_v=new_sv)
    return logits, cache
