"""Unified model API: dispatches decoder-only vs encoder-decoder, and
builds abstract input specs for every (arch × shape) dry-run cell."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeCell
from repro.models import encdec, transformer

Params = Dict[str, Any]


def param_specs(cfg: ArchConfig) -> Params:
    return encdec.param_specs(cfg) if cfg.encdec else \
        transformer.param_specs(cfg)


def init_params(rng: jax.Array, cfg: ArchConfig) -> Params:
    if not cfg.encdec:
        return transformer.init_params(rng, cfg)
    specs = encdec.param_specs(cfg)
    flat, treedef = jax.tree_util.tree_flatten_with_path(specs)
    keys = jax.random.split(rng, len(flat))
    leaves = []
    for key, (path, s) in zip(keys, flat):
        name = "/".join(str(getattr(p, "key", p)) for p in path)
        leaves.append(transformer._init_leaf(key, name, s))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def loss_fn(params: Params, cfg: ArchConfig, batch: Dict[str, jax.Array],
            use_pallas: bool = False, remat: bool = True,
            remat_policy: str = "full"):
    if cfg.encdec:
        return encdec.lm_loss(params, cfg, batch)
    return transformer.lm_loss(params, cfg, batch, use_pallas, remat,
                               remat_policy=remat_policy)


def forward(params: Params, cfg: ArchConfig, batch: Dict[str, jax.Array],
            **kw):
    if cfg.encdec:
        return encdec.forward(params, cfg, batch)
    return transformer.forward(params, cfg, batch, **kw)


def decode_step(params: Params, cfg: ArchConfig, cache: Params,
                batch: Dict[str, jax.Array]):
    if cfg.encdec:
        return encdec.decode_step(params, cfg, cache, batch)
    return transformer.decode_step(params, cfg, cache, batch)


def cache_specs(cfg: ArchConfig, batch: int, max_seq: int) -> Params:
    if cfg.encdec:
        # encoder side sees the same seq budget; decode grows up to max_seq
        return encdec.cache_specs(cfg, batch, src_len=max_seq,
                                  max_tgt=max_seq)
    return transformer.cache_specs(cfg, batch, max_seq)


def init_cache(cfg: ArchConfig, batch: int, max_seq: int) -> Params:
    return jax.tree_util.tree_map(lambda s: jnp.zeros(s.shape, s.dtype),
                                  cache_specs(cfg, batch, max_seq))


# ---------------------------------------------------------------------------
# Abstract input specs (ShapeDtypeStruct) per shape cell — dry-run inputs
# ---------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape: ShapeCell) -> Dict[str, Any]:
    """Stand-ins for every model input of the given cell (no allocation)."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    dt = jnp.dtype(cfg.param_dtype)

    if shape.mode in ("train", "prefill"):
        if cfg.encdec:
            return {"src_embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), dt),
                    "tokens": jax.ShapeDtypeStruct((B, S), i32)}
        if cfg.input_mode == "embeds":
            batch = {"embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), dt),
                     "labels": jax.ShapeDtypeStruct((B, S), i32)}
            if cfg.mrope:
                batch["positions3"] = jax.ShapeDtypeStruct((3, B, S), i32)
            return batch
        return {"tokens": jax.ShapeDtypeStruct((B, S), i32)}

    # decode: one new token against a seq_len-deep cache
    if cfg.encdec:
        batch = {"tokens": jax.ShapeDtypeStruct((B, 1), i32),
                 "pos": jax.ShapeDtypeStruct((1,), i32)}
        return batch
    if cfg.input_mode == "embeds":
        batch = {"embeds": jax.ShapeDtypeStruct((B, 1, cfg.d_model), dt),
                 "pos": jax.ShapeDtypeStruct((1,), i32)}
        if cfg.mrope:
            batch["positions3"] = jax.ShapeDtypeStruct((3, B, 1), i32)
        return batch
    return {"tokens": jax.ShapeDtypeStruct((B, 1), i32),
            "pos": jax.ShapeDtypeStruct((1,), i32)}


def concrete_inputs(rng: jax.Array, cfg: ArchConfig,
                    shape: ShapeCell) -> Dict[str, jax.Array]:
    """Real random inputs matching input_specs (smoke tests / examples)."""
    specs = input_specs(cfg, shape)
    out = {}
    for k, s in specs.items():
        if s.dtype == jnp.int32:
            rng, sub = jax.random.split(rng)
            hi = cfg.vocab if k in ("tokens", "labels") else max(shape.seq_len, 2)
            out[k] = jax.random.randint(sub, s.shape, 0, hi, jnp.int32)
        else:
            rng, sub = jax.random.split(rng)
            out[k] = jax.random.normal(sub, s.shape, jnp.float32).astype(s.dtype)
    if "pos" in out:
        out["pos"] = jnp.asarray([shape.seq_len - 1], jnp.int32)
    return out
