"""Decoder-only LM supporting every assigned block family.

Layers are grouped into *pattern periods* (one repetition of
``cfg.block_pattern``) with parameters stacked over periods; the forward
pass is a single ``lax.scan`` over periods so HLO size is O(1) in depth —
required to compile llama3-405b × 512 devices on a CPU host.  A remainder
prefix (e.g. recurrentgemma's 38 = 12·3 + 2) becomes a second, smaller
scan group.

Three entry points per architecture:
  * :func:`forward`      — full-sequence logits (+ MoE aux loss): train path
  * :func:`prefill`      — forward that also fills the decode cache
  * :func:`decode_step`  — one token against the cache: serve path

Caches for local-attention layers are ring buffers of the window size, so
recurrentgemma's 500k-token decode carries O(window) state, not O(seq).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import recurrent as rec
from repro.models.layers import (apply_mrope, apply_norm, apply_rope,
                                 blocked_attention, decode_attention,
                                 gated_mlp)
from repro.models.moe import moe_ffn
from repro.distributed.act_shard import constrain

Params = Dict[str, Any]


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.param_dtype)


def _norm_has_scale(cfg: ArchConfig) -> bool:
    return cfg.norm_kind in ("rmsnorm", "layernorm")


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

def _attn_specs(cfg: ArchConfig, moe: bool) -> Dict[str, Any]:
    D, Hq, Hkv, hd, F = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                         cfg.head_dim, cfg.d_ff)
    dt = _dtype(cfg)
    s: Dict[str, Any] = {
        "wq": jax.ShapeDtypeStruct((D, Hq, hd), dt),
        "wk": jax.ShapeDtypeStruct((D, Hkv, hd), dt),
        "wv": jax.ShapeDtypeStruct((D, Hkv, hd), dt),
        "wo": jax.ShapeDtypeStruct((Hq, hd, D), dt),
    }
    if _norm_has_scale(cfg):
        s["ln1"] = jax.ShapeDtypeStruct((D,), dt)
        s["ln2"] = jax.ShapeDtypeStruct((D,), dt)
    if moe:
        E, Fm = cfg.n_experts, cfg.moe_d_ff or F
        Ep = cfg.n_experts_padded      # dummy experts receive no tokens
        s["moe"] = {
            "router": jax.ShapeDtypeStruct((D, E), dt),
            "w_gate": jax.ShapeDtypeStruct((Ep, D, Fm), dt),
            "w_up": jax.ShapeDtypeStruct((Ep, D, Fm), dt),
            "w_down": jax.ShapeDtypeStruct((Ep, Fm, D), dt),
        }
        if cfg.n_shared_experts > 0:
            Fs = Fm * cfg.n_shared_experts
            s["moe"]["shared_gate"] = jax.ShapeDtypeStruct((D, Fs), dt)
            s["moe"]["shared_up"] = jax.ShapeDtypeStruct((D, Fs), dt)
            s["moe"]["shared_down"] = jax.ShapeDtypeStruct((Fs, D), dt)
    elif F > 0:
        s["mlp"] = {
            "w_gate": jax.ShapeDtypeStruct((D, F), dt),
            "w_up": jax.ShapeDtypeStruct((D, F), dt),
            "w_down": jax.ShapeDtypeStruct((F, D), dt),
        }
    return s


def _rglru_specs(cfg: ArchConfig) -> Dict[str, Any]:
    D, Dr, K, F = cfg.d_model, cfg.d_rec_actual, cfg.conv_width, cfg.d_ff
    dt = _dtype(cfg)
    s = {
        "w_gate": jax.ShapeDtypeStruct((D, Dr), dt),
        "w_rec": jax.ShapeDtypeStruct((D, Dr), dt),
        "conv": jax.ShapeDtypeStruct((K, Dr), dt),
        "w_a": jax.ShapeDtypeStruct((Dr, Dr), dt),
        "w_x": jax.ShapeDtypeStruct((Dr, Dr), dt),
        "lam": jax.ShapeDtypeStruct((Dr,), jnp.float32),
        "w_out": jax.ShapeDtypeStruct((Dr, D), dt),
    }
    if _norm_has_scale(cfg):
        s["ln1"] = jax.ShapeDtypeStruct((D,), dt)
        s["ln2"] = jax.ShapeDtypeStruct((D,), dt)
    if F > 0:
        s["mlp"] = {
            "w_gate": jax.ShapeDtypeStruct((D, F), dt),
            "w_up": jax.ShapeDtypeStruct((D, F), dt),
            "w_down": jax.ShapeDtypeStruct((F, D), dt),
        }
    return s


def _mlstm_specs(cfg: ArchConfig) -> Dict[str, Any]:
    D, H, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    dt = _dtype(cfg)
    s = {
        "wq": jax.ShapeDtypeStruct((D, H, hd), dt),
        "wk": jax.ShapeDtypeStruct((D, H, hd), dt),
        "wv": jax.ShapeDtypeStruct((D, H, hd), dt),
        "w_if": jax.ShapeDtypeStruct((D, 2 * H), jnp.float32),
        "w_og": jax.ShapeDtypeStruct((D, D), dt),
        "w_out": jax.ShapeDtypeStruct((H, hd, D), dt),
    }
    if _norm_has_scale(cfg):
        s["ln1"] = jax.ShapeDtypeStruct((D,), dt)
    return s


def _slstm_specs(cfg: ArchConfig) -> Dict[str, Any]:
    D = cfg.d_model
    dt = jnp.float32  # recurrent weights stay fp32 for stability
    s = {k: jax.ShapeDtypeStruct((D, D), dt)
         for k in ("w_z", "w_i", "w_f", "w_o", "r_z", "r_i", "r_f", "r_o")}
    if _norm_has_scale(cfg):
        s["ln1"] = jax.ShapeDtypeStruct((cfg.d_model,), _dtype(cfg))
    return s


def _block_specs(cfg: ArchConfig, kind: str) -> Dict[str, Any]:
    if kind in ("attn", "attn_local", "attn_global"):
        return _attn_specs(cfg, moe=cfg.family == "moe")
    if kind == "rglru":
        return _rglru_specs(cfg)
    if kind == "mlstm":
        return _mlstm_specs(cfg)
    if kind == "slstm":
        return _slstm_specs(cfg)
    raise ValueError(f"unknown block kind {kind}")


def _stack_specs(specs: Dict[str, Any], n: int) -> Dict[str, Any]:
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), specs)


def group_layout(cfg: ArchConfig) -> Tuple[int, int]:
    """(n_full_periods, n_remainder_layers)."""
    per = len(cfg.block_pattern)
    return cfg.n_layers // per, cfg.n_layers % per


def param_specs(cfg: ArchConfig) -> Params:
    dt = _dtype(cfg)
    D, V = cfg.d_model, cfg.vocab
    n_per, n_rem = group_layout(cfg)
    specs: Params = {
        "embed": jax.ShapeDtypeStruct((V, D), dt),
    }
    blocks = {}
    for i, kind in enumerate(cfg.block_pattern):
        blocks[f"p{i}_{kind}"] = _stack_specs(_block_specs(cfg, kind), n_per)
    specs["blocks"] = blocks
    if n_rem:
        specs["rem"] = {
            f"r{i}_{cfg.block_pattern[i]}": _block_specs(
                cfg, cfg.block_pattern[i])
            for i in range(n_rem)}
    if _norm_has_scale(cfg):
        specs["final_norm"] = jax.ShapeDtypeStruct((D,), dt)
    if not cfg.tie_embeddings:
        specs["lm_head"] = jax.ShapeDtypeStruct((D, V), dt)
    return specs


def init_params(rng: jax.Array, cfg: ArchConfig) -> Params:
    """Real initialisation (smoke tests / example training runs)."""
    specs = param_specs(cfg)
    flat, treedef = jax.tree_util.tree_flatten_with_path(specs)
    keys = jax.random.split(rng, len(flat))
    leaves = []
    for key, (path, s) in zip(keys, flat):
        name = "/".join(str(getattr(p, "key", p)) for p in path)
        leaves.append(_init_leaf(key, name, s))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _init_leaf(key: jax.Array, name: str, s: jax.ShapeDtypeStruct):
    if name.endswith("lam"):
        # RG-LRU: a = exp(-c softplus(lam)) in (0.9, 0.999) at r=0.5 paths
        a = jax.random.uniform(key, s.shape, jnp.float32, 0.9, 0.999)
        sp = -jnp.log(a) / rec.RGLRU_C * 2.0
        return jnp.log(jnp.expm1(jnp.maximum(sp, 1e-6)))
    if "ln" in name.split("/")[-1] or name.endswith("final_norm"):
        return jnp.zeros(s.shape, s.dtype)
    if name.endswith("conv"):
        return (jax.random.normal(key, s.shape, jnp.float32) * 0.1
                ).astype(s.dtype)
    fan_in = s.shape[-2] if len(s.shape) >= 2 else s.shape[-1]
    if len(s.shape) >= 3:
        fan_in = int(np.prod(s.shape[:-1])) // (s.shape[0] if len(s.shape) == 4 else 1)
        fan_in = max(fan_in, 1)
    std = 0.02 if "embed" in name else 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, s.shape, jnp.float32) * std).astype(s.dtype)


def param_count(cfg: ArchConfig) -> int:
    return sum(int(np.prod(s.shape))
               for s in jax.tree_util.tree_leaves(param_specs(cfg)))


def active_param_count(cfg: ArchConfig) -> int:
    """Active parameters per token (MoE counts top-k + shared experts only);
    used for MODEL_FLOPS = 6·N_active·D in the roofline."""
    total = 0
    for path, s in jax.tree_util.tree_flatten_with_path(param_specs(cfg))[0]:
        name = "/".join(str(getattr(p, "key", p)) for p in path)
        n = int(np.prod(s.shape))
        if "/moe/" in name or name.startswith("moe"):
            if any(k in name for k in ("w_gate", "w_up", "w_down")) \
                    and "shared" not in name:
                n = n * cfg.top_k // max(cfg.n_experts_padded, 1)
        if "embed" in name or "lm_head" in name:
            continue  # 6ND convention excludes embeddings
        total += n
    return total


# ---------------------------------------------------------------------------
# Block applications (train/prefill path)
# ---------------------------------------------------------------------------

def _window_for(cfg: ArchConfig, kind: str) -> int:
    if kind == "attn_global":
        return 0
    if kind in ("attn_local", "attn"):
        return cfg.sliding_window
    return 0


def _project_qkv(p: Params, h: jax.Array):
    q = constrain(jnp.einsum("bsd,dhe->bshe", h, p["wq"]), "bshe")
    k = constrain(jnp.einsum("bsd,dhe->bshe", h, p["wk"]), "bshe")
    v = constrain(jnp.einsum("bsd,dhe->bshe", h, p["wv"]), "bshe")
    return q, k, v


def _apply_attn_block(cfg: ArchConfig, kind: str, p: Params, x: jax.Array,
                      pos: jax.Array, pos3: Optional[jax.Array],
                      ) -> Tuple[jax.Array, jax.Array, Tuple]:
    """Returns (x_out, aux_loss, (k, v)) — k/v exposed for prefill caching."""
    h = apply_norm(cfg.norm_kind, x, p.get("ln1"))
    q, k, v = _project_qkv(p, h)
    if cfg.mrope and pos3 is not None:
        q = apply_mrope(q, pos3, theta=cfg.rope_theta)
        k = apply_mrope(k, pos3, theta=cfg.rope_theta)
    else:
        q = apply_rope(q, pos, theta=cfg.rope_theta)
        k = apply_rope(k, pos, theta=cfg.rope_theta)
    att = blocked_attention(q, k, v, causal=True,
                            window=_window_for(cfg, kind),
                            softcap=cfg.attn_softcap)
    x = constrain(x + jnp.einsum("bshe,hed->bsd", att, p["wo"]), "bsd")

    h2 = apply_norm(cfg.norm_kind, x, p.get("ln2"))
    aux = jnp.zeros((), jnp.float32)
    if "moe" in p:
        y, aux = moe_ffn(h2, p["moe"], n_experts=cfg.n_experts,
                         top_k=cfg.top_k,
                         capacity_factor=cfg.capacity_factor, act=cfg.act)
        x = x + y
    elif "mlp" in p:
        x = x + gated_mlp(h2, p["mlp"]["w_gate"], p["mlp"]["w_up"],
                          p["mlp"]["w_down"], act=cfg.act)
    return x, aux, (k, v)


def _apply_rglru_block(cfg: ArchConfig, p: Params, x: jax.Array,
                       use_pallas: bool) -> jax.Array:
    h = apply_norm(cfg.norm_kind, x, p.get("ln1"))
    x = x + rec.rglru_block(h, p, use_pallas=use_pallas).astype(x.dtype)
    if "mlp" in p:
        h2 = apply_norm(cfg.norm_kind, x, p.get("ln2"))
        x = x + gated_mlp(h2, p["mlp"]["w_gate"], p["mlp"]["w_up"],
                          p["mlp"]["w_down"], act=cfg.act)
    return x


def _apply_mlstm_block(cfg: ArchConfig, p: Params, x: jax.Array) -> jax.Array:
    h = apply_norm(cfg.norm_kind, x, p.get("ln1"))
    q, k, v = _project_qkv(p, h)
    gates = jnp.einsum("bsd,dg->bsg", h.astype(jnp.float32), p["w_if"])
    log_i, log_f = jnp.split(gates, 2, axis=-1)       # [B,S,H]
    log_f = jax.nn.log_sigmoid(log_f)
    y = rec.mlstm_parallel(q, k, v, log_f, log_i, chunk=cfg.mlstm_chunk)
    og = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", h, p["w_og"]))
    out = jnp.einsum("bshe,hed->bsd", y, p["w_out"])
    return x + (out * og).astype(x.dtype)


def _apply_slstm_block(cfg: ArchConfig, p: Params, x: jax.Array) -> jax.Array:
    h = apply_norm(cfg.norm_kind, x, p.get("ln1"))
    y, _ = rec.slstm_seq(h, p)
    return x + y.astype(x.dtype)


def apply_block(cfg: ArchConfig, kind: str, p: Params, x: jax.Array,
                pos: jax.Array, pos3: Optional[jax.Array],
                use_pallas: bool = False) -> Tuple[jax.Array, jax.Array]:
    if kind.startswith("attn"):
        x, aux, _ = _apply_attn_block(cfg, kind, p, x, pos, pos3)
        return x, aux
    if kind == "rglru":
        return _apply_rglru_block(cfg, p, x, use_pallas), jnp.zeros((), jnp.float32)
    if kind == "mlstm":
        return _apply_mlstm_block(cfg, p, x), jnp.zeros((), jnp.float32)
    if kind == "slstm":
        return _apply_slstm_block(cfg, p, x), jnp.zeros((), jnp.float32)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Forward (train) path
# ---------------------------------------------------------------------------

def embed_inputs(params: Params, cfg: ArchConfig, batch: Dict[str, jax.Array]
                 ) -> Tuple[jax.Array, jax.Array, Optional[jax.Array]]:
    if cfg.input_mode == "embeds":
        x = batch["embeds"].astype(_dtype(cfg))
    else:
        x = jnp.take(params["embed"], batch["tokens"], axis=0)
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    x = constrain(x, "bsd")
    S = x.shape[1]
    pos = batch.get("positions")
    if pos is None:
        pos = jnp.arange(S, dtype=jnp.int32)[None, :]
    pos3 = batch.get("positions3")
    return x, pos, pos3


def unembed(params: Params, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    x = apply_norm(cfg.norm_kind, x, params.get("final_norm"))
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"],
                            preferred_element_type=jnp.float32)
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"],
                            preferred_element_type=jnp.float32)
    if cfg.final_softcap > 0:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    return constrain(logits, "bsv")


def forward(params: Params, cfg: ArchConfig, batch: Dict[str, jax.Array],
            use_pallas: bool = False,
            remat: bool = True,
            remat_policy: str = "full") -> Tuple[jax.Array, jax.Array]:
    """Full-sequence logits. Returns (logits [B,S,V] f32, aux_loss)."""
    x, pos, pos3 = embed_inputs(params, cfg, batch)
    n_per, n_rem = group_layout(cfg)

    def period_body(carry, period_params):
        x, aux = carry
        for i, kind in enumerate(cfg.block_pattern):
            x, a = apply_block(cfg, kind, period_params[f"p{i}_{kind}"],
                               x, pos, pos3, use_pallas)
            aux = aux + a
        return (x, aux), None

    policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
              if remat_policy == "dots"
              else jax.checkpoint_policies.nothing_saveable)
    body = jax.checkpoint(period_body, policy=policy) \
        if remat else period_body

    aux0 = jnp.zeros((), jnp.float32)
    if n_per > 0:
        (x, aux), _ = jax.lax.scan(body, (x, aux0), params["blocks"])
    else:
        aux = aux0
    if n_rem:
        for i in range(n_rem):
            kind = cfg.block_pattern[i]
            x, a = apply_block(cfg, kind, params["rem"][f"r{i}_{kind}"],
                               x, pos, pos3, use_pallas)
            aux = aux + a
    return unembed(params, cfg, x), aux


def lm_loss(params: Params, cfg: ArchConfig, batch: Dict[str, jax.Array],
            use_pallas: bool = False, remat: bool = True,
            aux_weight: float = 0.01,
            remat_policy: str = "full") -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Next-token cross entropy (+ MoE aux). Labels default to shifted
    tokens; `embeds` inputs must supply explicit labels."""
    logits, aux = forward(params, cfg, batch, use_pallas, remat,
                          remat_policy)
    if "labels" in batch:
        labels = batch["labels"]
        valid = labels >= 0
        labels = jnp.maximum(labels, 0)
        lg, lb = logits, labels
    else:
        lg = logits[:, :-1]
        lb = batch["tokens"][:, 1:]
        valid = jnp.ones_like(lb, dtype=bool)
    logz = jax.nn.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, lb[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * valid
    loss = nll.sum() / jnp.maximum(valid.sum(), 1)
    total = loss + aux_weight * aux
    return total, {"loss": loss, "aux": aux}


# ---------------------------------------------------------------------------
# Decode cache
# ---------------------------------------------------------------------------

def _cache_len_for(cfg: ArchConfig, kind: str, max_seq: int) -> int:
    w = _window_for(cfg, kind)
    return min(max_seq, w) if w > 0 else max_seq


def cache_specs(cfg: ArchConfig, batch: int, max_seq: int) -> Params:
    """Abstract decode-state tree (ShapeDtypeStructs)."""
    dt = _dtype(cfg)
    n_per, n_rem = group_layout(cfg)

    def block_cache(kind: str) -> Dict[str, Any]:
        if kind.startswith("attn"):
            L = _cache_len_for(cfg, kind, max_seq)
            return {
                "k": jax.ShapeDtypeStruct((batch, L, cfg.n_kv_heads,
                                           cfg.head_dim), dt),
                "v": jax.ShapeDtypeStruct((batch, L, cfg.n_kv_heads,
                                           cfg.head_dim), dt),
            }
        if kind == "rglru":
            Dr, K = cfg.d_rec_actual, cfg.conv_width
            return {"h": jax.ShapeDtypeStruct((batch, Dr), jnp.float32),
                    "conv": jax.ShapeDtypeStruct((batch, K - 1, Dr), dt)}
        if kind == "mlstm":
            H, hd = cfg.n_heads, cfg.head_dim
            return {"S": jax.ShapeDtypeStruct((batch, H, hd, hd), jnp.float32),
                    "n": jax.ShapeDtypeStruct((batch, H, hd), jnp.float32),
                    "m": jax.ShapeDtypeStruct((batch, H), jnp.float32),
                    }
        if kind == "slstm":
            D = cfg.d_model
            return {k: jax.ShapeDtypeStruct((batch, D), jnp.float32)
                    for k in ("c", "n", "h", "m")}
        raise ValueError(kind)

    def stack(tree, n):
        return jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), tree)

    cache: Params = {"blocks": {
        f"p{i}_{kind}": stack(block_cache(kind), n_per)
        for i, kind in enumerate(cfg.block_pattern)}}
    if n_rem:
        cache["rem"] = {f"r{i}_{cfg.block_pattern[i]}":
                        block_cache(cfg.block_pattern[i])
                        for i in range(n_rem)}
    return cache


def init_cache(cfg: ArchConfig, batch: int, max_seq: int) -> Params:
    return jax.tree_util.tree_map(lambda s: jnp.zeros(s.shape, s.dtype),
                                  cache_specs(cfg, batch, max_seq))


# ---------------------------------------------------------------------------
# Decode step
# ---------------------------------------------------------------------------

def _decode_attn(cfg: ArchConfig, kind: str, p: Params, c: Params,
                 x: jax.Array, pos: jax.Array,
                 pos3: Optional[jax.Array]) -> Tuple[jax.Array, Params]:
    """x [B,1,D]; ring-buffer cache write + masked attention."""
    h = apply_norm(cfg.norm_kind, x, p.get("ln1"))
    q, k, v = _project_qkv(p, h)
    if cfg.mrope and pos3 is not None:
        q = apply_mrope(q, pos3, theta=cfg.rope_theta)
        k = apply_mrope(k, pos3, theta=cfg.rope_theta)
    else:
        q = apply_rope(q, pos[None, :], theta=cfg.rope_theta)
        k = apply_rope(k, pos[None, :], theta=cfg.rope_theta)
    L = c["k"].shape[1]
    slot = (pos[0] % L).astype(jnp.int32)
    kc = jax.lax.dynamic_update_slice(c["k"], k, (0, slot, 0, 0))
    vc = jax.lax.dynamic_update_slice(c["v"], v, (0, slot, 0, 0))
    cache_len = jnp.minimum(pos[0] + 1, L)
    att = decode_attention(q, kc, vc,
                           cache_len * jnp.ones((x.shape[0],), jnp.int32),
                           softcap=cfg.attn_softcap)
    x = x + jnp.einsum("bshe,hed->bsd", att, p["wo"])
    h2 = apply_norm(cfg.norm_kind, x, p.get("ln2"))
    if "moe" in p:
        y, _ = moe_ffn(h2, p["moe"], n_experts=cfg.n_experts,
                       top_k=cfg.top_k,
                       capacity_factor=cfg.capacity_factor, act=cfg.act)
        x = x + y
    elif "mlp" in p:
        x = x + gated_mlp(h2, p["mlp"]["w_gate"], p["mlp"]["w_up"],
                          p["mlp"]["w_down"], act=cfg.act)
    return x, {"k": kc, "v": vc}


def _decode_rglru(cfg: ArchConfig, p: Params, c: Params,
                  x: jax.Array) -> Tuple[jax.Array, Params]:
    h = apply_norm(cfg.norm_kind, x, p.get("ln1"))
    y, st = rec.rglru_block_step(h[:, 0], rec.RGLRUState(c["h"], c["conv"]), p)
    x = x + y[:, None, :].astype(x.dtype)
    if "mlp" in p:
        h2 = apply_norm(cfg.norm_kind, x, p.get("ln2"))
        x = x + gated_mlp(h2, p["mlp"]["w_gate"], p["mlp"]["w_up"],
                          p["mlp"]["w_down"], act=cfg.act)
    return x, {"h": st.h, "conv": st.conv}


def _decode_mlstm(cfg: ArchConfig, p: Params, c: Params,
                  x: jax.Array) -> Tuple[jax.Array, Params]:
    h = apply_norm(cfg.norm_kind, x, p.get("ln1"))
    q = jnp.einsum("bd,dhe->bhe", h[:, 0], p["wq"])
    k = jnp.einsum("bd,dhe->bhe", h[:, 0], p["wk"])
    v = jnp.einsum("bd,dhe->bhe", h[:, 0], p["wv"])
    gates = jnp.einsum("bd,dg->bg", h[:, 0].astype(jnp.float32), p["w_if"])
    log_i, log_f = jnp.split(gates, 2, axis=-1)
    log_f = jax.nn.log_sigmoid(log_f)
    y, st = rec.mlstm_step(q, k, v, log_f, log_i,
                           rec.MLSTMState(c["S"], c["n"], c["m"]))
    og = jax.nn.sigmoid(jnp.einsum("bd,de->be", h[:, 0], p["w_og"]))
    out = jnp.einsum("bhe,hed->bd", y, p["w_out"]) * og
    return x + out[:, None, :].astype(x.dtype), {"S": st.S, "n": st.n, "m": st.m}


def _decode_slstm(cfg: ArchConfig, p: Params, c: Params,
                  x: jax.Array) -> Tuple[jax.Array, Params]:
    h = apply_norm(cfg.norm_kind, x, p.get("ln1"))
    y, (cn, nn, hn, mn) = rec.slstm_seq(h[:, :1],
                                        p, state=(c["c"], c["n"],
                                                  c["h"], c["m"]))
    return x + y.astype(x.dtype), {"c": cn, "n": nn, "h": hn, "m": mn}


def _decode_block(cfg: ArchConfig, kind: str, p: Params, c: Params,
                  x: jax.Array, pos: jax.Array,
                  pos3: Optional[jax.Array]) -> Tuple[jax.Array, Params]:
    if kind.startswith("attn"):
        return _decode_attn(cfg, kind, p, c, x, pos, pos3)
    if kind == "rglru":
        return _decode_rglru(cfg, p, c, x)
    if kind == "mlstm":
        return _decode_mlstm(cfg, p, c, x)
    if kind == "slstm":
        return _decode_slstm(cfg, p, c, x)
    raise ValueError(kind)


def decode_step(params: Params, cfg: ArchConfig, cache: Params,
                batch: Dict[str, jax.Array]
                ) -> Tuple[jax.Array, Params]:
    """One decode step. batch: tokens [B,1] (or embeds [B,1,D]),
    pos [1] int32 (current absolute position), optional positions3 [3,B,1].
    Returns (logits [B,1,V], new cache)."""
    x, _, pos3 = embed_inputs(params, cfg, batch)
    pos = batch["pos"].astype(jnp.int32)           # [1]
    n_per, n_rem = group_layout(cfg)

    def period_body(x, xs):
        period_params, period_cache = xs
        new_cache = {}
        for i, kind in enumerate(cfg.block_pattern):
            key = f"p{i}_{kind}"
            x, nc = _decode_block(cfg, kind, period_params[key],
                                  period_cache[key], x, pos, pos3)
            new_cache[key] = nc
        return x, new_cache

    if n_per > 0:
        x, new_blocks = jax.lax.scan(period_body, x,
                                     (params["blocks"], cache["blocks"]))
    else:
        new_blocks = cache["blocks"]
    new_cache: Params = {"blocks": new_blocks}
    if n_rem:
        new_cache["rem"] = {}
        for i in range(n_rem):
            kind = cfg.block_pattern[i]
            key = f"r{i}_{kind}"
            x, nc = _decode_block(cfg, kind, params["rem"][key],
                                  cache["rem"][key], x, pos, pos3)
            new_cache["rem"][key] = nc
    logits = unembed(params, cfg, x)
    return logits, new_cache


# ---------------------------------------------------------------------------
# Prefill: forward + cache fill (used by the serving engine)
# ---------------------------------------------------------------------------

def prefill(params: Params, cfg: ArchConfig, batch: Dict[str, jax.Array],
            max_seq: int, use_pallas: bool = False
            ) -> Tuple[jax.Array, Params]:
    """Process a prompt of length S; returns (logits [B,S,V], filled cache).

    The cache is sized ``max_seq`` (ring-buffered for local attention).
    Implemented as the train-path forward with per-block state capture;
    recurrent blocks re-run their scan to obtain final state (cheap
    relative to the projections; acceptable for the serving path).
    """
    x, pos, pos3 = embed_inputs(params, cfg, batch)
    B, S = x.shape[0], x.shape[1]
    n_per, n_rem = group_layout(cfg)

    def capture_attn(kind: str, p: Params, x: jax.Array):
        x2, _, (k, v) = _apply_attn_block(cfg, kind, p, x, pos, pos3)
        L = _cache_len_for(cfg, kind, max_seq)
        dt = _dtype(cfg)
        kc = jnp.zeros((B, L, cfg.n_kv_heads, cfg.head_dim), dt)
        vc = jnp.zeros((B, L, cfg.n_kv_heads, cfg.head_dim), dt)
        if S >= L:
            # ring buffer holds the last L positions, aligned to slot pos%L
            tail_k, tail_v = k[:, S - L:], v[:, S - L:]
            roll = (S % L)
            kc = jnp.roll(tail_k, roll, axis=1)
            vc = jnp.roll(tail_v, roll, axis=1)
        else:
            kc = jax.lax.dynamic_update_slice(kc, k, (0, 0, 0, 0))
            vc = jax.lax.dynamic_update_slice(vc, v, (0, 0, 0, 0))
        return x2, {"k": kc, "v": vc}

    def capture_block(kind: str, p: Params, x: jax.Array):
        if kind.startswith("attn"):
            return capture_attn(kind, p, x)
        h = apply_norm(cfg.norm_kind, x, p.get("ln1"))
        if kind == "rglru":
            gate = jax.nn.gelu(jnp.einsum("bsd,de->bse", h, p["w_gate"]))
            r = jnp.einsum("bsd,de->bse", h, p["w_rec"])
            rc = rec.causal_conv1d(r, p["conv"])
            a, u = rec.rglru_gates(rc, p)
            hs = rec.rglru_scan_ref(a, u)
            y = jnp.einsum("bse,ed->bsd", hs * gate, p["w_out"])
            x = x + y.astype(x.dtype)
            if "mlp" in p:
                h2 = apply_norm(cfg.norm_kind, x, p.get("ln2"))
                x = x + gated_mlp(h2, p["mlp"]["w_gate"], p["mlp"]["w_up"],
                                  p["mlp"]["w_down"], act=cfg.act)
            K = cfg.conv_width
            conv_state = jnp.moveaxis(
                jnp.stack([r[:, S - K + 1 + i] for i in range(K - 1)], 0), 0, 1)
            return x, {"h": hs[:, -1].astype(jnp.float32), "conv": conv_state}
        if kind == "mlstm":
            x2 = _apply_mlstm_block(cfg, p, x)
            # recompute final state sequentially over chunked scan
            q, k, v = _project_qkv(p, h)
            gates = jnp.einsum("bsd,dg->bsg", h.astype(jnp.float32), p["w_if"])
            log_i, log_f = jnp.split(gates, 2, axis=-1)
            log_f = jax.nn.log_sigmoid(log_f)
            st = rec.MLSTMState(
                jnp.zeros((B, cfg.n_heads, cfg.head_dim, cfg.head_dim), jnp.float32),
                jnp.zeros((B, cfg.n_heads, cfg.head_dim), jnp.float32),
                jnp.zeros((B, cfg.n_heads), jnp.float32))

            def step(s, t):
                _, s2 = rec.mlstm_step(q[:, t], k[:, t], v[:, t],
                                       log_f[:, t], log_i[:, t], s)
                return s2, None
            st, _ = jax.lax.scan(step, st, jnp.arange(S))
            return x2, {"S": st.S, "n": st.n, "m": st.m}
        if kind == "slstm":
            y, (cn, nn, hn, mn) = rec.slstm_seq(h, p)
            return x + y, {"c": cn, "n": nn, "h": hn, "m": mn}
        raise ValueError(kind)

    def period_body(x, period_params):
        caches = {}
        for i, kind in enumerate(cfg.block_pattern):
            key = f"p{i}_{kind}"
            x, c = capture_block(kind, period_params[key], x)
            caches[key] = c
        return x, caches

    if n_per > 0:
        x, blocks_cache = jax.lax.scan(period_body, x, params["blocks"])
    else:
        blocks_cache = {}
    cache: Params = {"blocks": blocks_cache}
    if n_rem:
        cache["rem"] = {}
        for i in range(n_rem):
            kind = cfg.block_pattern[i]
            key = f"r{i}_{kind}"
            x, c = capture_block(kind, params["rem"][key], x)
            cache["rem"][key] = c
    return unembed(params, cfg, x), cache
