"""Mixture-of-Experts layer with capacity-based scatter dispatch.

Design notes (roofline-driven):
  * the common one-hot einsum dispatch builds a [tokens, experts, capacity]
    tensor — O(T·E·C) memory, hopeless at 1M tokens.  We instead compute
    per-assignment capacity positions with a cumsum over a [T·k, E]
    one-hot (cheap), scatter token activations into an [E_pad, C, D]
    buffer, run the expert FFNs as one batched einsum (the MXU-friendly
    form), and scatter back weighted by router probabilities.
  * the expert dim is PADDED to a multiple of 16 (``cfg.expert_pad_to``)
    so it shards cleanly over the model axis — without this, GSPMD
    replicates the whole expert compute on every device (measured 16×
    FLOPs blowup on qwen2-moe; EXPERIMENTS.md §Perf iteration M1).
    Dummy experts receive no tokens and contribute zero gradient.
  * capacity is rounded up to a multiple of 512 so the capacity dim can
    shard over the batch axes.
  * activation-sharding constraints pin [E,C,*] layouts (expert dim over
    tp, capacity over batch); the scatter/gather then lowers to the
    expected all-to-all-style redistribution instead of dense fallbacks.

Supports shared experts (Qwen2-MoE: 4 shared + 60 routed top-4) and an
auxiliary load-balance loss.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.distributed.act_shard import batch_groups, constrain
from repro.models.layers import gated_mlp

CAPACITY_ROUND = 512


class MoEOutput(NamedTuple):
    y: jax.Array
    aux_loss: jax.Array


def moe_ffn(x: jax.Array, params: dict, *, n_experts: int, top_k: int,
            capacity_factor: float = 1.25, act: str = "silu") -> MoEOutput:
    """x [B,S,D]; params: router [D,E], w_gate/w_up [E_pad,D,F],
    w_down [E_pad,F,D], optional shared_{gate,up,down}."""
    B, S, D = x.shape
    E, k = n_experts, top_k
    Ep = params["w_gate"].shape[0]
    T = B * S
    xt = x.reshape(T, D)

    logits = jnp.einsum("td,de->te", xt, params["router"],
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                      # [T,E]
    topw, topi = jax.lax.top_k(probs, k)                         # [T,k]
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    # aux load-balance loss (Switch-style)
    density = jnp.mean(jax.nn.one_hot(topi[:, 0], E, dtype=jnp.float32), 0)
    router_mean = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(density * router_mean)

    # GROUP-LOCAL dispatch: one capacity slice per batch shard (G groups)
    # so the scatter/gather never cross data shards — the only cross-device
    # traffic left is the expert-output partial-sum over the model axis.
    #
    # The buffer fill and the return path are G-batched take_along_axis
    # gathers (GSPMD partitions those shard-locally); the only scatter is
    # int32 token-ids into the slot table (~MBs even if replicated).
    # Dropped (over-capacity) assignments write to a trash slot so they
    # can never clobber a live slot.
    G = batch_groups()
    if T % G != 0:
        G = 1
    Tg = T // G
    cap_g = int(max(1, (k * Tg * capacity_factor) // Ep))
    cap_g = -(-cap_g // 128) * 128
    n_slots = Ep * cap_g

    flat_e = topi.reshape(G, Tg * k)                             # [G,Tgk]
    onehot = jax.nn.one_hot(flat_e, Ep, dtype=jnp.int32)         # [G,Tgk,Ep]
    pos_in_e = (jnp.cumsum(onehot, axis=1) - onehot)             # before me
    pos = jnp.take_along_axis(pos_in_e, flat_e[..., None],
                              axis=2)[..., 0]                    # [G,Tgk]
    keep = pos < cap_g
    lin = flat_e * cap_g + jnp.minimum(pos, cap_g - 1)           # [G,Tgk]
    lin_w = jnp.where(keep, lin, n_slots)                        # trash slot
    g_rows = jnp.arange(G, dtype=jnp.int32)[:, None]

    tok_ids = jnp.broadcast_to(jnp.arange(Tg * k, dtype=jnp.int32),
                               (G, Tg * k))
    slot_tok = jnp.full((G, n_slots + 1), Tg * k, jnp.int32)     # sentinel
    slot_tok = slot_tok.at[g_rows, lin_w].set(tok_ids, mode="drop")
    slot_tok = slot_tok[:, :n_slots]
    slot_valid = slot_tok < Tg * k

    xe = jnp.repeat(xt.reshape(G, Tg, D), k, axis=1)             # [G,Tgk,D]
    xe = constrain(xe, "gtd")
    buf = jnp.take_along_axis(
        xe, jnp.minimum(slot_tok, Tg * k - 1)[..., None], axis=1)
    buf = jnp.where(slot_valid[..., None], buf, 0)
    buf = constrain(buf.reshape(G, Ep, cap_g, D), "gecd")

    # expert FFNs as batched einsums over [G, Ep, C_g, *]
    g = constrain(jnp.einsum("gecd,edf->gecf", buf, params["w_gate"]),
                  "gecf")
    u = constrain(jnp.einsum("gecd,edf->gecf", buf, params["w_up"]),
                  "gecf")
    g = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g)
    ye = jnp.einsum("gecf,efd->gecd", g * u, params["w_down"])
    ye = constrain(ye, "gecd")

    # return path: G-batched gather, weight by router prob
    back = jnp.take_along_axis(ye.reshape(G, n_slots, D),
                               lin[..., None], axis=1)           # [G,Tgk,D]
    back = jnp.where(keep[..., None], back, 0)
    w = topw.reshape(G, Tg * k, 1).astype(back.dtype)
    y = jnp.sum((back * w).reshape(G, Tg, k, D), axis=2).reshape(T, D)

    if "shared_gate" in params:
        y = y + gated_mlp(x, params["shared_gate"], params["shared_up"],
                          params["shared_down"], act=act).reshape(T, D)

    return MoEOutput(y.reshape(B, S, D), aux.astype(jnp.float32))
