"""Recurrent sequence-mixing blocks: RG-LRU (Griffin/RecurrentGemma),
mLSTM and sLSTM (xLSTM).

All three are sub-quadratic — these are the architectures that run the
``long_500k`` shape cell.  Training uses parallel forms (associative scan
for RG-LRU, chunked gated-linear-attention for mLSTM, time scan for
sLSTM); decoding carries O(1) recurrent state.

The jnp reference oracles for the Pallas `rglru_scan` kernel call
:func:`rglru_scan_ref` here, keeping kernel and model in lockstep.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import rmsnorm

SQRT_EPS = 1e-6
RGLRU_C = 8.0


# ---------------------------------------------------------------------------
# causal depthwise conv (width-K), used by Griffin + mLSTM blocks
# ---------------------------------------------------------------------------

def causal_conv1d(x: jax.Array, kernel: jax.Array) -> jax.Array:
    """x [B,S,D], kernel [K,D] depthwise causal convolution."""
    K = kernel.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(K):
        out = out + xp[:, i:i + x.shape[1], :] * kernel[i]
    return out


def causal_conv1d_step(x_t: jax.Array, buf: jax.Array,
                       kernel: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Single decode step. x_t [B,D]; buf [B,K-1,D] (previous inputs)."""
    window = jnp.concatenate([buf, x_t[:, None, :]], axis=1)     # [B,K,D]
    y = jnp.einsum("bkd,kd->bd", window, kernel)
    return y, window[:, 1:, :]


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------

def rglru_gates(x: jax.Array, params: dict) -> Tuple[jax.Array, jax.Array]:
    """a_t (decay) and gated input for the linear recurrence.

    r_t = sigmoid(x W_a), i_t = sigmoid(x W_x),
    a_t = exp(-c * softplus(Lambda) * r_t),
    u_t = sqrt(1 - a_t^2) * (i_t * x_t).
    """
    r = jax.nn.sigmoid(jnp.einsum("...d,de->...e", x, params["w_a"]))
    i = jax.nn.sigmoid(jnp.einsum("...d,de->...e", x, params["w_x"]))
    log_a = -RGLRU_C * jax.nn.softplus(params["lam"]) * r
    a = jnp.exp(log_a)
    u = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), SQRT_EPS)) * (i * x)
    return a, u


def rglru_scan_ref(a: jax.Array, u: jax.Array,
                   h0: jax.Array | None = None) -> jax.Array:
    """Linear recurrence h_t = a_t*h_{t-1} + u_t via associative scan.

    a,u [B,S,D]; h0 [B,D] optional initial state. Returns h [B,S,D].
    This is also the jnp oracle for kernels/rglru_scan.
    """
    if h0 is not None:
        # fold the initial state into the first step
        u = u.at[:, 0].add(a[:, 0] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    af = a.astype(jnp.float32)
    uf = u.astype(jnp.float32)
    _, h = jax.lax.associative_scan(combine, (af, uf), axis=1)
    return h.astype(u.dtype)


def rglru_block(x: jax.Array, params: dict,
                use_pallas: bool = False) -> jax.Array:
    """Griffin recurrent block: gate branch ⊙ (conv → RG-LRU) branch."""
    gate = jax.nn.gelu(jnp.einsum("bsd,de->bse", x, params["w_gate"]))
    rec = jnp.einsum("bsd,de->bse", x, params["w_rec"])
    rec = causal_conv1d(rec, params["conv"])
    a, u = rglru_gates(rec, params)
    if use_pallas:
        from repro.kernels.ops import rglru_scan
        h = rglru_scan(a, u)
    else:
        h = rglru_scan_ref(a, u)
    return jnp.einsum("bse,ed->bsd", h * gate, params["w_out"])


class RGLRUState(NamedTuple):
    h: jax.Array        # [B, Dr]
    conv: jax.Array     # [B, K-1, Dr]


def rglru_block_step(x_t: jax.Array, state: RGLRUState,
                     params: dict) -> Tuple[jax.Array, RGLRUState]:
    """Decode step. x_t [B,D]."""
    gate = jax.nn.gelu(jnp.einsum("bd,de->be", x_t, params["w_gate"]))
    rec = jnp.einsum("bd,de->be", x_t, params["w_rec"])
    rec, conv = causal_conv1d_step(rec, state.conv, params["conv"])
    a, u = rglru_gates(rec, params)
    h = a * state.h + u
    y = jnp.einsum("be,ed->bd", h * gate, params["w_out"])
    return y, RGLRUState(h, conv)


def rglru_init_state(batch: int, d_rec: int, conv_k: int,
                     dtype=jnp.float32) -> RGLRUState:
    return RGLRUState(jnp.zeros((batch, d_rec), dtype),
                      jnp.zeros((batch, conv_k - 1, d_rec), dtype))


# ---------------------------------------------------------------------------
# mLSTM (matrix-memory LSTM) — chunked gated-linear-attention form
# ---------------------------------------------------------------------------

def mlstm_parallel(q: jax.Array, k: jax.Array, v: jax.Array,
                   log_f: jax.Array, log_i: jax.Array,
                   chunk: int = 128) -> jax.Array:
    """Chunk-parallel mLSTM.

    q,k,v [B,S,H,D]; log_f/log_i [B,S,H] (log forget / input gates).
    C_t = f_t C_{t-1} + i_t v_t k_t^T ; y_t = C_t q_t / max(|n_t.q_t|,1).
    O(S·chunk) time, O(1) state between chunks.
    """
    B, S, H, D = q.shape
    pad = (-S) % chunk
    if pad:
        q, k, v = (jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
                   for x in (q, k, v))
        log_f = jnp.pad(log_f, ((0, 0), (0, pad), (0, 0)), constant_values=0.0)
        log_i = jnp.pad(log_i, ((0, 0), (0, pad), (0, 0)),
                        constant_values=-1e9)
    Sp = q.shape[1]
    n_chunks = Sp // chunk

    def rs(x, d):
        return jnp.moveaxis(x.reshape(B, n_chunks, chunk, H, *d), 1, 0)

    qc, kc, vc = rs(q, (D,)), rs(k, (D,)), rs(v, (D,))       # [N,B,c,H,D]
    fc, ic = rs(log_f, ()), rs(log_i, ())                    # [N,B,c,H]
    scale = D ** -0.5

    def chunk_step(carry, xs):
        S_state, n_state, m_state = carry    # [B,H,D,D], [B,H,D], [B,H]
        qq, kk, vv, lf, li = xs
        cf = jnp.cumsum(lf, axis=1)                          # [B,c,H]
        total_f = cf[:, -1]                                  # [B,H]
        # stabiliser: running max of (cf - li-ish) terms
        m_intra = jnp.max(li - cf, axis=1)                   # [B,H] (for state)
        m_new = jnp.maximum(m_state + total_f, m_intra + total_f)

        # intra-chunk: A[t,s] = q_t.k_s * exp(cf_t - cf_s + li_s - (cf_t + m_rel))
        # use per-row stabilisation via m_row
        qk = jnp.einsum("bthd,bshd->bhts", qq, kk,
                        preferred_element_type=jnp.float32) * scale
        dmat = cf[:, :, None, :] - cf[:, None, :, :] + li[:, None, :, :]
        dmat = jnp.moveaxis(dmat, 3, 1)                      # [B,H,t,s]
        causal = jnp.tril(jnp.ones((chunk, chunk), bool))
        dmat = jnp.where(causal[None, None], dmat, -1e30)
        # inter contribution decay: exp(cf_t + m_prev_rel)
        inter_log = jnp.moveaxis(cf, 2, 1) + m_state[..., None]   # [B,H,t]
        m_row = jnp.maximum(jnp.max(dmat, axis=-1), inter_log)
        w_intra = jnp.exp(dmat - m_row[..., None])
        w_inter = jnp.exp(inter_log - m_row)
        y_intra = jnp.einsum("bhts,bhts,bshd->bthd",
                             jnp.where(causal[None, None], 1.0, 0.0),
                             w_intra * qk, vv.astype(jnp.float32))
        y_inter = jnp.einsum("bthd,bhde,bht->bthe", qq.astype(jnp.float32),
                             S_state, w_inter) * scale
        n_intra = jnp.einsum("bhts,bshd->bthd", w_intra * qk * 0 + w_intra,
                             kk.astype(jnp.float32)) * scale
        n_row = jnp.einsum("bthd,bthd->bth", qq.astype(jnp.float32),
                           n_intra) + jnp.einsum(
            "bthd,bhd,bht->bth", qq.astype(jnp.float32), n_state, w_inter) * scale
        denom = jnp.maximum(jnp.abs(n_row), jnp.exp(-m_row.transpose(0, 2, 1)))
        y = (y_intra + y_inter) / denom[..., None]

        # state update (relative to m_new)
        decay_state = jnp.exp(m_state + total_f - m_new)     # [B,H]
        w_tok = jnp.exp((total_f[:, None] - cf) + li - m_new[:, None])  # [B,c,H]
        S_new = (S_state * decay_state[..., None, None]
                 + jnp.einsum("bshd,bsh,bshe->bhde", kk.astype(jnp.float32),
                              w_tok, vv.astype(jnp.float32)))
        n_new = (n_state * decay_state[..., None]
                 + jnp.einsum("bshd,bsh->bhd", kk.astype(jnp.float32), w_tok))
        return (S_new, n_new, m_new), y.astype(q.dtype)

    S0 = jnp.zeros((B, H, D, D), jnp.float32)
    n0 = jnp.zeros((B, H, D), jnp.float32)
    m0 = jnp.zeros((B, H), jnp.float32)
    _, ys = jax.lax.scan(chunk_step, (S0, n0, m0), (qc, kc, vc, fc, ic))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, Sp, H, D)
    return y[:, :S]


class MLSTMState(NamedTuple):
    S: jax.Array   # [B,H,D,D]
    n: jax.Array   # [B,H,D]
    m: jax.Array   # [B,H]


def mlstm_step(q, k, v, log_f, log_i, state: MLSTMState
               ) -> Tuple[jax.Array, MLSTMState]:
    """Decode step; q,k,v [B,H,D]; gates [B,H]."""
    D = q.shape[-1]
    scale = D ** -0.5
    m_new = jnp.maximum(state.m + log_f, log_i)
    decay = jnp.exp(state.m + log_f - m_new)
    inw = jnp.exp(log_i - m_new)
    S_new = (state.S * decay[..., None, None]
             + jnp.einsum("bhd,bhe->bhde", k.astype(jnp.float32),
                          v.astype(jnp.float32)) * inw[..., None, None])
    n_new = state.n * decay[..., None] + k.astype(jnp.float32) * inw[..., None]
    num = jnp.einsum("bhd,bhde->bhe", q.astype(jnp.float32), S_new) * scale
    den = jnp.abs(jnp.einsum("bhd,bhd->bh", q.astype(jnp.float32),
                             n_new)) * scale
    y = num / jnp.maximum(den, jnp.exp(-m_new))[..., None]
    return y.astype(q.dtype), MLSTMState(S_new, n_new, m_new)


# ---------------------------------------------------------------------------
# sLSTM (scalar-memory LSTM with exponential gating) — sequential
# ---------------------------------------------------------------------------

def slstm_seq(x: jax.Array, params: dict,
              state: tuple | None = None) -> Tuple[jax.Array, tuple]:
    """x [B,S,D]. Sequential scan (the sLSTM recurrence is not
    parallelisable: gates depend on h_{t-1} through R)."""
    B, S, D = x.shape
    wz, wi, wf, wo = (params[k] for k in ("w_z", "w_i", "w_f", "w_o"))
    rz, ri, rf, ro = (params[k] for k in ("r_z", "r_i", "r_f", "r_o"))

    if state is None:
        z = jnp.zeros((B, D), jnp.float32)
        state = (z, z + 1e-6, z, z)   # c, n, h, m

    def step(carry, x_t):
        c, n, h, m = carry
        xf = x_t.astype(jnp.float32)
        zt = jnp.tanh(xf @ wz + h @ rz)
        it = xf @ wi + h @ ri
        ft = xf @ wf + h @ rf
        ot = jax.nn.sigmoid(xf @ wo + h @ ro)
        m_new = jnp.maximum(ft + m, it)
        i_e = jnp.exp(it - m_new)
        f_e = jnp.exp(ft + m - m_new)
        c_new = f_e * c + i_e * zt
        n_new = f_e * n + i_e
        h_new = ot * c_new / jnp.maximum(n_new, 1e-6)
        return (c_new, n_new, h_new, m_new), h_new

    final, hs = jax.lax.scan(step, state, jnp.moveaxis(x, 1, 0))
    return jnp.moveaxis(hs, 0, 1).astype(x.dtype), final


def slstm_init_state(batch: int, d: int) -> tuple:
    z = jnp.zeros((batch, d), jnp.float32)
    return (z, z + 1e-6, z, z)
