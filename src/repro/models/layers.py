"""Shared neural-net layers: norms, RoPE / M-RoPE, MLPs, attention.

Pure-functional JAX: every layer is (param-spec builder, apply fn).
Attention is a memory-efficient double-blocked online-softmax
implementation (flash-style in pure jnp/lax) so 32k–512k contexts lower
without materialising S×T score matrices; the Pallas TPU kernel in
``repro.kernels.flash_attention`` is a drop-in fast path.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.act_shard import constrain

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, scale: Optional[jax.Array],
            eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    if scale is not None:
        y = y * (1.0 + scale.astype(jnp.float32))
    return y.astype(dt)


def layernorm_nonparam(x: jax.Array, eps: float = 1e-5) -> jax.Array:
    """OLMo-style non-parametric LayerNorm (no scale, no bias)."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(dt)


def apply_norm(kind: str, x: jax.Array,
               scale: Optional[jax.Array]) -> jax.Array:
    if kind == "rmsnorm":
        return rmsnorm(x, scale)
    if kind == "nonparam_ln":
        return layernorm_nonparam(x)
    if kind == "layernorm":
        # parametric LN with scale only (bias-free, llama-era convention)
        y = layernorm_nonparam(x)
        if scale is not None:
            y = y * (1.0 + scale.astype(y.dtype))
        return y
    raise ValueError(f"unknown norm kind {kind}")


# ---------------------------------------------------------------------------
# Rotary position embeddings (standard + 3-axis M-RoPE)
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float = 10_000.0) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float = 10_000.0) -> jax.Array:
    """x [..., S, H, D]; positions [..., S] (broadcastable)."""
    freqs = rope_frequencies(x.shape[-1], theta)              # [D/2]
    ang = positions[..., None].astype(jnp.float32) * freqs    # [..., S, D/2]
    ang = ang[..., None, :]                                   # [..., S, 1, D/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions3: jax.Array,
                sections: tuple[int, int, int] = (1, 1, 2),
                theta: float = 10_000.0) -> jax.Array:
    """Qwen2-VL multimodal RoPE: the head-dim frequency bands are split
    across (temporal, height, width) position axes.

    x [B, S, H, D]; positions3 [3, B, S].
    ``sections`` are relative proportions of the D/2 frequency bands.
    """
    half = x.shape[-1] // 2
    total = sum(sections)
    sizes = [half * s // total for s in sections]
    sizes[-1] = half - sizes[0] - sizes[1]
    freqs = rope_frequencies(x.shape[-1], theta)              # [D/2]
    # per-frequency-band position selection
    band = jnp.concatenate([
        jnp.full((sizes[0],), 0, dtype=jnp.int32),
        jnp.full((sizes[1],), 1, dtype=jnp.int32),
        jnp.full((sizes[2],), 2, dtype=jnp.int32)])           # [D/2]
    # pos3 [3,B,S] -> select per band: [B,S,D/2]
    pos_sel = jnp.take(positions3, band, axis=0)              # [D/2? no]
    # positions3 indexed on axis 0 by band -> [D/2, B, S]; move axis
    pos_sel = jnp.moveaxis(pos_sel, 0, -1)                    # [B, S, D/2]
    ang = pos_sel.astype(jnp.float32) * freqs                 # [B, S, D/2]
    ang = ang[..., None, :]                                   # [B, S, 1, D/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def gated_mlp(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
              w_down: jax.Array, act: str = "silu") -> jax.Array:
    """SwiGLU/GeGLU block: (act(x·Wg) ⊙ x·Wu)·Wd."""
    g = constrain(jnp.einsum("bsd,df->bsf", x, w_gate), "bsf")
    u = constrain(jnp.einsum("bsd,df->bsf", x, w_up), "bsf")
    if act == "silu":
        g = jax.nn.silu(g)
    elif act == "gelu":
        g = jax.nn.gelu(g, approximate=True)
    else:
        raise ValueError(act)
    return constrain(jnp.einsum("bsf,fd->bsd", g * u, w_down), "bsd")


# ---------------------------------------------------------------------------
# Blocked online-softmax attention (train/prefill path)
# ---------------------------------------------------------------------------

def _softcap(s: jax.Array, cap: float) -> jax.Array:
    return cap * jnp.tanh(s / cap) if cap > 0.0 else s


def _pad_axis(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    n = x.shape[axis]
    pad = (-n) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def blocked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      q_offset: int | jax.Array = 0,
                      causal: bool = True,
                      window: int = 0,
                      softcap: float = 0.0,
                      block_q: int = 512,
                      block_k: int = 1024) -> jax.Array:
    """Memory-efficient attention.

    q [B,S,Hq,D], k/v [B,T,Hkv,D] with Hq = G·Hkv (GQA).
    ``window`` > 0 => sliding-window (local) attention of that width.
    ``softcap`` > 0 => gemma2-style logit soft-capping.
    Never materialises more than [B, block_q, Hq, block_k] scores.
    """
    B, S, Hq, Dh = q.shape
    _, T, Hkv, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    G = Hq // Hkv
    scale = Dh ** -0.5
    out_dtype = q.dtype

    block_q = min(block_q, max(S, 1))
    block_k = min(block_k, max(T, 1))

    qp = _pad_axis(q, 1, block_q)
    kp = _pad_axis(k, 1, block_k)
    vp = _pad_axis(v, 1, block_k)
    Sp, Tp = qp.shape[1], kp.shape[1]
    nq, nk = Sp // block_q, Tp // block_k

    qb = qp.reshape(B, nq, block_q, Hkv, G, Dh)
    kb = kp.reshape(B, nk, block_k, Hkv, Dh)
    vb = vp.reshape(B, nk, block_k, Hkv, Dh)
    kb = jnp.moveaxis(kb, 1, 0)      # [nk, B, bk, Hkv, D]
    vb = jnp.moveaxis(vb, 1, 0)

    q_pos_base = jnp.asarray(q_offset, jnp.int32)

    def one_q_block(args):
        qi, qblk = args                      # qblk [B,bq,Hkv,G,D]
        q_pos = q_pos_base + qi * block_q + jnp.arange(block_q, dtype=jnp.int32)
        valid_q = (qi * block_q + jnp.arange(block_q)) < S

        def kv_step(carry, inputs):
            m, l, acc = carry
            ki, kblk, vblk = inputs
            k_pos = ki * block_k + jnp.arange(block_k, dtype=jnp.int32)
            s = jnp.einsum("bqhgd,bkhd->bqhgk", qblk, kblk,
                           preferred_element_type=jnp.float32) * scale
            s = _softcap(s, softcap)
            mask = (k_pos[None, :] <= q_pos[:, None]) if causal else \
                jnp.ones((block_q, block_k), bool)
            if window > 0:
                mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
            mask = mask & (k_pos[None, :] < T)
            s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            # guard fully-masked rows (m_new == NEG_INF)
            m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(mask[None, :, None, None, :], p, 0.0)
            alpha = jnp.where(m <= NEG_INF / 2, 0.0,
                              jnp.exp(m - m_safe))
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bqhgk,bkhd->bqhgd", p.astype(vblk.dtype), vblk,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, block_q, Hkv, G), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, block_q, Hkv, G), jnp.float32)
        a0 = jnp.zeros((B, block_q, Hkv, G, Dh), jnp.float32)
        ks = jnp.arange(nk, dtype=jnp.int32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (ks, kb, vb))
        out = acc / jnp.maximum(l, 1e-20)[..., None]
        out = out * valid_q[None, :, None, None, None]
        return out.astype(out_dtype)     # [B,bq,Hkv,G,D]

    qis = jnp.arange(nq, dtype=jnp.int32)
    outs = jax.lax.map(one_q_block,
                       (qis, jnp.moveaxis(qb, 1, 0)))     # [nq,B,bq,Hkv,G,D]
    outs = jnp.moveaxis(outs, 0, 1).reshape(B, Sp, Hq, Dh)
    return outs[:, :S]


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_len: jax.Array, *,
                     window: int = 0, softcap: float = 0.0) -> jax.Array:
    """Single-position attention against a KV cache.

    q [B,1,Hq,D]; caches [B,T,Hkv,D]; cache_len: number of valid entries
    (new token already written at cache_len-1).
    """
    B, _, Hq, Dh = q.shape
    T, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    qr = q.reshape(B, Hkv, G, Dh)
    s = jnp.einsum("bhgd,bkhd->bhgk", qr, k_cache,
                   preferred_element_type=jnp.float32) * (Dh ** -0.5)
    s = _softcap(s, softcap)
    k_pos = jnp.arange(T, dtype=jnp.int32)
    mask = k_pos[None, :] < cache_len.reshape(-1, 1)
    if window > 0:
        mask = mask & (k_pos[None, :] >= cache_len.reshape(-1, 1) - window)
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, Hq, Dh).astype(q.dtype)
