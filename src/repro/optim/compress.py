"""Int8 gradient compression with error feedback.

Used in two places:
  1. Micro-batch gradient accumulation (train/step.py): per-microbatch
     gradients are quantised to int8 (per-tensor scale) before being added
     to the fp32 accumulator; the quantisation residual is carried to the
     next microbatch (error feedback), so the accumulated gradient is
     unbiased over the accumulation window.
  2. Cross-replica reduction (demonstration in benchmarks): a shard_map
     psum of int8-packed gradients halves ICI bytes vs bf16 at the cost of
     one extra all-reduce of the per-tensor scales.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class Quantized(NamedTuple):
    q: jax.Array        # int8 payload
    scale: jax.Array    # f32 per-tensor scale


def quantize(x: jax.Array) -> Quantized:
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return Quantized(q, scale)


def dequantize(qz: Quantized) -> jax.Array:
    return qz.q.astype(jnp.float32) * qz.scale


def quantize_with_feedback(x: jax.Array, err: jax.Array
                           ) -> Tuple[Quantized, jax.Array]:
    """Quantise (x + carried error); return new quantised value and the
    residual to carry forward."""
    target = x.astype(jnp.float32) + err
    qz = quantize(target)
    new_err = target - dequantize(qz)
    return qz, new_err


def tree_quantize_with_feedback(grads: Any, err_tree: Any
                                ) -> Tuple[Any, Any]:
    """Returns (dequantised grads, new error tree)."""
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(err_tree)
    deq, new_err = [], []
    for g, e in zip(flat_g, flat_e):
        qz, ne = quantize_with_feedback(g, e)
        deq.append(dequantize(qz))
        new_err.append(ne)
    return treedef.unflatten(deq), treedef.unflatten(new_err)


def init_error_tree(params: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """shard_map-level compressed all-reduce: quantise locally, psum the
    int32-widened payload, dequantise with the max scale.  Halving of ICI
    bytes vs bf16 comes from the int8 payload; the scale reduction is O(1).
    """
    qz = quantize(x)
    scale = jax.lax.pmax(qz.scale, axis_name)
    q32 = jax.lax.psum(qz.q.astype(jnp.int32), axis_name)
    return q32.astype(jnp.float32) * scale
