"""AdamW with fp32 master accumulators, global-norm clipping and optional
int8 error-feedback gradient compression (see compress.py).

Pure-functional: state is a pytree sharded identically to params (ZeRO-3
via the same ShardingRules), so the optimizer adds no resharding traffic.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import Config


@dataclasses.dataclass(frozen=True)
class AdamWConfig(Config):
    lr_peak: float = 3e-4
    betas: Tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    lr_min_ratio: float = 0.1


class AdamWState(NamedTuple):
    count: jax.Array
    mu: Any
    nu: Any


def init(params: Any) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        count=jnp.zeros((), jnp.int32),
        mu=jax.tree_util.tree_map(zeros, params),
        nu=jax.tree_util.tree_map(zeros, params),
    )


def state_specs(param_specs: Any) -> AdamWState:
    """Abstract state tree (for dry-run lowering)."""
    f32 = lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32)
    return AdamWState(
        count=jax.ShapeDtypeStruct((), jnp.int32),
        mu=jax.tree_util.tree_map(f32, param_specs),
        nu=jax.tree_util.tree_map(f32, param_specs),
    )


def cosine_lr(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.lr_min_ratio + (1 - cfg.lr_min_ratio) * cos
    return cfg.lr_peak * warm * frac


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def update(cfg: AdamWConfig, grads: Any, state: AdamWState, params: Any
           ) -> Tuple[Any, AdamWState, Dict[str, jax.Array]]:
    count = state.count + 1
    lr = cosine_lr(cfg, count)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    b1, b2 = cfg.betas
    bc1 = 1 - b1 ** count.astype(jnp.float32)
    bc2 = 1 - b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        decay = cfg.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        p_new = p.astype(jnp.float32) - lr * (step + decay)
        return p_new.astype(p.dtype), m, v

    out = jax.tree_util.tree_map(upd, params, grads, state.mu, state.nu)
    params_new = jax.tree_util.tree_map(lambda t: t[0], out,
                                        is_leaf=lambda x: isinstance(x, tuple))
    mu_new = jax.tree_util.tree_map(lambda t: t[1], out,
                                    is_leaf=lambda x: isinstance(x, tuple))
    nu_new = jax.tree_util.tree_map(lambda t: t[2], out,
                                    is_leaf=lambda x: isinstance(x, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return params_new, AdamWState(count, mu_new, nu_new), metrics
