"""Contextual activation-sharding constraints.

GSPMD left to its own devices reshards *activations* across the FSDP axis
(103 GB/device of per-layer all-reduces on llama/olmo train cells — see
EXPERIMENTS.md §Perf iteration 1) instead of gathering the far smaller
weight shards.  Pinning the canonical activation layouts with
``with_sharding_constraint`` flips the partitioner to the intended
ZeRO-3 + Megatron pattern.

The context is set by the launcher/dry-run (inside `with mesh:`); when no
context is set (CPU unit tests, single device) every call is a no-op, so
model code stays mesh-agnostic.
"""
from __future__ import annotations

import contextlib
from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

_CTX: Optional[dict] = None


def set_context(batch_axes: Tuple[str, ...], tp_axis: str,
                tp_size: int, batch_size: int = 1,
                fsdp_axis: str = "", fsdp_size: int = 1,
                mode: str = "train") -> None:
    global _CTX
    _CTX = {"batch": tuple(batch_axes), "tp": tp_axis, "tp_size": tp_size,
            "batch_size": batch_size, "fsdp": fsdp_axis,
            "fsdp_size": fsdp_size, "mode": mode}


def batch_groups() -> int:
    """Product of batch-axis sizes (1 when unset): the MoE grouped
    dispatch builds one capacity slice per batch shard so scatter/gather
    never cross data shards."""
    return _CTX["batch_size"] if _CTX else 1


def clear_context() -> None:
    global _CTX
    _CTX = None


@contextlib.contextmanager
def activation_sharding(batch_axes: Tuple[str, ...], tp_axis: str,
                        tp_size: int, batch_size: int = 1,
                        fsdp_axis: str = "", fsdp_size: int = 1,
                        mode: str = "train"):
    set_context(batch_axes, tp_axis, tp_size, batch_size, fsdp_axis,
                fsdp_size, mode)
    try:
        yield
    finally:
        clear_context()


def _tp_if(dim: int):
    if _CTX is None or not _CTX["tp"]:
        return None
    return _CTX["tp"] if dim % _CTX["tp_size"] == 0 else None


def _group_if(dim: int):
    if _CTX is None or not _CTX["batch"]:
        return None
    return _CTX["batch"] if dim % _CTX["batch_size"] == 0 else None


def constrain(x: jax.Array, kind: str) -> jax.Array:
    """Pin a canonical activation layout.

    kinds: 'bsd' [B,S,D] — batch-sharded, D replicated (residual stream)
           'bsf' [B,S,F] — MLP hidden, F over tp
           'bshe' [B,S,H,e] — attention heads over tp
           'bsv' [B,S,V] — logits, vocab over tp
    """
    if _CTX is None:
        return x
    b = _CTX["batch"] or None
    if kind == "bsd":
        if _CTX["mode"] == "decode":
            # decode: keep the residual stream FEATURE-sharded over the
            # fsdp axis so weight shards stay stationary (x is ~MBs; the
            # measured alternative gathered 218 MB/layer of weights)
            fa = _CTX["fsdp"] if (_CTX["fsdp"] and
                                  x.shape[-1] % _CTX["fsdp_size"] == 0) \
                else None
            spec = P(None, None, fa)
        else:
            spec = P(b, None, None)
    elif kind == "bsf":
        spec = P(b, None, _tp_if(x.shape[-1]))
    elif kind == "bshe":
        spec = P(b, None, _tp_if(x.shape[-2]), None)
    elif kind == "bsv":
        spec = P(b, None, _tp_if(x.shape[-1]))
    elif kind == "gecd":           # MoE buffer [G, E_pad, C_g, D]
        spec = P(_group_if(x.shape[0]), None, None, None)
    elif kind == "gecf":           # MoE hidden [G, E_pad, C_g, F]
        spec = P(_group_if(x.shape[0]), None, None, _tp_if(x.shape[-1]))
    elif kind == "gtd":            # grouped tokens [G, T_g, D]
        spec = P(_group_if(x.shape[0]), None, None)
    else:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:       # outside mesh context: leave unconstrained
        return x
