"""Name-based sharding rules (MaxText-style) with divisibility awareness.

Axes:
  * batch axes  — ("pod", "data") on the multi-pod mesh, ("data",) single-pod
  * fsdp axis   — "data": parameters are additionally sharded over the data
                  axis (ZeRO-3 style) on their non-TP dimension
  * tp axis     — "model": attention heads / FFN hidden / experts / vocab

A dimension is only sharded when its size is divisible by the axis size —
otherwise GSPMD would silently pad (e.g. recurrentgemma's single KV head
over a 16-way model axis would replicate 16×).  The skipped-sharding
decisions are recorded so the dry-run report can surface them.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.common.tree import path_str

# rule table: basename regex -> per-trailing-dim roles
# roles: "fsdp" | "tp" | "batch" | None
_PARAM_RULES: List[Tuple[str, Tuple[Optional[str], ...]]] = [
    (r"embed$", ("tp", "fsdp")),
    (r"lm_head$", ("fsdp", "tp")),
    (r"(x_)?wq$", ("fsdp", "tp", None)),
    (r"(x_)?wk$", ("fsdp", "tp", None)),
    (r"(x_)?wv$", ("fsdp", "tp", None)),
    (r"(x_)?wo$", ("tp", None, "fsdp")),
    (r"w_gate$", ("fsdp", "tp")),
    (r"w_up$", ("fsdp", "tp")),
    (r"w_down$", ("tp", "fsdp")),
    (r"shared_gate$", ("fsdp", "tp")),
    (r"shared_up$", ("fsdp", "tp")),
    (r"shared_down$", ("tp", "fsdp")),
    (r"router$", ("fsdp", None)),
    (r"w_rec$", ("fsdp", "tp")),
    (r"w_a$", ("fsdp", "tp")),
    (r"w_x$", ("fsdp", "tp")),
    (r"w_out$", ("tp", "fsdp")),
    (r"lam$", ("tp",)),
    (r"conv$", (None, "tp")),
    (r"w_if$", ("fsdp", None)),
    (r"w_og$", ("fsdp", "tp")),
    (r"[wr]_[zifo]$", ("fsdp", "tp")),
    (r"(ln1|ln2|ln_x|final_norm|enc_norm)$", (None,)),
]

# MoE expert-stacked tensors: expert dim replicated, D/F sharded like the
# dense MLP (weights are gathered once per layer; the expert-parallel
# alternative pushed 34 GB/layer of token traffic — §Perf iteration M2)
_MOE_RULES: List[Tuple[str, Tuple[Optional[str], ...]]] = [
    (r"w_gate$", (None, "fsdp", "tp")),
    (r"w_up$", (None, "fsdp", "tp")),
    (r"w_down$", (None, "tp", "fsdp")),
]


@dataclasses.dataclass
class ShardingRules:
    mesh: Mesh
    fsdp_axis: str = "data"
    tp_axis: str = "model"
    # layout "default": FSDP over data + TP over model (the right choice
    # for >5B models).  layout "fsdp_only": BOTH mesh axes act as
    # data/FSDP — for small models where 16-way TP only buys per-layer
    # activation all-reduces (measured 8× collective reduction on olmo-1b;
    # §Perf iteration O2).  --layout auto picks by active param count.
    layout: str = "default"
    # decode layout: activations/inputs replicated over the batch axes so
    # weight shards stay stationary (a single token's activations are ~MBs;
    # gathering 100s-of-GB weight shards per token was the measured
    # pathology — §Perf iteration D1). KV caches keep batch sharding.
    replicate_batch: bool = False

    def __post_init__(self):
        names = self.mesh.axis_names
        self.axis_sizes = dict(zip(names, self.mesh.devices.shape))
        if self.layout == "fsdp_only":
            all_batch = tuple(names)          # every axis is a batch axis
            self._fsdp_axes: Tuple[str, ...] = tuple(names)
            self._tp_axes: Tuple[str, ...] = ()
        else:
            all_batch = tuple(a for a in ("pod", "data") if a in names)
            self._fsdp_axes = (self.fsdp_axis,) if self.fsdp_axis in names \
                else ()
            self._tp_axes = (self.tp_axis,) if self.tp_axis in names else ()
        self.cache_batch_axes: Tuple[str, ...] = all_batch
        self.batch_axes: Tuple[str, ...] = () if self.replicate_batch \
            else all_batch
        self.skipped: List[str] = []

    def _role_axis(self, role: Optional[str]):
        if role == "fsdp":
            return self._fsdp_axes or None
        if role == "tp":
            return self._tp_axes or None
        return None

    def _apply(self, roles: Tuple[Optional[str], ...], shape: Tuple[int, ...],
               path: str) -> P:
        n_lead = len(shape) - len(roles)
        spec: List[Any] = [None] * n_lead
        used = set()
        for dim, role in zip(shape[n_lead:], roles):
            axes = self._role_axis(role)
            if axes is not None:
                size = int(np.prod([self.axis_sizes[a] for a in axes]))
            if (axes is not None and axes not in used
                    and dim % size == 0):
                spec.append(axes if len(axes) > 1 else axes[0])
                used.add(axes)
            else:
                if axes is not None:
                    self.skipped.append(
                        f"{path}: dim {dim} % {axes}({size}) != 0")
                spec.append(None)
        return P(*spec)

    def param_pspec(self, path: str, shape: Tuple[int, ...]) -> P:
        base = path.split(".")[-1]
        rules = _MOE_RULES + _PARAM_RULES if ".moe." in f".{path}." \
            else _PARAM_RULES
        for pat, roles in rules:
            if re.search(pat, base) and len(shape) >= len(roles):
                return self._apply(roles, shape, path)
        return P()

    def batch_pspec(self, shape: Tuple[int, ...]) -> P:
        """Shard the leading (batch) dim over all batch axes."""
        if not self.batch_axes:
            return P(*([None] * len(shape)))
        total = int(np.prod([self.axis_sizes[a] for a in self.batch_axes]))
        if shape and shape[0] % total == 0:
            return P(self.batch_axes, *([None] * (len(shape) - 1)))
        return P(*([None] * len(shape)))

    def input_pspec(self, name: str, shape: Tuple[int, ...]) -> P:
        if name == "positions3":          # [3, B, S]
            spec = self.batch_pspec(shape[1:])
            return P(None, *spec)
        if name == "pos":
            return P(None)
        return self.batch_pspec(shape)

    def cache_pspec(self, path: str, shape: Tuple[int, ...]) -> P:
        """Decode-state sharding: batch dim + head/channel dim over tp."""
        base = path.split(".")[-1]
        # stacked-layer leading dim possible; find batch dim by name
        if base in ("k", "v") or base in ("self_k", "self_v",
                                          "cross_k", "cross_v"):
            # [..., B, T, Hkv, hd]; when the KV heads don't divide the tp
            # axis (GQA/MQA), shard the SEQUENCE dim instead — decode
            # attention then runs flash-decoding style (partial softmax
            # over T shards; the cross-shard reductions are tiny scalars),
            # and the cache never round-trips through a reshard.
            n_lead = len(shape) - 4
            spec: List[Any] = [None] * n_lead
            spec.append(self._batch_axes_if(shape[n_lead]))
            head_ax = self._tp_if(shape[n_lead + 2])
            if head_ax is not None:
                spec.extend([None, head_ax, None])
            else:
                spec.extend([self._tp_if(shape[n_lead + 1]), None, None])
            return P(*spec)
        if base == "enc_out":
            return P(self._batch_axes_if(shape[0]), None, None)
        if base in ("h", "c", "n", "m", "S", "conv"):
            # recurrent state: [..., B, channels...] — batch then tp on last
            n_lead = len(shape) - 2 if base != "S" else len(shape) - 4
            n_lead = max(n_lead, 0)
            spec = [None] * n_lead
            if len(shape) > n_lead:
                spec.append(self._batch_axes_if(shape[n_lead]))
            rest = len(shape) - len(spec)
            for i in range(rest):
                if i == rest - 1 and base not in ("S",):
                    spec.append(self._tp_if(shape[len(spec)]))
                else:
                    spec.append(None)
            return P(*spec)
        return P(*([None] * len(shape)))

    def _batch_axes_if(self, dim: int):
        axes = self.cache_batch_axes
        total = int(np.prod([self.axis_sizes[a] for a in axes]))
        return axes if axes and total and dim % total == 0 else None

    def _tp_if(self, dim: int):
        if not self._tp_axes:
            return None
        ax = self._tp_axes[0]
        return ax if dim % self.axis_sizes[ax] == 0 else None


def tree_pspecs(rules: ShardingRules, tree: Any, kind: str) -> Any:
    """PartitionSpec tree for a (params|cache|inputs) spec tree."""
    def per_leaf(path, leaf):
        p = path_str(path)
        shape = tuple(leaf.shape)
        if kind == "params":
            return rules.param_pspec(p, shape)
        if kind == "cache":
            return rules.cache_pspec(p, shape)
        if kind == "inputs":
            return rules.input_pspec(p.split(".")[-1], shape)
        raise ValueError(kind)
    return jax.tree_util.tree_map_with_path(per_leaf, tree)


def tree_shardings(rules: ShardingRules, tree: Any, kind: str) -> Any:
    return jax.tree_util.tree_map(
        lambda p: NamedSharding(rules.mesh, p),
        tree_pspecs(rules, tree, kind),
        is_leaf=lambda x: isinstance(x, P))
