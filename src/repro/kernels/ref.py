"""Pure-jnp oracles for every Pallas kernel (tested with assert_allclose)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import blocked_attention
from repro.models.recurrent import rglru_scan_ref  # noqa: F401  (re-export)


def fma_chain_ref(x: jax.Array, niter: int,
                  active_fraction: float = 1.0) -> jax.Array:
    """The FMA chain is algebraically the identity: (x·2+2)/2 − 1 = x.

    In exact arithmetic the kernel returns its input for any chain length
    or active fraction; in f32 the operations are also exact for
    well-scaled inputs (×2, +2, ×0.5, −1 are all exact in binary fp).
    """
    del niter, active_fraction
    return x


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int = 0,
                        softcap: float = 0.0) -> jax.Array:
    """Oracle: the model-layer blocked attention (itself validated against
    a direct softmax for small shapes in tests)."""
    return blocked_attention(q, k, v, causal=causal, window=window,
                             softcap=softcap)


def attention_direct_ref(q, k, v, *, causal=True, window=0, softcap=0.0):
    """Small-shape direct softmax attention (quadratic, materialised)."""
    B, S, Hq, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qr = q.reshape(B, S, Hkv, G, D)
    s = jnp.einsum("bshgd,bthd->bshgt", qr.astype(jnp.float32),
                   k.astype(jnp.float32)) * (D ** -0.5)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, :, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bshgt,bthd->bshgd", p, v.astype(jnp.float32))
    return out.reshape(B, S, Hq, D).astype(q.dtype)
