"""Flash attention forward kernel (Pallas TPU).

Grid (B, Hkv, nq, nk) with the k axis innermost: VMEM scratch carries the
online-softmax state (m, l, acc) across k steps for a fixed q block, and
the output block is written on the last k step.  Q blocks are
(block_q, G·head_dim) where G = Hq // Hkv so GQA head groups share their
KV block straight from VMEM (no HBM re-reads per q head).

Supports causal masking, sliding windows (gemma2 local / recurrentgemma)
and gemma2 logit soft-capping.  MXU alignment: block_q and block_k are
multiples of 128; head_dim pads to 128 lanes outside the kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  block_q: int, block_k: int, seq_q: int, seq_k: int,
                  causal: bool, window: int, softcap: float, scale: float,
                  n_k_blocks: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)

    # skip fully-masked blocks (causal upper triangle / outside window)
    needed = True
    if causal:
        needed = (ki * block_k) <= (qi * block_q + block_q - 1)
    run = needed if isinstance(needed, bool) else needed

    @pl.when(run if isinstance(run, bool) else run)
    def _compute():
        q = q_ref[0, 0]                       # [bq, G, d]
        k = k_ref[0, 0]                       # [bk, d]
        v = v_ref[0, 0]                       # [bk, d]
        bq, G, d = q.shape
        s = jax.lax.dot_general(
            q.reshape(bq * G, d), k,
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale     # [bq*G, bk]
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)
        mask = (k_pos < seq_k) & (q_pos < seq_q)
        if causal:
            mask = mask & (k_pos <= q_pos)
        if window > 0:
            mask = mask & (k_pos > q_pos - window)
        maskg = jnp.repeat(mask, G, axis=0) if G > 1 else mask
        s = jnp.where(maskg, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
        p = jnp.exp(s - m_safe)
        p = jnp.where(maskg, p, 0.0)
        alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0,
                          jnp.exp(m_prev - m_safe))
        l_new = l_prev * alpha + p.sum(axis=-1, keepdims=True)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)             # [bq*G, d]
        acc_scr[...] = acc_scr[...] * alpha + pv
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == n_k_blocks - 1)
    def _finalize():
        l = l_scr[...]
        out = acc_scr[...] / jnp.maximum(l, 1e-20)
        bqG, d = out.shape
        o_ref[0, 0] = out.reshape(o_ref.shape[2], o_ref.shape[3],
                                  d).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    softcap: float = 0.0,
                    block_q: int = 256, block_k: int = 512,
                    interpret: bool = False) -> jax.Array:
    """q [B,S,Hq,D]; k/v [B,T,Hkv,D]; Hq = G·Hkv. Returns [B,S,Hq,D]."""
    B, S, Hq, D = q.shape
    _, T, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = D ** -0.5

    block_q = min(block_q, max(1, S))
    block_k = min(block_k, max(1, T))
    Sp = -(-S // block_q) * block_q
    Tp = -(-T // block_k) * block_k
    nq, nk = Sp // block_q, Tp // block_k

    # layout: [B, Hkv, S, G, D] so a q block is contiguous per (b, hkv)
    qr = jnp.moveaxis(q.reshape(B, S, Hkv, G, D), 1, 2)
    kr = jnp.moveaxis(k, 1, 2)      # [B,Hkv,T,D]
    vr = jnp.moveaxis(v, 1, 2)
    if Sp != S:
        qr = jnp.pad(qr, ((0, 0), (0, 0), (0, Sp - S), (0, 0), (0, 0)))
    if Tp != T:
        kr = jnp.pad(kr, ((0, 0), (0, 0), (0, Tp - T), (0, 0)))
        vr = jnp.pad(vr, ((0, 0), (0, 0), (0, Tp - T), (0, 0)))

    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, seq_q=S, seq_k=T,
        causal=causal, window=window, softcap=softcap, scale=scale,
        n_k_blocks=nk)

    out = pl.pallas_call(
        kernel,
        grid=(B, Hkv, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, G, D),
                         lambda b, h, qi, ki: (b, h, qi, 0, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, qi, ki: (b, h, ki, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, qi, ki: (b, h, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, G, D),
                               lambda b, h, qi, ki: (b, h, qi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, Sp, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q * G, 1), jnp.float32),
            pltpu.VMEM((block_q * G, 1), jnp.float32),
            pltpu.VMEM((block_q * G, D), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)

    out = jnp.moveaxis(out, 2, 1)[:, :S]          # [B,S,Hkv,G,D]
    return out.reshape(B, S, Hq, D)
