"""jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True on CPU hosts (kernels validated in
interpret mode per the brief) and False on real TPU backends.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels import fma_chain as _fma
from repro.kernels import flash_attention as _fa
from repro.kernels import rglru_scan as _rg


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("niter", "active_fraction",
                                             "block_rows", "interpret"))
def fma_chain(x, niter: int, active_fraction: float = 1.0,
              block_rows: int = 256, interpret: bool | None = None):
    it = _default_interpret() if interpret is None else interpret
    return _fma.fma_chain(x, niter, active_fraction, block_rows, interpret=it)


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, block_q: int = 256,
                    block_k: int = 512, interpret: bool | None = None):
    it = _default_interpret() if interpret is None else interpret
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               softcap=softcap, block_q=block_q,
                               block_k=block_k, interpret=it)


@functools.partial(jax.jit, static_argnames=("block_d", "chunk", "interpret"))
def rglru_scan(a, u, block_d: int = 512, chunk: int = 256,
               interpret: bool | None = None):
    it = _default_interpret() if interpret is None else interpret
    return _rg.rglru_scan(a, u, block_d=block_d, chunk=chunk, interpret=it)
