"""RG-LRU linear-recurrence kernel (Pallas TPU).

h_t = a_t ⊙ h_{t-1} + u_t — elementwise over channels, sequential over
time.  TPU adaptation: the recurrence is VPU-bound (no MXU), so the
kernel tiles (batch×channel) across the grid and walks time in VMEM
chunks; the carry h lives in a VMEM scratch register across sequential
grid steps.  Within a chunk the time loop is a ``fori_loop`` over rows of
the (chunk, block_d) VMEM block — 8-sublane×128-lane vector ops.

Grid: (B, nd, nt) with time innermost (sequential; carry in scratch).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, u_ref, o_ref, h_scr, *, chunk: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    def step(t, h):
        a_t = a_ref[0, t, :]
        u_t = u_ref[0, t, :]
        h = a_t * h + u_t
        o_ref[0, t, :] = h.astype(o_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_scr[0, :])
    h_scr[0, :] = h


def rglru_scan(a: jax.Array, u: jax.Array, *, block_d: int = 512,
               chunk: int = 256, interpret: bool = False) -> jax.Array:
    """a, u [B, S, D] → h [B, S, D] with h_t = a_t h_{t-1} + u_t."""
    B, S, D = a.shape
    block_d = min(block_d, D)
    chunk = min(chunk, S)
    assert D % block_d == 0, (D, block_d)
    Sp = -(-S // chunk) * chunk
    if Sp != S:
        # pad with a=1, u=0 (identity steps) at the end
        a = jnp.pad(a, ((0, 0), (0, Sp - S), (0, 0)), constant_values=1.0)
        u = jnp.pad(u, ((0, 0), (0, Sp - S), (0, 0)))
    nd, nt = D // block_d, Sp // chunk

    out = pl.pallas_call(
        functools.partial(_rglru_kernel, chunk=chunk),
        grid=(B, nd, nt),
        in_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda b, d, t: (b, t, d)),
            pl.BlockSpec((1, chunk, block_d), lambda b, d, t: (b, t, d)),
        ],
        out_specs=pl.BlockSpec((1, chunk, block_d), lambda b, d, t: (b, t, d)),
        out_shape=jax.ShapeDtypeStruct((B, Sp, D), u.dtype),
        scratch_shapes=[pltpu.VMEM((1, block_d), jnp.float32)],
        interpret=interpret,
    )(a.astype(jnp.float32), u.astype(jnp.float32))
    return out[:, :S]
