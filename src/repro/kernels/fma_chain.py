"""The paper's benchmark-load kernel (Listing 1), adapted to TPU.

CUDA original: each thread runs a data-dependent chain of FMA pairs
``x = x*2+2; x = x/2-1`` (algebraically the identity, so the compiler
cannot drop it without breaking the dependence chain); duration is linear
in ``niter`` (Fig. 5, R²=1.000) and amplitude is set by the fraction of
SMs launched.

TPU adaptation (DESIGN.md §2): the unit of occupancy is not an SM but the
VPU lane grid.  The kernel holds an (8·rows, 128) f32 block in VMEM and
runs the same dependent FMA chain with ``jax.lax.fori_loop``; *duration*
is ``niter`` (linear — each iteration is 2 dependent VPU ops on the whole
block), *amplitude* is the fraction of grid slots doing work (``active``
mask per grid step — idle slots copy through), mirroring the paper's
``nblocks = SM_count × PERCENT``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fma_chain_kernel(active_ref, x_ref, o_ref, *, niter: int):
    """One grid slot: dependent FMA chain over the whole VMEM block."""
    x = x_ref[...]
    is_active = active_ref[0] > 0

    def body(_, v):
        v = v * 2.0 + 2.0          # FMA 1 (dependent)
        v = v * 0.5 - 1.0          # FMA 2 (dependent, inverts FMA 1)
        return v

    burned = jax.lax.fori_loop(0, niter, body, x)
    o_ref[...] = jnp.where(is_active, burned, x)


def fma_chain(x: jax.Array, niter: int, active_fraction: float = 1.0,
              block_rows: int = 256, interpret: bool = False) -> jax.Array:
    """x [N, 128] f32. Returns x unchanged (the chain is the identity);
    the point is the work: 2·niter dependent VPU ops per element.

    ``active_fraction`` enables only that fraction of grid slots —
    the TPU analogue of launching a fraction of SMs.
    """
    n, lanes = x.shape
    assert lanes == 128, "benchmark load operates on 128-lane rows"
    assert n % block_rows == 0, (n, block_rows)
    grid = n // block_rows
    n_active = max(1, int(round(grid * active_fraction)))
    active = (jnp.arange(grid, dtype=jnp.int32) < n_active).astype(jnp.int32)

    return pl.pallas_call(
        functools.partial(_fma_chain_kernel, niter=niter),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((block_rows, 128), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, 128), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(active, x)
