"""Sharded, async, elastic checkpointing (no orbax on the image).

Layout:  <root>/step_<N>/
           manifest.json          — shapes, dtypes, tree structure, extras
           <leafpath>.npy         — one file per leaf (host-local shards on
                                    multi-host: each host writes the rows of
                                    its addressable shards; single-host CI
                                    writes full arrays)

Elastic restore: leaves are stored unsharded-logical (full arrays), so a
restore may target ANY mesh/sharding — `restore` device_puts each leaf
with the sharding the *new* topology asks for.  That is the
elastic-rescale path: save on 512 chips, resume on 256, or vice versa.

Async: `save_async` snapshots to host memory synchronously (cheap, numpy
copies of addressable data) and writes files on a background thread, so
the train loop blocks only for the device→host copy, not the filesystem.

Fault tolerance: writes go to a temp dir renamed atomically on completion;
partially-written checkpoints are never visible to `latest_step`; `retain`
old checkpoints are garbage-collected after each successful save.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

try:
    import jax
except ImportError:       # numpy-only host: save path still works
    jax = None
import numpy as np

from repro.common.logging import get_logger
from repro.common.tree import flatten_with_paths

log = get_logger("ckpt")


def _leaf_fname(path: str) -> str:
    return path.replace("/", "_") + ".npy"


class CheckpointManager:
    def __init__(self, root: str, retain: int = 3):
        self.root = root
        self.retain = retain
        os.makedirs(root, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- discovery ---------------------------------------------------------
    def steps(self) -> List[int]:
        out = []
        for d in os.listdir(self.root):
            if d.startswith("step_") and not d.endswith(".tmp"):
                try:
                    out.append(int(d.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    # -- save ---------------------------------------------------------------
    def _snapshot(self, tree: Any) -> List[Tuple[str, np.ndarray, str]]:
        out = []
        for path, leaf in flatten_with_paths(tree):
            arr = np.asarray(leaf if jax is None else jax.device_get(leaf))
            logical = str(arr.dtype)
            if arr.dtype.kind == "V" or logical == "bfloat16":
                # non-native numpy dtype (bf16): store as f32, remember
                arr = arr.astype(np.float32)
            out.append((path, arr, logical))
        return out

    def _write(self, step: int, snap: Dict[str, List[Tuple[str, np.ndarray]]],
               extras: Dict[str, Any]) -> None:
        final = os.path.join(self.root, f"step_{step}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        manifest: Dict[str, Any] = {"step": step, "extras": extras,
                                    "trees": {}}
        for tree_name, leaves in snap.items():
            entries = {}
            for path, arr, logical in leaves:
                fname = f"{tree_name}__{_leaf_fname(path)}"
                np.save(os.path.join(tmp, fname), arr)
                entries[path] = {"file": fname, "shape": list(arr.shape),
                                 "dtype": logical}
            manifest["trees"][tree_name] = entries
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()
        log.info("checkpoint written", step=step)

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[:-self.retain]:
            shutil.rmtree(os.path.join(self.root, f"step_{s}"),
                          ignore_errors=True)

    def save(self, step: int, trees: Dict[str, Any],
             extras: Optional[Dict[str, Any]] = None) -> None:
        snap = {name: self._snapshot(t) for name, t in trees.items()}
        self._write(step, snap, extras or {})

    def save_async(self, step: int, trees: Dict[str, Any],
                   extras: Optional[Dict[str, Any]] = None) -> None:
        self.wait()   # one in-flight save at a time
        snap = {name: self._snapshot(t) for name, t in trees.items()}
        ex = dict(extras or {})
        self._thread = threading.Thread(
            target=self._write, args=(step, snap, ex), daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # -- restore -------------------------------------------------------------
    def restore(self, step: int, tree_specs: Dict[str, Any],
                shardings: Optional[Dict[str, Any]] = None
                ) -> Tuple[Dict[str, Any], Dict[str, Any]]:
        """Rebuild trees (matching `tree_specs` structure) from disk.

        ``shardings``: optional matching trees of NamedShardings — the
        elastic path: leaves are device_put with the *target* topology's
        sharding regardless of how the checkpoint was produced.

        Requires jax (device placement + tree reconstruction).  On
        numpy-only hosts read the ``manifest.json`` + ``.npy`` layout
        directly — :func:`repro.core.stream.checkpoint.restore_monitor`
        is the reference reader.
        """
        if jax is None:
            raise RuntimeError(
                "CheckpointManager.restore requires jax; on numpy-only "
                "hosts read manifest.json + the .npy leaves directly")
        d = os.path.join(self.root, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        out: Dict[str, Any] = {}
        for name, spec_tree in tree_specs.items():
            entries = manifest["trees"][name]
            flat_spec = flatten_with_paths(spec_tree)
            shard_tree = shardings.get(name) if shardings else None
            flat_shard = (flatten_with_paths(shard_tree)
                          if shard_tree is not None else None)
            leaves = []
            for i, (path, spec) in enumerate(flat_spec):
                e = entries[path]
                arr = np.load(os.path.join(d, e["file"]))
                if tuple(arr.shape) != tuple(spec.shape):
                    raise ValueError(
                        f"{name}.{path}: ckpt shape {arr.shape} != "
                        f"spec {spec.shape}")
                jarr = jax.numpy.asarray(arr).astype(spec.dtype)
                if flat_shard is not None:
                    leaves.append(jax.device_put(jarr, flat_shard[i][1]))
                else:
                    leaves.append(jarr)
            treedef = jax.tree_util.tree_structure(spec_tree)
            out[name] = jax.tree_util.tree_unflatten(treedef, leaves)
        return out, manifest["extras"]
