"""Live collector subsystem: real telemetry → the streaming monitor.

The bridge from what fleets actually record — ``nvidia-smi --query-gpu``
CSV captures and daemon-style per-row logs — into the repo's streaming
monitor stack.  Layers, importable à la carte:

* :mod:`repro.collect.wire` — wire-format parsers/writers with
  drop-and-count accounting (:class:`WireCounters`) and the columnar
  :class:`SampleBatch` interchange type.
* :mod:`repro.collect.registry` — :class:`DeviceRegistry`, the
  gpu_uuid → dense-device-id mapping with hot-add / frozen-fleet
  policies.
* :mod:`repro.collect.sampler` — the NVML-style :class:`Sampler`
  protocol: :class:`SimulatedSampler` over a ``SensorBank`` and the
  lazily-imported :class:`NvmlSampler` for real hosts.
* :mod:`repro.collect.assembler` — :class:`SlabAssembler` (fixed-size
  ingest slabs) and :class:`CollectorPipeline` (registry + calibration
  store + lazy monitor + hot-growth, end to end).
* :mod:`repro.collect.cli` — ``python -m repro.collect replay`` /
  ``calibrate ...``.

See ``docs/collect.md``.
"""
from repro.collect.assembler import CollectorPipeline, SlabAssembler
from repro.collect.registry import DeviceRegistry, UnknownDeviceError
from repro.collect.sampler import NvmlSampler, Sampler, SimulatedSampler
from repro.collect.wire import (SampleBatch, WireCounters, format_daemon,
                                format_query_gpu, iter_batches, parse_daemon,
                                parse_log, parse_query_gpu, sniff_format)

__all__ = [
    "CollectorPipeline", "SlabAssembler",
    "DeviceRegistry", "UnknownDeviceError",
    "NvmlSampler", "Sampler", "SimulatedSampler",
    "SampleBatch", "WireCounters",
    "format_daemon", "format_query_gpu",
    "iter_batches", "parse_daemon", "parse_log", "parse_query_gpu",
    "sniff_format",
]
