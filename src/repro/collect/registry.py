"""gpu_uuid → dense device id mapping with hot-add semantics.

Every array in the monitor stack is indexed by a dense ``[0, N)`` device
id; real telemetry is keyed by opaque GPU uuids that appear whenever a
node joins the fleet.  :class:`DeviceRegistry` owns that mapping and the
policy for uuids it has never seen:

* ``on_unknown="add"`` (lenient, the default) — assign the next dense
  id in first-seen order; the collector pipeline then grows the monitor
  to match (see :meth:`~repro.core.stream.monitor.MonitorService.grow`).
* ``on_unknown="reject"`` (frozen fleet) — map to ``-1`` and count;
  downstream a ``MonitorService(strict_ids=False)`` rejects-and-counts
  those samples, so nothing raises but nothing is silently absorbed
  into the wrong device either.
* ``on_unknown="raise"`` (strict) — :class:`UnknownDeviceError`.

First-seen order is the registry's *contract*: replaying the same log
through a fresh registry reproduces the same uuid→id mapping, which is
what makes collector replays comparable run to run.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional

import numpy as np

_POLICIES = ("add", "reject", "raise")


class UnknownDeviceError(KeyError):
    """A uuid not in the registry under ``on_unknown="raise"``."""


class DeviceRegistry:
    """Dense-id registry over gpu uuids (see module doc).

    Usage::

        reg = DeviceRegistry()                    # lenient hot-add
        ids = reg.resolve(batch.uuid, t=batch.t)  # [K] int64 (-1 = rejected)
        reg.n_devices                             # grows in first-seen order
    """

    def __init__(self, uuids: Iterable[str] = (), *,
                 on_unknown: str = "add"):
        if on_unknown not in _POLICIES:
            raise ValueError(f"unknown on_unknown policy '{on_unknown}'; "
                             f"known: {', '.join(_POLICIES)}")
        self.on_unknown = on_unknown
        self._ids: Dict[str, int] = {}
        self.uuids: List[str] = []
        self.first_seen_t: List[float] = []
        self.n_rejected = 0
        for u in uuids:
            self.add(str(u))

    @property
    def n_devices(self) -> int:
        return len(self.uuids)

    def __contains__(self, uuid: str) -> bool:
        return uuid in self._ids

    def id_of(self, uuid: str) -> int:
        """The dense id of a known uuid (KeyError otherwise)."""
        return self._ids[uuid]

    def add(self, uuid: str, t: float = np.nan) -> int:
        """Register a uuid (idempotent); returns its dense id."""
        i = self._ids.get(uuid)
        if i is not None:
            return i
        i = len(self.uuids)
        self._ids[uuid] = i
        self.uuids.append(uuid)
        self.first_seen_t.append(float(t))
        return i

    def resolve(self, uuids: np.ndarray,
                t: Optional[np.ndarray] = None) -> np.ndarray:
        """Map a batch of uuids to dense ids [K] int64, applying the
        unknown-uuid policy.  ``t`` (optional, [K]) stamps each
        hot-added uuid's ``first_seen_t`` with its first sample time.
        """
        k = len(uuids)
        out = np.empty(k, dtype=np.int64)
        ids = self._ids
        for j in range(k):
            u = uuids[j]
            i = ids.get(u)
            if i is None:
                if self.on_unknown == "add":
                    i = self.add(u, np.nan if t is None else float(t[j]))
                elif self.on_unknown == "reject":
                    self.n_rejected += 1
                    i = -1
                else:
                    raise UnknownDeviceError(
                        f"uuid '{u}' not in the frozen registry "
                        f"({self.n_devices} known devices)")
            out[j] = i
        return out

    def summary(self) -> dict:
        return {
            "n_devices": self.n_devices,
            "on_unknown": self.on_unknown,
            "n_rejected": self.n_rejected,
            "uuids": list(self.uuids),
            "first_seen_t": [float(x) for x in self.first_seen_t],
        }
