"""Live samplers: the NVML-style polling interface behind the collector.

A :class:`Sampler` is anything that answers "one poll of every visible
device, now" as a :class:`~repro.collect.wire.SampleBatch` — the
protocol an on-host polling daemon implements against NVML.  Two
implementations ship:

* :class:`SimulatedSampler` — backed by a
  :class:`~repro.core.fleet_engine.SensorBank`, so the entire collector
  path (sampler → registry → assembler → monitor) is exercised without
  hardware, and its output is pinned bitwise against the simulation-fed
  :func:`repro.core.stream.replay.replay` driver in
  ``tests/test_collect.py``.
* :class:`NvmlSampler` — the real thing over ``pynvml``, imported
  lazily so the module stays importable (and the simulated path fully
  testable) on hosts without the NVIDIA stack.  CI never touches it;
  on a GPU host it is the drop-in producer for the same pipeline.
"""
from __future__ import annotations

from typing import Iterator, Optional, Protocol, Sequence

import numpy as np

from repro.collect.wire import SampleBatch


class Sampler(Protocol):
    """One poll of every visible device (NVML-style)."""

    def sample(self) -> SampleBatch:
        """Read every device once; timestamps are the sampler's clock."""
        ...


class SimulatedSampler:
    """Poll a :class:`~repro.core.fleet_engine.SensorBank` like a daemon.

    Each :meth:`sample` reads all N sensors at the current clock and
    advances it by ``period_s`` — exactly the uniform grid
    ``SensorBank.iter_poll_slabs`` emits, so a collector built on this
    sampler reproduces the simulation-fed replay bit for bit.  Synthetic
    uuids are ``{prefix}{seed:08x}`` (derived from each device's rng
    seed: stable across runs, unique within a bank).
    """

    def __init__(self, bank, t0: float = 0.0, period_s: float = 0.001,
                 uuid_prefix: str = "GPU-SIM-",
                 uuids: Optional[Sequence[str]] = None):
        if period_s <= 0.0:
            raise ValueError(f"period_s must be > 0, got {period_s}")
        self.bank = bank
        self.t0 = float(t0)
        self.period_s = float(period_s)
        n = bank.n_devices
        if uuids is None:
            self.uuids = np.asarray(
                [f"{uuid_prefix}{int(s) & 0xFFFFFFFF:08x}"
                 for s in bank.seeds], dtype=object)
        else:
            self.uuids = np.asarray(list(uuids), dtype=object)
        if self.uuids.shape != (n,):
            raise ValueError(f"need {n} uuids, got {self.uuids.shape}")
        if len(set(self.uuids)) != n:
            raise ValueError("sampler uuids must be unique")
        self._k = 0          # polls taken so far

    @property
    def t_next(self) -> float:
        """The clock instant the next :meth:`sample` will read at."""
        return self.t0 + self.period_s * self._k

    def sample(self) -> SampleBatch:
        t = self.t_next
        vals = np.asarray(self.bank.query(t), dtype=np.float64)
        self._k += 1
        n = self.bank.n_devices
        return SampleBatch(uuid=self.uuids.copy(),
                           t=np.full(n, t),
                           power_w=vals,
                           util=np.full(n, np.nan))

    def run(self, n_polls: int) -> Iterator[SampleBatch]:
        """Take ``n_polls`` consecutive samples."""
        for _ in range(int(n_polls)):
            yield self.sample()


class NvmlSampler:
    """Poll real GPUs through NVML (``pynvml``), lazily imported.

    Construction raises a clear RuntimeError when the NVIDIA stack is
    absent — no import-time dependency, so everything else in
    :mod:`repro.collect` works on a CPU-only host.
    """

    def __init__(self):
        try:
            import pynvml
        except ImportError as e:
            raise RuntimeError(
                "NvmlSampler needs the 'pynvml' package and an NVIDIA "
                "driver; on hosts without them use SimulatedSampler or "
                "replay a recorded log") from e
        self._nvml = pynvml
        pynvml.nvmlInit()
        n = pynvml.nvmlDeviceGetCount()
        self._handles = [pynvml.nvmlDeviceGetHandleByIndex(i)
                         for i in range(n)]
        self.uuids = np.asarray(
            [_as_str(pynvml.nvmlDeviceGetUUID(h)) for h in self._handles],
            dtype=object)

    def sample(self) -> SampleBatch:
        import time
        nvml = self._nvml
        t = time.time()
        n = len(self._handles)
        power = np.full(n, np.nan)
        util = np.full(n, np.nan)
        for i, h in enumerate(self._handles):
            try:
                power[i] = nvml.nvmlDeviceGetPowerUsage(h) * 1e-3  # mW → W
            except nvml.NVMLError:
                pass                      # [N/A] — stays NaN, counted
            try:                          # downstream by the monitor
                util[i] = nvml.nvmlDeviceGetUtilizationRates(h).gpu
            except nvml.NVMLError:
                pass
        return SampleBatch(uuid=self.uuids.copy(), t=np.full(n, t),
                           power_w=power, util=util)

    def close(self) -> None:
        self._nvml.nvmlShutdown()


def _as_str(x) -> str:
    return x.decode() if isinstance(x, bytes) else str(x)
