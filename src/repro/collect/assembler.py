"""Slab assembly: parsed wire samples → monitor ingest slabs.

:class:`SlabAssembler` turns any stream of
:class:`~repro.collect.wire.SampleBatch` chunks into the flat
``(device, t, reading)`` slabs the streaming monitor ingests — the same
shape :meth:`SensorBank.iter_poll_slabs` emits, so everything downstream
(ingest policy, fault counters, checkpointing, serving) is oblivious to
whether samples came from a simulation or a real collector.  Slabs are
emitted at **exactly** ``slab_samples`` samples (remainder on
``flush``): slab boundaries depend only on the sample stream and the
slab size, never on how the upstream file reader happened to chunk its
batches — which is what makes a replay reproducible slab-for-slab.

:class:`CollectorPipeline` is the end-to-end driver the CLI wraps:
registry resolution (hot-add or reject), correction lookup against a
:class:`~repro.core.calibrate_store.ArtifactStore`, lazy monitor
construction, and mid-stream :meth:`MonitorService.grow` when a new
gpu_uuid joins a lenient fleet.  The pipeline's result is pinned
bitwise (numpy backend) against building the full-width monitor up
front and ingesting the same slabs — hot-add is an optimisation, never
a semantic fork.
"""
from __future__ import annotations

from typing import Iterator, List, Optional, Tuple

import numpy as np

from repro.collect.registry import DeviceRegistry
from repro.collect.wire import SampleBatch
from repro.core.calibrate import CalibrationRecord
from repro.core.calibrate_store import ArtifactStore, resolve_corrections
from repro.core.stream.monitor import MonitorService

Slab = Tuple[np.ndarray, np.ndarray, np.ndarray]


class SlabAssembler:
    """Batch resolved samples into fixed-size ingest slabs (module doc).

    Usage::

        asm = SlabAssembler(registry, slab_samples=65536)
        for batch in wire.iter_batches(path):
            for dev, t, v in asm.push(batch):
                monitor.ingest(dev, t, v)
        for dev, t, v in asm.flush():
            monitor.ingest(dev, t, v)
    """

    def __init__(self, registry: DeviceRegistry, *,
                 slab_samples: int = 65536, rebase: bool = False):
        if slab_samples < 1:
            raise ValueError(f"slab_samples must be >= 1, "
                             f"got {slab_samples}")
        self.registry = registry
        self.slab_samples = int(slab_samples)
        self.rebase = bool(rebase)
        self.t0: Optional[float] = None     # rebase origin (first sample)
        self.n_samples = 0                  # samples pushed (pre-slab)
        self.n_slabs = 0
        self._dev: List[np.ndarray] = []
        self._t: List[np.ndarray] = []
        self._v: List[np.ndarray] = []
        self._buffered = 0

    def push(self, batch: SampleBatch) -> Iterator[Slab]:
        """Resolve one batch through the registry and yield every
        complete slab it fills.  Rejected uuids (frozen registry) keep
        their ``-1`` ids — the monitor's ``strict_ids=False`` path
        rejects-and-counts them, so accounting stays at the ingest
        layer where the other drop counters live."""
        k = len(batch)
        if k == 0:
            return
        dev = self.registry.resolve(batch.uuid, batch.t)
        t = np.asarray(batch.t, dtype=np.float64)
        if self.rebase:
            if self.t0 is None:
                self.t0 = float(t[0])
            t = t - self.t0
        self._dev.append(dev)
        self._t.append(t)
        self._v.append(np.asarray(batch.power_w, dtype=np.float64))
        self._buffered += k
        self.n_samples += k
        while self._buffered >= self.slab_samples:
            yield self._emit(self.slab_samples)

    def flush(self) -> Iterator[Slab]:
        """Yield the final partial slab (if any)."""
        if self._buffered:
            yield self._emit(self._buffered)

    def _emit(self, k: int) -> Slab:
        dev = np.concatenate(self._dev)
        t = np.concatenate(self._t)
        v = np.concatenate(self._v)
        self._dev, self._t, self._v = [dev[k:]], [t[k:]], [v[k:]]
        self._buffered = dev.size - k
        self.n_slabs += 1
        return dev[:k], t[:k], v[:k]


class CollectorPipeline:
    """Wire batches → calibrated streaming monitor (see module doc).

    ``store`` supplies per-device active calibration records (None →
    every device falls back to ``default_record`` or identity);
    ``max_age_s``/``now`` gate record freshness at resolve time (one
    consistent ``now`` for the whole run, so a record cannot age out
    halfway through a replay).  The monitor is built lazily at the
    registry's width when the first slab lands, with
    ``strict_ids=False`` (the defensive posture a real collector needs;
    override via ``monitor_kwargs``), and grows on hot-add.
    """

    def __init__(self, *, store: Optional[ArtifactStore] = None,
                 default_record: Optional[CalibrationRecord] = None,
                 registry: Optional[DeviceRegistry] = None,
                 backend: Optional[str] = None,
                 slab_samples: int = 65536,
                 rebase: bool = False,
                 baseline_w: float = 0.0,
                 max_age_s: Optional[float] = None,
                 now: Optional[float] = None,
                 monitor_kwargs: Optional[dict] = None):
        import time as _time
        self.store = store
        self.default_record = default_record
        self.registry = registry if registry is not None else DeviceRegistry()
        self.assembler = SlabAssembler(self.registry,
                                       slab_samples=slab_samples,
                                       rebase=rebase)
        self.backend = backend
        self.baseline_w = float(baseline_w)
        self.max_age_s = max_age_s
        self.now = float(now) if now is not None else _time.time()
        self.monitor_kwargs = dict(monitor_kwargs or {})
        self.monitor_kwargs.setdefault("strict_ids", False)
        self.monitor_kwargs.setdefault("backend", backend)
        self.monitor: Optional[MonitorService] = None
        self.n_active_records = 0

    # -- correction resolution --------------------------------------------
    def _resolve(self, uuids) -> tuple:
        corr, labels, n_act = resolve_corrections(
            uuids, store=self.store, default=self.default_record,
            baseline_w=self.baseline_w, max_age_s=self.max_age_s,
            now=self.now)
        return corr, labels, n_act

    # -- monitor lifecycle -------------------------------------------------
    def _ensure_monitor(self) -> MonitorService:
        n = max(self.registry.n_devices, 1)
        if self.monitor is None:
            corr, labels, n_act = self._resolve(self.registry.uuids)
            if self.registry.n_devices == 0:     # all-rejected stream:
                corr, labels = None, None        # a 1-wide husk monitor
            self.n_active_records = n_act
            self.monitor = MonitorService(
                n, corrections=corr, labels=labels, **self.monitor_kwargs)
        elif n > self.monitor.n_devices:
            n_old = self.monitor.n_devices
            tail = self.registry.uuids[n_old:]
            corr, labels, n_act = self._resolve(tail)
            self.n_active_records += n_act
            self.monitor.grow(n, corrections=corr, labels=labels)
        return self.monitor

    # -- driving -----------------------------------------------------------
    def feed(self, batch: SampleBatch) -> None:
        """Push one wire batch through registry + assembler, ingesting
        every complete slab (growing the monitor first when the batch
        hot-added devices)."""
        for dev, t, v in self.assembler.push(batch):
            self._ensure_monitor().ingest(dev, t, v)

    def finish(self) -> Optional[MonitorService]:
        """Flush the assembler's tail; returns the monitor (None when
        no sample ever arrived)."""
        for dev, t, v in self.assembler.flush():
            self._ensure_monitor().ingest(dev, t, v)
        return self.monitor

    def summary(self) -> dict:
        out = {
            "n_devices": self.registry.n_devices,
            "n_samples": self.assembler.n_samples,
            "n_slabs": self.assembler.n_slabs,
            "n_active_records": self.n_active_records,
            "registry_rejected": self.registry.n_rejected,
        }
        if self.monitor is not None:
            out["ingest"] = dict(self.monitor.counters)
        return out
