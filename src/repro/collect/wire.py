"""Wire-format parsing: real power-telemetry logs → sample batches.

Two formats cover what fleets actually emit:

* **smi** — ``nvidia-smi --query-gpu=... --format=csv`` output: a
  header row naming the columns (units in brackets, ``power.draw [W]``),
  then one row per GPU per poll.  Cells may be ``[N/A]``,
  ``[Unknown Error]`` or ``ERR!`` (the tool reports sensor failures
  in-band); power carries a unit suffix (``68.84 W``, ``68840 mW``) or
  none under ``--format=csv,nounits``; timestamps are
  ``YYYY/MM/DD HH:MM:SS.mmm`` (parsed as UTC — nvidia-smi prints local
  naive time, so collectors that care must run under ``TZ=UTC``; a
  deterministic parse beats a machine-dependent one).  Long captures
  (``-l``/``-lms`` loops, restarted collectors) repeat the header
  mid-stream; repeated headers re-bind the column order.
* **daemon** — per-row CSV from a polling daemon
  (``gpu_uuid,timestamp,power.draw,utilization``): epoch-seconds
  timestamps, unit-less floats, optional header.  This is the
  jacquetpi/daemon-ai-reader production shape.

Parsing never throws on bad data: malformed rows, ``[N/A]`` power
cells and error cells are dropped and **counted** in
:class:`WireCounters` — a collector that dies on one garbled line loses
the whole capture.  Rows survive in file order (duplicates and
out-of-order timestamps included): ordering policy belongs to the
monitor's ingest layer, which already drops-and-counts them, not to the
parser.

The writers (:func:`format_daemon`, :func:`format_query_gpu`) emit the
same formats — they feed the committed test fixture and the round-trip
property tests, and let a :class:`~repro.collect.sampler.Sampler` dump a
live capture to disk in a replayable form.
"""
from __future__ import annotations

import dataclasses
from datetime import datetime, timezone
from typing import Iterable, Iterator, List, Optional, Sequence, Tuple, Union

import numpy as np

FORMATS = ("smi", "daemon")

# normalised header aliases -> canonical column names
_COLUMN_ALIASES = {
    "uuid": "uuid", "gpu_uuid": "uuid", "gpu uuid": "uuid",
    "timestamp": "timestamp",
    "power.draw": "power", "power.draw.instant": "power",
    "power.draw.average": "power", "power": "power",
    "utilization.gpu": "util", "utilization": "util",
}
_UNIT_SCALE = {"w": 1.0, "mw": 1e-3, "kw": 1e3}
_NA_CELLS = {"[n/a]", "n/a", "na"}
_ERR_CELLS = {"[unknown error]", "err!", "[unsupported]"}
_SMI_TS = "%Y/%m/%d %H:%M:%S"


@dataclasses.dataclass
class WireCounters:
    """Per-parse accounting: every input row lands in exactly one
    bucket (``samples + malformed + not_available + error_cells``
    plus ``headers``/``blank`` covers ``rows``)."""

    rows: int = 0             # physical non-empty lines seen
    samples: int = 0          # rows that produced a sample
    headers: int = 0          # header lines (incl. mid-stream repeats)
    blank: int = 0            # empty/whitespace lines
    malformed: int = 0        # wrong arity / unparseable cells
    not_available: int = 0    # power cell was [N/A]
    error_cells: int = 0      # power cell was [Unknown Error] / ERR!

    def merge(self, other: "WireCounters") -> None:
        for f in dataclasses.fields(self):
            setattr(self, f.name,
                    getattr(self, f.name) + getattr(other, f.name))

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class SampleBatch:
    """One parsed batch of raw power samples, columnar.

    ``uuid`` [K] device uuids (object), ``t`` [K] seconds (epoch or
    collector-relative — the parser preserves whatever the wire said),
    ``power_w`` [K] watts, ``util`` [K] utilisation percent (NaN when
    the wire had none).
    """

    uuid: np.ndarray
    t: np.ndarray
    power_w: np.ndarray
    util: np.ndarray

    def __len__(self) -> int:
        return self.t.shape[0]

    @classmethod
    def empty(cls) -> "SampleBatch":
        return cls(uuid=np.empty(0, dtype=object), t=np.empty(0),
                   power_w=np.empty(0), util=np.empty(0))

    @classmethod
    def from_rows(cls, uuids: Sequence[str], t: Sequence[float],
                  power_w: Sequence[float],
                  util: Optional[Sequence[float]] = None) -> "SampleBatch":
        k = len(t)
        return cls(uuid=np.asarray(list(uuids), dtype=object),
                   t=np.asarray(t, dtype=np.float64),
                   power_w=np.asarray(power_w, dtype=np.float64),
                   util=(np.full(k, np.nan) if util is None
                         else np.asarray(util, dtype=np.float64)))

    def concat(self, other: "SampleBatch") -> "SampleBatch":
        return SampleBatch(
            uuid=np.concatenate([self.uuid, other.uuid]),
            t=np.concatenate([self.t, other.t]),
            power_w=np.concatenate([self.power_w, other.power_w]),
            util=np.concatenate([self.util, other.util]))


# -- cell parsers -----------------------------------------------------------

def parse_power_cell(cell: str) -> Tuple[float, str]:
    """One power cell → ``(watts, status)`` with status one of
    ``"ok"``/``"na"``/``"error"``/``"malformed"`` (watts is NaN for
    everything but ``"ok"``).  Handles unit suffixes (``W``/``mW``/
    ``kW``), ``nounits`` bare floats, and the in-band failure cells."""
    s = cell.strip()
    low = s.lower()
    if low in _NA_CELLS:
        return np.nan, "na"
    if low in _ERR_CELLS:
        return np.nan, "error"
    parts = s.split()
    try:
        if len(parts) == 1:
            return float(parts[0]), "ok"
        if len(parts) == 2:
            scale = _UNIT_SCALE.get(parts[1].lower())
            if scale is None:
                return np.nan, "malformed"
            return float(parts[0]) * scale, "ok"
    except ValueError:
        pass
    return np.nan, "malformed"


def parse_timestamp_cell(cell: str) -> float:
    """One timestamp cell → epoch seconds (NaN when unparseable).

    Accepts bare epoch floats (daemon logs), nvidia-smi's
    ``YYYY/MM/DD HH:MM:SS.mmm`` and ISO-8601 ``YYYY-MM-DDTHH:MM:SS[.f]``
    — naive stamps are taken as UTC so a log parses to the same numbers
    on every machine."""
    s = cell.strip()
    try:
        return float(s)
    except ValueError:
        pass
    base, frac = s, 0.0
    if "." in s:
        base, frac_s = s.rsplit(".", 1)
        try:
            frac = float("0." + frac_s)
        except ValueError:
            return np.nan
    for fmt in (_SMI_TS, "%Y-%m-%dT%H:%M:%S", "%Y-%m-%d %H:%M:%S"):
        try:
            dt = datetime.strptime(base, fmt)
        except ValueError:
            continue
        return dt.replace(tzinfo=timezone.utc).timestamp() + frac
    return np.nan


def parse_util_cell(cell: str) -> float:
    s = cell.strip().rstrip("%").strip()
    if s.lower() in _NA_CELLS or s.lower() in _ERR_CELLS or not s:
        return np.nan
    try:
        return float(s)
    except ValueError:
        return np.nan


def _header_map(cells: List[str]) -> Optional[dict]:
    """Map a header row to column positions, or None if it isn't one.
    A header binds a column for every alias it names; unknown columns
    (memory.used, temperature, ...) are simply ignored."""
    hit = {}
    for i, c in enumerate(cells):
        name = c.strip().lower()
        if "[" in name:                      # strip a " [W]" unit suffix
            name = name.split("[", 1)[0].strip()
        canon = _COLUMN_ALIASES.get(name)
        if canon is not None and canon not in hit:
            hit[canon] = i
    if "uuid" in hit and "power" in hit:
        return hit
    return None


# -- line-stream parsers ----------------------------------------------------

def _parse_lines(lines: Iterable[str], fmt: str,
                 strict_arity: bool = True
                 ) -> Tuple[SampleBatch, WireCounters]:
    """The shared row loop.  ``fmt`` picks the default column binding;
    header rows (either format) rebind columns mid-stream."""
    if fmt not in FORMATS:
        raise ValueError(f"unknown wire format '{fmt}'; "
                         f"known: {', '.join(FORMATS)}")
    # daemon default binding applies before any header is seen; smi
    # requires its header (column order is whatever --query-gpu said)
    cols = ({"uuid": 0, "timestamp": 1, "power": 2, "util": 3}
            if fmt == "daemon" else None)
    n_cols = 4 if fmt == "daemon" else None
    c = WireCounters()
    uuids: List[str] = []
    ts: List[float] = []
    pw: List[float] = []
    ut: List[float] = []
    for line in lines:
        s = line.strip()
        if not s:
            c.blank += 1
            continue
        c.rows += 1
        cells = s.split(",")
        hdr = _header_map(cells)
        if hdr is not None and any(not _is_number(cells[i])
                                   for i in hdr.values()):
            cols = hdr
            n_cols = len(cells)
            c.headers += 1
            continue
        if cols is None:           # smi data before any header: no
            c.malformed += 1       # column binding to parse it with
            continue
        if len(cells) <= max(cols.values()) or (
                strict_arity and n_cols is not None
                and len(cells) != n_cols):
            c.malformed += 1
            continue
        t = parse_timestamp_cell(cells[cols["timestamp"]]) \
            if "timestamp" in cols else np.nan
        if not np.isfinite(t):
            c.malformed += 1
            continue
        p, status = parse_power_cell(cells[cols["power"]])
        if status == "na":
            c.not_available += 1
            continue
        if status == "error":
            c.error_cells += 1
            continue
        if status == "malformed":
            c.malformed += 1
            continue
        uuid = cells[cols["uuid"]].strip()
        if not uuid:
            c.malformed += 1
            continue
        u = (parse_util_cell(cells[cols["util"]])
             if "util" in cols and cols["util"] < len(cells) else np.nan)
        uuids.append(uuid)
        ts.append(t)
        pw.append(p)
        ut.append(u)
        c.samples += 1
    return SampleBatch.from_rows(uuids, ts, pw, ut), c


def _is_number(cell: str) -> bool:
    try:
        float(cell.strip())
        return True
    except ValueError:
        return False


def parse_query_gpu(text: Union[str, Iterable[str]]
                    ) -> Tuple[SampleBatch, WireCounters]:
    """Parse ``nvidia-smi --query-gpu ... --format=csv`` output."""
    lines = text.splitlines() if isinstance(text, str) else text
    return _parse_lines(lines, "smi")


def parse_daemon(text: Union[str, Iterable[str]]
                 ) -> Tuple[SampleBatch, WireCounters]:
    """Parse daemon-style per-row CSV
    (``gpu_uuid,timestamp,power.draw,utilization``; header optional)."""
    lines = text.splitlines() if isinstance(text, str) else text
    return _parse_lines(lines, "daemon")


def sniff_format(first_lines: Sequence[str]) -> str:
    """Guess the wire format from the first few non-empty lines.

    A header with bracketed units (or any nvidia-smi date-shaped
    timestamp cell) means **smi**; a 4-column row whose second cell is
    a bare float (epoch seconds) means **daemon**.  Falls back to
    daemon — the format with a default binding."""
    for line in first_lines:
        s = line.strip()
        if not s:
            continue
        if "[" in s and "]" in s and _header_map(s.split(",")):
            return "smi"
        cells = s.split(",")
        hdr = _header_map(cells)
        if hdr is not None and any(not _is_number(cells[i])
                                   for i in hdr.values()):
            # unit-less header: daemon's own header names its columns
            return "daemon" if "[" not in s else "smi"
        if len(cells) >= 2:
            if _is_number(cells[1]):
                return "daemon"
            if np.isfinite(parse_timestamp_cell(cells[1])):
                return "smi"
    return "daemon"


def iter_batches(path: str, fmt: str = "auto",
                 batch_rows: int = 8192,
                 counters: Optional[WireCounters] = None
                 ) -> Iterator[SampleBatch]:
    """Stream a log file as :class:`SampleBatch` chunks of about
    ``batch_rows`` rows — bounded memory however long the capture.
    Pass a :class:`WireCounters` to accumulate parse accounting across
    the whole file (each yielded batch folds into it)."""
    if batch_rows < 1:
        raise ValueError(f"batch_rows must be >= 1, got {batch_rows}")
    with open(path) as f:
        if fmt == "auto":
            head = []
            for line in f:
                head.append(line)
                if len(head) >= 8:
                    break
            fmt = sniff_format(head)
            f.seek(0)
        if fmt not in FORMATS:
            raise ValueError(f"unknown wire format '{fmt}'")
        # smi headers must survive chunk boundaries: parse chunk-wise but
        # re-feed the last seen header so column bindings persist
        pend: List[str] = []
        carry_header: List[str] = []
        for line in f:
            pend.append(line)
            if len(pend) >= batch_rows:
                batch, c = _parse_lines(carry_header + pend, fmt)
                if carry_header:
                    c.headers -= len(carry_header)
                    c.rows -= len(carry_header)
                carry_header = _last_header(pend, carry_header)
                if counters is not None:
                    counters.merge(c)
                pend = []
                if len(batch):
                    yield batch
        if pend:
            batch, c = _parse_lines(carry_header + pend, fmt)
            if carry_header:
                c.headers -= len(carry_header)
                c.rows -= len(carry_header)
            if counters is not None:
                counters.merge(c)
            if len(batch):
                yield batch


def _last_header(lines: List[str], prev: List[str]) -> List[str]:
    """The most recent header line in ``lines`` (falling back to the
    carried one) — what the next chunk parses under."""
    for line in reversed(lines):
        cells = line.strip().split(",")
        hdr = _header_map(cells)
        if hdr is not None and any(not _is_number(cells[i])
                                   for i in hdr.values()):
            return [line if line.endswith("\n") else line + "\n"]
    return prev


def parse_log(path: str, fmt: str = "auto"
              ) -> Tuple[SampleBatch, WireCounters]:
    """Parse a whole log file in one go (see :func:`iter_batches` for
    the bounded-memory streaming form).  Returns the samples plus the
    full parse accounting."""
    c = WireCounters()
    batches = list(iter_batches(path, fmt=fmt, counters=c))
    if not batches:
        return SampleBatch.empty(), c
    out = batches[0]
    for b in batches[1:]:
        out = out.concat(b)
    return out, c


# -- writers ----------------------------------------------------------------

def format_daemon(batch: SampleBatch, header: bool = True,
                  precision: Optional[int] = None) -> str:
    """Render a batch as daemon-style per-row CSV.  ``precision=None``
    writes ``repr`` floats (lossless round-trip — what the fixture's
    bitwise tests rely on); an int mimics a daemon that rounds."""
    def num(x: float) -> str:
        if not np.isfinite(x):
            return "nan"
        return repr(float(x)) if precision is None \
            else f"{float(x):.{precision}f}"

    lines = ["gpu_uuid,timestamp,power.draw,utilization"] if header else []
    for i in range(len(batch)):
        lines.append(f"{batch.uuid[i]},{num(batch.t[i])},"
                     f"{num(batch.power_w[i])},{num(batch.util[i])}")
    return "\n".join(lines) + "\n"


def format_query_gpu(batch: SampleBatch, nounits: bool = False,
                     power_decimals: int = 2) -> str:
    """Render a batch as ``nvidia-smi --query-gpu`` CSV (the lossy
    production format: millisecond timestamps, 2-decimal watts)."""
    unit_hdr = "power.draw, utilization.gpu" if nounits else \
        "power.draw [W], utilization.gpu [%]"
    lines = [f"uuid, timestamp, {unit_hdr}"]
    for i in range(len(batch)):
        dt = datetime.fromtimestamp(float(batch.t[i]), tz=timezone.utc)
        stamp = dt.strftime(_SMI_TS) + f".{dt.microsecond // 1000:03d}"
        p = f"{float(batch.power_w[i]):.{power_decimals}f}"
        u = ("[N/A]" if not np.isfinite(batch.util[i])
             else f"{float(batch.util[i]):.0f}")
        if nounits:
            lines.append(f"{batch.uuid[i]}, {stamp}, {p}, {u}")
        else:
            u = u if u == "[N/A]" else u + " %"
            lines.append(f"{batch.uuid[i]}, {stamp}, {p} W, {u}")
    return "\n".join(lines) + "\n"
