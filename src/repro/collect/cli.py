"""``python -m repro.collect`` — replay recorded logs, manage artifacts.

Two command families:

``replay LOG``
    Parse a recorded nvidia-smi / daemon CSV log, resolve gpu_uuids
    through a :class:`~repro.collect.registry.DeviceRegistry`, look up
    active calibration artifacts, and drive the full streaming monitor —
    printing a JSON summary (wire counters, registry growth, ingest
    counters, raw and corrected fleet energy).  This is the committed
    fixture's smoke path in CI and the quickstart's "ingest a real
    cluster log" entry point.

``calibrate list|save|activate|deactivate|gc``
    The :class:`~repro.core.calibrate_store.ArtifactStore` lifecycle
    from the shell: inspect versions, save nominal records, roll the
    active version forward/back, and age out stale artifacts.

Everything prints JSON on stdout (one object), so the commands compose
with ``jq`` and the CI smoke test asserts on parsed output rather than
scraping text.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from typing import List, Optional

from repro.collect import wire
from repro.collect.assembler import CollectorPipeline
from repro.collect.registry import DeviceRegistry
from repro.core import profiles
from repro.core.calibrate import CalibrationRecord, nominal_record
from repro.core.calibrate_store import ArtifactStore, StoreError


def _default_record(profile_name: Optional[str],
                    gain: Optional[float] = None,
                    offset_w: Optional[float] = None,
                    device_id: str = "*",
                    note: str = "") -> Optional[CalibrationRecord]:
    if profile_name is None:
        return None
    rec = nominal_record(device_id, profiles.get(profile_name))
    if gain is not None or offset_w is not None or note:
        rec = dataclasses.replace(
            rec, gain=gain, offset_w=offset_w, note=note,
            source="repro.collect.cli")
    return rec


# -- replay -------------------------------------------------------------------

def cmd_replay(args: argparse.Namespace) -> int:
    store = ArtifactStore(args.store) if args.store else None
    default = _default_record(args.default_profile)
    registry = DeviceRegistry(
        on_unknown="reject" if args.frozen else "add")
    if args.frozen:
        for dev in args.frozen:
            registry.add(dev)
    pipe = CollectorPipeline(
        store=store, default_record=default, registry=registry,
        backend=args.backend, slab_samples=args.slab_samples,
        rebase=args.rebase, baseline_w=args.baseline_w,
        max_age_s=args.max_age_s, now=args.now,
        monitor_kwargs={"strict_ids": False})
    counters = wire.WireCounters()
    for batch in wire.iter_batches(args.log, fmt=args.format,
                                   batch_rows=args.batch_rows,
                                   counters=counters):
        pipe.feed(batch)
    monitor = pipe.finish()

    out = {
        "log": args.log,
        "wire": counters.as_dict(),
        "registry": registry.summary(),
        "pipeline": pipe.summary(),
    }
    if monitor is not None:
        from repro.serve.monitor_service import (MonitorQuery,
                                                 MonitorQueryService)
        svc = MonitorQueryService(monitor)
        corrected, raw = svc.query_many([
            MonitorQuery.fleet_energy(corrected=True),
            MonitorQuery.fleet_energy(corrected=False),
        ])
        out["fleet_energy"] = {
            "corrected_j": corrected.total_j,
            "raw_j": raw.total_j,
            "n_reporting": corrected.n_reporting,
            "sigma_independent_j": corrected.sigma_independent_j,
            "sigma_worstcase_j": corrected.sigma_worstcase_j,
            "coverage": corrected.coverage,
        }
    _emit(out, args.json_path)
    return 0


# -- calibrate ----------------------------------------------------------------

def cmd_calibrate_list(args: argparse.Namespace) -> int:
    store = ArtifactStore(args.store)
    out = {"store": store.root,
           "artifacts": [info.summary() for info in store.list_all()]}
    _emit(out, args.json_path)
    return 0


def cmd_calibrate_save(args: argparse.Namespace) -> int:
    store = ArtifactStore(args.store)
    rec = _default_record(args.profile, gain=args.gain,
                          offset_w=args.offset_w, device_id=args.device,
                          note=args.note)
    assert rec is not None          # --profile is required by argparse
    v = store.save(rec, activate=args.activate)
    _emit({"device_id": args.device, "version": v,
           "active": bool(args.activate)}, args.json_path)
    return 0


def cmd_calibrate_activate(args: argparse.Namespace) -> int:
    store = ArtifactStore(args.store)
    store.activate(args.device, args.version)
    _emit({"device_id": args.device, "active_version": args.version},
          args.json_path)
    return 0


def cmd_calibrate_deactivate(args: argparse.Namespace) -> int:
    store = ArtifactStore(args.store)
    was = store.deactivate(args.device)
    _emit({"device_id": args.device, "was_active": was}, args.json_path)
    return 0


def cmd_calibrate_gc(args: argparse.Namespace) -> int:
    store = ArtifactStore(args.store)
    removed = store.gc(args.max_age_s, now=args.now,
                       keep_active=not args.collect_active,
                       dry_run=args.dry_run)
    _emit({"removed": removed, "dry_run": bool(args.dry_run)},
          args.json_path)
    return 0


# -- plumbing -----------------------------------------------------------------

def _emit(obj: dict, json_path: Optional[str]) -> None:
    text = json.dumps(obj, indent=2, sort_keys=True, default=_jsonify)
    if json_path:
        with open(json_path, "w") as f:
            f.write(text + "\n")
    print(text)


def _jsonify(x):
    import numpy as np
    if isinstance(x, (np.integer,)):
        return int(x)
    if isinstance(x, (np.floating,)):
        return float(x)
    if isinstance(x, np.ndarray):
        return x.tolist()
    raise TypeError(f"not JSON-serialisable: {type(x).__name__}")


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.collect",
        description="Replay recorded power logs into the streaming "
                    "monitor; manage versioned calibration artifacts.")
    sub = ap.add_subparsers(dest="command", required=True)

    rp = sub.add_parser("replay", help="replay a recorded CSV log "
                        "through the streaming monitor")
    rp.add_argument("log", help="path to the recorded log")
    rp.add_argument("--format", choices=("auto",) + wire.FORMATS,
                    default="auto", help="wire format (default: sniff)")
    rp.add_argument("--store", default=None,
                    help="ArtifactStore root for active calibrations")
    rp.add_argument("--default-profile", default=None,
                    help="nominal profile for devices without an active "
                         "artifact (e.g. a100); omit for identity")
    rp.add_argument("--backend", default=None,
                    choices=("numpy", "jax"),
                    help="monitor execution backend (default: auto)")
    rp.add_argument("--slab-samples", type=int, default=65536)
    rp.add_argument("--batch-rows", type=int, default=8192)
    rp.add_argument("--rebase", action="store_true",
                    help="shift timestamps so the first sample is t=0")
    rp.add_argument("--baseline-w", type=float, default=0.0)
    rp.add_argument("--max-age-s", type=float, default=None,
                    help="ignore active artifacts older than this")
    rp.add_argument("--now", type=float, default=None,
                    help="reference instant for --max-age-s (epoch "
                         "seconds; default: wall clock)")
    rp.add_argument("--frozen", metavar="UUID", nargs="+", default=None,
                    help="freeze the fleet to these uuids: unknown "
                         "devices are rejected-and-counted, not added")
    rp.add_argument("--json", dest="json_path", default=None,
                    help="also write the summary JSON to this path")
    rp.set_defaults(func=cmd_replay)

    cal = sub.add_parser("calibrate",
                         help="versioned calibration artifact lifecycle")
    calsub = cal.add_subparsers(dest="subcommand", required=True)

    def _common(p, device=False):
        p.add_argument("--store", required=True,
                       help="ArtifactStore root directory")
        if device:
            p.add_argument("--device", required=True,
                           help="device id / gpu_uuid")
        p.add_argument("--json", dest="json_path", default=None)

    lp = calsub.add_parser("list", help="list every saved artifact")
    _common(lp)
    lp.set_defaults(func=cmd_calibrate_list)

    sp = calsub.add_parser("save", help="save a nominal record as a "
                           "new artifact version")
    _common(sp, device=True)
    sp.add_argument("--profile", required=True,
                    help=f"sensor profile ({', '.join(sorted(profiles.CATALOG))})")
    sp.add_argument("--gain", type=float, default=None)
    sp.add_argument("--offset-w", type=float, default=None)
    sp.add_argument("--note", default="")
    sp.add_argument("--activate", action="store_true")
    sp.set_defaults(func=cmd_calibrate_save)

    acp = calsub.add_parser("activate", help="activate a saved version")
    _common(acp, device=True)
    acp.add_argument("--version", type=int, required=True)
    acp.set_defaults(func=cmd_calibrate_activate)

    dep = calsub.add_parser("deactivate",
                            help="clear a device's active record")
    _common(dep, device=True)
    dep.set_defaults(func=cmd_calibrate_deactivate)

    gp = calsub.add_parser("gc", help="age out stale artifacts")
    _common(gp)
    gp.add_argument("--max-age-s", type=float, required=True)
    gp.add_argument("--now", type=float, default=None)
    gp.add_argument("--collect-active", action="store_true",
                    help="also collect active artifacts (default keeps "
                         "them)")
    gp.add_argument("--dry-run", action="store_true")
    gp.set_defaults(func=cmd_calibrate_gc)

    return ap


def main(argv: Optional[List[str]] = None) -> int:
    args = build_parser().parse_args(argv)
    try:
        return args.func(args)
    except (StoreError, ValueError, FileNotFoundError, KeyError) as e:
        print(json.dumps({"error": str(e)}), file=sys.stderr)
        return 2
