"""``python -m repro.collect`` entry point (see :mod:`repro.collect.cli`)."""
import sys

from repro.collect.cli import main

if __name__ == "__main__":
    sys.exit(main())
