"""Sensor-profile catalog — Fig. 14 of the paper as data.

Each entry is one row of the paper's all-GPU summary plus the GH200
findings (§6) and hypothetical TPU-fleet classes used by the launcher.
``update_period_s`` / ``window_s`` are the characterised values; the
`instant`/`average` nvidia-smi query options become separate profiles where
the paper found they differ.
"""
from __future__ import annotations

from typing import Dict

from repro.core.sensor import SensorProfile

CATALOG: Dict[str, SensorProfile] = {}


def _add(p: SensorProfile) -> SensorProfile:
    CATALOG[p.name] = p
    return p


# --- data-centre parts -----------------------------------------------------
# A100: 25 ms window out of a 100 ms period on every driver (the paper's
# headline "only 25 % of runtime is sampled").
A100 = _add(SensorProfile("a100", update_period_s=0.100, window_s=0.025))
# H100 instant option: 25/100; average/normal option: 1 s running average.
H100_INSTANT = _add(SensorProfile("h100_instant", 0.100, 0.025))
H100_AVERAGE = _add(SensorProfile("h100_average", 0.100, 1.000))
# GH200: GPU reading 20/100, CPU reading 10/100; `instant` is module-scope.
GH200_GPU = _add(SensorProfile("gh200_gpu", 0.100, 0.020))
GH200_CPU = _add(SensorProfile("gh200_cpu", 0.100, 0.010))
GH200_MODULE_INSTANT = _add(SensorProfile(
    "gh200_module_instant", 0.100, 0.020, scope="module"))

# --- workstation / gaming ----------------------------------------------------
# Ampere (non-GA100) & Ada: pre-530 drivers => 1 s window; 530 => 100/100;
# post-530 default/average => 1 s again, new `instant` => 100/100.
RTX3090_PRE530 = _add(SensorProfile("rtx3090_pre530", 0.100, 1.000))
RTX3090_530 = _add(SensorProfile("rtx3090_530", 0.100, 0.100))
RTX3090_INSTANT = _add(SensorProfile("rtx3090_instant", 0.100, 0.100))
RTX3090_AVERAGE = _add(SensorProfile("rtx3090_average", 0.100, 1.000))
ADA = _add(SensorProfile("rtx4090_instant", 0.100, 0.100))
TURING = _add(SensorProfile("turing", 0.100, 0.100))

# --- Volta / Pascal: 10 ms window out of a 20 ms period ----------------------
VOLTA = _add(SensorProfile("v100", 0.020, 0.010))
PASCAL = _add(SensorProfile("p100", 0.020, 0.010))

# --- Kepler / Maxwell: logarithmic (capacitor-charging) transient ------------
KEPLER = _add(SensorProfile("kepler", 0.015, None, transient="logarithmic",
                            tau_s=0.8))
MAXWELL = _add(SensorProfile("maxwell", 0.100, None, transient="logarithmic",
                             tau_s=0.6))

# --- Fermi: estimation-based or unsupported ----------------------------------
FERMI2 = _add(SensorProfile("fermi2", 0.100, None, transient="estimation",
                            model_error=0.15))
FERMI1 = _add(SensorProfile("fermi1", supported=False))

# --- TPU-fleet classes (hardware adaptation; DESIGN.md §2) -------------------
# A part-time host-daemon sensor analogous to A100's 25/100 behaviour.
TPU_V5E_CHIP = _add(SensorProfile("tpu_v5e_chip", 0.100, 0.025))
# A host-level telemetry stream: module scope, 50/50 boxcar.
TPU_V5E_HOST = _add(SensorProfile("tpu_v5e_host", 0.050, 0.050,
                                  scope="module"))
# An averaged dashboard feed (1 s) like cloud monitoring exports.
TPU_V5E_DASH = _add(SensorProfile("tpu_v5e_dash", 1.000, 1.000))


def get(name: str) -> SensorProfile:
    try:
        return CATALOG[name]
    except KeyError:
        raise KeyError(f"unknown sensor profile '{name}'; "
                       f"available: {sorted(CATALOG)}") from None


# The three evaluation classes of §5 (cases 1–3).
CASE1 = RTX3090_INSTANT    # W == T   (100/100)
CASE2 = RTX3090_AVERAGE    # W >  T   (1000/100)
CASE3 = A100               # W <  T   (25/100) — the part-time case
