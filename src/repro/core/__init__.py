"""repro.core — the paper's contribution: part-time power measurement.

Public API:

    from repro.core import profiles, microbench, meter
    sensor = OnboardSensor(profiles.get("a100"), seed=0)
    calib  = CalibrationStore(".calib").get_or_characterise("dev0", sensor)
    est    = meter.measure_good_practice(sensor, workload, calib)
"""
from repro.core.activity import ChipPowerModel, StepActivity, steps_timeline
from repro.core.calibrate import CalibrationRecord, CalibrationStore
from repro.core.engine_backend import (available_backends, get_backend,
                                       resolve_backend)
from repro.core.ground_truth import (ActivityTimeline, GroundTruthMeter,
                                     TimelineBank, from_segments)
from repro.core.fleet_engine import FleetAuditResult, SensorBank, fleet_audit
from repro.core.ledger import EnergyLedger, LedgerEntry
from repro.core.meter import (BatchedEnergyEstimate, EnergyEstimate,
                              GoodPracticeConfig, ModuleScopeError, Workload,
                              WorkloadSet, compare_protocols,
                              measure_good_practice,
                              measure_good_practice_batch, measure_naive,
                              measure_naive_batch)
from repro.core.microbench import (CharacterisationResult, characterise,
                                   estimate_boxcar_window,
                                   estimate_steady_state,
                                   estimate_update_period, measure_transient)
from repro.core.sensor import OnboardSensor, SensorProfile, SensorUnsupported
from repro.core.stream import (MonitorService, StreamCorrections,
                               replay, stream_fleet)
from repro.core.telemetry import (FleetLedger, FleetSummary,
                                  datacenter_projection)

__all__ = [
    "ActivityTimeline", "GroundTruthMeter", "TimelineBank", "from_segments",
    "OnboardSensor", "SensorProfile", "SensorUnsupported",
    "CalibrationRecord", "CalibrationStore",
    "CharacterisationResult", "characterise", "estimate_update_period",
    "measure_transient", "estimate_steady_state", "estimate_boxcar_window",
    "Workload", "WorkloadSet", "GoodPracticeConfig", "EnergyEstimate",
    "ModuleScopeError",
    "measure_naive", "measure_good_practice", "compare_protocols",
    "SensorBank", "FleetAuditResult", "fleet_audit",
    "BatchedEnergyEstimate", "measure_naive_batch",
    "measure_good_practice_batch",
    "EnergyLedger", "LedgerEntry", "FleetLedger", "FleetSummary",
    "datacenter_projection",
    "available_backends", "get_backend", "resolve_backend",
    "MonitorService", "StreamCorrections", "replay", "stream_fleet",
    "ChipPowerModel", "StepActivity", "steps_timeline",
]
