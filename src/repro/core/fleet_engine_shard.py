"""Mesh-sharded fleet audits: ``shard_map`` the audit kernels over devices.

The chunked :func:`~repro.core.fleet_engine.fleet_audit` streams device
slabs through one host; every kernel call — the transient responses in
:meth:`SensorBank.attach`, the closed-form poll counting behind
``integrate_polled``, the ``err_moments`` reductions — is embarrassingly
parallel across device *rows*.  This module puts those rows on a jax
mesh:

* :class:`ShardedBackend` wraps the jax backend's jitted kernel impls in
  ``shard_map`` over a 1-D ``("data",)`` mesh
  (:func:`repro.launch.mesh.data_mesh`).  It exposes the standard
  backend kernel surface, so ``SensorBank(..., backend=ShardedBackend(mesh))``
  and ``fleet_audit(..., mesh=mesh)`` work unchanged — row counts are
  padded to a multiple of the axis size (padding replicates the last
  row) and results sliced back.
* ``err_moments`` becomes an **on-device tree reduction**: each shard
  reduces its rows to one Chan moment block ``(count, mean, M2,
  mean_abs, max_abs)`` inside the mapped kernel (padded rows masked by
  global index), and the per-shard blocks merge on device through a
  log-depth binary tree of Chan parallel-Welford combines
  (:func:`tree_merge_moments`) — no sequential host-side folding.
  Tree-order invariance of the merge is property-tested in
  ``tests/test_fleet_engine.py``.
* :func:`fleet_audit_sharded` is the entry point: it builds the mesh,
  sizes super-slabs as ``n_shards x shard_chunk`` rows so every mesh
  device audits one slab-worth per step, and double-buffers workload
  synthesis (``prefetch_workloads=True`` — vecrng streams are jump-based
  so per-slab substreams are deterministic regardless of which thread
  synthesises them).

Determinism: per-device results are row-independent math, so a sharded
audit matches the single-process jax audit at the same super-slab
chunking to float-accumulation order (≲1e-12 relative; the only
reordering is each shard's padded reading width).  The single-shard path
is untouched — ``fleet_audit`` without ``mesh=`` never imports this
module.  See ``docs/scaling.md`` for the
``XLA_FLAGS=--xla_force_host_platform_device_count`` recipe.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence, Union

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import enable_x64
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.engine_backend import jax_backend as _jb
from repro.core.engine_backend.pytrees import (PollGrid, ReadingSchedule,
                                               TimelineArrays)

__all__ = ["ShardedBackend", "fleet_audit_sharded", "tree_merge_moments"]


# ---------------------------------------------------------------------------
# On-device Chan tree reduction
# ---------------------------------------------------------------------------

def _chan_pair(a, b):
    """Merge moment blocks pairwise: ``a``/``b`` are ``[k, 5]`` stacks of
    ``(count, mean, M2, mean_abs, max_abs)``; returns the ``[k, 5]`` Chan
    parallel-Welford combination.  Empty blocks (count 0) are identity
    elements on either side, so padding a tree with zero blocks is
    exact."""
    na, nb = a[:, 0], b[:, 0]
    tot = na + nb
    safe = jnp.maximum(tot, 1.0)
    delta = b[:, 1] - a[:, 1]
    mean = a[:, 1] + delta * nb / safe
    m2 = a[:, 2] + b[:, 2] + delta * delta * na * nb / safe
    mean_abs = a[:, 3] + (b[:, 3] - a[:, 3]) * nb / safe
    max_abs = jnp.maximum(a[:, 4], b[:, 4])
    merged = jnp.stack([tot, mean, m2, mean_abs, max_abs], axis=1)
    merged = jnp.where((nb == 0)[:, None], a, merged)
    return jnp.where((na == 0)[:, None], b, merged)


@jax.jit
def _tree_merge_impl(blocks):
    k = blocks.shape[0]
    p = 1 << max(k - 1, 0).bit_length()
    if p > k:
        blocks = jnp.concatenate(
            [blocks, jnp.zeros((p - k, 5), blocks.dtype)], axis=0)
    while blocks.shape[0] > 1:
        blocks = _chan_pair(blocks[0::2], blocks[1::2])
    return blocks[0]


def tree_merge_moments(blocks) -> np.ndarray:
    """Fold ``[k, 5]`` Chan moment blocks to one ``[5]`` block through a
    log-depth binary tree (``blocks[0::2]`` ⊕ ``blocks[1::2]`` per
    level).  ``k`` is padded to a power of two with empty blocks — exact
    identities under :func:`_chan_pair` — so any shard count works.  The
    tree is unrolled at trace time (k is static); for the shard counts
    this module sees (≤ dozens) that is a handful of fused combines."""
    with enable_x64():
        return np.asarray(
            _tree_merge_impl(jnp.asarray(blocks, jnp.float64)))


def _local_moments_impl(e, n_true):
    """Per-shard moment block over the locally-held error rows.  Rows at
    global index >= ``n_true`` are padding and masked out; runs *inside*
    ``shard_map``, so ``lax.axis_index`` supplies the shard's offset."""
    c = e.shape[0]
    i0 = lax.axis_index("data") * c
    valid = (i0 + jnp.arange(c)) < n_true
    cnt = jnp.sum(valid.astype(e.dtype))
    safe = jnp.maximum(cnt, 1.0)
    mean = jnp.sum(jnp.where(valid, e, 0.0)) / safe
    m2 = jnp.sum(jnp.where(valid, (e - mean) ** 2, 0.0))
    ae = jnp.where(valid, jnp.abs(e), 0.0)
    mean_abs = jnp.sum(ae) / safe
    max_abs = jnp.max(ae, initial=0.0)
    zero = cnt == 0
    mean = jnp.where(zero, 0.0, mean)
    mean_abs = jnp.where(zero, 0.0, mean_abs)
    return jnp.stack([cnt, mean, m2, mean_abs, max_abs])[None, :]


# ---------------------------------------------------------------------------
# The sharded backend
# ---------------------------------------------------------------------------

def _pad_rows(a: np.ndarray, rows: int) -> np.ndarray:
    """Pad axis 0 to ``rows`` by replicating the final row — always valid
    device data, so padded lanes trace the same math and never produce
    non-finite values (their outputs are sliced away)."""
    n = a.shape[0]
    if n == rows:
        return a
    reps = np.broadcast_to(a[-1:], (rows - n,) + a.shape[1:])
    return np.concatenate([np.asarray(a), reps], axis=0)


class ShardedBackend:
    """The jax kernel set ``shard_map``-ed over a ``("data",)`` mesh.

    Drop-in for a named backend module anywhere the engine takes
    ``backend=`` (``SensorBank``, ``fleet_audit``, ``StreamingMoments
    .update``): each kernel splits its row axis across the mesh devices,
    runs the jax backend's jitted impl per shard, and reassembles.
    Scalars and shared (1-row) timelines are replicated.  Kernels not on
    the audit hot path delegate to the plain jax module via attribute
    fallthrough.

    ``err_moments`` does NOT return per-row output: each shard reduces
    locally and the per-shard blocks merge through the on-device Chan
    tree (:func:`tree_merge_moments`), so a 10M-row error reduction
    ships 5 floats to the host.
    """

    def __init__(self, mesh, base: str = "jax"):
        if "data" not in mesh.axis_names:
            raise ValueError(
                f"mesh axes {mesh.axis_names} lack the 'data' axis; build "
                "one with repro.launch.mesh.data_mesh(n_shards)")
        if base not in ("jax", "auto"):
            raise ValueError(
                "ShardedBackend shards the jax kernel impls; "
                f"base='{base}' is not supported (use 'jax')")
        self.mesh = mesh
        self.n_shards = int(mesh.shape["data"])
        self.name = f"shard({self.n_shards})"

        def smap(fn, in_specs, out_specs=P("data")):
            return jax.jit(shard_map(
                fn, mesh=self.mesh, in_specs=in_specs,
                out_specs=out_specs, check_rep=False))

        D, R = P("data"), P()
        # two variants per timeline kernel: per-device timelines shard
        # with the query rows; a shared 1-row timeline replicates
        self._boxcar = {True: smap(_jb._boxcar_impl, (D, D, D)),
                        False: smap(_jb._boxcar_impl, (R, D, D))}
        self._estimation = {
            True: smap(_jb._estimation_impl, (D, D, D, D)),
            False: smap(_jb._estimation_impl, (R, D, D, D))}
        self._log_filter = {
            True: smap(_jb._log_filter_impl, (D, D, D, R, R)),
            False: smap(_jb._log_filter_impl, (R, D, D, R, R))}
        self._query_slots = smap(_jb._query_slots_impl, (D, D))
        self._poll_counts = smap(
            _jb._poll_counts_impl, (D, R, D, R, D, D, D),
            out_specs=(D, D, D, D))
        self._local_moments = smap(
            _local_moments_impl, (D, R), out_specs=D)

    # -- row plumbing ------------------------------------------------------

    def _rows(self, n: int) -> int:
        return self.n_shards * max(math.ceil(n / self.n_shards), 1)

    def _pad_tree(self, tree, rows: int):
        return type(tree)(*(_pad_rows(np.asarray(leaf), rows)
                            for leaf in tree))

    # -- kernel surface ----------------------------------------------------

    def boxcar_means(self, tl: TimelineArrays, t0, t1) -> np.ndarray:
        n = t0.shape[0]
        rows = self._rows(n)
        per_dev = tl.n_rows != 1
        if per_dev:
            tl = self._pad_tree(tl, rows)
        with enable_x64():
            out = self._boxcar[per_dev](
                tl, jnp.asarray(_pad_rows(t0, rows), jnp.float64),
                jnp.asarray(_pad_rows(t1, rows), jnp.float64))
        return np.asarray(out)[:n]

    def estimation_means(self, tl: TimelineArrays, t0, t1,
                         model_gain) -> np.ndarray:
        n = t0.shape[0]
        rows = self._rows(n)
        per_dev = tl.n_rows != 1
        if per_dev:
            tl = self._pad_tree(tl, rows)
        with enable_x64():
            out = self._estimation[per_dev](
                tl, jnp.asarray(_pad_rows(t0, rows), jnp.float64),
                jnp.asarray(_pad_rows(t1, rows), jnp.float64),
                jnp.asarray(_pad_rows(np.asarray(model_gain), rows),
                            jnp.float64))
        return np.asarray(out)[:n]

    def log_filter(self, tl: TimelineArrays, ticks, tau) -> np.ndarray:
        n = ticks.shape[0]
        rows = self._rows(n)
        tau = np.asarray(tau, dtype=np.float64)
        # concrete pad bounds exactly as the jax wrapper computes them
        t_lo = (min(float(np.min(ticks)), float(np.min(tl.t_start)))
                - 5.0 * float(np.max(tau)))
        t_hi = max(float(np.max(ticks)), float(np.max(tl.t_end))) + 1e-9
        per_dev = tl.n_rows != 1
        if per_dev:
            tl = self._pad_tree(tl, rows)
        with enable_x64():
            out = self._log_filter[per_dev](
                tl, jnp.asarray(_pad_rows(ticks, rows), jnp.float64),
                jnp.asarray(_pad_rows(tau, rows), jnp.float64),
                jnp.float64(t_lo), jnp.float64(t_hi))
        return np.asarray(out)[:n]

    def query_slots(self, sched: ReadingSchedule, tq) -> np.ndarray:
        n = tq.shape[0]
        rows = self._rows(n)
        sched = self._pad_tree(sched, rows)
        with enable_x64():
            out = self._query_slots(
                sched, jnp.asarray(_pad_rows(np.asarray(tq), rows),
                                   jnp.float64))
        return np.asarray(out)[:n]

    def poll_counts(self, sched: ReadingSchedule, grid: PollGrid, a, b):
        n = np.asarray(a).shape[0]
        rows = self._rows(n)
        sched = self._pad_tree(sched, rows)
        t1 = _pad_rows(np.asarray(grid.t1, dtype=np.float64), rows)
        off = _pad_rows(
            np.broadcast_to(np.asarray(grid.grid_offset, np.float64),
                            (n,)), rows)
        with enable_x64():
            counts, slot_b, tail_dt, nonempty = self._poll_counts(
                sched, jnp.float64(grid.t0), jnp.asarray(t1, jnp.float64),
                jnp.float64(grid.period_s), jnp.asarray(off, jnp.float64),
                jnp.asarray(_pad_rows(np.asarray(a, np.float64), rows),
                            jnp.float64),
                jnp.asarray(_pad_rows(np.asarray(b, np.float64), rows),
                            jnp.float64))
        return (np.asarray(counts)[:n], np.asarray(slot_b)[:n],
                np.asarray(tail_dt)[:n], np.asarray(nonempty)[:n])

    def err_moments(self, e: np.ndarray):
        """Sharded error-moment reduction: per-shard local blocks, then
        the on-device Chan tree.  Same contract as the module backends:
        ``(count, mean, M2, mean_abs, max_abs)``."""
        e = np.asarray(e, dtype=np.float64).ravel()
        n = e.size
        if n == 0:
            return 0, 0.0, 0.0, 0.0, 0.0
        rows = self._rows(n)
        padded = np.zeros(rows) if rows != n else e
        if rows != n:
            padded[:n] = e
        with enable_x64():
            blocks = self._local_moments(jnp.asarray(padded, jnp.float64),
                                         jnp.float64(n))
            merged = np.asarray(_tree_merge_impl(blocks))
        return (int(merged[0]), float(merged[1]), float(merged[2]),
                float(merged[3]), float(merged[4]))

    def __getattr__(self, item):
        # off-hot-path kernels (step_integrate, stream ingest, ...) run
        # on the plain jax tier
        return getattr(_jb, item)


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------

def fleet_audit_sharded(n_devices: int,
                        profile: Union[str, Sequence[str]] = "a100",
                        workload=None, seed: int = 0,
                        good_practice: bool = False, n_trials: int = 2,
                        n_shards: Optional[int] = None, mesh=None,
                        shard_chunk: Optional[int] = None,
                        prefetch_workloads: bool = True):
    """A :func:`~repro.core.fleet_engine.fleet_audit` whose kernels run
    ``shard_map``-ed over ``n_shards`` mesh devices.

    Super-slabs of ``n_shards x shard_chunk`` rows stream through the
    audit loop, so every mesh device processes ``shard_chunk`` rows per
    step and peak memory stays one slab per device; workload synthesis
    for slab *k+1* overlaps slab *k*'s audit
    (``prefetch_workloads=True``).  ``mesh`` may be supplied directly
    (any mesh with a ``"data"`` axis); otherwise
    :func:`repro.launch.mesh.data_mesh` builds one over the first
    ``n_shards`` visible devices.  Results match the single-process
    audit within the chunked-audit tolerance (``docs/scaling.md``).
    """
    from repro.core.fleet_engine import fleet_audit
    if mesh is None:
        from repro.launch.mesh import data_mesh
        mesh = data_mesh(n_shards)
    k = int(mesh.shape["data"])
    if shard_chunk is None:
        shard_chunk = min(max(math.ceil(n_devices / k), 1), 25_000)
    chunk = min(int(shard_chunk) * k, max(n_devices, 1))
    return fleet_audit(
        n_devices, profile=profile, workload=workload, seed=seed,
        good_practice=good_practice, n_trials=n_trials,
        backend=ShardedBackend(mesh), chunk_devices=chunk,
        prefetch_workloads=prefetch_workloads)
