"""The reverse-engineered on-board power sensor model.

This is the paper's §4 findings implemented *forwards*: a sensor publishes
a new reading every ``update_period_s`` (the Power Update Period, Fig. 6);
each reading is ``gain · boxcar_mean(P, window_s) + offset`` (Figs. 8–13),
where ``window_s`` may be a small fraction of the period (A100/H100:
25/100 ms → 75 % of activity is never observed).  Kepler/Maxwell-era parts
replace the boxcar with a first-order (capacitor-charging, "logarithmic")
filter (Fig. 7 case 4).  GH200's ``instant`` query reads the *whole
module* (GPU+CPU+DRAM, §6) — modelled by the ``scope`` field.

The sensor's phase (it "starts measuring at boot time") and its exact gain
and offset are hidden, seeded randomness: the micro-benchmarks
(:mod:`repro.core.microbench`) must recover them black-box, which is how
the test-suite validates the estimators closed-loop.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.ground_truth import ActivityTimeline


@dataclasses.dataclass(frozen=True)
class SensorProfile:
    """Static description of a sensor class (one row of Fig. 14)."""

    name: str
    update_period_s: float = 0.100
    window_s: Optional[float] = 0.025       # None => logarithmic transient
    transient: str = "boxcar"               # boxcar | logarithmic | estimation
    tau_s: float = 0.25                     # filter constant for logarithmic
    gain_tol: float = 0.05                  # ±5 % shunt tolerance (Fig. 9)
    offset_tol_w: float = 3.0               # additive component of the error
    quantum_w: float = 0.01                 # reporting resolution (watts)
    noise_w: float = 0.15                   # reading jitter
    scope: str = "chip"                     # chip | module  (GH200 §6)
    supported: bool = True                  # Fermi 1.0: no power readings
    model_error: float = 0.0                # estimation-based extra error

    @property
    def sampled_fraction(self) -> float:
        """Fraction of runtime the sensor actually observes (the paper's
        headline '25 %' for A100/H100)."""
        if self.window_s is None:
            return 1.0
        return min(1.0, self.window_s / self.update_period_s)


class SensorUnsupported(RuntimeError):
    pass


@dataclasses.dataclass
class OnboardSensor:
    """A concrete sensor instance with hidden per-device parameters.

    Usage::

        sensor = OnboardSensor(profile, seed=7)
        sensor.attach(timeline, t_end=10.0)      # device activity
        watts = sensor.query(t)                  # what nvidia-smi would print
    """

    profile: SensorProfile
    seed: int = 0
    host_timeline: Optional[ActivityTimeline] = None  # module-scope extra

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        p = self.profile
        # hidden truth: gain/offset within tolerance, phase within a period
        self._gain = float(1.0 + rng.uniform(-p.gain_tol, p.gain_tol))
        self._offset = float(rng.uniform(-p.offset_tol_w, p.offset_tol_w))
        self._phase = float(rng.uniform(0.0, p.update_period_s))
        if p.transient == "estimation":
            self._model_gain = float(1.0 + rng.uniform(-p.model_error,
                                                       p.model_error))
        self._times: Optional[np.ndarray] = None
        self._values: Optional[np.ndarray] = None

    # hidden-truth accessors for closed-loop validation only (tests grade
    # the estimators against these; the estimators never read them)
    @property
    def true_gain(self) -> float:
        return self._gain

    @property
    def true_offset(self) -> float:
        return self._offset

    @property
    def true_phase(self) -> float:
        return self._phase

    # -- simulation -------------------------------------------------------
    def attach(self, timeline: ActivityTimeline, t_end: float | None = None,
               t_start: float = 0.0) -> None:
        """Precompute the published-reading schedule for an activity trace."""
        p = self.profile
        if not p.supported:
            raise SensorUnsupported(f"{p.name} exposes no power readings")
        if t_end is None:
            t_end = timeline.t_end + 2.0 * p.update_period_s
        T = p.update_period_s
        k0 = int(np.floor((t_start - self._phase) / T))
        ticks = self._phase + T * np.arange(k0, int(np.ceil((t_end - self._phase) / T)) + 1)
        ticks = ticks[ticks >= t_start - T]

        total = timeline
        if p.scope == "module" and self.host_timeline is not None:
            total = _sum_timelines(timeline, self.host_timeline)

        if p.transient == "logarithmic":
            raw = self._filtered_at(total, ticks)
        elif p.transient == "estimation":
            # activity-proxy estimate: sees the true mean over the full
            # period but through a crude activity model
            raw = total.mean_power(ticks - T, ticks) * self._model_gain
        else:
            W = p.window_s if p.window_s is not None else T
            raw = total.mean_power(ticks - W, ticks)

        rng = np.random.default_rng(self.seed + 1)
        vals = self._gain * raw + self._offset
        vals = vals + rng.normal(0.0, p.noise_w, size=vals.shape)
        vals = np.round(vals / p.quantum_w) * p.quantum_w
        self._times = ticks
        self._values = np.maximum(vals, 0.0)

    def _filtered_at(self, timeline: ActivityTimeline,
                     ticks: np.ndarray) -> np.ndarray:
        """First-order filter y' = (P - y)/tau evaluated at tick times.

        Closed form per piecewise-constant segment:
        y(t0+dt) = P_seg + (y(t0) - P_seg) * exp(-dt/tau).
        """
        tau = self.profile.tau_s
        t_lo = min(float(ticks[0]) - 5 * tau, timeline.t_start - 5 * tau)
        edges = np.concatenate([[t_lo], timeline.edges,
                                [max(float(ticks[-1]), timeline.t_end) + 1e-9]])
        edges = np.unique(edges)
        mids = 0.5 * (edges[:-1] + edges[1:])
        seg_p = timeline.power_at(mids)
        # y at each edge, starting from steady idle
        y = np.empty(len(edges))
        y[0] = timeline.idle_w
        for i in range(len(seg_p)):
            dt = edges[i + 1] - edges[i]
            y[i + 1] = seg_p[i] + (y[i] - seg_p[i]) * np.exp(-dt / tau)
        # evaluate at ticks inside their segment
        idx = np.clip(np.searchsorted(edges, ticks, side="right") - 1,
                      0, len(seg_p) - 1)
        return seg_p[idx] + (y[idx] - seg_p[idx]) * np.exp(
            -(ticks - edges[idx]) / tau)

    # -- query API (all an nvidia-smi user gets) --------------------------
    def query(self, t: np.ndarray) -> np.ndarray:
        """Latest published reading at wall-clock time(s) ``t``."""
        if self._times is None:
            raise RuntimeError("sensor not attached to a timeline")
        t = np.asarray(t, dtype=np.float64)
        idx = np.searchsorted(self._times, t, side="right") - 1
        idx = np.clip(idx, 0, len(self._values) - 1)
        return self._values[idx]

    def poll(self, t0: float, t1: float, period_s: float = 0.001,
             jitter_s: float = 0.0) -> tuple[np.ndarray, np.ndarray]:
        """Poll like `nvidia-smi --query-gpu=power.draw -lms <period>`.

        Returns (query_times, readings).  Optional jitter models the
        'actual period can deviate by several milliseconds' behaviour.
        """
        n = int(np.floor((t1 - t0) / period_s))
        ts = t0 + period_s * np.arange(n)
        if jitter_s > 0:
            rng = np.random.default_rng(self.seed + 2)
            ts = ts + rng.uniform(0, jitter_s, size=n)
            ts = np.sort(ts)
        return ts, self.query(ts)


def _sum_timelines(a: ActivityTimeline, b: ActivityTimeline) -> ActivityTimeline:
    """Pointwise sum of two piecewise-constant timelines."""
    edges = np.unique(np.concatenate([a.edges, b.edges]))
    mids = 0.5 * (edges[:-1] + edges[1:])
    powers = a.power_at(mids) + b.power_at(mids)
    return ActivityTimeline(edges, powers, idle_w=a.idle_w + b.idle_w)
