"""Checkpoint/restore for the streaming monitor — bitwise resume.

Writing rides the seed :class:`repro.ckpt.checkpoint.CheckpointManager`
(manifest + one ``.npy`` per leaf, temp-dir + atomic rename, retain-GC,
optional async write thread), so monitor checkpoints share the layout,
crash-safety and tooling of the training checkpoints::

    <root>/step_<epoch>/
      manifest.json                       — shapes/dtypes + monitor meta
      monitor__state.energy_corr_j.npy    — one array per schema field
      ...

Reading deliberately does **not** go through ``CheckpointManager.
restore``: that path round-trips leaves through ``jax.numpy.asarray``,
which (without global x64) silently downcasts float64 → float32 and
would break the bitwise-resume pin.  :func:`restore_monitor` reads the
manifest + ``.npy`` files directly with numpy — byte-exact, and it works
on jax-free hosts.

The array set and its meaning are owned by
:mod:`repro.core.stream.schema`; a monitor restored at any slab
boundary and fed the remaining slabs answers every query bitwise
identically to one that never stopped (pinned in
``tests/test_serving.py`` on both backends, including across a process
boundary).
"""
from __future__ import annotations

import json
import os
from typing import Optional

import numpy as np

from repro.core.stream.schema import pack_monitor, unpack_monitor

_TREE = "monitor"

# one manager (and thus one async writer thread + retain-GC sequence)
# per checkpoint root: repeated save_monitor calls must serialise, or
# overlapping writers would garbage-collect each other out of order
_managers: dict = {}


def _manager(root: str, retain: int):
    from repro.ckpt.checkpoint import CheckpointManager
    key = os.path.abspath(root)
    mgr = _managers.get(key)
    if mgr is None or mgr.retain != retain:
        if mgr is not None:
            mgr.wait()
        mgr = CheckpointManager(root, retain=retain)
        _managers[key] = mgr
    return mgr


def save_monitor(monitor, root: str, *, step: Optional[int] = None,
                 retain: int = 3, asynchronous: bool = False):
    """Write one monitor checkpoint under ``root`` and return the
    :class:`~repro.ckpt.checkpoint.CheckpointManager` used (call
    ``.wait()`` after an ``asynchronous`` save before relying on it).

    ``step`` defaults to the monitor's current ingest epoch, so
    checkpoints taken at slab boundaries order themselves; the pack is
    a full copy, so ingestion may continue immediately even while an
    async write drains.  Saves to the same ``root`` share one manager,
    so back-to-back ``asynchronous`` saves queue up instead of racing.
    """
    arrays, meta = pack_monitor(monitor)
    if step is None:
        step = int(meta["epoch"])
    mgr = _manager(root, retain)
    if asynchronous:
        mgr.save_async(step, {_TREE: arrays}, extras=meta)
    else:
        mgr.save(step, {_TREE: arrays}, extras=meta)
    return mgr


def checkpoint_steps(root: str):
    """Completed checkpoint steps under ``root``, ascending."""
    if not os.path.isdir(root):
        return []
    out = []
    for d in os.listdir(root):
        if d.startswith("step_") and not d.endswith(".tmp"):
            try:
                out.append(int(d.split("_")[1]))
            except ValueError:
                pass
    return sorted(out)


def restore_monitor(root: str, *, step: Optional[int] = None,
                    backend: Optional[str] = None):
    """Rebuild a :class:`~repro.core.stream.MonitorService` from the
    checkpoint at ``step`` (default: latest) — bitwise, numpy-only.

    ``backend`` overrides the checkpointed backend selection (the state
    arrays are backend-agnostic, so a jax-written checkpoint restores
    on a numpy-only host and vice versa).
    """
    steps = checkpoint_steps(root)
    if not steps:
        raise FileNotFoundError(f"no checkpoints under {root}")
    if step is None:
        step = steps[-1]
    elif step not in steps:
        raise FileNotFoundError(
            f"no checkpoint step_{step} under {root}; have {steps}")
    d = os.path.join(root, f"step_{step}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    entries = manifest["trees"][_TREE]
    arrays = {path: np.load(os.path.join(d, e["file"]))
              for path, e in entries.items()}
    return unpack_monitor(arrays, manifest["extras"], backend=backend)
