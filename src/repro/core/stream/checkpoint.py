"""Checkpoint/restore for the streaming monitor — bitwise resume.

Writing rides the seed :class:`repro.ckpt.checkpoint.CheckpointManager`
(manifest + one ``.npy`` per leaf, temp-dir + atomic rename, retain-GC,
optional async write thread), so monitor checkpoints share the layout,
crash-safety and tooling of the training checkpoints::

    <root>/step_<epoch>/
      manifest.json                       — shapes/dtypes + monitor meta
      monitor__state.energy_corr_j.npy    — one array per schema field
      ...

Reading deliberately does **not** go through ``CheckpointManager.
restore``: that path round-trips leaves through ``jax.numpy.asarray``,
which (without global x64) silently downcasts float64 → float32 and
would break the bitwise-resume pin.  :func:`restore_monitor` reads the
manifest + ``.npy`` files directly with numpy — byte-exact, and it works
on jax-free hosts.

Failure typing: a checkpoint that exists but cannot be read back —
truncated/corrupt ``.npy`` payloads, a garbled or partially-written
manifest, manifest entries whose files are missing — raises
:class:`CheckpointError` instead of leaking raw numpy/OS/json
exceptions.  A checkpoint that simply isn't there (no root, unknown
step) raises :class:`MissingCheckpointError`, which subclasses both
``CheckpointError`` and ``FileNotFoundError`` (the pre-typed contract).
``restore_monitor(..., fallback=True)`` walks backward through the
retained generations and restores the newest *complete* one — the
posture a crash-recovery supervisor wants when the newest write may
have died mid-flight.

The array set and its meaning are owned by
:mod:`repro.core.stream.schema`; a monitor restored at any slab
boundary and fed the remaining slabs answers every query bitwise
identically to one that never stopped (pinned in
``tests/test_serving.py`` on both backends, including across a process
boundary).
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro.core.stream.schema import pack_monitor, unpack_monitor

_TREE = "monitor"

# one manager (and thus one async writer thread + retain-GC sequence)
# per checkpoint root: repeated save_monitor calls must serialise, or
# overlapping writers would garbage-collect each other out of order
_managers: dict = {}


class CheckpointError(RuntimeError):
    """A monitor checkpoint exists but cannot be read back (truncated
    ``.npy``, garbled manifest, missing manifest entries, partial
    write)."""


class MissingCheckpointError(CheckpointError, FileNotFoundError):
    """No checkpoint to read (missing root or unknown step)."""


def _manager(root: str, retain: int):
    from repro.ckpt.checkpoint import CheckpointManager
    key = os.path.abspath(root)
    mgr = _managers.get(key)
    if mgr is None or mgr.retain != retain:
        if mgr is not None:
            mgr.wait()
        mgr = CheckpointManager(root, retain=retain)
        _managers[key] = mgr
    return mgr


def save_monitor(monitor, root: str, *, step: Optional[int] = None,
                 retain: int = 3, asynchronous: bool = False,
                 extras: Optional[Dict[str, Any]] = None):
    """Write one monitor checkpoint under ``root`` and return the
    :class:`~repro.ckpt.checkpoint.CheckpointManager` used (call
    ``.wait()`` after an ``asynchronous`` save before relying on it).

    ``step`` defaults to the monitor's current ingest epoch, so
    checkpoints taken at slab boundaries order themselves; the pack is
    a full copy, so ingestion may continue immediately even while an
    async write drains.  Saves to the same ``root`` share one manager,
    so back-to-back ``asynchronous`` saves queue up instead of racing.

    ``extras`` merges additional JSON-able keys into the manifest meta
    (e.g. a supervisor's slab cursor); keys must not collide with the
    schema's own meta keys.
    """
    arrays, meta = pack_monitor(monitor)
    if extras:
        clash = sorted(set(extras) & set(meta))
        if clash:
            raise ValueError(f"extras keys collide with schema meta: "
                             f"{clash}")
        meta = {**meta, **extras}
    if step is None:
        step = int(meta["epoch"])
    mgr = _manager(root, retain)
    if asynchronous:
        mgr.save_async(step, {_TREE: arrays}, extras=meta)
    else:
        mgr.save(step, {_TREE: arrays}, extras=meta)
    return mgr


def checkpoint_steps(root: str):
    """Completed checkpoint steps under ``root``, ascending."""
    if not os.path.isdir(root):
        return []
    out = []
    for d in os.listdir(root):
        if d.startswith("step_") and not d.endswith(".tmp"):
            try:
                out.append(int(d.split("_")[1]))
            except ValueError:
                pass
    return sorted(out)


def _load_step(root: str, step: int
               ) -> Tuple[Dict[str, np.ndarray], Dict[str, Any]]:
    """Read one checkpoint generation's arrays + meta, wrapping every
    partial-write failure mode in :class:`CheckpointError`."""
    d = os.path.join(root, f"step_{step}")
    try:
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
    except FileNotFoundError as exc:
        raise CheckpointError(
            f"step_{step}: manifest.json missing (partial write?)"
        ) from exc
    except (OSError, json.JSONDecodeError) as exc:
        raise CheckpointError(
            f"step_{step}: unreadable manifest.json: {exc}") from exc
    try:
        entries = manifest["trees"][_TREE]
        meta = manifest["extras"]
    except (KeyError, TypeError) as exc:
        raise CheckpointError(
            f"step_{step}: manifest has no '{exc}' entry — not a "
            f"monitor checkpoint, or a garbled manifest") from exc
    arrays = {}
    for path, e in entries.items():
        try:
            fname = e["file"]
        except (KeyError, TypeError) as exc:
            raise CheckpointError(
                f"step_{step}: manifest entry for '{path}' has no "
                f"file reference") from exc
        try:
            arrays[path] = np.load(os.path.join(d, fname))
        except FileNotFoundError as exc:
            raise CheckpointError(
                f"step_{step}: array file '{fname}' missing "
                f"(partial write?)") from exc
        except (OSError, ValueError, EOFError, KeyError) as exc:
            raise CheckpointError(
                f"step_{step}: array file '{fname}' is truncated or "
                f"corrupt: {exc}") from exc
    return arrays, meta


def restore_monitor(root: str, *, step: Optional[int] = None,
                    backend: Optional[str] = None,
                    fallback: bool = False,
                    with_meta: bool = False):
    """Rebuild a :class:`~repro.core.stream.MonitorService` from the
    checkpoint at ``step`` (default: latest) — bitwise, numpy-only.

    ``backend`` overrides the checkpointed backend selection (the state
    arrays are backend-agnostic, so a jax-written checkpoint restores
    on a numpy-only host and vice versa).

    With ``fallback=True`` (and no explicit ``step``), corrupt
    generations are skipped newest-first and the newest *complete* one
    restores instead; only if every retained generation is unreadable
    does the corruption surface (as a :class:`CheckpointError` listing
    each generation's failure).  ``with_meta=True`` returns
    ``(monitor, meta)`` — the full manifest meta including any
    ``extras`` recorded at save time.
    """
    steps = checkpoint_steps(root)
    if not steps:
        raise MissingCheckpointError(f"no checkpoints under {root}")
    if step is None:
        candidates = steps[::-1] if fallback else [steps[-1]]
    elif step not in steps:
        raise MissingCheckpointError(
            f"no checkpoint step_{step} under {root}; have {steps}")
    else:
        candidates = [step]
    failures = []
    for s in candidates:
        try:
            arrays, meta = _load_step(root, s)
        except CheckpointError as exc:
            failures.append(str(exc))
            continue
        mon = unpack_monitor(arrays, meta, backend=backend)
        return (mon, meta) if with_meta else mon
    raise CheckpointError(
        "no readable checkpoint generation under "
        f"{root}: {'; '.join(failures)}")
