"""The streaming monitor's ingest core: mutable state + the hot path.

:class:`IngestCore` owns everything the monitor accumulates online —
the :class:`~repro.core.stream.state.DeviceState` arrays, the recent-
sample ring, the period histograms, the per-label reading moments — and
the two slab-folding entry points (``ingest`` for arbitrary slabs,
``ingest_grid`` for the rectangular clean-stream fast path).  It serves
**no queries**: readers go through the immutable
:class:`~repro.core.stream.snapshot.MonitorSnapshot` the façade
publishes, so nothing ever reads this object's arrays concurrently with
a scatter update.

Every slab that lands bumps :attr:`epoch` — the monotonic counter the
snapshot layer and the ``(query, epoch)`` result cache key on.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Union

import numpy as np

from repro.core.engine_backend import get_backend, resolve_backend
from repro.core.fleet_engine import StreamingMoments
from repro.core.stream.estimators import (OnlinePeriodEstimator,
                                          StreamCorrections)
from repro.core.stream.health import HealthPolicy, HealthTracker
from repro.core.stream.state import DeviceState, IngestBuffer

_INTEGRATIONS = ("rectangle", "trapezoid")


@dataclasses.dataclass(frozen=True)
class IngestReport:
    """What one ``ingest`` call did with its slab."""

    accepted: int
    duplicates: int
    late: int
    invalid: int
    n_devices: int      # distinct devices that contributed samples
    rejected: int = 0   # out-of-range device ids (strict_ids=False only)


class IngestCore:
    """Mutable online state + slab ingestion (see module doc).

    Construction arguments are identical to
    :class:`~repro.core.stream.monitor.MonitorService`, which documents
    them — the façade forwards its ``__init__`` here verbatim.
    """

    def __init__(self, n_devices: int, *,
                 corrections: Optional[StreamCorrections] = None,
                 labels: Optional[np.ndarray] = None,
                 integration: str = "rectangle",
                 max_hold_s: Union[None, float, np.ndarray] = None,
                 envelope_w: Optional[tuple] = None,
                 ring_slots: int = 8,
                 period_bins: int = 24,
                 min_runs: int = 3,
                 silent_after_s: Optional[float] = None,
                 drift_tau_s: float = 30.0,
                 drift_rel: float = 0.25,
                 drift_abs_w: float = 5.0,
                 strict_ids: bool = True,
                 health: Optional[HealthPolicy] = None,
                 health_every_s: float = 0.0,
                 backend: Optional[str] = None):
        if n_devices < 1:
            raise ValueError("need at least one device")
        if integration not in _INTEGRATIONS:
            raise ValueError(f"unknown integration '{integration}'; "
                             f"known: {', '.join(_INTEGRATIONS)}")
        n = int(n_devices)
        self.n_devices = n
        self.backend = resolve_backend(backend)
        self._be = get_backend(self.backend)
        self.corrections = (corrections if corrections is not None
                            else StreamCorrections.identity(n))
        if self.corrections.n_devices != n:
            raise ValueError(
                f"corrections cover {self.corrections.n_devices} devices, "
                f"monitor has {n}")
        if labels is None:
            self.labels = np.full(n, "all", dtype=object)
        else:
            self.labels = np.asarray(labels, dtype=object)
            if self.labels.shape != (n,):
                raise ValueError(f"labels must be [{n}], "
                                 f"got {self.labels.shape}")
        # integer label codes keep object-array work off the hot path
        names, codes = np.unique(self.labels.astype(str),
                                 return_inverse=True)
        self._label_names = [str(x) for x in names]
        self._label_codes = codes.astype(np.int64)
        self.trapezoid = (integration == "trapezoid")
        if max_hold_s is None:
            self._max_hold = np.full(n, np.inf)
        else:
            self._max_hold = np.broadcast_to(
                np.asarray(max_hold_s, dtype=np.float64), (n,)).copy()
            if np.any(self._max_hold <= 0.0):
                raise ValueError("max_hold_s must be positive")
        if envelope_w is None:
            self._env_lo = np.full(n, -np.inf)
            self._env_hi = np.full(n, np.inf)
        else:
            lo, hi = envelope_w
            self._env_lo = np.broadcast_to(
                np.asarray(lo, dtype=np.float64), (n,)).copy()
            self._env_hi = np.broadcast_to(
                np.asarray(hi, dtype=np.float64), (n,)).copy()

        self.state = DeviceState.zeros(n)
        self.ring = IngestBuffer(n, ring_slots)
        self.periods = OnlinePeriodEstimator(n, n_bins=period_bins,
                                             min_runs=min_runs)
        # windows disabled until registered: [+inf, -inf] selects nothing
        self._win_a = np.full(n, np.inf)
        self._win_b = np.full(n, -np.inf)

        self.silent_after_s = silent_after_s
        self.drift_tau_s = float(drift_tau_s)
        self.drift_rel = float(drift_rel)
        self.drift_abs_w = float(drift_abs_w)
        self._moments: Dict[str, StreamingMoments] = {}
        self._n_invalid = 0
        # defensive-mode knobs: with strict_ids=False, out-of-range ids
        # are rejected and counted instead of raising (the posture for
        # streams behind a corrupting collector); with a HealthPolicy,
        # the per-device state machine runs at slab boundaries (at most
        # every health_every_s of stream time)
        self.strict_ids = bool(strict_ids)
        self.health_policy = health
        self.health = HealthTracker.zeros(n) if health is not None else None
        self.health_every_s = float(health_every_s)
        self._next_health_t = -np.inf
        self._n_rejected = 0
        # bumped on every slab that mutates state; snapshots and the
        # (query, epoch) result cache key on it
        self.epoch = 0

    # -- configuration ----------------------------------------------------
    def set_windows(self, a, b) -> None:
        """Register per-device measurement windows ``[a_i, b_i]`` (the §5
        execution windows — e.g. each device's workload span).  Window
        energy accumulates sample-by-sample, so windows must be set
        before the first sample arrives."""
        if int(np.sum(self.state.n_samples)) > 0:
            raise RuntimeError("windows must be registered before the "
                               "first ingest (accumulation is not "
                               "retroactive)")
        n = self.n_devices
        a = np.broadcast_to(np.asarray(a, dtype=np.float64), (n,)).copy()
        b = np.broadcast_to(np.asarray(b, dtype=np.float64), (n,)).copy()
        self._win_a, self._win_b = a, b
        self.epoch += 1

    def nbytes(self) -> int:
        """Approximate resident size of the monitor state (the memory
        that scales with fleet size) — summed through the same schema
        registries checkpointing serializes, so a field added to the
        state without a schema update fails here first."""
        return (self.state.nbytes() + self.ring.nbytes()
                + self.periods.nbytes()
                + (self.health.nbytes() if self.health is not None else 0))

    def grow(self, n_new: int, *,
             corrections: Optional[StreamCorrections] = None,
             labels: Optional[np.ndarray] = None) -> None:
        """Widen the monitor to ``n_new`` devices mid-stream.

        The live-collector contract (:mod:`repro.collect`): a gpu_uuid
        the registry has never seen hot-adds a device, and the monitor
        must grow to match **without perturbing anything already
        accumulated** — after growth, every state array equals what a
        monitor built at the full width from the start would hold, with
        the appended rows in their pristine zero state (pinned bitwise
        in ``tests/test_collect.py``).  ``corrections``/``labels``
        cover the appended tail (``n_new - n_devices`` rows; identity
        corrections and the ``"all"`` label by default); tail windows
        start disabled, tail ``max_hold``/envelope unlimited — exactly
        a fresh monitor's defaults.  Bumps the epoch, so held snapshots
        stay valid and the next query publishes at the new width.
        """
        from repro.core.stream import schema
        n_old = self.n_devices
        n_new = int(n_new)
        if n_new < n_old:
            raise ValueError(f"cannot shrink a monitor: {n_old} -> {n_new}")
        if n_new == n_old:
            return
        n_add = n_new - n_old
        tail_corr = (corrections if corrections is not None
                     else StreamCorrections.identity(n_add))
        if tail_corr.n_devices != n_add:
            raise ValueError(f"tail corrections cover "
                             f"{tail_corr.n_devices} devices, growing "
                             f"by {n_add}")
        self.corrections = StreamCorrections(**{
            f.name: np.concatenate([getattr(self.corrections, f.name),
                                    getattr(tail_corr, f.name)])
            for f in dataclasses.fields(StreamCorrections)})
        if labels is None:
            tail_labels = np.full(n_add, "all", dtype=object)
        else:
            tail_labels = np.asarray(labels, dtype=object)
            if tail_labels.shape != (n_add,):
                raise ValueError(f"tail labels must be [{n_add}], "
                                 f"got {tail_labels.shape}")
        self.labels = np.concatenate([self.labels, tail_labels])
        names, codes = np.unique(self.labels.astype(str),
                                 return_inverse=True)
        self._label_names = [str(x) for x in names]
        self._label_codes = codes.astype(np.int64)

        # per-device state: fieldwise concat with the pristine zero rows,
        # walked through the schema registries so a state field added
        # without growth support fails loudly here
        pad = DeviceState.zeros(n_add)
        old = schema.check_registry(self.state, schema.DEVICE_STATE_FIELDS,
                                    "DeviceState")
        self.state = DeviceState(**{
            k: np.concatenate([v, getattr(pad, k)])
            for k, v in old.items()})
        ring_pad = IngestBuffer(n_add, self.ring.slots)
        for k in schema.check_registry(
                self.ring, schema.RING_FIELDS, "IngestBuffer",
                optional=schema.RING_SLOT_FIELDS):
            setattr(self.ring, k, np.concatenate(
                [getattr(self.ring, k), getattr(ring_pad, k)]))
        self.periods.counts = np.concatenate(
            [self.periods.counts,
             np.zeros((n_add, self.periods.n_bins), dtype=np.int64)])
        self.periods.sums = np.concatenate(
            [self.periods.sums, np.zeros((n_add, self.periods.n_bins))])
        if self.health is not None:
            hp = HealthTracker.zeros(n_add)
            for k in schema.check_registry(self.health,
                                           schema.HEALTH_FIELDS,
                                           "HealthTracker"):
                setattr(self.health, k, np.concatenate(
                    [getattr(self.health, k), getattr(hp, k)]))

        # config vectors: tail rows take a fresh monitor's defaults
        self._max_hold = np.concatenate([self._max_hold,
                                         np.full(n_add, np.inf)])
        self._env_lo = np.concatenate([self._env_lo,
                                       np.full(n_add, -np.inf)])
        self._env_hi = np.concatenate([self._env_hi,
                                       np.full(n_add, np.inf)])
        self._win_a = np.concatenate([self._win_a, np.full(n_add, np.inf)])
        self._win_b = np.concatenate([self._win_b, np.full(n_add, -np.inf)])
        self.n_devices = n_new
        self.epoch += 1

    # -- ingestion --------------------------------------------------------
    def ingest(self, dev, t, v) -> IngestReport:
        """Fold one slab of raw poll samples into the online state.

        ``dev`` [K] int device ids, ``t`` [K] sample times, ``v`` [K]
        raw readings — any order, duplicates and late samples tolerated
        (dropped and counted).  Out-of-range device ids raise by
        default; with ``strict_ids=False`` they are rejected and counted
        instead (the defensive posture for corrupting collectors) —
        either way they never touch state.  Returns an
        :class:`IngestReport`.
        """
        dev = np.asarray(dev, dtype=np.int64).ravel()
        t = np.asarray(t, dtype=np.float64).ravel()
        v = np.asarray(v, dtype=np.float64).ravel()
        if not (dev.shape == t.shape == v.shape):
            raise ValueError(f"shape mismatch: dev {dev.shape}, "
                             f"t {t.shape}, v {v.shape}")
        n_rej = 0
        if dev.size and (dev.min() < 0 or dev.max() >= self.n_devices):
            if self.strict_ids:
                raise ValueError("device id out of range")
            ok_id = (dev >= 0) & (dev < self.n_devices)
            n_rej = int(ok_id.size - ok_id.sum())
            self._n_rejected += n_rej
            dev, t, v = dev[ok_id], t[ok_id], v[ok_id]
        k_in = dev.size
        if k_in == 0:
            if n_rej:               # counters mutated: publish fresh
                self.epoch += 1
            return IngestReport(0, 0, 0, 0, 0, n_rej)
        # even an all-dropped slab mutates counters: publish fresh
        self.epoch += 1

        ok = np.isfinite(t) & np.isfinite(v)
        n_invalid = int(k_in - ok.sum())
        if n_invalid:
            self._n_invalid += n_invalid
            dev, t, v = dev[ok], t[ok], v[ok]

        order = np.lexsort((t, dev))
        dev, t, v = dev[order], t[order], v[order]

        # duplicates: same (device, t) — keep the first arrival
        dup = np.zeros(len(dev), dtype=bool)
        dup[1:] = (dev[1:] == dev[:-1]) & (t[1:] == t[:-1])
        st = self.state
        # vs stored state: strictly older samples arrive late, a repeat
        # of the newest timestamp is a duplicate
        late = ~dup & st.has[dev] & (t < st.last_t[dev])
        dup_state = ~dup & st.has[dev] & (t == st.last_t[dev])
        n_dup = int(np.sum(dup | dup_state))
        n_late = int(np.sum(late))
        if n_dup:
            np.add.at(st.n_dup, dev[dup | dup_state], 1)
        if n_late:
            np.add.at(st.n_late, dev[late], 1)
        keep = ~(dup | dup_state | late)
        dev, t, v = dev[keep], t[keep], v[keep]
        k = dev.size
        if k == 0:
            return IngestReport(0, n_dup, n_late, n_invalid, 0, n_rej)

        v = v - self.corrections.baseline_w[dev]

        # compact to per-slab groups (devices sorted => contiguous)
        first = np.empty(k, dtype=bool)
        first[0] = True
        first[1:] = dev[1:] != dev[:-1]
        start_idx = np.flatnonzero(first)
        end_idx = np.concatenate([start_idx[1:] - 1, [k - 1]])
        u_dev = dev[start_idx]
        seg = np.cumsum(first) - 1

        had = st.has[u_dev]
        c = self.corrections
        run_t_in = np.where(had, st.run_t[u_dev], t[start_idx])
        (new_t, new_v, new_run_t, new_nchg, counts, d_e, d_ec, d_w, d_wc,
         sum_vc, n_out, cum_e, cum_ec, vc, run_dur, run_rec) = \
            self._be.stream_ingest(
                t, v, seg, first, start_idx, end_idx,
                st.last_t[u_dev], st.last_v[u_dev], had,
                run_t_in, st.n_changes[u_dev],
                c.gain[u_dev], c.offset_w[u_dev], c.time_shift_s[u_dev],
                self._win_a[u_dev], self._win_b[u_dev],
                self._max_hold[u_dev], self._env_lo[u_dev],
                self._env_hi[u_dev], self.trapezoid)

        # ring snapshots see running totals *before* this slab is folded
        if self.ring.slots:
            ordinal = np.arange(k) - start_idx[seg]
            self.ring.write(dev, ordinal, counts[seg], t, v,
                            st.energy_j[u_dev][seg] + cum_e,
                            st.energy_corr_j[u_dev][seg] + cum_ec,
                            u_dev, counts)
        else:
            self.ring.n_written[u_dev] += counts

        old_last_t = st.last_t[u_dev]
        st.first_t[u_dev] = np.where(had, st.first_t[u_dev], t[start_idx])
        st.last_t[u_dev] = new_t
        st.last_v[u_dev] = new_v
        st.has[u_dev] = True
        st.n_samples[u_dev] += counts
        st.energy_j[u_dev] += d_e
        st.energy_corr_j[u_dev] += d_ec
        st.win_j[u_dev] += d_w
        st.win_corr_j[u_dev] += d_wc
        st.run_t[u_dev] = new_run_t
        st.n_changes[u_dev] = new_nchg
        st.n_out[u_dev] += n_out

        # drift EWMA over wall time, one slab-mean step per device
        mean_vc = sum_vc / counts
        alpha = np.exp(-np.maximum(new_t - old_last_t, 0.0)
                       / self.drift_tau_s)
        st.ewma_w[u_dev] = np.where(
            had, alpha * st.ewma_w[u_dev] + (1.0 - alpha) * mean_vc,
            mean_vc)

        rec = np.asarray(run_rec, dtype=bool)
        if np.any(rec):
            self.periods.record(dev[rec], np.asarray(run_dur)[rec])

        # per-label corrected-reading moments (Chan–Welford): one
        # bincount pass over the slab, O(K + labels) — no per-label
        # masks, so per-device labels stay cheap at fleet scale
        codes = self._label_codes[dev]
        nl = len(self._label_names)
        cnt = np.bincount(codes, minlength=nl)
        s1 = np.bincount(codes, weights=vc, minlength=nl)
        s2 = np.bincount(codes, weights=vc * vc, minlength=nl)
        av = np.abs(vc)
        sa = np.bincount(codes, weights=av, minlength=nl)
        mx = np.zeros(nl)
        np.maximum.at(mx, codes, av)
        for ci in np.flatnonzero(cnt):
            nb = int(cnt[ci])
            mean = s1[ci] / nb
            m2 = max(float(s2[ci] - nb * mean * mean), 0.0)
            self._moments.setdefault(
                self._label_names[ci], StreamingMoments()).merge(
                    nb, float(mean), m2, float(sa[ci] / nb),
                    float(mx[ci]))

        self._maybe_update_health(float(np.max(new_t)))
        return IngestReport(k, n_dup, n_late, n_invalid, len(u_dev), n_rej)

    def ingest_grid(self, dev, ts, vals) -> IngestReport:
        """Fold one *rectangular* slab: ``dev`` [D] distinct ascending
        device ids, ``ts`` [M] strictly-increasing sample times shared by
        every device, ``vals`` [D, M] raw readings.

        This is the clean-stream fast path: no sorting, no per-sample
        scatter — the backend's ``stream_ingest_grid`` kernel does
        row-wise cumsums and reductions over the [D, M] slab directly.
        Slabs that violate the rectangular contract (unsorted ids or
        times, non-finite readings, samples at/behind a device's newest
        accepted sample) fall back to the general :meth:`ingest` path
        with identical semantics.
        """
        dev = np.asarray(dev, dtype=np.int64).ravel()
        ts = np.asarray(ts, dtype=np.float64).ravel()
        vals = np.asarray(vals, dtype=np.float64)
        d, m = dev.size, ts.size
        if vals.shape != (d, m):
            raise ValueError(f"vals must be [{d}, {m}], "
                             f"got {vals.shape}")
        if d == 0 or m == 0:
            return IngestReport(0, 0, 0, 0, 0)
        n_rej = 0
        if dev.min() < 0 or dev.max() >= self.n_devices:
            if self.strict_ids:
                raise ValueError("device id out of range")
            ok_id = (dev >= 0) & (dev < self.n_devices)
            n_rej = int(ok_id.size - ok_id.sum()) * m
            self._n_rejected += n_rej
            dev, vals = dev[ok_id], vals[ok_id]
            d = dev.size
            if d == 0:
                self.epoch += 1     # counters mutated: publish fresh
                return IngestReport(0, 0, 0, 0, 0, n_rej)

        st = self.state
        clean = (np.all(np.diff(dev) > 0)
                 and np.all(np.diff(ts) > 0)
                 and bool(np.all(np.isfinite(ts)))
                 and bool(np.all(np.isfinite(vals)))
                 and not np.any(st.has[dev] & (ts[0] <= st.last_t[dev])))
        if not clean:
            rep = self.ingest(np.repeat(dev, m), np.tile(ts, d),
                              vals.ravel())
            return (dataclasses.replace(rep, rejected=rep.rejected + n_rej)
                    if n_rej else rep)
        self.epoch += 1

        c = self.corrections
        v = vals - c.baseline_w[dev][:, None]
        had = st.has[dev]
        run_t_in = np.where(had, st.run_t[dev], ts[0])
        (new_v, new_run_t, new_nchg, d_e, d_ec, d_w, d_wc,
         sum_vc, sum_vc2, sum_abs_vc, max_abs_vc, n_out,
         cum_e, cum_ec, run_dur, run_rec) = \
            self._be.stream_ingest_grid(
                ts, v, st.last_t[dev], st.last_v[dev], had, run_t_in,
                st.n_changes[dev], c.gain[dev], c.offset_w[dev],
                c.time_shift_s[dev], self._win_a[dev], self._win_b[dev],
                self._max_hold[dev], self._env_lo[dev],
                self._env_hi[dev], self.trapezoid)

        # ring snapshots see running totals *before* this slab is folded
        if self.ring.slots:
            self.ring.write_grid(dev, ts, v,
                                 st.energy_j[dev][:, None] + cum_e,
                                 st.energy_corr_j[dev][:, None] + cum_ec)
        else:
            self.ring.n_written[dev] += m

        old_last_t = st.last_t[dev]
        st.first_t[dev] = np.where(had, st.first_t[dev], ts[0])
        st.last_t[dev] = ts[-1]
        st.last_v[dev] = new_v
        st.has[dev] = True
        st.n_samples[dev] += m
        st.energy_j[dev] += d_e
        st.energy_corr_j[dev] += d_ec
        st.win_j[dev] += d_w
        st.win_corr_j[dev] += d_wc
        st.run_t[dev] = new_run_t
        st.n_changes[dev] = new_nchg
        st.n_out[dev] += n_out

        mean_vc = sum_vc / m
        alpha = np.exp(-np.maximum(ts[-1] - old_last_t, 0.0)
                       / self.drift_tau_s)
        st.ewma_w[dev] = np.where(
            had, alpha * st.ewma_w[dev] + (1.0 - alpha) * mean_vc,
            mean_vc)

        rec = np.asarray(run_rec, dtype=bool)
        if np.any(rec):
            dgrid = np.broadcast_to(dev[:, None], rec.shape)
            self.periods.record(dgrid[rec], np.asarray(run_dur)[rec])

        # per-label moments straight from the kernel's per-device
        # reductions — O(D + labels) instead of O(D·M)
        codes = self._label_codes[dev]
        nl = len(self._label_names)
        cnt = m * np.bincount(codes, minlength=nl)
        s1 = np.bincount(codes, weights=sum_vc, minlength=nl)
        s2 = np.bincount(codes, weights=sum_vc2, minlength=nl)
        sa = np.bincount(codes, weights=sum_abs_vc, minlength=nl)
        mx = np.zeros(nl)
        np.maximum.at(mx, codes, max_abs_vc)
        for ci in np.flatnonzero(cnt):
            nb = int(cnt[ci])
            mean = s1[ci] / nb
            m2 = max(float(s2[ci] - nb * mean * mean), 0.0)
            self._moments.setdefault(
                self._label_names[ci], StreamingMoments()).merge(
                    nb, float(mean), m2, float(sa[ci] / nb),
                    float(mx[ci]))

        self._maybe_update_health(float(ts[-1]))
        return IngestReport(d * m, 0, 0, 0, d, n_rej)

    # -- health -----------------------------------------------------------
    def _maybe_update_health(self, t_now: float) -> None:
        """Run the health machine at a slab boundary, throttled to at
        most once per ``health_every_s`` of stream time.  Time going
        *backward* across slabs (chunked replays re-start the clock per
        device slab) never triggers an evaluation, so chunk order cannot
        quarantine devices that simply haven't been streamed yet."""
        if self.health is None or not np.isfinite(t_now):
            return
        if t_now < self._next_health_t:
            return
        self._next_health_t = t_now + self.health_every_s
        self.update_health(t_now, _bump_epoch=False)

    def update_health(self, t_now: float, _bump_epoch: bool = True) -> bool:
        """Evaluate one health step at wall-clock ``t_now`` (no-op
        without a policy).  Returns True when any device changed state;
        an explicit call that changes state bumps the epoch (ingestion's
        own slab-boundary evaluations ride the slab's bump)."""
        if self.health is None:
            return False
        changed = self.health.update(
            self.state, t_now=float(t_now), policy=self.health_policy,
            period_est=self.periods.estimates(),
            ref_period_s=self.corrections.ref_period_s,
            silent_after_s=self.silent_after_s,
            drift_tau_s=self.drift_tau_s, drift_rel=self.drift_rel,
            drift_abs_w=self.drift_abs_w)
        if changed and _bump_epoch:
            self.epoch += 1
        return changed

    # -- accounting -------------------------------------------------------
    @property
    def counters(self) -> Dict[str, int]:
        st = self.state
        out = {
            "accepted": int(np.sum(st.n_samples)),
            "duplicates": int(np.sum(st.n_dup)),
            "late": int(np.sum(st.n_late)),
            "invalid": self._n_invalid,
            "rejected": self._n_rejected,
            "devices_reporting": int(np.sum(st.has)),
        }
        if self.health is not None:
            out.update(self.health.counts())
        return out
