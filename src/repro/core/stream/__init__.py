"""Streaming fleet monitor: online ingestion, correction, query serving.

Everything else in :mod:`repro.core` is offline — ``fleet_audit``,
``measure_*_batch`` and ``SensorBank.integrate_polled`` all need the
full workload timeline before integrating.  This package is the *live*
counterpart: raw per-device poll samples arrive tick by tick (in any
order, with duplicates and gaps) and the paper's §5 corrections are
applied as they arrive, so corrected energy queries are served while
the fleet is still running.

Layers (see ``docs/streaming.md``):

* :mod:`~repro.core.stream.state` — stacked per-device accumulators and
  the recent-sample ring buffer (no per-device Python objects);
* :mod:`~repro.core.stream.estimators` — the online update-period
  estimator and the stacked §5 correction parameters;
* :mod:`~repro.core.stream.monitor` — :class:`MonitorService`, the
  ingestion + query API (hot kernels live in
  :mod:`repro.core.engine_backend`, one implementation per backend);
* :mod:`~repro.core.stream.replay` — drivers that replay any
  ``SensorBank`` / ``TimelineBank`` / ``FleetScenarioSpec`` fleet as a
  live stream, pinned against the offline audit on the same schedules.
"""
from repro.core.stream.estimators import (OnlinePeriodEstimator,
                                          StreamCorrections,
                                          default_calibrations)
from repro.core.stream.monitor import (FleetEnergy, IngestReport,
                                       MonitorService)
from repro.core.stream.replay import StreamFleetResult, replay, stream_fleet
from repro.core.stream.state import DeviceState, IngestBuffer

__all__ = [
    "DeviceState", "IngestBuffer",
    "OnlinePeriodEstimator", "StreamCorrections", "default_calibrations",
    "FleetEnergy", "IngestReport", "MonitorService",
    "StreamFleetResult", "replay", "stream_fleet",
]
