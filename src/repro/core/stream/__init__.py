"""Streaming fleet monitor: online ingestion, correction, query serving.

Everything else in :mod:`repro.core` is offline — ``fleet_audit``,
``measure_*_batch`` and ``SensorBank.integrate_polled`` all need the
full workload timeline before integrating.  This package is the *live*
counterpart: raw per-device poll samples arrive tick by tick (in any
order, with duplicates and gaps) and the paper's §5 corrections are
applied as they arrive, so corrected energy queries are served while
the fleet is still running.

Layers (see ``docs/streaming.md``):

* :mod:`~repro.core.stream.state` — stacked per-device accumulators and
  the recent-sample ring buffer (no per-device Python objects);
* :mod:`~repro.core.stream.estimators` — the online update-period
  estimator and the stacked §5 correction parameters;
* :mod:`~repro.core.stream.ingest` — :class:`IngestCore`, the mutable
  write side: slab folding through the backend kernels
  (:mod:`repro.core.engine_backend`, one implementation per backend);
* :mod:`~repro.core.stream.snapshot` — :class:`MonitorSnapshot`,
  immutable epoch-tagged published views that serve every query;
* :mod:`~repro.core.stream.monitor` — :class:`MonitorService`, the
  one-object façade over ingest + snapshot publication;
* :mod:`~repro.core.stream.schema` — the versioned (de)serialization
  registries shared by checkpointing and ``nbytes()`` reporting;
* :mod:`~repro.core.stream.health` — the opt-in per-device health
  machine (healthy → stale → quarantined) behind degraded-mode queries;
* :mod:`~repro.core.stream.checkpoint` — bitwise monitor
  save/restore on the seed checkpoint layout, with typed corruption
  errors and last-complete-generation fallback;
* :mod:`~repro.core.stream.supervisor` — :class:`MonitorSupervisor`,
  the crash-recovery loop (auto-checkpoint, restore-then-resume,
  slab-boundary dedup);
* :mod:`~repro.core.stream.replay` — drivers that replay any
  ``SensorBank`` / ``TimelineBank`` / ``FleetScenarioSpec`` fleet as a
  live stream, pinned against the offline audit on the same schedules,
  with seeded transport-fault injection (:class:`FaultSpec`).

(The batched, cached query executor for serving lives one level up, in
:mod:`repro.serve.monitor_service`.)
"""
from repro.core.stream.checkpoint import (CheckpointError,
                                          MissingCheckpointError,
                                          restore_monitor, save_monitor)
from repro.core.stream.estimators import (OnlinePeriodEstimator,
                                          StreamCorrections,
                                          default_calibrations)
from repro.core.stream.health import (HEALTHY, QUARANTINED, STALE,
                                      HealthPolicy, HealthTracker)
from repro.core.stream.ingest import IngestCore
from repro.core.stream.monitor import (FleetEnergy, IngestReport,
                                       MonitorService)
from repro.core.stream.replay import (FaultInjector, FaultSpec,
                                      InjectionLog, StreamFleetResult,
                                      replay, stream_fleet)
from repro.core.stream.schema import SCHEMA_VERSION, SchemaError
from repro.core.stream.snapshot import MonitorSnapshot
from repro.core.stream.state import DeviceState, IngestBuffer
from repro.core.stream.supervisor import MonitorSupervisor, SupervisorReport

__all__ = [
    "DeviceState", "IngestBuffer",
    "OnlinePeriodEstimator", "StreamCorrections", "default_calibrations",
    "FleetEnergy", "IngestReport", "IngestCore", "MonitorService",
    "MonitorSnapshot", "SCHEMA_VERSION", "SchemaError",
    "HEALTHY", "STALE", "QUARANTINED", "HealthPolicy", "HealthTracker",
    "CheckpointError", "MissingCheckpointError",
    "save_monitor", "restore_monitor",
    "MonitorSupervisor", "SupervisorReport",
    "FaultSpec", "FaultInjector", "InjectionLog",
    "StreamFleetResult", "replay", "stream_fleet",
]
