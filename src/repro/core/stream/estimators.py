"""Online estimators and correction parameters for the streaming monitor.

:class:`OnlinePeriodEstimator` is the streaming counterpart of
:func:`repro.core.microbench.estimate_update_period`: the offline
estimator takes the median of *complete* run durations (runs of
identical consecutive readings bounded by a change on both sides) over a
finished capture; here the same complete runs arrive one at a time —
extracted by the ingest kernel with the same first/last-run-dropped rule
(see :func:`repro.core.microbench.complete_run_durations`) — and fold
into a per-device log-spaced duration histogram.  The estimate is the
mean duration inside the median bin: with run durations concentrated at
the true update period (reading noise breaks value ties, so nearly every
sensor tick is a change) this converges to the offline median as runs
accumulate, at O(bins) memory per device instead of O(runs).

:class:`StreamCorrections` stacks the paper's §5 per-device correction
parameters — calibrated gain/offset inversion, the boxcar-window
re-synchronisation shift, a host-baseline debit for module-scope
sensors — as [N] arrays consumed directly by the ingest kernel.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Sequence

import numpy as np

from repro.core.calibrate import CalibrationRecord


class OnlinePeriodEstimator:
    """Per-device streaming update-period estimate from complete runs."""

    def __init__(self, n_devices: int, lo_s: float = 1e-3,
                 hi_s: float = 100.0, n_bins: int = 24,
                 min_runs: int = 3):
        if not (0.0 < lo_s < hi_s):
            raise ValueError(f"bad histogram range [{lo_s}, {hi_s}]")
        if n_bins < 2:
            raise ValueError("need at least two histogram bins")
        self.min_runs = int(min_runs)
        # interior edges: bin 0 catches everything below lo_s, the last
        # bin everything above hi_s, so no run is ever dropped
        self.edges = np.geomspace(lo_s, hi_s, n_bins - 1)
        self.counts = np.zeros((n_devices, n_bins), dtype=np.int64)
        self.sums = np.zeros((n_devices, n_bins))

    @property
    def n_bins(self) -> int:
        return self.counts.shape[1]

    def nbytes(self) -> int:
        from repro.core.stream import schema
        return schema.registry_nbytes(self, schema.PERIOD_FIELDS,
                                      "OnlinePeriodEstimator")

    def record(self, dev: np.ndarray, durations: np.ndarray) -> None:
        """Fold one slab's completed runs (device ids + durations)."""
        if len(dev) == 0:
            return
        b = np.searchsorted(self.edges, durations, side="right")
        np.add.at(self.counts, (dev, b), 1)
        np.add.at(self.sums, (dev, b), durations)

    @property
    def n_runs(self) -> np.ndarray:
        return self.counts.sum(axis=1)

    def estimates(self) -> np.ndarray:
        """[N] update-period estimates; nan below ``min_runs`` complete
        runs (the offline estimator's guard against phase-biased
        short captures)."""
        n = self.n_runs
        cum = np.cumsum(self.counts, axis=1)
        need = (n + 1) // 2
        bstar = np.argmax(cum >= need[:, None], axis=1)
        rows = np.arange(self.counts.shape[0])
        cnt = self.counts[rows, bstar]
        est = self.sums[rows, bstar] / np.maximum(cnt, 1)
        return np.where((n >= self.min_runs) & (cnt > 0), est, np.nan)


@dataclasses.dataclass(frozen=True)
class StreamCorrections:
    """Per-device §5 correction parameters as stacked arrays.

    ``gain``/``offset_w`` invert the calibrated steady-state transform
    (``corrected = (reading - offset) / gain``); ``time_shift_s``
    re-synchronises reported timestamps with device activity (a reading
    at ``t`` covers ``[t - W, t]``); ``baseline_w`` is debited from every
    raw reading before anything else (module-scope sensors, §6);
    ``ref_period_s`` is the calibration's update period, the fallback
    reference when the online estimate has not converged yet;
    ``calibrated`` marks devices with a gain-calibrated record (their
    energy uncertainty uses the calibrated tolerance).
    """

    gain: np.ndarray
    offset_w: np.ndarray
    time_shift_s: np.ndarray
    baseline_w: np.ndarray
    ref_period_s: np.ndarray
    calibrated: np.ndarray

    def __post_init__(self):
        n = self.gain.shape[0]
        for fld in dataclasses.fields(self):
            a = getattr(self, fld.name)
            if a.shape != (n,):
                raise ValueError(f"{fld.name} must be [{n}], got {a.shape}")
        if np.any(self.gain == 0.0):
            raise ValueError("correction gain must be non-zero")

    @property
    def n_devices(self) -> int:
        return self.gain.shape[0]

    @classmethod
    def identity(cls, n: int,
                 baseline_w: float | np.ndarray = 0.0,
                 ref_period_s: float = 0.1) -> "StreamCorrections":
        """No-op corrections: corrected energy equals raw energy."""
        return cls(gain=np.ones(n), offset_w=np.zeros(n),
                   time_shift_s=np.zeros(n),
                   baseline_w=np.broadcast_to(
                       np.asarray(baseline_w, dtype=np.float64), (n,)).copy(),
                   ref_period_s=np.full(n, float(ref_period_s)),
                   calibrated=np.zeros(n, dtype=bool))

    @classmethod
    def from_calibrations(cls, profile_names: Sequence[str],
                          calibs: Dict[str, CalibrationRecord],
                          baseline_w: float | np.ndarray = 0.0,
                          apply_gain: bool = True,
                          time_shift: bool = True) -> "StreamCorrections":
        """Gather per-device parameters from calibration records keyed by
        profile name — the same shape ``fleet_audit`` threads its
        records through the offline §5 protocol."""
        names = list(profile_names)
        n = len(names)
        uniq = sorted(set(names))
        missing = [u for u in uniq if u not in calibs]
        if missing:
            raise KeyError("no calibration record for profile(s): "
                           + ", ".join(missing))
        rows = {u: i for i, u in enumerate(uniq)}
        code = np.array([rows[x] for x in names], dtype=np.int64)

        def field(fn, dtype=np.float64):
            return np.array([fn(calibs[u]) for u in uniq],
                            dtype=dtype)[code]

        gain = (field(lambda c: c.correction_gain) if apply_gain
                else np.ones(n))
        return cls(
            gain=gain,
            offset_w=(field(lambda c: c.correction_offset_w) if apply_gain
                      else np.zeros(n)),
            time_shift_s=(field(lambda c: c.time_shift_s) if time_shift
                          else np.zeros(n)),
            baseline_w=np.broadcast_to(
                np.asarray(baseline_w, dtype=np.float64), (n,)).copy(),
            ref_period_s=field(lambda c: c.update_period_s),
            calibrated=field(lambda c: c.gain is not None, dtype=bool))


def default_calibrations(
        profile_names: Sequence[str]) -> Dict[str, CalibrationRecord]:
    """Synthetic per-profile records from the catalog's nominal
    parameters (no gain/offset — uncalibrated): the same
    :func:`repro.core.calibrate.nominal_record` recipe
    ``fleet_audit(good_practice=True)`` builds for itself."""
    from repro.core import profiles as _profiles
    from repro.core.calibrate import nominal_record
    return {name: nominal_record("stream", _profiles.get(name))
            for name in sorted(set(profile_names))}
