"""Per-device health state machine: healthy → stale → quarantined.

The streaming monitor's flags (:meth:`MonitorSnapshot.flags`) are
*instantaneous* observations — silent, anomalous, drifting.  This module
adds the *stateful* layer a degraded-mode query needs: each device walks
a three-state machine driven by those same signals, evaluated at slab
boundaries, and quarantined devices are excluded from fleet aggregates
until they earn their way back with a clean streak
(:class:`HealthPolicy.recover_after_s`).

States (stored as an ``int8`` code per device, checkpointed with the
rest of the monitor state):

* ``HEALTHY`` (0) — reporting on schedule, inside the envelope, no
  drift;
* ``STALE`` (1) — no sample for longer than ``stale_factor ×`` the
  silent threshold (the same per-device threshold ``flags`` uses: the
  online update-period estimate when converged, the calibration
  reference otherwise, or the monitor's explicit ``silent_after_s``);
  stale devices still count toward aggregates — staleness is a warning,
  not an exclusion;
* ``QUARANTINED`` (2) — silent past ``quarantine_factor ×`` the
  threshold (dead / dropped out), or fresh out-of-envelope readings
  (``quarantine_anomalous``), or reading drift
  (``quarantine_drifting``).  Quarantined devices are excluded from
  coverage-aware queries; they return to ``HEALTHY`` after streaming
  cleanly for ``recover_after_s``.

Health tracking is **opt-in** (``MonitorService(health=HealthPolicy())``)
— without a policy the monitor behaves exactly as before, bit for bit.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

HEALTHY = 0
STALE = 1
QUARANTINED = 2

STATE_NAMES = {HEALTHY: "healthy", STALE: "stale",
               QUARANTINED: "quarantined"}


@dataclasses.dataclass(frozen=True)
class HealthPolicy:
    """When devices demote/promote through the health machine.

    Thresholds are multiples of the monitor's per-device silent
    threshold (see module doc), so one policy adapts to heterogeneous
    update periods.  ``recover_after_s`` is the clean-streak dwell a
    quarantined device must sustain before re-admission (0 readmits on
    the first clean evaluation).
    """

    stale_factor: float = 1.0
    quarantine_factor: float = 3.0
    quarantine_anomalous: bool = True
    quarantine_drifting: bool = True
    recover_after_s: float = 0.0

    def __post_init__(self):
        if not 0.0 < self.stale_factor <= self.quarantine_factor:
            raise ValueError(
                f"need 0 < stale_factor <= quarantine_factor, got "
                f"{self.stale_factor} / {self.quarantine_factor}")
        if self.recover_after_s < 0.0:
            raise ValueError("recover_after_s must be >= 0")

    def to_meta(self) -> dict:
        """JSON-able form for checkpoint manifests."""
        return dataclasses.asdict(self)

    @classmethod
    def from_meta(cls, d: dict) -> "HealthPolicy":
        return cls(**d)


class HealthTracker:
    """The [N] state arrays of the health machine (see module doc).

    Field set is owned by ``stream.schema.HEALTH_FIELDS`` — adding an
    array here without a schema bump fails the registry check.
    """

    def __init__(self, code, since_t, clean_t, clean, last_n_out,
                 n_quarantines):
        self.code = code                    # [N] i1 state code
        self.since_t = since_t              # [N] f8 last transition time
        self.clean_t = clean_t              # [N] f8 clean-streak start
        self.clean = clean                  # [N] b1 in a clean streak
        self.last_n_out = last_n_out        # [N] i8 n_out at last eval
        self.n_quarantines = n_quarantines  # [N] i8 lifetime quarantines

    @classmethod
    def zeros(cls, n: int) -> "HealthTracker":
        return cls(code=np.zeros(n, dtype=np.int8),
                   since_t=np.zeros(n), clean_t=np.zeros(n),
                   clean=np.zeros(n, dtype=bool),
                   last_n_out=np.zeros(n, dtype=np.int64),
                   n_quarantines=np.zeros(n, dtype=np.int64))

    def nbytes(self) -> int:
        from repro.core.stream.schema import HEALTH_FIELDS, registry_nbytes
        return registry_nbytes(self, HEALTH_FIELDS, "HealthTracker")

    def counts(self) -> Dict[str, int]:
        return {"n_healthy": int(np.sum(self.code == HEALTHY)),
                "n_stale": int(np.sum(self.code == STALE)),
                "n_quarantined": int(np.sum(self.code == QUARANTINED))}

    def update(self, st, *, t_now: float, policy: HealthPolicy,
               period_est: np.ndarray, ref_period_s: np.ndarray,
               silent_after_s: Optional[float], drift_tau_s: float,
               drift_rel: float, drift_abs_w: float) -> bool:
        """Evaluate one health step at wall-clock ``t_now`` against the
        :class:`~repro.core.stream.state.DeviceState` accumulators.
        Returns True when any device changed state.

        The silence/anomaly/drift criteria are the exact rules
        :meth:`MonitorSnapshot.flags` reports, so the machine never
        disagrees with the flags a reader sees — it only adds memory
        (dwell times, clean streaks) on top.
        """
        n = st.last_t.shape[0]
        ref = np.where(np.isfinite(period_est), period_est, ref_period_s)
        after = (np.full(n, float(silent_after_s))
                 if silent_after_s is not None else 5.0 * ref)
        silent_for = t_now - st.last_t
        stale_sig = st.has & (silent_for > policy.stale_factor * after)
        dead_sig = st.has & (silent_for > policy.quarantine_factor * after)
        fresh_anom = st.has & (st.n_out > self.last_n_out)
        dur = st.last_t - st.first_t
        with np.errstate(invalid="ignore", divide="ignore"):
            mean_p = np.where(dur > 0.0, st.energy_corr_j / dur, np.nan)
        dev_w = np.abs(st.ewma_w - mean_p)
        drift_sig = (st.has & (dur > 2.0 * drift_tau_s)
                     & (dev_w > np.maximum(drift_rel * np.abs(mean_p),
                                           drift_abs_w)))
        drift_sig = np.where(np.isfinite(mean_p), drift_sig, False)

        bad = dead_sig.copy()
        if policy.quarantine_anomalous:
            bad |= fresh_anom
        if policy.quarantine_drifting:
            bad |= drift_sig
        clean_now = st.has & ~stale_sig & ~fresh_anom & ~drift_sig
        starting = clean_now & ~self.clean
        self.clean_t = np.where(starting, t_now, self.clean_t)

        new = self.code.copy()
        new[(self.code == HEALTHY) & stale_sig & ~bad] = STALE
        new[bad] = QUARANTINED
        promote_stale = (self.code == STALE) & clean_now & ~bad
        dwell_ok = (t_now - self.clean_t) >= policy.recover_after_s
        promote_q = ((self.code == QUARANTINED) & clean_now & dwell_ok
                     & ~bad)
        new[promote_stale | promote_q] = HEALTHY

        changed = new != self.code
        self.n_quarantines += ((new == QUARANTINED)
                               & (self.code != QUARANTINED))
        self.since_t = np.where(changed, t_now, self.since_t)
        self.code = new
        self.clean = clean_now
        self.last_n_out = st.n_out.copy()
        return bool(np.any(changed))
