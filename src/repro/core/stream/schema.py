"""Versioned (de)serialization schema for the streaming monitor state.

Everything a live monitor accumulates online — the :class:`DeviceState`
arrays, the ring buffer, the period histograms, the per-label reading
moments — has exactly one canonical flat representation, declared here
as explicit ``{field: dtype}`` registries.  Both consumers share it:

* **checkpointing** (:mod:`repro.core.stream.checkpoint`) packs the
  registry walk into the manifest+npy layout and unpacks it on restore;
* **memory reporting** (``MonitorService.nbytes()`` and the component
  ``nbytes()`` methods) sums the same walk.

The registries are *closed*: packing validates that the live object's
array attributes match the declared field set exactly, so adding a
field to :class:`DeviceState` (or the ring / estimator) without bumping
:data:`SCHEMA_VERSION` and the registry fails loudly in the first test
that touches ``nbytes()`` or a checkpoint — instead of silently writing
checkpoints that restore into a corrupted (field-dropped) monitor.

This module imports nothing from the rest of :mod:`repro.core.stream`
at module scope (the stream modules import *it*); the monitor-level
pack/unpack resolves its classes lazily.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import numpy as np

#: Bump whenever a registry below changes shape or meaning.  Restores
#: refuse manifests written under a different version.
#: v2: health-machine arrays (``health.*``, present only when the
#: monitor tracks health) + ``strict_ids``/``health``/``health_every_s``
#: /``next_health_t``/``n_rejected`` meta.
SCHEMA_VERSION = 2

# -- field registries (name -> expected dtype kind) -------------------------
DEVICE_STATE_FIELDS = {
    "last_t": "f8", "last_v": "f8", "has": "b1", "first_t": "f8",
    "n_samples": "i8", "n_dup": "i8", "n_late": "i8",
    "energy_j": "f8", "energy_corr_j": "f8",
    "win_j": "f8", "win_corr_j": "f8",
    "run_t": "f8", "n_changes": "i8", "ewma_w": "f8", "n_out": "i8",
}

#: ring arrays; ``t``/``v``/``e_raw``/``e_corr`` exist only when
#: ``slots > 0`` (the registry marks them optional).
RING_FIELDS = {"n_written": "i8"}
RING_SLOT_FIELDS = {"t": "f8", "v": "f8", "e_raw": "f8", "e_corr": "f8"}

PERIOD_FIELDS = {"edges": "f8", "counts": "i8", "sums": "f8"}

CORRECTION_FIELDS = {
    "gain": "f8", "offset_w": "f8", "time_shift_s": "f8",
    "baseline_w": "f8", "ref_period_s": "f8", "calibrated": "b1",
}

#: per-device monitor configuration arrays (set at construction /
#: ``set_windows`` time, immutable during ingest — checkpointed so a
#: restore needs no out-of-band config).
CONFIG_FIELDS = {
    "win_a": "f8", "win_b": "f8", "max_hold": "f8",
    "env_lo": "f8", "env_hi": "f8", "label_codes": "i8",
}

#: per-label Chan–Welford reading moments, stacked over the sorted
#: label names recorded in the manifest meta.
MOMENT_FIELDS = {"n": "i8", "mean": "f8", "m2": "f8",
                 "mean_abs": "f8", "max_abs": "f8"}

#: health state machine arrays; present only when the monitor was built
#: with a :class:`~repro.core.stream.health.HealthPolicy`.
HEALTH_FIELDS = {"code": "i1", "since_t": "f8", "clean_t": "f8",
                 "clean": "b1", "last_n_out": "i8", "n_quarantines": "i8"}


class SchemaError(RuntimeError):
    """A live object's fields diverged from the declared registry (or a
    checkpoint was written under a different schema)."""


def _array_attrs(obj: Any) -> Dict[str, np.ndarray]:
    """The ndarray-valued attributes of a dataclass or plain object."""
    if dataclasses.is_dataclass(obj):
        items = [(f.name, getattr(obj, f.name))
                 for f in dataclasses.fields(obj)]
    else:
        items = list(vars(obj).items())
    return {k: v for k, v in items if isinstance(v, np.ndarray)}


def check_registry(obj: Any, registry: Dict[str, str], what: str,
                   optional: Optional[Dict[str, str]] = None
                   ) -> Dict[str, np.ndarray]:
    """Validate ``obj``'s array attributes against ``registry`` and
    return them as ``{field: array}``.

    Extra *or* missing arrays raise :class:`SchemaError` naming the
    offending fields — the loud failure that protects checkpoints from
    silent field drift.  ``optional`` fields may be absent (the ring's
    slot arrays with ``slots=0``) but must match dtype when present.
    """
    arrays = _array_attrs(obj)
    expected = dict(registry)
    allowed = dict(registry, **(optional or {}))
    missing = sorted(set(expected) - set(arrays))
    extra = sorted(set(arrays) - set(allowed))
    if missing or extra:
        raise SchemaError(
            f"{what} diverged from schema v{SCHEMA_VERSION}: "
            + (f"missing {missing} " if missing else "")
            + (f"undeclared {extra} " if extra else "")
            + "— update repro.core.stream.schema (and bump "
              "SCHEMA_VERSION) alongside the state change")
    for name, arr in arrays.items():
        want = allowed[name]
        if np.dtype(arr.dtype).str[1:] != want:
            raise SchemaError(f"{what}.{name}: dtype {arr.dtype} != "
                              f"declared {want}")
    return arrays


def registry_nbytes(obj: Any, registry: Dict[str, str], what: str,
                    optional: Optional[Dict[str, str]] = None) -> int:
    """Resident bytes of ``obj``'s declared arrays — the shared walk
    behind the component ``nbytes()`` methods, so memory reporting
    exercises the same schema validation as checkpointing."""
    return sum(a.nbytes
               for a in check_registry(obj, registry, what, optional).values())


# -- monitor-level pack / unpack --------------------------------------------

def pack_monitor(mon) -> Tuple[Dict[str, np.ndarray], Dict[str, Any]]:
    """Flatten a live :class:`~repro.core.stream.MonitorService` (or its
    ingest core) into ``(arrays, meta)``.

    ``arrays`` is a flat ``{"group.field": ndarray}`` dict (every value a
    copy, safe to write asynchronously); ``meta`` is the JSON-able
    configuration needed to rebuild the monitor.  :func:`unpack_monitor`
    inverts it bitwise.
    """
    core = getattr(mon, "_core", mon)
    arrays: Dict[str, np.ndarray] = {}
    for k, v in check_registry(core.state, DEVICE_STATE_FIELDS,
                               "DeviceState").items():
        arrays[f"state.{k}"] = v.copy()
    ring = check_registry(core.ring, RING_FIELDS, "IngestBuffer",
                          optional=RING_SLOT_FIELDS)
    for k, v in ring.items():
        arrays[f"ring.{k}"] = v.copy()
    for k, v in check_registry(core.periods, PERIOD_FIELDS,
                               "OnlinePeriodEstimator").items():
        arrays[f"periods.{k}"] = v.copy()
    for k in CORRECTION_FIELDS:
        arrays[f"corrections.{k}"] = np.asarray(
            getattr(core.corrections, k)).copy()
    cfg = {"win_a": core._win_a, "win_b": core._win_b,
           "max_hold": core._max_hold, "env_lo": core._env_lo,
           "env_hi": core._env_hi, "label_codes": core._label_codes}
    for k, want in CONFIG_FIELDS.items():
        arr = np.asarray(cfg[k])
        if np.dtype(arr.dtype).str[1:] != want:
            raise SchemaError(f"config.{k}: dtype {arr.dtype} != "
                              f"declared {want}")
        arrays[f"config.{k}"] = arr.copy()
    # object-dtype labels are stored as their integer codes above plus
    # the name table in meta (np.save would need pickle for objects)
    moment_labels = sorted(core._moments)
    for k in MOMENT_FIELDS:
        dtype = np.int64 if MOMENT_FIELDS[k] == "i8" else np.float64
        arrays[f"moments.{k}"] = np.array(
            [getattr(core._moments[lb], k) for lb in moment_labels],
            dtype=dtype).reshape(len(moment_labels))
    if core.health is not None:
        for k, v in check_registry(core.health, HEALTH_FIELDS,
                                   "HealthTracker").items():
            arrays[f"health.{k}"] = v.copy()
    meta = {
        "schema_version": SCHEMA_VERSION,
        "n_devices": int(core.n_devices),
        "backend": core.backend if isinstance(core.backend, str) else "numpy",
        "trapezoid": bool(core.trapezoid),
        "ring_slots": int(core.ring.slots),
        "min_runs": int(core.periods.min_runs),
        "silent_after_s": (None if core.silent_after_s is None
                           else float(core.silent_after_s)),
        "drift_tau_s": float(core.drift_tau_s),
        "drift_rel": float(core.drift_rel),
        "drift_abs_w": float(core.drift_abs_w),
        "n_invalid": int(core._n_invalid),
        "n_rejected": int(core._n_rejected),
        "strict_ids": bool(core.strict_ids),
        "health": (None if core.health_policy is None
                   else core.health_policy.to_meta()),
        "health_every_s": float(core.health_every_s),
        # -inf (never evaluated) is not JSON-able; None stands in
        "next_health_t": (None if core._next_health_t == -np.inf
                          else float(core._next_health_t)),
        "epoch": int(core.epoch),
        "label_names": list(core._label_names),
        "moment_labels": moment_labels,
    }
    return arrays, meta


def expected_keys(meta: Dict[str, Any]) -> set:
    """The exact array-key set a v``meta['schema_version']`` checkpoint
    must contain (ring slot arrays only when the ring was enabled)."""
    keys = {f"state.{k}" for k in DEVICE_STATE_FIELDS}
    keys |= {f"ring.{k}" for k in RING_FIELDS}
    if int(meta.get("ring_slots", 0)) > 0:
        keys |= {f"ring.{k}" for k in RING_SLOT_FIELDS}
    keys |= {f"periods.{k}" for k in PERIOD_FIELDS}
    keys |= {f"corrections.{k}" for k in CORRECTION_FIELDS}
    keys |= {f"config.{k}" for k in CONFIG_FIELDS}
    keys |= {f"moments.{k}" for k in MOMENT_FIELDS}
    if meta.get("health") is not None:
        keys |= {f"health.{k}" for k in HEALTH_FIELDS}
    return keys


def unpack_monitor(arrays: Dict[str, np.ndarray], meta: Dict[str, Any],
                   backend: Optional[str] = None):
    """Rebuild a :class:`~repro.core.stream.MonitorService` from a
    :func:`pack_monitor` flattening — bitwise: continuing the stream
    from the rebuilt monitor is indistinguishable from never stopping.

    ``backend`` overrides the checkpointed backend name (restore a
    jax-written checkpoint on a numpy-only host and vice versa; the
    state arrays are backend-agnostic float64).
    """
    from repro.core.fleet_engine import StreamingMoments
    from repro.core.stream.estimators import StreamCorrections
    from repro.core.stream.health import HealthPolicy
    from repro.core.stream.monitor import MonitorService

    version = meta.get("schema_version")
    if version != SCHEMA_VERSION:
        raise SchemaError(f"checkpoint written under monitor schema "
                          f"v{version}, this build reads v{SCHEMA_VERSION}"
                          f" — no migration path is registered")
    want = expected_keys(meta)
    got = set(arrays)
    if want - got or got - want:
        raise SchemaError(
            f"checkpoint array set diverged from schema "
            f"v{SCHEMA_VERSION}: missing {sorted(want - got)}, "
            f"undeclared {sorted(got - want)}")

    n = int(meta["n_devices"])
    corr = StreamCorrections(**{
        k: np.ascontiguousarray(arrays[f"corrections.{k}"])
        for k in CORRECTION_FIELDS})
    names = np.asarray(meta["label_names"], dtype=object)
    labels = names[arrays["config.label_codes"]]
    policy = (None if meta["health"] is None
              else HealthPolicy.from_meta(meta["health"]))
    mon = MonitorService(
        n, corrections=corr, labels=labels,
        integration="trapezoid" if meta["trapezoid"] else "rectangle",
        ring_slots=int(meta["ring_slots"]),
        min_runs=int(meta["min_runs"]),
        silent_after_s=meta["silent_after_s"],
        drift_tau_s=meta["drift_tau_s"],
        drift_rel=meta["drift_rel"],
        drift_abs_w=meta["drift_abs_w"],
        strict_ids=bool(meta["strict_ids"]),
        health=policy,
        health_every_s=float(meta["health_every_s"]),
        backend=backend if backend is not None else meta["backend"])
    core = mon._core
    for k in DEVICE_STATE_FIELDS:
        setattr(core.state, k, arrays[f"state.{k}"].copy())
    core.ring.n_written = arrays["ring.n_written"].copy()
    if core.ring.slots:
        for k in RING_SLOT_FIELDS:
            setattr(core.ring, k, arrays[f"ring.{k}"].copy())
    for k in PERIOD_FIELDS:
        setattr(core.periods, k, arrays[f"periods.{k}"].copy())
    core._win_a = arrays["config.win_a"].copy()
    core._win_b = arrays["config.win_b"].copy()
    core._max_hold = arrays["config.max_hold"].copy()
    core._env_lo = arrays["config.env_lo"].copy()
    core._env_hi = arrays["config.env_hi"].copy()
    core._moments = {}
    for i, lb in enumerate(meta["moment_labels"]):
        sm = StreamingMoments()
        sm.n = int(arrays["moments.n"][i])
        sm.mean = float(arrays["moments.mean"][i])
        sm.m2 = float(arrays["moments.m2"][i])
        sm.mean_abs = float(arrays["moments.mean_abs"][i])
        sm.max_abs = float(arrays["moments.max_abs"][i])
        core._moments[lb] = sm
    if core.health is not None:
        for k in HEALTH_FIELDS:
            setattr(core.health, k, arrays[f"health.{k}"].copy())
    core._n_invalid = int(meta["n_invalid"])
    core._n_rejected = int(meta["n_rejected"])
    core._next_health_t = (-np.inf if meta["next_health_t"] is None
                           else float(meta["next_health_t"]))
    core.epoch = int(meta["epoch"])
    return mon
