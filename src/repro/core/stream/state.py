"""Stacked per-device state for the streaming fleet monitor.

No per-device Python objects anywhere — the same array discipline as
:class:`~repro.core.fleet_engine.SensorBank`: every accumulator is one
[N] (or [N, R]) array, updated by scatter operations over the devices a
slab actually touched.

Two layers:

* :class:`DeviceState` — the streaming accumulators: last accepted
  sample, running raw/corrected energy, registered-window energy,
  run-tracking state for the online update-period estimator, ingestion
  counters, and the EWMA used for drift detection.
* :class:`IngestBuffer` — a ring of each device's most recent samples
  ``(t, reading, running raw energy, running corrected energy)``.  The
  energy snapshots make any *recent* instant exactly reconstructible
  (``energy_at = e[j] + v[j] · (t - t[j])``), which is what serves
  windowed mid-run queries without keeping the full history.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class DeviceState:
    """Streaming accumulators, one slot per device (see module doc)."""

    last_t: np.ndarray          # [N] newest accepted sample time
    last_v: np.ndarray          # [N] newest accepted (baselined) reading
    has: np.ndarray             # [N] device has reported at least once
    first_t: np.ndarray         # [N] first accepted sample time
    n_samples: np.ndarray       # [N] accepted samples
    n_dup: np.ndarray           # [N] duplicates dropped
    n_late: np.ndarray          # [N] out-of-order (late) samples dropped
    energy_j: np.ndarray        # [N] ∫ raw readings dt since first sample
    energy_corr_j: np.ndarray   # [N] ∫ corrected readings dt
    win_j: np.ndarray           # [N] raw energy clipped to the window
    win_corr_j: np.ndarray      # [N] corrected energy clipped to the window
    run_t: np.ndarray           # [N] time of the last reading change
    n_changes: np.ndarray       # [N] reading changes seen (ever)
    ewma_w: np.ndarray          # [N] EWMA of corrected readings (drift)
    n_out: np.ndarray           # [N] readings outside the envelope

    @classmethod
    def zeros(cls, n: int) -> "DeviceState":
        f = lambda: np.zeros(n)                       # noqa: E731
        i = lambda: np.zeros(n, dtype=np.int64)       # noqa: E731
        return cls(last_t=f(), last_v=f(),
                   has=np.zeros(n, dtype=bool), first_t=f(),
                   n_samples=i(), n_dup=i(), n_late=i(),
                   energy_j=f(), energy_corr_j=f(),
                   win_j=f(), win_corr_j=f(),
                   run_t=f(), n_changes=i(), ewma_w=f(), n_out=i())

    @property
    def n_devices(self) -> int:
        return self.last_t.shape[0]

    def nbytes(self) -> int:
        from repro.core.stream import schema
        return schema.registry_nbytes(self, schema.DEVICE_STATE_FIELDS,
                                      "DeviceState")


class IngestBuffer:
    """Ring of each device's ``slots`` most recent accepted samples.

    Writes happen once per ingest slab: the caller passes the slab's
    per-sample within-group ordinals, and only each group's last
    ``slots`` samples are written (earlier ones would be overwritten in
    the same slab anyway), so scatter indices never collide.

    ``slots=0`` disables the buffer — the monitor still answers live
    queries, but windowed/past queries report not-covered.
    """

    def __init__(self, n_devices: int, slots: int):
        if slots < 0:
            raise ValueError(f"ring slots must be >= 0, got {slots}")
        self.slots = int(slots)
        self.n_written = np.zeros(n_devices, dtype=np.int64)
        if self.slots:
            self.t = np.full((n_devices, self.slots), np.inf)
            self.v = np.zeros((n_devices, self.slots))
            self.e_raw = np.zeros((n_devices, self.slots))
            self.e_corr = np.zeros((n_devices, self.slots))

    def nbytes(self) -> int:
        from repro.core.stream import schema
        return schema.registry_nbytes(self, schema.RING_FIELDS,
                                      "IngestBuffer",
                                      optional=schema.RING_SLOT_FIELDS)

    def write(self, dev: np.ndarray, ordinal: np.ndarray,
              group_count: np.ndarray, t: np.ndarray, v: np.ndarray,
              e_raw: np.ndarray, e_corr: np.ndarray,
              u_dev: np.ndarray, counts: np.ndarray) -> None:
        """Append one slab's accepted samples.

        ``dev``/``ordinal``/``group_count`` are per-sample [K] (device
        id, position within its device's group, that group's size);
        ``u_dev``/``counts`` are the slab's distinct devices and their
        sample counts [U].
        """
        if self.slots:
            keep = ordinal >= group_count - self.slots
            d = dev[keep]
            slot = (self.n_written[d] + ordinal[keep]) % self.slots
            self.t[d, slot] = t[keep]
            self.v[d, slot] = v[keep]
            self.e_raw[d, slot] = e_raw[keep]
            self.e_corr[d, slot] = e_corr[keep]
        self.n_written[u_dev] += counts

    def write_grid(self, dev: np.ndarray, t: np.ndarray, v: np.ndarray,
                   e_raw: np.ndarray, e_corr: np.ndarray) -> None:
        """Append one rectangular slab: ``dev`` [D] distinct devices all
        sampled at the shared, increasing times ``t`` [M]; ``v``/
        ``e_raw``/``e_corr`` are [D, M].  Equivalent to :meth:`write`
        with ordinal = column index — only each row's last ``slots``
        columns land, so scatter indices never collide."""
        m = t.shape[0]
        if self.slots:
            kc = min(self.slots, m)
            cols = np.arange(m - kc, m)
            rows = dev[:, None]
            slot = (self.n_written[dev][:, None] + cols[None, :]) \
                % self.slots
            self.t[rows, slot] = t[cols][None, :]
            self.v[rows, slot] = v[:, cols]
            self.e_raw[rows, slot] = e_raw[:, cols]
            self.e_corr[rows, slot] = e_corr[:, cols]
        self.n_written[dev] += m

    def sorted_view(self):
        """``(t, v, e_raw, e_corr)`` [N, R] oldest→newest per row, unused
        slots ``+inf`` — ready for row-wise binary search."""
        if not self.slots:
            raise RuntimeError("ring buffer disabled (slots=0)")
        r = self.slots
        start = np.where(self.n_written >= r, self.n_written % r, 0)
        order = (start[:, None] + np.arange(r)[None, :]) % r
        return (np.take_along_axis(self.t, order, axis=1),
                np.take_along_axis(self.v, order, axis=1),
                np.take_along_axis(self.e_raw, order, axis=1),
                np.take_along_axis(self.e_corr, order, axis=1))
