"""Crash-recovery supervisor: checkpointed ingest that survives kills.

:class:`MonitorSupervisor` wraps a :class:`~repro.core.stream.monitor.
MonitorService` with the operational loop a long-lived collector needs:

* **periodic auto-checkpoints** at slab boundaries (every
  ``checkpoint_every`` slabs, via :func:`~repro.core.stream.checkpoint.
  save_monitor`), each stamping the slab cursor into the manifest meta
  (``extras={"slab_seq": seq}``);
* **restore-then-resume**: :meth:`start` restores the newest *complete*
  checkpoint generation under the root (``fallback=True`` — a write
  that died mid-flight is skipped, not fatal) and picks up the slab
  cursor from its meta; a fresh monitor from ``factory()`` only when no
  checkpoint exists;
* **in-run crash handling**: an exception escaping the slab source or
  the ingest path triggers restore + retry with optional backoff, up to
  ``max_restores`` times;
* **slab-boundary dedup**: the slab source is (re)played from the
  beginning on every (re)start and slabs with ``seq <= slab_seq`` are
  skipped, so a slab is never folded twice — the exactly-once guarantee
  rides the checkpoint, not the source.

Recovery contract (pinned in ``tests/test_resilience.py`` on both
backends): for a *deterministic* slab source — one that regenerates the
identical slab sequence on each call, e.g. replaying a recorded stream
through a seeded :class:`~repro.core.stream.replay.FaultSpec` — a run
killed at ANY slab boundary and resumed through the supervisor answers
every query bitwise identically to a run that was never interrupted.
Mid-slab kills lose at most the slabs since the last checkpoint, which
the resumed source re-plays; nothing is double-counted.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Iterable, Optional, Tuple

import numpy as np

from repro.core.stream.checkpoint import (MissingCheckpointError,
                                          restore_monitor, save_monitor)

Slab = Tuple[int, np.ndarray, np.ndarray, np.ndarray]


@dataclasses.dataclass
class SupervisorReport:
    """Outcome of one :meth:`MonitorSupervisor.run`."""

    n_slabs: int = 0        #: slabs folded into the monitor this run
    n_skipped: int = 0      #: slabs skipped by the dedup cursor
    n_crashes: int = 0      #: exceptions caught from source/ingest
    n_restores: int = 0     #: successful restore-then-resume cycles
    n_checkpoints: int = 0  #: checkpoints written (incl. the final one)
    resumed_from: Optional[int] = None  #: slab cursor found at start()
    last_seq: int = -1      #: newest slab seq folded or skipped


class MonitorSupervisor:
    """Supervise a monitor's ingest loop with checkpoint/restore.

    ``factory`` builds a fresh monitor for cold starts (it is NOT called
    when a checkpoint restores).  ``slab_source`` passed to :meth:`run`
    is a zero-argument callable returning an iterable of
    ``(seq, dev, ts, vs)`` tuples with ``seq`` strictly increasing from
    0 — it is re-invoked from the top after every in-run restore, and
    must regenerate the same slabs for the recovery contract to hold
    (seeded generators and :class:`~repro.core.stream.replay.
    FaultInjector` plans are keyed so they do).
    """

    def __init__(self, factory: Callable[[], object], root: str, *,
                 checkpoint_every: int = 8, retain: int = 3,
                 max_restores: int = 8, backoff_s: float = 0.0,
                 asynchronous: bool = False,
                 backend: Optional[str] = None):
        if checkpoint_every < 1:
            raise ValueError("checkpoint_every must be >= 1")
        if max_restores < 0:
            raise ValueError("max_restores must be >= 0")
        self.factory = factory
        self.root = root
        self.checkpoint_every = int(checkpoint_every)
        self.retain = int(retain)
        self.max_restores = int(max_restores)
        self.backoff_s = float(backoff_s)
        self.asynchronous = bool(asynchronous)
        self.backend = backend
        self.monitor = None
        self._seq_done = -1
        self._ckpt_seq = -1
        self._mgr = None

    # -- lifecycle ---------------------------------------------------------
    def start(self, report: Optional[SupervisorReport] = None):
        """Restore the newest complete checkpoint (or build fresh) and
        position the slab cursor; returns the live monitor."""
        try:
            mon, meta = restore_monitor(self.root, backend=self.backend,
                                        fallback=True, with_meta=True)
            self._seq_done = int(meta.get("slab_seq", -1))
            if report is not None:
                report.resumed_from = self._seq_done
        except MissingCheckpointError:
            mon = self.factory()
            self._seq_done = -1
        self._ckpt_seq = self._seq_done
        self.monitor = mon
        return mon

    def checkpoint(self, *, step: Optional[int] = None) -> None:
        """Write one checkpoint now, stamping the slab cursor."""
        self._mgr = save_monitor(
            self.monitor, self.root, step=step, retain=self.retain,
            asynchronous=self.asynchronous,
            extras={"slab_seq": self._seq_done})
        self._ckpt_seq = self._seq_done

    def wait(self) -> None:
        """Drain any pending async checkpoint write."""
        if self._mgr is not None:
            self._mgr.wait()

    # -- the supervised loop -----------------------------------------------
    def run(self, slab_source: Callable[[], Iterable[Slab]], *,
            grid: bool = False) -> SupervisorReport:
        """Fold every slab from ``slab_source`` into the monitor,
        checkpointing periodically and restoring + resuming on crashes.

        Returns a :class:`SupervisorReport`; the live monitor is
        ``self.monitor``.  A final checkpoint is always written once the
        source drains (so a follow-up run resumes past the whole
        stream), and the last in-run exception re-raises once
        ``max_restores`` is exhausted.
        """
        report = SupervisorReport()
        if self.monitor is None:
            self.start(report)
        restores_left = self.max_restores
        while True:
            try:
                for seq, dev, ts, vs in slab_source():
                    if seq <= self._seq_done:
                        report.n_skipped += 1
                        report.last_seq = max(report.last_seq, int(seq))
                        continue
                    if grid:
                        self.monitor.ingest_grid(dev, ts, vs)
                    else:
                        self.monitor.ingest(dev, ts, vs)
                    self._seq_done = int(seq)
                    report.n_slabs += 1
                    report.last_seq = max(report.last_seq, int(seq))
                    if (seq + 1) % self.checkpoint_every == 0:
                        self.checkpoint(step=int(seq))
                        report.n_checkpoints += 1
                break
            except Exception:
                report.n_crashes += 1
                if restores_left == 0:
                    raise
                restores_left -= 1
                if self.backoff_s > 0.0:
                    time.sleep(self.backoff_s)
                self.start()
                report.n_restores += 1
        if self._seq_done > self._ckpt_seq:
            self.checkpoint(step=self._seq_done)
            report.n_checkpoints += 1
        self.wait()
        return report
