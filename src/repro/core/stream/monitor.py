"""The streaming fleet monitor façade: ingest core + snapshot serving.

:class:`MonitorService` keeps the one-object API the rest of the repo
(and the parity pins in ``tests/test_stream.py``) program against, but
is now a thin façade over the layered stack:

* :class:`~repro.core.stream.ingest.IngestCore` — the mutable state and
  the slab-folding hot path (correction kernels, ring writes, period
  recording, per-label moments).  ``ingest``/``ingest_grid`` delegate
  straight through; the hot path gained no indirection beyond one
  attribute hop.
* :class:`~repro.core.stream.snapshot.MonitorSnapshot` — immutable,
  epoch-tagged copy-on-write views.  Every query method here resolves
  ``self.snapshot()`` — published lazily, at most once per ingest epoch
  — and delegates, so readers never touch mutable ingest state and a
  query's answer is reproducible for as long as its snapshot is held.
* :class:`~repro.serve.monitor_service.MonitorQueryService` — the
  batched query executor for high-traffic serving (thousands of
  concurrent queries per snapshot as one vectorized op, LRU-cached by
  ``(query, epoch)``).
* :mod:`~repro.core.stream.checkpoint` — save/restore of the full
  online state (bitwise resume at any slab boundary).

Parity contract (pinned by ``tests/test_stream.py``): replaying a
fleet's poll series through ``ingest`` yields — on both execution
backends — registered-window energies equal to
``SensorBank.integrate_polled`` (and hence ``fleet_audit``'s naive
estimates) and full-span energies equal to the offline integration of
the same series, within float accumulation order (~1e-12 relative).
See ``docs/streaming.md``.
"""
from __future__ import annotations

from typing import Dict, Optional, Union

import numpy as np

from repro.core.stream.estimators import StreamCorrections
from repro.core.stream.health import HealthPolicy
from repro.core.stream.ingest import IngestCore, IngestReport
from repro.core.stream.snapshot import FleetEnergy, MonitorSnapshot

__all__ = ["FleetEnergy", "HealthPolicy", "IngestReport", "MonitorService"]


class MonitorService:
    """Online fleet monitor over raw poll-sample slabs.

    Usage::

        mon = MonitorService(n_devices, corrections=corr, labels=labels)
        mon.set_windows(a, b)              # optional §5 execution windows
        for dev, t, v in sensor_bank.iter_poll_slabs(0.0, 10.0, 0.001):
            mon.ingest(dev, t, v)
        fleet = mon.fleet_energy(t=8.0)    # mid-run corrected energy
        per_label = mon.by_label(t0=6.0, t1=8.0)

    Ingestion policy (graceful by construction): slabs may arrive with
    samples in any order — they are sorted per device; exact duplicates
    and samples older than a device's newest accepted sample are dropped
    and counted (``state.n_dup`` / ``state.n_late``); non-finite samples
    are dropped and counted; devices simply absent from a slab keep
    their last reading (rectangle extrapolation, optionally capped by
    ``max_hold_s`` for gap-aware integration).

    Queries are answered from the current epoch's immutable
    :class:`~repro.core.stream.snapshot.MonitorSnapshot` (see
    :meth:`snapshot`); hold one to pin a consistent view across several
    queries while ingestion continues.
    """

    def __init__(self, n_devices: int, *,
                 corrections: Optional[StreamCorrections] = None,
                 labels: Optional[np.ndarray] = None,
                 integration: str = "rectangle",
                 max_hold_s: Union[None, float, np.ndarray] = None,
                 envelope_w: Optional[tuple] = None,
                 ring_slots: int = 8,
                 period_bins: int = 24,
                 min_runs: int = 3,
                 silent_after_s: Optional[float] = None,
                 drift_tau_s: float = 30.0,
                 drift_rel: float = 0.25,
                 drift_abs_w: float = 5.0,
                 strict_ids: bool = True,
                 health: Optional[HealthPolicy] = None,
                 health_every_s: float = 0.0,
                 backend: Optional[str] = None):
        self._core = IngestCore(
            n_devices, corrections=corrections, labels=labels,
            integration=integration, max_hold_s=max_hold_s,
            envelope_w=envelope_w, ring_slots=ring_slots,
            period_bins=period_bins, min_runs=min_runs,
            silent_after_s=silent_after_s, drift_tau_s=drift_tau_s,
            drift_rel=drift_rel, drift_abs_w=drift_abs_w,
            strict_ids=strict_ids, health=health,
            health_every_s=health_every_s, backend=backend)
        self._snap: Optional[MonitorSnapshot] = None

    # -- layer access ------------------------------------------------------
    @property
    def core(self) -> IngestCore:
        """The mutable ingest core (write side of the split)."""
        return self._core

    def snapshot(self) -> MonitorSnapshot:
        """The current epoch's immutable published view, created lazily
        and reused until the next slab lands — copy-on-write: holding an
        old snapshot while ingestion continues is free and its answers
        stay bitwise stable."""
        if self._snap is None or self._snap.epoch != self._core.epoch:
            self._snap = MonitorSnapshot.publish(self._core)
        return self._snap

    @property
    def epoch(self) -> int:
        """Monotonic ingest epoch (bumps on every slab that lands)."""
        return self._core.epoch

    # -- pass-through state (the pre-split attribute surface) --------------
    @property
    def n_devices(self) -> int:
        return self._core.n_devices

    @property
    def backend(self):
        return self._core.backend

    @property
    def corrections(self) -> StreamCorrections:
        return self._core.corrections

    @property
    def labels(self) -> np.ndarray:
        return self._core.labels

    @property
    def trapezoid(self) -> bool:
        return self._core.trapezoid

    @property
    def silent_after_s(self):
        return self._core.silent_after_s

    @property
    def state(self):
        """Live (mutable) per-device accumulators — ingest-side state;
        readers wanting a stable view should use :meth:`snapshot`."""
        return self._core.state

    @property
    def ring(self):
        return self._core.ring

    @property
    def periods(self):
        return self._core.periods

    # -- configuration -----------------------------------------------------
    def set_windows(self, a, b) -> None:
        self._core.set_windows(a, b)

    set_windows.__doc__ = IngestCore.set_windows.__doc__

    def nbytes(self) -> int:
        return self._core.nbytes()

    nbytes.__doc__ = IngestCore.nbytes.__doc__

    def grow(self, n_new: int, *, corrections=None, labels=None) -> None:
        self._core.grow(n_new, corrections=corrections, labels=labels)

    grow.__doc__ = IngestCore.grow.__doc__

    # -- ingestion ---------------------------------------------------------
    def ingest(self, dev, t, v) -> IngestReport:
        return self._core.ingest(dev, t, v)

    ingest.__doc__ = IngestCore.ingest.__doc__

    def ingest_grid(self, dev, ts, vals) -> IngestReport:
        return self._core.ingest_grid(dev, ts, vals)

    ingest_grid.__doc__ = IngestCore.ingest_grid.__doc__

    # -- queries (delegated to the current snapshot) -----------------------
    def fleet_energy(self, t: Optional[float] = None,
                     corrected: bool = True) -> FleetEnergy:
        return self.snapshot().fleet_energy(t, corrected)

    fleet_energy.__doc__ = MonitorSnapshot.fleet_energy.__doc__

    def window_energy(self, t: Optional[float] = None,
                      corrected: bool = True) -> np.ndarray:
        return self.snapshot().window_energy(t, corrected)

    window_energy.__doc__ = MonitorSnapshot.window_energy.__doc__

    def energy_between(self, t0: float, t1: float,
                       corrected: bool = True):
        return self.snapshot().energy_between(t0, t1, corrected)

    energy_between.__doc__ = MonitorSnapshot.energy_between.__doc__

    def by_label(self, t0: Optional[float] = None,
                 t1: Optional[float] = None,
                 corrected: bool = True) -> Dict[str, Dict[str, float]]:
        return self.snapshot().by_label(t0, t1, corrected)

    by_label.__doc__ = MonitorSnapshot.by_label.__doc__

    def reading_stats(self) -> Dict[str, Dict[str, float]]:
        return self.snapshot().reading_stats()

    reading_stats.__doc__ = MonitorSnapshot.reading_stats.__doc__

    def update_period_s(self) -> np.ndarray:
        return self.snapshot().update_period_s()

    update_period_s.__doc__ = MonitorSnapshot.update_period_s.__doc__

    def flags(self, t: Optional[float] = None) -> Dict[str, np.ndarray]:
        return self.snapshot().flags(t)

    flags.__doc__ = MonitorSnapshot.flags.__doc__

    # -- health ------------------------------------------------------------
    @property
    def health(self):
        """The live :class:`~repro.core.stream.health.HealthTracker`
        (None unless constructed with a ``health=`` policy)."""
        return self._core.health

    @property
    def health_policy(self):
        return self._core.health_policy

    def update_health(self, t_now: float) -> bool:
        return self._core.update_health(t_now)

    update_health.__doc__ = IngestCore.update_health.__doc__

    def health_summary(self) -> Dict[str, float]:
        return self.snapshot().health_summary()

    @property
    def counters(self) -> Dict[str, int]:
        return self._core.counters
