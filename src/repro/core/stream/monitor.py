"""The streaming fleet monitor: online ingestion, correction, queries.

:class:`MonitorService` consumes raw per-device poll samples
incrementally — array slabs of ``(device, t, reading)`` per tick, in any
order, with duplicates and gaps — and serves corrected energy queries
while the fleet is still running.  Everything the offline §5 pipeline
does *after* a capture finishes happens here *as samples arrive*:

* rectangle (or trapezoid) integration of the polled series, through the
  same backend kernel the offline protocol integrates with
  (:func:`~repro.core.engine_backend.numpy_backend.step_integrate` /
  ``stream_ingest``);
* the calibrated gain/offset inversion and the boxcar-window
  re-synchronisation shift (:class:`.estimators.StreamCorrections`);
* the update-period estimate, converging online as complete runs of
  identical readings accumulate
  (:class:`.estimators.OnlinePeriodEstimator`);
* per-label reading statistics via the fleet engine's Chan–Welford
  :class:`~repro.core.fleet_engine.StreamingMoments`.

Parity contract (pinned by ``tests/test_stream.py``): replaying a
fleet's poll series through ``ingest`` yields — on both execution
backends — registered-window energies equal to
``SensorBank.integrate_polled`` (and hence ``fleet_audit``'s naive
estimates) and full-span energies equal to the offline integration of
the same series, within float accumulation order (~1e-12 relative).
See ``docs/streaming.md``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Union

import numpy as np

from repro.core.engine_backend import get_backend, resolve_backend
from repro.core.engine_backend.numpy_backend import searchsorted_rows
from repro.core.fleet_engine import StreamingMoments
from repro.core.stream.estimators import (OnlinePeriodEstimator,
                                          StreamCorrections)
from repro.core.stream.state import DeviceState, IngestBuffer

_INTEGRATIONS = ("rectangle", "trapezoid")


@dataclasses.dataclass(frozen=True)
class IngestReport:
    """What one ``ingest`` call did with its slab."""

    accepted: int
    duplicates: int
    late: int
    invalid: int
    n_devices: int      # distinct devices that contributed samples


@dataclasses.dataclass(frozen=True)
class FleetEnergy:
    """A fleet-energy query answer with uncertainty bounds.

    ``per_device_j`` is nan where ``covered`` is False (the query instant
    predates the device's ring-buffer coverage); totals and sigmas are
    over covered devices only.  Uncertainty follows the telemetry
    model: per-device sigma is the shunt tolerance of the energy
    (calibrated devices use the calibrated floor), aggregated both as
    independent (1/√N) and worst-case (correlated lot) bounds.
    """

    t: Optional[float]
    corrected: bool
    per_device_j: np.ndarray
    covered: np.ndarray
    total_j: float
    n_reporting: int
    sigma_independent_j: float
    sigma_worstcase_j: float


class MonitorService:
    """Online fleet monitor over raw poll-sample slabs.

    Usage::

        mon = MonitorService(n_devices, corrections=corr, labels=labels)
        mon.set_windows(a, b)              # optional §5 execution windows
        for dev, t, v in sensor_bank.iter_poll_slabs(0.0, 10.0, 0.001):
            mon.ingest(dev, t, v)
        fleet = mon.fleet_energy(t=8.0)    # mid-run corrected energy
        per_label = mon.by_label(t0=6.0, t1=8.0)

    Ingestion policy (graceful by construction): slabs may arrive with
    samples in any order — they are sorted per device; exact duplicates
    and samples older than a device's newest accepted sample are dropped
    and counted (``state.n_dup`` / ``state.n_late``); non-finite samples
    are dropped and counted; devices simply absent from a slab keep
    their last reading (rectangle extrapolation, optionally capped by
    ``max_hold_s`` for gap-aware integration).
    """

    def __init__(self, n_devices: int, *,
                 corrections: Optional[StreamCorrections] = None,
                 labels: Optional[np.ndarray] = None,
                 integration: str = "rectangle",
                 max_hold_s: Union[None, float, np.ndarray] = None,
                 envelope_w: Optional[tuple] = None,
                 ring_slots: int = 8,
                 period_bins: int = 24,
                 min_runs: int = 3,
                 silent_after_s: Optional[float] = None,
                 drift_tau_s: float = 30.0,
                 drift_rel: float = 0.25,
                 drift_abs_w: float = 5.0,
                 backend: Optional[str] = None):
        if n_devices < 1:
            raise ValueError("need at least one device")
        if integration not in _INTEGRATIONS:
            raise ValueError(f"unknown integration '{integration}'; "
                             f"known: {', '.join(_INTEGRATIONS)}")
        n = int(n_devices)
        self.n_devices = n
        self.backend = resolve_backend(backend)
        self._be = get_backend(self.backend)
        self.corrections = (corrections if corrections is not None
                            else StreamCorrections.identity(n))
        if self.corrections.n_devices != n:
            raise ValueError(
                f"corrections cover {self.corrections.n_devices} devices, "
                f"monitor has {n}")
        if labels is None:
            self.labels = np.full(n, "all", dtype=object)
        else:
            self.labels = np.asarray(labels, dtype=object)
            if self.labels.shape != (n,):
                raise ValueError(f"labels must be [{n}], "
                                 f"got {self.labels.shape}")
        # integer label codes keep object-array work off the hot path
        names, codes = np.unique(self.labels.astype(str),
                                 return_inverse=True)
        self._label_names = [str(x) for x in names]
        self._label_codes = codes.astype(np.int64)
        self.trapezoid = (integration == "trapezoid")
        if max_hold_s is None:
            self._max_hold = np.full(n, np.inf)
        else:
            self._max_hold = np.broadcast_to(
                np.asarray(max_hold_s, dtype=np.float64), (n,)).copy()
            if np.any(self._max_hold <= 0.0):
                raise ValueError("max_hold_s must be positive")
        if envelope_w is None:
            self._env_lo = np.full(n, -np.inf)
            self._env_hi = np.full(n, np.inf)
        else:
            lo, hi = envelope_w
            self._env_lo = np.broadcast_to(
                np.asarray(lo, dtype=np.float64), (n,)).copy()
            self._env_hi = np.broadcast_to(
                np.asarray(hi, dtype=np.float64), (n,)).copy()

        self.state = DeviceState.zeros(n)
        self.ring = IngestBuffer(n, ring_slots)
        self.periods = OnlinePeriodEstimator(n, n_bins=period_bins,
                                             min_runs=min_runs)
        # windows disabled until registered: [+inf, -inf] selects nothing
        self._win_a = np.full(n, np.inf)
        self._win_b = np.full(n, -np.inf)

        self.silent_after_s = silent_after_s
        self.drift_tau_s = float(drift_tau_s)
        self.drift_rel = float(drift_rel)
        self.drift_abs_w = float(drift_abs_w)
        self._moments: Dict[str, StreamingMoments] = {}
        self._n_invalid = 0

    # -- configuration ----------------------------------------------------
    def set_windows(self, a, b) -> None:
        """Register per-device measurement windows ``[a_i, b_i]`` (the §5
        execution windows — e.g. each device's workload span).  Window
        energy accumulates sample-by-sample, so windows must be set
        before the first sample arrives."""
        if int(np.sum(self.state.n_samples)) > 0:
            raise RuntimeError("windows must be registered before the "
                               "first ingest (accumulation is not "
                               "retroactive)")
        n = self.n_devices
        a = np.broadcast_to(np.asarray(a, dtype=np.float64), (n,)).copy()
        b = np.broadcast_to(np.asarray(b, dtype=np.float64), (n,)).copy()
        self._win_a, self._win_b = a, b

    def nbytes(self) -> int:
        """Approximate resident size of the monitor state (the memory
        that scales with fleet size)."""
        return (self.state.nbytes() + self.ring.nbytes()
                + self.periods.nbytes())

    # -- ingestion --------------------------------------------------------
    def ingest(self, dev, t, v) -> IngestReport:
        """Fold one slab of raw poll samples into the online state.

        ``dev`` [K] int device ids, ``t`` [K] sample times, ``v`` [K]
        raw readings — any order, duplicates and late samples tolerated
        (dropped and counted).  Returns an :class:`IngestReport`.
        """
        dev = np.asarray(dev, dtype=np.int64).ravel()
        t = np.asarray(t, dtype=np.float64).ravel()
        v = np.asarray(v, dtype=np.float64).ravel()
        if not (dev.shape == t.shape == v.shape):
            raise ValueError(f"shape mismatch: dev {dev.shape}, "
                             f"t {t.shape}, v {v.shape}")
        if dev.size and (dev.min() < 0 or dev.max() >= self.n_devices):
            raise ValueError("device id out of range")
        k_in = dev.size
        if k_in == 0:
            return IngestReport(0, 0, 0, 0, 0)

        ok = np.isfinite(t) & np.isfinite(v)
        n_invalid = int(k_in - ok.sum())
        if n_invalid:
            self._n_invalid += n_invalid
            dev, t, v = dev[ok], t[ok], v[ok]

        order = np.lexsort((t, dev))
        dev, t, v = dev[order], t[order], v[order]

        # duplicates: same (device, t) — keep the first arrival
        dup = np.zeros(len(dev), dtype=bool)
        dup[1:] = (dev[1:] == dev[:-1]) & (t[1:] == t[:-1])
        st = self.state
        # vs stored state: strictly older samples arrive late, a repeat
        # of the newest timestamp is a duplicate
        late = ~dup & st.has[dev] & (t < st.last_t[dev])
        dup_state = ~dup & st.has[dev] & (t == st.last_t[dev])
        n_dup = int(np.sum(dup | dup_state))
        n_late = int(np.sum(late))
        if n_dup:
            np.add.at(st.n_dup, dev[dup | dup_state], 1)
        if n_late:
            np.add.at(st.n_late, dev[late], 1)
        keep = ~(dup | dup_state | late)
        dev, t, v = dev[keep], t[keep], v[keep]
        k = dev.size
        if k == 0:
            return IngestReport(0, n_dup, n_late, n_invalid, 0)

        v = v - self.corrections.baseline_w[dev]

        # compact to per-slab groups (devices sorted => contiguous)
        first = np.empty(k, dtype=bool)
        first[0] = True
        first[1:] = dev[1:] != dev[:-1]
        start_idx = np.flatnonzero(first)
        end_idx = np.concatenate([start_idx[1:] - 1, [k - 1]])
        u_dev = dev[start_idx]
        seg = np.cumsum(first) - 1

        had = st.has[u_dev]
        c = self.corrections
        run_t_in = np.where(had, st.run_t[u_dev], t[start_idx])
        (new_t, new_v, new_run_t, new_nchg, counts, d_e, d_ec, d_w, d_wc,
         sum_vc, n_out, cum_e, cum_ec, vc, run_dur, run_rec) = \
            self._be.stream_ingest(
                t, v, seg, first, start_idx, end_idx,
                st.last_t[u_dev], st.last_v[u_dev], had,
                run_t_in, st.n_changes[u_dev],
                c.gain[u_dev], c.offset_w[u_dev], c.time_shift_s[u_dev],
                self._win_a[u_dev], self._win_b[u_dev],
                self._max_hold[u_dev], self._env_lo[u_dev],
                self._env_hi[u_dev], self.trapezoid)

        # ring snapshots see running totals *before* this slab is folded
        if self.ring.slots:
            ordinal = np.arange(k) - start_idx[seg]
            self.ring.write(dev, ordinal, counts[seg], t, v,
                            st.energy_j[u_dev][seg] + cum_e,
                            st.energy_corr_j[u_dev][seg] + cum_ec,
                            u_dev, counts)
        else:
            self.ring.n_written[u_dev] += counts

        old_last_t = st.last_t[u_dev]
        st.first_t[u_dev] = np.where(had, st.first_t[u_dev], t[start_idx])
        st.last_t[u_dev] = new_t
        st.last_v[u_dev] = new_v
        st.has[u_dev] = True
        st.n_samples[u_dev] += counts
        st.energy_j[u_dev] += d_e
        st.energy_corr_j[u_dev] += d_ec
        st.win_j[u_dev] += d_w
        st.win_corr_j[u_dev] += d_wc
        st.run_t[u_dev] = new_run_t
        st.n_changes[u_dev] = new_nchg
        st.n_out[u_dev] += n_out

        # drift EWMA over wall time, one slab-mean step per device
        mean_vc = sum_vc / counts
        alpha = np.exp(-np.maximum(new_t - old_last_t, 0.0)
                       / self.drift_tau_s)
        st.ewma_w[u_dev] = np.where(
            had, alpha * st.ewma_w[u_dev] + (1.0 - alpha) * mean_vc,
            mean_vc)

        rec = np.asarray(run_rec, dtype=bool)
        if np.any(rec):
            self.periods.record(dev[rec], np.asarray(run_dur)[rec])

        # per-label corrected-reading moments (Chan–Welford): one
        # bincount pass over the slab, O(K + labels) — no per-label
        # masks, so per-device labels stay cheap at fleet scale
        codes = self._label_codes[dev]
        nl = len(self._label_names)
        cnt = np.bincount(codes, minlength=nl)
        s1 = np.bincount(codes, weights=vc, minlength=nl)
        s2 = np.bincount(codes, weights=vc * vc, minlength=nl)
        av = np.abs(vc)
        sa = np.bincount(codes, weights=av, minlength=nl)
        mx = np.zeros(nl)
        np.maximum.at(mx, codes, av)
        for ci in np.flatnonzero(cnt):
            nb = int(cnt[ci])
            mean = s1[ci] / nb
            m2 = max(float(s2[ci] - nb * mean * mean), 0.0)
            self._moments.setdefault(
                self._label_names[ci], StreamingMoments()).merge(
                    nb, float(mean), m2, float(sa[ci] / nb),
                    float(mx[ci]))

        return IngestReport(k, n_dup, n_late, n_invalid, len(u_dev))

    def ingest_grid(self, dev, ts, vals) -> IngestReport:
        """Fold one *rectangular* slab: ``dev`` [D] distinct ascending
        device ids, ``ts`` [M] strictly-increasing sample times shared by
        every device, ``vals`` [D, M] raw readings.

        This is the clean-stream fast path: no sorting, no per-sample
        scatter — the backend's ``stream_ingest_grid`` kernel does
        row-wise cumsums and reductions over the [D, M] slab directly.
        Slabs that violate the rectangular contract (unsorted ids or
        times, non-finite readings, samples at/behind a device's newest
        accepted sample) fall back to the general :meth:`ingest` path
        with identical semantics.
        """
        dev = np.asarray(dev, dtype=np.int64).ravel()
        ts = np.asarray(ts, dtype=np.float64).ravel()
        vals = np.asarray(vals, dtype=np.float64)
        d, m = dev.size, ts.size
        if vals.shape != (d, m):
            raise ValueError(f"vals must be [{d}, {m}], "
                             f"got {vals.shape}")
        if d == 0 or m == 0:
            return IngestReport(0, 0, 0, 0, 0)
        if dev.min() < 0 or dev.max() >= self.n_devices:
            raise ValueError("device id out of range")

        st = self.state
        clean = (np.all(np.diff(dev) > 0)
                 and np.all(np.diff(ts) > 0)
                 and bool(np.all(np.isfinite(ts)))
                 and bool(np.all(np.isfinite(vals)))
                 and not np.any(st.has[dev] & (ts[0] <= st.last_t[dev])))
        if not clean:
            return self.ingest(np.repeat(dev, m), np.tile(ts, d),
                               vals.ravel())

        c = self.corrections
        v = vals - c.baseline_w[dev][:, None]
        had = st.has[dev]
        run_t_in = np.where(had, st.run_t[dev], ts[0])
        (new_v, new_run_t, new_nchg, d_e, d_ec, d_w, d_wc,
         sum_vc, sum_vc2, sum_abs_vc, max_abs_vc, n_out,
         cum_e, cum_ec, run_dur, run_rec) = \
            self._be.stream_ingest_grid(
                ts, v, st.last_t[dev], st.last_v[dev], had, run_t_in,
                st.n_changes[dev], c.gain[dev], c.offset_w[dev],
                c.time_shift_s[dev], self._win_a[dev], self._win_b[dev],
                self._max_hold[dev], self._env_lo[dev],
                self._env_hi[dev], self.trapezoid)

        # ring snapshots see running totals *before* this slab is folded
        if self.ring.slots:
            self.ring.write_grid(dev, ts, v,
                                 st.energy_j[dev][:, None] + cum_e,
                                 st.energy_corr_j[dev][:, None] + cum_ec)
        else:
            self.ring.n_written[dev] += m

        old_last_t = st.last_t[dev]
        st.first_t[dev] = np.where(had, st.first_t[dev], ts[0])
        st.last_t[dev] = ts[-1]
        st.last_v[dev] = new_v
        st.has[dev] = True
        st.n_samples[dev] += m
        st.energy_j[dev] += d_e
        st.energy_corr_j[dev] += d_ec
        st.win_j[dev] += d_w
        st.win_corr_j[dev] += d_wc
        st.run_t[dev] = new_run_t
        st.n_changes[dev] = new_nchg
        st.n_out[dev] += n_out

        mean_vc = sum_vc / m
        alpha = np.exp(-np.maximum(ts[-1] - old_last_t, 0.0)
                       / self.drift_tau_s)
        st.ewma_w[dev] = np.where(
            had, alpha * st.ewma_w[dev] + (1.0 - alpha) * mean_vc,
            mean_vc)

        rec = np.asarray(run_rec, dtype=bool)
        if np.any(rec):
            dgrid = np.broadcast_to(dev[:, None], rec.shape)
            self.periods.record(dgrid[rec], np.asarray(run_dur)[rec])

        # per-label moments straight from the kernel's per-device
        # reductions — O(D + labels) instead of O(D·M)
        codes = self._label_codes[dev]
        nl = len(self._label_names)
        cnt = m * np.bincount(codes, minlength=nl)
        s1 = np.bincount(codes, weights=sum_vc, minlength=nl)
        s2 = np.bincount(codes, weights=sum_vc2, minlength=nl)
        sa = np.bincount(codes, weights=sum_abs_vc, minlength=nl)
        mx = np.zeros(nl)
        np.maximum.at(mx, codes, max_abs_vc)
        for ci in np.flatnonzero(cnt):
            nb = int(cnt[ci])
            mean = s1[ci] / nb
            m2 = max(float(s2[ci] - nb * mean * mean), 0.0)
            self._moments.setdefault(
                self._label_names[ci], StreamingMoments()).merge(
                    nb, float(mean), m2, float(sa[ci] / nb),
                    float(mx[ci]))

        return IngestReport(d * m, 0, 0, 0, d)

    # -- queries ----------------------------------------------------------
    def _tail_energy(self, tq: np.ndarray, corrected: bool):
        """Energy at ``tq`` ([N]) for ``tq`` at/after each device's newest
        sample; (values, valid) — valid False where ``tq`` is in the
        past (needs the ring) or the device never reported."""
        st = self.state
        c = self.corrections
        if corrected:
            base = st.energy_corr_j
            dens = (st.last_v - c.offset_w) / c.gain
        else:
            base = st.energy_j
            dens = st.last_v
        dt = tq - st.last_t
        hold = np.minimum(dt, self._max_hold)
        valid = st.has & (dt >= 0.0)
        return np.where(valid, base + dens * hold, 0.0), valid

    def _energy_at(self, tq: np.ndarray, corrected: bool):
        """Energy since first sample at instants ``tq`` [N]; returns
        ``(energy, covered)`` with nan where not covered (instant
        predates ring coverage)."""
        st = self.state
        e_live, live = self._tail_energy(tq, corrected)
        covered = live | ~st.has | (tq <= st.first_t)
        e = np.where(st.has & (tq > st.first_t), e_live, 0.0)
        past = st.has & (tq < st.last_t) & (tq > st.first_t)
        if np.any(past) and self.ring.slots:
            ts, vs, er, ec = self.ring.sorted_view()
            j = searchsorted_rows(ts, tq[:, None], "right")[:, 0] - 1
            ok = j >= 0
            jc = np.clip(j, 0, self.ring.slots - 1)[:, None]
            rt = np.take_along_axis(ts, jc, axis=1)[:, 0]
            rv = np.take_along_axis(vs, jc, axis=1)[:, 0]
            re_ = np.take_along_axis(ec if corrected else er, jc,
                                     axis=1)[:, 0]
            if corrected:
                rv = (rv - self.corrections.offset_w) / self.corrections.gain
            hold = np.minimum(tq - rt, self._max_hold)
            e_past = re_ + rv * hold
            sel = past & ok
            e = np.where(sel, e_past, e)
            covered = covered | sel
        return np.where(covered, e, np.nan), covered

    def fleet_energy(self, t: Optional[float] = None,
                     corrected: bool = True) -> FleetEnergy:
        """Running fleet energy at wall-clock ``t`` (default: each
        device's newest sample — no extrapolation), with the telemetry
        uncertainty bounds."""
        from repro.core.telemetry import (CALIBRATED_TOLERANCE,
                                          SHUNT_TOLERANCE)
        st = self.state
        if t is None:
            e = (st.energy_corr_j if corrected else st.energy_j).copy()
            covered = np.ones(self.n_devices, dtype=bool)
        else:
            tq = np.full(self.n_devices, float(t))
            e, covered = self._energy_at(tq, corrected)
        tol = np.where(self.corrections.calibrated,
                       CALIBRATED_TOLERANCE, SHUNT_TOLERANCE)
        sig = np.where(covered, tol * np.abs(np.nan_to_num(e)), 0.0)
        total = float(np.nansum(np.where(covered, e, 0.0)))
        return FleetEnergy(
            t=t, corrected=corrected, per_device_j=e, covered=covered,
            total_j=total, n_reporting=int(np.sum(st.has)),
            sigma_independent_j=float(np.sqrt(np.sum(sig ** 2))),
            sigma_worstcase_j=float(np.sum(sig)))

    def window_energy(self, t: Optional[float] = None,
                      corrected: bool = True) -> np.ndarray:
        """Per-device energy clipped to the registered §5 windows [N].

        With ``t`` given, devices whose window is still open get the live
        rectangle tail up to ``min(t, b)``; with ``t=None`` the
        accumulated value is returned as-is (exact once the stream has
        passed each window's end).  Window accumulation cannot be
        rewound: a query instant that a device's still-open window has
        already streamed past reports nan for that device rather than
        silently overstating."""
        st = self.state
        c = self.corrections
        e = (st.win_corr_j if corrected else st.win_j).copy()
        if t is None:
            return e
        shift = c.time_shift_s if corrected else 0.0
        t_rep = st.last_t - shift       # newest sample, reported time
        tq = float(t) - shift           # query instant, reported time
        dens = ((st.last_v - c.offset_w) / c.gain if corrected
                else st.last_v)
        lim = np.minimum(tq, np.minimum(self._win_b,
                                        t_rep + self._max_hold))
        tail = np.where(st.has & (t_rep >= self._win_a),
                        dens * np.maximum(lim - t_rep, 0.0), 0.0)
        # accumulated-through-b is exact once the window closed; an
        # open window already streamed past tq is not reconstructible
        stale = (st.has & (tq < t_rep) & (tq < self._win_b)
                 & (tq > self._win_a))
        out = np.where(stale, np.nan, e + tail)
        # before the window opens the exact answer is 0, whatever has
        # accumulated since
        return np.where(st.has & (tq <= self._win_a), 0.0, out)

    def energy_between(self, t0: float, t1: float,
                       corrected: bool = True):
        """Windowed energy ``∫[t0, t1]`` per device from the ring buffer;
        returns ``(energy, covered)``.  Held-value semantics (the value
        at ``t0`` is the sample covering it); exact whenever both
        endpoints lie within ring coverage, nan otherwise."""
        if not (t1 >= t0):
            raise ValueError(f"bad window [{t0}, {t1}]")
        n = self.n_devices
        e1, c1 = self._energy_at(np.full(n, float(t1)), corrected)
        e0, c0 = self._energy_at(np.full(n, float(t0)), corrected)
        covered = c0 & c1
        return np.where(covered, e1 - e0, np.nan), covered

    def by_label(self, t0: Optional[float] = None,
                 t1: Optional[float] = None,
                 corrected: bool = True) -> Dict[str, Dict[str, float]]:
        """Energy breakdown by workload label — over ``[t0, t1]`` (ring
        coverage permitting) or since stream start.  Each label reports
        its covered-device count, total energy and the Chan–Welford
        moments of the per-device energies."""
        if (t0 is None) != (t1 is None):
            raise ValueError("pass both t0 and t1, or neither")
        if t0 is None:
            st = self.state
            e = (st.energy_corr_j if corrected else st.energy_j)
            covered = st.has.copy()
        else:
            e, covered = self.energy_between(t0, t1, corrected)
            covered = covered & self.state.has
        out: Dict[str, Dict[str, float]] = {}
        for label in np.unique(self.labels):
            sel = (self.labels == label) & covered
            vals = e[sel]
            sm = StreamingMoments().update(vals, self._be)
            stats = sm.stats()
            out[str(label)] = {
                "n_devices": int(np.sum(self.labels == label)),
                "n_covered": int(np.sum(sel)),
                "total_j": float(np.sum(vals)) if vals.size else 0.0,
                "mean_j": stats["mean_err"],
                "std_j": stats["std_err"],
            }
        return out

    def reading_stats(self) -> Dict[str, Dict[str, float]]:
        """Per-label corrected-reading moments accumulated at ingest
        (``StreamingMoments`` — mean/std/worst in watts)."""
        return {label: sm.stats()
                for label, sm in sorted(self._moments.items())}

    def update_period_s(self) -> np.ndarray:
        """[N] online update-period estimates (nan until a device has
        published ``min_runs`` complete runs)."""
        return self.periods.estimates()

    def flags(self, t: Optional[float] = None) -> Dict[str, np.ndarray]:
        """Per-device health flags at wall-clock ``t`` (default: the
        newest sample seen fleet-wide).

        * ``silent`` — no sample for longer than ``silent_after_s``
          (default 5× the device's update period — online estimate when
          converged, calibration reference otherwise);
        * ``anomalous`` — published readings outside the calibrated
          envelope;
        * ``drifting`` — the recent EWMA of corrected readings diverges
          from the device's lifetime mean corrected power;
        * ``reporting`` — has ever reported.
        """
        st = self.state
        if t is None:
            t = float(np.max(st.last_t[st.has])) if np.any(st.has) else 0.0
        that = self.periods.estimates()
        ref = np.where(np.isfinite(that), that,
                       self.corrections.ref_period_s)
        after = (np.full(self.n_devices, float(self.silent_after_s))
                 if self.silent_after_s is not None else 5.0 * ref)
        silent = st.has & (t - st.last_t > after)
        dur = st.last_t - st.first_t
        with np.errstate(invalid="ignore", divide="ignore"):
            mean_p = np.where(dur > 0.0, st.energy_corr_j / dur, np.nan)
        dev = np.abs(st.ewma_w - mean_p)
        drifting = (st.has & (dur > 2.0 * self.drift_tau_s)
                    & (dev > np.maximum(self.drift_rel * np.abs(mean_p),
                                        self.drift_abs_w)))
        return {
            "reporting": st.has.copy(),
            "silent": silent,
            "anomalous": st.n_out > 0,
            "drifting": np.where(np.isfinite(mean_p), drifting, False),
        }

    @property
    def counters(self) -> Dict[str, int]:
        st = self.state
        return {
            "accepted": int(np.sum(st.n_samples)),
            "duplicates": int(np.sum(st.n_dup)),
            "late": int(np.sum(st.n_late)),
            "invalid": self._n_invalid,
            "devices_reporting": int(np.sum(st.has)),
        }
