"""Immutable, epoch-tagged published views of the streaming monitor.

:class:`MonitorSnapshot` is the read side of the ingest/serve split: a
compact copy-on-write capture of everything queries need — the
:class:`~repro.core.stream.state.DeviceState` accumulators, the ring
buffer *pre-sorted* per device, the online period estimates, per-label
moments and ingestion counters — published at a slab boundary and never
mutated again (every captured array is marked read-only; writing to one
raises).  Readers therefore never touch mutable ingest state: a held
snapshot keeps answering bitwise-identically while ingestion races
ahead, and the :attr:`epoch` tag makes results cacheable by
``(query, epoch)``.

All query semantics live here (the façade
:class:`~repro.core.stream.monitor.MonitorService` delegates).  Query
edge contract, pinned by ``tests/test_serving.py``:

* ``energy_between(t0, t1)`` raises ``ValueError`` unless
  ``t0 <= t1`` (NaN endpoints included); ``t0 == t1`` is exact zero
  wherever covered.
* Instants beyond the ring horizon (older than the oldest retained
  sample of a reporting device) answer ``nan`` with ``covered=False``
  — never a silently-wrong number.
* ``by_label`` groups with no covered device report ``mean_j``/
  ``std_j`` of ``nan`` (and ``total_j`` 0.0) — including every group of
  a never-ingested monitor.

The batched entry points (:meth:`energy_at_batch`,
:meth:`window_energy_batch`) answer ``Q`` instants for all ``N``
devices as one array op — the substrate of the
:class:`~repro.serve.monitor_service.MonitorQueryService` executor —
and are elementwise-identical to the single-instant paths (the scalar
methods are the ``Q=1`` case of the same kernel).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.engine_backend import numpy_backend as _nb
from repro.core.fleet_engine import StreamingMoments
from repro.core.stream.health import QUARANTINED, STALE
from repro.core.stream.state import DeviceState


@dataclasses.dataclass(frozen=True)
class FleetEnergy:
    """A fleet-energy query answer with uncertainty bounds.

    ``per_device_j`` is nan where ``covered`` is False (the query instant
    predates the device's ring-buffer coverage); totals and sigmas are
    over covered devices only.  Uncertainty follows the telemetry
    model: per-device sigma is the shunt tolerance of the energy
    (calibrated devices use the calibrated floor), aggregated both as
    independent (1/√N) and worst-case (correlated lot) bounds.

    Degraded-mode accounting (monitors with health tracking): devices
    quarantined by the health machine are excluded from ``total_j`` and
    the sigmas (their ``per_device_j`` rows remain visible), the sigma
    bounds are widened by the covered-but-excluded fraction
    (``× n_covered / n_included`` — the monitor's honest admission that
    it is extrapolating over silent/anomalous devices), and ``coverage``
    reports the included fraction of the fleet so a reader can tell a
    confident answer from a degraded one.  Without health tracking
    ``coverage`` is simply the covered fraction and ``n_quarantined``
    is 0.
    """

    t: Optional[float]
    corrected: bool
    per_device_j: np.ndarray
    covered: np.ndarray
    total_j: float
    n_reporting: int
    sigma_independent_j: float
    sigma_worstcase_j: float
    coverage: float = 1.0
    n_quarantined: int = 0


def _frozen(arr: np.ndarray) -> np.ndarray:
    out = arr.copy()
    out.setflags(write=False)
    return out


def _copy_moments(sm: StreamingMoments) -> StreamingMoments:
    out = StreamingMoments()
    out.n, out.mean, out.m2 = sm.n, sm.mean, sm.m2
    out.mean_abs, out.max_abs = sm.mean_abs, sm.max_abs
    return out


class MonitorSnapshot:
    """One immutable published view of a monitor (see module doc).

    Build with :meth:`publish`; the constructor is internal.
    """

    def __init__(self, *, epoch, n_devices, backend, be, state, ring_view,
                 ring_slots, period_est, moments, counters, corrections,
                 labels, win_a, win_b, max_hold, silent_after_s,
                 drift_tau_s, drift_rel, drift_abs_w, health_code=None):
        self.epoch = epoch
        self.n_devices = n_devices
        self.backend = backend
        self._be = be
        self.state = state
        self._ring_view = ring_view          # (t, v, e_raw, e_corr) or None
        self.ring_slots = ring_slots
        self._period_est = period_est
        self._moments = moments
        self._counters = counters
        self.corrections = corrections
        self.labels = labels
        self._win_a = win_a
        self._win_b = win_b
        self._max_hold = max_hold
        self.silent_after_s = silent_after_s
        self.drift_tau_s = drift_tau_s
        self.drift_rel = drift_rel
        self.drift_abs_w = drift_abs_w
        self._health_code = health_code      # [N] i1 codes or None
        self._flavor_cache: Dict[bool, tuple] = {}

    @classmethod
    def publish(cls, core) -> "MonitorSnapshot":
        """Capture a copy-on-write view of an
        :class:`~repro.core.stream.ingest.IngestCore` at its current
        epoch.  The ring is captured already sorted oldest→newest (one
        gather here instead of one per query)."""
        st = core.state
        state = DeviceState(**{
            f.name: _frozen(getattr(st, f.name))
            for f in dataclasses.fields(DeviceState)})
        ring_view = None
        if core.ring.slots:
            ring_view = tuple(_frozen(a) for a in core.ring.sorted_view())
        return cls(
            epoch=core.epoch, n_devices=core.n_devices,
            backend=core.backend, be=core._be, state=state,
            ring_view=ring_view, ring_slots=core.ring.slots,
            period_est=_frozen(core.periods.estimates()),
            moments={k: _copy_moments(v) for k, v in core._moments.items()},
            counters=dict(core.counters),
            corrections=core.corrections, labels=_frozen(core.labels),
            win_a=_frozen(core._win_a), win_b=_frozen(core._win_b),
            max_hold=_frozen(core._max_hold),
            silent_after_s=core.silent_after_s,
            drift_tau_s=core.drift_tau_s, drift_rel=core.drift_rel,
            drift_abs_w=core.drift_abs_w,
            health_code=(_frozen(core.health.code)
                         if core.health is not None else None))

    # -- batched kernels --------------------------------------------------
    def _flavor(self, corrected: bool):
        """Per-flavour (raw/corrected) tail + ring arrays for the
        snapshot-view kernel, computed once per snapshot."""
        if corrected not in self._flavor_cache:
            st, c = self.state, self.corrections
            if corrected:
                dens = (st.last_v - c.offset_w) / c.gain
                base = st.energy_corr_j
            else:
                dens = st.last_v
                base = st.energy_j
            if self._ring_view is not None:
                ts, vs, er, ec = self._ring_view
                if corrected:
                    ring_dens = (vs - c.offset_w[:, None]) / c.gain[:, None]
                    ring_base = ec
                else:
                    ring_dens, ring_base = vs, er
            else:
                ts = ring_dens = ring_base = None
            self._flavor_cache[corrected] = (dens, base, ts, ring_dens,
                                             ring_base)
        return self._flavor_cache[corrected]

    def energy_at_batch(self, tq: np.ndarray, corrected: bool = True
                        ) -> Tuple[np.ndarray, np.ndarray]:
        """Energy since first sample at instants ``tq`` [Q] for every
        device: ``(e, covered)`` [Q, N], nan where an instant predates
        ring coverage."""
        tq = np.asarray(tq, dtype=np.float64).ravel()
        st = self.state
        dens, base, ring_t, ring_dens, ring_base = self._flavor(corrected)
        kernel = getattr(self._be, "snapshot_energy_at",
                         _nb.snapshot_energy_at)
        return kernel(tq, st.last_t, dens, st.has, st.first_t, base,
                      self._max_hold, ring_t, ring_dens, ring_base)

    def window_energy_batch(self, tq: np.ndarray, corrected: bool = True
                            ) -> np.ndarray:
        """Registered-window energy at instants ``tq`` [Q] → [Q, N]
        (same open-window semantics as :meth:`window_energy`)."""
        tq = np.asarray(tq, dtype=np.float64).ravel()
        st, c = self.state, self.corrections
        e = (st.win_corr_j if corrected else st.win_j)[None, :]
        shift = c.time_shift_s if corrected else 0.0
        t_rep = st.last_t - shift       # newest sample, reported time
        tqs = tq[:, None] - shift       # query instants, reported time
        dens = ((st.last_v - c.offset_w) / c.gain if corrected
                else st.last_v)
        lim = np.minimum(tqs, np.minimum(self._win_b,
                                         t_rep + self._max_hold)[None, :])
        tail = np.where(st.has[None, :] & (t_rep >= self._win_a)[None, :],
                        dens[None, :] * np.maximum(lim - t_rep[None, :],
                                                   0.0), 0.0)
        # accumulated-through-b is exact once the window closed; an
        # open window already streamed past tq is not reconstructible
        stale = (st.has[None, :] & (tqs < t_rep[None, :])
                 & (tqs < self._win_b[None, :]) & (tqs > self._win_a[None, :]))
        out = np.where(stale, np.nan, e + tail)
        # before the window opens the exact answer is 0, whatever has
        # accumulated since
        return np.where(st.has[None, :] & (tqs <= self._win_a[None, :]),
                        0.0, out)

    # -- result assembly (shared with the batched executor) ---------------
    @property
    def active_mask(self) -> Optional[np.ndarray]:
        """[N] bool, False where the health machine quarantined the
        device — or None when health tracking is off."""
        if self._health_code is None:
            return None
        return self._health_code != QUARANTINED

    def fleet_from_rows(self, t: Optional[float], corrected: bool,
                        e: np.ndarray, covered: np.ndarray) -> FleetEnergy:
        """Fold one [N] energy row into a :class:`FleetEnergy` (the
        reductions both the direct and the batched-executor paths use).
        See :class:`FleetEnergy` for the degraded-mode exclusion and
        sigma-widening semantics on health-tracked monitors."""
        from repro.core.telemetry import (CALIBRATED_TOLERANCE,
                                          SHUNT_TOLERANCE)
        tol = np.where(self.corrections.calibrated,
                       CALIBRATED_TOLERANCE, SHUNT_TOLERANCE)
        active = self.active_mask
        if active is None:
            include, n_q = covered, 0
        else:
            include = covered & active
            n_q = int(np.sum(covered & ~active))
        sig = np.where(include, tol * np.abs(np.nan_to_num(e)), 0.0)
        total = float(np.nansum(np.where(include, e, 0.0)))
        n_inc = int(np.sum(include))
        if n_q == 0:
            si = float(np.sqrt(np.sum(sig ** 2)))
            sw = float(np.sum(sig))
        elif n_inc:
            widen = (n_inc + n_q) / n_inc
            si = float(widen * np.sqrt(np.sum(sig ** 2)))
            sw = float(widen * np.sum(sig))
        else:               # every covered device quarantined: the
            si = sw = np.inf        # answer carries no information
        return FleetEnergy(
            t=t, corrected=corrected, per_device_j=e, covered=covered,
            total_j=total, n_reporting=int(np.sum(self.state.has)),
            sigma_independent_j=si, sigma_worstcase_j=sw,
            coverage=n_inc / self.n_devices, n_quarantined=n_q)

    @staticmethod
    def between_from_rows(e0, c0, e1, c1) -> Tuple[np.ndarray, np.ndarray]:
        covered = c0 & c1
        return np.where(covered, e1 - e0, np.nan), covered

    # -- queries ----------------------------------------------------------
    def fleet_energy(self, t: Optional[float] = None,
                     corrected: bool = True) -> FleetEnergy:
        """Running fleet energy at wall-clock ``t`` (default: each
        device's newest sample — no extrapolation), with the telemetry
        uncertainty bounds."""
        st = self.state
        if t is None:
            e = (st.energy_corr_j if corrected else st.energy_j).copy()
            covered = np.ones(self.n_devices, dtype=bool)
        else:
            em, cm = self.energy_at_batch(np.array([float(t)]), corrected)
            e, covered = em[0], cm[0]
        return self.fleet_from_rows(t, corrected, e, covered)

    def window_energy(self, t: Optional[float] = None,
                      corrected: bool = True) -> np.ndarray:
        """Per-device energy clipped to the registered §5 windows [N].

        With ``t`` given, devices whose window is still open get the live
        rectangle tail up to ``min(t, b)``; with ``t=None`` the
        accumulated value is returned as-is (exact once the stream has
        passed each window's end).  Window accumulation cannot be
        rewound: a query instant that a device's still-open window has
        already streamed past reports nan for that device rather than
        silently overstating."""
        st = self.state
        if t is None:
            return (st.win_corr_j if corrected else st.win_j).copy()
        return self.window_energy_batch(np.array([float(t)]), corrected)[0]

    def energy_between(self, t0: float, t1: float,
                       corrected: bool = True):
        """Windowed energy ``∫[t0, t1]`` per device from the ring buffer;
        returns ``(energy, covered)``.  Held-value semantics (the value
        at ``t0`` is the sample covering it); exact whenever both
        endpoints lie within ring coverage, nan otherwise.  Raises
        ``ValueError`` unless ``t0 <= t1`` (NaN endpoints included);
        ``t0 == t1`` is exactly zero wherever covered."""
        if not (t1 >= t0):
            raise ValueError(f"bad window [{t0}, {t1}]")
        em, cm = self.energy_at_batch(
            np.array([float(t0), float(t1)]), corrected)
        return self.between_from_rows(em[0], cm[0], em[1], cm[1])

    def by_label(self, t0: Optional[float] = None,
                 t1: Optional[float] = None,
                 corrected: bool = True) -> Dict[str, Dict[str, float]]:
        """Energy breakdown by workload label — over ``[t0, t1]`` (ring
        coverage permitting) or since stream start.  Each label reports
        its covered-device count, total energy and the Chan–Welford
        moments of the per-device energies; groups with no covered
        device (including every group of a never-ingested monitor)
        report nan moments.  On health-tracked monitors quarantined
        devices are excluded from every aggregate (and reported per
        label as ``n_quarantined``, 0 otherwise) — the per-label
        counterpart of :class:`FleetEnergy`'s degraded mode."""
        if (t0 is None) != (t1 is None):
            raise ValueError("pass both t0 and t1, or neither")
        st = self.state
        if t0 is None:
            e = (st.energy_corr_j if corrected else st.energy_j)
            covered = st.has.copy()
        else:
            e, covered = self.energy_between(t0, t1, corrected)
            covered = covered & st.has
        active = self.active_mask
        out: Dict[str, Dict[str, float]] = {}
        for label in np.unique(self.labels):
            sel = (self.labels == label) & covered
            n_q = 0
            if active is not None:
                n_q = int(np.sum(sel & ~active))
                sel = sel & active
            vals = e[sel]
            sm = StreamingMoments().update(vals, self._be)
            stats = sm.stats()
            n_cov = int(np.sum(sel))
            out[str(label)] = {
                "n_devices": int(np.sum(self.labels == label)),
                "n_covered": n_cov,
                "n_quarantined": n_q,
                "total_j": float(np.sum(vals)) if vals.size else 0.0,
                "mean_j": stats["mean_err"] if n_cov else float("nan"),
                "std_j": stats["std_err"] if n_cov else float("nan"),
            }
        return out

    def reading_stats(self) -> Dict[str, Dict[str, float]]:
        """Per-label corrected-reading moments accumulated at ingest
        (``StreamingMoments`` — mean/std/worst in watts)."""
        return {label: sm.stats()
                for label, sm in sorted(self._moments.items())}

    def update_period_s(self) -> np.ndarray:
        """[N] online update-period estimates (nan until a device has
        published ``min_runs`` complete runs)."""
        return self._period_est.copy()

    def flags(self, t: Optional[float] = None) -> Dict[str, np.ndarray]:
        """Per-device health flags at wall-clock ``t`` (default: the
        newest sample seen fleet-wide).

        * ``silent`` — no sample for longer than ``silent_after_s``
          (default 5× the device's update period — online estimate when
          converged, calibration reference otherwise);
        * ``anomalous`` — published readings outside the calibrated
          envelope;
        * ``drifting`` — the recent EWMA of corrected readings diverges
          from the device's lifetime mean corrected power;
        * ``reporting`` — has ever reported;
        * ``stale`` / ``quarantined`` — the health machine's current
          state codes (all-False on monitors without health tracking:
          the instantaneous flags above are always available, the
          stateful machine is opt-in).
        """
        st = self.state
        if t is None:
            t = float(np.max(st.last_t[st.has])) if np.any(st.has) else 0.0
        that = self._period_est
        ref = np.where(np.isfinite(that), that,
                       self.corrections.ref_period_s)
        after = (np.full(self.n_devices, float(self.silent_after_s))
                 if self.silent_after_s is not None else 5.0 * ref)
        silent = st.has & (t - st.last_t > after)
        dur = st.last_t - st.first_t
        with np.errstate(invalid="ignore", divide="ignore"):
            mean_p = np.where(dur > 0.0, st.energy_corr_j / dur, np.nan)
        dev = np.abs(st.ewma_w - mean_p)
        drifting = (st.has & (dur > 2.0 * self.drift_tau_s)
                    & (dev > np.maximum(self.drift_rel * np.abs(mean_p),
                                        self.drift_abs_w)))
        code = self._health_code
        return {
            "reporting": st.has.copy(),
            "silent": silent,
            "anomalous": st.n_out > 0,
            "drifting": np.where(np.isfinite(mean_p), drifting, False),
            "stale": (code == STALE if code is not None
                      else np.zeros(self.n_devices, dtype=bool)),
            "quarantined": (code == QUARANTINED if code is not None
                            else np.zeros(self.n_devices, dtype=bool)),
        }

    def health_summary(self) -> Dict[str, float]:
        """Fleet-level health digest: state-machine population counts
        plus the coverage fraction degraded-mode queries report.  On
        monitors without health tracking every device counts healthy
        and ``tracked`` is False."""
        st = self.state
        n = self.n_devices
        code = self._health_code
        n_stale = int(np.sum(code == STALE)) if code is not None else 0
        n_quar = int(np.sum(code == QUARANTINED)) if code is not None else 0
        return {
            "tracked": code is not None,
            "epoch": int(self.epoch),
            "n_devices": n,
            "n_reporting": int(np.sum(st.has)),
            "n_healthy": n - n_stale - n_quar,
            "n_stale": n_stale,
            "n_quarantined": n_quar,
            "coverage": (n - n_quar) / n,
        }

    @property
    def counters(self) -> Dict[str, int]:
        return dict(self._counters)
