"""Replay drivers: run any fleet as a live poll-sample stream.

:func:`replay` pushes a :class:`~repro.core.fleet_engine.SensorBank`'s
poll grid through a :class:`~repro.core.stream.monitor.MonitorService`
tick by tick, optionally injecting the failure modes a real collection
pipeline produces.  :class:`FaultSpec` is the declarative fault
configuration: the legacy transport knobs (shuffled arrival order,
duplicated / dropped / one-tick-delayed samples) plus the fault-domain
taxonomy — per-device clock drift and skew between device and collector,
collector restarts that black out every device for a moment, corrupt
slabs (garbled values, ids, timestamps), and permanent mid-stream device
dropouts.  :class:`FaultInjector` realises a spec deterministically
(every per-slab decision comes from ``default_rng((seed, slab_seq))``,
so replaying any slab re-produces its faults bit-for-bit) and keeps a
machine-readable :class:`InjectionLog` so any failure reproduces from
the log alone.

``grid=True`` is the *clean-stream* contract: the rectangular fast path
assumes every device shares one strictly-increasing time base, which is
exactly what every fault above destroys — so ``grid=True`` combined
with any active fault raises ``ValueError`` instead of silently
degrading to undefined semantics (``grid=None``, the default, picks the
grid path only when the spec is fault-free).

:func:`stream_fleet` is the end-to-end driver: it builds the same
per-device sensor fleet as :func:`repro.core.fleet_engine.fleet_audit`
(same profiles, seeds, hidden parameters, workload synthesis and attach
geometry), streams it through a monitor in bounded-memory device slabs,
and — with ``compare=True`` — computes the offline
``integrate_polled`` ground truth on the very same reading schedules, so
the stream/offline parity is measured on identical inputs.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Sequence, Union

import numpy as np

from repro.core import load as loads
from repro.core import profiles as _profiles
from repro.core.fleet_engine import SensorBank
from repro.core.meter import Workload, as_workload_set
from repro.core.stream.estimators import (StreamCorrections,
                                          default_calibrations)
from repro.core.stream.monitor import MonitorService

_FRACTIONS = ("dup_fraction", "drop_fraction", "delay_fraction",
              "corrupt_fraction", "dropout_fraction", "dropout_after")
# substream tags for the plan/slab rng derivations (any fixed ints work;
# they only have to differ so plan draws never alias slab draws)
_PLAN_STREAM = 101
_SLAB_STREAM = 202


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Declarative transport/collector fault configuration.

    Legacy transport knobs (identical semantics to the old ``replay``
    keyword arguments):

    * ``shuffle`` — permute each slab's arrival order,
    * ``dup_fraction`` — re-emit that fraction of samples,
    * ``drop_fraction`` — remove samples (sampling gaps),
    * ``delay_fraction`` — hold samples back one slab (arrive late).

    Fault-domain taxonomy:

    * ``clock_drift`` / ``clock_skew_s`` — each device's reported
      timestamps become ``skew_i + (1 + rate_i) · t`` with ``rate_i``
      uniform in ``±clock_drift`` and ``skew_i`` uniform in
      ``±clock_skew_s`` (unsynchronised device/collector clocks),
    * ``restart_every_s`` — collector restarts at exponentially-spaced
      instants; every sample inside the following
      ``restart_blackout_s`` window is lost (slab stream truncated and
      resumed),
    * ``corrupt_fraction`` — that fraction of samples is garbled:
      values to NaN/inf, device ids pushed out of range, timestamps to
      NaN (all detectable, so a defensive ingest rejects and counts
      them; see ``MonitorService(strict_ids=False)``),
    * ``dropout_fraction`` — that fraction of devices dies permanently
      at a uniform instant in the last ``1 - dropout_after`` of the
      replay span and never reports again.

    Everything is seeded and composable; ``FaultInjector`` realises the
    spec with per-slab rng substreams, so any slab's faults reproduce
    independently of how many slabs came before it.
    """

    shuffle: bool = False
    dup_fraction: float = 0.0
    drop_fraction: float = 0.0
    delay_fraction: float = 0.0
    clock_drift: float = 0.0
    clock_skew_s: float = 0.0
    restart_every_s: float = 0.0
    restart_blackout_s: float = 0.05
    corrupt_fraction: float = 0.0
    dropout_fraction: float = 0.0
    dropout_after: float = 0.35
    seed: int = 0

    def __post_init__(self):
        for name in _FRACTIONS:
            f = getattr(self, name)
            if not 0.0 <= f <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {f}")
        if not 0.0 <= self.clock_drift < 1.0:
            raise ValueError("clock_drift must be in [0, 1) — a rate "
                             "error of ±100% would reverse time")
        if self.clock_skew_s < 0.0:
            raise ValueError("clock_skew_s must be >= 0")
        if self.restart_every_s < 0.0 or self.restart_blackout_s < 0.0:
            raise ValueError("restart intervals must be >= 0")

    @property
    def any(self) -> bool:
        """Whether any fault is active (False → clean, grid-eligible)."""
        return bool(self.shuffle or self.dup_fraction or self.drop_fraction
                    or self.delay_fraction or self.clock_drift
                    or self.clock_skew_s or self.restart_every_s
                    or self.corrupt_fraction or self.dropout_fraction)

    def counts_zero(self) -> Dict[str, int]:
        """The all-zero injection-count dict (clean replays report it)."""
        return {k: 0 for k in _COUNT_KEYS}


_COUNT_KEYS = ("dropped_out", "blacked_out", "dropped", "corrupt_value",
               "corrupt_id", "corrupt_time", "duplicated", "delayed",
               "shuffled_slabs")


@dataclasses.dataclass
class InjectionLog:
    """Machine-readable record of every injection decision.

    ``counts`` aggregates per category; ``slabs`` records one dict per
    slab (seq, samples in/out, per-category counts); the plan arrays
    (``drift_rate``/``skew_s`` per device, ``dropout_t`` — ``+inf`` for
    survivors — and collector ``restarts``) fully determine the
    deterministic part.  Together with the spec, the log reproduces the
    exact faulty stream: feed the same spec/span to a fresh
    :class:`FaultInjector` and every decision repeats bit-for-bit.
    """

    spec: FaultSpec
    n_devices: int
    t0: float
    t1: float
    drift_rate: np.ndarray          # [N] per-device clock rate error
    skew_s: np.ndarray              # [N] per-device clock offset
    dropout_t: np.ndarray           # [N] death instant, +inf = never
    restarts: np.ndarray            # [R] collector restart instants
    counts: Dict[str, int] = dataclasses.field(default_factory=dict)
    slabs: list = dataclasses.field(default_factory=list)

    def summary(self) -> dict:
        """JSON-able digest (plan extremes + aggregate counts)."""
        dead = np.flatnonzero(np.isfinite(self.dropout_t))
        return {
            "seed": self.spec.seed,
            "n_devices": self.n_devices,
            "span": [self.t0, self.t1],
            "n_slabs": len(self.slabs),
            "counts": dict(self.counts),
            "restarts": [float(r) for r in self.restarts],
            "dropped_out_devices": [int(d) for d in dead],
            "dropout_t": [float(self.dropout_t[d]) for d in dead],
            "max_abs_drift": float(np.max(np.abs(self.drift_rate),
                                          initial=0.0)),
            "max_abs_skew_s": float(np.max(np.abs(self.skew_s),
                                           initial=0.0)),
        }


class FaultInjector:
    """Realise a :class:`FaultSpec` over a slab stream, deterministically.

    The device-level plan (drift rates, skews, dropout instants, restart
    schedule) is drawn once from ``default_rng((seed, plan))``; every
    per-slab decision comes from ``default_rng((seed, slab, seq))`` — so
    slab ``seq`` injects identical faults no matter how the stream is
    resumed or re-chunked upstream, which is what makes crash-recovery
    replays bitwise comparable to uninterrupted ones.

    ``apply(seq, dev, ts, vs)`` returns the faulted slab; delayed
    samples are held internally and prepended to the next ``apply``;
    call :meth:`flush` after the source is exhausted to collect any
    still-held tail.
    """

    def __init__(self, spec: FaultSpec, n_devices: int,
                 t0: float, t1: float):
        if n_devices < 1:
            raise ValueError("need at least one device")
        self.spec = spec
        self.n_devices = int(n_devices)
        plan = np.random.default_rng((spec.seed, _PLAN_STREAM))
        n = self.n_devices
        drift = (spec.clock_drift * plan.uniform(-1.0, 1.0, n)
                 if spec.clock_drift else np.zeros(n))
        skew = (spec.clock_skew_s * plan.uniform(-1.0, 1.0, n)
                if spec.clock_skew_s else np.zeros(n))
        dropout_t = np.full(n, np.inf)
        if spec.dropout_fraction:
            dead = plan.random(n) < spec.dropout_fraction
            at = plan.uniform(spec.dropout_after, 1.0, n)
            dropout_t[dead] = t0 + at[dead] * (t1 - t0)
        restarts = []
        if spec.restart_every_s:
            t = float(t0)
            while True:
                t += plan.exponential(spec.restart_every_s)
                if t >= t1:
                    break
                restarts.append(t)
        self.log = InjectionLog(
            spec=spec, n_devices=n, t0=float(t0), t1=float(t1),
            drift_rate=drift, skew_s=skew, dropout_t=dropout_t,
            restarts=np.asarray(restarts, dtype=np.float64),
            counts=spec.counts_zero())
        self._held = None

    def reset(self) -> None:
        """Drop any held (delayed) samples, e.g. before re-playing the
        stream from the top; the plan and log are kept."""
        self._held = None

    def apply(self, seq: int, dev, ts, vs):
        """Inject slab ``seq``'s faults; returns ``(dev, ts, vs)``."""
        spec = self.spec
        c = self.log.counts
        rng = np.random.default_rng((spec.seed, _SLAB_STREAM, int(seq)))
        dev = np.asarray(dev, dtype=np.int64).ravel()
        ts = np.asarray(ts, dtype=np.float64).ravel()
        vs = np.asarray(vs, dtype=np.float64).ravel()
        rec = {"seq": int(seq), "in": int(dev.size)}
        # device deaths and collector blackouts act on true (collector)
        # time, before the device clock garbles the reported timestamps
        if spec.dropout_fraction and dev.size:
            alive = ts < self.log.dropout_t[dev]
            k = int(alive.size - alive.sum())
            if k:
                dev, ts, vs = dev[alive], ts[alive], vs[alive]
                c["dropped_out"] += k
                rec["dropped_out"] = k
        if self.log.restarts.size and dev.size:
            black = np.zeros(ts.shape, dtype=bool)
            for r in self.log.restarts:
                black |= (ts >= r) & (ts < r + spec.restart_blackout_s)
            k = int(black.sum())
            if k:
                keep = ~black
                dev, ts, vs = dev[keep], ts[keep], vs[keep]
                c["blacked_out"] += k
                rec["blacked_out"] = k
        if spec.clock_drift or spec.clock_skew_s:
            ts = self.log.skew_s[dev] + (1.0 + self.log.drift_rate[dev]) * ts
        if spec.drop_fraction and dev.size:
            keep = rng.random(dev.size) >= spec.drop_fraction
            k = int(keep.size - keep.sum())
            if k:
                dev, ts, vs = dev[keep], ts[keep], vs[keep]
                c["dropped"] += k
                rec["dropped"] = k
        if spec.corrupt_fraction and dev.size:
            hit = np.flatnonzero(rng.random(dev.size) < spec.corrupt_fraction)
            if hit.size:
                cat = rng.integers(0, 4, hit.size)
                dev, ts, vs = dev.copy(), ts.copy(), vs.copy()
                vs[hit[cat == 0]] = np.nan
                vs[hit[cat == 1]] = np.inf
                dev[hit[cat == 2]] += self.n_devices    # out-of-range id
                ts[hit[cat == 3]] = np.nan
                nv = int(np.sum(cat <= 1))
                ni = int(np.sum(cat == 2))
                nt = int(np.sum(cat == 3))
                c["corrupt_value"] += nv
                c["corrupt_id"] += ni
                c["corrupt_time"] += nt
                rec["corrupt"] = nv + ni + nt
        if spec.dup_fraction and dev.size:
            extra = rng.random(dev.size) < spec.dup_fraction
            k = int(extra.sum())
            if k:
                dev = np.concatenate([dev, dev[extra]])
                ts = np.concatenate([ts, ts[extra]])
                vs = np.concatenate([vs, vs[extra]])
                c["duplicated"] += k
                rec["duplicated"] = k
        if spec.delay_fraction and dev.size:
            hold = rng.random(dev.size) < spec.delay_fraction
            new_held = (dev[hold], ts[hold], vs[hold])
            dev, ts, vs = dev[~hold], ts[~hold], vs[~hold]
            k = int(hold.sum())
            if k:
                c["delayed"] += k
                rec["delayed"] = k
        else:
            new_held = None
        if self._held is not None:
            dev = np.concatenate([self._held[0], dev])
            ts = np.concatenate([self._held[1], ts])
            vs = np.concatenate([self._held[2], vs])
        self._held = new_held
        if spec.shuffle and dev.size:
            perm = rng.permutation(dev.size)
            dev, ts, vs = dev[perm], ts[perm], vs[perm]
            c["shuffled_slabs"] += 1
        rec["out"] = int(dev.size)
        self.log.slabs.append(rec)
        return dev, ts, vs

    def flush(self):
        """Hand back any still-held delayed samples (possibly empty)."""
        held = self._held
        self._held = None
        if held is None:
            return (np.empty(0, dtype=np.int64), np.empty(0), np.empty(0))
        return held


def replay(bank: SensorBank, monitor: MonitorService, t0: float, t1: float,
           period_s: float = 0.001, tick_s: float = 0.5,
           chunk_devices: Optional[int] = None, device_base: int = 0, *,
           shuffle: bool = False, dup_fraction: float = 0.0,
           drop_fraction: float = 0.0, delay_fraction: float = 0.0,
           seed: int = 0, faults: Optional[FaultSpec] = None,
           grid: Optional[bool] = None,
           progress: Optional[Callable] = None) -> Dict[str, int]:
    """Stream ``bank``'s poll grid into ``monitor`` slab by slab.

    Faults come from ``faults`` (a :class:`FaultSpec`) or, equivalently,
    the legacy keyword knobs ``shuffle``/``dup_fraction``/
    ``drop_fraction``/``delay_fraction`` + ``seed`` (which build the
    spec internally; passing both is an error).  With no fault active
    the replay is bit-exact: every poll instant arrives exactly once, in
    order — and flows through the monitor's rectangular
    :meth:`MonitorService.ingest_grid` fast path (``grid`` defaults to
    exactly that condition).  ``grid=True`` with any active fault raises
    ``ValueError``: the rectangular contract (one shared
    strictly-increasing time base) is precisely what faults destroy, so
    there is no meaningful faulty grid replay — pass ``grid=False`` to
    force the flattened path on a clean stream instead.

    ``progress(monitor, t_emitted)`` is called after each ingested slab.
    Returns the monitor's counter snapshot after the replay, with the
    injector's per-category decision counts under ``"injected"`` (all
    zero for clean/grid replays) — see :class:`InjectionLog` for the
    full per-slab log (build a :class:`FaultInjector` yourself and pass
    its spec to keep it).

    Corrupt-id injection (``FaultSpec.corrupt_fraction``) produces
    device ids ``>= n_devices``; the monitor must be built with
    ``strict_ids=False`` to reject-and-count them instead of raising.
    """
    if faults is None:
        faults = FaultSpec(shuffle=shuffle, dup_fraction=dup_fraction,
                           drop_fraction=drop_fraction,
                           delay_fraction=delay_fraction, seed=seed)
    elif shuffle or dup_fraction or drop_fraction or delay_fraction:
        raise ValueError("pass either faults= or the legacy fault knobs, "
                         "not both")
    faulty = faults.any
    if grid is None:
        grid = not faulty
    elif grid and faulty:
        raise ValueError(
            "grid replay is only defined for clean streams: the "
            "rectangular fast path assumes one shared strictly-"
            "increasing time base, which active faults "
            f"({faults!r}) violate — use grid=False or drop the faults")
    if grid:
        for dev, ts, vals in bank.iter_poll_slabs(
                t0, t1, period_s=period_s, tick_s=tick_s,
                chunk_devices=chunk_devices, device_base=device_base,
                grid=True):
            if len(ts):
                monitor.ingest_grid(dev, ts, vals)
                if progress is not None:
                    progress(monitor, float(ts[-1]))
        out = dict(monitor.counters)
        out["injected"] = faults.counts_zero()
        return out
    inj = FaultInjector(faults, monitor.n_devices, t0, t1)
    for seq, (dev, ts, vs) in enumerate(bank.iter_poll_slabs(
            t0, t1, period_s=period_s, tick_s=tick_s,
            chunk_devices=chunk_devices, device_base=device_base)):
        dev, ts, vs = inj.apply(seq, dev, ts, vs)
        if len(dev):
            monitor.ingest(dev, ts, vs)
            if progress is not None:
                fin = np.isfinite(ts)
                if fin.any():
                    progress(monitor, float(ts[fin].max()))
    held = inj.flush()
    if len(held[0]):
        monitor.ingest(*held)
    out = dict(monitor.counters)
    out["injected"] = dict(inj.log.counts)
    return out


@dataclasses.dataclass
class StreamFleetResult:
    """A streamed fleet plus its offline cross-check (see
    :func:`stream_fleet`)."""

    monitor: MonitorService
    n_devices: int
    labels: np.ndarray                  # [N] workload labels
    durations_s: np.ndarray             # [N] workload spans
    win_a: np.ndarray                   # [N] §5 window starts
    win_b: np.ndarray                   # [N] §5 window ends
    naive_stream_j: np.ndarray          # [N] streamed window energy, raw
    corrected_stream_j: np.ndarray      # [N] streamed, calibrated+shifted
    naive_offline_j: Optional[np.ndarray] = None      # integrate_polled
    corrected_offline_j: Optional[np.ndarray] = None  # integrate_polled
    n_samples: int = 0


def stream_fleet(n_devices: int,
                 profile: Union[str, Sequence[str]] = "a100",
                 workload=None, seed: int = 0,
                 chunk_devices: Optional[int] = None,
                 period_s: float = 0.001, tick_s: float = 0.5,
                 start_offset_s: float = 0.3,
                 host_baseline_w: Optional[float] = None,
                 backend: Optional[str] = None,
                 compare: bool = False,
                 monitor_kwargs: Optional[dict] = None,
                 progress: Optional[Callable] = None) -> StreamFleetResult:
    """Monitor a synthetic fleet live, mirroring ``fleet_audit``'s setup.

    Builds the same :class:`SensorBank` slabs as
    ``fleet_audit(n_devices, profile, workload, seed, chunk_devices)``
    — identical hidden parameters and reading schedules — registers each
    device's §5 execution window ``[0.3, 0.3 + duration]``, and streams
    the poll grid through a :class:`MonitorService`.  With
    ``compare=True`` the offline ``integrate_polled`` references (raw
    and calibrated+re-synchronised) are computed on the same schedules,
    which is the subsystem's parity pin.

    ``workload`` is one shared :class:`~repro.core.meter.Workload`, a
    :class:`~repro.core.meter.WorkloadSet`/sequence, or a
    :class:`~repro.core.load.FleetScenarioSpec` (slab-synthesised, so a
    100k+-device fleet streams at bounded memory).
    """
    if workload is None:
        workload = Workload("audit_burst", loads.multi_phase_workload(
            [(0.130, 215.0), (0.070, 165.0)]))
    names = ([profile] * n_devices if isinstance(profile, str)
             else list(profile))
    if len(names) != n_devices:
        raise ValueError(f"{len(names)} profile names for "
                         f"{n_devices} devices")
    spec = workload if isinstance(workload, loads.FleetScenarioSpec) else None
    if spec is not None and spec.n != n_devices:
        raise ValueError(f"FleetScenarioSpec covers {spec.n} devices, "
                         f"stream asked for {n_devices}")
    ws_full = (None if spec is not None
               else as_workload_set(workload, n_devices))

    if chunk_devices is None:
        slabs = [(0, n_devices)]
    else:
        if chunk_devices < 1:
            raise ValueError(f"chunk_devices must be >= 1, "
                             f"got {chunk_devices}")
        slabs = [(lo, min(lo + chunk_devices, n_devices))
                 for lo in range(0, n_devices, chunk_devices)]

    def slab_ws(lo, hi):
        if spec is not None:
            return spec.workload_set(lo, hi)
        if ws_full is not None:
            return ws_full if len(slabs) == 1 else ws_full.rows(lo, hi)
        return None

    # pass 1 — durations and labels (cheap [N] vectors; workload banks
    # are regenerated slab-by-slab in the stream pass)
    durations = np.empty(n_devices)
    labels = np.empty(n_devices, dtype=object)
    for lo, hi in slabs:
        ws = slab_ws(lo, hi)
        if ws is None:
            durations[lo:hi] = workload.duration_s
            labels[lo:hi] = workload.scenario_label
        else:
            durations[lo:hi] = ws.durations_s
            labels[lo:hi] = np.asarray(ws.scenarios)

    module = np.array([_profiles.get(nm).scope == "module" for nm in names])
    if np.any(module) and host_baseline_w is None:
        from repro.core.meter import ModuleScopeError
        raise ModuleScopeError(
            "module-scope profiles need host_baseline_w to debit host "
            "power from the stream")
    baseline = np.where(module, host_baseline_w or 0.0, 0.0)
    calibs = default_calibrations(names)
    corr = StreamCorrections.from_calibrations(names, calibs,
                                               baseline_w=baseline)
    monitor = MonitorService(n_devices, corrections=corr, labels=labels,
                             backend=backend, **(monitor_kwargs or {}))
    win_a = np.full(n_devices, float(start_offset_s))
    win_b = start_offset_s + durations
    monitor.set_windows(win_a, win_b)

    naive_off = np.empty(n_devices) if compare else None
    corr_off = np.empty(n_devices) if compare else None

    # pass 2 — build each slab's bank (identical to fleet_audit's), emit
    # its poll grid as a live stream, optionally pin the offline result
    for lo, hi in slabs:
        bank = SensorBank.from_catalog(
            names[lo:hi], seeds=np.arange(lo, hi) + seed, backend=backend)
        ws = slab_ws(lo, hi)
        if ws is None:
            tl = workload.timeline.shift(start_offset_s
                                         - workload.timeline.t_start)
            bank.attach(tl, t_end=tl.t_end + 1.0)
            grid_t1 = float(tl.t_end + 0.5)
        else:
            tlb = ws.timeline_bank
            tlb = tlb.shift(start_offset_s - tlb.t_start)
            bank.attach(tlb, t_end=tlb.t_end + 1.0)
            grid_t1 = float(np.max(tlb.t_end) + 0.5)
        replay(bank, monitor, 0.0, grid_t1, period_s=period_s,
               tick_s=tick_s, device_base=lo, progress=progress)

        if compare:
            base_rows = baseline[lo:hi]
            a = win_a[lo:hi]
            b = win_b[lo:hi]
            naive_off[lo:hi] = bank.integrate_polled(
                0.0, grid_t1, period_s, a, b,
                transform=lambda v, br=base_rows: v - br[:, None])
            # the calibrated+re-synchronised reference: each sensor
            # class re-synchronises by its own window (per-device
            # grid_offset), one pass over the slab
            gains = corr.gain[lo:hi]
            offs = corr.offset_w[lo:hi]
            corr_off[lo:hi] = bank.integrate_polled(
                0.0, grid_t1, period_s, a, b,
                transform=lambda v, br=base_rows, g=gains, o=offs:
                    ((v - br[:, None]) - o[:, None]) / g[:, None],
                grid_offset=-corr.time_shift_s[lo:hi])

    return StreamFleetResult(
        monitor=monitor, n_devices=n_devices, labels=labels,
        durations_s=durations, win_a=win_a, win_b=win_b,
        naive_stream_j=monitor.window_energy(corrected=False),
        corrected_stream_j=monitor.window_energy(corrected=True),
        naive_offline_j=naive_off, corrected_offline_j=corr_off,
        n_samples=monitor.counters["accepted"])
