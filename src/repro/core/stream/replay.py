"""Replay drivers: run any fleet as a live poll-sample stream.

:func:`replay` pushes a :class:`~repro.core.fleet_engine.SensorBank`'s
poll grid through a :class:`~repro.core.stream.monitor.MonitorService`
tick by tick, optionally injecting the failure modes a real collection
pipeline produces — shuffled arrival order, duplicated samples, dropped
samples, and samples delayed into a later tick (which arrive late and
are counted, not integrated).

:func:`stream_fleet` is the end-to-end driver: it builds the same
per-device sensor fleet as :func:`repro.core.fleet_engine.fleet_audit`
(same profiles, seeds, hidden parameters, workload synthesis and attach
geometry), streams it through a monitor in bounded-memory device slabs,
and — with ``compare=True`` — computes the offline
``integrate_polled`` ground truth on the very same reading schedules, so
the stream/offline parity is measured on identical inputs.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Sequence, Union

import numpy as np

from repro.core import load as loads
from repro.core import profiles as _profiles
from repro.core.fleet_engine import SensorBank
from repro.core.meter import Workload, as_workload_set
from repro.core.stream.estimators import (StreamCorrections,
                                          default_calibrations)
from repro.core.stream.monitor import MonitorService


def replay(bank: SensorBank, monitor: MonitorService, t0: float, t1: float,
           period_s: float = 0.001, tick_s: float = 0.5,
           chunk_devices: Optional[int] = None, device_base: int = 0, *,
           shuffle: bool = False, dup_fraction: float = 0.0,
           drop_fraction: float = 0.0, delay_fraction: float = 0.0,
           seed: int = 0, grid: Optional[bool] = None,
           progress: Optional[Callable] = None) -> Dict[str, int]:
    """Stream ``bank``'s poll grid into ``monitor`` slab by slab.

    The injection knobs model a lossy collection pipeline: ``shuffle``
    permutes each slab (the monitor re-sorts), ``dup_fraction`` re-emits
    that fraction of samples, ``drop_fraction`` removes samples
    (sampling gaps), ``delay_fraction`` holds samples back one slab so
    they arrive out of order across slabs (late — dropped and counted).
    With all knobs at zero the replay is bit-exact: every poll instant
    arrives exactly once, in order — and flows through the monitor's
    rectangular :meth:`MonitorService.ingest_grid` fast path (``grid``
    defaults to exactly that condition; pass ``grid=False`` to force the
    flattened path, e.g. to A/B the two).  ``progress(monitor,
    t_emitted)`` is called after each ingested slab.  Returns the
    monitor's counter snapshot after the replay.
    """
    faulty = (shuffle or dup_fraction > 0.0 or drop_fraction > 0.0
              or delay_fraction > 0.0)
    if grid is None:
        grid = not faulty
    elif grid and faulty:
        raise ValueError("grid replay is only defined for clean streams "
                         "(no shuffle/dup/drop/delay injection)")
    if grid:
        for dev, ts, vals in bank.iter_poll_slabs(
                t0, t1, period_s=period_s, tick_s=tick_s,
                chunk_devices=chunk_devices, device_base=device_base,
                grid=True):
            if len(ts):
                monitor.ingest_grid(dev, ts, vals)
                if progress is not None:
                    progress(monitor, float(ts[-1]))
        return monitor.counters
    rng = np.random.default_rng(seed)
    held = None
    for dev, ts, vs in bank.iter_poll_slabs(
            t0, t1, period_s=period_s, tick_s=tick_s,
            chunk_devices=chunk_devices, device_base=device_base):
        if drop_fraction > 0.0:
            keep = rng.random(len(dev)) >= drop_fraction
            dev, ts, vs = dev[keep], ts[keep], vs[keep]
        if dup_fraction > 0.0 and len(dev):
            extra = rng.random(len(dev)) < dup_fraction
            dev = np.concatenate([dev, dev[extra]])
            ts = np.concatenate([ts, ts[extra]])
            vs = np.concatenate([vs, vs[extra]])
        if delay_fraction > 0.0 and len(dev):
            hold = rng.random(len(dev)) < delay_fraction
            new_held = (dev[hold], ts[hold], vs[hold])
            dev, ts, vs = dev[~hold], ts[~hold], vs[~hold]
        else:
            new_held = None
        if held is not None:
            dev = np.concatenate([held[0], dev])
            ts = np.concatenate([held[1], ts])
            vs = np.concatenate([held[2], vs])
        held = new_held
        if shuffle and len(dev):
            perm = rng.permutation(len(dev))
            dev, ts, vs = dev[perm], ts[perm], vs[perm]
        if len(dev):
            monitor.ingest(dev, ts, vs)
            if progress is not None:
                progress(monitor, float(ts.max()))
    if held is not None and len(held[0]):
        monitor.ingest(*held)
    return monitor.counters


@dataclasses.dataclass
class StreamFleetResult:
    """A streamed fleet plus its offline cross-check (see
    :func:`stream_fleet`)."""

    monitor: MonitorService
    n_devices: int
    labels: np.ndarray                  # [N] workload labels
    durations_s: np.ndarray             # [N] workload spans
    win_a: np.ndarray                   # [N] §5 window starts
    win_b: np.ndarray                   # [N] §5 window ends
    naive_stream_j: np.ndarray          # [N] streamed window energy, raw
    corrected_stream_j: np.ndarray      # [N] streamed, calibrated+shifted
    naive_offline_j: Optional[np.ndarray] = None      # integrate_polled
    corrected_offline_j: Optional[np.ndarray] = None  # integrate_polled
    n_samples: int = 0


def stream_fleet(n_devices: int,
                 profile: Union[str, Sequence[str]] = "a100",
                 workload=None, seed: int = 0,
                 chunk_devices: Optional[int] = None,
                 period_s: float = 0.001, tick_s: float = 0.5,
                 start_offset_s: float = 0.3,
                 host_baseline_w: Optional[float] = None,
                 backend: Optional[str] = None,
                 compare: bool = False,
                 monitor_kwargs: Optional[dict] = None,
                 progress: Optional[Callable] = None) -> StreamFleetResult:
    """Monitor a synthetic fleet live, mirroring ``fleet_audit``'s setup.

    Builds the same :class:`SensorBank` slabs as
    ``fleet_audit(n_devices, profile, workload, seed, chunk_devices)``
    — identical hidden parameters and reading schedules — registers each
    device's §5 execution window ``[0.3, 0.3 + duration]``, and streams
    the poll grid through a :class:`MonitorService`.  With
    ``compare=True`` the offline ``integrate_polled`` references (raw
    and calibrated+re-synchronised) are computed on the same schedules,
    which is the subsystem's parity pin.

    ``workload`` is one shared :class:`~repro.core.meter.Workload`, a
    :class:`~repro.core.meter.WorkloadSet`/sequence, or a
    :class:`~repro.core.load.FleetScenarioSpec` (slab-synthesised, so a
    100k+-device fleet streams at bounded memory).
    """
    if workload is None:
        workload = Workload("audit_burst", loads.multi_phase_workload(
            [(0.130, 215.0), (0.070, 165.0)]))
    names = ([profile] * n_devices if isinstance(profile, str)
             else list(profile))
    if len(names) != n_devices:
        raise ValueError(f"{len(names)} profile names for "
                         f"{n_devices} devices")
    spec = workload if isinstance(workload, loads.FleetScenarioSpec) else None
    if spec is not None and spec.n != n_devices:
        raise ValueError(f"FleetScenarioSpec covers {spec.n} devices, "
                         f"stream asked for {n_devices}")
    ws_full = (None if spec is not None
               else as_workload_set(workload, n_devices))

    if chunk_devices is None:
        slabs = [(0, n_devices)]
    else:
        if chunk_devices < 1:
            raise ValueError(f"chunk_devices must be >= 1, "
                             f"got {chunk_devices}")
        slabs = [(lo, min(lo + chunk_devices, n_devices))
                 for lo in range(0, n_devices, chunk_devices)]

    def slab_ws(lo, hi):
        if spec is not None:
            return spec.workload_set(lo, hi)
        if ws_full is not None:
            return ws_full if len(slabs) == 1 else ws_full.rows(lo, hi)
        return None

    # pass 1 — durations and labels (cheap [N] vectors; workload banks
    # are regenerated slab-by-slab in the stream pass)
    durations = np.empty(n_devices)
    labels = np.empty(n_devices, dtype=object)
    for lo, hi in slabs:
        ws = slab_ws(lo, hi)
        if ws is None:
            durations[lo:hi] = workload.duration_s
            labels[lo:hi] = workload.scenario_label
        else:
            durations[lo:hi] = ws.durations_s
            labels[lo:hi] = np.asarray(ws.scenarios)

    module = np.array([_profiles.get(nm).scope == "module" for nm in names])
    if np.any(module) and host_baseline_w is None:
        from repro.core.meter import ModuleScopeError
        raise ModuleScopeError(
            "module-scope profiles need host_baseline_w to debit host "
            "power from the stream")
    baseline = np.where(module, host_baseline_w or 0.0, 0.0)
    calibs = default_calibrations(names)
    corr = StreamCorrections.from_calibrations(names, calibs,
                                               baseline_w=baseline)
    monitor = MonitorService(n_devices, corrections=corr, labels=labels,
                             backend=backend, **(monitor_kwargs or {}))
    win_a = np.full(n_devices, float(start_offset_s))
    win_b = start_offset_s + durations
    monitor.set_windows(win_a, win_b)

    naive_off = np.empty(n_devices) if compare else None
    corr_off = np.empty(n_devices) if compare else None

    # pass 2 — build each slab's bank (identical to fleet_audit's), emit
    # its poll grid as a live stream, optionally pin the offline result
    for lo, hi in slabs:
        bank = SensorBank.from_catalog(
            names[lo:hi], seeds=np.arange(lo, hi) + seed, backend=backend)
        ws = slab_ws(lo, hi)
        if ws is None:
            tl = workload.timeline.shift(start_offset_s
                                         - workload.timeline.t_start)
            bank.attach(tl, t_end=tl.t_end + 1.0)
            grid_t1 = float(tl.t_end + 0.5)
        else:
            tlb = ws.timeline_bank
            tlb = tlb.shift(start_offset_s - tlb.t_start)
            bank.attach(tlb, t_end=tlb.t_end + 1.0)
            grid_t1 = float(np.max(tlb.t_end) + 0.5)
        replay(bank, monitor, 0.0, grid_t1, period_s=period_s,
               tick_s=tick_s, device_base=lo, progress=progress)

        if compare:
            base_rows = baseline[lo:hi]
            a = win_a[lo:hi]
            b = win_b[lo:hi]
            naive_off[lo:hi] = bank.integrate_polled(
                0.0, grid_t1, period_s, a, b,
                transform=lambda v, br=base_rows: v - br[:, None])
            # the calibrated+re-synchronised reference: each sensor
            # class re-synchronises by its own window (per-device
            # grid_offset), one pass over the slab
            gains = corr.gain[lo:hi]
            offs = corr.offset_w[lo:hi]
            corr_off[lo:hi] = bank.integrate_polled(
                0.0, grid_t1, period_s, a, b,
                transform=lambda v, br=base_rows, g=gains, o=offs:
                    ((v - br[:, None]) - o[:, None]) / g[:, None],
                grid_offset=-corr.time_shift_s[lo:hi])

    return StreamFleetResult(
        monitor=monitor, n_devices=n_devices, labels=labels,
        durations_s=durations, win_a=win_a, win_b=win_b,
        naive_stream_j=monitor.window_energy(corrected=False),
        corrected_stream_j=monitor.window_energy(corrected=True),
        naive_offline_j=naive_off, corrected_offline_j=corr_off,
        n_samples=monitor.counters["accepted"])
