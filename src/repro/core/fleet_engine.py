"""Batched fleet-scale sensor simulation engine.

The scalar :class:`~repro.core.sensor.OnboardSensor` attaches and polls one
device at a time in Python loops, which caps fleet studies at a few hundred
devices.  This module is the vectorized, array-programming rewrite: a
:class:`SensorBank` holds *stacked* hidden parameters (gain, offset, phase)
and profile fields for thousands of heterogeneous devices and evaluates
N sensors × M readings as batched NumPy operations.

Numerical contract
------------------
``SensorBank`` is *per-device equivalent* to ``OnboardSensor``: device ``i``
built from ``(profile_i, seed_i)`` publishes the same reading schedule as
``OnboardSensor(profile_i, seed=seed_i)`` attached to the same timeline —
bitwise for an unshifted attach, and within one reporting quantum when the
timeline is rebased per device (the ``shifts`` fast path used by the batched
measurement protocols).  The guarantees rest on three implementation rules:

* hidden parameters and reading noise are drawn from the same per-device
  ``np.random.default_rng(seed)`` / ``default_rng(seed + 1)`` streams as the
  scalar sensor (``seed_mode="per_device"``; ``"fleet"`` trades equivalence
  for a single vectorized draw);
* the published tick grid is computed with the same expression
  ``phase + T * k`` on a padded ``[N, M]`` matrix, with leading/trailing
  slots masked rather than filtered;
* the Kepler/Maxwell first-order ("logarithmic") filter is a *scan across
  timeline segments with vector state over devices* — each step advances
  every device at once, and with per-device timelines the scan walks each
  row's own padded edge sequence (zero-width padding steps are masked).

Timelines are *heterogeneous-first*: ``attach`` takes either one shared
:class:`ActivityTimeline` (optionally with per-device ``shifts``) or a
:class:`~repro.core.ground_truth.TimelineBank` giving every device its own
trace — a fleet where each GPU runs a different job.  Internally both paths
feed the same three transient kernels; the shared timeline is simply the
degenerate single-row bank broadcast across devices.

Execution backends
------------------
The transient kernels and the closed-form poll counting are pure array
functions living in :mod:`repro.core.engine_backend`, with a NumPy
reference implementation and a ``jax.jit``/``vmap`` implementation
(``lax.associative_scan`` for the filter recurrence, traced under x64 so
the one-quantum equivalence contract holds).  Pick one per bank with
``SensorBank(..., backend="numpy"|"jax"|"auto")``; everything around the
kernels (RNG streams, schedule layout, quantisation) stays NumPy, so the
per-device seed contract is backend-independent.  See
``docs/backends.md``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from repro.core import profiles as _profiles
from repro.core.engine_backend import get_backend, resolve_backend
from repro.core.engine_backend.pytrees import PollGrid, ReadingSchedule
from repro.core.ground_truth import ActivityTimeline, TimelineBank
from repro.core.sensor import (OnboardSensor, SensorProfile,
                               SensorUnsupported, _sum_timelines)

_TRANSIENTS = ("boxcar", "logarithmic", "estimation")


def _as_array(x, n: int, dtype=np.float64) -> np.ndarray:
    """Broadcast a scalar or length-n sequence to a [n] array."""
    a = np.asarray(x, dtype=dtype)
    if a.ndim == 0:
        return np.full(n, a, dtype=dtype)
    if a.shape != (n,):
        raise ValueError(f"expected scalar or shape ({n},), got {a.shape}")
    return a


def auto_chunk_devices(n_devices: int, per_device_elems: int,
                       budget_elems: int = 16_000_000) -> int:
    """Device-slab size keeping one slab's intermediates near a budget.

    The one sizing rule behind every chunked path in this module: a slab
    of ``chunk`` devices materialises ``chunk x per_device_elems``
    float64 scratch elements, so ``chunk = budget_elems //
    per_device_elems`` holds peak memory around ``budget_elems * 8``
    bytes (128 MB at the default) regardless of fleet size.  Callers and
    their budgets:

    * :meth:`SensorBank.poll` — ``per_device_elems`` = poll instants,
      default budget (the [chunk, n_polls] query/jitter matrices);
    * :meth:`SensorBank.iter_poll_slabs` — poll instants per tick, 4M
      budget (a streamed slab additionally flattens to device-major);
    * :meth:`SensorBank.query` with ``chunk_devices="auto"`` — query
      grid width, default budget (``None`` keeps the historical
      unchunked default: one [N, K] slot-index pass).

    Degenerate inputs clamp sanely: zero/negative ``per_device_elems``
    counts as one element, the result is always >= 1 (tiny budgets
    stream row by row) and never exceeds ``n_devices`` (when positive),
    so ``range(0, n, chunk)`` covers any fleet, including ``n == 0``.
    """
    per = max(int(per_device_elems), 1)
    chunk = max(1, int(budget_elems) // per)
    if n_devices > 0:
        chunk = min(chunk, int(n_devices))
    return chunk


class SensorBank:
    """N heterogeneous on-board sensors as stacked arrays.

    Usage::

        bank = SensorBank.from_catalog(["a100"] * 5000 + ["v100"] * 5000)
        bank.attach(timeline, t_end=10.0)
        vals = bank.query(t)                    # [N] readings at time t
        ts, mat = bank.poll(0.0, 10.0, 0.001)   # mat is [N, M]
    """

    def __init__(self, profile_list: Sequence[SensorProfile],
                 seeds: Optional[Sequence[int]] = None,
                 host_timeline: Optional[ActivityTimeline] = None,
                 seed_mode: str = "per_device", base_seed: int = 0,
                 backend: Optional[str] = None):
        if seed_mode not in ("per_device", "fleet"):
            raise ValueError(f"unknown seed_mode '{seed_mode}'")
        self.backend = resolve_backend(backend)
        self._be = get_backend(self.backend)
        self.profiles: List[SensorProfile] = list(profile_list)
        n = len(self.profiles)
        if n == 0:
            raise ValueError("empty sensor bank")
        if seeds is None:
            seeds = np.arange(n) + base_seed
        self.seeds = np.asarray(seeds, dtype=np.int64)
        if self.seeds.shape != (n,):
            raise ValueError(f"need {n} seeds, got {self.seeds.shape}")
        self.host_timeline = host_timeline
        self.seed_mode = seed_mode

        # -- stacked profile fields (grouped by identity: a fleet has few
        # distinct profiles, so each field is gathered from a small
        # per-profile table instead of N attribute lookups) --------------
        prof = self.profiles
        uniq: Dict[int, int] = {}      # keyed by object identity: distinct
        codes = np.fromiter((uniq.setdefault(id(p), len(uniq))   # profiles
                             for p in prof), dtype=np.int64, count=n)
        by_code = [None] * len(uniq)   # sharing a name must not collapse
        for p in prof:
            by_code[uniq[id(p)]] = p

        def field(fn, dtype=np.float64):
            return np.array([fn(p) for p in by_code], dtype=dtype)[codes]

        self.update_period_s = field(lambda p: p.update_period_s)
        self.window_s = field(lambda p: p.window_s if p.window_s is not None
                              else p.update_period_s)
        self.tau_s = field(lambda p: p.tau_s)
        self.quantum_w = field(lambda p: p.quantum_w)
        self.noise_w = field(lambda p: p.noise_w)
        self.sampled_fraction = field(lambda p: p.sampled_fraction)
        self.transient = field(lambda p: p.transient, dtype=object)
        self.module_scope = field(lambda p: p.scope == "module", dtype=bool)
        self.supported = field(lambda p: p.supported, dtype=bool)
        for p in by_code:
            if p.transient not in _TRANSIENTS:
                raise ValueError(f"unknown transient '{p.transient}'")

        # -- hidden per-device truth -------------------------------------
        gain_tol = field(lambda p: p.gain_tol)
        off_tol = field(lambda p: p.offset_tol_w)
        model_err = field(lambda p: p.model_error)
        if seed_mode == "per_device":
            # replicate OnboardSensor.__post_init__ draw-for-draw so the
            # hidden truth matches the scalar reference device-by-device;
            # VecStreams lanes are bitwise default_rng(seed) streams, so
            # this is the same loop, N lanes at a time
            from repro.core.engine_backend.vecrng import VecStreams
            streams = VecStreams(self.seeds)
            gain = 1.0 + streams.uniform(-gain_tol, gain_tol)
            offset = streams.uniform(-off_tol, off_tol)
            phase = streams.uniform(0.0, self.update_period_s)
            est = self.transient == "estimation"
            mgain = np.where(
                est, 1.0 + streams.uniform(-model_err, model_err, mask=est),
                1.0)
        else:
            rng = np.random.default_rng(int(base_seed))
            gain = 1.0 + rng.uniform(-1.0, 1.0, n) * gain_tol
            offset = rng.uniform(-1.0, 1.0, n) * off_tol
            phase = rng.uniform(0.0, 1.0, n) * self.update_period_s
            mgain = 1.0 + rng.uniform(-1.0, 1.0, n) * model_err
        self._gain = gain
        self._offset = offset
        self._phase = phase
        self._model_gain = mgain

        self._ticks: Optional[np.ndarray] = None    # [N, M] padded
        self._values: Optional[np.ndarray] = None   # [N, M] padded
        self._first: Optional[np.ndarray] = None    # [N] first valid slot
        self._last: Optional[np.ndarray] = None     # [N] last valid slot
        self._k0: Optional[np.ndarray] = None       # [N] k of slot 0

    # -- constructors -----------------------------------------------------
    @classmethod
    def from_catalog(cls, names: Union[str, Sequence[str]],
                     n: Optional[int] = None,
                     seeds: Optional[Sequence[int]] = None,
                     base_seed: int = 0,
                     host_timeline: Optional[ActivityTimeline] = None,
                     seed_mode: str = "per_device",
                     backend: Optional[str] = None) -> "SensorBank":
        """Build a bank from `profiles.CATALOG` names.

        ``names`` is one name (with ``n`` copies) or an explicit per-device
        list; seeds default to ``base_seed + arange(N)``.
        """
        if isinstance(names, str):
            names = [names] * (n if n is not None else 1)
        elif n is not None and len(names) != n:
            raise ValueError(f"len(names)={len(names)} != n={n}")
        prof = [_profiles.get(name) for name in names]
        if seeds is None:
            seeds = np.arange(len(prof)) + base_seed
        return cls(prof, seeds=seeds, host_timeline=host_timeline,
                   seed_mode=seed_mode, backend=backend)

    # -- introspection ----------------------------------------------------
    @property
    def n_devices(self) -> int:
        return len(self.profiles)

    @property
    def true_gain(self) -> np.ndarray:
        return self._gain

    @property
    def true_offset(self) -> np.ndarray:
        return self._offset

    @property
    def true_phase(self) -> np.ndarray:
        return self._phase

    def scalar_reference(self, i: int) -> OnboardSensor:
        """The scalar sensor this bank row must agree with (for tests)."""
        return OnboardSensor(self.profiles[i], seed=int(self.seeds[i]),
                             host_timeline=self.host_timeline)

    _ROW_FIELDS = ("seeds", "update_period_s", "window_s", "tau_s",
                   "quantum_w", "noise_w", "sampled_fraction", "transient",
                   "module_scope", "supported", "_gain", "_offset", "_phase",
                   "_model_gain")

    def subset(self, idx: np.ndarray) -> "SensorBank":
        """A view-bank over a subset of devices (hidden params are sliced,
        not re-drawn, so rows stay identical to the parent bank)."""
        idx = np.asarray(idx)
        nb = object.__new__(SensorBank)
        nb.profiles = [self.profiles[i] for i in idx]
        nb.host_timeline = self.host_timeline
        nb.seed_mode = self.seed_mode
        nb.backend = self.backend
        nb._be = self._be
        for f in self._ROW_FIELDS:
            setattr(nb, f, getattr(self, f)[idx])
        nb._ticks = nb._values = nb._first = nb._last = nb._k0 = None
        return nb

    def with_backend(self, backend: Optional[str]) -> "SensorBank":
        """The same bank rows (hidden params shared, not re-drawn) bound
        to another execution backend.  The reading schedule is reset — a
        backend choice must never leak a stale schedule computed by the
        other implementation."""
        nb = object.__new__(SensorBank)
        nb.profiles = self.profiles
        nb.host_timeline = self.host_timeline
        nb.seed_mode = self.seed_mode
        nb.backend = resolve_backend(backend)
        nb._be = get_backend(nb.backend)
        for f in self._ROW_FIELDS:
            setattr(nb, f, getattr(self, f))
        nb._ticks = nb._values = nb._first = nb._last = nb._k0 = None
        return nb

    # -- simulation -------------------------------------------------------
    def attach(self, timeline: Union[ActivityTimeline, TimelineBank],
               t_end: Union[None, float, np.ndarray] = None,
               t_start: float = 0.0,
               shifts: Optional[np.ndarray] = None) -> None:
        """Precompute every device's published-reading schedule at once.

        ``timeline`` is one shared :class:`ActivityTimeline` for the whole
        fleet, or a :class:`TimelineBank` with one row per device (every
        GPU running its own job).  With a shared timeline, ``shifts[i]``
        makes device ``i`` observe ``timeline.shift(shifts[i])`` without
        materialising N shifted timelines (the batched measurement
        protocols randomise per-device start offsets this way); with a
        bank, bake offsets in via :meth:`TimelineBank.shift` instead.
        ``t_end`` may be per-device.
        """
        n = self.n_devices
        if not np.all(self.supported):
            bad = self.profiles[int(np.argmin(self.supported))]
            raise SensorUnsupported(f"{bad.name} exposes no power readings")

        per_device = isinstance(timeline, TimelineBank)
        if per_device:
            if timeline.n_rows != n:
                raise ValueError(
                    f"TimelineBank has {timeline.n_rows} rows for "
                    f"{n} devices")
            if shifts is not None:
                raise ValueError(
                    "per-device shifts are redundant with a TimelineBank; "
                    "bake them in with TimelineBank.shift(offsets)")
            if self.seed_mode == "fleet":
                raise ValueError(
                    "seed_mode='fleet' draws one shared noise stream and "
                    "cannot honour the per-device equivalence contract "
                    "with per-device timelines; build the bank with "
                    "seed_mode='per_device'")
            chip_bank = timeline
        else:
            if (shifts is not None and self.host_timeline is not None
                    and np.any(self.module_scope)):
                raise NotImplementedError(
                    "per-device shifts with a module-scope host timeline")
            chip_bank = TimelineBank.from_timelines([timeline])
        s = _as_array(0.0 if (shifts is None or per_device) else shifts, n)

        mod_local = None    # module_bank row order, when not device order
        if self.host_timeline is not None and np.any(self.module_scope):
            if per_device:
                # sum the host trace into the module-scope rows only
                mod_local = np.nonzero(self.module_scope)[0]
                module_bank = TimelineBank.from_timelines(
                    [_sum_timelines(timeline.row(i), self.host_timeline)
                     for i in mod_local])
            else:
                module_bank = TimelineBank.from_timelines(
                    [_sum_timelines(timeline, self.host_timeline)])
        else:
            module_bank = chip_bank

        T = self.update_period_s
        if t_end is None:
            te = (chip_bank.t_end if per_device
                  else (timeline.t_end + s)) + 2.0 * T
        else:
            te = _as_array(t_end, n)

        # padded tick grid: same `phase + T*k` expression as the scalar path
        k0 = np.floor((t_start - self._phase) / T).astype(np.int64)
        k1 = np.ceil((te - self._phase) / T).astype(np.int64)   # inclusive
        m = int(np.max(k1 - k0) + 1)
        ks = k0[:, None] + np.arange(m)[None, :]
        ticks = self._phase[:, None] + T[:, None] * ks
        valid = (ks <= k1[:, None]) & (ticks >= t_start - T[:, None])
        first = np.argmax(valid, axis=1)
        count = np.sum(valid, axis=1)
        if np.any(count <= 0):
            raise ValueError("a device published no readings in the window")
        last = first + count - 1

        raw = np.zeros_like(ticks)
        for kind in _TRANSIENTS:
            rows = np.nonzero(self.transient == kind)[0]
            if len(rows) == 0:
                continue
            chip_rows = rows[~self.module_scope[rows]]
            mod_rows = rows[self.module_scope[rows]]
            for rr, bank_tl, remap in ((chip_rows, chip_bank, None),
                                       (mod_rows, module_bank, mod_local)):
                if len(rr) == 0:
                    continue
                if bank_tl.n_rows == 1:
                    tl = bank_tl
                elif remap is not None:
                    tl = bank_tl.rows(np.searchsorted(remap, rr))
                else:
                    tl = bank_tl.rows(rr)
                t_eval = ticks[rr] - s[rr, None]
                if kind == "boxcar":
                    raw[rr] = self._be.boxcar_means(
                        tl.arrays, t_eval - self.window_s[rr, None], t_eval)
                elif kind == "estimation":
                    raw[rr] = self._be.estimation_means(
                        tl.arrays, t_eval - T[rr, None], t_eval,
                        self._model_gain[rr])
                else:
                    raw[rr] = self._be.log_filter(tl.arrays, t_eval,
                                                  self.tau_s[rr])

        vals = self._gain[:, None] * raw + self._offset[:, None]
        vals = vals + self._noise(m, first, count)
        vals = np.round(vals / self.quantum_w[:, None]) * self.quantum_w[:, None]
        vals = np.maximum(vals, 0.0)
        vals[~valid] = 0.0

        self._ticks, self._values = ticks, vals
        self._first, self._last, self._k0 = first, last, k0

    def _noise(self, m: int, first: np.ndarray,
               count: np.ndarray) -> np.ndarray:
        """Reading jitter aligned to each device's valid tick slots.

        The per-device mode draws from N lock-step ``default_rng(seed+1)``
        streams (:class:`~repro.core.engine_backend.vecrng.VecStreams`) —
        same stream, same draw count, bitwise the same values as the
        scalar sensor's ``attach()``, with no per-device ``Generator``
        construction."""
        n = self.n_devices
        out = np.zeros((n, m))
        if self.seed_mode == "per_device":
            from repro.core.engine_backend.vecrng import VecStreams
            noise = VecStreams(self.seeds + 1).normal_block(
                self.noise_w, count)
            cols = np.arange(noise.shape[1])[None, :]
            valid = cols < count[:, None]
            rows = np.broadcast_to(np.arange(n)[:, None], valid.shape)
            out[rows[valid], (first[:, None] + cols)[valid]] = noise[valid]
        else:
            rng = np.random.default_rng(int(self.seeds[0]) + 1)
            out = rng.normal(0.0, 1.0, size=(n, m)) * self.noise_w[:, None]
        return out

    # -- query API --------------------------------------------------------
    @property
    def _schedule(self) -> ReadingSchedule:
        """The attached reading schedule as the backend pytree."""
        if self._ticks is None:
            raise RuntimeError("bank not attached to a timeline")
        return ReadingSchedule(self._ticks, self._first, self._last,
                               self._k0, self._phase, self.update_period_s)

    def query(self, t: Union[float, np.ndarray],
              chunk_devices: Union[int, str, None] = None) -> np.ndarray:
        """Latest published reading per device at time(s) ``t``.

        ``t`` may be a scalar (returns [N]), a shared [K] query grid
        (returns [N, K]), or per-device times [N, K].  ``chunk_devices``
        bounds the slot-index intermediates to device slabs (the [N, K]
        result is still returned whole); per-device values are identical
        under any chunking.  ``"auto"`` sizes slabs by
        :func:`auto_chunk_devices`; the default ``None`` keeps the
        historical one-pass behaviour.
        """
        sched = self._schedule
        t = np.asarray(t, dtype=np.float64)
        scalar = (t.ndim == 0)
        if t.ndim <= 1:
            tq = np.broadcast_to(np.atleast_1d(t)[None, :],
                                 (self.n_devices, np.atleast_1d(t).shape[0]))
        elif t.ndim == 2 and t.shape[0] == self.n_devices:
            tq = t
        else:
            raise ValueError(f"bad query shape {t.shape}")

        if chunk_devices == "auto":
            chunk_devices = auto_chunk_devices(self.n_devices, tq.shape[1])
        if chunk_devices is None or chunk_devices >= self.n_devices:
            j = self._be.query_slots(sched, tq)
            out = np.take_along_axis(self._values, j, axis=1)
        else:
            out = np.empty(tq.shape)
            for lo in range(0, self.n_devices, chunk_devices):
                hi = min(lo + chunk_devices, self.n_devices)
                j = self._be.query_slots(self._schedule_rows(lo, hi),
                                         tq[lo:hi])
                out[lo:hi] = np.take_along_axis(self._values[lo:hi], j,
                                                axis=1)
        return out[:, 0] if scalar else out

    def _schedule_rows(self, lo: int, hi: int) -> ReadingSchedule:
        """The attached schedule restricted to device rows [lo, hi)."""
        sched = self._schedule
        return ReadingSchedule(
            sched.ticks[lo:hi], sched.first[lo:hi], sched.last[lo:hi],
            sched.k0[lo:hi], sched.phase[lo:hi],
            sched.update_period_s[lo:hi])

    def iter_poll_slabs(self, t0: float, t1: float,
                        period_s: float = 0.001, tick_s: float = 0.5,
                        chunk_devices: Optional[int] = None,
                        device_base: int = 0, grid: bool = False):
        """Yield ``(devices, times, readings)`` raw poll-sample slabs —
        the live-stream emission a :class:`repro.core.stream.\
MonitorService` consumes.

        The uniform ``poll`` grid over ``[t0, t1)`` is cut into
        wall-clock ticks of ``tick_s`` and, within a tick, into device
        chunks (``chunk_devices`` defaults to keeping one slab around a
        few million samples), so no ``[N, n_poll]`` matrix is ever
        materialised: peak memory is one slab.  Slabs are flattened
        device-major; ``device_base`` offsets the emitted device ids
        (a bank that models rows ``[base, base+n)`` of a larger fleet).

        With ``grid=True`` each slab keeps its natural rectangular shape
        instead: ``(devices [D], times [M], readings [D, M])`` — the
        exact input of :meth:`MonitorService.ingest_grid`, skipping the
        flatten/re-sort round-trip entirely.
        """
        n_polls = int(np.floor((t1 - t0) / period_s))
        per_tick = max(1, int(round(tick_s / period_s)))
        if chunk_devices is None:
            chunk_devices = auto_chunk_devices(self.n_devices, per_tick,
                                               budget_elems=4_000_000)
        for j_lo in range(0, n_polls, per_tick):
            j_hi = min(j_lo + per_tick, n_polls)
            ts = t0 + period_s * np.arange(j_lo, j_hi)
            m = j_hi - j_lo
            for lo in range(0, self.n_devices, chunk_devices):
                hi = min(lo + chunk_devices, self.n_devices)
                tq = np.broadcast_to(ts[None, :], (hi - lo, m))
                j = self._be.query_slots(self._schedule_rows(lo, hi), tq)
                vals = np.take_along_axis(self._values[lo:hi], j, axis=1)
                if grid:
                    yield np.arange(lo, hi) + device_base, ts, vals
                    continue
                dev = np.repeat(np.arange(lo, hi) + device_base, m)
                yield dev, np.tile(ts, hi - lo), vals.ravel()

    def poll(self, t0: float, t1: float, period_s: float = 0.001,
             jitter_s: float = 0.0,
             chunk_devices: Optional[int] = None
             ) -> tuple[np.ndarray, np.ndarray]:
        """Fleet-wide `nvidia-smi -lms`: shared query grid, [N, M] readings.

        With ``jitter_s`` the per-device grids deviate like the real tool
        (per-device ``default_rng(seed + 2)`` streams, as the scalar
        sensor) and the returned times are [N, M]; the jitter matrix is
        drawn by lock-step vectorized streams
        (:class:`~repro.core.engine_backend.vecrng.VecStreams`), bitwise
        what the scalar per-device loop produced.  Work proceeds in
        device slabs of ``chunk_devices`` rows (default: sized so
        intermediates stay around ~128 MB), so polling 10k devices no
        longer builds multi-GB [N, M] scratch matrices.
        """
        n = int(np.floor((t1 - t0) / period_s))
        ts = t0 + period_s * np.arange(n)
        if chunk_devices is None:
            chunk_devices = auto_chunk_devices(self.n_devices, n)
        if jitter_s > 0:
            from repro.core.engine_backend.vecrng import VecStreams
            mat = np.empty((self.n_devices, n))
            for lo in range(0, self.n_devices, chunk_devices):
                hi = min(lo + chunk_devices, self.n_devices)
                streams = VecStreams(self.seeds[lo:hi] + 2)
                jit = streams.uniform_block(0.0, jitter_s,
                                            np.full(hi - lo, n))
                mat[lo:hi] = np.sort(ts[None, :] + jit, axis=1)
            return mat, self.query(mat, chunk_devices=chunk_devices)
        return ts, self.query(ts, chunk_devices=chunk_devices)

    def integrate_polled(self, poll_t0: float,
                         poll_t1: Union[float, np.ndarray],
                         period_s: float,
                         a: Union[float, np.ndarray],
                         b: Union[float, np.ndarray],
                         transform=None,
                         grid_offset: Union[float, np.ndarray] = 0.0,
                         chunk: int = 2048) -> np.ndarray:
        """Step-integrate each device's polled series over [a_i, b_i].

        Matches ``meter._integrate_readings`` applied to a
        ``poll(poll_t0, poll_t1, period_s)`` series device-by-device — but
        never materialises the [N, n_poll] reading matrix (0.5 GB for a
        10k-device × multi-second × 1 kHz poll).  Because the poll grid is
        uniform and the published readings are a step function over the
        tick grid, the number of poll instants falling inside each reading
        interval has a closed form; the integral reduces to
        ``period · Σ_k v_k · count_k`` over the [N, M_ticks] schedule,
        ~100× less work than visiting every poll instant.

        ``transform`` maps raw readings (e.g. baseline or calibration
        correction) before integration; ``grid_offset`` shifts the
        *reported* poll timestamps (the §5 re-synchronisation step) while
        queries still happen at the true wall-clock instant — a scalar,
        or per-device [N] for fleets mixing averaging windows;
        ``poll_t1`` may be per-device (each scalar sensor's grid ends
        with its own trial).
        """
        sched = self._schedule
        n = self.n_devices
        a = _as_array(a, n)
        b = _as_array(b, n)
        grid = PollGrid(float(poll_t0), _as_array(poll_t1, n),
                        float(period_s), _as_array(grid_offset, n))
        # the closed-form poll counting is the backend kernel; the
        # (cheap) weighted contraction below stays NumPy so ``transform``
        # may be any Python callable over the reading matrix
        counts, slot_b, tail_dt, nonempty = self._be.poll_counts(
            sched, grid, a, b)

        vals = self._values
        if transform is not None:
            vals = transform(vals)
        total = np.sum(vals * counts, axis=1) * period_s

        # final poll instant integrates over the partial step b - r(j1)
        vb = np.take_along_axis(vals, slot_b[:, None], axis=1)[:, 0]
        total += np.where(nonempty, vb * tail_dt, 0.0)
        return np.where(nonempty, total, 0.0)


# ---------------------------------------------------------------------------
# Monte-Carlo fleet audit
# ---------------------------------------------------------------------------

def _err_stats(e: np.ndarray) -> Dict[str, float]:
    q = np.percentile(np.abs(e), [50, 90, 99])
    return {
        "mean_err": float(np.mean(e)),
        "mean_abs_err": float(np.mean(np.abs(e))),
        "std_err": float(np.std(e)),
        "p50_abs": float(q[0]),
        "p90_abs": float(q[1]),
        "p99_abs": float(q[2]),
        "worst_abs": float(np.max(np.abs(e))),
    }


class StreamingMoments:
    """Mergeable error-moment accumulator for chunked fleet audits.

    Each device slab contributes one backend ``err_moments`` reduction
    (count, mean, M2, mean of |e|, max |e|); slabs merge by Chan's
    parallel-Welford update, so the audit never needs all N errors in
    one reduction.  ``stats()`` returns the moment-derived subset of
    :func:`_err_stats` — means/std/worst agree with the exact vector
    computation to float accumulation order; percentiles are not
    moment-expressible and stay with the exact path.
    """

    __slots__ = ("n", "mean", "m2", "mean_abs", "max_abs")

    def __init__(self):
        self.n = 0
        self.mean = 0.0
        self.m2 = 0.0
        self.mean_abs = 0.0
        self.max_abs = 0.0

    def update(self, e: np.ndarray, backend=None) -> "StreamingMoments":
        be = backend if backend is not None else get_backend("numpy")
        return self.merge(*be.err_moments(e))

    def merge(self, nb: int, mean_b: float, m2_b: float,
              mean_abs_b: float, max_abs_b: float) -> "StreamingMoments":
        """Fold one pre-reduced moment block (Chan's parallel-Welford
        update) — the primitive behind :meth:`update`, also fed directly
        by callers that reduce their own slabs (the streaming monitor's
        per-label bincount path)."""
        if nb == 0:
            return self
        na = self.n
        tot = na + nb
        delta = mean_b - self.mean
        self.mean += delta * nb / tot
        self.m2 += m2_b + delta * delta * na * nb / tot
        self.mean_abs += (mean_abs_b - self.mean_abs) * nb / tot
        self.max_abs = max(self.max_abs, max_abs_b)
        self.n = tot
        return self

    def stats(self) -> Dict[str, float]:
        if self.n == 0:
            return {"mean_err": 0.0, "mean_abs_err": 0.0, "std_err": 0.0,
                    "worst_abs": 0.0, "n_devices": 0}
        return {
            "mean_err": float(self.mean),
            "mean_abs_err": float(self.mean_abs),
            "std_err": float(np.sqrt(max(self.m2 / self.n, 0.0))),
            "worst_abs": float(self.max_abs),
            "n_devices": int(self.n),
        }


@dataclasses.dataclass
class FleetAuditResult:
    """Per-device error distribution of a fleet-wide energy audit.

    ``true_j`` is one shared per-repetition truth (homogeneous workload)
    or a [N] vector (heterogeneous fleet, one workload per device);
    ``scenarios`` labels each device's workload class for the per-scenario
    breakdown (the paper's Fig. 18 spread, emergent from workload mix).
    """

    n_devices: int
    profile_names: List[str]
    true_j: Union[float, np.ndarray]   # per-repetition analytic truth
    naive_j: np.ndarray                # [N] single-shot estimates
    naive_err: np.ndarray              # [N] relative errors
    gp_j: Optional[np.ndarray] = None  # [N] good-practice estimates
    gp_err: Optional[np.ndarray] = None
    scenarios: Optional[np.ndarray] = None  # [N] workload labels
    chunk_devices: Optional[int] = None     # slab size of a chunked audit
    streamed: Optional[Dict[str, Dict]] = None  # merged StreamingMoments

    def stats(self, errs: Optional[np.ndarray] = None) -> Dict[str, float]:
        e = self.naive_err if errs is None else errs
        return _err_stats(e)

    def by_scenario(self, errs: Optional[np.ndarray] = None
                    ) -> Dict[str, Dict[str, float]]:
        """Error stats split by workload scenario label: how much of the
        fleet-wide spread each workload shape contributes."""
        if self.scenarios is None:
            st = self.stats(errs)
            st["n_devices"] = int(self.n_devices)
            return {"all": st}
        e = self.naive_err if errs is None else errs
        labels = np.asarray(self.scenarios)
        out: Dict[str, Dict[str, float]] = {}
        for label in np.unique(labels):
            sel = e[labels == label]
            st = _err_stats(sel)
            st["n_devices"] = int(sel.shape[0])
            out[str(label)] = st
        return out

    def uncertainty(self) -> Dict[str, float]:
        """1/√N (independent) vs worst-case (correlated lot) fleet bounds."""
        from repro.core.telemetry import SHUNT_TOLERANCE
        est = self.gp_j if self.gp_j is not None else self.naive_j
        sigma = SHUNT_TOLERANCE * est
        total = float(np.sum(est))
        return {
            "total_j": total,
            "sigma_independent_j": float(np.sqrt(np.sum(sigma ** 2))),
            "sigma_worstcase_j": float(np.sum(sigma)),
            "sigma_independent_rel": float(
                np.sqrt(np.sum(sigma ** 2)) / max(total, 1e-12)),
            "sigma_worstcase_rel": float(
                np.sum(sigma) / max(total, 1e-12)),
        }


def fleet_audit(n_devices: int, profile: Union[str, Sequence[str]] = "a100",
                workload=None, seed: int = 0,
                good_practice: bool = False, n_trials: int = 2,
                seed_mode: str = "per_device",
                backend=None,
                chunk_devices: Optional[int] = None,
                mesh=None,
                prefetch_workloads: bool = False) -> FleetAuditResult:
    """Monte-Carlo audit: N devices, each with hidden gain/offset/phase,
    measure naively (and optionally with the §5 protocol) and return the
    per-device error distribution.

    ``workload`` is one shared :class:`~repro.core.meter.Workload`, a
    sequence / :class:`~repro.core.meter.WorkloadSet` of N per-device
    workloads — a mixed fleet where every device runs its own job (see
    :func:`repro.core.load.mixed_fleet_workloads`) — or a
    :class:`~repro.core.load.FleetScenarioSpec` recipe, in which case
    each device slab's timelines are synthesised on demand.

    ``backend`` selects the execution backend for the array kernels
    (``"numpy"`` default / ``"jax"`` / ``"auto"``); results agree within
    one reporting quantum, so error statistics are backend-independent.

    ``chunk_devices`` streams the audit over device slabs of that size:
    peak memory is bounded by one slab's [chunk, M] matrices (plus O(N)
    per-device results), per-device estimates match the unchunked audit
    within float accumulation (each slab's reading grid pads to the
    slab max, permuting the padded-width summation tree — ≲1e-12
    relative; bitwise when the padding coincides), and error statistics
    are merged across slabs by :class:`StreamingMoments` (exposed as
    ``result.streamed``; the exact vector stats remain available through
    ``result.stats()``).  This is what makes million-device
    heterogeneous audits practical — see ``docs/scaling.md``.

    ``mesh`` (a jax mesh with a ``"data"`` axis) runs every kernel
    ``shard_map``-ed over the mesh devices via a
    :class:`~repro.core.fleet_engine_shard.ShardedBackend`, with the
    error-moment merge as an on-device Chan tree; ``backend`` may also
    be such a backend *object* directly.  ``prefetch_workloads``
    double-buffers :class:`~repro.core.load.FleetScenarioSpec` slab
    synthesis against audit compute (identical results — slabs are
    exact row-ranges; defaults on for the sharded entry point).  Both
    default off, so the single-shard path is byte-for-byte the
    historical code path.

    10,000 devices run in seconds: everything after bank construction is
    [N, M] array arithmetic.
    """
    from repro.core import load as loads
    from repro.core.calibrate import CalibrationRecord
    from repro.core.meter import (Workload, GoodPracticeConfig,
                                  as_workload_set,
                                  measure_good_practice_batch,
                                  measure_naive_batch)

    if mesh is not None:
        # lazy import: the module (and jax) only loads when a mesh asks
        from repro.core.fleet_engine_shard import ShardedBackend
        if backend is not None and not isinstance(backend, str):
            raise ValueError("pass either mesh= or a backend object, "
                             "not both")
        backend = ShardedBackend(mesh, base=backend or "jax")

    if workload is None:
        workload = Workload("audit_burst", loads.multi_phase_workload(
            [(0.130, 215.0), (0.070, 165.0)]))
    names = ([profile] * n_devices if isinstance(profile, str)
             else list(profile))
    if len(names) != n_devices:
        raise ValueError(f"{len(names)} profile names for {n_devices} devices")

    spec = workload if isinstance(workload, loads.FleetScenarioSpec) else None
    if spec is not None:
        if spec.n != n_devices:
            raise ValueError(f"FleetScenarioSpec covers {spec.n} devices, "
                             f"audit asked for {n_devices}")
        ws_full = None
    else:
        ws_full = as_workload_set(workload, n_devices)
    shared = spec is None and ws_full is None
    labelled = not shared

    if chunk_devices is None:
        slabs = [(0, n_devices)]
    else:
        if chunk_devices < 1:
            raise ValueError(f"chunk_devices must be >= 1, "
                             f"got {chunk_devices}")
        if seed_mode == "fleet" and chunk_devices < n_devices:
            raise ValueError(
                "chunk_devices requires seed_mode='per_device': the "
                "'fleet' mode draws one shared RNG stream across the "
                "whole bank, which a per-slab bank would restart — "
                "per-device results would differ from the unchunked "
                "audit and correlate across slabs")
        slabs = [(lo, min(lo + chunk_devices, n_devices))
                 for lo in range(0, n_devices, chunk_devices)]

    calibs: Dict[str, "CalibrationRecord"] = {}
    if good_practice:
        from repro.core.calibrate import nominal_record
        for name in set(names):
            calibs[name] = nominal_record("fleet", _profiles.get(name))

    be = get_backend(resolve_backend(backend))
    naive_j = np.empty(n_devices)
    naive_err = np.empty(n_devices)
    truth_v = np.empty(n_devices) if not shared else None
    scenarios = np.empty(n_devices, dtype=object) if labelled else None
    gp_j = np.empty(n_devices) if good_practice else None
    gp_err = np.empty(n_devices) if good_practice else None
    sm: Dict[str, Dict] = {
        "naive": {"overall": StreamingMoments(), "by_scenario": {}}}
    if good_practice:
        sm["good_practice"] = {"overall": StreamingMoments(),
                               "by_scenario": {}}

    def _stream(key: str, err: np.ndarray, labels) -> None:
        sm[key]["overall"].update(err, be)
        if labels is None:
            return
        for label in np.unique(labels):
            sm[key]["by_scenario"].setdefault(
                str(label), StreamingMoments()).update(
                    err[labels == label], be)

    ws_iter = (spec.iter_workload_sets(slabs, prefetch=prefetch_workloads)
               if spec is not None else None)
    for lo, hi in slabs:
        bank = SensorBank.from_catalog(
            names[lo:hi], seeds=np.arange(lo, hi) + seed,
            seed_mode=seed_mode, backend=backend)
        if spec is not None:
            ws = next(ws_iter)
        elif ws_full is not None:
            ws = ws_full if len(slabs) == 1 else ws_full.rows(lo, hi)
        else:
            ws = None
        wl = workload if ws is None else ws
        baseline = 0.0 if np.any(bank.module_scope) else None
        naive = measure_naive_batch(bank, wl, host_baseline_w=baseline)
        tr = workload.true_energy_j if ws is None else ws.true_energies_j
        err = (naive - tr) / tr
        labels = None if ws is None else np.asarray(ws.scenarios)
        naive_j[lo:hi] = naive
        naive_err[lo:hi] = err
        if truth_v is not None:
            truth_v[lo:hi] = tr
        if scenarios is not None:
            scenarios[lo:hi] = labels
        _stream("naive", err, labels)

        if good_practice:
            est = measure_good_practice_batch(
                bank, wl, calibs, GoodPracticeConfig(n_trials=n_trials),
                host_baseline_w=baseline, seeds=np.arange(lo, hi))
            gp_j[lo:hi] = est.joules_per_rep
            ge = (est.joules_per_rep - tr) / tr
            gp_err[lo:hi] = ge
            _stream("good_practice", ge, labels)

    streamed = {key: {"overall": v["overall"].stats(),
                      "by_scenario": {k: s.stats() for k, s in
                                      sorted(v["by_scenario"].items())}}
                for key, v in sm.items()}
    return FleetAuditResult(
        n_devices=n_devices, profile_names=names,
        true_j=(workload.true_energy_j if shared else truth_v),
        naive_j=naive_j, naive_err=naive_err,
        gp_j=gp_j, gp_err=gp_err, scenarios=scenarios,
        chunk_devices=chunk_devices, streamed=streamed)
