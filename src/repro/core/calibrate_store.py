"""Versioned, persisted calibration artifacts with active-record tracking.

The :class:`~repro.core.calibrate.CalibrationStore` in ``calibrate.py``
is the job-launcher cache: one mutable JSON file per device, overwritten
on re-characterisation.  A deployed fleet auditor needs the estimator
lifecycle instead (the Pioreactor estimator-store pattern): every fitted
:class:`~repro.core.calibrate.CalibrationRecord` is an **immutable,
versioned artifact** saved to disk, at most one version per device is
**active** at a time, and stale artifacts are **aged out** by a
``max_age_s`` policy instead of silently trusted forever.

Layout (all plain JSON, human-diffable)::

    <root>/
      devices/<device_id>/v0001.json      # artifact, never rewritten
      devices/<device_id>/v0002.json
      active.json                         # {device_id: version} tracking

``active.json`` is rewritten atomically (tmp + rename) so a crashed
writer can never leave a torn activation map.  Device ids are
sanitised for the filesystem exactly like the legacy store
(``/`` → ``_``).

:meth:`ArtifactStore.resolve` turns the active records for a list of
device ids into the stacked
:class:`~repro.core.stream.estimators.StreamCorrections` the streaming
monitor consumes — the bridge between the artifact lifecycle and the
ingest hot path.  Devices without an active (or fresh-enough) record
fall back to a caller-supplied default record, or to identity
corrections (gain 1, no offset, no time shift) when there is none:
never a stale guess.
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.common.logging import get_logger
from repro.core.calibrate import CalibrationRecord

log = get_logger("calibrate_store")

_VERSION_RE = re.compile(r"^v(\d{4,})\.json$")


class StoreError(RuntimeError):
    """A calibration-store operation could not be honoured (unknown
    device/version, activating a missing artifact, corrupt layout)."""


def _safe(device_id: str) -> str:
    return device_id.replace("/", "_")


def record_stamp(rec: CalibrationRecord) -> float:
    """The age-out reference instant of a record: ``fitted_at`` when the
    characterisation stamped one, else ``created_at``.  Returns 0.0 for
    legacy/synthetic records with no provenance at all — callers treat
    an unknown age as *never expiring* (ageing out a record because it
    predates the ``fitted_at`` field would silently un-calibrate every
    legacy fleet)."""
    if rec.fitted_at is not None:
        return float(rec.fitted_at)
    return float(rec.created_at or 0.0)


@dataclasses.dataclass(frozen=True)
class ArtifactInfo:
    """One saved artifact as listed by :meth:`ArtifactStore.versions`."""

    device_id: str
    version: int
    path: str
    active: bool
    record: CalibrationRecord

    @property
    def stamp(self) -> float:
        return record_stamp(self.record)

    def summary(self) -> dict:
        rec = self.record
        return {
            "device_id": self.device_id,
            "version": self.version,
            "active": self.active,
            "profile": rec.profile_name,
            "gain": rec.gain,
            "offset_w": rec.offset_w,
            "update_period_s": rec.update_period_s,
            "fitted_at": rec.fitted_at,
            "source": rec.source,
        }


class ArtifactStore:
    """Versioned on-disk calibration artifacts (see module doc).

    Usage::

        store = ArtifactStore(root)
        v = store.save(record, activate=True)      # -> 1, 2, 3, ...
        rec = store.active(record.device_id)       # the activated record
        store.activate(dev, v - 1)                 # roll back one version
        store.gc(max_age_s=90 * 86400)             # age out stale artifacts
        corr = store.resolve(uuids)                # -> StreamCorrections
    """

    def __init__(self, root: str):
        self.root = str(root)
        os.makedirs(os.path.join(self.root, "devices"), exist_ok=True)

    # -- layout ------------------------------------------------------------
    def _device_dir(self, device_id: str) -> str:
        return os.path.join(self.root, "devices", _safe(device_id))

    def _artifact_path(self, device_id: str, version: int) -> str:
        return os.path.join(self._device_dir(device_id),
                            f"v{int(version):04d}.json")

    def _active_path(self) -> str:
        return os.path.join(self.root, "active.json")

    def _active_map(self) -> Dict[str, int]:
        p = self._active_path()
        if not os.path.exists(p):
            return {}
        with open(p) as f:
            data = json.load(f)
        if not isinstance(data, dict):
            raise StoreError(f"corrupt active map {p}: expected an "
                             f"object, got {type(data).__name__}")
        return {str(k): int(v) for k, v in data.items()}

    def _write_active_map(self, m: Dict[str, int]) -> None:
        p = self._active_path()
        tmp = p + ".tmp"
        with open(tmp, "w") as f:
            json.dump(dict(sorted(m.items())), f, indent=2)
        os.replace(tmp, p)

    # -- artifact lifecycle ------------------------------------------------
    def devices(self) -> List[str]:
        """Sanitised device ids with at least one saved artifact."""
        d = os.path.join(self.root, "devices")
        return sorted(x for x in os.listdir(d)
                      if os.path.isdir(os.path.join(d, x)))

    def _version_numbers(self, device_id: str) -> List[int]:
        d = self._device_dir(device_id)
        if not os.path.isdir(d):
            return []
        out = []
        for name in os.listdir(d):
            m = _VERSION_RE.match(name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def save(self, rec: CalibrationRecord, activate: bool = False) -> int:
        """Persist ``rec`` as the next version for its device (versions
        are append-only — an artifact file is never rewritten).  Returns
        the version number; with ``activate=True`` the new artifact
        also becomes the device's active record."""
        versions = self._version_numbers(rec.device_id)
        v = (versions[-1] + 1) if versions else 1
        os.makedirs(self._device_dir(rec.device_id), exist_ok=True)
        path = self._artifact_path(rec.device_id, v)
        with open(path, "w") as f:
            f.write(rec.to_json())
        log.info("saved calibration artifact", device=rec.device_id,
                 version=v)
        if activate:
            self.activate(rec.device_id, v)
        return v

    def load(self, device_id: str, version: int) -> CalibrationRecord:
        path = self._artifact_path(device_id, version)
        if not os.path.exists(path):
            raise StoreError(f"no artifact v{version} for device "
                             f"'{device_id}' under {self.root}")
        with open(path) as f:
            return CalibrationRecord.from_json(f.read())

    def versions(self, device_id: str) -> List[ArtifactInfo]:
        """Every saved artifact for a device, oldest first."""
        act = self._active_map().get(_safe(device_id))
        return [ArtifactInfo(device_id=device_id, version=v,
                             path=self._artifact_path(device_id, v),
                             active=(v == act),
                             record=self.load(device_id, v))
                for v in self._version_numbers(device_id)]

    def list_all(self) -> List[ArtifactInfo]:
        return [info for dev in self.devices()
                for info in self.versions(dev)]

    def activate(self, device_id: str, version: int) -> None:
        """Mark ``version`` as the device's active record (it must
        exist — activating a phantom artifact is a :class:`StoreError`,
        not a deferred surprise)."""
        if not os.path.exists(self._artifact_path(device_id, version)):
            raise StoreError(f"cannot activate v{version} for "
                             f"'{device_id}': artifact does not exist")
        m = self._active_map()
        m[_safe(device_id)] = int(version)
        self._write_active_map(m)

    def deactivate(self, device_id: str) -> bool:
        """Clear the device's active record (the device falls back to
        the resolver's default).  Returns whether one was active."""
        m = self._active_map()
        was = m.pop(_safe(device_id), None)
        if was is not None:
            self._write_active_map(m)
        return was is not None

    def active_version(self, device_id: str) -> Optional[int]:
        return self._active_map().get(_safe(device_id))

    def active(self, device_id: str,
               max_age_s: Optional[float] = None,
               now: Optional[float] = None) -> Optional[CalibrationRecord]:
        """The device's active record, or None when none is active — or
        when the active record is older than ``max_age_s`` (a stale
        characterisation is worse than an honest "uncalibrated":
        sensors drift, drivers change the averaging window).  Records
        without any provenance stamp never age out (see
        :func:`record_stamp`)."""
        v = self.active_version(device_id)
        if v is None:
            return None
        rec = self.load(device_id, v)
        if max_age_s is not None:
            stamp = record_stamp(rec)
            t = time.time() if now is None else float(now)
            if stamp > 0.0 and (t - stamp) > float(max_age_s):
                return None
        return rec

    def gc(self, max_age_s: float, now: Optional[float] = None,
           keep_active: bool = True, dry_run: bool = False) -> List[str]:
        """Delete artifacts older than ``max_age_s``; returns the
        removed paths.  Active artifacts are kept by default (delete the
        activation first if you really mean it); records without a
        provenance stamp are never collected."""
        t = time.time() if now is None else float(now)
        removed = []
        act = self._active_map()
        for dev in self.devices():
            for v in self._version_numbers(dev):
                rec = self.load(dev, v)
                stamp = record_stamp(rec)
                if stamp <= 0.0 or (t - stamp) <= float(max_age_s):
                    continue
                if keep_active and act.get(dev) == v:
                    continue
                path = self._artifact_path(dev, v)
                removed.append(path)
                if not dry_run:
                    os.remove(path)
        if removed and not dry_run:
            log.info("aged out calibration artifacts", n=len(removed))
        return removed

    # -- the bridge into the streaming monitor -----------------------------
    def resolve(self, device_ids: Sequence[str],
                default: Optional[CalibrationRecord] = None,
                baseline_w: float | np.ndarray = 0.0,
                max_age_s: Optional[float] = None,
                now: Optional[float] = None):
        """Stack the active records for ``device_ids`` into the
        :class:`~repro.core.stream.estimators.StreamCorrections` the
        monitor's ingest kernels consume.  See
        :func:`resolve_corrections` for the per-device fallback rules.
        """
        return resolve_corrections(device_ids, store=self, default=default,
                                   baseline_w=baseline_w,
                                   max_age_s=max_age_s, now=now)


def resolve_corrections(device_ids: Sequence[str],
                        store: Optional[ArtifactStore] = None,
                        default: Optional[CalibrationRecord] = None,
                        baseline_w: float | np.ndarray = 0.0,
                        max_age_s: Optional[float] = None,
                        now: Optional[float] = None):
    """Per-device corrections + labels from a store's active records.

    For each device id, in order: the store's active (and fresh-enough,
    under ``max_age_s``) record; else ``default``; else identity
    corrections (gain 1, offset 0, no time shift, 0.1 s reference
    period, ``calibrated=False``) — an unknown device is treated as an
    honest uncalibrated sensor, never given another device's gains.

    Returns ``(StreamCorrections, labels, n_active)`` where ``labels``
    [N] carries each record's profile name (``"uncalibrated"`` for the
    identity fallback) — ready for ``MonitorService(labels=)`` so
    by-label breakdowns group by sensor class.
    """
    from repro.core.stream.estimators import StreamCorrections

    ids = list(device_ids)
    n = len(ids)
    gain = np.ones(n)
    offset = np.zeros(n)
    shift = np.zeros(n)
    ref = np.full(n, 0.1)
    calib = np.zeros(n, dtype=bool)
    labels = np.full(n, "uncalibrated", dtype=object)
    n_active = 0
    for i, dev in enumerate(ids):
        rec = (store.active(dev, max_age_s=max_age_s, now=now)
               if store is not None else None)
        if rec is not None:
            n_active += 1
        elif default is not None:
            rec = default
        else:
            continue
        gain[i] = rec.correction_gain
        offset[i] = rec.correction_offset_w
        shift[i] = rec.time_shift_s
        ref[i] = rec.update_period_s
        calib[i] = rec.gain is not None
        labels[i] = rec.profile_name
    corr = StreamCorrections(
        gain=gain, offset_w=offset, time_shift_s=shift,
        baseline_w=np.broadcast_to(
            np.asarray(baseline_w, dtype=np.float64), (n,)).copy(),
        ref_period_s=ref, calibrated=calib)
    return corr, labels, n_active
