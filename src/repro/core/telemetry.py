"""Fleet-level energy telemetry with uncertainty propagation.

The paper's data-centre argument made first-class: per-device ±5 % gain
errors are i.i.d. within the shunt tolerance, so the *relative* fleet
uncertainty shrinks as 1/√N — but only if the errors are independent; a
procurement batch sharing a resistor lot does not average out, hence the
ledger also reports the worst-case (fully correlated) bound, matching the
paper's "could (but not guaranteed to) average out" caveat.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core.calibrate import CalibrationRecord
from repro.core.ledger import EnergyLedger

# per-device relative energy uncertainty: the ±5 % shunt-resistor
# tolerance (paper §6) uncalibrated, and a 1 % floor once calibrated
# (post-correction error std ~0.25 %, plus drift headroom)
SHUNT_TOLERANCE = 0.05
CALIBRATED_TOLERANCE = 0.01


@dataclasses.dataclass
class FleetSummary:
    n_devices: int
    total_j: float
    sigma_independent_j: float
    sigma_worstcase_j: float
    mean_power_w: float
    kwh: float
    cost_usd: float
    cost_sigma_usd: float
    annual_cost_uncertainty_usd: float


class FleetLedger:
    """Aggregates per-device ledgers + calibrations across a fleet.

    Two registration paths: :meth:`register` keeps one
    :class:`EnergyLedger` object per device (fine up to a few hundred
    devices), while :meth:`register_batch` takes whole fleets as stacked
    arrays from the batched engine (:mod:`repro.core.fleet_engine`) —
    10k+ devices without 10k Python objects.  :meth:`summary` folds both.
    """

    def __init__(self, price_usd_per_kwh: float = 0.35):
        self.price = price_usd_per_kwh
        self.ledgers: Dict[str, EnergyLedger] = {}
        self.calibrations: Dict[str, CalibrationRecord] = {}
        # (energies_j, sigmas_j, duration_s, labels)
        self._batches: List[tuple] = []

    def register(self, ledger: EnergyLedger,
                 calib: Optional[CalibrationRecord] = None) -> None:
        self.ledgers[ledger.device_id] = ledger
        if calib is not None:
            self.calibrations[calib.device_id] = calib

    def register_batch(self, energies_j: np.ndarray,
                       sigmas_j: Optional[np.ndarray] = None,
                       duration_s: float = 0.0,
                       calibrated: bool = False,
                       labels: Optional[np.ndarray] = None) -> None:
        """Array-native registration for fleet-scale audits.

        ``sigmas_j`` defaults to the same per-device model as the object
        path: 5 % shunt tolerance uncalibrated, 1 % calibrated floor.
        ``labels`` optionally tags each device with its workload scenario
        (one string, or [N]) for :meth:`by_label` breakdowns.
        """
        e = np.asarray(energies_j, dtype=np.float64)
        if sigmas_j is None:
            s = (CALIBRATED_TOLERANCE if calibrated else SHUNT_TOLERANCE) * e
        else:
            s = np.broadcast_to(
                np.asarray(sigmas_j, dtype=np.float64), e.shape).copy()
        if labels is None:
            lab = None
        else:
            lab = np.broadcast_to(np.asarray(labels, dtype=object),
                                  e.shape).copy()
        self._batches.append((e, s, float(duration_s), lab))

    def register_monitor(self, monitor, t: Optional[float] = None,
                         corrected: bool = True) -> None:
        """Fold a live :class:`repro.core.stream.MonitorService` snapshot
        into the ledger — the online counterpart of
        :meth:`register_batch`.

        Per-device energies come from ``monitor.fleet_energy(t)``
        (devices outside ring coverage contribute nothing), sigmas use
        the calibrated tolerance for gain-calibrated devices and the
        shunt tolerance otherwise, and the monitor's workload labels
        flow into :meth:`by_label`.
        """
        fe = monitor.fleet_energy(t, corrected=corrected)
        e = np.where(fe.covered, np.nan_to_num(fe.per_device_j), 0.0)
        tol = np.where(monitor.corrections.calibrated,
                       CALIBRATED_TOLERANCE, SHUNT_TOLERANCE)
        st = monitor.state
        if np.any(st.has):
            dur = float(np.max(st.last_t[st.has])
                        - np.min(st.first_t[st.has]))
        else:
            dur = 0.0
        self.register_batch(e, sigmas_j=tol * np.abs(e), duration_s=dur,
                            labels=monitor.labels)

    def _device_sigma(self, device_id: str, energy_j: float) -> float:
        calib = self.calibrations.get(device_id)
        if calib is not None and calib.gain is not None:
            return CALIBRATED_TOLERANCE * energy_j
        return SHUNT_TOLERANCE * energy_j

    def summary(self) -> FleetSummary:
        """Fold object-path ledgers and array batches into one summary.

        ``mean_power_w`` treats registered groups as *concurrent*: each
        group (one per-device ledger, or one registered batch) converts
        its energy to power over its *own* duration, and the fleet draw
        is the sum.  Folding with a single shared duration (the previous
        behaviour used ``max`` across groups) understates every group
        that ran shorter than the longest one, which skewed both
        ``mean_power_w`` and the annualised-uncertainty projection
        whenever merged fleets ran for different durations.
        """
        if not self.ledgers and not self._batches:
            # an empty ledger reports a clean all-zero summary rather
            # than leaning on div-by-zero guards downstream
            return FleetSummary(0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0)
        totals = []
        sigmas = []
        mean_p = 0.0
        n_devices = len(self.ledgers)
        for dev, led in self.ledgers.items():
            e = led.total_corrected_j
            totals.append(e)
            sigmas.append(self._device_sigma(dev, e))
            if led.total_duration_s > 0:
                mean_p += e / led.total_duration_s
        total = float(np.sum(totals)) if totals else 0.0
        sig_sq = float(np.sum(np.square(sigmas))) if sigmas else 0.0
        sig_wc = float(np.sum(sigmas)) if sigmas else 0.0
        for e, s, dur, _ in self._batches:
            n_devices += len(e)
            total += float(np.sum(e))
            sig_sq += float(np.sum(np.square(s)))
            sig_wc += float(np.sum(s))
            if dur > 0:
                mean_p += float(np.sum(e)) / dur
        sig_ind = float(np.sqrt(sig_sq))
        kwh = total / 3.6e6
        # annualised uncertainty if this fleet ran at this mean power all year
        annual_kwh_sigma = (sig_wc / max(total, 1e-9)) * mean_p * 8760.0 / 1000.0
        return FleetSummary(
            n_devices=n_devices,
            total_j=total,
            sigma_independent_j=sig_ind,
            sigma_worstcase_j=sig_wc,
            mean_power_w=mean_p,
            kwh=kwh,
            cost_usd=kwh * self.price,
            cost_sigma_usd=(sig_wc / 3.6e6) * self.price,
            annual_cost_uncertainty_usd=annual_kwh_sigma * self.price,
        )

    def by_label(self) -> Dict[str, FleetSummary]:
        """Per-scenario fleet summaries over labelled batches.

        Groups every batch-registered device by its workload label (the
        paper's Fig. 18 spread as an accounting column: which job classes
        carry the energy, and the uncertainty, of the bill).  Unlabelled
        batch devices fall under ``"(unlabelled)"``; object-path ledgers
        are not labelled and are excluded.
        """
        groups: Dict[str, List[tuple]] = {}
        for e, s, dur, lab in self._batches:
            if lab is None:
                groups.setdefault("(unlabelled)", []).append((e, s, dur))
                continue
            for label in sorted(set(lab.tolist())):
                sel = lab == label
                groups.setdefault(str(label), []).append(
                    (e[sel], s[sel], dur))
        out: Dict[str, FleetSummary] = {}
        for label, parts in sorted(groups.items()):
            sub = FleetLedger(price_usd_per_kwh=self.price)
            for e, s, dur in parts:
                sub._batches.append((e, s, dur, None))
            out[label] = sub.summary()
        return out


def datacenter_projection(n_gpus: int = 10_000, tdp_w: float = 700.0,
                          gain_tol: float = 0.05, duty: float = 0.8,
                          price_usd_per_kwh: float = 0.35) -> dict:
    """The paper's headline: ±5 % of 700 W ≈ ±30 W per GPU; for a 10k-GPU
    centre that is ~$1M/yr of unaccounted electricity."""
    err_w = gain_tol * tdp_w
    fleet_err_w = err_w * n_gpus * duty
    annual_kwh = fleet_err_w * 8760.0 / 1000.0
    return {
        "per_gpu_err_w": err_w,
        "fleet_err_mw": fleet_err_w / 1e6,
        "annual_err_kwh": annual_kwh,
        "annual_err_usd": annual_kwh * price_usd_per_kwh,
    }
