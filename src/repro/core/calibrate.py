"""Per-device calibration records and a persistent store.

The framework's stance (from the paper's conclusions): never trust a power
sensor you have not characterised.  At job start the launcher runs (or
loads a cached) characterisation per device class and threads the
:class:`CalibrationRecord` into every meter and ledger.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Dict, Optional

from repro.common.logging import get_logger

log = get_logger("calibrate")


@dataclasses.dataclass(frozen=True)
class CalibrationRecord:
    device_id: str
    profile_name: str
    update_period_s: float
    window_s: Optional[float]          # None => logarithmic-transient class
    transient_kind: str                # instant | linear | logarithmic
    rise_time_s: float
    gain: Optional[float] = None       # None when no ground-truth meter
    offset_w: Optional[float] = None
    r2: Optional[float] = None
    sampled_fraction: float = 1.0
    created_at: float = 0.0
    # -- provenance metadata (all optional: records persisted before
    # these fields existed load with the defaults, via from_json's
    # schema-drift filter) ------------------------------------------------
    fitted_at: Optional[float] = None  # when the characterisation ran
    source: str = ""                   # protocol/tool that fitted it
    note: str = ""                     # free-form operator annotation

    @property
    def correction_gain(self) -> float:
        """The gain to invert when applying this calibration (1.0 when
        the record was built without a ground-truth meter)."""
        return self.gain if self.gain else 1.0

    @property
    def correction_offset_w(self) -> float:
        return self.offset_w or 0.0

    @property
    def time_shift_s(self) -> float:
        """The §5 re-synchronisation shift: a reading at ``t`` covers the
        trailing averaging window, so reported timestamps move back by
        the window (or one update period for window-less transients)."""
        return self.window_s if self.window_s else self.update_period_s

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), indent=2)

    @classmethod
    def from_json(cls, s: str) -> "CalibrationRecord":
        """Load a persisted record, tolerating schema drift.

        Stores outlive the code that wrote them: a record persisted
        before a field was added (the new field falls back to its
        dataclass default), or after one was removed (the stale key is
        dropped), must still load — that is the module's "load a cached
        characterisation" contract.  Only fields without defaults are
        truly required.
        """
        data = json.loads(s)
        if not isinstance(data, dict):
            raise ValueError("calibration record must be a JSON object, "
                             f"got {type(data).__name__}")
        fields = {f.name: f for f in dataclasses.fields(cls)}
        unknown = sorted(set(data) - set(fields))
        if unknown:
            log.info("dropping unknown calibration fields",
                     fields=",".join(unknown))
        required = [n for n, f in fields.items()
                    if f.default is dataclasses.MISSING
                    and f.default_factory is dataclasses.MISSING]
        missing = sorted(set(required) - set(data))
        if missing:
            raise ValueError("calibration record missing required "
                             f"field(s): {', '.join(missing)}")
        return cls(**{k: v for k, v in data.items() if k in fields})


def nominal_record(device_id: str, profile) -> CalibrationRecord:
    """A synthetic record from a profile's *nominal* catalog parameters.

    No measured gain/offset (the device is uncalibrated — correction
    inverts nothing); rise time defaults to 2.5 update periods.  This is
    the record ``fleet_audit(good_practice=True)`` and the streaming
    monitor's :func:`repro.core.stream.default_calibrations` both build
    when no measured characterisation is supplied — one recipe, so the
    offline protocol and the online monitor stay in lock-step.
    """
    return CalibrationRecord(
        device_id, profile.name, profile.update_period_s,
        profile.window_s, "instant", 2.5 * profile.update_period_s,
        sampled_fraction=profile.sampled_fraction)


def record_from_characterisation(device_id: str, profile_name: str,
                                 result) -> CalibrationRecord:
    """Build a record from microbench.CharacterisationResult."""
    return CalibrationRecord(
        device_id=device_id,
        profile_name=profile_name,
        update_period_s=result.update_period_s,
        window_s=result.window_s,
        transient_kind=result.transient.kind,
        rise_time_s=(result.transient.rise_time_s
                     if result.transient.kind != "instant"
                     else result.update_period_s * 2.5),
        gain=result.gain,
        offset_w=result.offset_w,
        r2=result.r2,
        sampled_fraction=result.sampled_fraction,
        created_at=time.time(),
        fitted_at=time.time(),
        source="microbench.characterise",
    )


class CalibrationStore:
    """JSON-file-backed store, one file per device id."""

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self._cache: Dict[str, CalibrationRecord] = {}

    def _path(self, device_id: str) -> str:
        safe = device_id.replace("/", "_")
        return os.path.join(self.root, f"{safe}.json")

    def get(self, device_id: str) -> Optional[CalibrationRecord]:
        if device_id in self._cache:
            return self._cache[device_id]
        p = self._path(device_id)
        if os.path.exists(p):
            with open(p) as f:
                rec = CalibrationRecord.from_json(f.read())
            self._cache[device_id] = rec
            return rec
        return None

    def put(self, rec: CalibrationRecord) -> None:
        self._cache[rec.device_id] = rec
        with open(self._path(rec.device_id), "w") as f:
            f.write(rec.to_json())

    def get_or_characterise(self, device_id: str, sensor, meter=None,
                            profile_name: str = "") -> CalibrationRecord:
        rec = self.get(device_id)
        if rec is not None:
            return rec
        from repro.core.microbench import characterise
        log.info("characterising sensor", device=device_id)
        result = characterise(sensor, meter)
        rec = record_from_characterisation(
            device_id, profile_name or sensor.profile.name, result)
        self.put(rec)
        return rec
