"""Ground-truth power: activity timelines and the external-meter analogue.

The paper scores nvidia-smi against an ElmorLabs PMD (shunt-resistor meter,
5 kHz effective sampling, 12-bit ADC).  Here the physical truth is an
:class:`ActivityTimeline` — a piecewise-constant power profile derived from
either (a) a synthetic benchmark load (square wave / step / plateaus) or
(b) the roofline activity model of a compiled training/serving step.
:class:`GroundTruthMeter` plays the PMD role: a quantised, noisy, finite-
rate sampling of the timeline, *plus* the exact analytic integral used for
scoring (the paper's "ground truth" column).

Fleet studies need N *different* truths at once — every device in a data
centre runs its own job — so :class:`TimelineBank` stacks N piecewise-
constant traces as padded ``[N, S]`` edge/power arrays with the same
analytics (``power_at`` / ``integral`` / ``mean_power``) vectorised over
``[N, M]`` query matrices.  Row ``i`` of a bank is *bitwise* equivalent to
the scalar :class:`ActivityTimeline` it was built from: padding repeats
each row's final edge (zero-width idle segments that contribute nothing),
and the row-wise searchsorted is an exact-comparison binary search, so no
value is ever perturbed.  ``ActivityTimeline`` stays the N=1 reference
view, round-tripping through ``TimelineBank.from_timelines`` / ``.row``.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.common.config import Config
from repro.core.engine_backend.numpy_backend import (
    searchsorted_rows as batch_searchsorted, timeline_integral)
from repro.core.engine_backend.pytrees import TimelineArrays


@dataclasses.dataclass(frozen=True)
class ActivityTimeline:
    """Piecewise-constant power profile P(t).

    ``edges`` has n+1 monotonically increasing entries (seconds);
    ``powers`` has n entries (watts) — ``powers[i]`` holds on
    ``[edges[i], edges[i+1])``.  Outside the covered range the profile is
    ``idle_w``.
    """

    edges: np.ndarray
    powers: np.ndarray
    idle_w: float = 60.0

    def __post_init__(self):
        e = np.asarray(self.edges, dtype=np.float64)
        p = np.asarray(self.powers, dtype=np.float64)
        if e.ndim != 1 or p.ndim != 1 or e.shape[0] != p.shape[0] + 1:
            raise ValueError(f"bad timeline shapes {e.shape} {p.shape}")
        if np.any(np.diff(e) < -1e-12):
            raise ValueError("edges must be non-decreasing")
        object.__setattr__(self, "edges", e)
        object.__setattr__(self, "powers", p)

    # -- queries ----------------------------------------------------------
    @property
    def t_end(self) -> float:
        return float(self.edges[-1])

    @property
    def t_start(self) -> float:
        return float(self.edges[0])

    def power_at(self, t: np.ndarray) -> np.ndarray:
        """Vectorised P(t)."""
        t = np.asarray(t, dtype=np.float64)
        idx = np.searchsorted(self.edges, t, side="right") - 1
        out = np.full(t.shape, self.idle_w, dtype=np.float64)
        inside = (idx >= 0) & (idx < len(self.powers)) & (t < self.edges[-1])
        out[inside] = self.powers[idx[inside]]
        return out

    def _cum_energy(self) -> np.ndarray:
        seg = self.powers * np.diff(self.edges)
        return np.concatenate([[0.0], np.cumsum(seg)])

    def integral(self, t0: np.ndarray, t1: np.ndarray) -> np.ndarray:
        """Exact ∫P dt over [t0, t1] (vectorised), idle outside coverage."""
        t0 = np.asarray(t0, dtype=np.float64)
        t1 = np.asarray(t1, dtype=np.float64)
        cum = self._cum_energy()

        def eval_I(t):
            tc = np.clip(t, self.edges[0], self.edges[-1])
            idx = np.clip(np.searchsorted(self.edges, tc, side="right") - 1,
                          0, len(self.powers) - 1)
            inner = cum[idx] + self.powers[idx] * (tc - self.edges[idx])
            # idle contribution outside the covered range
            before = np.minimum(t - self.edges[0], 0.0) * self.idle_w
            after = np.maximum(t - self.edges[-1], 0.0) * self.idle_w
            return inner + before + after

        return eval_I(t1) - eval_I(t0)

    def mean_power(self, t0: np.ndarray, t1: np.ndarray) -> np.ndarray:
        t0 = np.asarray(t0, dtype=np.float64)
        t1 = np.asarray(t1, dtype=np.float64)
        dt = np.maximum(t1 - t0, 1e-12)
        return self.integral(t0, t1) / dt

    def energy(self, t0: float | None = None, t1: float | None = None) -> float:
        """Analytic ground-truth energy in joules."""
        if t0 is None:
            t0 = self.t_start
        if t1 is None:
            t1 = self.t_end
        return float(self.integral(np.asarray(t0), np.asarray(t1)))

    # -- composition ------------------------------------------------------
    def shift(self, dt: float) -> "ActivityTimeline":
        return ActivityTimeline(self.edges + dt, self.powers, self.idle_w)

    def with_idle(self, idle_w: float) -> "ActivityTimeline":
        return ActivityTimeline(self.edges, self.powers, idle_w)

    @staticmethod
    def concat(parts: Sequence["ActivityTimeline"], gap_s: float = 0.0,
               idle_w: float | None = None) -> "ActivityTimeline":
        """Concatenate fragments back-to-back (each re-based to follow the
        previous one), inserting ``gap_s`` of idle between them."""
        if not parts:
            raise ValueError("no parts")
        idle = parts[0].idle_w if idle_w is None else idle_w
        edges: List[float] = []
        powers: List[float] = []
        cursor = parts[0].t_start
        for i, p in enumerate(parts):
            dur = p.t_end - p.t_start
            if i > 0 and gap_s > 0:
                edges.append(cursor)
                powers.append(idle)
                cursor += gap_s
            # rebase the fragment so it starts exactly at the cursor
            seg_edges = p.edges + (cursor - p.t_start)
            edges.extend(seg_edges[:-1].tolist())
            powers.extend(p.powers.tolist())
            cursor += dur
        edges.append(cursor)
        return ActivityTimeline(np.asarray(edges), np.asarray(powers), idle)

    def repeat(self, n: int, gap_s: float = 0.0) -> "ActivityTimeline":
        return ActivityTimeline.concat([self] * n, gap_s=gap_s)


def from_segments(segments: Iterable[Tuple[float, float]],
                  t0: float = 0.0, idle_w: float = 60.0) -> ActivityTimeline:
    """Build a timeline from (duration_s, power_w) segments starting at t0."""
    edges = [t0]
    powers = []
    for dur, watts in segments:
        if dur < 0:
            raise ValueError("negative segment duration")
        powers.append(watts)
        edges.append(edges[-1] + dur)
    return ActivityTimeline(np.asarray(edges), np.asarray(powers), idle_w)


# Row-wise exact binary search now lives with the other pure array
# kernels in the backend package; re-exported here because this is its
# historical home and the substrate's tests pin its bitwise contract.
# (`batch_searchsorted` is `engine_backend.numpy_backend.searchsorted_rows`.)


@dataclasses.dataclass(frozen=True)
class TimelineBank:
    """N piecewise-constant power traces as stacked, padded arrays.

    ``edges`` is [N, S+1] (non-decreasing per row), ``powers`` [N, S],
    ``idle_w`` and ``n_segs`` are [N].  Row ``i`` uses its first
    ``n_segs[i]`` segments; padding slots repeat the row's final edge
    (zero-width) and hold ``idle_w[i]`` — both are normalised on
    construction, so hand-built arrays only need valid prefixes.

    Analytics mirror :class:`ActivityTimeline` operation-for-operation and
    are bitwise equal on each row.  Query shapes: a scalar broadcasts to
    every row (returns [N]); a [N] vector is one instant per row (returns
    [N]); a [G, M] matrix is per-row query grids (returns [G, M], where G
    must equal N unless the bank has a single row, which broadcasts).
    """

    edges: np.ndarray
    powers: np.ndarray
    idle_w: np.ndarray
    n_segs: np.ndarray

    def __post_init__(self):
        e = np.array(np.asarray(self.edges, dtype=np.float64), copy=True)
        p = np.array(np.asarray(self.powers, dtype=np.float64), copy=True)
        idle = np.asarray(self.idle_w, dtype=np.float64)
        ns = np.asarray(self.n_segs, dtype=np.int64)
        if e.ndim != 2 or p.ndim != 2 or e.shape != (p.shape[0],
                                                     p.shape[1] + 1):
            raise ValueError(f"bad bank shapes {e.shape} {p.shape}")
        n, s = p.shape
        if n == 0:
            raise ValueError("empty TimelineBank (no rows)")
        if idle.shape != (n,) or ns.shape != (n,):
            raise ValueError(f"idle_w/n_segs must be [{n}], got "
                             f"{idle.shape} {ns.shape}")
        if np.any(ns < 1) or np.any(ns > s):
            raise ValueError(f"n_segs must be within [1, {s}] "
                             "(a row needs at least one segment)")
        # normalise padding: repeat the final valid edge, idle power
        cols = np.arange(s + 1)[None, :]
        last = np.take_along_axis(e, ns[:, None], axis=1)
        e = np.where(cols > ns[:, None], last, e)
        p = np.where(cols[:, :s] >= ns[:, None], idle[:, None], p)
        if np.any(np.diff(e, axis=1) < -1e-12):
            raise ValueError("edges must be non-decreasing per row")
        object.__setattr__(self, "edges", e)
        object.__setattr__(self, "powers", p)
        object.__setattr__(self, "idle_w", idle)
        object.__setattr__(self, "n_segs", ns)

    # -- construction / views ---------------------------------------------
    @staticmethod
    def from_timelines(timelines: Sequence[ActivityTimeline]) -> "TimelineBank":
        """Stack scalar timelines into a bank (``row(i)`` round-trips)."""
        tls = list(timelines)
        if not tls:
            raise ValueError("empty TimelineBank (no timelines)")
        ns = np.array([len(t.powers) for t in tls], dtype=np.int64)
        s = int(ns.max())
        n = len(tls)
        edges = np.empty((n, s + 1))
        powers = np.empty((n, s))
        idle = np.array([t.idle_w for t in tls])
        for i, t in enumerate(tls):
            k = len(t.powers)
            edges[i, :k + 1] = t.edges
            edges[i, k + 1:] = t.edges[-1]
            powers[i, :k] = t.powers
            powers[i, k:] = t.idle_w
        return TimelineBank(edges, powers, idle, ns)

    @staticmethod
    def from_timeline(timeline: ActivityTimeline, n: int,
                      shifts: Optional[np.ndarray] = None) -> "TimelineBank":
        """Broadcast one timeline to ``n`` rows, optionally shifted per row
        (row ``i`` is ``timeline.shift(shifts[i])``)."""
        if n < 1:
            raise ValueError("empty TimelineBank (n < 1)")
        s = len(timeline.powers)
        edges = np.tile(timeline.edges, (n, 1))
        if shifts is not None:
            edges = edges + np.asarray(shifts, dtype=np.float64)[:, None]
        return TimelineBank(edges, np.tile(timeline.powers, (n, 1)),
                            np.full(n, timeline.idle_w),
                            np.full(n, max(s, 1), dtype=np.int64))

    def row(self, i: int) -> ActivityTimeline:
        """The scalar reference view of row ``i`` (exact round-trip)."""
        k = int(self.n_segs[i])
        return ActivityTimeline(self.edges[i, :k + 1].copy(),
                                self.powers[i, :k].copy(),
                                float(self.idle_w[i]))

    def rows(self, idx: np.ndarray) -> "TimelineBank":
        """A bank over a subset of rows (values sliced, not re-derived)."""
        idx = np.asarray(idx)
        return TimelineBank(self.edges[idx], self.powers[idx],
                            self.idle_w[idx], self.n_segs[idx])

    # -- introspection ----------------------------------------------------
    @property
    def n_rows(self) -> int:
        return self.edges.shape[0]

    @property
    def arrays(self) -> TimelineArrays:
        """The padded array (pytree) view consumed by the execution
        backends (:mod:`repro.core.engine_backend`) — zero-copy."""
        return TimelineArrays(self.edges, self.powers, self.idle_w,
                              self.n_segs)

    @property
    def t_start(self) -> np.ndarray:
        return self.edges[:, 0]

    @property
    def t_end(self) -> np.ndarray:
        # padding repeats each row's final edge, so the last column is it
        return self.edges[:, -1]

    @property
    def duration_s(self) -> np.ndarray:
        return self.t_end - self.t_start

    # -- composition ------------------------------------------------------
    def shift(self, dt) -> "TimelineBank":
        """Shift every row by ``dt`` (scalar) or row ``i`` by ``dt[i]``."""
        dt = np.asarray(dt, dtype=np.float64)
        if dt.ndim == 1:
            dt = dt[:, None]
        return TimelineBank(self.edges + dt, self.powers, self.idle_w,
                            self.n_segs)

    # -- queries ----------------------------------------------------------
    def _prep(self, t) -> Tuple[np.ndarray, tuple]:
        """Normalise a query to [G, M]; returns (queries, output shape)."""
        t = np.asarray(t, dtype=np.float64)
        if t.ndim == 0:
            return np.full((self.n_rows, 1), float(t)), (self.n_rows,)
        if t.ndim == 1:
            if self.n_rows == 1:
                return t[None, :], t.shape      # grid on the single row
            if t.shape[0] == self.n_rows:
                return t[:, None], (self.n_rows,)
            raise ValueError(f"1-D query of length {t.shape[0]} for "
                             f"{self.n_rows} rows (pass [N] or [N, M])")
        if t.ndim == 2:
            if t.shape[0] == 1 and self.n_rows > 1:   # shared query grid
                t = np.broadcast_to(t, (self.n_rows, t.shape[1]))
            if t.shape[0] == self.n_rows or self.n_rows == 1:
                return t, t.shape
        raise ValueError(f"bad query shape {t.shape} for {self.n_rows} rows")

    def _row_arrays(self, g: int):
        """edges/powers/idle/n_segs broadcast to ``g`` query rows."""
        e, p = self.edges, self.powers
        idle, ns = self.idle_w, self.n_segs
        if self.n_rows == 1 and g > 1:
            e = np.broadcast_to(e, (g, e.shape[1]))
            p = np.broadcast_to(p, (g, p.shape[1]))
            idle = np.broadcast_to(idle, (g,))
            ns = np.broadcast_to(ns, (g,))
        elif self.n_rows != g:
            raise ValueError(f"{g} query rows for {self.n_rows} bank rows")
        return e, p, idle, ns

    def power_at(self, t) -> np.ndarray:
        """Vectorised P_i(t): same semantics as the scalar ``power_at``
        applied to each row."""
        tq, out_shape = self._prep(t)
        e, p, idle, ns = self._row_arrays(tq.shape[0])
        idx = batch_searchsorted(e, tq, "right") - 1
        vals = np.take_along_axis(p, np.clip(idx, 0, p.shape[1] - 1), axis=1)
        inside = ((idx >= 0) & (idx < ns[:, None])
                  & (tq < e[:, -1][:, None]))
        out = np.where(inside, vals, idle[:, None])
        return out.reshape(out_shape)

    def integral(self, t0, t1) -> np.ndarray:
        """Exact per-row ∫P_i dt over [t0_i, t1_i], idle outside coverage.

        The array math lives in the backend kernel
        (:func:`repro.core.engine_backend.numpy_backend.timeline_integral`)
        shared with the fleet engine; this method only normalises query
        shapes."""
        tq0, sh0 = self._prep(t0)
        tq1, sh1 = self._prep(t1)
        tq0, tq1 = np.broadcast_arrays(tq0, tq1)
        out_shape = sh1 if len(sh1) >= len(sh0) else sh0
        if self.n_rows not in (1, tq0.shape[0]):
            raise ValueError(f"{tq0.shape[0]} query rows for "
                             f"{self.n_rows} bank rows")
        return timeline_integral(self.arrays, tq0, tq1).reshape(out_shape)

    def mean_power(self, t0, t1) -> np.ndarray:
        dt = np.maximum(np.asarray(t1, dtype=np.float64)
                        - np.asarray(t0, dtype=np.float64), 1e-12)
        return self.integral(t0, t1) / dt

    def energy(self, t0=None, t1=None) -> np.ndarray:
        """Analytic per-row ground-truth energy [N] in joules."""
        if t0 is None:
            t0 = self.t_start
        if t1 is None:
            t1 = self.t_end
        return self.integral(t0, t1)


class MeterConfig(Config):
    pass


@dataclasses.dataclass(frozen=True)
class GroundTruthMeter:
    """PMD analogue: finite-rate, quantised, noisy sampling of the truth.

    Quantisation mirrors the PMD hardware: 12-bit ADC, 0–31 V
    (7.568 mV/level) and 0–200 A (48.8 mA/level) at a 12 V rail.
    """

    sample_hz: float = 5000.0
    volt_per_level: float = 0.007568
    amp_per_level: float = 0.0488
    rail_volts: float = 12.0
    noise_w: float = 0.3
    seed: int = 0

    def trace(self, timeline: ActivityTimeline, t0: float | None = None,
              t1: float | None = None) -> Tuple[np.ndarray, np.ndarray]:
        """Sampled (times, watts) like the PMD raw logger."""
        if t0 is None:
            t0 = timeline.t_start
        if t1 is None:
            t1 = timeline.t_end
        n = max(2, int(round((t1 - t0) * self.sample_hz)))
        ts = t0 + np.arange(n) / self.sample_hz
        p = timeline.power_at(ts)
        rng = np.random.default_rng(self.seed)
        # quantise through the ADC model: volts exact-ish, amps coarse
        volts = np.round(self.rail_volts / self.volt_per_level) * self.volt_per_level
        amps = p / self.rail_volts
        amps = np.round(amps / self.amp_per_level) * self.amp_per_level
        watts = volts * amps + rng.normal(0.0, self.noise_w, size=n)
        return ts, watts

    def energy(self, timeline: ActivityTimeline, t0: float | None = None,
               t1: float | None = None) -> float:
        """Energy integrated from the sampled trace (what the paper's PMD
        reports); close to but not exactly the analytic truth."""
        ts, watts = self.trace(timeline, t0, t1)
        return float(np.trapezoid(watts, ts))

    def energy_batch(self, bank: TimelineBank,
                     t0: Optional[np.ndarray] = None,
                     t1: Optional[np.ndarray] = None,
                     chunk_rows: Optional[int] = None) -> np.ndarray:
        """Per-row PMD energies [N] for a whole :class:`TimelineBank`.

        Row ``i`` draws its ADC noise from ``default_rng(seed + i)``, so it
        equals ``GroundTruthMeter(..., seed=seed + i).energy(bank.row(i))``
        bitwise — one meter per device, not one shared noise stream.  The
        trace sampling itself (the expensive part) is one batched
        ``power_at`` over a padded [chunk, M] grid, processed in row
        slabs of ``chunk_rows`` (default: sized to keep the 5 kHz sample
        grid around ~128 MB) so fleet-scale banks never materialise the
        full [N, M] trace matrix; results are identical under any
        chunking.
        """
        n = bank.n_rows
        t0 = bank.t_start if t0 is None else np.broadcast_to(
            np.asarray(t0, dtype=np.float64), (n,))
        t1 = bank.t_end if t1 is None else np.broadcast_to(
            np.asarray(t1, dtype=np.float64), (n,))
        counts = np.maximum(
            2, np.round((t1 - t0) * self.sample_hz).astype(np.int64))
        m = int(counts.max())
        if chunk_rows is None:
            chunk_rows = max(1, 16_000_000 // max(m, 1))
        volts = (np.round(self.rail_volts / self.volt_per_level)
                 * self.volt_per_level)
        out = np.empty(n)
        for lo in range(0, n, chunk_rows):
            hi = min(lo + chunk_rows, n)
            # row i's first counts[i] instants match the scalar trace() grid
            ts = t0[lo:hi, None] + np.arange(m)[None, :] / self.sample_hz
            p = bank.rows(np.arange(lo, hi)).power_at(ts)
            amps = p / self.rail_volts
            amps = np.round(amps / self.amp_per_level) * self.amp_per_level
            watts = volts * amps
            for g, i in enumerate(range(lo, hi)):
                k = int(counts[i])
                rng = np.random.default_rng(self.seed + i)
                w = watts[g, :k] + rng.normal(0.0, self.noise_w, size=k)
                out[i] = np.trapezoid(w, ts[g, :k])
        return out
