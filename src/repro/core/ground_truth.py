"""Ground-truth power: activity timelines and the external-meter analogue.

The paper scores nvidia-smi against an ElmorLabs PMD (shunt-resistor meter,
5 kHz effective sampling, 12-bit ADC).  Here the physical truth is an
:class:`ActivityTimeline` — a piecewise-constant power profile derived from
either (a) a synthetic benchmark load (square wave / step / plateaus) or
(b) the roofline activity model of a compiled training/serving step.
:class:`GroundTruthMeter` plays the PMD role: a quantised, noisy, finite-
rate sampling of the timeline, *plus* the exact analytic integral used for
scoring (the paper's "ground truth" column).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List, Sequence, Tuple

import numpy as np

from repro.common.config import Config


@dataclasses.dataclass(frozen=True)
class ActivityTimeline:
    """Piecewise-constant power profile P(t).

    ``edges`` has n+1 monotonically increasing entries (seconds);
    ``powers`` has n entries (watts) — ``powers[i]`` holds on
    ``[edges[i], edges[i+1])``.  Outside the covered range the profile is
    ``idle_w``.
    """

    edges: np.ndarray
    powers: np.ndarray
    idle_w: float = 60.0

    def __post_init__(self):
        e = np.asarray(self.edges, dtype=np.float64)
        p = np.asarray(self.powers, dtype=np.float64)
        if e.ndim != 1 or p.ndim != 1 or e.shape[0] != p.shape[0] + 1:
            raise ValueError(f"bad timeline shapes {e.shape} {p.shape}")
        if np.any(np.diff(e) < -1e-12):
            raise ValueError("edges must be non-decreasing")
        object.__setattr__(self, "edges", e)
        object.__setattr__(self, "powers", p)

    # -- queries ----------------------------------------------------------
    @property
    def t_end(self) -> float:
        return float(self.edges[-1])

    @property
    def t_start(self) -> float:
        return float(self.edges[0])

    def power_at(self, t: np.ndarray) -> np.ndarray:
        """Vectorised P(t)."""
        t = np.asarray(t, dtype=np.float64)
        idx = np.searchsorted(self.edges, t, side="right") - 1
        out = np.full(t.shape, self.idle_w, dtype=np.float64)
        inside = (idx >= 0) & (idx < len(self.powers)) & (t < self.edges[-1])
        out[inside] = self.powers[idx[inside]]
        return out

    def _cum_energy(self) -> np.ndarray:
        seg = self.powers * np.diff(self.edges)
        return np.concatenate([[0.0], np.cumsum(seg)])

    def integral(self, t0: np.ndarray, t1: np.ndarray) -> np.ndarray:
        """Exact ∫P dt over [t0, t1] (vectorised), idle outside coverage."""
        t0 = np.asarray(t0, dtype=np.float64)
        t1 = np.asarray(t1, dtype=np.float64)
        cum = self._cum_energy()

        def eval_I(t):
            tc = np.clip(t, self.edges[0], self.edges[-1])
            idx = np.clip(np.searchsorted(self.edges, tc, side="right") - 1,
                          0, len(self.powers) - 1)
            inner = cum[idx] + self.powers[idx] * (tc - self.edges[idx])
            # idle contribution outside the covered range
            before = np.minimum(t - self.edges[0], 0.0) * self.idle_w
            after = np.maximum(t - self.edges[-1], 0.0) * self.idle_w
            return inner + before + after

        return eval_I(t1) - eval_I(t0)

    def mean_power(self, t0: np.ndarray, t1: np.ndarray) -> np.ndarray:
        t0 = np.asarray(t0, dtype=np.float64)
        t1 = np.asarray(t1, dtype=np.float64)
        dt = np.maximum(t1 - t0, 1e-12)
        return self.integral(t0, t1) / dt

    def energy(self, t0: float | None = None, t1: float | None = None) -> float:
        """Analytic ground-truth energy in joules."""
        if t0 is None:
            t0 = self.t_start
        if t1 is None:
            t1 = self.t_end
        return float(self.integral(np.asarray(t0), np.asarray(t1)))

    # -- composition ------------------------------------------------------
    def shift(self, dt: float) -> "ActivityTimeline":
        return ActivityTimeline(self.edges + dt, self.powers, self.idle_w)

    def with_idle(self, idle_w: float) -> "ActivityTimeline":
        return ActivityTimeline(self.edges, self.powers, idle_w)

    @staticmethod
    def concat(parts: Sequence["ActivityTimeline"], gap_s: float = 0.0,
               idle_w: float | None = None) -> "ActivityTimeline":
        """Concatenate fragments back-to-back (each re-based to follow the
        previous one), inserting ``gap_s`` of idle between them."""
        if not parts:
            raise ValueError("no parts")
        idle = parts[0].idle_w if idle_w is None else idle_w
        edges: List[float] = []
        powers: List[float] = []
        cursor = parts[0].t_start
        for i, p in enumerate(parts):
            dur = p.t_end - p.t_start
            if i > 0 and gap_s > 0:
                edges.append(cursor)
                powers.append(idle)
                cursor += gap_s
            # rebase the fragment so it starts exactly at the cursor
            seg_edges = p.edges + (cursor - p.t_start)
            edges.extend(seg_edges[:-1].tolist())
            powers.extend(p.powers.tolist())
            cursor += dur
        edges.append(cursor)
        return ActivityTimeline(np.asarray(edges), np.asarray(powers), idle)

    def repeat(self, n: int, gap_s: float = 0.0) -> "ActivityTimeline":
        return ActivityTimeline.concat([self] * n, gap_s=gap_s)


def from_segments(segments: Iterable[Tuple[float, float]],
                  t0: float = 0.0, idle_w: float = 60.0) -> ActivityTimeline:
    """Build a timeline from (duration_s, power_w) segments starting at t0."""
    edges = [t0]
    powers = []
    for dur, watts in segments:
        if dur < 0:
            raise ValueError("negative segment duration")
        powers.append(watts)
        edges.append(edges[-1] + dur)
    return ActivityTimeline(np.asarray(edges), np.asarray(powers), idle_w)


class MeterConfig(Config):
    pass


@dataclasses.dataclass(frozen=True)
class GroundTruthMeter:
    """PMD analogue: finite-rate, quantised, noisy sampling of the truth.

    Quantisation mirrors the PMD hardware: 12-bit ADC, 0–31 V
    (7.568 mV/level) and 0–200 A (48.8 mA/level) at a 12 V rail.
    """

    sample_hz: float = 5000.0
    volt_per_level: float = 0.007568
    amp_per_level: float = 0.0488
    rail_volts: float = 12.0
    noise_w: float = 0.3
    seed: int = 0

    def trace(self, timeline: ActivityTimeline, t0: float | None = None,
              t1: float | None = None) -> Tuple[np.ndarray, np.ndarray]:
        """Sampled (times, watts) like the PMD raw logger."""
        if t0 is None:
            t0 = timeline.t_start
        if t1 is None:
            t1 = timeline.t_end
        n = max(2, int(round((t1 - t0) * self.sample_hz)))
        ts = t0 + np.arange(n) / self.sample_hz
        p = timeline.power_at(ts)
        rng = np.random.default_rng(self.seed)
        # quantise through the ADC model: volts exact-ish, amps coarse
        volts = np.round(self.rail_volts / self.volt_per_level) * self.volt_per_level
        amps = p / self.rail_volts
        amps = np.round(amps / self.amp_per_level) * self.amp_per_level
        watts = volts * amps + rng.normal(0.0, self.noise_w, size=n)
        return ts, watts

    def energy(self, timeline: ActivityTimeline, t0: float | None = None,
               t1: float | None = None) -> float:
        """Energy integrated from the sampled trace (what the paper's PMD
        reports); close to but not exactly the analytic truth."""
        ts, watts = self.trace(timeline, t0, t1)
        return float(np.trapezoid(watts, ts))
