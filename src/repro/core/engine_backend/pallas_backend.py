"""Pallas implementation of the streaming hot-loop kernels.

Same signatures, same semantics as
:mod:`repro.core.engine_backend.numpy_backend` — NumPy arrays in, NumPy
arrays out — with the three streaming hot loops fused into
``pl.pallas_call`` kernels:

* ``stream_ingest`` — jax prologue (per-sample parameter gathers, shift
  of previous sample/time across group firsts) feeding a 1-D blocked
  kernel that fuses the hold/window/envelope elementwise math with the
  running energy cumsums, carried across blocks in VMEM scratch; a jax
  epilogue re-bases the cumsums at group starts and does the segment
  reductions and run tracking;
* ``stream_ingest_grid`` — the rectangular fast path: one fused
  row-block kernel per device block computing everything (cumulative
  energies, window overlaps, run tracking via an in-kernel ``cummax``
  over change columns, and the per-device moment reductions) in a
  single pass over the ``[block_d, M]`` slab;
* ``step_integrate`` — row-blocked kernel; the window edges are located
  by counting (``sum(ts < t0)``), which equals binary search on the
  sorted, inf-padded rows but vectorises cleanly inside the kernel;
* ``log_filter`` — the affine recurrence ``y_{i+1} = a_i·y_i + b_i`` as
  a blocked sequential scan over segment chunks (grid iterates the
  segment axis innermost; VMEM scratch carries the filter state), the
  same idiom as :mod:`repro.kernels.rglru_scan`.

Gather-bound kernels with no streaming inner loop (``boxcar_means``,
``poll_counts``, ``query_slots``, …) delegate to the jax tier — they are
binary-search + take_along_axis compositions XLA already fuses well, and
a Pallas rewrite would only re-derive the same gathers.

All kernels run under ``interpret=True`` when no accelerator is present
(or when ``REPRO_PALLAS_INTERPRET`` is set), so the tier is exercised on
CPU-only CI with identical float64 semantics.  Kernel construction
happens inside ``jax.jit`` so each (shape, flags) combination compiles
once and replays from the jit cache.
"""
from __future__ import annotations

import functools
import os
from typing import Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import enable_x64
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.engine_backend import jax_backend as _jb
from repro.core.engine_backend import numpy_backend as _nb

name = "pallas"

# block sizes: 1-D ingest blocks and the log-filter (chunk, group) tile
# are padded to these; the grid ingest kernel blocks only the device axis
_INGEST_BLOCK = 32768
_GRID_BLOCK_D = 4096
_SCAN_CHUNK = 64
_SCAN_BLOCK_G = 512
_STEP_BLOCK_N = 1024

# gather-bound kernels: same jitted jax implementations, re-exported
boxcar_means = _jb.boxcar_means
estimation_means = _jb.estimation_means
timeline_integral = _jb.timeline_integral
poll_counts = _jb.poll_counts
query_slots = _jb.query_slots
err_moments = _jb.err_moments
snapshot_energy_at = _jb.snapshot_energy_at


def _interpret() -> bool:
    """True when kernels should run via the Pallas interpreter.

    ``REPRO_PALLAS_INTERPRET`` overrides (any value but ``0``/``false``
    forces interpret mode, ``0`` forces compiled mode); otherwise
    interpret exactly when the default jax backend is the CPU.
    """
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env.strip().lower() not in ("", "0", "false", "no")
    return jax.default_backend() == "cpu"


def _pad_to(x, n, value):
    k = x.shape[0]
    if k == n:
        return x
    return jnp.concatenate(
        [x, jnp.full((n - k,), value, dtype=x.dtype)])


# -- stream_ingest: 1-D blocked elementwise + carried cumsums ---------------

def _ingest1d_kernel(t_ref, v_ref, pt_ref, pv_ref, has_ref, g_ref,
                     off_ref, tsh_ref, wa_ref, wb_ref, mh_ref, el_ref,
                     eh_ref, inc_ref, incc_ref, cs_ref, csc_ref,
                     cchg_ref, wi_ref, wic_ref, vc_ref, chg_ref,
                     out_ref, carry, *, trapezoid: bool):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        carry[...] = jnp.zeros_like(carry)

    t = t_ref[...]
    v = v_ref[...]
    pt = pt_ref[...]
    pv = pv_ref[...]
    has = has_ref[...]
    g = g_ref[...]
    off = off_ref[...]

    vc = (v - off) / g
    pvc = (pv - off) / g
    hold = jnp.minimum(t - pt, mh_ref[...])
    dens_r = 0.5 * (pv + v) if trapezoid else pv
    dens_c = 0.5 * (pvc + vc) if trapezoid else pvc
    inc = jnp.where(has, dens_r * hold, 0.0)
    inc_c = jnp.where(has, dens_c * hold, 0.0)

    a = wa_ref[...]
    b = wb_ref[...]
    wi_ref[...] = jnp.where(
        has & (pt >= a),
        dens_r * jnp.maximum(jnp.minimum(pt + hold, b) - pt, 0.0), 0.0)
    pts = pt - tsh_ref[...]
    wic_ref[...] = jnp.where(
        has & (pts >= a),
        dens_c * jnp.maximum(jnp.minimum(pts + hold, b) - pts, 0.0), 0.0)

    change = has & (v != pv)
    cs_l = jnp.cumsum(inc)
    csc_l = jnp.cumsum(inc_c)
    cchg_l = jnp.cumsum(change.astype(jnp.float64))
    inc_ref[...] = inc
    incc_ref[...] = inc_c
    cs_ref[...] = cs_l + carry[0]
    csc_ref[...] = csc_l + carry[1]
    cchg_ref[...] = cchg_l + carry[2]
    carry[0] = carry[0] + cs_l[-1]
    carry[1] = carry[1] + csc_l[-1]
    carry[2] = carry[2] + cchg_l[-1]
    vc_ref[...] = vc
    chg_ref[...] = change
    out_ref[...] = (vc < el_ref[...]) | (vc > eh_ref[...])


@functools.partial(jax.jit, static_argnums=(19, 20))
def _stream_ingest_impl(t, v, seg, first, start_idx, end_idx, prev_t,
                        prev_v, has_prev, run_t, n_changes, gain, offset,
                        tshift, win_a, win_b, max_hold, env_lo, env_hi,
                        trapezoid: bool, interpret: bool):
    k = t.shape[0]
    u = prev_t.shape[0]
    idx = jnp.arange(k)

    # prologue: per-sample parameter gathers + previous-sample shifts
    shift_t = jnp.concatenate([jnp.zeros(1), t[:-1]])
    shift_v = jnp.concatenate([jnp.zeros(1), v[:-1]])
    pt = jnp.where(first, prev_t[seg], shift_t)
    pv = jnp.where(first, prev_v[seg], shift_v)
    has = jnp.where(first, has_prev[seg], True)

    block = min(_INGEST_BLOCK, max(k, 1))
    kp = -(-k // block) * block
    # neutral padding: has=False zeroes the increments, gain=1 keeps the
    # division defined, the open envelope keeps the tail out of n_out
    args = (
        _pad_to(t, kp, 0.0), _pad_to(v, kp, 0.0), _pad_to(pt, kp, 0.0),
        _pad_to(pv, kp, 0.0), _pad_to(has, kp, False),
        _pad_to(gain[seg], kp, 1.0), _pad_to(offset[seg], kp, 0.0),
        _pad_to(tshift[seg], kp, 0.0),
        _pad_to(win_a[seg], kp, jnp.inf),
        _pad_to(win_b[seg], kp, -jnp.inf),
        _pad_to(max_hold[seg], kp, 0.0),
        _pad_to(env_lo[seg], kp, -jnp.inf),
        _pad_to(env_hi[seg], kp, jnp.inf))
    spec = pl.BlockSpec((block,), lambda i: (i,))
    f64 = functools.partial(jax.ShapeDtypeStruct, (kp,))
    outs = pl.pallas_call(
        functools.partial(_ingest1d_kernel, trapezoid=trapezoid),
        grid=(kp // block,),
        in_specs=[spec] * 13,
        out_specs=[spec] * 10,
        out_shape=[f64(jnp.float64)] * 7
        + [f64(jnp.float64), f64(jnp.bool_), f64(jnp.bool_)],
        scratch_shapes=[pltpu.VMEM((3,), jnp.float64)],
        interpret=interpret,
    )(*args)
    (inc, inc_c, cs, csc, cchg_f, w_inc, w_inc_c, vc, change,
     out) = (o[:k] for o in outs)
    cchg = cchg_f.astype(jnp.int64)
    chg_i = change.astype(jnp.int64)

    # epilogue: re-base the carried cumsums at group starts, segment
    # reductions, and the same ordinal-scatter run tracking as the jax
    # tier (see jax_backend._stream_ingest_impl)
    cum_e = cs - (cs[start_idx] - inc[start_idx])[seg]
    cum_ec = csc - (csc[start_idx] - inc_c[start_idx])[seg]
    d_energy = cum_e[end_idx]
    d_energy_corr = cum_ec[end_idx]
    d_win = jax.ops.segment_sum(w_inc, seg, num_segments=u)
    d_win_corr = jax.ops.segment_sum(w_inc_c, seg, num_segments=u)

    slot = jnp.where(change, cchg, k + 1)
    pch = jnp.full(k + 2, -1, dtype=jnp.int64).at[slot].set(
        jnp.where(change, idx, -1))
    tch = jnp.zeros(k + 2).at[slot].set(jnp.where(change, t, 0.0))
    prev_ord = cchg - chg_i
    gstart = start_idx[seg]
    run_start = jnp.where(pch[prev_ord] >= gstart, tch[prev_ord],
                          run_t[seg])
    run_dur = jnp.where(change, t - run_start, 0.0)
    chg_before_slab = prev_ord - (cchg - chg_i)[start_idx][seg]
    run_rec = change & (n_changes[seg] + chg_before_slab >= 1)
    ord_last = cchg[end_idx]
    new_run_t = jnp.where(pch[ord_last] >= start_idx,
                          tch[ord_last], run_t)
    new_n_changes = n_changes + jax.ops.segment_sum(
        chg_i, seg, num_segments=u)

    counts = jax.ops.segment_sum(jnp.ones(k, dtype=jnp.int64), seg,
                                 num_segments=u)
    sum_vc = jax.ops.segment_sum(vc, seg, num_segments=u)
    n_out = jax.ops.segment_sum(out.astype(jnp.int64), seg,
                                num_segments=u)

    return (t[end_idx], v[end_idx], new_run_t, new_n_changes, counts,
            d_energy, d_energy_corr, d_win, d_win_corr, sum_vc, n_out,
            cum_e, cum_ec, vc, run_dur, run_rec)


def stream_ingest(t, v, seg, first, start_idx, end_idx, prev_t, prev_v,
                  has_prev, run_t, n_changes, gain, offset, tshift,
                  win_a, win_b, max_hold, env_lo, env_hi,
                  trapezoid: bool = False) -> Tuple:
    """Streaming-monitor ingest slab (see the numpy backend's reference
    docstring); the elementwise + cumsum core runs as a blocked Pallas
    kernel with the running totals carried in VMEM scratch."""
    t = np.asarray(t, dtype=np.float64)
    if t.shape[0] == 0:
        return _nb.stream_ingest(
            t, v, seg, first, start_idx, end_idx, prev_t, prev_v,
            has_prev, run_t, n_changes, gain, offset, tshift, win_a,
            win_b, max_hold, env_lo, env_hi, trapezoid)
    with enable_x64():
        outs = _stream_ingest_impl(
            jnp.asarray(t, jnp.float64), jnp.asarray(v, jnp.float64),
            jnp.asarray(seg, jnp.int64), jnp.asarray(first, jnp.bool_),
            jnp.asarray(start_idx, jnp.int64),
            jnp.asarray(end_idx, jnp.int64),
            jnp.asarray(prev_t, jnp.float64),
            jnp.asarray(prev_v, jnp.float64),
            jnp.asarray(has_prev, jnp.bool_),
            jnp.asarray(run_t, jnp.float64),
            jnp.asarray(n_changes, jnp.int64),
            jnp.asarray(gain, jnp.float64),
            jnp.asarray(offset, jnp.float64),
            jnp.asarray(tshift, jnp.float64),
            jnp.asarray(win_a, jnp.float64),
            jnp.asarray(win_b, jnp.float64),
            jnp.asarray(max_hold, jnp.float64),
            jnp.asarray(env_lo, jnp.float64),
            jnp.asarray(env_hi, jnp.float64),
            bool(trapezoid), _interpret())
    return tuple(np.asarray(o) for o in outs)


# -- stream_ingest_grid: fused [block_d, M] row-block kernel ----------------

def _ingest_grid_kernel(ts_ref, v_ref, pt0_ref, pv0_ref, has0_ref,
                        rt_ref, nch_ref, g_ref, off_ref, tsh_ref,
                        wa_ref, wb_ref, mh_ref, el_ref, eh_ref,
                        nv_ref, nrt_ref, nnc_ref, de_ref, dec_ref,
                        dw_ref, dwc_ref, sv_ref, sv2_ref, sa_ref,
                        mx_ref, no_ref, ce_ref, cec_ref, rd_ref,
                        rr_ref, *, trapezoid: bool):
    ts = ts_ref[...]
    v = v_ref[...]
    d, m = v.shape

    pt = jnp.concatenate(
        [pt0_ref[...][:, None],
         jnp.broadcast_to(ts[:-1][None, :], (d, m - 1))], axis=1)
    pv = jnp.concatenate([pv0_ref[...][:, None], v[:, :-1]], axis=1)
    has = jnp.concatenate(
        [has0_ref[...][:, None], jnp.full((d, m - 1), True)], axis=1)

    g = g_ref[...][:, None]
    off = off_ref[...][:, None]
    vc = (v - off) / g
    pvc = (pv - off) / g
    hold = jnp.minimum(ts[None, :] - pt, mh_ref[...][:, None])
    dens_r = 0.5 * (pv + v) if trapezoid else pv
    dens_c = 0.5 * (pvc + vc) if trapezoid else pvc
    inc = jnp.where(has, dens_r * hold, 0.0)
    inc_c = jnp.where(has, dens_c * hold, 0.0)
    cum_e = jnp.cumsum(inc, axis=1)
    cum_ec = jnp.cumsum(inc_c, axis=1)
    ce_ref[...] = cum_e
    cec_ref[...] = cum_ec
    de_ref[...] = cum_e[:, -1]
    dec_ref[...] = cum_ec[:, -1]

    a = wa_ref[...][:, None]
    b = wb_ref[...][:, None]
    w_inc = jnp.where(
        has & (pt >= a),
        dens_r * jnp.maximum(jnp.minimum(pt + hold, b) - pt, 0.0), 0.0)
    pts = pt - tsh_ref[...][:, None]
    w_inc_c = jnp.where(
        has & (pts >= a),
        dens_c * jnp.maximum(jnp.minimum(pts + hold, b) - pts, 0.0), 0.0)
    dw_ref[...] = jnp.sum(w_inc, axis=1)
    dwc_ref[...] = jnp.sum(w_inc_c, axis=1)

    # run tracking: the latest change at-or-before each column via an
    # in-kernel cummax over change column indices (the pre-slab state is
    # carried in run_t, so the scan never leaves the block)
    run_t = rt_ref[...]
    change = has & (v != pv)
    cols = lax.broadcasted_iota(jnp.int64, (d, m), 1)
    ci = jnp.where(change, cols, -1)
    acc = lax.cummax(ci, axis=1)
    acc_excl = jnp.concatenate(
        [jnp.full((d, 1), -1, jnp.int64), acc[:, :-1]], axis=1)
    run_start = jnp.where(acc_excl >= 0,
                          ts[jnp.maximum(acc_excl, 0)], run_t[:, None])
    rd_ref[...] = jnp.where(change, ts[None, :] - run_start, 0.0)
    cchg = jnp.cumsum(change.astype(jnp.int64), axis=1)
    rr_ref[...] = change & (
        nch_ref[...][:, None] + (cchg - change) >= 1)
    last = acc[:, -1]
    nrt_ref[...] = jnp.where(last >= 0, ts[jnp.maximum(last, 0)], run_t)
    nnc_ref[...] = nch_ref[...] + cchg[:, -1]
    nv_ref[...] = v[:, -1]

    av = jnp.abs(vc)
    out = (vc < el_ref[...][:, None]) | (vc > eh_ref[...][:, None])
    sv_ref[...] = jnp.sum(vc, axis=1)
    sv2_ref[...] = jnp.sum(vc * vc, axis=1)
    sa_ref[...] = jnp.sum(av, axis=1)
    mx_ref[...] = jnp.max(av, axis=1)
    no_ref[...] = jnp.sum(out, axis=1).astype(jnp.int64)


@functools.partial(jax.jit, static_argnums=(15, 16))
def _stream_ingest_grid_impl(ts, v, prev_t, prev_v, has_prev, run_t,
                             n_changes, gain, offset, tshift, win_a,
                             win_b, max_hold, env_lo, env_hi,
                             trapezoid: bool, interpret: bool):
    d, m = v.shape
    bd = min(_GRID_BLOCK_D, max(d, 1))
    dp = -(-d // bd) * bd
    # neutral device padding (dropped by the [:d] slices below)
    pad2 = lambda x: jnp.concatenate(
        [x, jnp.zeros((dp - d, m), dtype=x.dtype)]) if dp != d else x
    args = (
        ts, pad2(v), _pad_to(prev_t, dp, 0.0), _pad_to(prev_v, dp, 0.0),
        _pad_to(has_prev, dp, False), _pad_to(run_t, dp, 0.0),
        _pad_to(n_changes, dp, 0), _pad_to(gain, dp, 1.0),
        _pad_to(offset, dp, 0.0), _pad_to(tshift, dp, 0.0),
        _pad_to(win_a, dp, jnp.inf), _pad_to(win_b, dp, -jnp.inf),
        _pad_to(max_hold, dp, 0.0), _pad_to(env_lo, dp, -jnp.inf),
        _pad_to(env_hi, dp, jnp.inf))
    row = pl.BlockSpec((bd,), lambda i: (i,))
    mat = pl.BlockSpec((bd, m), lambda i: (i, 0))
    vec = functools.partial(jax.ShapeDtypeStruct, (dp,))
    slab = functools.partial(jax.ShapeDtypeStruct, (dp, m))
    outs = pl.pallas_call(
        functools.partial(_ingest_grid_kernel, trapezoid=trapezoid),
        grid=(dp // bd,),
        in_specs=[pl.BlockSpec((m,), lambda i: (0,))] + [mat]
        + [row] * 13,
        out_specs=[row] * 12 + [mat] * 4,
        out_shape=[vec(jnp.float64), vec(jnp.float64), vec(jnp.int64)]
        + [vec(jnp.float64)] * 8 + [vec(jnp.int64)]
        + [slab(jnp.float64), slab(jnp.float64), slab(jnp.float64),
           slab(jnp.bool_)],
        interpret=interpret,
    )(*args)
    return tuple(o[:d] for o in outs)


def stream_ingest_grid(ts, v, prev_t, prev_v, has_prev, run_t, n_changes,
                       gain, offset, tshift, win_a, win_b, max_hold,
                       env_lo, env_hi, trapezoid: bool = False) -> Tuple:
    """Rectangular-slab streaming ingest (see the numpy backend's
    reference docstring) as one fused row-block Pallas kernel."""
    ts = np.asarray(ts, dtype=np.float64)
    v = np.asarray(v, dtype=np.float64)
    if v.shape[1] == 0:
        return _nb.stream_ingest_grid(
            ts, v, prev_t, prev_v, has_prev, run_t, n_changes, gain,
            offset, tshift, win_a, win_b, max_hold, env_lo, env_hi,
            trapezoid)
    with enable_x64():
        outs = _stream_ingest_grid_impl(
            jnp.asarray(ts, jnp.float64), jnp.asarray(v, jnp.float64),
            jnp.asarray(prev_t, jnp.float64),
            jnp.asarray(prev_v, jnp.float64),
            jnp.asarray(has_prev, jnp.bool_),
            jnp.asarray(run_t, jnp.float64),
            jnp.asarray(n_changes, jnp.int64),
            jnp.asarray(gain, jnp.float64),
            jnp.asarray(offset, jnp.float64),
            jnp.asarray(tshift, jnp.float64),
            jnp.asarray(win_a, jnp.float64),
            jnp.asarray(win_b, jnp.float64),
            jnp.asarray(max_hold, jnp.float64),
            jnp.asarray(env_lo, jnp.float64),
            jnp.asarray(env_hi, jnp.float64),
            bool(trapezoid), _interpret())
    return tuple(np.asarray(o) for o in outs)


# -- step_integrate: row-blocked window integration -------------------------

def _step_kernel(ts_ref, vals_ref, t0_ref, t1_ref, o_ref, *,
                 trapezoid: bool):
    ts = ts_ref[...]
    vals = vals_ref[...]
    t0 = t0_ref[...]
    t1 = t1_ref[...]
    n, m = ts.shape
    nxt = ts[:, 1:]
    nxt_finite = nxt < jnp.inf
    dt = jnp.where(nxt_finite, nxt - ts[:, :-1], 0.0)
    if trapezoid:
        dens = 0.5 * (vals[:, :-1]
                      + jnp.where(nxt_finite, vals[:, 1:], 0.0))
    else:
        dens = vals[:, :-1]
    cum = jnp.concatenate(
        [jnp.zeros((n, 1)), jnp.cumsum(dens * dt, axis=1)], axis=1)

    # counting == binary search on the sorted, inf-padded rows
    j0 = jnp.sum(ts < t0[:, None], axis=1)
    j1 = jnp.sum(ts <= t1[:, None], axis=1) - 1
    j0c = jnp.clip(j0, 0, m - 1)[:, None]
    j1c = jnp.clip(j1, 0, m - 1)[:, None]
    core = (jnp.take_along_axis(cum, j1c, axis=1)
            - jnp.take_along_axis(cum, j0c, axis=1))[:, 0]
    tail = (jnp.take_along_axis(vals, j1c, axis=1)[:, 0]
            * (t1 - jnp.take_along_axis(ts, j1c, axis=1)[:, 0]))
    nonempty = (j1 >= j0) & (j0 < m)
    o_ref[...] = jnp.where(nonempty, core + tail, 0.0)


@functools.partial(jax.jit, static_argnums=(4, 5))
def _step_integrate_impl(ts, vals, t0, t1, trapezoid: bool,
                         interpret: bool):
    n, m = ts.shape
    bn = min(_STEP_BLOCK_N, max(n, 1))
    npad = -(-n // bn) * bn
    if npad != n:
        # inf-padded rows integrate to zero (j1 = -1 -> nonempty False)
        ts = jnp.concatenate([ts, jnp.full((npad - n, m), jnp.inf)])
        vals = jnp.concatenate([vals, jnp.zeros((npad - n, m))])
        t0 = _pad_to(t0, npad, 0.0)
        t1 = _pad_to(t1, npad, 0.0)
    out = pl.pallas_call(
        functools.partial(_step_kernel, trapezoid=trapezoid),
        grid=(npad // bn,),
        in_specs=[pl.BlockSpec((bn, m), lambda i: (i, 0)),
                  pl.BlockSpec((bn, m), lambda i: (i, 0)),
                  pl.BlockSpec((bn,), lambda i: (i,)),
                  pl.BlockSpec((bn,), lambda i: (i,))],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((npad,), jnp.float64),
        interpret=interpret,
    )(ts, vals, t0, t1)
    return out[:n]


def step_integrate(ts: np.ndarray, vals: np.ndarray, t0: np.ndarray,
                   t1: np.ndarray, trapezoid: bool = False) -> np.ndarray:
    """Batched rectangle/trapezoid step integration (see the numpy
    backend's reference docstring) as a row-blocked Pallas kernel."""
    ts = np.asarray(ts, dtype=np.float64)
    if ts.shape[1] == 0:    # no samples at all: every window is 0
        return np.zeros(ts.shape[0])
    with enable_x64():
        return np.asarray(_step_integrate_impl(
            jnp.asarray(ts, jnp.float64), jnp.asarray(vals, jnp.float64),
            jnp.asarray(t0, jnp.float64), jnp.asarray(t1, jnp.float64),
            bool(trapezoid), _interpret()))


# -- log_filter: blocked sequential scan over segments ----------------------

def _scan_kernel(a_ref, b_ref, y0_ref, o_ref, carry):
    si = pl.program_id(1)

    @pl.when(si == 0)
    def _init():
        carry[...] = y0_ref[...]

    a = a_ref[...]
    b = b_ref[...]

    def step(i, y):
        y = a[i, :] * y + b[i, :]
        o_ref[i, :] = y
        return y

    carry[0, :] = lax.fori_loop(0, a.shape[0], step, carry[0, :])


@functools.partial(jax.jit, static_argnums=(5,))
def _log_filter_impl(tl, ticks, tau, t_lo, t_hi, interpret: bool):
    # prologue: identical segment coefficients to the jax tier
    g = ticks.shape[0]
    r = tl.edges.shape[0]
    ext_e = jnp.concatenate([jnp.full((r, 1), t_lo), tl.edges,
                             jnp.full((r, 1), t_hi)], axis=1)
    ext_p = jnp.concatenate([tl.idle_w[:, None], tl.powers,
                             tl.idle_w[:, None]], axis=1)
    n_seg = ext_p.shape[1]
    dts = jnp.broadcast_to(jnp.diff(ext_e, axis=1), (g, n_seg))
    sp = jnp.broadcast_to(ext_p, (g, n_seg))
    decay = jnp.exp(-dts / tau[:, None])
    a_seg = jnp.where(dts > 0, decay, 1.0)
    b_seg = jnp.where(dts > 0, sp * (1.0 - decay), 0.0)
    y0 = jnp.broadcast_to(tl.idle_w, (g,))

    # blocked sequential scan: transpose to [segments, rows], pad the
    # segment axis with identity steps (a=1, b=0) and the row axis with
    # zero columns, grid iterates segment chunks innermost
    ch = min(_SCAN_CHUNK, max(n_seg, 1))
    bg = min(_SCAN_BLOCK_G, max(g, 1))
    sp_n = -(-n_seg // ch) * ch
    gp = -(-g // bg) * bg
    aT = jnp.ones((sp_n, gp)).at[:n_seg, :g].set(a_seg.T)
    bT = jnp.zeros((sp_n, gp)).at[:n_seg, :g].set(b_seg.T)
    y0p = _pad_to(y0, gp, 0.0)[None, :]
    yT = pl.pallas_call(
        _scan_kernel,
        grid=(gp // bg, sp_n // ch),
        in_specs=[pl.BlockSpec((ch, bg), lambda gi, si: (si, gi)),
                  pl.BlockSpec((ch, bg), lambda gi, si: (si, gi)),
                  pl.BlockSpec((1, bg), lambda gi, si: (0, gi))],
        out_specs=pl.BlockSpec((ch, bg), lambda gi, si: (si, gi)),
        out_shape=jax.ShapeDtypeStruct((sp_n, gp), jnp.float64),
        scratch_shapes=[pltpu.VMEM((1, bg), jnp.float64)],
        interpret=interpret,
    )(aT, bT, y0p)
    y = jnp.concatenate([y0[:, None], yT[:n_seg, :g].T], axis=1)

    # epilogue: locate each tick's segment and decay from its entry state
    ext_e_g = jnp.broadcast_to(ext_e, (g, n_seg + 1))
    idx = jnp.clip(_jb._searchsorted_rows(ext_e, ticks, "right") - 1,
                   0, n_seg - 1)
    y_at = jnp.take_along_axis(y, idx, axis=1)
    sp_at = jnp.take_along_axis(sp, idx, axis=1)
    e_at = jnp.take_along_axis(ext_e_g, idx, axis=1)
    return sp_at + (y_at - sp_at) * jnp.exp(-(ticks - e_at)
                                            / tau[:, None])


def log_filter(tl, ticks: np.ndarray, tau: np.ndarray) -> np.ndarray:
    """Logarithmic-filter readings (see the numpy backend's reference
    docstring); the per-segment affine recurrence runs as a blocked
    sequential Pallas scan with the filter state carried in VMEM."""
    tau = np.asarray(tau, dtype=np.float64)
    t_lo = (min(float(np.min(ticks)), float(np.min(tl.t_start)))
            - 5.0 * float(np.max(tau)))
    t_hi = max(float(np.max(ticks)), float(np.max(tl.t_end))) + 1e-9
    with enable_x64():
        return np.asarray(_log_filter_impl(
            tl, jnp.asarray(ticks, jnp.float64), jnp.asarray(tau),
            jnp.float64(t_lo), jnp.float64(t_hi), _interpret()))
